"""End-to-end driver: train a DiT with the production substrate — sharded
train step (pjit), async fault-tolerant checkpointing, resume, data
pipeline — then sample a grid of class-conditional latents.

This is the paper's training-side substrate at CPU scale; the identical
code path scales to the 256-chip mesh via --data/--model (see
launch/train.py for the full launcher and launch/dryrun.py for the
production-mesh proof).

Run:  PYTHONPATH=src python examples/train_dit.py [--steps 300]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt
from repro.data import LatentPipeline
from repro.diffusion import DiffusionCfg, ddpm_sample, make_schedule
from repro.distributed import param_specs
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import make_dit_train_step
from repro.models import DiTCfg, dit_apply, dit_init
from repro.optim import adamw, cosine_schedule

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=32)
ap.add_argument("--ckpt", default="/tmp/dit_example_ckpt")
args = ap.parse_args()

cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=96, n_layers=3,
             n_heads=4, n_classes=8)
dif = DiffusionCfg(T=1000, tgq_groups=10)
sched = make_schedule(dif)
mesh = make_debug_mesh(1, 1)
pipe = LatentPipeline(cfg.img_size, cfg.in_ch, cfg.n_classes, seed=7)

key = jax.random.PRNGKey(0)
params = dit_init(key, cfg)
opt = adamw(cosine_schedule(2e-3, 30, args.steps))
opt_state = opt.init(params)

start = ckpt.latest_step(args.ckpt) or 0
if start:
    state = ckpt.restore(args.ckpt, {"p": params, "o": opt_state})
    params, opt_state = state["p"], state["o"]
    print(f"resumed from step {start}")

pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      param_specs(params, mesh))
step_fn = make_dit_train_step(cfg, opt, sched)

with mesh:
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    t0 = time.time()
    for i in range(start, args.steps):
        key, k1, k2, k3 = jax.random.split(key, 4)
        x0, y = pipe.sample(args.batch, k1)
        batch = {"x0": x0, "y": y,
                 "t": jax.random.randint(k2, (args.batch,), 0, dif.T),
                 "noise": jax.random.normal(k3, x0.shape)}
        loss, params, opt_state = jstep(params, opt_state, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({(time.time()-t0)/(i-start+1)*1000:.0f} ms/step)",
                  flush=True)
        if (i + 1) % 100 == 0:
            ckpt.save_async(args.ckpt, i + 1, {"p": params, "o": opt_state})
    ckpt.wait_async()
    ckpt.save(args.ckpt, args.steps, {"p": params, "o": opt_state})

# sample one latent per class
eps = lambda x, t, y, ctx: dit_apply(params, cfg, x, t, y)
y = jnp.arange(cfg.n_classes)
out = ddpm_sample(eps, dif, sched, (cfg.n_classes, 8, 8, 4), y,
                  jax.random.PRNGKey(1), steps=50)
real, _ = pipe.sample(cfg.n_classes, jax.random.PRNGKey(2))
print("per-class sample/real correlation:")
for c in range(cfg.n_classes):
    g = np.asarray(out[c]).ravel()
    r = np.asarray(pipe.patterns[c]).ravel()
    print(f"  class {c}: corr={np.corrcoef(g, r)[0, 1]:.3f}")
