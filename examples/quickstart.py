"""Quickstart: TQ-DiT in ~60 lines.

Trains a tiny DiT on synthetic latents for a few steps, calibrates W8A8
quantization with the full TQ-DiT pipeline (HO + MRQ + TGQ), and samples
from both the FP and the quantized model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import build_dit_calibration, dit_loss_fn
from repro.quant import QuantRecipe, quantize
from repro.diffusion import DiffusionCfg, ddpm_sample, make_schedule, q_sample
from repro.models import DiTCfg, dit_apply, dit_init
from repro.optim import adamw, apply_updates

# --- 1. a small DiT ---------------------------------------------------------
cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
             n_heads=4, n_classes=8)
dif = DiffusionCfg(T=100, tgq_groups=4)
sched = make_schedule(dif)
key = jax.random.PRNGKey(0)
params = dit_init(key, cfg)

# --- 2. brief training on synthetic latents ---------------------------------
opt = adamw(2e-3)
opt_state = opt.init(params)

@jax.jit
def train_step(p, o, x0, t, y, noise):
    def loss(p):
        xt = q_sample(sched, x0, t, noise)
        return jnp.mean((dit_apply(p, cfg, xt, t, y) - noise) ** 2)
    l, g = jax.value_and_grad(loss)(p)
    u, o = opt.update(g, o, p)
    return l, apply_updates(p, u), o

for i in range(60):
    key, k1, k2, k3, k4 = jax.random.split(key, 5)
    x0 = jax.random.normal(k1, (16, 8, 8, 4)) * 0.5
    t = jax.random.randint(k2, (16,), 0, dif.T)
    y = jax.random.randint(k3, (16,), 0, cfg.n_classes)
    l, params, opt_state = train_step(params, opt_state, x0, t, y,
                                      jax.random.normal(k4, x0.shape))
print(f"trained: loss={float(l):.3f}")

# --- 3. TQ-DiT post-training quantization (Algorithm 1) ---------------------
calib = build_dit_calibration(
    params, cfg, dif, sched,
    lambda n, k: jax.random.normal(k, (n, 8, 8, 4)) * 0.5,
    jax.random.PRNGKey(1), n_per_group=4, batch=4)
recipe = QuantRecipe(bits="w8a8", method="ho", tgq_groups=4, n_alpha=8,
                     rounds=2)
artifact = quantize(params, cfg, dif, recipe, calib_data=calib, sched=sched)
print(f"calibrated {artifact.summary()} "
      f"in {artifact.meta['calib']['wall_s']:.1f}s")

# --- 4. sample FP vs quantized ----------------------------------------------
eps = lambda x, t, y, ctx: dit_apply(params, cfg, x, t, y, ctx=ctx)
y = jnp.arange(4) % cfg.n_classes
k = jax.random.PRNGKey(2)
fp = ddpm_sample(eps, dif, sched, (4, 8, 8, 4), y, k, steps=20)
qt = ddpm_sample(eps, dif, sched, (4, 8, 8, 4), y, k, steps=20,
                 ctx=artifact.context(kernel=False))   # fake-quant fidelity
drift = float(jnp.abs(fp - qt).mean() / jnp.abs(fp).mean())
print(f"W8A8 sample drift vs FP: {drift:.4f} (should be small)")
