"""Beyond-paper transfer: apply the TQ-DiT quantization stack to an
assigned LM architecture (qwen3 family — SwiGLU + GQA + qk-norm).

The technique maps as: per-channel weight quant + HO search (unchanged),
MRQ-softmax on attention probabilities (unchanged), MRQ-signed on the
SiLU gate (the GELU two-lobe construction transfers; DESIGN §5), TGQ
disabled (no diffusion timestep). Measures CE-loss drift at W8A8/W6A6.

Run:  PYTHONPATH=src python examples/lm_ptq.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import (QuantContext, build_lm_calibration, lm_loss_fn,
                        run_ptq)
from repro.core.baselines import SCHEMES
from repro.data import TokenPipeline
from repro.models import lm_init
from repro.nn.ctx import FPContext

cfg = get_smoke("qwen3-1.7b")
key = jax.random.PRNGKey(0)
params = lm_init(key, cfg)

pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, batch=4, seed=5)
calib = build_lm_calibration([pipe.batch_at(i)["tokens"] for i in range(6)])
evalb = build_lm_calibration([pipe.batch_at(100 + i)["tokens"]
                              for i in range(4)])
loss = lm_loss_fn(params, cfg)
fp = sum(float(loss(FPContext(), b)) for b, _ in evalb) / len(evalb)
print(f"FP eval CE: {fp:.4f}")

for bits in (8, 6):
    for scheme in ("baseline", "tq_dit"):
        t0 = time.time()
        qp, rep = run_ptq(loss, calib,
                          SCHEMES[scheme](bits, bits, n_alpha=10, rounds=2))
        ctx = QuantContext(qparams=qp)
        q = sum(float(loss(ctx, b)) for b, _ in evalb) / len(evalb)
        print(f"W{bits}A{bits} {scheme:9s}: CE {q:.4f} "
              f"(drift {q-fp:+.4f}, calib {rep['wall_s']:.0f}s)")
