"""Serve a quantized DiT: batched class-conditional requests through the
respaced DDPM sampler with TQ-DiT W8A8 execution, including the int8
Pallas kernel deployment path for eligible linears.

Run:  PYTHONPATH=src:. python examples/serve_quantized.py
(the repo root must be on the path too — this example reuses the
benchmark harness in ``benchmarks/``).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import QuantContext
from repro.core.contexts import CalibrationContext, RecordingContext
from repro.core import dit_loss_fn
from repro.diffusion import ddpm_sample, make_schedule
from repro.kernels import ops as kops
from repro.models import dit_apply

print("loading / training the benchmark DiT ...")
cfg, params = C.trained_dit()
sched = make_schedule(C.DIF)

print("calibrating W8A8 (TQ-DiT) ...")
calib = C.calibration_set(params, cfg, n_per_group=16, batch=8)
qp, rep = C.calibrate("tq_dit", 8, params, cfg, calib)
print(f"  {rep['n_quantized']} ops, {rep['wall_s']:.1f}s wall")

# --- deployment packing: int8 codes for eligible linears ---------------------
rec = RecordingContext()
loss = dit_loss_fn(params, cfg)
loss(rec, calib[0][0])
cal = CalibrationContext(registry=rec.registry, max_rows_per_batch=8)
cal.begin_batch()
loss(cal, calib[0][0])
qp_kernel = kops.convert_for_kernels(qp, cal.weights)
n_int8 = sum(1 for v in qp_kernel.values() if "int8" in v)
n_mrq = sum(1 for v in qp_kernel.values() if "int8_mrq" in v)
n_tgq = sum(1 for v in qp_kernel.values()
            if v.get("int8", v.get("int8_mrq", {})).get("groups", 1) > 1)
print(f"  packed {n_int8} fused-quantize + {n_mrq} single-pass-MRQ linears "
      f"for the int8 MXU kernels ({n_tgq} time-grouped)")

# --- batched serving ----------------------------------------------------------
def serve(requests, ctx, kernel=False, steps=25):
    """requests: list of class ids."""
    y = jnp.asarray(requests)
    eps = lambda x, t, yy, c: dit_apply(params, cfg, x, t, yy, ctx=c)
    return ddpm_sample(eps, C.DIF, sched,
                       (len(requests), cfg.img_size, cfg.img_size, cfg.in_ch),
                       y, jax.random.PRNGKey(42), steps=steps, ctx=ctx)

reqs = list(range(8)) * 2
from repro.nn.ctx import FPContext
for name, ctx in [("FP", FPContext()),
                  ("W8A8 fake-quant", QuantContext(qparams=qp)),
                  ("W8A8 int8-kernel", QuantContext(qparams=qp_kernel,
                                                    kernel=True))]:
    t0 = time.time()
    out = serve(reqs, ctx)
    out.block_until_ready()
    print(f"{name:18s}: {len(reqs)} samples x 25 steps in "
          f"{time.time()-t0:5.1f}s  mean={float(out.mean()):+.3f} "
          f"std={float(out.std()):.3f}")

# quality check: quantized output close to FP
fp = serve(reqs, FPContext())
qt = serve(reqs, QuantContext(qparams=qp))
print(f"W8A8 vs FP drift: {float(jnp.abs(fp-qt).mean()/jnp.abs(fp).mean()):.4f}")
