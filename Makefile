.PHONY: verify verify-all kernel-micro bench-attn bench-flash bench-int4 \
	bench-vector-tgq bench-residue bench-serve serve-throughput \
	serve-poisson chaos serve-async-smoke docs-check artifact-smoke \
	autotune-smoke

# tier-1 verify: fast suite, `slow` deselected (pyproject addopts)
verify:
	python -m pytest -x -q

# include the multi-minute end-to-end runs
verify-all:
	python -m pytest -x -q -m ""

kernel-micro:
	PYTHONPATH=src python -m benchmarks.kernel_micro

# attention rows only: int8 QK^T / softmax->codes / P·V / flash
# correctness + modeled probs-traffic saving (fp round-trip vs int8 codes)
bench-attn:
	PYTHONPATH=src python -m benchmarks.kernel_micro --attn

# flash rows only: the fused single-kernel attention vs the composed
# three-kernel path (correctness within the documented tolerance + the
# whole-attention traffic cut from eliminating the (S,S) HBM round-trip)
bench-flash:
	PYTHONPATH=src python -m benchmarks.kernel_micro --flash

# packed-int4 rows only: int4_matmul_fq / int4_matmul_mrq_fq vs their
# oracles + packed-kv flash bit-identity; ASSERTS the >=1.8x
# weight-traffic cut vs int8 (nibble payload + per-K-group metadata)
bench-int4:
	PYTHONPATH=src python -m benchmarks.kernel_micro --int4

# vector-tgroup rows only: per-row-gather kernels vs their oracles +
# the mixed-timestep dispatch traffic model; ASSERTS the one-weight-read
# contract (weight bytes per dispatch independent of active-slot count)
bench-vector-tgq:
	PYTHONPATH=src python -m benchmarks.kernel_micro --vector-tgq

# prologue/epilogue fusion-residue audit: the fully fused kernel vs its
# oracle + the XL/2 block traffic table; ASSERTS zero uncharged
# adaLN/residual fp bytes and the >=1.15x modeled block traffic win vs
# the pre-fusion baseline
bench-residue:
	PYTHONPATH=src python -m benchmarks.kernel_micro --residue

# machine-readable modeled serving trajectory (writes BENCH_serve.json):
# fp / w8a8 / w4a4 req/s, sync bucketed vs async continuous batching;
# ASSERTS async modeled cost per slot-step <= sync at 2 slots/device
bench-serve:
	PYTHONPATH=src python -m benchmarks.serve_throughput --bench-json

serve-throughput:
	PYTHONPATH=src python -m benchmarks.serve_throughput

# open-loop Poisson arrivals: continuous batching vs the step-bucketed
# baseline at equal modeled cost, + async==sync bit-identity (measured)
serve-poisson:
	PYTHONPATH=src python -m benchmarks.serve_throughput --arrivals poisson

# fault-injection suite under a hard timeout (a hung async loop must
# FAIL, not stall); CI runs the same command in its chaos job
chaos:
	timeout 600 python -m pytest tests/test_chaos.py tests/test_async_serving.py -q

# async continuous-batching serving smoke on CPU (quantized)
serve-async-smoke:
	PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
		--async --requests 4 --microbatch 2 --steps 2 --chunk 2 \
		--quantize w8a8

# docs link/anchor check + execution of the `# ci-smoke` quickstart lines
docs-check:
	python tools/check_docs.py --run README.md docs/*.md

# recipe auto-search smoke: a 6-trial grid (w8a8/w4a4 x 2 group counts
# + 2 mixed-precision bit budgets) on a short-trained tiny DiT, run as
# the full kill/resume protocol — (1) killed after 3 newly-calibrated
# trials, (2) resumed to completion with the frontier-endpoint asserts
# (fastest point w4a4, a w8a8 point present, strict quality/throughput
# trade-off), (3) re-run asserting EVERY trial cache-hits and the
# frontier on disk is reproduced. Hard per-phase timeout: a hung sweep
# must fail, not stall.
AUTOTUNE_DIR ?= /tmp/tqdit-autotune-smoke
AUTOTUNE_ARGS = --arch tiny --out $(AUTOTUNE_DIR) --bits w8a8,w4a4 \
	--groups default,5 --budgets 5,6
autotune-smoke:
	rm -rf $(AUTOTUNE_DIR)
	timeout 600 env PYTHONPATH=src python -m repro.launch.autotune \
		$(AUTOTUNE_ARGS) --max-new-stage1 3
	timeout 900 env PYTHONPATH=src python -m repro.launch.autotune \
		$(AUTOTUNE_ARGS) --assert-endpoints
	timeout 300 env PYTHONPATH=src python -m repro.launch.autotune \
		$(AUTOTUNE_ARGS) --assert-endpoints --assert-resumed

# the quantization-artifact lifecycle on CPU: quantize w8a8 -> save ->
# load in a FRESH process (no calibration) -> serve 2 requests
ARTIFACT_DIR ?= /tmp/tqdit-artifact-smoke
artifact-smoke:
	PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
		--requests 2 --microbatch 2 --steps 2 --quantize w8a8 \
		--save-artifact $(ARTIFACT_DIR)
	PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
		--requests 2 --microbatch 2 --steps 2 --quantize w8a8 \
		--load-artifact $(ARTIFACT_DIR)
