.PHONY: verify verify-all kernel-micro

# tier-1 verify: fast suite, `slow` deselected (pyproject addopts)
verify:
	python -m pytest -x -q

# include the multi-minute end-to-end runs
verify-all:
	python -m pytest -x -q -m ""

kernel-micro:
	PYTHONPATH=src python -m benchmarks.kernel_micro
