import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# Performance hillclimbing harness (EXPERIMENTS.md section "Perf").
#
# Three cells chosen from the 34-cell baseline:
#   qwen2.5-14b x train_4k   — worst roofline fraction (0.01)
#   kimi-k2-1t-a32b x train_4k — most collective-bound in absolute terms
#   dit-xl-2 x sample_128    — the paper's own serving workload
#
# Each named variant is hypothesis -> change -> re-lower -> re-analyse;
# results append to experiments/perf.json.
#
# Run: PYTHONPATH=src python -m benchmarks.perf_iter --exp <name>

import argparse
import json
import time

import jax
import numpy as np


def measure_variant(arch, shape_id, overrides=None, mesh_shape=None,
                    quantized_weights=False, replicate_params=False):
    """Like benchmarks.roofline.measure but with config overrides and an
    optional custom layout of the same 256 chips."""
    from repro.launch.steps import build_cell
    from repro.launch.hlo_stats import collective_stats
    from benchmarks.roofline import analyse

    if mesh_shape is not None:
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        tp = mesh_shape[1]
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=False)
        tp = 16
    if replicate_params:
        tp = 1

    rec = {}
    for L in (1, 2):
        over = {"n_layers": L, "scan_layers": False, "remat": False,
                "grad_accum": 1}
        if arch == "whisper-tiny":
            over["n_enc_layers"] = L
        if arch == "hymba-1.5b":
            over["global_layers"] = ()
        over.update(overrides or {})
        cell = build_cell(arch, shape_id, mesh, cfg_overrides=over,
                          force_micro=1, replicate_params=replicate_params)
        with mesh:
            compiled = jax.jit(
                cell["fn"], in_shardings=cell["in_shardings"],
                donate_argnums=cell["donate_argnums"]).lower(
                *cell["args"]).compile()
        cost = compiled.cost_analysis()
        colls = collective_stats(compiled.as_text())
        rec[L] = {"flops": float(cost.get("flops", 0.0)),
                  "bytes": float(cost.get("bytes accessed", 0.0)),
                  "coll": float(sum(v["bytes"] for v in colls.values())),
                  "meta": cell["meta"]}
    r = analyse(arch, shape_id, rec, tp=tp)
    if quantized_weights:
        # int8 weights: halve the analytic weight-read traffic and the
        # MXU compute time (2x int8 peak) — the paper's deployment effect
        # on the roofline terms (weight bytes dominate decode/serve).
        r["t_memory_s"] = r["t_memory_s"] / 2
        r["t_compute_s"] = r["t_compute_s"] / 2
        _rebottleneck(r)
        r["note"] = "int8-weight terms (W8A8 serve)"
    return r


def _rebottleneck(r):
    dom = max(("compute", r["t_compute_s"]), ("memory", r["t_memory_s"]),
              ("collective", r["t_collective_s"]), key=lambda kv: kv[1])
    r["bottleneck"] = dom[0]
    r["roofline_frac"] = r["t_compute_s"] / dom[1] if dom[1] else 1.0


def dit_fused_serving_factor(d: int = 1152, T: int = 256) -> float:
    """Memory-term factor for the fused single-pass int8 serving kernels
    vs the unfused int8 path, from the per-block DiT traffic model
    (consistent with benchmarks/kernel_micro.py's per-op models).

    Weights: qkv 3d^2 + proj d^2 + fc1 4d^2 + fc2 4d^2 = 12d^2 int8 bytes;
    the UNFUSED two-matmul MRQ path reads fc2's 4d^2 TWICE -> 16d^2.
    Activation input traffic per element: UNFUSED pays the standalone
    quantize pass (4B fp32 read + 1B code write) plus the matmul's 1B code
    read = 6B; FUSED reads the fp32 tile once in-kernel = 4B. Linear
    inputs per block: qkv/proj/fc1 (T,d) + fc2 (T,4d) = 7*T*d elements.
    Both paths write the fp32 outputs once (3d+d+4d+d per token = 36*T*d
    bytes).
    """
    unfused = 16 * d * d + 6 * 7 * T * d + 36 * T * d
    fused = 12 * d * d + 4 * 7 * T * d + 36 * T * d
    return fused / unfused


def log(exp, hypothesis, variant, r):
    path = "experiments/perf.json"
    data = json.load(open(path)) if os.path.exists(path) else []
    entry = {"exp": exp, "variant": variant, "hypothesis": hypothesis,
             "t_compute_ms": round(r["t_compute_s"] * 1e3, 3),
             "t_memory_ms": round(r["t_memory_s"] * 1e3, 3),
             "t_collective_ms": round(r["t_collective_s"] * 1e3, 3),
             "bottleneck": r["bottleneck"],
             "roofline_frac": round(r["roofline_frac"], 3)}
    data.append(entry)
    os.makedirs("experiments", exist_ok=True)
    json.dump(data, open(path, "w"), indent=1)
    print(f"[perf] {exp} / {variant}: comp={entry['t_compute_ms']}ms "
          f"mem={entry['t_memory_ms']}ms coll={entry['t_collective_ms']}ms "
          f"-> {entry['bottleneck']} frac={entry['roofline_frac']}",
          flush=True)
    return entry


SP = (("data",), "model")


def exp_qwen14b():
    arch, shape = "qwen2.5-14b", "train_4k"
    r = measure_variant(arch, shape)
    log(arch, "baseline (head-sharded attention; 40 heads % 16 != 0 makes "
        "GSPMD all-reduce the (S,S) scores)", "baseline", r)
    r = measure_variant(arch, shape, overrides={"attn_sp": SP})
    log(arch, "SP attention: shard q/scores/probs on seq over the model "
        "axis -> no quadratic-tensor collectives; predicted coll "
        "~100x down", "sp_attn", r)
    r = measure_variant(arch, shape, overrides={"attn_sp": SP,
                                                "q_chunk": 2048,
                                                "attn_impl": "qchunk"})
    log(arch, "SP + q-chunked attention: bound transient scores "
        "(memory-side insurance; collective term should hold)",
        "sp_attn+qchunk", r)


def exp_kimi():
    arch, shape = "kimi-k2-1t-a32b", "train_4k"
    r = measure_variant(arch, shape)
    log(arch, "baseline (FSDP expert tables re-gathered per layer; GQA "
        "kv=8 heads also hit the scores all-reduce)", "baseline", r)
    r = measure_variant(arch, shape, overrides={"attn_sp": SP})
    log(arch, "SP attention first (same fix as qwen2.5-14b)", "sp_attn", r)
    r = measure_variant(arch, shape, overrides={"attn_sp": SP,
                                                "moe_groups": 16})
    log(arch, "MoE dispatch groups = dp size: dispatch per data shard -> "
        "smaller expert all-gathers / token all-to-alls", "sp+moe_groups", r)


def exp_dit():
    arch, shape = "dit-xl-2", "sample_128"
    r = measure_variant(arch, shape)
    log(arch, "baseline TP16xDP16: per-device compute 0.6ms vs 37ms "
        "residual all-reduces — TP is wasted on a 675M model at serve",
        "baseline", r)
    r = measure_variant(arch, shape, mesh_shape=(128, 2))
    log(arch, "relayout the same 256 chips as DP128 x TP2: TP all-reduce "
        "bytes fall 8x per device; predicted collective ~50x down, "
        "memory(weights)-bound at ~0.8ms", "dp128_tp2", r)
    r = measure_variant(arch, shape, mesh_shape=(128, 2),
                        replicate_params=True)
    log(arch, "pure DP serving (params replicated, 675M bf16 = 1.35GB "
        "fits easily): ZERO per-layer collectives; each device does the "
        "full model at batch 1 -> weight-read bound", "dp_replicated", r)
    r = measure_variant(arch, shape, mesh_shape=(128, 2),
                        replicate_params=True, quantized_weights=True)
    log(arch, "the paper's W8A8 on top: int8 weights halve the weight-read "
        "term AND the MXU time (2x int8 peak) -> balanced compute/memory",
        "dp_replicated+w8a8", r)
    # fused single-pass serving kernels on top of the int8 layout: the
    # in-VMEM quantize prologue removes the standalone activation quantize
    # pass and the single-pass MRQ kernel reads fc2 weights once instead
    # of twice (see dit_fused_serving_factor for the per-block model).
    f = dit_fused_serving_factor()
    r = dict(r)
    r["t_memory_s"] = r["t_memory_s"] * f
    _rebottleneck(r)
    log(arch, f"fused int8 serving kernels (int8_matmul_fq + single-pass "
        f"MRQ): no standalone quantize pass, one fc2 weight read -> "
        f"memory term x{f:.2f} on the weight/activation traffic model",
        "dp_replicated+w8a8+fused", r)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    choices=("all", "qwen14b", "kimi", "dit"))
    args = ap.parse_args()
    if args.exp in ("all", "qwen14b"):
        exp_qwen14b()
    if args.exp in ("all", "kimi"):
        exp_kimi()
    if args.exp in ("all", "dit"):
        exp_dit()


if __name__ == "__main__":
    main()


def exp_qwen14b_round2():
    """Round 2 after profiling the SP-attention HLO: the residual monster
    was the CE path — take_along_axis over vocab-sharded logits forced a
    37 GiB/device all-gather of the f32 logits. ce_loss was rewritten to
    the vocab-parallel form (iota-mask reduction + sharded logsumexp)."""
    arch, shape = "qwen2.5-14b", "train_4k"
    r = measure_variant(arch, shape, overrides={"attn_sp": SP})
    log(arch, "vocab-parallel CE (iota-mask reduction; no logits gather) "
        "+ SP attention; predicted collective ~50x down from baseline",
        "sp_attn+vp_ce", r)
    r = measure_variant(arch, shape)
    log(arch, "vocab-parallel CE alone (no SP attention) — isolate the "
        "contribution of each change", "vp_ce_only", r)


def exp_qwen14b_round3():
    """Round 3: after the head/embed FSDP-contraction fix (37 GiB logits
    all-reduce eliminated at the sharding-rule level), the remaining
    per-layer cost is the standard TP activation all-reduce, which scales
    with per-device batch. At fixed 256 chips, shrinking TP shrinks
    B_loc and the AR bytes 1:1 — and 40 heads divide TP=4/8, so the
    score-sharding problem vanishes without SP."""
    arch, shape = "qwen2.5-14b", "train_4k"
    r = measure_variant(arch, shape)
    log(arch, "fixed head/embed sharding rules (vocab-only, no fsdp on the "
        "contraction dim) — no SP needed", "headfix_tp16", r)
    r = measure_variant(arch, shape, overrides={"attn_sp": SP})
    log(arch, "head fix + SP attention (40 heads % 16 != 0 still pays "
        "score resharding at TP16)", "headfix_tp16_sp", r)
    r = measure_variant(arch, shape, mesh_shape=(32, 8))
    log(arch, "relayout 256 chips as DP32 x TP8: heads divide 8 -> clean "
        "head-sharded attention; AR bytes halve with B_loc", "dp32_tp8", r)
    r = measure_variant(arch, shape, mesh_shape=(64, 4))
    log(arch, "DP64 x TP4: AR bytes 4x down vs TP16; FSDP gather cost "
        "rises only ~2x (net win predicted ~3x)", "dp64_tp4", r)


def exp_qwen14b_round4():
    arch, shape = "qwen2.5-14b", "train_4k"
    r = measure_variant(arch, shape, mesh_shape=(128, 2))
    log(arch, "DP128 x TP2: AR bytes halve again; FSDP gather ~2x up; "
        "predicted coll ~1.9s vs compute 2.0s -> frac ~0.9", "dp128_tp2", r)


def exp_kimi_round2():
    """Round 2 after diagnosing the HLO: the monsters were (a) gate/up
    expert weights FSDP-sharded on their CONTRACTION dim d -> partial-sum
    all-reduces of the giant (E,C,f) tensors over "data", and (b) the
    global sort-based dispatch materializing the (NK,d) slot tensor
    cross-device. Fixed the expert sharding rules (f-dim FSDP) and added
    the EP dispatch pin (groups=dp, buffers G@data x E@model)."""
    arch, shape = "kimi-k2-1t-a32b", "train_4k"
    r = measure_variant(arch, shape)
    log(arch, "expert-FSDP rule fix alone (gate/up f-dim, down d-dim; no "
        "contraction dims)", "expert_fsdp_fix", r)
    r = measure_variant(arch, shape,
                        overrides={"moe_groups": 16,
                                   "moe_shard": (("data",), "model")})
    log(arch, "+ EP dispatch pin: local per-data-shard sort, buffers "
        "G@data x E@model (token all-to-all layout)", "ep_dispatch_pin", r)


def exp_kimi_round3():
    """Round 3: revert to the original expert rules (round 2 refuted both
    alternatives — recorded); remeasure the kimi baseline with only the
    head/embed fix, then try the one remaining safe lever: smaller TP
    (kv=8 heads divide TP=8, B_loc and AR bytes shrink)."""
    arch, shape = "kimi-k2-1t-a32b", "train_4k"
    r = measure_variant(arch, shape)
    log(arch, "reverted expert rules + head/embed fix only", "headfix", r)
    r = measure_variant(arch, shape, mesh_shape=(32, 8))
    log(arch, "DP32 x TP8: kv heads divide 8; EP=8 (48 experts/shard); "
        "B_loc halves -> activation ARs halve", "dp32_tp8", r)


def exp_kimi_round4():
    """Round 4: TP shrink refuted (dispatch cost is invariant to B_loc —
    the GLOBAL argsort keeps the slot tensors unsharded). Retry local
    dispatch (groups = dp) with the ORIGINAL expert rules, with and
    without the buffer pin."""
    arch, shape = "kimi-k2-1t-a32b", "train_4k"
    r = measure_variant(arch, shape, overrides={"moe_groups": 16})
    log(arch, "local dispatch: moe_groups=16 (argsort within each data "
        "shard; no sharding pins)", "moe_groups16", r)
    r = measure_variant(arch, shape,
                        overrides={"moe_groups": 16,
                                   "moe_shard": (("data",), "model")})
    log(arch, "local dispatch + buffer pin G@data x E@model",
        "moe_groups16_pin", r)
