"""Table IV: calibration-efficiency comparison — TQ-DiT vs the
PTQ4DiT-like baseline (salience redistribution, which needs a larger
capture and more search work). Reports wall-clock, stored calibration
bytes, and peak-RSS delta, mirroring the paper's GPU-hours / GPU-memory
comparison on this container's substrate."""
from __future__ import annotations

import resource
import time

from benchmarks import common as C


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main() -> None:
    cfg, params = C.trained_dit()

    rows = [("method", "wall_s", "capture_s", "search_s", "calib_MB",
             "n_batches")]
    # PTQ4DiT-like: salience balancing + 4x capture rows + 2x samples
    calib_big = C.calibration_set(params, cfg, n_per_group=64, batch=8,
                                  seed=31)
    t0 = time.time()
    _, rep_p = C.calibrate("ptq4dit", 8, params, cfg, calib_big, force=True,
                           max_rows_per_batch=512, rounds=3)
    rows.append(("ptq4dit-like", round(rep_p["wall_s"], 1),
                 round(rep_p["capture_s"], 1), round(rep_p["search_s"], 1),
                 round(rep_p["calib_bytes"] / 2**20, 1), rep_p["n_batches"]))

    calib = C.calibration_set(params, cfg)
    _, rep_t = C.calibrate("tq_dit", 8, params, cfg, calib, force=True,
                           rounds=3)
    rows.append(("tq_dit", round(rep_t["wall_s"], 1),
                 round(rep_t["capture_s"], 1), round(rep_t["search_s"], 1),
                 round(rep_t["calib_bytes"] / 2**20, 1), rep_t["n_batches"]))

    red_t = 100 * (1 - rep_t["wall_s"] / rep_p["wall_s"])
    red_m = 100 * (1 - rep_t["calib_bytes"] / rep_p["calib_bytes"])
    rows.append(("reduction_%", round(red_t, 1), "", "", round(red_m, 1), ""))
    print(f"[table4] time reduction {red_t:.1f}% (paper: 89.3%), "
          f"calib-memory reduction {red_m:.1f}% (paper: 45.4%)", flush=True)
    C.emit("table4", rows)


if __name__ == "__main__":
    main()
