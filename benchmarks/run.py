"""Benchmark aggregator — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only table3,fig2
  REPRO_DIT_STEPS=200 REPRO_N_GEN=128 ... --fast       # reduced budgets

The roofline matrix is heavyweight (512-device compiles) and runs as its
own module: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,table4,fig2,"
                         "fig3,kernel_micro")
    ap.add_argument("--fast", action="store_true",
                    help="reduced sampling budget (CI-scale)")
    args = ap.parse_args()

    if args.fast:
        os.environ.setdefault("REPRO_DIT_STEPS", "200")
        os.environ.setdefault("REPRO_N_GEN", "128")

    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    if want("fig2"):
        from benchmarks import fig2_distributions
        print("== Fig 2: value distributions ==", flush=True)
        fig2_distributions.main()
    if want("fig3"):
        from benchmarks import fig3_time_variance
        print("== Fig 3: timestep variance ==", flush=True)
        fig3_time_variance.main()
    if want("kernel_micro"):
        from benchmarks import kernel_micro
        print("== kernel micro ==", flush=True)
        kernel_micro.main()
    if want("table4"):
        from benchmarks import table4_efficiency
        print("== Table IV: calibration efficiency ==", flush=True)
        table4_efficiency.main()
    if want("table3"):
        from benchmarks import table3_ablation
        print("== Table III: ablation (W6A6) ==", flush=True)
        table3_ablation.main()
    if want("table1"):
        from benchmarks import table1_quality
        print("== Table I: quality (long schedule) ==", flush=True)
        table1_quality.main()
    if want("table2"):
        from benchmarks import table2_quality
        print("== Table II: quality (short schedule) ==", flush=True)
        table2_quality.main()
    print(f"== all done in {time.time()-t0:.0f}s ==", flush=True)


if __name__ == "__main__":
    main()
