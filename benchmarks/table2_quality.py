"""Table II: same comparison at the SHORT sampling schedule (paper: 100
steps; CPU-scale: 25 respaced steps)."""
from benchmarks import table1_quality


def main() -> None:
    table1_quality.main(bits_list=(8, 6), steps=20, table="table2")


if __name__ == "__main__":
    main()
