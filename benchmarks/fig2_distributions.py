"""Fig. 2: distribution statistics of post-softmax and post-GELU values in
DiT blocks — the asymmetry that motivates MRQ. Prints concentration and
skew stats (the CPU stand-in for the paper's histograms) and dumps
histogram arrays to experiments/."""
from __future__ import annotations

import numpy as np
import jax

from benchmarks import common as C
from repro.core import CalibrationContext, RecordingContext, dit_loss_fn


def main() -> None:
    cfg, params = C.trained_dit()
    calib = C.calibration_set(params, cfg, n_per_group=8, batch=8)
    loss = dit_loss_fn(params, cfg)
    rec = RecordingContext()
    loss(rec, calib[0][0])

    # capture post-softmax (pv einsum operand a) and post-gelu (fc2 input)
    import dataclasses
    cal = CalibrationContext(registry=rec.registry, max_rows_per_batch=512,
                             max_batch_sub=8)
    for b, g in calib[:8]:
        cal.begin_batch()
        loss(dataclasses.replace(cal, tgroup=g), b)

    probs = np.concatenate([r["a"].reshape(-1)
                            for r in cal.store["blk0/attn/pv"]])
    gelu = np.concatenate([r["x"].reshape(-1)
                           for r in cal.store["blk0/fc2"]])

    n_tok = cfg.n_tokens
    rows = [("tensor", "frac<uniform/2", "median", "min", "max", "skew")]
    for name, v in (("post_softmax", probs), ("post_gelu", gelu)):
        skew = float(((v - v.mean()) ** 3).mean() / (v.std() ** 3 + 1e-12))
        thr = 1.0 / (2 * n_tok) if name == "post_softmax" else 1 / 255
        rows.append((name,
                     round(float((np.abs(v) < thr).mean()), 4),
                     round(float(np.median(v)), 4),
                     round(float(v.min()), 4), round(float(v.max()), 4),
                     round(skew, 3)))
        print(f"[fig2] {name}: {rows[-1]}", flush=True)

    # paper claims (scaled to n_tokens=16 here; DiT-XL/2 has 256 tokens
    # where concentration is far stronger): post-softmax mass concentrated
    # well below its max with a long right tail; post-GELU has the bounded
    # negative lobe.
    probs_med, probs_max = rows[1][2], rows[1][4]
    assert probs_med < 0.25 * probs_max, "post-softmax not concentrated"
    assert rows[1][5] > 0.5, "post-softmax should be right-skewed"
    assert rows[2][3] < 0, "post-GELU should have a negative lobe"
    C.emit("fig2", rows)


if __name__ == "__main__":
    main()
