"""Serving throughput: fused-int8 vs fp requests/sec under the sharded
batched serving subsystem (``repro.serving``).

Two sections, same philosophy as ``kernel_micro``:

1. **Modeled (TPU v5e)** — per-op roofline over one CFG-paired DiT-XL/2
   denoising step at serving batch sizes. For every linear the fp path
   reads x, reads W, writes y in f32 (the repo's serving dtype); the
   fused-int8 path reads x in f32 but W as int8 codes and quantizes /
   dequantizes in VMEM (``int8_matmul_fq`` / ``int8_matmul_mrq_fq``
   traffic, see ``kernel_micro``). Attention is charged per path: fp pays
   the f32 probs round-trip through HBM; the int8 path charges the
   FLASH kernel's traffic model (``kernel_micro``'s
   ``traffic_attention_flash`` — q/k/v read f32 once and quantized in
   VMEM, the whole (S,S) scores/codes round-trip eliminated) at the
   MXU's 2x int8 throughput, with the composed three-kernel path
   (``attn_impl="composed"``) reported alongside — the roofline and the
   kernel micro-bench share ONE attention traffic model per impl, so the
   end-to-end ratio is honest rather than attention-at-fp conservative.
   The w4a4 recipe is reported as ``int4_packed``: packed-int4 linears
   (nibble payload + per-K-group metadata,
   ``kernel_micro.traffic_int4_linear``) and flash attention with the
   nibble-packed kv stream — asserted faster than int8 at the
   weight-bound serving point.
   The adaLN elementwise chains are charged per path: the quantized
   kernels fuse norm-modulate into their quantize prologues and
   gate+residual into their dequant epilogues (``int8_fused`` /
   ``int4_packed`` ``norm_mod=`` / ``gate_residual=``), so the fused
   paths carry no chain traffic beyond the kernel's own x/W/y streams —
   while the fp path honestly pays the HBM round-trips XLA's
   elementwise fusion cannot eliminate (normalized/modulated x
   re-materialized before qkv/fc1, the gate*out + residual read-modify-
   write after proj/fc2). GELU stays uncharged on BOTH paths (it is
   XLA-fused into fc1's output on fp and remains the one fp island
   between the quantized fc1/fc2 kernels — ``kernel_micro --residue``
   reports its bytes separately). Per-op time is
   ``max(bytes/hbm_bw, flops/peak)``. Serving
   is weight-bound at small per-device batch, which is exactly where the
   4x weight-byte reduction pays: the benchmark asserts >= 1.5x
   requests/sec at microbatch == n_devices (one request per device, the
   latency-optimized serving point).

2. **Measured (this host)** — the small serving DiT actually runs through
   ``ServeEngine`` fp and fused-int8 on forced host devices, quantized
   through the unified API (``repro.quant.quantize`` ->
   ``QuantArtifact``). CPU wall-clock for the int8 path is
   interpret-mode (meaningless as perf), so this section is a
   correctness gate: all requests served, and the SHARDED w8a8 samples
   are bit-identical to the single-device w8a8 samples for the same
   seeds.

3. **Poisson arrivals** (``--arrivals poisson``) — an event-driven
   simulation of the two serving policies under open-loop Poisson load,
   both charged the SAME modeled cost per slot-step (the honest
   comparison point: one slot per device, where the async engine's
   slot-map dispatch and the sync path's batched dispatch read the same
   weights per slot). The step-bucketed baseline waits to fill full
   same-bucket microbatches (draining partials when arrivals are
   exhausted) and commits the machine for a request's WHOLE chain; the
   continuous-batching policy admits at every ``chunk`` boundary and
   frees finished slots immediately. The benchmark asserts
   continuous-batching goodput >= the bucketed baseline at equal load,
   and (measured, small DiT) that the async engine's samples stay
   bit-identical to the synchronous path while compiling its in-flight
   executable exactly once.

4. **BENCH_serve.json** (``--bench-json``, ``make bench-serve``) — the
   machine-readable perf trajectory across PRs: modeled DiT-XL/2
   requests/sec for fp / w8a8 / w4a4 under BOTH serving policies (sync
   step-bucketed vs async continuous batching) at 2 slots per device.
   Since the vector-TGQ batched forward, one async dispatch advances ALL
   of a device's slots — mixed timesteps and all — through ONE weight
   read, so the async modeled cost per slot-step is no worse than the
   sync bucketed batch's (asserted here, at >= 2 slots/device), where
   the retired per-slot dispatch paid the whole weight stream per slot.

Run: PYTHONPATH=src:. python -m benchmarks.serve_throughput
     PYTHONPATH=src:. python -m benchmarks.serve_throughput --arrivals poisson
     PYTHONPATH=src:. python -m benchmarks.serve_throughput --bench-json
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.kernel_micro import (
    traffic_attention_flash, traffic_attention_flash_packed,
    traffic_attention_probs, traffic_attention_qk, traffic_int4_linear,
)
from repro.launch.mesh import HW
from repro.models.dit import DiTCfg

N_DEV = int(os.environ.get("REPRO_SERVE_DEVICES", 4))

# DiT-XL/2, the paper's serving workload (configs/dit_xl_2.py full()).
XL2 = DiTCfg(img_size=32, in_ch=4, patch=2, d_model=1152, n_layers=28,
             n_heads=16, mlp_ratio=4.0, n_classes=1000)


# ---------------------------------------------------------------------------
# analytic per-step roofline (importable; tests assert the 1.5x floor)
# ---------------------------------------------------------------------------
def _linear(M: int, K: int, N: int, path: str) -> Dict[str, float]:
    """One serving linear. fp: f32 x/W/y. int8: fused-kernel traffic
    (f32 x in, int8 W, f32 y out; codes + s32 accumulators never leave
    VMEM) at 2x MXU throughput."""
    flops = 2.0 * M * K * N
    if path == "fp":
        return {"bytes": 4 * M * K + 4 * K * N + 4 * M * N, "flops": flops,
                "peak": HW["peak_bf16_flops"]}
    if path == "int4":
        # packed-int4 weight stream: nibble payload + per-K-group
        # scale/corr metadata (kernel_micro.traffic_int4_linear); the
        # widened nibbles feed the same int8 MXU.
        t = traffic_int4_linear(M, K, N)
        return {"bytes": 4 * M * K + t["int4_weight"] + 4 * M * N,
                "flops": flops, "peak": HW["peak_int8_ops"]}
    return {"bytes": 4 * M * K + 1 * K * N + 4 * M * N, "flops": flops,
            "peak": HW["peak_int8_ops"]}


def _attention(R: int, T: int, d: int, H: int, path: str) -> Dict[str, float]:
    """QK^T + softmax + P.V for R samples of T tokens.

    fp: f32 q/k/v reads, f32 scores round-trip, and the (S,S) f32 probs
    written + read through HBM. int8 (the serving default,
    ``attn_impl="flash"``): ONE ``flash_attn_mrq`` kernel per block —
    q/k/v read f32 once and quantized in VMEM, output written once, the
    whole (S,S) scores/codes round-trip eliminated
    (``kernel_micro``'s ``traffic_attention_flash``, the SAME model the
    flash micro-bench rows report). int8_composed: the three-kernel
    chain (``int8_bmm_qk`` -> ``softmax_mrq_codes`` -> ``int8_bmm_pv``,
    ``attn_impl="composed"``), which still pays the (S,S) f32 scores
    write+read and int8 code write+read. All int8 matmuls at the MXU's
    2x int8 throughput.
    """
    hd = d // H
    BH = R * H
    probs = BH * T * T
    flops = 2 * 2.0 * probs * hd                 # QK^T + P.V MACs
    if path == "fp":
        qk = 4 * (2 * R * T * d + probs)
        sm = 4 * 2 * probs
        pv = 4 * (probs + 2 * R * T * d)
        return {"bytes": qk + sm + pv, "flops": flops,
                "peak": HW["peak_bf16_flops"]}
    if path == "int8_composed":
        return {"bytes": traffic_attention_qk(BH, T, hd)["fused"]
                + traffic_attention_probs(BH, T, hd)["fused"],
                "flops": flops, "peak": HW["peak_int8_ops"]}
    if path == "int4":
        # w4a4 serving lowers attention onto flash with a nibble-packed
        # kv stream (``ops.flash_attention`` packs whenever the attention
        # packs are 4-bit); charged HONESTLY — the pack pass reads kv in
        # fp and writes the codes, so at n_qtiles == 1 this is slightly
        # MORE traffic than the unpacked flash model, paid for by the
        # linear weight-stream halving.
        return {"bytes": traffic_attention_flash_packed(BH, T, hd)["packed"],
                "flops": flops, "peak": HW["peak_int8_ops"]}
    return {"bytes": traffic_attention_flash(BH, T, hd)["flash"],
            "flops": flops, "peak": HW["peak_int8_ops"]}


def modeled_dit_step(cfg: DiTCfg, b_local: int, path: str) -> Dict[str, float]:
    """One CFG-paired denoising step on one device: ``b_local`` requests
    run as a 2*b_local model batch. Returns summed bytes/flops and the
    per-op roofline time. ``path``: 'fp', 'int8' (flash attention — the
    serving default), 'int8_composed' (three-kernel attention) or 'int4'
    (packed-int4 linears + packed-kv flash, the w4a4 recipe)."""
    assert path in ("fp", "int8", "int8_composed", "int4")
    R = 2 * b_local                     # CFG pairing doubles the model batch
    T, d, f = cfg.n_tokens, cfg.d_model, cfg.d_ff
    Mt = R * T                          # per-token rows

    def _chain(nbytes: float) -> Dict[str, float]:
        # adaLN elementwise chain (fp path only): pure-bandwidth HBM
        # round-trips XLA's fusion cannot eliminate around a matmul.
        # The quantized paths fuse these into the kernel prologue
        # (norm-modulate: read x, write modulated x = 8 bytes/elt) or
        # epilogue (gate+residual: read out, read residual, write
        # gated sum = 12 bytes/elt), so they charge nothing here.
        return {"bytes": float(nbytes), "flops": 0.0,
                "peak": HW["peak_bf16_flops"]}

    fp = path == "fp"
    ops = [
        _linear(Mt, cfg.patch_dim, d, path),            # x_proj
        _linear(R, 256, d, path),                       # t_mlp1
        _linear(R, d, d, path),                         # t_mlp2
        _linear(R, d, 2 * d, path),                     # final_ada
        _linear(Mt, d, cfg.patch_dim, path),            # final
    ]
    if fp:
        ops.append(_chain(8 * Mt * d))                  # final norm-modulate
    for _ in range(cfg.n_layers):
        ops += [
            _linear(R, d, 6 * d, path),                 # ada (weight-bound)
            _linear(Mt, d, 3 * d, path),                # qkv
            _linear(Mt, d, d, path),                    # proj
            _linear(Mt, d, f, path),                    # fc1
            _linear(Mt, f, d, path),                    # fc2 (MRQ single-pass)
            _attention(R, T, d, cfg.n_heads, path),     # per-path traffic
        ]
        if fp:
            ops += [
                _chain(8 * Mt * d),                     # qkv norm-modulate
                _chain(12 * Mt * d),                    # proj gate+residual
                _chain(8 * Mt * d),                     # fc1 norm-modulate
                _chain(12 * Mt * d),                    # fc2 gate+residual
            ]
    out = {"bytes": sum(o["bytes"] for o in ops),
           "flops": sum(o["flops"] for o in ops)}
    out["time_s"] = sum(max(o["bytes"] / HW["hbm_bw"], o["flops"] / o["peak"])
                        for o in ops)
    return out


def modeled_requests_per_sec(cfg: DiTCfg, batch: int, n_dev: int, steps: int,
                             path: str) -> Dict[str, float]:
    """Data-parallel serving: ``batch`` requests spread over ``n_dev``
    devices, ``steps`` denoising steps per request."""
    if batch % n_dev:
        raise ValueError(f"batch {batch} not divisible by {n_dev} devices")
    step = modeled_dit_step(cfg, batch // n_dev, path)
    return {"req_per_s": batch / (steps * step["time_s"]),
            "ms_per_step": step["time_s"] * 1e3}


def modeled_async_slot_step(cfg: DiTCfg, b_local: int, path: str,
                            batched: bool = True) -> float:
    """Modeled cost (s) of advancing ONE slot by ONE denoising step in
    the async continuous-batching engine, ``b_local`` slots per device.

    ``batched=True`` — the vector-TGQ batched forward (current engine):
    one dispatch advances all ``b_local`` slots regardless of their
    timestep groups, so the dispatch cost (one weight stream) amortizes
    over the slots — identical per-slot-step cost to the sync bucketed
    path's ``b_local``-batch, which is exactly the contract
    ``BENCH_serve.json`` asserts.

    ``batched=False`` — the retired per-slot dispatch: slots at
    different timesteps could not share a launch, so each slot-step paid
    a full single-slot dispatch (the whole weight stream)."""
    if batched:
        return modeled_dit_step(cfg, b_local, path)["time_s"] / b_local
    return modeled_dit_step(cfg, 1, path)["time_s"]


# ---------------------------------------------------------------------------
# recipe-level entrypoint (importable; the autotune throughput objective)
# ---------------------------------------------------------------------------
def recipe_model_path(recipe) -> str:
    """The roofline path a ``QuantRecipe`` serves on.

    w8a8 and w6a6 both ride the fused int8 kernel family (byte codes —
    only the clip range differs, so the modeled traffic is identical);
    w4a4 rides the packed-int4 family. The recipe's ``attn_impl`` picks
    flash vs the composed three-kernel attention model at 8/6 bits
    (w4a4 always streams packed-kv flash)."""
    if recipe.bits == "w4a4":
        return "int4"
    if recipe.attn_impl == "composed":
        return "int8_composed"
    return "int8"


def modeled_goodput(recipe, *, cfg: DiTCfg = XL2, n_dev: int = N_DEV,
                    b_local: int = 1, steps: int = 100) -> Dict[str, float]:
    """Modeled serving throughput of one ``QuantRecipe`` — a pure
    function of the recipe and the serving point, importable without
    executing anything (``repro.autotune.evaluate`` charges every trial
    through it, so the Pareto frontier's throughput axis and this
    benchmark's tables come from ONE roofline).

    Returns closed-loop ``req_per_s`` / ``ms_per_step`` (exactly
    :func:`modeled_requests_per_sec` at ``batch = b_local * n_dev``) plus
    the async continuous-batching cost per slot-step and the path name
    charged."""
    path = recipe_model_path(recipe)
    out = dict(modeled_requests_per_sec(cfg, b_local * n_dev, n_dev,
                                        steps, path))
    out["path"] = path
    out["s_per_slot_step_async"] = modeled_async_slot_step(cfg, b_local,
                                                           path)
    return out


# ---------------------------------------------------------------------------
# Poisson-arrival policy simulation (pure python; no jax)
# ---------------------------------------------------------------------------
def poisson_trace(n_req: int, rate_rps: float, buckets: Tuple[int, ...],
                  seed: int = 0) -> List[Tuple[float, int]]:
    """Open-loop load: (arrival_time_s, steps) per request — exponential
    interarrivals at ``rate_rps``, step counts drawn from the bucket
    mixture. Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_req))
    steps = rng.choice(buckets, n_req)
    return list(zip(arrivals.tolist(), [int(s) for s in steps]))


def simulate_bucketed(trace: List[Tuple[float, int]], microbatch: int,
                      s_per_step: float) -> Dict[str, float]:
    """The synchronous step-bucketed policy on a machine of ``microbatch``
    devices (one slot each): wait for a FULL same-bucket microbatch
    (``flush(partial=False)``), pad + drain partials only once arrivals
    are exhausted, and commit the machine for the batch's whole chain.
    Cost per dispatch = ``steps * s_per_step`` wall (slots run DP)."""
    waiting: Dict[int, List[float]] = {}
    done: List[Tuple[float, float]] = []          # (arrival, completion)
    pending = sorted(trace)
    t = 0.0
    i = 0
    while i < len(pending) or any(waiting.values()):
        while i < len(pending) and pending[i][0] <= t:
            arr, st = pending[i]
            waiting.setdefault(st, []).append(arr)
            i += 1
        full = [b for b, w in waiting.items() if len(w) >= microbatch]
        if full:
            b = min(full, key=lambda bb: waiting[bb][0])   # FIFO-ish
        elif i >= len(pending):                            # drain partials
            cands = [b for b, w in waiting.items() if w]
            if not cands:
                break
            b = min(cands, key=lambda bb: waiting[bb][0])
        else:                                              # wait for arrivals
            t = max(t, pending[i][0])
            continue
        batch = waiting[b][:microbatch]
        waiting[b] = waiting[b][microbatch:]
        t_end = t + b * s_per_step                         # whole chain
        done.extend((a, t_end) for a in batch)
        t = t_end
    make = max(c for _, c in done)
    return {"goodput_rps": len(done) / make,
            "latency_mean_s": float(np.mean([c - a for a, c in done])),
            "makespan_s": make}


def simulate_continuous(trace: List[Tuple[float, int]], microbatch: int,
                        chunk: int, s_per_step: float) -> Dict[str, float]:
    """The continuous-batching policy on the same machine: ``microbatch``
    slots, every dispatch advances all active slots ``chunk`` steps
    (``chunk * s_per_step`` wall — slots run in parallel, one per
    device), finished slots freed and queued requests admitted at every
    chunk boundary. Same cost per slot-step as the bucketed machine."""
    slots: List[Tuple[float, int]] = []           # (arrival, remaining)
    done: List[Tuple[float, float]] = []
    pending = sorted(trace)
    t = 0.0
    i = 0
    while i < len(pending) or slots:
        while i < len(pending) and pending[i][0] <= t and \
                len(slots) < microbatch:
            slots.append((pending[i][0], pending[i][1]))
            i += 1
        if not slots:
            t = max(t, pending[i][0])
            continue
        t += chunk * s_per_step
        nxt = []
        for arr, rem in slots:
            rem -= chunk
            if rem <= 0:
                done.append((arr, t))
            else:
                nxt.append((arr, rem))
        slots = nxt
    make = max(c for _, c in done)
    return {"goodput_rps": len(done) / make,
            "latency_mean_s": float(np.mean([c - a for a, c in done])),
            "makespan_s": make}


# ---------------------------------------------------------------------------
# BENCH_serve.json: machine-readable modeled trajectory (pure model)
# ---------------------------------------------------------------------------
def bench_serve_data(steps: int = 100, b_local: int = 2) -> dict:
    """Modeled DiT-XL/2 serving numbers for ``BENCH_serve.json``.

    Per recipe (fp / w8a8 / w4a4): closed-loop requests/sec at
    ``b_local`` slots per device, plus open-loop Poisson goodput under
    each policy — sync step-bucketed (full same-bucket batches, whole-
    chain commitment) vs async continuous batching (chunk-boundary
    admission), both charged the SAME modeled wall cost per machine
    step. ASSERTS, at >= 2 slots/device, that the async engine's modeled
    cost per slot-step is (a) no worse than the sync bucketed batch and
    (b) strictly better than the retired per-slot dispatch."""
    buckets = (25, 50, 100)
    micro, chunk = b_local * N_DEV, 5
    trace = poisson_trace(400, 16.0, buckets, seed=7)
    data = {"meta": {"model": "DiT-XL/2", "n_dev": N_DEV,
                     "slots_per_device": b_local, "steps": steps,
                     "buckets": list(buckets), "chunk": chunk,
                     "load_rps": 16.0},
            "paths": {}}
    for name, path in (("fp", "fp"), ("w8a8", "int8"), ("w4a4", "int4")):
        sync_c = modeled_dit_step(XL2, b_local, path)["time_s"] / b_local
        async_c = modeled_async_slot_step(XL2, b_local, path)
        unbatched_c = modeled_async_slot_step(XL2, b_local, path,
                                              batched=False)
        assert async_c <= sync_c, (
            f"{name}: async CB modeled cost/slot-step {async_c:.3e}s > "
            f"sync bucketed {sync_c:.3e}s at {b_local} slots/device — "
            "the vector-TGQ batched dispatch must amortize the weight "
            "stream exactly like the sync batch")
        assert async_c < unbatched_c, (
            f"{name}: batched async dispatch must beat the per-slot "
            f"dispatch at {b_local} slots/device")
        if name == "w8a8":
            # prologue/epilogue-fusion regression bound: the quantized
            # roofline charges exactly the fused kernel's x/W/y streams
            # (adaLN chains live in the kernel, not HBM) — the fp-side
            # honest-chain charges must never leak into this path.
            assert sync_c <= 0.0020322836630036626, (
                f"w8a8 modeled cost/slot-step {sync_c:.16e}s regressed "
                "past the PR 8 fused-kernel bound — a chain charge "
                "leaked into the quantized path")
        wall = modeled_dit_step(XL2, b_local, path)["time_s"]
        base = simulate_bucketed(trace, micro, wall)
        cb = simulate_continuous(trace, micro, chunk, wall)
        data["paths"][name] = {
            "req_per_s_closed_loop": round(modeled_requests_per_sec(
                XL2, b_local * N_DEV, N_DEV, steps, path)["req_per_s"], 3),
            "sync_bucketed_goodput_rps": round(base["goodput_rps"], 4),
            "async_cb_goodput_rps": round(cb["goodput_rps"], 4),
            "sync_latency_mean_s": round(base["latency_mean_s"], 3),
            "async_latency_mean_s": round(cb["latency_mean_s"], 3),
            "s_per_slot_step_sync": sync_c,
            "s_per_slot_step_async": async_c,
            "s_per_slot_step_async_per_slot_dispatch": unbatched_c,
        }
    return data


def main_bench_json() -> None:
    import json

    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    data = bench_serve_data()
    with open(out, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    for name, d in data["paths"].items():
        print(f"{name}: closed-loop {d['req_per_s_closed_loop']} req/s; "
              f"poisson goodput sync {d['sync_bucketed_goodput_rps']} vs "
              f"async {d['async_cb_goodput_rps']} rps", flush=True)
    print(f"wrote {os.path.normpath(out)} (async cost/slot-step <= sync "
          f"bucketed asserted at {data['meta']['slots_per_device']} "
          "slots/device)")


# ---------------------------------------------------------------------------
# executed section (forced host devices; import-safe until main())
# ---------------------------------------------------------------------------
def main_poisson() -> None:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    from benchmarks import common as C
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.launch.mesh import make_serving_mesh
    from repro.models import dit_init
    from repro.serving import AsyncServeEngine, GenRequest, ServeEngine

    rows = [("section", "policy", "load_rps", "goodput_rps",
             "latency_mean_s", "note")]

    # -- simulated XL/2 under open-loop Poisson load (modeled roofline) -----
    buckets = (25, 50, 100)
    # chunk divides every bucket: a slot finishing mid-chunk wastes the
    # chunk's remaining iterations (the compiled body masks, it doesn't
    # shrink), so deployments pick chunk | gcd(buckets)
    micro, chunk = N_DEV, 5
    ms1 = modeled_dit_step(XL2, 1, "int8")["time_s"]
    worst_margin = None
    for rate in (2.0, 8.0, 32.0):
        trace = poisson_trace(400, rate, buckets, seed=7)
        base = simulate_bucketed(trace, micro, ms1)
        cb = simulate_continuous(trace, micro, chunk, ms1)
        margin = cb["goodput_rps"] / base["goodput_rps"]
        worst_margin = margin if worst_margin is None else \
            min(worst_margin, margin)
        rows.append(("poisson_sim_xl2", "bucketed", rate,
                     round(base["goodput_rps"], 3),
                     round(base["latency_mean_s"], 3), ""))
        rows.append(("poisson_sim_xl2", "continuous", rate,
                     round(cb["goodput_rps"], 3),
                     round(cb["latency_mean_s"], 3),
                     f"{margin:.2f}x goodput"))

    # -- measured: async engine == sync path, compile-once ------------------
    cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
                 n_heads=4, n_classes=8)
    params = dit_init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + jax.random.normal(jax.random.PRNGKey(1), a.shape) * .01,
        params)
    dif = DiffusionCfg(T=100, tgq_groups=4)
    sched = make_schedule(dif)
    small_buckets = (4, 8)
    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes,
                       steps=small_buckets[i % 2], cfg_scale=1.5,
                       seed=1000 + i) for i in range(6)]
    sync = ServeEngine(params, cfg, dif, sched, mesh=make_serving_mesh(1),
                       microbatch=2, step_buckets=small_buckets)
    ref = sync.serve(reqs)
    eng = AsyncServeEngine(params, cfg, dif, sched, microbatch=2,
                           step_buckets=small_buckets, chunk=3)
    out = eng.serve(reqs)
    identical = all(out[i].status == "OK"
                    and np.array_equal(out[i].sample, ref[i].sample)
                    for i in range(len(reqs)))
    rows.append(("identity", "async_vs_sync", len(reqs), "", "",
                 "BIT-IDENTICAL" if identical else "MISMATCH"))
    rows.append(("compile_once", "continuous", "",
                 eng.stats["chunk_traces"], eng.stats["dispatches"],
                 "traces/dispatches"))

    C.emit("serve_throughput_poisson", rows)
    assert identical, "async continuous batching diverged from sync serving"
    assert eng.stats["chunk_traces"] == 1, (
        f"in-flight executable traced {eng.stats['chunk_traces']} times — "
        "must compile exactly once per chunk shape")
    assert worst_margin is not None and worst_margin >= 1.0, (
        f"continuous-batching goodput {worst_margin:.2f}x < bucketed "
        "baseline at equal load")
    print(f"poisson: continuous batching >= bucketed at all loads (worst "
          f"margin {worst_margin:.2f}x); async == sync bit-identical with "
          f"{eng.stats['chunk_traces']} trace / "
          f"{eng.stats['dispatches']} dispatches")


def main() -> None:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEV}")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax
    import time

    from benchmarks import common as C
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.launch.mesh import make_serving_mesh
    from repro.models import dit_init
    from repro.quant import QuantRecipe, quantize
    from repro.serving import GenRequest, ServeEngine

    rows = [("section", "path", "batch", "req_per_s", "ms_per_step",
             "speedup")]

    # --- modeled TPU v5e throughput, DiT-XL/2 at 100 steps -------------------
    steps = 100
    floor_ratio = composed_floor = int4_floor = None
    for batch in (N_DEV, 2 * N_DEV, 4 * N_DEV):
        fp = modeled_requests_per_sec(XL2, batch, N_DEV, steps, "fp")
        q8 = modeled_requests_per_sec(XL2, batch, N_DEV, steps, "int8")
        qc = modeled_requests_per_sec(XL2, batch, N_DEV, steps,
                                      "int8_composed")
        q4 = modeled_requests_per_sec(XL2, batch, N_DEV, steps, "int4")
        ratio = q8["req_per_s"] / fp["req_per_s"]
        if batch == N_DEV:
            floor_ratio = ratio
            composed_floor = qc["req_per_s"] / fp["req_per_s"]
            int4_floor = q4["req_per_s"] / fp["req_per_s"]
        rows.append(("modeled_xl2", "fp", batch,
                     round(fp["req_per_s"], 3), round(fp["ms_per_step"], 3),
                     1.0))
        rows.append(("modeled_xl2", "int8_composed_attn", batch,
                     round(qc["req_per_s"], 3), round(qc["ms_per_step"], 3),
                     round(qc["req_per_s"] / fp["req_per_s"], 2)))
        rows.append(("modeled_xl2", "int8_fused", batch,
                     round(q8["req_per_s"], 3), round(q8["ms_per_step"], 3),
                     round(ratio, 2)))
        rows.append(("modeled_xl2", "int4_packed", batch,
                     round(q4["req_per_s"], 3), round(q4["ms_per_step"], 3),
                     round(q4["req_per_s"] / fp["req_per_s"], 2)))

    # --- executed: small DiT through the real engine -------------------------
    cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
                 n_heads=4, n_classes=8)
    params = dit_init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + jax.random.normal(jax.random.PRNGKey(1), a.shape) * .01,
        params)
    dif = DiffusionCfg(T=100, tgq_groups=4)
    sched = make_schedule(dif)
    artifact = quantize(params, cfg, dif,
                        QuantRecipe(bits="w8a8", method="range",
                                    n_per_group=1, calib_batch=1),
                        sched=sched)
    ctx8 = artifact.context()
    mesh = make_serving_mesh()          # all forced devices
    run_steps = 8
    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=run_steps,
                       cfg_scale=1.5, seed=1000 + i) for i in range(2 * N_DEV)]
    served = {}
    for path, ctx in (("fp", None), ("int8_fused", ctx8)):
        eng = ServeEngine(params, cfg, dif, sched, ctx=ctx, mesh=mesh,
                          microbatch=N_DEV, step_buckets=(run_steps,))
        eng.serve(reqs[:N_DEV])         # warm up (compile)
        t0 = time.perf_counter()
        served[path] = eng.serve(reqs)
        dt = time.perf_counter() - t0
        rows.append(("measured_cpu", path, N_DEV,
                     round(len(reqs) / dt, 3),
                     round(dt / (len(reqs) // N_DEV * run_steps) * 1e3, 1),
                     ""))

    # --- sharded w8a8 == single-device w8a8, same seeds ----------------------
    eng1 = ServeEngine(params, cfg, dif, sched, ctx=ctx8,
                       mesh=make_serving_mesh(1), microbatch=N_DEV,
                       step_buckets=(run_steps,))
    single = eng1.serve(reqs)
    identical = all(
        np.array_equal(single[i].sample, served["int8_fused"][i].sample)
        for i in range(len(reqs)))
    rows.append(("identity", "sharded_vs_single_w8a8", len(reqs),
                 "", "", "BIT-IDENTICAL" if identical else "MISMATCH"))

    C.emit("serve_throughput", rows)
    assert identical, "sharded w8a8 diverged from single-device w8a8"
    assert floor_ratio is not None and floor_ratio >= 1.5, (
        f"fused-int8 modeled speedup {floor_ratio:.2f}x < 1.5x at "
        f"batch == n_devices")
    assert floor_ratio > composed_floor, (
        f"flash attention must beat the composed three-kernel model "
        f"({floor_ratio:.2f}x vs {composed_floor:.2f}x)")
    assert int4_floor is not None and int4_floor > floor_ratio, (
        f"packed-int4 must beat int8 at the weight-bound serving point "
        f"({int4_floor:.2f}x vs {floor_ratio:.2f}x) — the halved weight "
        "stream is the whole point")
    print(f"fused-int8 serving: {floor_ratio:.2f}x requests/sec over fp at "
          f"batch {N_DEV} on {N_DEV} devices (modeled, DiT-XL/2, flash "
          f"attention traffic charged; composed-attention path: "
          f"{composed_floor:.2f}x; packed-int4 w4a4: {int4_floor:.2f}x); "
          f"sharded == single-device: {identical}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arrivals", default="batch",
                    choices=("batch", "poisson"),
                    help="'batch': closed-loop fp-vs-int8 throughput; "
                         "'poisson': open-loop arrival simulation, "
                         "continuous batching vs the bucketed baseline")
    ap.add_argument("--bench-json", action="store_true",
                    help="write BENCH_serve.json (modeled fp/w8a8/w4a4 "
                         "req/s, sync vs async) and exit — the "
                         "machine-readable perf trajectory across PRs")
    cli = ap.parse_args()
    if cli.bench_json:
        main_bench_json()
    elif cli.arrivals == "poisson":
        main_poisson()
    else:
        main()
