"""Table III: component ablation at W6A6 — Baseline, +HO, +HO+MRQ,
+HO+MRQ+TGQ (full TQ-DiT)."""
from __future__ import annotations

from benchmarks import common as C
from repro.core import QuantContext

STEPS = 40
ABLATION = ["baseline", "+HO", "+HO+MRQ", "tq_dit"]


def main() -> None:
    cfg, params = C.trained_dit()
    calib = C.calibration_set(params, cfg)

    rows = [("method", "FD", "sFD", "IS*", "noiseMSE")]
    gen, _ = C.generate(params, cfg, steps=STEPS)
    s = C.score(gen)
    rows.append(("FP", s["FD"], s["sFD"], s["IS*"], 0.0))
    print(f"[table3] FP: {s}", flush=True)

    for scheme in ABLATION:
        qp, _ = C.calibrate(scheme, 6, params, cfg, calib)
        ctx = QuantContext(qparams=qp)
        gen, _ = C.generate(params, cfg, ctx=ctx, steps=STEPS)
        s = C.score(gen)
        mse = C.noise_mse(params, cfg, ctx)
        rows.append((scheme, s["FD"], s["sFD"], s["IS*"], round(mse, 6)))
        print(f"[table3] {scheme}: {s} mse={mse:.2e}", flush=True)
    C.emit("table3", rows)


if __name__ == "__main__":
    main()
