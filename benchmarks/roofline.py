import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# Roofline analysis (EXPERIMENTS.md section "Roofline").
#
# Methodology: cost_analysis() counts a lax.scan body ONCE and XLA:CPU
# stages bf16 compute through f32 buffers, so the raw dry-run numbers need
# care. We therefore lower each (arch x shape) UNROLLED (scan off) at
# n_layers=1 and n_layers=2 on the production mesh; the L2-L1 diff is the
# exact per-layer cost, and total = base + L x per-layer. Collective bytes
# are parsed from the compiled HLO text the same way. Cross-checked against
# the 6ND model-FLOPs identity (the MODEL/HLO ratio column).
#
# Terms (TPU v5e, per chip): compute = FLOPs / 197e12, memory =
# bytes / 819e9, collective = coll_bytes / 50e9 (ICI). The dominant term
# is the bottleneck; the roofline fraction = compute / dominant.
#
# Run: PYTHONPATH=src python -m benchmarks.roofline [--arch A] [--shape S]

import argparse
import json
import time
import traceback

import jax
import numpy as np


def measure(arch, shape_id, overrides=None, force_micro=1):
    """Lower+compile at L=1 and L=2 (unrolled), return per-layer stats."""
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.launch.hlo_stats import collective_stats

    mesh = make_production_mesh(multi_pod=False)
    out = {}
    for L in (1, 2):
        over = {"n_layers": L, "scan_layers": False, "remat": False,
                "grad_accum": 1}
        if arch == "whisper-tiny":
            over["n_enc_layers"] = L
        if arch == "hymba-1.5b":
            over["global_layers"] = ()
        over.update(overrides or {})
        cell = build_cell(arch, shape_id, mesh, cfg_overrides=over,
                          force_micro=force_micro)
        with mesh:
            lowered = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                              donate_argnums=cell["donate_argnums"])\
                .lower(*cell["args"])
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        colls = collective_stats(compiled.as_text())
        out[L] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(sum(v["bytes"] for v in colls.values())),
            "coll_by_kind": {k: v["bytes"] for k, v in colls.items()},
            "meta": cell["meta"],
        }
    return out


HW = {"flops": 197e12, "hbm": 819e9, "ici": 50e9}
N_DEV = 256
TP = 16


def analytic_bytes(cfg, kind: str, batch: int, seq: int, tp: int = TP
                   ) -> float:
    """Napkin per-device HBM traffic (bytes/step) for the TPU target.

    XLA:CPU's 'bytes accessed' counts every unfused op's operands at f32,
    inflating the memory term ~100-200x vs a fused TPU execution, so the
    memory TERM uses this analytic model (params + cache + activation
    traffic under standard fusion assumptions); HLO bytes are reported
    alongside for reference.
    """
    from repro.models import DiTCfg
    if isinstance(cfg, DiTCfg):
        n_par = cfg.n_params()
        tok_loc = batch * cfg.n_tokens / tp       # batch sharded on "data"
        p_dev = n_par * 2 / tp                    # bf16 TP shard per pass
        if kind == "dit_train":
            act = 2 * cfg.n_layers * tok_loc * cfg.d_model * 2 * 2
            return 2 * 2 * p_dev + (4 + 16) * n_par * 4 / N_DEV + act
        return 2 * p_dev + 4 * tok_loc * cfg.d_model * 2
    n_act = cfg.n_active_params()
    p_dev = n_act * 2 / tp                        # bf16 weights, TP-sharded
    tok_loc = batch * seq / tp
    d = cfg.d_model

    # decode-cache bytes (read once per step)
    if cfg.block_type == "ssm_only":
        cache = cfg.n_layers * batch * (cfg.d_inner * cfg.ssm_state * 4)
    elif cfg.attn_type == "mla":
        cache = cfg.n_layers * batch * seq * (cfg.kv_lora + cfg.rope_dim) * 2
    else:
        cache = cfg.n_layers * batch * seq * 2 * cfg.n_kv_heads \
            * cfg.head_dim * 2
        if cfg.block_type == "hymba":
            cache += cfg.n_layers * batch * (cfg.d_inner * cfg.ssm_state * 4)

    if kind == "train":
        # fwd+bwd weight reads, grad write (f32), AdamW/Adafactor state rw,
        # remat carries written+read, logits path
        opt = (4 + 16) * cfg.n_params() * 4 / N_DEV
        act = 2 * cfg.n_layers * tok_loc * d * 2 * 2
        logits = tok_loc * (cfg.vocab / tp) * 10
        return 2 * 2 * p_dev + opt + act + logits
    if kind == "prefill":
        act = 4 * cfg.n_layers * tok_loc * d * 2
        return 2 * p_dev + act + cache / N_DEV
    # decode: weights + cache dominate
    return 2 * p_dev + cache / N_DEV + batch * cfg.vocab / tp * 2


def model_flops(meta, cfg) -> float:
    """6ND (train) / 2ND (inference) useful-FLOPs identity, global."""
    from repro.configs import SHAPES, DIT_SHAPES
    from repro.models import DiTCfg
    kind = meta["kind"]
    if isinstance(cfg, DiTCfg):
        n = cfg.n_params()
        sh = DIT_SHAPES["train_256" if kind == "dit_train" else "sample_128"]
        toks = sh["batch"] * cfg.n_tokens
        return (6 if kind == "dit_train" else 2) * n * toks
    n = cfg.n_active_params()
    sh = SHAPES[meta["shape"]] if "shape" in meta else None
    if kind == "train":
        return 6 * n * meta_tokens(meta)
    if kind == "prefill":
        return 2 * n * meta_tokens(meta)
    return 2 * n * meta["batch_"]          # decode: one token per sequence


def meta_tokens(meta):
    return meta["batch_"] * meta["seq_"]


def analyse(arch, shape_id, rec, n_devices=256, tp=TP):
    """Extrapolate L1/L2 to the full config and compute the three terms."""
    from repro.configs import get as get_cfg
    from repro.models import DiTCfg
    cfg = get_cfg(arch)
    L = cfg.n_layers
    per = {k: rec[2][k] - rec[1][k] for k in ("flops", "bytes", "coll")}
    tot = {k: rec[1][k] + (L - 1) * per[k] for k in per}

    meta = dict(rec[1]["meta"])
    from repro.configs import SHAPES, DIT_SHAPES
    sh = (DIT_SHAPES if arch == "dit-xl-2" else SHAPES)[shape_id]
    meta["batch_"] = sh["batch"]
    meta["seq_"] = sh.get("seq", 0)
    meta["shape"] = shape_id

    t_comp = tot["flops"] / HW["flops"]
    t_mem_hlo = tot["bytes"] / HW["hbm"]
    an_bytes = analytic_bytes(cfg, meta["kind"], meta["batch_"],
                              meta["seq_"] or 1, tp=tp)
    t_mem = an_bytes / HW["hbm"]
    t_coll = tot["coll"] / HW["ici"]
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    mf = model_flops(meta, cfg)
    hlo_global = tot["flops"] * n_devices
    return {
        "arch": arch, "shape": shape_id,
        "flops_dev": tot["flops"], "bytes_dev_hlo": tot["bytes"],
        "bytes_dev_analytic": an_bytes, "coll_dev": tot["coll"],
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_hlo_s": t_mem_hlo, "t_collective_s": t_coll,
        "bottleneck": dom[0],
        "roofline_frac": t_comp / dom[1] if dom[1] > 0 else 1.0,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "model_over_hlo": mf / hlo_global if hlo_global else 0.0,
        "n_micro": rec[1]["meta"].get("n_micro", 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, cells

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"]) for r in results if "error" not in r}

    archs = [args.arch] if args.arch else list(ARCHS)
    for arch in archs:
        for shape_id, _ in cells(arch):
            if args.shape and shape_id != args.shape:
                continue
            if (arch, shape_id) in done:
                continue
            t0 = time.time()
            try:
                rec = measure(arch, shape_id)
                r = analyse(arch, shape_id, rec)
                r["measure_s"] = round(time.time() - t0, 1)
                dom_t = max(r["t_compute_s"], r["t_memory_s"],
                            r["t_collective_s"])
                print(f"[roofline] {arch} x {shape_id}: "
                      f"comp={r['t_compute_s']*1e3:.2f}ms "
                      f"mem={r['t_memory_s']*1e3:.2f}ms "
                      f"coll={r['t_collective_s']*1e3:.2f}ms "
                      f"-> {r['bottleneck']} "
                      f"(frac={r['roofline_frac']:.2f}, "
                      f"model/hlo={r['model_over_hlo']:.2f})", flush=True)
            except Exception as e:  # noqa: BLE001
                r = {"arch": arch, "shape": shape_id,
                     "error": f"{type(e).__name__}: {e}",
                     "trace": traceback.format_exc()[-1500:]}
                print(f"[roofline] FAIL {arch} x {shape_id}: {r['error']}",
                      flush=True)
            results.append(r)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
