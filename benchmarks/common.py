"""Shared benchmark substrate.

- trains (once, cached) a small-but-real DiT on the synthetic latent
  dataset with the exact DDPM objective,
- calibrates every quantization scheme once per bit-width (cached),
- samples with each scheme and scores FD / sFD / IS-proxy + noise-MSE,
  the CPU-scale stand-ins for FID / sFID / IS (see repro.core.metrics).

The eval stack itself (generate / score / noise_mse / eval_assets) lives
in ``repro.quant.eval`` — a library module keyed by explicit
(model config, seeds, sizes) so other consumers (``repro.autotune``)
share its caches safely; the wrappers here just bind the bench model
(``BENCH_DIT`` / ``DIF``) and the table protocol constants.

All artifacts land under experiments/ so table benchmarks are re-runnable
and individually cheap.
"""
from __future__ import annotations

import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_dit_calibration, dit_loss_fn, run_ptq
from repro.core.baselines import SCHEMES
from repro.data import LatentPipeline
from repro.diffusion import DiffusionCfg, make_schedule, q_sample
from repro.models import DiTCfg, dit_apply, dit_init
from repro.optim import adamw, apply_updates, cosine_schedule
from repro.quant import eval as qeval

EXP = os.environ.get("REPRO_EXP_DIR",
                     os.path.join(os.path.dirname(__file__), "..",
                                  "experiments"))

# 64 tokens so post-softmax probs (~1/64) sit BELOW the W6A6 uniform step
# (1/31) — the regime where the paper's MRQ is structurally necessary —
# and 6 layers so per-op quantization errors compound through the stack.
BENCH_DIT = DiTCfg(img_size=16, in_ch=4, patch=2, d_model=160, n_layers=6,
                   n_heads=4, n_classes=8)
DIF = DiffusionCfg(T=1000, tgq_groups=10)
TRAIN_STEPS = int(os.environ.get("REPRO_DIT_STEPS", 450))
N_EVAL_REAL = 1024
N_GEN = int(os.environ.get("REPRO_N_GEN", 128))
GEN_BATCH = 64


def pipeline() -> LatentPipeline:
    return qeval.make_pipeline(BENCH_DIT, pipe_seed=11, pipe_noise=0.3)


def trained_dit(force: bool = False):
    """Train (or load) the benchmark DiT. Returns (cfg, params)."""
    os.makedirs(EXP, exist_ok=True)
    path = os.path.join(EXP, f"dit_bench_{TRAIN_STEPS}.pkl")
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            return BENCH_DIT, pickle.load(f)

    cfg = BENCH_DIT
    key = jax.random.PRNGKey(0)
    params = dit_init(key, cfg)
    sched = make_schedule(DIF)
    pipe = pipeline()
    opt = adamw(cosine_schedule(2e-3, 50, TRAIN_STEPS), weight_decay=0.0)
    opt_state = opt.init(params)

    def loss_fn(p, x0, t, y, noise):
        xt = q_sample(sched, x0, t, noise)
        eps = dit_apply(p, cfg, xt, t, y)
        return jnp.mean(jnp.square(eps - noise))

    @jax.jit
    def step(p, o, x0, t, y, noise):
        l, g = jax.value_and_grad(loss_fn)(p, x0, t, y, noise)
        u, o = opt.update(g, o, p)
        return l, apply_updates(p, u), o

    B = 64
    t0 = time.time()
    for i in range(TRAIN_STEPS):
        key, k1, k2, k3 = jax.random.split(key, 4)
        x0, y = pipe.sample(B, k1)
        t = jax.random.randint(k2, (B,), 0, DIF.T)
        noise = jax.random.normal(k3, x0.shape)
        l, params, opt_state = step(params, opt_state, x0, t, y, noise)
        if i % 100 == 0 or i == TRAIN_STEPS - 1:
            print(f"  [dit-train] step {i} loss {float(l):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    host = jax.tree.map(np.asarray, params)
    with open(path, "wb") as f:
        pickle.dump(host, f)
    return cfg, host


def calibration_set(params, cfg, n_per_group=32, batch=8, seed=3):
    sched = make_schedule(DIF)
    pipe = pipeline()
    return build_dit_calibration(
        params, cfg, DIF, sched, lambda n, k: pipe.sample(n, k)[0],
        jax.random.PRNGKey(seed), n_per_group=n_per_group, batch=batch)


def calibrate(scheme: str, bits: int, params, cfg, calib=None,
              force: bool = False, **overrides):
    """Run (or load) one scheme's PTQ. Returns (qparams, report)."""
    path = os.path.join(EXP, f"qparams_{scheme.replace('+','p')}_w{bits}a{bits}"
                             f"_{TRAIN_STEPS}.pkl")
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            d = pickle.load(f)
        return d["qparams"], d["report"]
    calib = calib or calibration_set(params, cfg)
    over = {"tgq_groups": DIF.tgq_groups, "n_alpha": 8,
            "rounds": 2, "max_rows_per_batch": 96}
    over.update(overrides)
    qcfg = SCHEMES[scheme](bits, bits, **over)
    qp, rep = run_ptq(dit_loss_fn(params, cfg), calib, qcfg)
    # rep["weights"] is a full FP weight copy for in-process int8 packing;
    # keep it out of the on-disk cache (cached reports never had it, and
    # serializing it would balloon every per-scheme pickle)
    rep = {k: v for k, v in rep.items() if k != "weights"}
    with open(path, "wb") as f:
        pickle.dump({"qparams": qp, "report": rep}, f)
    return qp, rep


def capture_weights(params, cfg):
    """One eager forward through ``CalibrationContext`` to (re)capture
    the FP weight of every quantized op, keyed by op name — exactly the
    second argument ``kernels.ops.convert_for_kernels`` wants. The
    cached per-scheme PTQ reports deliberately strip their in-process
    weight copy (see :func:`calibrate`), so kernel-path benchmarks that
    load from cache recapture here (~one tiny forward, no search)."""
    from repro.core.contexts import CalibrationContext
    cal = CalibrationContext(max_rows_per_batch=1)
    cal.begin_batch()
    x = jnp.zeros((1, cfg.img_size, cfg.img_size, cfg.in_ch))
    t = jnp.zeros((1,), jnp.int32)
    y = jnp.zeros((1,), jnp.int32)
    dit_apply(params, cfg, x, t, y, ctx=cal)
    return dict(cal.weights)


def generate(params, cfg, ctx=None, steps=50, n=N_GEN, seed=123):
    """Sample n latents with the (possibly quantized) model."""
    return qeval.generate(params, cfg, DIF, ctx=ctx, steps=steps, n=n,
                          seed=seed, batch=GEN_BATCH)


def eval_assets():
    """(real latents, labels, feature net, class proxy) — cached by
    ``repro.quant.eval`` under the full (config, seeds, size) key."""
    return qeval.eval_assets(BENCH_DIT, n_real=N_EVAL_REAL)


def score(gen: np.ndarray) -> dict:
    return qeval.score(gen, BENCH_DIT, n_real=N_EVAL_REAL)


def noise_mse(params, cfg, ctx, n=128, seed=55) -> float:
    """Quantized-vs-FP noise prediction MSE across timestep groups."""
    return qeval.noise_mse(params, cfg, DIF, ctx, n=n, seed=seed)


def emit(table: str, rows: list) -> None:
    """Print CSV rows and append to experiments/results.json."""
    os.makedirs(EXP, exist_ok=True)
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    path = os.path.join(EXP, "results.json")
    data = {}
    if os.path.exists(path):
        data = json.load(open(path))
    data[table] = [list(r) for r in rows]
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
