"""Fig. 3: variation of the maximum post-softmax magnitude across
diffusion timesteps — the motivation for TGQ. Reports per-group maxima
and the cross-timestep variance."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common as C
from repro.core import CalibrationContext, RecordingContext, dit_loss_fn


def main() -> None:
    cfg, params = C.trained_dit()
    calib = C.calibration_set(params, cfg, n_per_group=8, batch=8)
    loss = dit_loss_fn(params, cfg)
    rec = RecordingContext()
    loss(rec, calib[0][0])

    cal = CalibrationContext(registry=rec.registry, max_batch_sub=8)
    for b, g in calib:
        cal.begin_batch()
        loss(dataclasses.replace(cal, tgroup=g), b)

    op = "blk0/attn/pv"
    per_group = {}
    for r in cal.store[op]:
        # max prob per sample, channel-style: max over attention rows
        m = float(np.max(r["a"]))
        per_group.setdefault(r["tg"], []).append(m)

    rows = [("tgroup", "max_softmax_mean", "max_softmax_std")]
    means = []
    for g in sorted(per_group):
        vals = per_group[g]
        rows.append((g, round(float(np.mean(vals)), 4),
                     round(float(np.std(vals)), 4)))
        means.append(np.mean(vals))
        print(f"[fig3] group {g}: max={np.mean(vals):.4f}", flush=True)
    spread = float(np.max(means) - np.min(means))
    rows.append(("spread_across_groups", round(spread, 4), ""))
    print(f"[fig3] spread of per-group max across timesteps: {spread:.4f} "
          f"(nonzero spread motivates TGQ)", flush=True)
    C.emit("fig3", rows)


if __name__ == "__main__":
    main()
