"""Table I: W8A8 / W6A6 quality comparison at the LONG sampling schedule
(paper: 250 DDPM steps; CPU-scale: 50 respaced steps, recorded deviation).

Schemes: Q-Diffusion-like, PTQD-like, PTQ4DiT-like, TQ-DiT, vs FP.
Metrics: FD / sFD / IS* (stand-ins preserving Table-I orderings).
"""
from __future__ import annotations

import sys

from benchmarks import common as C
from repro.core import QuantContext

STEPS = 40
SCHEMES = ["q_diffusion", "ptqd", "ptq4dit", "tq_dit"]


def main(bits_list=(8, 6), steps=STEPS, table="table1") -> None:
    cfg, params = C.trained_dit()
    calib = C.calibration_set(params, cfg)

    rows = [("bits", "method", "FD", "sFD", "IS*", "noiseMSE")]
    gen, _ = C.generate(params, cfg, steps=steps)
    s = C.score(gen)
    rows.append(("32/32", "FP", s["FD"], s["sFD"], s["IS*"], 0.0))
    print(f"[{table}] FP: {s}", flush=True)

    for bits in bits_list:
        for scheme in SCHEMES:
            qp, rep = C.calibrate(scheme, bits, params, cfg, calib)
            ctx = QuantContext(qparams=qp)
            gen, _ = C.generate(params, cfg, ctx=ctx, steps=steps)
            s = C.score(gen)
            mse = C.noise_mse(params, cfg, ctx)
            rows.append((f"{bits}/{bits}", scheme, s["FD"], s["sFD"],
                         s["IS*"], round(mse, 6)))
            print(f"[{table}] W{bits}A{bits} {scheme}: {s} mse={mse:.2e}",
                  flush=True)
    C.emit(table, rows)


if __name__ == "__main__":
    main()
