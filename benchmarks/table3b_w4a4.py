"""Table III-b (scale addendum): the ablation at W4A4.

At this reproduction's scale (6L / d160 / 64 tokens) W6A6 quantization
error is within metric noise for every searched scheme — the paper's
W6A6 separation needs DiT-XL depth. W4A4 is the bit-width where OUR
model shows visible damage, so the component ordering (Baseline -> +HO ->
+HO+MRQ -> +TGQ) is exercised in its intended regime.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import QuantContext

STEPS = 40
ABLATION = ["baseline", "+HO", "+HO+MRQ", "tq_dit"]


def main() -> None:
    cfg, params = C.trained_dit()
    calib = C.calibration_set(params, cfg)

    rows = [("method", "FD", "sFD", "IS*", "noiseMSE")]
    gen, _ = C.generate(params, cfg, steps=STEPS)
    s = C.score(gen)
    rows.append(("FP", s["FD"], s["sFD"], s["IS*"], 0.0))
    print(f"[table3b] FP: {s}", flush=True)

    for scheme in ABLATION:
        qp, _ = C.calibrate(scheme, 4, params, cfg, calib)
        ctx = QuantContext(qparams=qp)
        gen, _ = C.generate(params, cfg, ctx=ctx, steps=STEPS)
        s = C.score(gen)
        mse = C.noise_mse(params, cfg, ctx)
        rows.append((scheme, s["FD"], s["sFD"], s["IS*"], round(mse, 6)))
        print(f"[table3b] W4A4 {scheme}: {s} mse={mse:.2e}", flush=True)
    C.emit("table3b", rows)


if __name__ == "__main__":
    main()
