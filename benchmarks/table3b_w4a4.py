"""Table III-b (scale addendum): the ablation at W4A4, served through
the PACKED-INT4 kernel path.

At this reproduction's scale (6L / d160 / 64 tokens) W6A6 quantization
error is within metric noise for every searched scheme — the paper's
W6A6 separation needs DiT-XL depth. W4A4 is the bit-width where OUR
model shows visible damage, so the component ordering (Baseline -> +HO ->
+HO+MRQ -> +TGQ) is exercised in its intended regime.

Each scheme's qparams are converted with ``convert_for_kernels`` and
sampled with ``QuantContext(kernel=True)`` — scores are produced by the
nibble-packed ``int4_matmul_fq`` / ``int4_matmul_mrq_fq`` deployment
kernels (per-K-group weight scales and all), not the fake-quant seams.
``n_packed`` counts the ops that actually lowered onto kernels;
channel-balanced quantizers pack too (the ``x_prescale`` divide runs in
the kernel quantize prologue), so any op the column shows unpacked is a
genuine structural refusal, not silently-absorbed fallback.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import QuantContext
from repro.kernels import ops as kops

STEPS = 40
ABLATION = ["baseline", "+HO", "+HO+MRQ", "tq_dit"]
PACK_KEYS = ("int4", "int4_mrq", "int8", "int8_mrq", "int8_qk", "int8_pv")


def main() -> None:
    cfg, params = C.trained_dit()
    calib = C.calibration_set(params, cfg)
    weights = C.capture_weights(params, cfg)

    rows = [("method", "FD", "sFD", "IS*", "noiseMSE", "n_packed")]
    gen, _ = C.generate(params, cfg, steps=STEPS)
    s = C.score(gen)
    rows.append(("FP", s["FD"], s["sFD"], s["IS*"], 0.0, 0))
    print(f"[table3b] FP: {s}", flush=True)

    for scheme in ABLATION:
        qp, _ = C.calibrate(scheme, 4, params, cfg, calib)
        qp = kops.convert_for_kernels(qp, weights)
        n_packed = sum(1 for v in qp.values()
                       if any(k in v for k in PACK_KEYS))
        ctx = QuantContext(qparams=qp, kernel=n_packed > 0)
        gen, _ = C.generate(params, cfg, ctx=ctx, steps=STEPS)
        s = C.score(gen)
        mse = C.noise_mse(params, cfg, ctx)
        rows.append((scheme, s["FD"], s["sFD"], s["IS*"], round(mse, 6),
                     n_packed))
        print(f"[table3b] W4A4 {scheme}: {s} mse={mse:.2e} "
              f"(kernel path, {n_packed} packed ops)", flush=True)
    C.emit("table3b", rows)


if __name__ == "__main__":
    main()
