"""Render EXPERIMENTS.md from the experiment artifacts
(experiments/{dryrun,roofline,perf,results}.json).

Run: PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md
(or let it write the file directly with --write)
"""
from __future__ import annotations

import argparse
import io
import json
import os

EXP = "experiments"


def _load(name):
    p = os.path.join(EXP, name)
    return json.load(open(p)) if os.path.exists(p) else None


def render() -> str:
    out = io.StringIO()
    w = out.write
    w("# EXPERIMENTS — TQ-DiT reproduction + multi-pod system analysis\n\n")
    w("All numbers produced on this container (CPU; TPU v5e is the lowering "
      "TARGET).\nMetric stand-ins (FD/sFD/IS*) keep FID/sFID/IS math with a "
      "fixed seeded feature\nnet — orderings, not absolute values, are the "
      "comparable quantity (DESIGN §2).\n\n")

    # ------------------------------------------------------------- repro
    res = _load("results.json") or {}
    w("## §Repro — paper tables\n\n")
    names = {"table1": "Table I — quality, long schedule (paper: 250 steps; "
                       "here: 50 respaced)",
             "table2": "Table II — quality, short schedule (paper: 100; "
                       "here: 25)",
             "table3": "Table III — ablation at W6A6",
             "table3b": "Table III-b — ablation at W4A4 (scale addendum, "
                        "below the paper's range)",
             "table4": "Table IV — calibration efficiency",
             "fig2": "Fig. 2 — value distributions",
             "fig3": "Fig. 3 — timestep variance of max post-softmax",
             "kernel_micro": "Kernel micro (traffic model)"}
    for key, title in names.items():
        if key not in res:
            continue
        rows = res[key]
        w(f"### {title}\n\n")
        w("| " + " | ".join(str(c) for c in rows[0]) + " |\n")
        w("|" + "---|" * len(rows[0]) + "\n")
        for r in rows[1:]:
            w("| " + " | ".join(str(c) for c in r) + " |\n")
        w("\n")

    if "table1" in res:
        w("""Paper-claim checks (vs our FP baseline; orderings are the
comparable quantity — DESIGN §2):

- **W8A8 ~= FP for every scheme** (FD within 0.2% of FP; paper: +0.29 FID
  for TQ-DiT at W8A8). Reproduced.
- **W6A6**: TQ-DiT best/tied-best FD (1.163 vs FP 1.15); the
  PTQ4DiT-like salience baseline degrades sharply (FD 1.94, sFD 13.1) —
  mirroring the paper's PTQ4DiT W6A6 collapse (their Table I: 20.53 FID
  vs TQ-DiT 8.58). PTQD/Q-Diffusion-like remain competitive at this
  scale: our 6L/d160 model is too shallow to compound the softmax/GELU
  errors that separate them at DiT-XL depth (margins compress; noted).
- **Table III** ordering on end-to-end noise-MSE: Baseline 2.67e-3 >=
  +HO 2.64e-3 >= +HO+MRQ 2.61e-3 >= TQ-DiT 2.61e-3 (paper's monotone
  ordering, compressed margins at this scale).
- **Table IV**: TQ-DiT calibrates **83.5% faster** with **83.1% fewer
  stored calibration bytes** than the PTQ4DiT-like baseline (paper:
  −89.3% time, −45.4% memory). Reproduced.
- **Fig. 2**: post-softmax concentrated near zero (median 0.015 ~= 1/64
  tokens, right-skew 1.49) and post-GELU negative lobe at −0.17.
  Reproduced.
- **Fig. 3**: max post-softmax varies 2.3x across timestep groups
  (0.068 at high noise -> 0.030 at low). Reproduced — the TGQ motivation.
- **W4A4 addendum (beyond the paper's range)**: MRQ HALVES one-step
  noise-MSE (4.8e-2 -> 2.6e-2) yet worsens sampled FD (4.5 -> 17.9):
  MRQ's residuals are biased (small probs snap to the fine region's grid)
  and bias compounds over the 40-step trajectory, while uniform-quant
  errors are closer to zero-mean and wash out. A one-step objective
  (Eq. 16/17) cannot see this — an honest limitation of the method
  below W6A6, and the reason the paper's operating floor is W6A6.

""")

    # ------------------------------------------------------------- dryrun
    dr = _load("dryrun.json")
    w("## §Dry-run — multi-pod compile matrix\n\n")
    if dr:
        ok = [r for r in dr if r.get("ok")]
        w(f"{len(ok)}/{len(dr)} cells `.lower().compile()` green on the "
          "single-pod (16,16) and\nmulti-pod (2,16,16) = 512-chip meshes "
          "(every assigned arch x shape, plus\ndit-xl-2's own shapes; "
          "long_500k runs for SSM/hybrid archs and is a documented\nskip "
          "for the 8 pure-full-attention archs — DESIGN §6).\n\n")
        w("| arch | shape | mesh | compile_s | args_GiB | temp_GiB* | "
          "coll_MiB/dev |\n|---|---|---|---|---|---|---|\n")
        for r in ok:
            w(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r['compile_s']} | "
              f"{(r.get('argument_size_in_bytes') or 0)/2**30:.2f} | "
              f"{(r.get('temp_size_in_bytes') or 0)/2**30:.2f} | "
              f"{r['collective_bytes_per_device']/2**20:.0f} |\n")
        w("\n*temp is XLA:CPU's conservative packing and f32-staged — an "
          "upper bound\n(DESIGN §7); per-microbatch compiles bound the true "
          "TPU peak (e.g. qwen3-1.7b\ntrain grad at B=64 microbatch: "
          "6.5 GiB/device).\n\n")

    # ------------------------------------------------------------- roofline
    rl = _load("roofline.json")
    w("## §Roofline — three-term analysis (single-pod, per chip)\n\n")
    if rl:
        w("Method: unrolled L=1/L=2 lowering diff -> per-layer cost, "
          "extrapolated to full\ndepth; memory term from the analytic "
          "traffic model (HLO bytes are f32-staged\non CPU); collective "
          "bytes parsed from compiled HLO (DESIGN §7).\nHW: 197 TFLOP/s "
          "bf16, 819 GB/s HBM, 50 GB/s ICI per chip.\n\n")
        w("| arch | shape | compute_ms | memory_ms | collective_ms | "
          "bottleneck | roofline_frac | model/HLO flops |\n"
          "|---|---|---|---|---|---|---|---|\n")
        for r in rl:
            if "error" in r:
                w(f"| {r['arch']} | {r['shape']} | - | - | - | ERROR | - "
                  f"| - |\n")
                continue
            w(f"| {r['arch']} | {r['shape']} | "
              f"{r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} | "
              f"{r['t_collective_s']*1e3:.2f} | {r['bottleneck']} | "
              f"{r['roofline_frac']:.3f} | {r['model_over_hlo']:.2f} |\n")
        w("\nReading: roofline_frac = compute_term / dominant_term — the "
          "fraction of peak\nMXU issue the step could reach if perfectly "
          "overlapped; 1.0 = compute-bound.\nmodel/HLO ~1 means compiled "
          "FLOPs are 'useful' 2ND/6ND work; <<1 flags\nattention/vocab-"
          "dominated cells (expected for decode) or redundancy.\n\n")

    # ------------------------------------------------------------- perf
    pf = _load("perf.json")
    w("## §Perf — hillclimbing log (hypothesis -> change -> measure)\n\n")
    w("Cells chosen from the baseline table: worst roofline fraction "
      "(qwen2.5-14b\ntrain_4k), most collective-bound (kimi-k2 train_4k), "
      "most representative of the\npaper (dit-xl-2 sample_128 — the DiT "
      "serving step the paper accelerates).\n\n")
    if pf:
        w("| cell | variant | compute_ms | memory_ms | collective_ms | "
          "bottleneck | frac |\n|---|---|---|---|---|---|---|\n")
        for e in pf:
            w(f"| {e['exp']} | {e['variant']} | {e['t_compute_ms']} | "
              f"{e['t_memory_ms']} | {e['t_collective_ms']} | "
              f"{e['bottleneck']} | {e['roofline_frac']} |\n")
        w("\nFull hypothesis text per entry in experiments/perf.json.\n\n")
    w(PERF_NARRATIVE)
    return out.getvalue()


PERF_NARRATIVE = """### Iteration narrative

**Iteration 0 — KV-cache sharding (applies to every decode cell).**
Hypothesis: the 87 GB/step/device collective on qwen3-1.7b decode_32k came
from sharding the cache's trailing head_dim — a contraction dim of the
attention dots — forcing GSPMD "involuntary full rematerialization" of the
cache every step. Change: never shard the last dim; prefer kv-heads, fall
back to sequence. Measured: collective term 1751 ms -> 0.23 ms (7600x).
CONFIRMED; adopted globally before the baseline table was recorded.

**qwen2.5-14b train_4k (worst fraction, 0.015).**
1. SP attention (40 heads % 16 != 0 -> (S,S) scores all-reduced):
   159.6 s -> 116 s. PARTIALLY CONFIRMED — scores fixed, but profiling the
   new HLO found a bigger monster: take_along_axis over vocab-sharded
   logits all-gathered the full f32 (B,S,V) tensor (37 GiB/device).
2. Vocab-parallel CE (iota-mask reduction + sharded logsumexp) — no
   change alone; the gather persisted because the lm_head/embedding FSDP
   rule sharded the CONTRACTION dim d, making GSPMD partial-sum logits
   with a REPLICATED batch. Rule fix (vocab-only sharding for tables):
   collective 159.6 s -> 20.8 s (frac 0.099). CONFIRMED (7.7x).
3. TP shrink at fixed 256 chips (40 heads divide 4/8 -> no SP needed;
   per-device batch and AR bytes shrink with TP):
   DP32xTP8 frac 0.411; DP64xTP4 frac 0.655; DP128xTP2 frac 0.729
   (collective 3.08 s vs compute 2.01 s at TP4). CONFIRMED.
   Net: roofline fraction 0.015 -> 0.729 (49x).

**kimi-k2-1t-a32b train_4k (most collective-bound).**
Five hypotheses measured, four REFUTED — recorded as such:
SP attention (120.9 -> 127 s), local dispatch groups (412 s), dispatch
groups + buffer pin (1221 s), expert-FSDP off the contraction dims
(715 s), TP8 relayout (118.6 s). The sort-based MoE dispatch under GSPMD
resists every tested resharding: the global argsort keeps the (NK,d) slot
tensors effectively unsharded, and — unlike the dense lm_head — the
expert-weight gather IS the cheaper resolution for contraction-dim FSDP,
so the cost model's baseline choice stands. Escalation path (recorded,
not yet implemented): a shard_map dispatch with explicit
all-to-all(tokens) per data shard, bypassing GSPMD's scatter resolution.
Baseline with the head/embed fix: frac 0.051.

**dit-xl-2 sample_128 (the paper's own workload).**
1. Baseline TP16xDP16: 0.62 ms compute vs 37.3 ms collectives — TP is
   wasted on a 675M model at serve. frac 0.017.
2. DP128xTP2 relayout (same 256 chips): collective 37.3 -> 4.66 ms
   (8x; predicted ~50x — PARTIALLY: the per-layer qkv gathers remain).
3. Pure DP serving (params replicated, 1.35 GB bf16 fits): ZERO layer
   collectives, weight-read bound.
4. + the paper's W8A8: int8 weights halve both the weight-read term and
   the MXU time -> balanced compute/memory at the serving roofline.
   The paper's quantization is exactly the lever that moves this cell's
   dominant term. Final frac: see table (dp_replicated+w8a8).

### Beyond-paper optimizations shipped
- vocab-parallel cross entropy (models/lm.py) — benefits every LM train
  cell; e.g. qwen2.5-3b train collective 54 GiB -> measured drop in the
  re-based roofline.
- cache-sharding rules (launch/steps.py) — every decode cell.
- embedding/head sharding rules (distributed/sharding.py).
- SP attention knob (nn/attention.py, cfg.attn_sp) for head-indivisible
  TP degrees.
- int8-weight serving path (kernels/) with fused dequant epilogue, plus
  int8 gradient compression with error feedback (optim/) for DP
  all-reduce (off by default; both halve their respective byte terms).

### Reproduction deviations (scale-forced, recorded)
- DiT-XL/2 / ImageNet-256 / InceptionV3 replaced by a 6L/d160/64-token
  DiT on synthetic structured latents with FD/sFD/IS* stand-ins
  (orderings comparable, absolutes not; DESIGN §2).
- Sampling schedules 250/100 -> 40/20 respaced steps (CPU wall-clock).
- Empirical-Fisher finding: at near-converged toy scale the raw
  residual-based Fisher under-weights high-noise timesteps and over-clips
  wide-range inputs (x_proj) — +36% end-to-end noise-MSE vs plain MSE.
  Fix: per-batch Fisher RMS normalization (PTQConfig.fisher_norm="batch",
  ablatable back to "raw"), which restores the paper's Table-III
  ordering. The paper's DiT-XL (higher residuals, harder data) would not
  hit this regime as hard; documented as an honest scale artifact.
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    md = render()
    if args.write:
        with open("EXPERIMENTS.md", "w") as f:
            f.write(md)
        print(f"wrote EXPERIMENTS.md ({len(md.splitlines())} lines)")
    else:
        print(md)


if __name__ == "__main__":
    main()
