"""Kernel micro-benchmarks: correctness-at-scale sweeps plus the analytic
TPU benefit model for each Pallas kernel (wall-clock on CPU interpret mode
is meaningless; the TPU win is structural and computed from traffic).

  int8_matmul_fq     : fused-quantize prologue removes the standalone
                       quantize pass (fp32 read + int8 write of the full
                       activation through HBM) and the dequant round trip.
  int8_matmul_mrq_fq : single W traversal for the MRQ twin-region linear
                       (the old deployment paid TWO full int8 matmuls:
                       2x weight bytes, two (M,N) f32 intermediates + add).
  softmax_mrq        : probs tile stays in VMEM; saves read+write of the
                       (rows, cols) f32 probs per attention.
  act_mrq            : saves read+write of the (tokens, d_ff) hidden tensor.
  int8_bmm_qk /      : the composed int8 attention path. The headline
  softmax_mrq_codes /  saving is the PROBS tensor: the fp path writes +
  int8_bmm_pv          reads the (S,S) f32 probabilities through HBM
                       every attention; the fused path moves int8 CODES
                       instead — 4x less probs traffic (1B write + 1B
                       read vs 4B + 4B).
  flash_attn_mrq     : the flash-style fused kernel subsumes all three —
                       scores, softmax state and prob codes stay in
                       VMEM, so the ENTIRE (S,S) HBM round-trip (f32
                       scores write+read + int8 codes write+read, 10B
                       per score element) is eliminated: >=3x whole-
                       attention traffic cut vs composed at DiT-XL/2
                       shapes.

The traffic functions are importable (tests assert the structural-saving
floors, e.g. >=1.5x for the MRQ linear, >=2x probs traffic for fused
attention, >=3x whole-attention for flash at S>=256). ``--attn`` prints
only the attention rows (``make bench-attn``); ``--flash`` only the
flash rows (``make bench-flash``).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels import (act_mrq, flash_attn_mrq, int8_bmm_pv, int8_bmm_qk,
                           int8_matmul, int8_matmul_fq, int8_matmul_mrq_fq,
                           softmax_mrq, softmax_mrq_codes, ref)


# ---------------------------------------------------------------------------
# analytic HBM-traffic models (bytes)
# ---------------------------------------------------------------------------
def traffic_int8_linear(M: int, K: int, N: int) -> dict:
    """W8A8 linear with a per-tensor/TGQ-uniform input.

    unfused — the pre-fusion serving chain:
      quantize pass:  read fp32 x (4B/elt) + write int8 codes (1B/elt),
      int8 matmul:    read codes (1B) + read int8 W (1B), write s32 (4B),
      dequant pass:   read s32 (4B) + write fp32 y (4B).
    fused — int8_matmul_fq: read fp32 x once, read W once, write fp32 y
      once; codes and s32 accumulator never leave VMEM.
    """
    quant_pass = M * K * 4 + M * K * 1
    matmul = M * K * 1 + K * N * 1 + M * N * 4
    dequant = M * N * 4 + M * N * 4
    return {"unfused": quant_pass + matmul + dequant,
            "fused": M * K * 4 + K * N * 1 + M * N * 4}


def traffic_mrq_linear(M: int, K: int, N: int) -> dict:
    """MRQ-signed-input linear (post-GELU fc2).

    unfused — the two-matmul twin-region decomposition:
      region split:   read fp32 x (4B) + write qn AND qp codes (2x1B),
      two matmuls:    read qn + qp (2x1B), read int8 W TWICE (2x1B),
                      write two fp32 (M,N) intermediates (2x4B),
      combine:        read both intermediates + write fp32 y (3x4B).
    fused — int8_matmul_mrq_fq: read fp32 x once, read W ONCE (sign mask
      + dual accumulators in VMEM), write fp32 y once.
    """
    split = M * K * 4 + 2 * M * K * 1
    two_matmuls = 2 * M * K * 1 + 2 * K * N * 1 + 2 * M * N * 4
    combine = 3 * M * N * 4
    return {"unfused": split + two_matmuls + combine,
            "fused": M * K * 4 + K * N * 1 + M * N * 4}


def traffic_attention_probs(BH: int, S: int, D: int) -> dict:
    """Attention softmax->P·V tail for BH (batch*heads) matrices of
    (S, S) scores against (S, D) values.

    unfused — fp probs round-trip (the pre-int8-attention serving path):
      softmax(+qdq): read f32 scores (4B/elt) + WRITE f32 probs (4B),
      P·V:           READ f32 probs (4B) + read f32 v (4B),
                     write f32 out (4B).
    fused — softmax_mrq_codes + int8_bmm_pv: the probs tensor moves as
      int8 codes (1B write + 1B read); v is read once in fp and
      quantized in VMEM; out written once.

    probs_unfused/probs_fused isolate the probs-tensor bytes — the
    quadratic term the codes path shrinks 4x.
    """
    probs_unfused = BH * S * S * (4 + 4)          # f32 write + f32 read
    probs_fused = BH * S * S * (1 + 1)            # int8 codes write + read
    rest = BH * S * S * 4 + BH * S * D * 4 + BH * S * D * 4
    return {
        "probs_unfused": probs_unfused,
        "probs_fused": probs_fused,
        "unfused": probs_unfused + rest,
        "fused": probs_fused + rest,
    }


def traffic_attention_qk(BH: int, S: int, D: int) -> dict:
    """QK^T: the int8 path reads q/k once in fp (quantized in VMEM) and
    writes f32 scores once; the unfused int8 chain would pay a separate
    quantize pass (f32 read + int8 write) per operand."""
    quant_pass = 2 * BH * S * D * (4 + 1)
    matmul = 2 * BH * S * D * 1 + BH * S * S * 4
    return {"unfused": quant_pass + matmul,
            "fused": 2 * BH * S * D * 4 + BH * S * S * 4}


def traffic_attention_flash(BH: int, S: int, D: int,
                            bm: int | None = None) -> dict:
    """Whole-attention HBM bytes: composed three-kernel int8 path vs the
    flash-style fused kernel (``kernels.flash_attn_mrq``).

    composed — ``int8_bmm_qk`` -> ``softmax_mrq_codes`` -> ``int8_bmm_pv``
      still round-trips the quadratic (S, S) tensors through HBM:
      f32 scores write (4B) + read (4B), int8 prob-code write (1B) +
      read (1B) — 10 bytes per score element — on top of the f32 q/k/v
      reads and the output write.
    flash — q is read once and the output written once in f32; the K/V
      stream is charged HONESTLY at one fetch per q-tile
      (``ceil(S/bm)`` reads each — the kernel's kv BlockSpec index maps
      revisit every kv tile for every q-tile, so Pallas cannot elide the
      re-fetch). With the kernel's default ``bm = 256`` that is exactly
      ONE fetch at DiT-serving lengths. Scores, running softmax state
      and prob codes never leave VMEM: the (S, S) round-trip is
      ELIMINATED — ``scores_codes_eliminated`` counts those bytes.

    At DiT-XL/2 attention shape (S = 256, hd = 72) the cut is >= 3x
    (asserted in ``tests/test_flash_attn.py``).
    """
    from repro.kernels.flash_attn_mrq import DEFAULT_BM
    bm = DEFAULT_BM if bm is None else bm
    n_qtiles = -(-S // bm)
    flash = BH * S * D * 4 * (2 + 2 * n_qtiles)  # q+out once, k/v per q-tile
    scores_codes = BH * S * S * (4 + 4 + 1 + 1)
    composed = 4 * BH * S * D * 4 + scores_codes
    return {"composed": composed,
            "flash": flash,
            "scores_codes_eliminated": scores_codes}


def _attention_rows(rows, flash_only: bool = False) -> None:
    key = jax.random.PRNGKey(7)
    # DiT-XL/2 attention shape: 256 tokens, 16 heads, head dim 72 — and a
    # ragged case to exercise padding (and, for flash, the NEG_INF lane
    # masking ahead of the online max).
    for (BH, S, D) in [(16, 256, 72), (3, 130, 17)]:
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (BH, S, D)) * 2
        k = jax.random.normal(k2, (BH, S, D)) * 2
        v = jax.random.normal(k3, (BH, S, D))
        s_q = jnp.full((1, 1), 0.03, jnp.float32)
        s_k = jnp.full((1, 1), 0.04, jnp.float32)
        scale = s_q * s_k * (D ** -0.5)
        scores = int8_bmm_qk(q, k, s_q, s_k, scale, interpret=True)
        s1 = jnp.full((1, 1), 2e-3, jnp.float32)
        codes = softmax_mrq_codes(scores, s1, interpret=True)
        s_v = jnp.full((1, 1), 0.05, jnp.float32)
        out = int8_bmm_pv(codes, v, s_v, s1 * s_v, (1.0 / 128) * s_v,
                          interpret=True)
        t = traffic_attention_qk(BH, S, D)
        tp = traffic_attention_probs(BH, S, D)
        if not flash_only:
            want = ref.int8_bmm_qk_ref(q, k, s_q, s_k, scale)
            err = float(jnp.max(jnp.abs(scores - want)))
            rows.append(("int8_bmm_qk", f"{BH}x{S}x{D}", f"{err:.1e}",
                         t["unfused"], t["fused"],
                         round(t["unfused"] / t["fused"], 2)))

            cerr = int(jnp.max(jnp.abs(
                codes.astype(jnp.int32)
                - ref.softmax_mrq_codes_ref(scores, s1).astype(jnp.int32))))
            rows.append(("softmax_mrq_codes", f"{BH}x{S}x{S}", f"{cerr:d}",
                         tp["probs_unfused"], tp["probs_fused"],
                         round(tp["probs_unfused"] / tp["probs_fused"], 2)))

            pwant = ref.int8_bmm_pv_ref(codes, v, s_v, s1 * s_v,
                                        (1.0 / 128) * s_v)
            perr = float(jnp.max(jnp.abs(out - pwant)))
            rows.append(("int8_bmm_pv", f"{BH}x{S}x{D}", f"{perr:.1e}",
                         tp["unfused"], tp["fused"],
                         round(tp["unfused"] / tp["fused"], 2)))

        # flash-style fused kernel: whole block in one launch, (S,S)
        # scores/codes never in HBM. max_err is vs the COMPOSED output
        # above (the exactness oracle; documented tolerance contract in
        # kernels/ref.py::flash_vs_composed_atol), traffic vs composed.
        fout = flash_attn_mrq(
            q, k, v, s_q, s_k, scale, s1, s_v, s1 * s_v,
            (1.0 / 128) * s_v, interpret=True)
        ferr = float(jnp.max(jnp.abs(fout - out)))
        tf = traffic_attention_flash(BH, S, D)
        rows.append(("flash_attn_mrq", f"{BH}x{S}x{D}", f"{ferr:.1e}",
                     tf["composed"], tf["flash"],
                     round(tf["composed"] / tf["flash"], 2)))


def main(attn_only: bool = False, flash_only: bool = False) -> None:
    rows = [("kernel", "case", "max_err", "hbm_bytes_unfused",
             "hbm_bytes_fused", "traffic_saving")]
    if flash_only:
        _attention_rows(rows, flash_only=True)
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        C.emit("kernel_micro_flash", rows)
        return
    if attn_only:
        _attention_rows(rows)
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        C.emit("kernel_micro_attn", rows)
        return

    key = jax.random.PRNGKey(0)
    # --- fused-quantize int8 matmul: M,K,N sweep ------------------------------
    for (M, K, N) in [(256, 2048, 2048), (512, 4096, 1024)]:
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (M, K)) * 2
        wq = jax.random.randint(k2, (K, N), -128, 128,
                                jnp.int32).astype(jnp.int8)
        sx = jnp.full((1, 1), 0.02, jnp.float32)
        zx = jnp.full((1, 1), 110.0, jnp.float32)
        sw = jax.random.uniform(k1, (N,)) * 1e-3
        corr = (jnp.round(zx).astype(jnp.int32) - 128) * jnp.sum(
            wq.astype(jnp.int32), axis=0)[None, :]
        scale = sx * sw[None, :]
        out = int8_matmul_fq(x, wq, sx, zx, scale, corr, interpret=True)
        want = ref.int8_matmul_fq_ref(x, wq, sx, zx, scale, corr)
        err = float(jnp.max(jnp.abs(out - want)))
        t = traffic_int8_linear(M, K, N)
        rows.append(("int8_matmul_fq", f"{M}x{K}x{N}", f"{err:.1e}",
                     t["unfused"], t["fused"],
                     round(t["unfused"] / t["fused"], 2)))

    # --- single-pass MRQ matmul (fc2-shaped cases) ----------------------------
    for (M, K, N) in [(256, 4608, 1152), (512, 4096, 1024)]:
        k1, k2 = jax.random.split(key)
        x = jax.nn.gelu(jax.random.normal(k1, (M, K)) * 1.5)
        wq = jax.random.randint(k2, (K, N), -128, 128,
                                jnp.int32).astype(jnp.int8)
        s_neg = jnp.full((1, 1), 1.5e-3, jnp.float32)
        s_pos = jnp.full((1, 1), 2.5e-2, jnp.float32)
        sw = jax.random.uniform(k1, (N,)) * 1e-3
        out = int8_matmul_mrq_fq(x, wq, s_neg, s_pos, s_neg * sw[None, :],
                                 s_pos * sw[None, :], interpret=True)
        want = ref.int8_matmul_mrq_fq_ref(x, wq, s_neg, s_pos,
                                          s_neg * sw[None, :],
                                          s_pos * sw[None, :])
        err = float(jnp.max(jnp.abs(out - want)))
        t = traffic_mrq_linear(M, K, N)
        rows.append(("int8_matmul_mrq_fq", f"{M}x{K}x{N}", f"{err:.1e}",
                     t["unfused"], t["fused"],
                     round(t["unfused"] / t["fused"], 2)))

    # --- pre-quantized-codes matmul (einsum-style operands keep it) -----------
    for (M, K, N) in [(256, 2048, 2048)]:
        k1, k2 = jax.random.split(key)
        xq = jax.random.randint(k1, (M, K), -128, 128,
                                jnp.int32).astype(jnp.int8)
        wq = jax.random.randint(k2, (K, N), -128, 128,
                                jnp.int32).astype(jnp.int8)
        scale = jax.random.uniform(k1, (N,)) * 1e-3
        corr = jnp.sum(wq.astype(jnp.int32), axis=0) * 3
        out = int8_matmul(xq, wq, scale, corr, interpret=True)
        want = ref.int8_matmul_ref(xq, wq, scale, corr)
        err = float(jnp.max(jnp.abs(out - want)))
        # epilogue fusion only: saves the s32 round trip of the output
        unfused = M * K + K * N + M * N * (4 + 4 + 4)
        fused = M * K + K * N + M * N * 4
        rows.append(("int8_matmul", f"{M}x{K}x{N}", f"{err:.1e}", unfused,
                     fused, round(unfused / fused, 2)))

    # --- softmax_mrq ------------------------------------------------------------
    for (R, Cc) in [(1024, 1024), (4096, 512)]:
        s = jax.random.normal(key, (R, Cc)) * 4
        out = softmax_mrq(s, 0.3 / 128, bits=8, interpret=True)
        want = ref.softmax_mrq_ref(s, 0.3 / 128, 8)
        err = float(jnp.max(jnp.abs(out - want)))
        unfused = R * Cc * (4 + 4 + 4 + 4)   # probs write+read, q write+read
        fused = R * Cc * (4 + 4)             # scores in, quantized out
        rows.append(("softmax_mrq", f"{R}x{Cc}", f"{err:.1e}", unfused,
                     fused, round(unfused / fused, 2)))

    # --- act_mrq ----------------------------------------------------------------
    for (T, F) in [(2048, 4096)]:
        x = jax.random.normal(key, (T, F)) * 2
        out = act_mrq(x, 0.004, 0.03, bits=8, kind="gelu", interpret=True)
        want = ref.act_mrq_ref(x, 0.004, 0.03, 8, "gelu")
        err = float(jnp.max(jnp.abs(out - want)))
        unfused = T * F * (4 + 4 + 4 + 4)
        fused = T * F * (4 + 4)
        rows.append(("act_mrq", f"{T}x{F}", f"{err:.1e}", unfused, fused,
                     round(unfused / fused, 2)))

    # --- int8 attention (QK^T / softmax codes / P·V) --------------------------
    _attention_rows(rows)

    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    C.emit("kernel_micro", rows)


if __name__ == "__main__":
    main(attn_only="--attn" in sys.argv[1:],
         flash_only="--flash" in sys.argv[1:])
