"""Kernel micro-benchmarks: correctness-at-scale sweeps plus the analytic
TPU benefit model for each Pallas kernel (wall-clock on CPU interpret mode
is meaningless; the TPU win is structural and computed from traffic).

  int8_matmul_fq     : fused-quantize prologue removes the standalone
                       quantize pass (fp32 read + int8 write of the full
                       activation through HBM) and the dequant round trip.
  int8_matmul_mrq_fq : single W traversal for the MRQ twin-region linear
                       (the old deployment paid TWO full int8 matmuls:
                       2x weight bytes, two (M,N) f32 intermediates + add).
  softmax_mrq        : probs tile stays in VMEM; saves read+write of the
                       (rows, cols) f32 probs per attention.
  act_mrq            : saves read+write of the (tokens, d_ff) hidden tensor.
  int8_bmm_qk /      : the composed int8 attention path. The headline
  softmax_mrq_codes /  saving is the PROBS tensor: the fp path writes +
  int8_bmm_pv          reads the (S,S) f32 probabilities through HBM
                       every attention; the fused path moves int8 CODES
                       instead — 4x less probs traffic (1B write + 1B
                       read vs 4B + 4B).
  flash_attn_mrq     : the flash-style fused kernel subsumes all three —
                       scores, softmax state and prob codes stay in
                       VMEM, so the ENTIRE (S,S) HBM round-trip (f32
                       scores write+read + int8 codes write+read, 10B
                       per score element) is eliminated: >=3x whole-
                       attention traffic cut vs composed at DiT-XL/2
                       shapes.

  int4_matmul_fq /   : nibble-packed weights (two 4-bit codes per byte,
  int4_matmul_mrq_fq   per-K-group scales) HALVE the weight stream vs
                       int8 — ~1.88x weight-traffic cut at DiT linear
                       shapes after charging the per-group metadata
                       (asserted >= 1.8x under ``--int4``).

  vector-tgroup      : the ``*_vec`` kernel variants take a per-row
  (``--vector-tgq``)   group VECTOR instead of one prefetched scalar, so
                       a batch whose slots sit at DIFFERENT diffusion
                       timesteps shares one launch — the weight stream
                       is paid once per dispatch, independent of the
                       active-slot count (asserted), where the scalar-
                       prefetch alternative re-streams the weights per
                       slot.

  prologue/epilogue   : the adaLN fp islands around the linears fold
  fusions               into the kernels — norm-modulate (layernorm +
  (``--residue``)       shift/scale) runs in the quantize PROLOGUE, the
                        gate+residual add in the dequant EPILOGUE, the
                        channel-balance prescale divide in the quantize
                        step — so the normalized fp activation and the
                        pre-gate matmul output never round-trip HBM.
                        ``--residue`` audits the whole DiT block: every
                        adaLN/residual fp byte is either fused (operand
                        streams charged) or named as a remaining
                        island; asserts ZERO uncharged adaLN/residual
                        bytes and >= 1.15x modeled block traffic vs the
                        pre-fusion baseline.

The traffic functions are importable (tests assert the structural-saving
floors, e.g. >=1.5x for the MRQ linear, >=2x probs traffic for fused
attention, >=3x whole-attention for flash at S>=256, >=1.8x weight
bytes for packed int4, >=1.15x block traffic for the adaLN fusions).
``--attn`` prints only the attention rows (``make bench-attn``);
``--flash`` only the flash rows (``make bench-flash``); ``--int4`` only
the packed-int4 rows (``make bench-int4``); ``--vector-tgq`` only the
vector-tgroup rows (``make bench-vector-tgq``); ``--residue`` only the
fusion-residue audit (``make bench-residue``).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels import (act_mrq, flash_attn_mrq, int8_bmm_pv, int8_bmm_qk,
                           int8_matmul, int8_matmul_fq, int8_matmul_mrq_fq,
                           softmax_mrq, softmax_mrq_codes, ref)


# ---------------------------------------------------------------------------
# analytic HBM-traffic models (bytes)
# ---------------------------------------------------------------------------
def traffic_int8_linear(M: int, K: int, N: int) -> dict:
    """W8A8 linear with a per-tensor/TGQ-uniform input.

    unfused — the pre-fusion serving chain:
      quantize pass:  read fp32 x (4B/elt) + write int8 codes (1B/elt),
      int8 matmul:    read codes (1B) + read int8 W (1B), write s32 (4B),
      dequant pass:   read s32 (4B) + write fp32 y (4B).
    fused — int8_matmul_fq: read fp32 x once, read W once, write fp32 y
      once; codes and s32 accumulator never leave VMEM.
    """
    quant_pass = M * K * 4 + M * K * 1
    matmul = M * K * 1 + K * N * 1 + M * N * 4
    dequant = M * N * 4 + M * N * 4
    return {"unfused": quant_pass + matmul + dequant,
            "fused": M * K * 4 + K * N * 1 + M * N * 4}


def traffic_mrq_linear(M: int, K: int, N: int) -> dict:
    """MRQ-signed-input linear (post-GELU fc2).

    unfused — the two-matmul twin-region decomposition:
      region split:   read fp32 x (4B) + write qn AND qp codes (2x1B),
      two matmuls:    read qn + qp (2x1B), read int8 W TWICE (2x1B),
                      write two fp32 (M,N) intermediates (2x4B),
      combine:        read both intermediates + write fp32 y (3x4B).
    fused — int8_matmul_mrq_fq: read fp32 x once, read W ONCE (sign mask
      + dual accumulators in VMEM), write fp32 y once.
    """
    split = M * K * 4 + 2 * M * K * 1
    two_matmuls = 2 * M * K * 1 + 2 * K * N * 1 + 2 * M * N * 4
    combine = 3 * M * N * 4
    return {"unfused": split + two_matmuls + combine,
            "fused": M * K * 4 + K * N * 1 + M * N * 4}


def traffic_int4_linear(M: int, K: int, N: int, group_k: int = 256) -> dict:
    """W4A4 linear (``int4_matmul_fq``) vs the W8A8 fused path: the
    weight stream HALVES (two codes per byte) at the price of per-K-group
    metadata — one f32 scale + one s32 zero-correction per
    (K-group, out-channel), i.e. ``ceil(K/group_k) * N * 8`` bytes.  At
    DiT linear shapes (K >= 2048, group_k = 256) the metadata is ~6% of
    the nibble payload, so the weight-traffic cut lands at ~1.88x
    (asserted >= 1.8x in CI via ``--int4``).  Activation read and output
    write are identical between the two paths (fp32 in / fp32 out; codes
    never leave VMEM), so ``fused_int8``/``fused_int4`` differ only by
    the weight stream."""
    nk = -(-K // group_k)
    kp = nk * group_k                      # pack-time padding (code-0 rows)
    int8_weight = K * N * 1
    int4_weight = (kp * N) // 2 + nk * N * (4 + 4)
    return {"int8_weight": int8_weight, "int4_weight": int4_weight,
            "fused_int8": M * K * 4 + int8_weight + M * N * 4,
            "fused_int4": M * K * 4 + int4_weight + M * N * 4}


def traffic_int4_mrq_linear(M: int, K: int, N: int,
                            group_k: int = 256) -> dict:
    """W4A4 MRQ linear (``int4_matmul_mrq_fq``): same nibble payload as
    the uniform path; the metadata is the twin-region scale pair
    (scale_neg + scale_pos, 2 x f32 per (K-group, out-channel)) and no
    zero-correction (both regions are symmetric) — the same 8 bytes per
    (group, channel), so the same ~1.88x weight cut."""
    nk = -(-K // group_k)
    kp = nk * group_k
    int8_weight = K * N * 1
    int4_weight = (kp * N) // 2 + nk * N * (4 + 4)
    return {"int8_weight": int8_weight, "int4_weight": int4_weight,
            "fused_int8": M * K * 4 + int8_weight + M * N * 4,
            "fused_int4": M * K * 4 + int4_weight + M * N * 4}


def traffic_norm_mod_fusion(M: int, B: int, K: int, N: int) -> dict:
    """A linear site with the adaLN norm-modulate chain fused into its
    quantize prologue (qkv / fc1 / the final projection).

    unfused — the PR-8 baseline: the fused linear
      (``traffic_int8_linear['fused']``) PLUS the elementwise chain as
      an XLA pass: read fp32 x (4B/elt) + write the normalized+modulated
      fp32 x (4B) that the linear then reads — 8 bytes/elt of x.
    fused — the chain's write/read disappears; what remains is charged
      HONESTLY: one extra fp32 read of x for the row stats (the mean/var
      reduction runs outside the kernel), the (M, 1) mu/rsig stream
      (write + read, 16 bytes/row) and the per-batch (B, K) shift/scale
      rows (8 bytes/elt) the prologue gathers in VMEM.
    """
    base = M * K * 4 + K * N * 1 + M * N * 4
    chain = 8 * M * K
    charged = 4 * M * K + 16 * M + 8 * B * K
    return {"unfused": base + chain, "fused": base + charged,
            "chain_bytes": chain, "charged_bytes": charged}


def traffic_gate_residual_fusion(M: int, B: int, K: int, N: int) -> dict:
    """A linear site with the adaLN gate + residual add fused into its
    dequant epilogue (proj / fc2).

    unfused — PR-8 baseline: the fused linear plus the
      ``x + g * y`` chain as an XLA pass over the (M, N) output: read y
      (4B/elt) + read the residual (4B) + write the new x (4B) — 12
      bytes/elt.
    fused — the epilogue consumes y in VMEM and writes the gated sum as
      the kernel's single output; charged: the streamed residual tile
      (4B/elt) and the per-batch (B, N) gate rows (4B/elt).
    """
    base = M * K * 4 + K * N * 1 + M * N * 4
    chain = 12 * M * N
    charged = 4 * M * N + 4 * B * N
    return {"unfused": base + chain, "fused": base + charged,
            "chain_bytes": chain, "charged_bytes": charged}


def fused_block_traffic(M: int = 1024, B: int = 4, d: int = 1152,
                        f: int = 4608) -> dict:
    """Whole-DiT-block linear traffic, PR-8 baseline vs fused prologues/
    epilogues, at the XL/2 serving shape (B CFG-paired slots x M/B
    tokens). Returns per-site entries plus aggregates and the residue:
    adaLN/residual chain bytes served by NO fusion (must be zero — every
    chain in the block rides a seam). The post-GELU island is reported
    separately (``gelu_island_bytes``): it is charged on neither path
    and excluded from the residue contract (it feeds the MRQ quantizer,
    not an adaLN chain)."""
    sites = [
        ("xl2_ada", B, d, 6 * d, None),
        ("xl2_qkv", M, d, 3 * d, "nm"),
        ("xl2_proj", M, d, d, "gr"),
        ("xl2_fc1", M, d, f, "nm"),
        ("xl2_fc2", M, f, d, "gr"),
    ]
    per_site, unfused, fused, residue = [], 0, 0, 0
    for name, m, k, n, fusion in sites:
        if fusion == "nm":
            t = traffic_norm_mod_fusion(m, B, k, n)
        elif fusion == "gr":
            t = traffic_gate_residual_fusion(m, B, k, n)
        else:
            base = m * k * 4 + k * n * 1 + m * n * 4
            t = {"unfused": base, "fused": base, "chain_bytes": 0,
                 "charged_bytes": 0}
        # a chain byte is residue iff the site has a chain but no fusion
        # serving it — today every chain is fused, so this stays 0
        t["residue_bytes"] = 0 if fusion is not None else t["chain_bytes"]
        per_site.append((name, fusion, t))
        unfused += t["unfused"]
        fused += t["fused"]
        residue += t["residue_bytes"]
    return {"sites": per_site, "unfused": unfused, "fused": fused,
            "residue_adaln_residual": residue,
            "gelu_island_bytes": 8 * M * f}


def traffic_vector_tgq_linear(M_per_slot: int, K: int, N: int,
                              n_slots: int, bits: int = 8,
                              group_k: int = 256) -> dict:
    """Weight traffic for ONE mixed-timestep dispatch over ``n_slots``
    slots of ``M_per_slot`` activation rows each.

    per_slot — the scalar-prefetch alternative: slots sitting at
      different timestep groups cannot share a launch (the TGQ group
      index is a single prefetched scalar baked into the param index
      maps), so each slot dispatches separately and re-streams the
      weight matrix — ``n_slots`` weight reads per chunk step.
    vector — the ``*_vec`` kernel: the (B,) per-row group vector rides
      as a tiny int32 operand and every row gathers its activation
      params in VMEM (one-hot dot against the (G, ...) stacks), so ALL
      slots share ONE launch and the weights stream exactly once per
      dispatch, independent of the slot count.

    Activation in/out bytes are identical on both paths; per-group
    metadata vectors are not charged, following this file's convention
    (they are noise next to the weight stream).
    """
    if bits == 4:
        w = traffic_int4_linear(M_per_slot, K, N, group_k)["int4_weight"]
    else:
        w = K * N * 1
    act = n_slots * M_per_slot * (K * 4 + N * 4)
    return {"weight_bytes_per_dispatch": w,
            "per_slot": n_slots * w + act,
            "vector": w + act}


def traffic_attention_flash_packed(BH: int, S: int, D: int,
                                   bm: int | None = None) -> dict:
    """Flash attention kv stream: unpacked fp32 vs 4-bit nibble-packed.

    unpacked — k/v are fetched in fp32 once per q-tile:
      ``BH*S*D * (8 + 8*n_qtiles)`` (q read + out write, then 2x4B per
      kv element per q-tile).
    packed — ONE fp32 read of k/v to quantize + nibble-pack them
      (2x4B), one packed write (2x0.5B), then each q-tile streams the
      packed codes (2x0.5B each):
      ``BH*S*D * (8 + 8 + 1 + n_qtiles)``.

    The trade is honest: packing costs an extra 9B/elt up front, so it
    WINS only when the kv stream is re-fetched — n_qtiles >= 2 (e.g.
    S = 512 with the default bm = 256).  At n_qtiles = 1 the unpacked
    path is strictly cheaper and ``ops.flash_attention`` still uses the
    packed path for 4-bit packs only because the code path must match
    the pack bits, not for traffic."""
    from repro.kernels.flash_attn_mrq import DEFAULT_BM
    bm = DEFAULT_BM if bm is None else bm
    n_qtiles = -(-S // bm)
    return {"unpacked": BH * S * D * (8 + 8 * n_qtiles),
            "packed": BH * S * D * (8 + 8 + 1 + n_qtiles),
            "n_qtiles": n_qtiles}


def traffic_attention_probs(BH: int, S: int, D: int) -> dict:
    """Attention softmax->P·V tail for BH (batch*heads) matrices of
    (S, S) scores against (S, D) values.

    unfused — fp probs round-trip (the pre-int8-attention serving path):
      softmax(+qdq): read f32 scores (4B/elt) + WRITE f32 probs (4B),
      P·V:           READ f32 probs (4B) + read f32 v (4B),
                     write f32 out (4B).
    fused — softmax_mrq_codes + int8_bmm_pv: the probs tensor moves as
      int8 codes (1B write + 1B read); v is read once in fp and
      quantized in VMEM; out written once.

    probs_unfused/probs_fused isolate the probs-tensor bytes — the
    quadratic term the codes path shrinks 4x.
    """
    probs_unfused = BH * S * S * (4 + 4)          # f32 write + f32 read
    probs_fused = BH * S * S * (1 + 1)            # int8 codes write + read
    rest = BH * S * S * 4 + BH * S * D * 4 + BH * S * D * 4
    return {
        "probs_unfused": probs_unfused,
        "probs_fused": probs_fused,
        "unfused": probs_unfused + rest,
        "fused": probs_fused + rest,
    }


def traffic_attention_qk(BH: int, S: int, D: int) -> dict:
    """QK^T: the int8 path reads q/k once in fp (quantized in VMEM) and
    writes f32 scores once; the unfused int8 chain would pay a separate
    quantize pass (f32 read + int8 write) per operand."""
    quant_pass = 2 * BH * S * D * (4 + 1)
    matmul = 2 * BH * S * D * 1 + BH * S * S * 4
    return {"unfused": quant_pass + matmul,
            "fused": 2 * BH * S * D * 4 + BH * S * S * 4}


def traffic_attention_flash(BH: int, S: int, D: int,
                            bm: int | None = None) -> dict:
    """Whole-attention HBM bytes: composed three-kernel int8 path vs the
    flash-style fused kernel (``kernels.flash_attn_mrq``).

    composed — ``int8_bmm_qk`` -> ``softmax_mrq_codes`` -> ``int8_bmm_pv``
      still round-trips the quadratic (S, S) tensors through HBM:
      f32 scores write (4B) + read (4B), int8 prob-code write (1B) +
      read (1B) — 10 bytes per score element — on top of the f32 q/k/v
      reads and the output write.
    flash — q is read once and the output written once in f32; the K/V
      stream is charged HONESTLY at one fetch per q-tile
      (``ceil(S/bm)`` reads each — the kernel's kv BlockSpec index maps
      revisit every kv tile for every q-tile, so Pallas cannot elide the
      re-fetch). With the kernel's default ``bm = 256`` that is exactly
      ONE fetch at DiT-serving lengths. Scores, running softmax state
      and prob codes never leave VMEM: the (S, S) round-trip is
      ELIMINATED — ``scores_codes_eliminated`` counts those bytes.

    At DiT-XL/2 attention shape (S = 256, hd = 72) the cut is >= 3x
    (asserted in ``tests/test_flash_attn.py``).
    """
    from repro.kernels.flash_attn_mrq import DEFAULT_BM
    bm = DEFAULT_BM if bm is None else bm
    n_qtiles = -(-S // bm)
    flash = BH * S * D * 4 * (2 + 2 * n_qtiles)  # q+out once, k/v per q-tile
    scores_codes = BH * S * S * (4 + 4 + 1 + 1)
    composed = 4 * BH * S * D * 4 + scores_codes
    return {"composed": composed,
            "flash": flash,
            "scores_codes_eliminated": scores_codes}


def _attention_rows(rows, flash_only: bool = False) -> None:
    key = jax.random.PRNGKey(7)
    # DiT-XL/2 attention shape: 256 tokens, 16 heads, head dim 72 — and a
    # ragged case to exercise padding (and, for flash, the NEG_INF lane
    # masking ahead of the online max).
    for (BH, S, D) in [(16, 256, 72), (3, 130, 17)]:
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (BH, S, D)) * 2
        k = jax.random.normal(k2, (BH, S, D)) * 2
        v = jax.random.normal(k3, (BH, S, D))
        s_q = jnp.full((1, 1), 0.03, jnp.float32)
        s_k = jnp.full((1, 1), 0.04, jnp.float32)
        scale = s_q * s_k * (D ** -0.5)
        scores = int8_bmm_qk(q, k, s_q, s_k, scale, interpret=True)
        s1 = jnp.full((1, 1), 2e-3, jnp.float32)
        codes = softmax_mrq_codes(scores, s1, interpret=True)
        s_v = jnp.full((1, 1), 0.05, jnp.float32)
        out = int8_bmm_pv(codes, v, s_v, s1 * s_v, (1.0 / 128) * s_v,
                          interpret=True)
        t = traffic_attention_qk(BH, S, D)
        tp = traffic_attention_probs(BH, S, D)
        if not flash_only:
            want = ref.int8_bmm_qk_ref(q, k, s_q, s_k, scale)
            err = float(jnp.max(jnp.abs(scores - want)))
            rows.append(("int8_bmm_qk", f"{BH}x{S}x{D}", f"{err:.1e}",
                         t["unfused"], t["fused"],
                         round(t["unfused"] / t["fused"], 2)))

            cerr = int(jnp.max(jnp.abs(
                codes.astype(jnp.int32)
                - ref.softmax_mrq_codes_ref(scores, s1).astype(jnp.int32))))
            rows.append(("softmax_mrq_codes", f"{BH}x{S}x{S}", f"{cerr:d}",
                         tp["probs_unfused"], tp["probs_fused"],
                         round(tp["probs_unfused"] / tp["probs_fused"], 2)))

            pwant = ref.int8_bmm_pv_ref(codes, v, s_v, s1 * s_v,
                                        (1.0 / 128) * s_v)
            perr = float(jnp.max(jnp.abs(out - pwant)))
            rows.append(("int8_bmm_pv", f"{BH}x{S}x{D}", f"{perr:.1e}",
                         tp["unfused"], tp["fused"],
                         round(tp["unfused"] / tp["fused"], 2)))

        # flash-style fused kernel: whole block in one launch, (S,S)
        # scores/codes never in HBM. max_err is vs the COMPOSED output
        # above (the exactness oracle; documented tolerance contract in
        # kernels/ref.py::flash_vs_composed_atol), traffic vs composed.
        fout = flash_attn_mrq(
            q, k, v, s_q, s_k, scale, s1, s_v, s1 * s_v,
            (1.0 / 128) * s_v, interpret=True)
        ferr = float(jnp.max(jnp.abs(fout - out)))
        tf = traffic_attention_flash(BH, S, D)
        rows.append(("flash_attn_mrq", f"{BH}x{S}x{D}", f"{ferr:.1e}",
                     tf["composed"], tf["flash"],
                     round(tf["composed"] / tf["flash"], 2)))


def _int4_rows(rows) -> None:
    """Packed-int4 linear family + packed-kv flash: correctness vs the
    ref.py oracles through the REAL pack builders, and the weight-stream
    traffic cut (asserted >= 1.8x at DiT linear shapes — the CI gate for
    ``make bench-int4``)."""
    from repro.core.quantizers import (ChannelQ, MRQSignedQ, TGQ, UniformQ,
                                       channel_scale_from_absmax,
                                       weight_absmax)
    from repro.kernels import ops

    G = 3
    for (M, K, N) in [(256, 2048, 2048), (256, 4608, 1152)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(11 + K), 2)
        w = jax.random.normal(kw, (K, N)) * 0.05

        x = jax.random.normal(kx, (M, K)) * 2.0
        qp = {"x": TGQ(UniformQ(scale=jnp.linspace(0.01, 0.05, G),
                                zero=jnp.round(jnp.linspace(5.6, 9.4, G)),
                                bits=4)),
              "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 4),
                            4)}
        pack = ops.pack_int4_linear(qp, np.asarray(w))
        out = ops.int4_linear(x, pack, tgroup=1)
        want = ref.int4_matmul_fq_ref(
            x, pack["wp"], pack["sx"], pack["zx"], pack["scale"],
            pack["corr"], g=1, group_k=pack["group_k"])
        err = float(jnp.max(jnp.abs(out - want)))
        t = traffic_int4_linear(M, K, N, group_k=pack["group_k"])
        cut = t["int8_weight"] / t["int4_weight"]
        assert cut >= 1.8, (
            f"int4 weight-traffic cut {cut:.2f}x < 1.8x at {M}x{K}x{N}")
        rows.append(("int4_matmul_fq", f"{M}x{K}x{N}", f"{err:.1e}",
                     t["int8_weight"], t["int4_weight"], round(cut, 2)))

        xg = jax.nn.gelu(jax.random.normal(kx, (M, K)) * 1.5)
        qpm = {"x": TGQ(MRQSignedQ(s_neg=jnp.geomspace(1e-4, 2e-3, G),
                                   s_pos=jnp.geomspace(1e-3, 2e-2, G),
                                   bits=4)),
               "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 4),
                             4)}
        packm = ops.pack_int4_mrq_linear(qpm, np.asarray(w))
        outm = ops.int4_linear_mrq(xg, packm, tgroup=1)
        wantm = ref.int4_matmul_mrq_fq_ref(
            xg, packm["wp"], packm["s_neg"], packm["s_pos"],
            packm["scale_neg"], packm["scale_pos"], g=1,
            group_k=packm["group_k"])
        errm = float(jnp.max(jnp.abs(outm - wantm)))
        tm = traffic_int4_mrq_linear(M, K, N, group_k=packm["group_k"])
        cutm = tm["int8_weight"] / tm["int4_weight"]
        assert cutm >= 1.8, (
            f"int4 MRQ weight-traffic cut {cutm:.2f}x < 1.8x at {M}x{K}x{N}")
        rows.append(("int4_matmul_mrq_fq", f"{M}x{K}x{N}", f"{errm:.1e}",
                     tm["int8_weight"], tm["int4_weight"], round(cutm, 2)))

    # packed-kv flash: packed vs unpacked 4-bit kv stream is BIT-identical
    # (same codes either way); traffic quoted at the multi-q-tile shape
    # where packing actually wins (S = 512 > bm = 256 -> n_qtiles = 2).
    BH, S, D, bn = 3, 130, 17, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(23), 3)
    q = jax.random.normal(k1, (BH, S, D)) * 2
    k = jax.random.normal(k2, (BH, S, D)) * 2
    v = jax.random.normal(k3, (BH, S, D))
    s_q = jnp.full((1, 1), 0.03, jnp.float32)
    s_k = jnp.full((1, 1), 0.04, jnp.float32)
    scale = s_q * s_k * (D ** -0.5)
    s1 = jnp.full((1, 1), 2e-3, jnp.float32)
    s_v = jnp.full((1, 1), 0.05, jnp.float32)
    kwargs = dict(bits=4, bn=bn, interpret=True)
    f_packed = flash_attn_mrq(q, k, v, s_q, s_k, scale, s1, s_v, s1 * s_v,
                              (1.0 / 8) * s_v, packed_kv=True, **kwargs)
    f_plain = flash_attn_mrq(q, k, v, s_q, s_k, scale, s1, s_v, s1 * s_v,
                             (1.0 / 8) * s_v, packed_kv=False, **kwargs)
    ferr = float(jnp.max(jnp.abs(f_packed - f_plain)))
    tf = traffic_attention_flash_packed(16, 512, 72)
    assert tf["n_qtiles"] >= 2
    rows.append(("flash_attn_mrq[packed_kv]", "16x512x72", f"{ferr:.1e}",
                 tf["unpacked"], tf["packed"],
                 round(tf["unpacked"] / tf["packed"], 2)))


def _vector_tgq_rows(rows) -> None:
    """Vector-tgroup rows (``--vector-tgq``): correctness of the per-row
    gather kernels at a MIXED group vector (vs the per-row oracles,
    through the real pack builders) plus the dispatch traffic model for
    a mixed-timestep slot batch. ASSERTS the one-weight-read contract:
    modeled weight bytes per dispatch do not depend on the number of
    active slots."""
    from repro.core.quantizers import (ChannelQ, MRQSoftmaxQ, SymQ, TGQ,
                                       UniformQ, channel_scale_from_absmax,
                                       weight_absmax)
    from repro.kernels import ops
    from repro.kernels.flash_attn_mrq import flash_attn_mrq_vec

    G = 4
    M, K, N = 64, 256, 128
    kx, kw = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(kx, (M, K)) * 2.0
    w = jax.random.normal(kw, (K, N)) * 0.05
    gv = jnp.asarray(np.arange(M) % G, jnp.int32)
    for bits, name in ((8, "int8_matmul_fq_vec"), (4, "int4_matmul_fq_vec")):
        half = 2 ** (bits - 1)
        qp = {"x": TGQ(UniformQ(scale=jnp.linspace(0.01, 0.05, G),
                                zero=jnp.round(jnp.linspace(
                                    0.7 * half, 1.17 * half, G)),
                                bits=bits)),
              "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w),
                                                      bits), bits)}
        if bits == 4:
            pack = ops.pack_int4_linear(qp, np.asarray(w))
            out = ops.int4_linear(x, pack, tgroup=gv)
            want = ref.int4_matmul_fq_vec_ref(
                x, pack["wp"], pack["sx"], pack["zx"], pack["scale"],
                pack["corr"], gv=gv, group_k=pack["group_k"])
        else:
            pack = ops.pack_int8_linear(qp, np.asarray(w))
            out = ops.int8_linear(x, pack, tgroup=gv)
            want = ref.int8_matmul_fq_vec_ref(
                x, pack["wq"], pack["sx"], pack["zx"], pack["scale"],
                pack["corr"], gv=gv)
        err = float(jnp.max(jnp.abs(out - want)))
        t = traffic_vector_tgq_linear(M, K, N, G, bits=bits)
        rows.append((name, f"{M}x{K}x{N}[mixed,G={G}]", f"{err:.1e}",
                     t["per_slot"], t["vector"],
                     round(t["per_slot"] / t["vector"], 2)))

    # flash with a per-batch-row group vector: a constant vector must be
    # BIT-identical to the scalar-prefetch kernel (asserted)
    B, S, D = 3, 16, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (B, S, D)) * 2
    k = jax.random.normal(k2, (B, S, D)) * 2
    v = jax.random.normal(k3, (B, S, D))
    qk_pack = ops.pack_int8_qk(
        {"x": TGQ(SymQ(scale=jnp.linspace(0.01, 0.05, G))),
         "b": TGQ(SymQ(scale=jnp.linspace(0.02, 0.06, G)))})
    pv_pack = ops.pack_int8_pv(
        {"x": TGQ(MRQSoftmaxQ(s1=jnp.geomspace(3e-4, 6e-3, G))),
         "b": TGQ(SymQ(scale=jnp.linspace(0.01, 0.04, G)))})
    scale = D ** -0.5
    args = (q, k, v, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * scale, pv_pack["s1"], pv_pack["s_v"],
            pv_pack["scale1"], pv_pack["scale2"])
    got = flash_attn_mrq_vec(*args, g_qk=jnp.full((B,), 2, jnp.int32),
                             g_pv=jnp.full((B,), 2, jnp.int32),
                             interpret=True)
    want = flash_attn_mrq(*args, g_qk=2, g_pv=2, interpret=True)
    ferr = float(jnp.max(jnp.abs(got - want)))
    assert ferr == 0.0, (
        f"constant group vector diverged from scalar prefetch: {ferr}")
    rows.append(("flash_attn_mrq_vec", f"{B}x{S}x{D}[const==scalar]",
                 f"{ferr:.1e}", "-", "-", "-"))

    # one-weight-read contract at the DiT-XL/2 fc1 shape: one chunk-step
    # dispatch over n active mixed-timestep slots (CFG-paired, 2*256
    # token rows per slot) streams the weights ONCE
    T, d, f = 256, 1152, 4608
    base = None
    for n_slots in (1, 2, 4, 8):
        t = traffic_vector_tgq_linear(2 * T, d, f, n_slots)
        if base is None:
            base = t["weight_bytes_per_dispatch"]
        assert t["weight_bytes_per_dispatch"] == base, (
            "vector-tgq dispatch weight bytes must not scale with the "
            f"active-slot count ({t['weight_bytes_per_dispatch']} != "
            f"{base} at {n_slots} slots)")
        rows.append(("vector_tgq_dispatch", f"xl2_fc1[{n_slots}_slots]",
                     "-", t["per_slot"], t["vector"],
                     round(t["per_slot"] / t["vector"], 2)))


def _residue_rows(rows) -> None:
    """Fusion-residue audit (``--residue``): correctness of the fully
    fused kernel (norm-modulate prologue + gate+residual epilogue in one
    launch, vs the jitted ``*_fused_ref`` oracle), then the XL/2 block
    traffic table. ASSERTS zero uncharged adaLN/residual fp bytes and a
    >= 1.15x modeled block-aggregate traffic win over the PR-8 baseline
    (fused linears, chains still in XLA) — the CI gate for
    ``make bench-residue``."""
    # correctness probe: all three fusions live in one int8 launch
    M, K, N, B, G = 64, 96, 80, 4, 3
    kx, kw, kf = jax.random.split(jax.random.PRNGKey(41), 3)
    x = jax.random.normal(kx, (M, K)) * 2
    wq = jax.random.randint(kw, (K, N), -128, 128, jnp.int32).astype(
        jnp.int8)
    sx = (jax.random.uniform(kf, (G, 1)) * 0.04 + 0.01).astype(jnp.float32)
    zx = jnp.round(jax.random.uniform(kx, (G, 1)) * 200.0)
    scale = (jax.random.uniform(kw, (G, N)) * 1e-3 + 1e-5).astype(
        jnp.float32)
    corr = (jnp.round(zx).astype(jnp.int32) - 128) * jnp.sum(
        wq.astype(jnp.int32), axis=0)[None, :]
    bias = jax.random.normal(kf, (N,))
    ks = jax.random.split(kf, 5)
    ps = jnp.exp(jax.random.uniform(ks[0], (K,), minval=-1.0, maxval=1.0))
    nm = (jax.random.normal(ks[1], (B, K)) * 0.5,
          jax.random.normal(ks[2], (B, K)) * 0.2)
    gr = (jax.random.normal(ks[3], (B, N)) * 0.8,
          jax.random.normal(ks[4], (M, N)))
    bv = jnp.repeat(jnp.arange(B, dtype=jnp.int32), M // B)
    out = int8_matmul_fq(x, wq, sx, zx, scale, corr, bias, g=1, ps=ps,
                         nm=nm, gr=gr, bv=bv, interpret=True)
    want = jax.jit(lambda *a: ref.int8_matmul_fq_fused_ref(
        *a, bias, g=1, ps=ps, nm=nm, gr=gr, bv=bv))(x, wq, sx, zx, scale,
                                                    corr)
    err = float(jnp.max(jnp.abs(out - want)))
    rows.append(("int8_matmul_fq[nm+ps+gr]", f"{M}x{K}x{N}", f"{err:.1e}",
                 "-", "-", "-"))

    # XL/2 block traffic: PR-8 baseline vs fused prologues/epilogues
    t = fused_block_traffic()
    for name, fusion, ts in t["sites"]:
        rows.append((f"linear[{fusion or 'plain'}]", name,
                     f"residue={ts['residue_bytes']}", ts["unfused"],
                     ts["fused"],
                     round(ts["unfused"] / ts["fused"], 3)))
    assert t["residue_adaln_residual"] == 0, (
        "uncharged adaLN/residual fp bytes remain: "
        f"{t['residue_adaln_residual']}")
    win = t["unfused"] / t["fused"]
    assert win >= 1.15, (
        f"fused block traffic win {win:.3f}x < 1.15x vs the PR-8 baseline")
    rows.append(("dit_block_aggregate", "xl2[4x256tok]", "residue=0",
                 t["unfused"], t["fused"], round(win, 3)))
    # the one elementwise fp island left between the linears — charged on
    # neither path, excluded from the residue contract
    rows.append(("post_gelu_island", "xl2_fc1->fc2",
                 f"bytes={t['gelu_island_bytes']}", "-", "-", "-"))


def main(attn_only: bool = False, flash_only: bool = False,
         int4_only: bool = False, vector_tgq_only: bool = False,
         residue_only: bool = False) -> None:
    rows = [("kernel", "case", "max_err", "hbm_bytes_unfused",
             "hbm_bytes_fused", "traffic_saving")]
    if residue_only:
        _residue_rows(rows)
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        C.emit("kernel_micro_residue", rows)
        return
    if vector_tgq_only:
        _vector_tgq_rows(rows)
        C.emit("kernel_micro_vector_tgq", rows)
        return
    if int4_only:
        _int4_rows(rows)
        C.emit("kernel_micro_int4", rows)
        return
    if flash_only:
        _attention_rows(rows, flash_only=True)
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        C.emit("kernel_micro_flash", rows)
        return
    if attn_only:
        _attention_rows(rows)
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        C.emit("kernel_micro_attn", rows)
        return

    key = jax.random.PRNGKey(0)
    # --- fused-quantize int8 matmul: M,K,N sweep ------------------------------
    for (M, K, N) in [(256, 2048, 2048), (512, 4096, 1024)]:
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (M, K)) * 2
        wq = jax.random.randint(k2, (K, N), -128, 128,
                                jnp.int32).astype(jnp.int8)
        sx = jnp.full((1, 1), 0.02, jnp.float32)
        zx = jnp.full((1, 1), 110.0, jnp.float32)
        sw = jax.random.uniform(k1, (N,)) * 1e-3
        corr = (jnp.round(zx).astype(jnp.int32) - 128) * jnp.sum(
            wq.astype(jnp.int32), axis=0)[None, :]
        scale = sx * sw[None, :]
        out = int8_matmul_fq(x, wq, sx, zx, scale, corr, interpret=True)
        want = ref.int8_matmul_fq_ref(x, wq, sx, zx, scale, corr)
        err = float(jnp.max(jnp.abs(out - want)))
        t = traffic_int8_linear(M, K, N)
        rows.append(("int8_matmul_fq", f"{M}x{K}x{N}", f"{err:.1e}",
                     t["unfused"], t["fused"],
                     round(t["unfused"] / t["fused"], 2)))

    # --- single-pass MRQ matmul (fc2-shaped cases) ----------------------------
    for (M, K, N) in [(256, 4608, 1152), (512, 4096, 1024)]:
        k1, k2 = jax.random.split(key)
        x = jax.nn.gelu(jax.random.normal(k1, (M, K)) * 1.5)
        wq = jax.random.randint(k2, (K, N), -128, 128,
                                jnp.int32).astype(jnp.int8)
        s_neg = jnp.full((1, 1), 1.5e-3, jnp.float32)
        s_pos = jnp.full((1, 1), 2.5e-2, jnp.float32)
        sw = jax.random.uniform(k1, (N,)) * 1e-3
        out = int8_matmul_mrq_fq(x, wq, s_neg, s_pos, s_neg * sw[None, :],
                                 s_pos * sw[None, :], interpret=True)
        want = ref.int8_matmul_mrq_fq_ref(x, wq, s_neg, s_pos,
                                          s_neg * sw[None, :],
                                          s_pos * sw[None, :])
        err = float(jnp.max(jnp.abs(out - want)))
        t = traffic_mrq_linear(M, K, N)
        rows.append(("int8_matmul_mrq_fq", f"{M}x{K}x{N}", f"{err:.1e}",
                     t["unfused"], t["fused"],
                     round(t["unfused"] / t["fused"], 2)))

    # --- pre-quantized-codes matmul (einsum-style operands keep it) -----------
    for (M, K, N) in [(256, 2048, 2048)]:
        k1, k2 = jax.random.split(key)
        xq = jax.random.randint(k1, (M, K), -128, 128,
                                jnp.int32).astype(jnp.int8)
        wq = jax.random.randint(k2, (K, N), -128, 128,
                                jnp.int32).astype(jnp.int8)
        scale = jax.random.uniform(k1, (N,)) * 1e-3
        corr = jnp.sum(wq.astype(jnp.int32), axis=0) * 3
        out = int8_matmul(xq, wq, scale, corr, interpret=True)
        want = ref.int8_matmul_ref(xq, wq, scale, corr)
        err = float(jnp.max(jnp.abs(out - want)))
        # epilogue fusion only: saves the s32 round trip of the output
        unfused = M * K + K * N + M * N * (4 + 4 + 4)
        fused = M * K + K * N + M * N * 4
        rows.append(("int8_matmul", f"{M}x{K}x{N}", f"{err:.1e}", unfused,
                     fused, round(unfused / fused, 2)))

    # --- softmax_mrq ------------------------------------------------------------
    for (R, Cc) in [(1024, 1024), (4096, 512)]:
        s = jax.random.normal(key, (R, Cc)) * 4
        out = softmax_mrq(s, 0.3 / 128, bits=8, interpret=True)
        want = ref.softmax_mrq_ref(s, 0.3 / 128, 8)
        err = float(jnp.max(jnp.abs(out - want)))
        unfused = R * Cc * (4 + 4 + 4 + 4)   # probs write+read, q write+read
        fused = R * Cc * (4 + 4)             # scores in, quantized out
        rows.append(("softmax_mrq", f"{R}x{Cc}", f"{err:.1e}", unfused,
                     fused, round(unfused / fused, 2)))

    # --- act_mrq ----------------------------------------------------------------
    for (T, F) in [(2048, 4096)]:
        x = jax.random.normal(key, (T, F)) * 2
        out = act_mrq(x, 0.004, 0.03, bits=8, kind="gelu", interpret=True)
        want = ref.act_mrq_ref(x, 0.004, 0.03, 8, "gelu")
        err = float(jnp.max(jnp.abs(out - want)))
        unfused = T * F * (4 + 4 + 4 + 4)
        fused = T * F * (4 + 4)
        rows.append(("act_mrq", f"{T}x{F}", f"{err:.1e}", unfused, fused,
                     round(unfused / fused, 2)))

    # --- int8 attention (QK^T / softmax codes / P·V) --------------------------
    _attention_rows(rows)

    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    C.emit("kernel_micro", rows)


if __name__ == "__main__":
    main(attn_only="--attn" in sys.argv[1:],
         flash_only="--flash" in sys.argv[1:],
         int4_only="--int4" in sys.argv[1:],
         vector_tgq_only="--vector-tgq" in sys.argv[1:],
         residue_only="--residue" in sys.argv[1:])
