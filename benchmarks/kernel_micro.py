"""Kernel micro-benchmarks: correctness-at-scale sweeps plus the analytic
TPU benefit model for each Pallas kernel (wall-clock on CPU interpret mode
is meaningless; the TPU win is structural and computed from traffic).

  int8_matmul  : MXU int8 = 2x bf16 peak; weights at 1B vs 2B -> weight-
                 bound decode speedup ~2x, epilogue fusion saves one HBM
                 round trip of the (M,N) f32 output.
  softmax_mrq  : probs tile stays in VMEM; saves read+write of the
                 (rows, cols) f32 probs (8 bytes/element) per attention.
  act_mrq      : saves read+write of the (tokens, d_ff) hidden tensor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels import act_mrq, int8_matmul, softmax_mrq, ref


def main() -> None:
    rows = [("kernel", "case", "max_err", "hbm_bytes_unfused",
             "hbm_bytes_fused", "traffic_saving")]

    key = jax.random.PRNGKey(0)
    # --- int8 matmul: M,K,N sweep -------------------------------------------
    for (M, K, N) in [(256, 2048, 2048), (512, 4096, 1024)]:
        k1, k2 = jax.random.split(key)
        xq = jax.random.randint(k1, (M, K), -128, 128, jnp.int32).astype(jnp.int8)
        wq = jax.random.randint(k2, (K, N), -128, 128, jnp.int32).astype(jnp.int8)
        scale = jax.random.uniform(k1, (N,)) * 1e-3
        corr = jnp.sum(wq.astype(jnp.int32), axis=0) * 3
        out = int8_matmul(xq, wq, scale, corr, interpret=True)
        want = ref.int8_matmul_ref(xq, wq, scale, corr)
        err = float(jnp.max(jnp.abs(out - want)))
        # unfused: int8 mm writes s32 (4B) + dequant reads s32 writes f32
        unfused = M * K + K * N + M * N * (4 + 4 + 4)
        fused = M * K + K * N + M * N * 4
        rows.append(("int8_matmul", f"{M}x{K}x{N}", f"{err:.1e}", unfused,
                     fused, round(unfused / fused, 2)))

    # --- softmax_mrq ------------------------------------------------------------
    for (R, Cc) in [(1024, 1024), (4096, 512)]:
        s = jax.random.normal(key, (R, Cc)) * 4
        out = softmax_mrq(s, 0.3 / 128, bits=8, interpret=True)
        want = ref.softmax_mrq_ref(s, 0.3 / 128, 8)
        err = float(jnp.max(jnp.abs(out - want)))
        unfused = R * Cc * (4 + 4 + 4 + 4)   # probs write+read, q write+read
        fused = R * Cc * (4 + 4)             # scores in, quantized out
        rows.append(("softmax_mrq", f"{R}x{Cc}", f"{err:.1e}", unfused,
                     fused, round(unfused / fused, 2)))

    # --- act_mrq ----------------------------------------------------------------
    for (T, F) in [(2048, 4096)]:
        x = jax.random.normal(key, (T, F)) * 2
        out = act_mrq(x, 0.004, 0.03, bits=8, kind="gelu", interpret=True)
        want = ref.act_mrq_ref(x, 0.004, 0.03, 8, "gelu")
        err = float(jnp.max(jnp.abs(out - want)))
        unfused = T * F * (4 + 4 + 4 + 4)
        fused = T * F * (4 + 4)
        rows.append(("act_mrq", f"{T}x{F}", f"{err:.1e}", unfused, fused,
                     round(unfused / fused, 2)))

    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
    C.emit("kernel_micro", rows)


if __name__ == "__main__":
    main()
