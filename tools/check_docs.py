"""Docs link/anchor checker + quickstart smoke executor.

Keeps README.md and docs/*.md from rotting:

- every relative markdown link must point at an existing file, and every
  anchor (``other.md#section`` or ``#section``) must match a heading slug
  in its target (http(s) links are skipped — CI has no business flaking
  on the network);
- with ``--run``, every line inside a fenced ```bash block that ends with
  the marker comment ``# ci-smoke`` is executed from the repo root — the
  quickstart commands the docs show are the ones CI actually runs.

Usage:
    python tools/check_docs.py README.md docs/*.md
    python tools/check_docs.py --run README.md docs/*.md
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from typing import List

LINK_RE = re.compile(r"\[([^\]]*)\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)
FENCE_RE = re.compile(r"^```")
SMOKE_MARK = "# ci-smoke"


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (good enough for our headings)."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    h = re.sub(r"[^\w\s-]", "", h)
    return re.sub(r"\s+", "-", h).strip("-")


def strip_code(text: str) -> str:
    """Remove fenced blocks and inline code spans before link scanning."""
    out, fenced = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line.strip()):
            fenced = not fenced
            continue
        if not fenced:
            out.append(re.sub(r"`[^`]*`", "", line))
    return "\n".join(out)


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {slugify(h) for h in HEADING_RE.findall(f.read())}


def check_file(path: str) -> List[str]:
    """Returns a list of error strings for one markdown file."""
    errors: List[str] = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for _, target in LINK_RE.findall(strip_code(text)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        dest = os.path.normpath(os.path.join(base, ref)) if ref \
            else os.path.abspath(path)
        if not os.path.exists(dest):
            errors.append(f"{path}: broken link -> {target}")
            continue
        if anchor:
            if not dest.endswith(".md"):
                errors.append(f"{path}: anchor on non-markdown -> {target}")
            elif slugify(anchor) not in anchors_of(dest):
                errors.append(f"{path}: missing anchor -> {target}")
    return errors


def smoke_commands(path: str) -> List[str]:
    """Lines marked `# ci-smoke` inside ```bash fences."""
    cmds, fenced_bash = [], False
    with open(path, encoding="utf-8") as f:
        for line in f.read().splitlines():
            s = line.strip()
            if s.startswith("```"):
                fenced_bash = s[3:].strip() in ("bash", "sh") \
                    and not fenced_bash
                continue
            if fenced_bash and s.endswith(SMOKE_MARK):
                cmds.append(s[: -len(SMOKE_MARK)].rstrip(" \\"))
    return cmds


def run_smoke(files: List[str], root: str) -> List[str]:
    errors = []
    for path in files:
        for cmd in smoke_commands(path):
            print(f"[ci-smoke] {cmd}", flush=True)
            r = subprocess.run(cmd, shell=True, cwd=root)
            if r.returncode != 0:
                errors.append(f"{path}: ci-smoke failed ({r.returncode}): "
                              f"{cmd}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--run", action="store_true",
                    help="also execute `# ci-smoke` commands")
    args = ap.parse_args(argv)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    errors: List[str] = []
    n_links = 0
    for path in args.files:
        errors.extend(check_file(path))
        with open(path, encoding="utf-8") as f:
            n_links += len(LINK_RE.findall(strip_code(f.read())))
    if args.run:
        errors.extend(run_smoke(args.files, root))

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {len(args.files)} files, {n_links} links: "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
