"""End-to-end behaviour tests for the TQ-DiT system: quantized sampling
pipeline, LM PTQ, HLO collective parsing, launcher smoke."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_quantized_sampler_end_to_end(tiny_dit):
    """Calibrate TQ-DiT at W8A8 and sample: outputs stay close to FP."""
    from repro.core import (QuantContext, run_ptq,
                            build_dit_calibration, dit_loss_fn)
    from repro.core.baselines import tq_dit
    from repro.diffusion import DiffusionCfg, make_schedule, ddpm_sample
    from repro.models import dit_apply

    cfg, p = tiny_dit
    dif = DiffusionCfg(T=100, tgq_groups=4)
    sched = make_schedule(dif)
    calib = build_dit_calibration(
        p, cfg, dif, sched, lambda n, k: jax.random.normal(k, (n, 8, 8, 4)),
        jax.random.PRNGKey(3), n_per_group=4, batch=4)
    qp, rep = run_ptq(dit_loss_fn(p, cfg), calib,
                      tq_dit(8, 8, tgq_groups=4, n_alpha=6, rounds=1))
    assert rep["n_quantized"] > 10

    eps = lambda x, t, y, ctx: dit_apply(p, cfg, x, t, y, ctx=ctx)
    key = jax.random.PRNGKey(7)
    y = jnp.array([0, 1])
    fp = ddpm_sample(eps, dif, sched, (2, 8, 8, 4), y, key, steps=10)
    qt = ddpm_sample(eps, dif, sched, (2, 8, 8, 4), y, key, steps=10,
                     ctx=QuantContext(qparams=qp))
    assert bool(jnp.all(jnp.isfinite(qt)))
    rel = float(jnp.abs(fp - qt).mean() / (jnp.abs(fp).mean() + 1e-9))
    assert rel < 0.15, f"W8A8 sampling drifted {rel:.3f} from FP"


def test_lm_ptq_end_to_end():
    """The technique transfers to an LM arch (MRQ-SiLU, no TGQ): W8A8
    loss stays near FP."""
    from repro.configs import get_smoke
    from repro.core import (QuantContext, run_ptq,
                            build_lm_calibration, lm_loss_fn,
                            RecordingContext)
    from repro.core.baselines import tq_dit
    from repro.models import lm_init
    from repro.nn.ctx import FPContext

    cfg = get_smoke("qwen3-1.7b")
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = [jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0, cfg.vocab)
            for i in range(4)]
    calib = build_lm_calibration(toks)
    loss = lm_loss_fn(p, cfg)
    qp, rep = run_ptq(loss, calib, tq_dit(8, 8, n_alpha=6, rounds=1))
    fp_loss = float(loss(FPContext(), calib[0][0]))
    q_loss = float(loss(QuantContext(qparams=qp), calib[0][0]))
    assert abs(q_loss - fp_loss) / fp_loss < 0.05
    # post-silu hooks discovered (quantized AT the hook on swiglu archs —
    # the gate feeds an elementwise product, not a matmul directly) and
    # post-softmax provenance attributed to the consuming matmul.
    rec = RecordingContext()
    loss(rec, calib[0][0])
    assert "post_silu" in set(rec.acts.values())
    assert "post_softmax" in {i.a_kind for i in rec.registry.values()}
    # hook quantizers present in qparams
    assert any("act" in v for v in qp.values())


def test_hlo_collective_parser():
    from repro.launch.hlo_stats import collective_stats, total_collective_bytes
    txt = """
  %all-gather.3 = bf16[16,2048,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y)
  %t = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(%a, %b)
  %cp = u8[100]{0} collective-permute(%z)
  %not_a_coll = f32[5]{0} add(%p, %q)
"""
    st = collective_stats(txt)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 2048 * 128 * 2
    assert st["all-reduce"]["bytes"] == 4096
    assert st["all-to-all"]["bytes"] == 2 * 8 * 4 * 4
    assert st["collective-permute"]["bytes"] == 100
    assert total_collective_bytes(txt) == (16 * 2048 * 128 * 2 + 4096
                                           + 256 + 100)


@pytest.mark.slow
def test_train_launcher_smoke(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--smoke", "--steps", "4", "--batch", "2", "--seq", "32",
         "--ckpt_dir", str(tmp_path / "ck"), "--ckpt_every", "2"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done." in out.stdout
    assert (tmp_path / "ck" / "latest").exists()


@pytest.mark.slow
def test_serve_launcher_smoke():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mamba2-130m",
         "--smoke", "--batch", "2", "--prompt_len", "16", "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "generated" in out.stdout
