"""The recipe auto-search subsystem (`repro.autotune`).

Three layers, in dependency order:

1. Pure logic: Pareto dominance properties (no frontier point dominated,
   every excluded point dominated, permutation-invariant output — also
   hypothesis-fuzzed), the greedy bit allocator's invariants (budget
   respected, endpoints exact, sensitivity-targeted, deterministic) and
   the stage-1 gate (fast endpoint always advances).
2. Space expansion: content-hash dedupe, the range-method knob rule (no
   trial a ``quantize()`` guard would reject), mixed-trial component
   ordering, stable keys across field ordering.
3. The driver's resume contract on a REAL (tiny) sweep: killed after N
   trials -> rerun ledgers exactly N stage-1 cache hits and recomputes
   only the rest -> a third run is a 100% cache hit reproducing the
   identical frontier, with every frontier artifact loadable; a
   truncated trailing ledger line is tolerated; resuming under a
   different space or eval protocol fails fast.
"""
import itertools
import json
import os
import random

import numpy as np
import pytest

from repro.autotune import (
    EvalConfig, SearchSpace, allocate_bits, dominates, expand,
    is_strict_tradeoff, load_trial_artifact, mean_bits, pareto_frontier,
    read_ledger, run_autotune, select_survivors,
)
from repro.autotune.driver import run as run_driver
from repro.diffusion import DiffusionCfg
from repro.quant import QuantArtifact, QuantRecipe

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # optional dep
    HAVE_HYPOTHESIS = False

MAXMIN = dict(maximize=("req_per_s",), minimize=("FD",))
DIF = DiffusionCfg(T=40, tgq_groups=4)


def _pts(pairs):
    return [{"key": f"p{i}", "req_per_s": r, "FD": f}
            for i, (r, f) in enumerate(pairs)]


# ---------------------------------------------------------------------------
# pareto: dominance + frontier properties
# ---------------------------------------------------------------------------
def test_dominates_basics():
    a, b = _pts([(10, 1.0), (5, 2.0)])
    assert dominates(a, b, **MAXMIN)
    assert not dominates(b, a, **MAXMIN)
    assert not dominates(a, dict(a, key="x"), **MAXMIN)   # equal: no
    # incomparable: each wins one axis
    c, d = _pts([(10, 2.0), (5, 1.0)])
    assert not dominates(c, d, **MAXMIN)
    assert not dominates(d, c, **MAXMIN)


def _check_frontier_properties(points):
    front = pareto_frontier(points)
    keys = {p["key"] for p in front}
    for p in front:                     # no frontier point dominated
        assert not any(dominates(q, p, **MAXMIN) for q in points)
    for p in points:                    # every excluded point dominated
        if p["key"] not in keys:
            dominated = any(dominates(q, p, **MAXMIN) for q in points)
            duplicate = any(q["key"] != p["key"]
                            and q["req_per_s"] == p["req_per_s"]
                            and q["FD"] == p["FD"] for q in front)
            assert dominated or duplicate
    # sorted fastest-first, strictly improving quality
    assert is_strict_tradeoff(front)
    # the max-throughput point is always represented
    best = max(p["req_per_s"] for p in points)
    assert front[0]["req_per_s"] == best
    return front


def test_frontier_properties_fixed_cases():
    cases = [
        [(10, 5.0), (5, 2.0), (7, 6.0), (10, 5.0)],
        [(1, 1.0)],
        [(3, 3.0), (3, 3.0), (3, 3.0)],
        [(1, 5.0), (2, 4.0), (3, 3.0), (4, 2.0), (5, 1.0)],  # all optimal
        [(5, 1.0), (4, 2.0), (3, 3.0)],                      # one optimal
    ]
    for case in cases:
        _check_frontier_properties(_pts(case))


def test_frontier_permutation_stable():
    pts = _pts([(10, 5.0), (5, 2.0), (7, 6.0), (10, 5.0), (8, 2.5),
                (8, 2.5), (6, 9.0)])
    base = pareto_frontier(pts)
    rng = random.Random(0)
    for _ in range(20):
        shuffled = pts[:]
        rng.shuffle(shuffled)
        assert pareto_frontier(shuffled) == base


if HAVE_HYPOTHESIS:
    @settings(max_examples=80, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 12), st.integers(1, 12)),
                    min_size=1, max_size=24),
           seed=st.integers(0, 2 ** 16))
    def test_frontier_properties_fuzz(pairs, seed):
        """Dominance properties + permutation stability over random
        point sets (integer grids force plenty of exact ties)."""
        pts = _pts([(float(r), float(f)) for r, f in pairs])
        front = _check_frontier_properties(pts)
        shuffled = pts[:]
        random.Random(seed).shuffle(shuffled)
        assert pareto_frontier(shuffled) == front


# ---------------------------------------------------------------------------
# the greedy bit allocator
# ---------------------------------------------------------------------------
SENS = {"w4a4": [10.0, 1.0, 1.0, 1.0], "w8a8": [0.1, 0.9, 0.9, 0.9]}


def test_allocate_endpoints():
    assert allocate_bits(SENS, 4.0) == ["w4a4"] * 4
    assert allocate_bits(SENS, 8.0) == ["w8a8"] * 4
    assert allocate_bits(SENS, 3.9) == ["w4a4"] * 4   # below min: floor


def test_allocate_respects_budget_and_targets_sensitivity():
    for budget in (4.5, 5.0, 6.0, 7.0, 7.9):
        alloc = allocate_bits(SENS, budget)
        assert mean_bits(alloc) <= budget + 1e-9
    # exactly one upgrade affordable: it must go to the most sensitive
    # group (g0 drops 9.9 MSE; the others 0.1)
    assert allocate_bits(SENS, 5.0) == ["w8a8", "w4a4", "w4a4", "w4a4"]


def test_allocate_deterministic_and_fills_budget():
    a = allocate_bits(SENS, 6.0)
    assert a == allocate_bits(dict(SENS), 6.0)
    # flat sensitivity still spends the budget (ties break low-g first)
    flat = {"w4a4": [1.0] * 4, "w8a8": [1.0] * 4}
    assert allocate_bits(flat, 6.0) == ["w8a8", "w8a8", "w4a4", "w4a4"]


def test_allocate_three_levels_one_step_at_a_time():
    sens = {"w4a4": [8.0, 8.0], "w6a6": [2.0, 6.0], "w8a8": [1.0, 1.0]}
    # budget 6: both up to 6 bits (mean 6), or one to 8 one at 4 —
    # greedy takes the per-bit best drops: g1's 4->6 (1.0/bit) then
    # g0's 4->6 (3.0/bit first, actually chosen first), etc.
    alloc = allocate_bits(sens, 6.0)
    assert mean_bits(alloc) <= 6.0
    assert set(alloc) <= {"w4a4", "w6a6", "w8a8"}


def test_allocate_validates():
    with pytest.raises(ValueError, match=">= 2 bits levels"):
        allocate_bits({"w8a8": [1.0, 1.0]}, 8.0)
    with pytest.raises(ValueError, match="group count"):
        allocate_bits({"w4a4": [1.0, 1.0], "w8a8": [1.0]}, 6.0)


# ---------------------------------------------------------------------------
# the stage-1 gate
# ---------------------------------------------------------------------------
def test_survivors_keep_threshold_floor_and_fast_endpoint():
    ecfg = EvalConfig(prune_factor=10.0, keep_at_least=1)
    mse = {"good": 1.0, "ok": 5.0, "bad": 1000.0, "fast": 500.0}
    req = {"good": 10.0, "ok": 10.0, "bad": 10.0, "fast": 99.0}
    kept = select_survivors(mse, req, ecfg)
    assert "good" in kept and "ok" in kept          # within threshold
    assert "fast" in kept                           # max-req/s always
    assert "bad" not in kept
    assert kept == sorted(kept)                     # deterministic order


def test_survivors_deterministic_under_dict_order():
    ecfg = EvalConfig(prune_factor=2.0, keep_at_least=2)
    mse = {"a": 1.0, "b": 3.0, "c": 9.0, "d": 2.0}
    req = {"a": 1.0, "b": 2.0, "c": 3.0, "d": 4.0}
    base = select_survivors(mse, req, ecfg)
    for perm in itertools.permutations(mse):
        assert select_survivors({k: mse[k] for k in perm},
                                {k: req[k] for k in perm}, ecfg) == base


# ---------------------------------------------------------------------------
# space expansion
# ---------------------------------------------------------------------------
def test_expand_dedupes_and_labels():
    sp = SearchSpace(bits=("w8a8", "w8a8", "w4a4"), tgq_groups=(None,))
    ts = expand(sp)
    assert [t.label for t in ts] == ["w8a8/range", "w4a4/range"]
    assert len({t.key() for t in ts}) == len(ts)


def test_expand_range_rows_carry_default_ho_knobs():
    """No expanded 'range' trial may carry a knob quantize() rejects
    under that method — the guard the API enforces, honored at
    expansion time so the ledger has no dead entries."""
    defaults = QuantRecipe()
    sp = SearchSpace(bits=("w8a8", "w4a4"), methods=("range", "ho"),
                     use_mrq=(True, False), tgq_groups=(None, 2))
    ts = expand(sp)
    range_ts = [t for t in ts if t.recipe.method == "range"]
    ho_ts = [t for t in ts if t.recipe.method == "ho"]
    assert len(range_ts) == 4                       # mrq axis inert
    assert len(ho_ts) == 8                          # mrq axis live
    for t in range_ts:
        for f in ("use_mrq", "use_tgq", "rounds", "n_alpha"):
            assert getattr(t.recipe, f) == getattr(defaults, f)
    assert {t.recipe.use_mrq for t in ho_ts} == {True, False}
    assert all(t.recipe.rounds == sp.ho_rounds for t in ho_ts)


def test_expand_mixed_components_precede_and_key_stably():
    sp = SearchSpace(bits=("w4a4", "w8a8"), tgq_groups=(2, 4),
                     bit_budgets=(6.0,))
    ts = expand(sp)
    mixed = [t for t in ts if t.kind == "mixed"]
    assert len(mixed) == 1
    uniform_keys = [t.key() for t in ts if t.kind == "uniform"]
    m = mixed[0]
    assert ts.index(m) > max(ts.index(t) for t in ts
                             if t.kind == "uniform")
    # components are uniform trials of the FIRST group setting,
    # sorted by ascending wbits
    assert [c.bits for c in m.components] == ["w4a4", "w8a8"]
    assert all(c.tgq_groups == 2 for c in m.components)
    assert all(c.content_hash() in uniform_keys for c in m.components)
    # key is content-derived: same space -> same key, budget changes it
    assert m.key() == expand(sp)[-1].key()
    sp2 = SearchSpace(bits=("w4a4", "w8a8"), tgq_groups=(2, 4),
                      bit_budgets=(7.0,))
    assert expand(sp2)[-1].key() != m.key()


def test_space_validation():
    with pytest.raises(ValueError, match="unknown bits"):
        SearchSpace(bits=("w3a3",))
    with pytest.raises(ValueError, match="unknown methods"):
        SearchSpace(methods=("minmax",))
    with pytest.raises(ValueError, match=">= 2 distinct bits"):
        SearchSpace(bits=("w8a8",), bit_budgets=(6.0,))
    with pytest.raises(ValueError, match="achievable mean-bit range"):
        SearchSpace(bits=("w8a8", "w4a4"), bit_budgets=(9.0,))
    with pytest.raises(ValueError, match="full-structure component"):
        expand(SearchSpace(bits=("w8a8", "w4a4"), methods=("ho",),
                           use_mrq=(False,), bit_budgets=(6.0,)))


# ---------------------------------------------------------------------------
# the driver's resume contract (real tiny sweep)
# ---------------------------------------------------------------------------
SPACE = SearchSpace(bits=("w8a8", "w4a4"), tgq_groups=(None,),
                    bit_budgets=(6.0,), n_per_group=1, calib_batch=1)
ECFG = EvalConfig(steps=3, n_gen=8, gen_batch=8, n_real=32, n_mse=8,
                  keep_at_least=3)
N_TRIALS = 3                                        # 2 uniform + 1 mixed


@pytest.fixture(scope="module")
def sweep(tiny_dit, tmp_path_factory):
    """One killed-then-resumed-then-replayed sweep, shared by the
    asserting tests below (the expensive part runs once)."""
    cfg, params = tiny_dit
    out = str(tmp_path_factory.mktemp("autotune"))
    killed = run_autotune(params, cfg, DIF, SPACE, ECFG, out,
                          log=lambda *_: None, max_new_stage1=1)
    full = run_autotune(params, cfg, DIF, SPACE, ECFG, out,
                        log=lambda *_: None)
    resumed = run_autotune(params, cfg, DIF, SPACE, ECFG, out,
                           log=lambda *_: None)
    return cfg, params, out, killed, full, resumed


def test_driver_kill_then_resume_counts(sweep):
    *_, killed, full, resumed = sweep
    assert killed.stopped_early and killed.recomputed == 1
    # resume after the kill: exactly the 1 completed trial cache-hits
    # its stage-1, the other N-1 recompute
    assert full.stage1_hits == 1
    assert full.recomputed == N_TRIALS - 1
    assert not full.stopped_early
    assert len(full.records) == N_TRIALS


def test_driver_full_resume_is_pure_cache_hit(sweep):
    *_, full, resumed = sweep
    assert resumed.recomputed == 0
    assert resumed.cache_hits == N_TRIALS
    assert resumed.frontier == full.frontier
    assert resumed.records == full.records


def test_driver_frontier_shape_and_artifacts(sweep):
    cfg, params, out, _, full, _ = sweep
    assert full.frontier, "frontier must be non-empty"
    assert is_strict_tradeoff(full.frontier)
    by_key = {r["key"]: r for r in full.records}
    for p in full.frontier:
        art = load_trial_artifact(out, by_key[p["key"]])
        if p["kind"] == "uniform":
            assert isinstance(art, QuantArtifact)
            # provenance: the artifact names the recipe that made it
            assert art.meta["recipe_hash"] == p["key"]
        else:
            assert set(art["loaded_components"])
            assert len(art["allocation"]) == DIF.tgq_groups


def test_driver_outputs_deterministic_across_resume(sweep):
    """A fully-cache-hit resume rewrites BENCH_autotune.json and
    report.md byte-identically (wall-clock stays in the ledger)."""
    _, _, out, _, _, resumed = sweep
    with open(os.path.join(out, "BENCH_autotune.json")) as f:
        doc = json.load(f)
    assert doc["frontier"] == resumed.frontier
    assert doc["strict_tradeoff"]
    report = open(os.path.join(out, "report.md")).read()
    assert "Pareto frontier" in report
    for p in resumed.frontier:
        assert p["label"] in report


def test_driver_tolerates_truncated_ledger_tail(sweep):
    _, _, out, *_ = sweep
    ledger = os.path.join(out, "ledger.jsonl")
    n_rows = len(read_ledger(out))
    with open(ledger, "a") as f:
        f.write('{"kind": "final", "key": "dead-beef", "trunca')
    assert len(read_ledger(out)) == n_rows          # tail ignored


def test_driver_resume_under_changed_inputs_fails_fast(sweep):
    cfg, params, out, *_ = sweep
    other_space = SearchSpace(bits=("w8a8",), n_per_group=1,
                              calib_batch=1)
    with pytest.raises(ValueError, match="different space"):
        run_driver(params, cfg, DIF, other_space, ECFG, out,
                   log=lambda *_: None)
    with pytest.raises(ValueError, match="different eval"):
        run_driver(params, cfg, DIF, SPACE,
                   EvalConfig(steps=5, n_gen=8, gen_batch=8, n_real=32,
                              n_mse=8, keep_at_least=3), out,
                   log=lambda *_: None)


def test_mixed_trial_allocation_recorded(sweep):
    *_, full, resumed = sweep
    mixed = [r for r in full.records if r["trial"]["kind"] == "mixed"]
    assert len(mixed) == 1
    alloc = mixed[0]["allocation"]
    assert len(alloc) == DIF.tgq_groups
    assert mean_bits(alloc) <= 6.0 + 1e-9
    assert set(alloc) <= {"w8a8", "w4a4"}
