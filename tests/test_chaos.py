"""Chaos suite: the async engine under injected faults
(`repro.serving.faults`). Every fault is deterministic, every outcome
structured, and the headline invariant holds throughout: UNINJECTED
requests complete bit-identical to the synchronous step-bucketed path no
matter what happens to their neighbours.

Covers: NaN-burst quarantine + retry determinism (fp AND w8a8 kernel
contexts — the `fold_in(PRNGKey(seed), step)` per-slot key contract),
sticky poison -> bounded retries -> structured FAILED, the graceful-
degradation ladder (flash attn -> composed -> fake-quant) on dispatch
faults, ladder exhaustion -> EngineFault with every live request failed,
deadline overruns driven by a FakeClock (no sleeping), and artifact
corruption surfacing as a fail-fast shard-naming error at load.

The dispatch-ahead pipeline section re-runs the NaN / deadline / ladder
faults with pipeline depth 1 vs 2 (the engine speculates the next chunk
before reading back the current one, and must drain the in-flight
dispatch at every fault/lifecycle boundary): outcomes, retry counts,
degradation logs, and samples are asserted byte-for-byte equal across
depths, and a subprocess test repeats the quarantine contract on a
2-device sharded slot pool."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.diffusion import DiffusionCfg
from repro.quant import QuantArtifact, QuantRecipe, quantize
from repro.serving import (
    AsyncServeEngine, EngineFault, FakeClock, Fault, FaultInjector,
    GenRequest, ServeEngine,
)

DIF = DiffusionCfg(T=40, tgq_groups=4)
BUCKETS = (4, 6)

REQS = [
    GenRequest(request_id=0, label=1, steps=4, cfg_scale=1.5, seed=10),
    GenRequest(request_id=1, label=2, steps=6, cfg_scale=1.0, seed=11),
    GenRequest(request_id=2, label=3, steps=4, cfg_scale=0.0, seed=12),
]


@pytest.fixture(scope="module")
def sync_ref(tiny_dit):
    cfg, p = tiny_dit
    eng = ServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    return eng.serve(REQS)


@pytest.fixture(scope="module")
def w8a8(tiny_dit):
    cfg, p = tiny_dit
    return quantize(p, cfg, DIF, QuantRecipe(bits="w8a8", method="range",
                                             n_per_group=1, calib_batch=1))


# ---------------------------------------------------------------------------
# NaN quarantine + retry determinism
# ---------------------------------------------------------------------------
def test_nan_burst_retry_is_bit_identical_fp(tiny_dit, sync_ref):
    """A NaN burst poisons request 1 mid-chain; the engine quarantines
    ONLY that slot and retries it with the same fold_in(PRNGKey(seed), i)
    keys — the retried sample, and every neighbour, is bit-identical to
    the uninjected synchronous run."""
    cfg, p = tiny_dit
    inj = FaultInjector([Fault(kind="nan", request_id=1, at_step=2)])
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           chunk=2, max_retries=2, injector=inj)
    out = eng.serve(REQS)
    assert all(o.status == "OK" for o in out.values())
    assert out[1].retries == 1 and out[0].retries == 0
    for rid, o in out.items():
        assert np.array_equal(o.sample, sync_ref[rid].sample), rid
    assert len(inj.fired) == 1 and eng.stats["retries"] == 1


def test_nan_burst_retry_is_bit_identical_w8a8(tiny_dit, w8a8):
    """Same retry-determinism contract through the fused int8 kernels."""
    cfg, p = tiny_dit
    sync = ServeEngine.from_artifact(p, w8a8, microbatch=2,
                                     step_buckets=BUCKETS)
    ref = sync.serve(REQS)
    inj = FaultInjector([Fault(kind="nan", request_id=2, at_step=1)])
    eng = AsyncServeEngine.from_artifact(p, w8a8, microbatch=2,
                                         step_buckets=BUCKETS, chunk=3,
                                         injector=inj)
    out = eng.serve(REQS)
    assert all(o.status == "OK" for o in out.values())
    assert out[2].retries == 1
    for rid, o in out.items():
        assert np.array_equal(o.sample, ref[rid].sample), rid


def test_sticky_poison_fails_structured_after_max_retries(tiny_dit,
                                                          sync_ref):
    cfg, p = tiny_dit
    inj = FaultInjector([Fault(kind="nan", request_id=0, at_step=1,
                               sticky=True)])
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           chunk=2, max_retries=2, injector=inj)
    out = eng.serve(REQS)
    o = out[0]
    assert o.status == "FAILED" and o.sample is None
    assert o.error.code == "nan_poisoned" and o.error.retries == 2
    assert "request 0" in o.error.message
    # the quarantine is per-slot: neighbours finish bit-identical
    for rid in (1, 2):
        assert out[rid].status == "OK"
        assert np.array_equal(out[rid].sample, sync_ref[rid].sample)


def test_slot_error_fault_kind(tiny_dit):
    cfg, p = tiny_dit
    inj = FaultInjector([Fault(kind="slot_error", request_id=0, at_step=0,
                               sticky=True)])
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           chunk=2, max_retries=1, injector=inj)
    out = eng.serve(REQS[:2])
    assert out[0].status == "FAILED" and out[0].error.code == "slot_error"
    assert out[1].status == "OK"


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------
def test_dispatch_faults_walk_the_degradation_ladder(tiny_dit, w8a8):
    """Two dispatch faults walk flash -> composed -> fake-quant; each rung
    is logged with a reason and every request still completes OK."""
    cfg, p = tiny_dit
    inj = FaultInjector([Fault(kind="dispatch_error", at_dispatch=1),
                         Fault(kind="dispatch_error", at_dispatch=2)])
    eng = AsyncServeEngine.from_artifact(p, w8a8, microbatch=2,
                                         step_buckets=BUCKETS, chunk=2,
                                         injector=inj)
    assert eng.ctx.kernel and eng.ctx.attn_impl == "flash"
    out = eng.serve(REQS)
    assert all(o.status == "OK" for o in out.values())
    reasons = [d["reason"] for d in eng.stats["degradations"]]
    assert len(reasons) == 2
    assert "composed" in reasons[0] and "fake-quant" in reasons[1]
    assert eng.ctx.kernel is False            # landed on the bottom rung


def test_ladder_exhausted_fails_everything_structured(tiny_dit):
    """An fp context has no rung below it: a dispatch fault fails every
    live request with a structured engine_fault and raises EngineFault —
    loud, attributable, nothing dropped on the floor."""
    cfg, p = tiny_dit
    inj = FaultInjector([Fault(kind="dispatch_error", at_dispatch=1)])
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           chunk=2, injector=inj)
    for r in REQS:
        eng.submit_request(r)
    with pytest.raises(EngineFault, match="no degradation rung"):
        eng.run_until_drained()
    assert len(eng.outcomes) == len(REQS)
    assert all(o.status == "FAILED" and o.error.code == "engine_fault"
               for o in eng.outcomes.values())


# ---------------------------------------------------------------------------
# deadlines (FakeClock: no sleeping)
# ---------------------------------------------------------------------------
def test_deadline_overrun_cancels_at_chunk_boundary(tiny_dit, sync_ref):
    cfg, p = tiny_dit
    clk = FakeClock()
    inj = FaultInjector([Fault(kind="stall", at_dispatch=2, seconds=100.0)],
                        clock=clk)
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           chunk=2, deadline_s=10.0, clock=clk, injector=inj)
    out = eng.serve(REQS)
    cancelled = [o for o in out.values() if o.status == "CANCELLED"]
    assert cancelled and all(o.error.code == "deadline" for o in cancelled)
    # request 0 (4 steps, chunk 2) finished BY the stalled boundary: a
    # request that completes on time delivers OK even if the deadline has
    # since passed
    assert out[0].status == "OK"
    assert np.array_equal(out[0].sample, sync_ref[0].sample)


def test_deadline_expired_in_queue_never_admitted(tiny_dit):
    cfg, p = tiny_dit
    clk = FakeClock()
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=1, step_buckets=BUCKETS,
                           clock=clk)
    rid = eng.submit(label=1, steps=4, deadline_s=5.0)
    clk.advance(50.0)                        # expires while queued
    out = eng.run_until_drained()
    assert out[rid].status == "CANCELLED"
    assert out[rid].error.code == "deadline"
    assert eng.stats["admitted"] == 0        # never wasted a slot


# ---------------------------------------------------------------------------
# dispatch-ahead pipelining: faults at chunk boundaries with a two-deep
# in-flight dispatch, and the 2-device sharded slot pool (subprocess)
# ---------------------------------------------------------------------------
def test_pipeline_nan_quarantine_matches_unpipelined(tiny_dit, sync_ref):
    """pipeline=2 speculates the next chunk before the current one is read
    back; a NaN quarantine resets the slot, so the stale in-flight
    dispatch must be drained. Outcomes, retry counts, and samples are
    byte-for-byte those of the unpipelined engine (and of the uninjected
    sync run)."""
    cfg, p = tiny_dit
    outs = {}
    for depth in (1, 2):
        inj = FaultInjector([Fault(kind="nan", request_id=1, at_step=2)])
        eng = AsyncServeEngine(p, cfg, DIF, microbatch=2,
                               step_buckets=BUCKETS, chunk=2, max_retries=2,
                               pipeline=depth, injector=inj)
        outs[depth] = eng.serve(REQS)
    for rid in outs[1]:
        a, b = outs[1][rid], outs[2][rid]
        assert a.status == b.status == "OK"
        assert a.retries == b.retries
        assert np.array_equal(a.sample, b.sample)
        assert np.array_equal(b.sample, sync_ref[rid].sample), rid


def test_pipeline_deadline_cancel_matches_unpipelined(tiny_dit, sync_ref):
    """Deadline cancellation happens at a chunk boundary while a
    speculative chunk is in flight — the cancel must drain it, and the
    set of OK/CANCELLED outcomes must match pipeline=1 exactly."""
    cfg, p = tiny_dit
    outs = {}
    for depth in (1, 2):
        clk = FakeClock()
        inj = FaultInjector([Fault(kind="stall", at_dispatch=2,
                                   seconds=100.0)], clock=clk)
        eng = AsyncServeEngine(p, cfg, DIF, microbatch=2,
                               step_buckets=BUCKETS, chunk=2,
                               deadline_s=10.0, clock=clk, pipeline=depth,
                               injector=inj)
        outs[depth] = eng.serve(REQS)
    for rid in outs[1]:
        a, b = outs[1][rid], outs[2][rid]
        assert a.status == b.status
        if a.status == "OK":
            assert np.array_equal(a.sample, b.sample), rid
        else:
            assert b.error.code == "deadline"
    assert outs[2][0].status == "OK"
    assert np.array_equal(outs[2][0].sample, sync_ref[0].sample)


def test_pipeline_degradation_ladder_matches_unpipelined(tiny_dit, w8a8):
    """Dispatch faults fire while a speculative chunk is in flight: the
    ladder drains the pipeline, degrades, rebuilds the executable, and
    re-dispatches from committed slot state — same rungs, same reasons,
    same samples as pipeline=1 (a failed dispatch stays side-effect
    free at any depth)."""
    cfg, p = tiny_dit
    outs, reasons = {}, {}
    for depth in (1, 2):
        inj = FaultInjector([Fault(kind="dispatch_error", at_dispatch=1),
                             Fault(kind="dispatch_error", at_dispatch=2)])
        eng = AsyncServeEngine.from_artifact(p, w8a8, microbatch=2,
                                             step_buckets=BUCKETS, chunk=2,
                                             pipeline=depth, injector=inj)
        outs[depth] = eng.serve(REQS)
        reasons[depth] = [d["reason"] for d in eng.stats["degradations"]]
        assert eng.ctx.kernel is False
    assert reasons[1] == reasons[2] and len(reasons[2]) == 2
    for rid in outs[1]:
        assert outs[1][rid].status == outs[2][rid].status == "OK"
        assert np.array_equal(outs[1][rid].sample, outs[2][rid].sample), rid


_PIPELINE_DP_SCRIPT = r"""
import jax, numpy as np
assert jax.device_count() == 2, jax.device_count()
from repro.diffusion import DiffusionCfg
from repro.launch.mesh import make_serving_mesh
from repro.models import DiTCfg, dit_init
from repro.serving import (AsyncServeEngine, Fault, FaultInjector,
                           GenRequest, ServeEngine)

cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=32, n_layers=2,
             n_heads=4, n_classes=8)
p = dit_init(jax.random.PRNGKey(0), cfg)
dif = DiffusionCfg(T=40, tgq_groups=4)
reqs = [GenRequest(request_id=i, label=i % 8, steps=s, cfg_scale=1.5,
                   seed=700 + i) for i, s in enumerate([4, 6, 4, 6])]
sync = ServeEngine(p, cfg, dif, microbatch=2,
                   step_buckets=(4, 6)).serve(reqs)
inj = FaultInjector([Fault(kind="nan", request_id=1, at_step=2)])
eng = AsyncServeEngine(p, cfg, dif, mesh=make_serving_mesh(), microbatch=4,
                       step_buckets=(4, 6), chunk=2, pipeline=2,
                       max_retries=2, injector=inj)
out = eng.serve(reqs)
ok = (all(o.status == "OK" for o in out.values())
      and out[1].retries == 1
      and all(np.array_equal(out[i].sample, sync[i].sample)
              for i in range(4)))
print("IDENTICAL" if ok else "MISMATCH")
"""


def test_pipeline_nan_quarantine_on_2dev_sharded_pool():
    """The headline chaos invariant on the scaled-out engine: a 2-device
    sharded slot pool with a two-deep dispatch pipeline quarantines one
    poisoned slot and still delivers every sample bit-identical to the
    single-device synchronous path (subprocess: this test process is
    pinned to 1 CPU device by conftest)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _PIPELINE_DP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "IDENTICAL" in r.stdout, (r.stdout, r.stderr[-2000:])


# ---------------------------------------------------------------------------
# artifact corruption
# ---------------------------------------------------------------------------
def test_artifact_byteflip_fails_fast_naming_shard(tiny_dit, w8a8,
                                                   tmp_path):
    """Flip one byte in a saved artifact's npz shard: load must fail fast
    with an error naming the shard file and the leaves it carries —
    not a cryptic zip/zlib traceback, and never silently-wrong
    quantizer state."""
    path = str(tmp_path / "art")
    w8a8.save(path)
    step_dir = os.path.join(path, "step_00000000")
    shard = os.path.join(step_dir, "shard_00000.npz")
    data = bytearray(open(shard, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(shard, "wb").write(bytes(data))
    with pytest.raises(ValueError, match=r"shard_00000\.npz is corrupted"):
        QuantArtifact.load(path)
    with pytest.raises(ValueError, match="leaf 0"):
        ckpt.verify_shards(path)


def test_artifact_truncated_shard(tiny_dit, w8a8, tmp_path):
    path = str(tmp_path / "art")
    w8a8.save(path)
    shard = os.path.join(path, "step_00000000", "shard_00000.npz")
    data = open(shard, "rb").read()
    open(shard, "wb").write(data[:len(data) // 2])
    with pytest.raises(ValueError, match="corrupted"):
        QuantArtifact.load(path)


def test_artifact_missing_shard(tiny_dit, w8a8, tmp_path):
    path = str(tmp_path / "art")
    w8a8.save(path)
    os.remove(os.path.join(path, "step_00000000", "shard_00000.npz"))
    with pytest.raises(FileNotFoundError, match="missing"):
        QuantArtifact.load(path)


def test_intact_artifact_still_roundtrips(tiny_dit, w8a8, tmp_path):
    """The integrity check must not reject healthy artifacts."""
    path = str(tmp_path / "art")
    w8a8.save(path)
    art = QuantArtifact.load(path)
    assert art.recipe == w8a8.recipe


# ---------------------------------------------------------------------------
# slow sweep: random-but-seeded fault schedules, invariant checked
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fault_schedule_sweep(tiny_dit, sync_ref):
    """Many seeded fault schedules; invariants: every request terminal,
    every OK sample bit-identical to the uninjected sync run, every
    non-OK outcome carries a structured error."""
    cfg, p = tiny_dit
    rng = np.random.default_rng(0)
    for trial in range(10):
        faults = []
        for rid in range(len(REQS)):
            if rng.random() < 0.5:
                faults.append(Fault(
                    kind="nan", request_id=rid,
                    at_step=int(rng.integers(0, 4)),
                    sticky=bool(rng.random() < 0.2)))
        inj = FaultInjector(faults)
        eng = AsyncServeEngine(p, cfg, DIF, microbatch=2,
                               step_buckets=BUCKETS, chunk=2,
                               max_retries=1, injector=inj)
        out = eng.serve(REQS)
        assert len(out) == len(REQS), f"trial {trial} dropped requests"
        for rid, o in out.items():
            if o.status == "OK":
                assert np.array_equal(o.sample, sync_ref[rid].sample), \
                    (trial, rid)
            else:
                assert o.status == "FAILED" and o.error is not None
