import os
import sys

# tests run against the real 1-CPU backend (the dry-run alone forces 512
# placeholder devices, in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables between test modules.

    The full suite compiles thousands of tiny programs (every kernel
    conformance cell is its own jit); letting the live-executable count
    grow across all modules eventually segfaults XLA:CPU's compiler
    deep in ``backend_compile`` (reproducible at suite scale only —
    every module passes in isolation). Nothing relies on cross-module
    cache hits: the compile-once tests count traces within one test."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_dit():
    """Small trained-ish DiT (perturbed from init so outputs are nonzero)."""
    from repro.models import DiTCfg, dit_init
    cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
                 n_heads=4, n_classes=8)
    p = dit_init(jax.random.PRNGKey(0), cfg)
    p["final"]["w"] = jax.random.normal(
        jax.random.PRNGKey(9), p["final"]["w"].shape) * 0.02
    p["blocks"] = jax.tree.map(
        lambda a: a + jax.random.normal(jax.random.PRNGKey(1), a.shape) * 0.01,
        p["blocks"])
    return cfg, p
