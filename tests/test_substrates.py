"""Optimizers, checkpointing, data pipelines, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw, adafactor, apply_updates, clip_by_global_norm,
    compress_grads_int8, cosine_schedule, global_norm, init_error_state,
)
from repro import checkpoint as ckpt
from repro.data import LatentPipeline, TokenPipeline, prefetch


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [lambda: adamw(0.1),
                                      lambda: adafactor(0.5)],
                         ids=["adamw", "adafactor"])
def test_optimizer_descends_quadratic(make_opt):
    opt = make_opt()
    p = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([[1.0, 1.0], [1.0, 1.0]])}
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    st = opt.init(p)
    l0 = float(loss(p))
    for _ in range(50):
        g = jax.grad(loss)(p)
        u, st = opt.update(g, st, p)
        p = apply_updates(p, u)
    assert float(loss(p)) < l0 * 0.1


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    p = {"w": jnp.zeros((64, 32))}
    st = opt.init(p)
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 0.11
    assert float(lr(100)) < 0.15


def test_grad_compression_error_feedback_unbiased():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    e = init_error_state(g)
    total_raw = jnp.zeros((256,))
    total_cmp = jnp.zeros((256,))
    for _ in range(50):
        dg, e = compress_grads_int8(g, e)
        total_raw += g["w"]
        total_cmp += dg["w"]
    # error feedback keeps the long-run sum unbiased
    rel = float(jnp.abs(total_cmp - total_raw).max()
                / jnp.abs(total_raw).max())
    assert rel < 0.01


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "opt": {"step": jnp.int32(7)}}


def test_ckpt_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        s = _state()
        ckpt.save(d, 5, s)
        ckpt.save(d, 10, s)
        assert ckpt.latest_step(d) == 10
        r = ckpt.restore(d, s)
        np.testing.assert_array_equal(r["params"]["w"], s["params"]["w"])


def test_ckpt_retention():
    with tempfile.TemporaryDirectory() as d:
        for i in range(6):
            ckpt.save(d, i, _state(), keep=2)
        dirs = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(dirs) == 2
        assert ckpt.latest_step(d) == 5


def test_ckpt_uncommitted_ignored():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, _state())
        # simulate a crash mid-save at step 9: no _COMMITTED marker
        os.makedirs(os.path.join(d, "step_00000009"))
        with open(os.path.join(d, "latest"), "w") as f:
            f.write("step_00000009")
        assert ckpt.latest_step(d) == 3           # falls back to scan
        r = ckpt.restore(d, _state())
        assert r is not None


def test_ckpt_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, _state())
        bad = {"params": {"w": jnp.zeros((2, 2))}, "opt": {"step": jnp.int32(0)}}
        with pytest.raises(AssertionError):
            ckpt.restore(d, bad)


def test_ckpt_async():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_async(d, 4, _state())
        ckpt.wait_async()
        assert ckpt.latest_step(d) == 4


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_token_pipeline_deterministic_and_host_disjoint():
    a = TokenPipeline(vocab=100, seq_len=16, batch=4, seed=1)
    b = TokenPipeline(vocab=100, seq_len=16, batch=4, seed=1)
    np.testing.assert_array_equal(a.batch_at(3)["tokens"],
                                  b.batch_at(3)["tokens"])
    h0 = TokenPipeline(vocab=100, seq_len=16, batch=4, seed=1, host_id=0,
                       n_hosts=2)
    h1 = TokenPipeline(vocab=100, seq_len=16, batch=4, seed=1, host_id=1,
                       n_hosts=2)
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_token_labels_shifted():
    b = TokenPipeline(vocab=50, seq_len=8, batch=2, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert np.all(np.asarray(b["labels"][:, -1]) == -1)


def test_latent_pipeline_classes_distinct():
    lp = LatentPipeline(img_size=8, channels=2, n_classes=4, seed=0,
                        noise=0.01)
    x, y = lp.sample(64, jax.random.PRNGKey(0))
    x, y = np.asarray(x), np.asarray(y)
    mus = [x[y == k].mean(0) for k in range(4) if np.any(y == k)]
    d01 = np.abs(mus[0] - mus[1]).mean()
    assert d01 > 0.1                             # class patterns differ


def test_prefetch_preserves_order():
    out = list(prefetch(iter(range(10)), depth=3))
    assert out == list(range(10))
