"""Metric stand-ins: Fréchet distance identities, IS-proxy behaviour."""
import jax
import numpy as np
import pytest

from repro.core.metrics import (
    ClassProxy, FeatureNet, fd_score, frechet_distance, gaussian_stats,
    inception_score_proxy, sfd_score,
)


def test_frechet_zero_for_identical():
    f = np.random.default_rng(0).normal(size=(500, 8))
    mu, cov = gaussian_stats(f)
    assert abs(frechet_distance(mu, cov, mu, cov)) < 1e-6


def test_frechet_increases_with_mean_shift():
    rng = np.random.default_rng(0)
    f1 = rng.normal(size=(500, 8))
    d = [frechet_distance(*gaussian_stats(f1), *gaussian_stats(f1 + s))
         for s in (0.1, 0.5, 2.0)]
    assert d[0] < d[1] < d[2]
    np.testing.assert_allclose(d[2], 8 * 4.0, rtol=0.2)   # ||mu||^2 term


def test_fd_score_orders_degradation():
    rng = np.random.default_rng(1)
    real = rng.normal(size=(400, 8, 8, 4)).astype(np.float32)
    gen_good = real + 0.05 * rng.normal(size=real.shape).astype(np.float32)
    gen_bad = real + 1.0 * rng.normal(size=real.shape).astype(np.float32)
    assert fd_score(real, gen_good) < fd_score(real, gen_bad)


def test_sfd_sensitive_to_spatial_scramble():
    rng = np.random.default_rng(2)
    base = rng.normal(size=(300, 8, 8, 2)).astype(np.float32)
    base[:, :4] += 2.0                                  # spatial structure
    scram = base[:, rng.permutation(8)]                 # break rows
    assert sfd_score(base, scram) > sfd_score(base, base + 1e-3)


def test_is_proxy_separable_higher():
    rng = np.random.default_rng(3)
    K, N = 4, 400
    labels = rng.integers(0, K, N)
    centers = rng.normal(size=(K, 6, 6, 2)) * 3
    real = centers[labels] + 0.3 * rng.normal(size=(N, 6, 6, 2))
    proxy = ClassProxy.fit(real.astype(np.float32), labels, K)
    well_sep = centers[rng.integers(0, K, 200)] + 0.3 * rng.normal(
        size=(200, 6, 6, 2))
    collapsed = centers[0][None] + 0.3 * rng.normal(size=(200, 6, 6, 2))
    is_sep = inception_score_proxy(well_sep.astype(np.float32), proxy)
    is_col = inception_score_proxy(collapsed.astype(np.float32), proxy)
    assert is_sep > is_col
    assert is_sep > 2.0                                  # diverse classes
    assert is_col < 1.5                                  # mode collapse


def test_feature_net_deterministic():
    n1 = FeatureNet.make(64, seed=5)
    n2 = FeatureNet.make(64, seed=5)
    x = np.random.default_rng(0).normal(size=(10, 8, 8)).astype(np.float32)
    np.testing.assert_array_equal(n1(x), n2(x))
