"""Docs tree integrity: every relative link/anchor in README.md and
docs/*.md resolves (the execution half of the checker — the `# ci-smoke`
quickstart commands — runs in the CI docs job, not here)."""
import glob
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "tools"))

import check_docs  # noqa: E402


def _doc_files():
    return [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))


def test_docs_tree_exists():
    names = {os.path.basename(p) for p in _doc_files()}
    assert {"README.md", "quantization.md", "kernels.md",
            "serving.md"} <= names


def test_links_and_anchors_resolve():
    errors = []
    for path in _doc_files():
        errors.extend(check_docs.check_file(path))
    assert not errors, "\n".join(errors)


def test_docs_actually_link_the_code():
    """The docs must stay maps, not prose: each page links real files."""
    for path in _doc_files():
        with open(path, encoding="utf-8") as f:
            links = check_docs.LINK_RE.findall(
                check_docs.strip_code(f.read()))
        assert len(links) >= 3, f"{path} has almost no links"


def test_readme_quickstart_is_executable_by_ci():
    """The README must carry `# ci-smoke` serving commands so the docs CI
    job exercises exactly what the quickstart shows."""
    cmds = check_docs.smoke_commands(os.path.join(ROOT, "README.md"))
    assert any("repro.launch.serve" in c for c in cmds), cmds
    assert any("--quantize w8a8" in c for c in cmds), cmds


def test_slugify_matches_github_style():
    assert check_docs.slugify("## TGQ inside the kernels".lstrip("# ")) \
        == "tgq-inside-the-kernels"
    assert check_docs.slugify("Serving: a `ServeEngine` FAQ") \
        == "serving-a-serveengine-faq"
