"""Sharding rules: logical-axis mapping, divisibility guard, spec trees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import (
    batch_axes, bind_logical, logical_axes, param_specs,
)


@pytest.fixture(scope="module")
def mesh11():
    # 1x1 mesh works on one CPU device but exercises the rule machinery
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_axes_rules():
    assert logical_axes("blocks/attn/q/w", 3) == (None, "fsdp", "tp")
    assert logical_axes("blocks/mlp/down/w", 3) == (None, "tp", "fsdp")
    assert logical_axes("blocks/mlp/gate", 4) == (None, "ep", "fsdp", None)
    # embedding/head tables shard vocab ONLY: FSDP on d_model (the logits
    # contraction dim) makes GSPMD partial-sum full-batch logits
    # (EXPERIMENTS §Perf, qwen2.5-14b: 37 GiB/device all-reduce)
    assert logical_axes("embed/emb", 2) == ("vocab", None)
    assert logical_axes("head/w", 2) == (None, "vocab")
    assert logical_axes("blocks/mlp/router/w", 3) == (None, None, None)
    assert logical_axes("unknown/thing", 2) == (None, None)
    # shared-expert dense rules win over the raw-expert rule
    assert logical_axes("blocks/mlp/shared/gate/w", 3) == (None, "fsdp", "tp")


def test_divisibility_guard(mesh11):
    mesh16 = _fake_mesh16()
    # vocab 51865 (whisper) is not divisible by 16 -> replicated
    spec = bind_logical(("vocab", None), (51865, 384), mesh16, fsdp=False)
    assert spec == P(None, None)
    spec = bind_logical(("vocab", None), (151936, 2048), mesh16, fsdp=False)
    assert spec == P("model", None)


def _fake_mesh16():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    return FakeMesh()


def test_fsdp_binding():
    mesh16 = _fake_mesh16()
    on = bind_logical(("fsdp", "tp"), (2048, 11008), mesh16, fsdp=True)
    off = bind_logical(("fsdp", "tp"), (2048, 11008), mesh16, fsdp=False)
    assert on == P("data", "model")
    assert off == P(None, "model")


def test_param_specs_tree_matches(mesh11):
    from repro.configs import get_smoke
    from repro.models import lm_init
    cfg = get_smoke("qwen3-1.7b")
    p = lm_init(jax.random.PRNGKey(0), cfg)
    specs = param_specs(p, mesh11, fsdp=False)
    flat_p = jax.tree.leaves(p)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)


def test_moe_expert_specs(mesh11):
    mesh16 = _fake_mesh16()
    # deepseek expert stack (160, 5120, 1536): EP on experts + FSDP on d
    spec = bind_logical(logical_axes("blocks/mlp/gate", 4),
                        (60, 160, 5120, 1536), mesh16, fsdp=True)
    assert spec == P(None, "model", "data", None)


def test_batch_axes(mesh11):
    assert batch_axes(mesh11) == ("data",)

    class FakeMulti:
        axis_names = ("pod", "data", "model")
    assert batch_axes(FakeMulti()) == ("pod", "data")


def test_jit_with_specs_runs(mesh11):
    """End-to-end: sharded jit of a smoke train step on the 1x1 mesh."""
    from repro.configs import get_smoke
    from repro.models import lm_init, lm_loss_fn
    from jax.sharding import NamedSharding
    cfg = get_smoke("qwen2.5-3b")
    p = lm_init(jax.random.PRNGKey(0), cfg)
    shard = jax.tree.map(lambda s: NamedSharding(mesh11, s),
                         param_specs(p, mesh11))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    with mesh11:
        f = jax.jit(lambda pp, bb: lm_loss_fn(pp, cfg, bb)[0],
                    in_shardings=(shard, NamedSharding(mesh11, P())))
        assert np.isfinite(float(f(p, batch)))
