"""Fused int8 serving kernels — structural and integration tests: block
shape overrides, TGQ group sweeps (bit-identical to per-group
repacking), fused-vs-unfused equivalence, kernel-path routing for
TGQ-wrapped ops, and the compile-once contract of ``ddpm_sample`` with
``QuantContext(kernel=True)``. The kernel-vs-oracle shape x bits x group
sweeps live in tests/test_kernel_conformance.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.contexts import QuantContext
from repro.core.quantizers import (
    ChannelQ, MRQSignedQ, TGQ, UniformQ, channel_scale_from_absmax,
    uniform_params_from_range, weight_absmax,
)
from repro.kernels import int8_matmul, int8_matmul_fq, int8_matmul_mrq_fq
from repro.kernels import ops, ref


def _rand_case(M, K, N, G, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (M, K)) * 2.0
    wq = jax.random.randint(k2, (K, N), -128, 128, jnp.int32).astype(jnp.int8)
    sx = (jax.random.uniform(k3, (G, 1)) * 0.05 + 0.01).astype(jnp.float32)
    zx = jnp.round(jax.random.uniform(k1, (G, 1)) * 200.0)
    scale = (jax.random.uniform(k2, (G, N)) * 1e-3 + 1e-5).astype(jnp.float32)
    colsum = jnp.sum(wq.astype(jnp.int32), axis=0)
    corr = (jnp.round(zx).astype(jnp.int32) - 128) * colsum[None, :]
    bias = jax.random.normal(k3, (N,))
    return x, wq, sx, zx, scale, corr, bias


# ---------------------------------------------------------------------------
# fused-quantize matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block", [(32, 64, 64), (128, 128, 256)])
def test_int8_matmul_fq_block_shapes(block):
    bm, bn, bk = block
    x, wq, sx, zx, scale, corr, _ = _rand_case(100, 300, 90, G=2, seed=1)
    out = int8_matmul_fq(x, wq, sx, zx, scale, corr, g=1, bm=bm, bn=bn,
                         bk=bk, interpret=True)
    want = ref.int8_matmul_fq_ref(x, wq, sx, zx, scale, corr, g=1)
    assert float(jnp.max(jnp.abs(out - want))) <= 1e-4


def test_int8_matmul_fq_matches_unfused_pipeline():
    """Fused == standalone quantize pass + pre-quantized-codes matmul."""
    M, K, N = 64, 160, 48
    x, wq, sx, zx, scale, corr, bias = _rand_case(M, K, N, G=2, seed=7)
    g = 1
    xq = ops.quantize_int8(x, sx[g, 0], zx[g, 0])
    unfused = int8_matmul(xq, wq, scale[g], corr[g], bias, interpret=True)
    fused = int8_matmul_fq(x, wq, sx, zx, scale, corr, bias, g=g,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


# ---------------------------------------------------------------------------
# single-pass MRQ matmul
# ---------------------------------------------------------------------------
def test_mrq_single_pass_matches_two_matmul_decomposition():
    """The collapsed kernel reproduces the old twin-region TWO-matmul path."""
    M, K, N = 48, 96, 64
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.nn.gelu(jax.random.normal(k1, (M, K)) * 2.0)
    wq = jax.random.randint(k2, (K, N), -128, 128, jnp.int32).astype(jnp.int8)
    s_neg, s_pos = jnp.float32(1.5e-3), jnp.float32(2.5e-2)
    sw = jax.random.uniform(k1, (N,)) * 1e-2 + 1e-4
    half = 128
    neg = x < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(x / s_neg), -half, 0),
                   0).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(x / s_pos), 0, half - 1)
                   ).astype(jnp.int8)
    zc = jnp.zeros((N,), jnp.int32)
    yn = int8_matmul(qn, wq, s_neg * sw, zc, interpret=True)
    yp = int8_matmul(qp, wq, s_pos * sw, zc, interpret=True)
    two_pass = yn + yp
    one_pass = int8_matmul_mrq_fq(
        x, wq, s_neg.reshape(1, 1), s_pos.reshape(1, 1),
        (s_neg * sw).reshape(1, -1), (s_pos * sw).reshape(1, -1),
        interpret=True)
    np.testing.assert_allclose(np.asarray(one_pass), np.asarray(two_pass),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# TGQ packing: group sweep bit-identical to per-group repacking
# ---------------------------------------------------------------------------
def _tgq_uniform_qp(key, K, N, G):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (K, N)) * 0.05
    scales = jnp.linspace(0.01, 0.05, G)
    zeros = jnp.round(jnp.linspace(90.0, 150.0, G))
    qp = {"x": TGQ(UniformQ(scale=scales, zero=zeros, bits=8)),
          "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8)}
    return qp, w


def test_tgq_uniform_pack_group_sweep():
    """Every group g of the stacked pack is bit-identical to repacking the
    scalar group-g quantizer on its own (the old per-group Python path)."""
    K, N, G = 96, 80, 5
    qp, w = _tgq_uniform_qp(jax.random.PRNGKey(0), K, N, G)
    pack = ops.pack_int8_linear(qp, np.asarray(w))
    assert pack is not None and pack["groups"] == G
    x = jax.random.normal(jax.random.PRNGKey(1), (33, K)) * 2
    tq: TGQ = qp["x"]
    for g in range(G):
        qp_g = {"x": tq.select(g), "w": qp["w"]}
        pack_g = ops.pack_int8_linear(qp_g, np.asarray(w))
        assert pack_g is not None and pack_g["groups"] == 1
        y_tgq = ops.int8_linear(x, pack, tgroup=g)
        y_repack = ops.int8_linear(x, pack_g)
        np.testing.assert_array_equal(np.asarray(y_tgq), np.asarray(y_repack))


def test_tgq_mrq_pack_group_sweep():
    K, N, G = 64, 48, 4
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    w = jax.random.normal(kw, (K, N)) * 0.05
    qp = {"x": TGQ(MRQSignedQ(s_neg=jnp.linspace(1e-3, 3e-3, G),
                              s_pos=jnp.linspace(1e-2, 4e-2, G), bits=8)),
          "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8)}
    pack = ops.pack_int8_mrq_linear(qp, np.asarray(w))
    assert pack is not None and pack["groups"] == G
    x = jax.nn.gelu(jax.random.normal(kx, (17, K)) * 1.5)
    tq: TGQ = qp["x"]
    for g in range(G):
        pack_g = ops.pack_int8_mrq_linear({"x": tq.select(g), "w": qp["w"]},
                                          np.asarray(w))
        y_tgq = ops.int8_linear_mrq(x, pack, tgroup=g)
        y_repack = ops.int8_linear_mrq(x, pack_g)
        np.testing.assert_array_equal(np.asarray(y_tgq), np.asarray(y_repack))


# ---------------------------------------------------------------------------
# routing: TGQ-wrapped W8A8 linears take the kernel path (no fallback)
# ---------------------------------------------------------------------------
def test_tgq_uniform_routes_through_kernel():
    K, N, G = 64, 32, 4
    qp, w = _tgq_uniform_qp(jax.random.PRNGKey(4), K, N, G)
    qp2 = ops.convert_for_kernels({"lin": qp}, {"lin": np.asarray(w)})
    assert "int8" in qp2["lin"], "TGQ(UniformQ) must pack, not fall back"
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 9, K))
    for g in range(G):
        y_kern = QuantContext(qparams=qp2, kernel=True,
                              tgroup=g).linear("lin", x, w)
        y_fake = QuantContext(qparams=qp2, tgroup=g).linear("lin", x, w)
        np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_fake),
                                   rtol=1e-4, atol=1e-4)


def test_tgq_mrq_routes_through_kernel():
    K, N, G = 48, 32, 3
    kw = jax.random.PRNGKey(6)
    w = jax.random.normal(kw, (K, N)) * 0.05
    x = jax.nn.gelu(jax.random.normal(jax.random.PRNGKey(7), (2, 7, K)))
    qp = {"fc2": {
        "x": TGQ(MRQSignedQ(s_neg=jnp.full((G,), float(-x.min()) / 128),
                            s_pos=jnp.full((G,), float(x.max()) / 128),
                            bits=8)),
        "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8)}}
    qp2 = ops.convert_for_kernels(qp, {"fc2": np.asarray(w)})
    assert "int8_mrq" in qp2["fc2"]
    y_kern = QuantContext(qparams=qp2, kernel=True, tgroup=1).linear(
        "fc2", x, w)
    y_fake = QuantContext(qparams=qp2, tgroup=1).linear("fc2", x, w)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_fake),
                               rtol=1e-3, atol=2e-3)


def test_channel_balanced_ops_pack_with_prescale_folded():
    """Ops with an x_prescale (PTQ4DiT-style channel balancing) pack like
    everything else: the balance divide runs in the kernel's quantize
    prologue (``pack["x_prescale"]``) and its inverse is baked into the
    weight codes (built from w*ps — exactly the tensor the calibrated
    ``ChannelQ`` saw). Kernel path ≡ fake-quant path bit-for-bit."""
    K, N = 24, 16
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (K, N)) * 0.05)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, K))
    ps = jnp.linspace(0.5, 2.0, K)
    ws = jnp.asarray(w) * ps[:, None]
    s, z = uniform_params_from_range((x / ps).min(), (x / ps).max(), 8)
    qp = {"lin": {
        "x": UniformQ(s, z, 8),
        "w": ChannelQ(channel_scale_from_absmax(weight_absmax(ws), 8), 8),
        "x_prescale": ps}}
    out = ops.convert_for_kernels(qp, {"lin": w})
    assert "int8" in out["lin"], "channel-balanced op must pack"
    np.testing.assert_array_equal(np.asarray(out["lin"]["int8"]["x_prescale"]),
                                  np.asarray(ps, np.float32))
    # the packed codes must be the codes calibration measured (on w*ps)
    codes_cal = np.asarray(jnp.clip(
        jnp.round(ws / qp["lin"]["w"].scale.reshape(1, -1)), -127, 127),
        np.int8)
    np.testing.assert_array_equal(np.asarray(out["lin"]["int8"]["wq"]),
                                  codes_cal)
    y_fake = QuantContext(qparams=out).linear("lin", x, jnp.asarray(w))
    y_kern = QuantContext(qparams=out, kernel=True).linear(
        "lin", x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y_fake), np.asarray(y_kern),
                               rtol=0, atol=1e-5)


def test_per_tensor_pack_still_works():
    """Plain UniformQ packs as G=1 and ignores any tgroup passed at serve."""
    x = jax.random.normal(jax.random.PRNGKey(0), (11, 24))
    w = jax.random.normal(jax.random.PRNGKey(1), (24, 16)) * 0.05
    s, z = uniform_params_from_range(x.min(), x.max(), 8)
    qp = {"x": UniformQ(s, z, 8),
          "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8)}
    pack = ops.pack_int8_linear(qp, np.asarray(w))
    assert pack["groups"] == 1
    y0 = ops.int8_linear(x, pack)
    y9 = ops.int8_linear(x, pack, tgroup=9)     # clamped to the only group
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y9))


# ---------------------------------------------------------------------------
# modeled HBM-traffic floors (the structural saving the fusion buys)
# ---------------------------------------------------------------------------
def test_traffic_model_floors():
    from benchmarks.kernel_micro import (traffic_int8_linear,
                                         traffic_mrq_linear)
    # DiT-XL/2 fc2-shaped case: one W pass instead of two -> >=1.5x
    t = traffic_mrq_linear(256, 4608, 1152)
    assert t["unfused"] / t["fused"] >= 1.5
    # plain linear: the fused path must not charge the standalone
    # quantize-pass bytes (fp32 read + int8 write of x) while the unfused
    # path must include them
    M, K, N = 256, 2048, 2048
    t = traffic_int8_linear(M, K, N)
    assert t["unfused"] - t["fused"] >= M * K * 1 + M * K * 4
    assert t["fused"] == M * K * 4 + K * N + M * N * 4


def test_fusion_residue_traffic_model():
    """The adaLN prologue/epilogue fusions: every chain byte in the XL/2
    block is served by a fusion (zero uncharged residue), the charged
    fused-operand bytes are strictly below the eliminated chain bytes at
    every fused site, and the block aggregate clears the >=1.15x CI gate
    vs the pre-fusion baseline."""
    from benchmarks.kernel_micro import (
        fused_block_traffic, traffic_gate_residual_fusion,
        traffic_norm_mod_fusion)
    t = fused_block_traffic()
    assert t["residue_adaln_residual"] == 0
    assert t["unfused"] / t["fused"] >= 1.15
    for name, fusion, ts in t["sites"]:
        if fusion is not None:
            assert ts["charged_bytes"] < ts["chain_bytes"], name
            assert ts["fused"] < ts["unfused"], name
    # per-site models at the fc2 shape: the gate+residual epilogue saves
    # the full 12B/elt output chain minus the streamed residual + gate
    M, B, K, N = 1024, 4, 4608, 1152
    tg = traffic_gate_residual_fusion(M, B, K, N)
    assert tg["unfused"] - tg["fused"] == 8 * M * N - 4 * B * N
    tn = traffic_norm_mod_fusion(M, B, N, K)
    assert tn["unfused"] - tn["fused"] == 4 * M * N - 16 * M - 8 * B * N


# ---------------------------------------------------------------------------
# compile-once contract: one executable across all timestep groups
# ---------------------------------------------------------------------------
def test_ddpm_sample_kernel_path_compiles_once(monkeypatch):
    """``ddpm_sample`` with ``QuantContext(kernel=True)`` and TGQ-packed
    int8 linears must trace/compile ONCE — the traced group index is
    resolved inside the kernel, never by Python-level repacking."""
    from repro.diffusion import DiffusionCfg, ddpm_sample, make_schedule
    from repro.kernels import ops as kops

    B, H, W_, C = 2, 4, 4, 1
    K = H * W_ * C
    G = 4
    dif = DiffusionCfg(T=40, tgq_groups=G)
    sched = make_schedule(dif)
    qp, w = _tgq_uniform_qp(jax.random.PRNGKey(8), K, K, G)
    qp2 = ops.convert_for_kernels({"lin": qp}, {"lin": np.asarray(w)})
    assert "int8" in qp2["lin"]
    qctx = QuantContext(qparams=qp2, kernel=True)

    kernel_calls = []
    orig_fq = kops.int8_matmul_fq
    monkeypatch.setattr(
        kops, "int8_matmul_fq",
        lambda *a, **k: (kernel_calls.append(1), orig_fq(*a, **k))[1])

    traces = []

    def eps_fn(x, t, y, ctx):
        traces.append(1)                      # fires once per (re)trace
        out = ctx.linear("lin", x.reshape(x.shape[0], -1), w)
        return out.reshape(x.shape)

    sample = jax.jit(lambda key: ddpm_sample(
        eps_fn, dif, sched, (B, H, W_, C), jnp.zeros((B,), jnp.int32), key,
        steps=8, ctx=qctx))
    out1 = sample(jax.random.PRNGKey(0))
    n_traces_first = len(traces)
    n_kernel_first = len(kernel_calls)
    assert n_traces_first == 1, "sampler retraced across timestep groups"
    assert n_kernel_first >= 1, "int8 kernel path was not taken"
    out2 = sample(jax.random.PRNGKey(1))
    assert len(traces) == n_traces_first, "second call recompiled"
    assert len(kernel_calls) == n_kernel_first
    assert bool(jnp.all(jnp.isfinite(out1))) and bool(
        jnp.all(jnp.isfinite(out2)))


# ---------------------------------------------------------------------------
# end-to-end: channel-balanced w8a8 serves fully on kernels (zero
# fallback packs), fused adaLN prologues/epilogues active, compiled once
# ---------------------------------------------------------------------------
def test_engine_w8a8_channel_balance_zero_fallback_fused_serve(
        tiny_dit, monkeypatch):
    """The prescale-fold regression: a ``channel_balance=True`` HO w8a8
    artifact packs EVERY quantized matmul — ``fallback_ops()`` is empty,
    the serve-CLI fallback warning is None, the balance vectors ride the
    packs — and the engine serves it through the fused int8 kernels with
    the adaLN norm-modulate/gate-residual fusions live, tracing ONCE.
    The kernel samples agree with the same artifact's fake-quant oracle
    (which runs the identical chains UNFUSED in fp via the ctx helpers),
    so this is also the engine-level fused == unfused contract. Edge
    projections (x_proj / final) must be packed too."""
    import functools
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.kernels import ops as kops
    from repro.launch.serve import fake_quant_fallback_warning
    from repro.models import dit_apply
    from repro.quant import QuantRecipe, quantize
    from repro.serving import GenRequest, ServeEngine

    cfg, p = tiny_dit
    dif = DiffusionCfg(T=40, tgq_groups=4)
    sched = make_schedule(dif)
    art = quantize(p, cfg, dif, QuantRecipe(
        bits="w8a8", method="ho", rounds=1, n_alpha=4, n_per_group=2,
        calib_batch=2, channel_balance=True))
    assert art.has_kernel_packs
    assert art.fallback_ops() == [], \
        "channel-balanced ops must pack (prescale folds into the kernel)"
    assert fake_quant_fallback_warning(art) is None
    balanced = [n for n, qp in art.qparams.items() if "x_prescale" in qp]
    assert balanced, "channel_balance=True produced no balance vectors"
    for n in balanced:
        pack = art.qparams[n].get("int8") or art.qparams[n].get("int8_mrq")
        assert pack is not None and "x_prescale" in pack, n
    for n in ("x_proj", "final"):
        assert any(k in art.qparams.get(n, {})
                   for k in ("int8", "int8_mrq")), \
            f"edge projection {n} must serve quantized"

    calls = {"n": 0}
    for fname in ("int8_matmul_fq", "int8_matmul_mrq_fq"):
        orig = getattr(kops, fname)
        monkeypatch.setattr(kops, fname, functools.partial(
            lambda orig, *a, **kw: (calls.__setitem__("n", calls["n"] + 1),
                                    orig(*a, **kw))[1], orig))
    traces = []
    orig_apply = dit_apply

    def traced_apply(*a, **kw):
        traces.append(1)
        return orig_apply(*a, **kw)

    import repro.serving.engine as eng_mod
    monkeypatch.setattr(eng_mod, "dit_apply", traced_apply)

    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=4,
                       cfg_scale=1.5, seed=60 + i) for i in range(2)]
    eng = ServeEngine(p, cfg, dif, sched, ctx=art.context(), microbatch=2,
                      step_buckets=(4,))
    res = eng.serve(reqs)
    assert len(traces) == 1, \
        "fused prologues broke the compile-once contract"
    assert calls["n"] > 0, "int8 kernels never fired"
    kern = np.stack([res[i].sample for i in range(2)])
    assert np.isfinite(kern).all()

    eng_fake = ServeEngine(p, cfg, dif, sched, ctx=art.context(kernel=False),
                           microbatch=2, step_buckets=(4,))
    fake = np.stack([eng_fake.serve(reqs)[i].sample for i in range(2)])
    np.testing.assert_allclose(kern, fake, rtol=0, atol=1e-4)
