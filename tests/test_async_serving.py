"""The async continuous-batching engine (`repro.serving.AsyncServeEngine`):
bit-identity to the synchronous step-bucketed path across mixed step
buckets and timestep mixtures (fp and w8a8 kernel contexts), compile-once
for the in-flight executable, structured admission control (bad label,
bounded queue), `requested_steps` recording with a once-per-count rounding
warning, and the cancellation API."""
import warnings

import jax
import numpy as np
import pytest

from repro.diffusion import DiffusionCfg
from repro.quant import QuantRecipe, quantize
from repro.serving import (
    AsyncServeEngine, GenRequest, RequestScheduler, ServeEngine,
    summarize,
)

DIF = DiffusionCfg(T=40, tgq_groups=4)
BUCKETS = (4, 6)

REQS = [
    GenRequest(request_id=0, label=1, steps=4, cfg_scale=1.5, seed=10),
    GenRequest(request_id=1, label=2, steps=6, cfg_scale=1.0, seed=11),
    GenRequest(request_id=2, label=3, steps=4, cfg_scale=0.0, seed=12),
    GenRequest(request_id=3, label=4, steps=6, cfg_scale=2.0, seed=13),
    GenRequest(request_id=4, label=5, steps=4, cfg_scale=1.0, seed=14),
]


@pytest.fixture(scope="module")
def sync_ref(tiny_dit):
    """Synchronous step-bucketed reference samples for REQS."""
    cfg, p = tiny_dit
    eng = ServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    return eng.serve(REQS)


@pytest.fixture(scope="module")
def w8a8(tiny_dit):
    cfg, p = tiny_dit
    return quantize(p, cfg, DIF, QuantRecipe(bits="w8a8", method="range",
                                             n_per_group=1, calib_batch=1))


def test_async_matches_sync_mixed_buckets(tiny_dit, sync_ref):
    """The tentpole acceptance bit: a pool mixing step buckets 4 and 6,
    every slot at a different timestep mid-flight, served chunk-by-chunk
    — every sample bit-identical to the synchronous path, with the
    in-flight executable compiled exactly ONCE across all mixtures."""
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           chunk=2)
    out = eng.serve(REQS)
    assert all(o.status == "OK" for o in out.values())
    for rid, o in out.items():
        assert np.array_equal(o.sample, sync_ref[rid].sample), rid
    assert eng.stats["chunk_traces"] == 1
    assert eng.stats["dispatches"] > 1        # genuinely continuous
    assert eng.stats["admitted"] == len(REQS)


def test_async_matches_sync_w8a8_kernels(tiny_dit, w8a8):
    """Same contract through the fused int8 kernel path: per-slot TGQ
    groups stay traced scalars inside the Pallas kernels, so the slot
    pool's timestep mixture still shares one executable."""
    cfg, p = tiny_dit
    sync = ServeEngine.from_artifact(p, w8a8, microbatch=2,
                                     step_buckets=BUCKETS)
    ref = sync.serve(REQS)
    eng = AsyncServeEngine.from_artifact(p, w8a8, microbatch=2,
                                         step_buckets=BUCKETS, chunk=3)
    out = eng.serve(REQS)
    for rid, o in out.items():
        assert o.status == "OK"
        assert np.array_equal(o.sample, ref[rid].sample), rid
    assert eng.stats["chunk_traces"] == 1


def test_chunk_size_does_not_change_samples(tiny_dit, sync_ref):
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=3, step_buckets=BUCKETS,
                           chunk=5)                # chunk > shortest chain
    out = eng.serve(REQS)
    for rid, o in out.items():
        assert np.array_equal(o.sample, sync_ref[rid].sample), rid


def test_bad_label_rejected_naming_request(tiny_dit):
    """Admission control: an out-of-range label gets a structured REJECTED
    outcome naming the request id — never a slot, never a silent corrupt
    sample."""
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    rid = eng.submit(label=cfg.n_classes + 3, steps=4)
    o = eng.outcomes[rid]
    assert o.status == "REJECTED"
    assert o.error.code == "bad_label"
    assert f"request {rid}" in o.error.message
    assert str(cfg.n_classes + 3) in o.error.message
    assert eng.stats["rejected"] == 1
    # the sync scheduler raises instead (a blocking frontend)
    sch = RequestScheduler(microbatch=2, step_buckets=BUCKETS,
                           n_classes=cfg.n_classes)
    with pytest.raises(ValueError, match="request 0: label"):
        sch.submit(label=-1, steps=4)


def test_queue_full_backpressure(tiny_dit):
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS,
                           max_queue=2)
    rids = [eng.submit(label=1, steps=4) for _ in range(4)]
    rejected = [r for r in rids if r in eng.outcomes
                and eng.outcomes[r].status == "REJECTED"]
    assert len(rejected) == 2
    assert all(eng.outcomes[r].error.code == "queue_full" for r in rejected)
    out = eng.run_until_drained()
    assert sum(1 for o in out.values() if o.status == "OK") == 2
    assert len(out) == 4                      # nothing dropped silently


def test_requested_steps_recorded_and_rounding_warns_once(tiny_dit):
    cfg, p = tiny_dit
    sch = RequestScheduler(microbatch=2, step_buckets=BUCKETS)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sch.submit(label=1, steps=5)          # rounds 5 -> 6
        sch.submit(label=2, steps=5)          # same count: no second warning
        sch.submit(label=3, steps=4)          # exact: no warning
    assert len(w) == 1 and "rounded" in str(w[0].message)
    eng = ServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    res = sch.run(eng)
    assert res[0].steps == 6 and res[0].requested_steps == 5
    assert res[2].steps == 4 and res[2].requested_steps == 4

    aeng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rid = aeng.submit(label=1, steps=5)
        aeng.submit(label=2, steps=5)
    assert len(w) == 1
    out = aeng.run_until_drained()
    assert out[rid].steps == 6 and out[rid].requested_steps == 5


def test_cancel_queued_and_running(tiny_dit):
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=1, step_buckets=BUCKETS,
                           chunk=2)
    r0 = eng.submit(label=1, steps=6, seed=1)
    r1 = eng.submit(label=2, steps=6, seed=2)   # waits behind r0 (1 slot)
    assert eng.pump()                            # r0 running
    assert eng.cancel(r0) and eng.cancel(r1)
    out = eng.run_until_drained()
    assert out[r0].status == "CANCELLED"         # freed at chunk boundary
    assert out[r0].error.code == "cancelled"
    assert out[r1].status == "CANCELLED"         # resolved at admission
    assert eng.cancel(r0) is False               # already terminal


def test_lifecycle_metrics(tiny_dit):
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    out = eng.serve(REQS[:3])
    m = eng.metrics()
    assert m["requests"] == 3 and m["ok"] == 3
    assert m["by_status"] == {"OK": 3}
    assert m["goodput_rps"] > 0
    assert m["latency_p99_s"] >= m["latency_p50_s"] > 0
    # summarize is pure over outcomes
    again = summarize(list(out.values()), m["wall_s"])
    assert again["ok"] == 3


def test_duplicate_request_id_rejected(tiny_dit):
    cfg, p = tiny_dit
    eng = AsyncServeEngine(p, cfg, DIF, microbatch=2, step_buckets=BUCKETS)
    eng.submit_request(REQS[0])
    with pytest.raises(ValueError, match="duplicate request id"):
        eng.submit_request(REQS[0])
