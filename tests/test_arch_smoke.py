"""Per-assigned-architecture smoke tests: instantiate the REDUCED config
of the same family and run one forward + one train step on CPU, asserting
output shapes and finiteness. The FULL configs are exercised by the
dry-run (ShapeDtypeStruct only, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get, get_smoke
from repro.models import (
    DiTCfg, lm_init, lm_apply, lm_loss_fn, encdec_init, encdec_loss_fn,
    dit_init, dit_apply,
)
from repro.optim import adamw, apply_updates

LM_ARCHS = [a for a in ARCHS if a != "dit-xl-2"]


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiates(arch):
    cfg = get(arch)
    assert cfg.n_layers >= 1
    if not isinstance(cfg, DiTCfg):
        assert cfg.vocab > 0 and cfg.d_model > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks,
             "labels": jnp.concatenate(
                 [toks[:, 1:], jnp.full((B, 1), -1, toks.dtype)], 1)}
    if cfg.encdec:
        p = encdec_init(key, cfg)
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
        loss_fn = lambda pp, bb: encdec_loss_fn(pp, cfg, bb)
    else:
        p = lm_init(key, cfg)
        logits, _ = lm_apply(p, cfg, toks)
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        loss_fn = lambda pp, bb: lm_loss_fn(pp, cfg, bb)

    opt = adamw(1e-3)
    st = opt.init(p)
    (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    u, st = opt.update(g, st, p)
    p2 = apply_updates(p, u)
    (loss2, _), _ = jax.value_and_grad(loss_fn, has_aux=True)(p2, batch)
    assert np.isfinite(float(loss2))


def test_smoke_dit_train_step():
    cfg = get_smoke("dit-xl-2")
    from repro.diffusion import DiffusionCfg, make_schedule, ddpm_loss
    key = jax.random.PRNGKey(0)
    p = dit_init(key, cfg)
    sched = make_schedule(DiffusionCfg(T=100))
    x0 = jax.random.normal(key, (2, cfg.img_size, cfg.img_size, cfg.in_ch))
    t = jnp.array([10, 90])
    y = jnp.array([0, 3])

    def loss_fn(pp):
        return ddpm_loss(lambda x, tt, yy: dit_apply(pp, cfg, x, tt, yy),
                         sched, x0, t, y, key)

    loss, g = jax.value_and_grad(loss_fn)(p)
    assert np.isfinite(float(loss))
    opt = adamw(1e-3)
    u, _ = opt.update(g, opt.init(p), p)
    p2 = apply_updates(p, u)
    assert np.isfinite(float(loss_fn(p2)))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_matches_family(arch):
    full, sm = get(arch), get_smoke(arch)
    assert full.family == sm.family
    assert full.block_type == sm.block_type
    assert full.attn_type == sm.attn_type
    assert full.moe == sm.moe
    assert full.encdec == sm.encdec
