"""The §Perf knobs must be semantics-preserving: sharding constraints and
dispatch pins change layouts, never values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelCfg, lm_init, lm_apply


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_attn_sp_preserves_values(mesh11):
    cfg = ModelCfg(name="t", family="dense", n_layers=2, d_model=64,
                   vocab=128, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128)
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    base, _ = lm_apply(p, cfg, toks)
    cfg_sp = dataclasses.replace(cfg, attn_sp=(("data",), "model"))
    with mesh11:
        sp, _ = jax.jit(lambda pp, tt: lm_apply(pp, cfg_sp, tt))(p, toks)
    np.testing.assert_allclose(base, sp, atol=2e-5)


def test_moe_shard_pin_preserves_values(mesh11):
    cfg = ModelCfg(name="m", family="moe", n_layers=2, d_model=64, vocab=128,
                   n_heads=4, n_kv_heads=2, head_dim=16, moe=True,
                   n_experts=8, top_k=2, n_shared=1, d_expert=32, d_ff=0,
                   capacity_factor=8.0)
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    base, _ = lm_apply(p, cfg, toks)
    cfg_pin = dataclasses.replace(cfg, moe_shard=(("data",), "model"))
    with mesh11:
        pin, _ = jax.jit(lambda pp, tt: lm_apply(pp, cfg_pin, tt))(p, toks)
    np.testing.assert_allclose(base, pin, atol=2e-5)


def test_fisher_norm_modes_both_calibrate(tiny_dit):
    """'batch' (default) and 'raw' both produce working quantizers; the
    normalized mode repairs the cross-timestep clipping artifact
    (DESIGN/EXPERIMENTS; here we just assert both run and differ)."""
    from repro.core import (PTQConfig, QuantContext, run_ptq,
                            build_dit_calibration, dit_loss_fn)
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.models import dit_apply

    cfg, p = tiny_dit
    dif = DiffusionCfg(T=100, tgq_groups=2)
    sched = make_schedule(dif)
    calib = build_dit_calibration(
        p, cfg, dif, sched, lambda n, k: jax.random.normal(k, (n, 8, 8, 4)),
        jax.random.PRNGKey(3), n_per_group=4, batch=4)
    loss = dit_loss_fn(p, cfg)
    outs = {}
    for mode in ("batch", "raw"):
        qp, _ = run_ptq(loss, calib, PTQConfig(
            wbits=6, abits=6, tgq_groups=2, n_alpha=6, rounds=1,
            fisher_norm=mode))
        b = calib[0][0]
        outs[mode] = dit_apply(p, cfg, b["xt"], b["t"], b["y"],
                               ctx=QuantContext(qparams=qp))
        assert bool(jnp.all(jnp.isfinite(outs[mode])))


def test_vocab_parallel_ce_matches_reference():
    from repro.models.lm import ce_loss
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 64)) * 3
    labels = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, 64)
    labels = labels.at[0, :2].set(-1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), -1)[..., 0]
    mask = (labels != -1).astype(jnp.float32)
    want = jnp.sum((lse - ll) * mask) / mask.sum()
    np.testing.assert_allclose(ce_loss(logits, labels), want, rtol=1e-6)
