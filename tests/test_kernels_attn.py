"""Int8 attention kernels — structural and integration tests: block
shape overrides, TGQ group sweeps (bit-identical to per-group
repacking), the codes-in/codes-out contract (softmax codes decode to
exactly the fidelity qdq kernel's output; P·V consumes the codes
directly), fused-vs-unfused equivalence of the whole attention block,
QuantContext routing, and the compile-once serving contract with int8
attention inside the engine's scan. The kernel-vs-oracle shape x bits x
group sweeps live in tests/test_kernel_conformance.py. All Pallas calls
run in interpret mode on CPU.

Oracle comparisons jit the ref: the kernels execute under jit, where XLA
may contract the epilogue's multiply-add into an FMA; the eager ref
dispatches op-by-op and can differ by 1 ulp. Bit-identity is asserted
against the jitted oracle (same fusion semantics).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.contexts import QuantContext
from repro.core.quantizers import (
    MRQSoftmaxQ, SymQ, TGQ, mrq_softmax_qdq, sym_act_qdq,
)
from repro.kernels import int8_bmm_pv, int8_bmm_qk, softmax_mrq_codes
from repro.kernels import ops, ref


def _jit_ref(fn, **static):
    return jax.jit(functools.partial(fn, **static))


def _qk_case(B, M, N, D, G, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, M, D)) * 2.0
    k = jax.random.normal(k2, (B, N, D)) * 2.0
    s_q = (jax.random.uniform(k3, (G, 1)) * 0.05 + 0.01).astype(jnp.float32)
    s_k = (jax.random.uniform(k1, (G, 1)) * 0.05 + 0.01).astype(jnp.float32)
    return q, k, s_q, s_k, s_q * s_k * 0.25


def _pv_case(B, M, N, D, G, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
    s1 = (jax.random.uniform(k1, (G, 1)) * 5e-3 + 5e-4).astype(jnp.float32)
    codes = ref.softmax_mrq_codes_ref(
        jax.random.normal(k2, (B, M, N)) * 4.0, s1, g=min(1, G - 1))
    v = jax.random.normal(k3, (B, N, D)) * 1.5
    s_v = (jax.random.uniform(k2, (G, 1)) * 0.05 + 0.01).astype(jnp.float32)
    return codes, v, s1, s_v, s1 * s_v, (1.0 / 128) * s_v


# ---------------------------------------------------------------------------
# batched QK^T
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block", [(32, 64, 64), (128, 128, 256)])
def test_int8_bmm_qk_block_shapes(block):
    bm, bn, bk = block
    q, k, s_q, s_k, scale = _qk_case(2, 100, 90, 48, G=2, seed=1)
    out = int8_bmm_qk(q, k, s_q, s_k, scale, g=1, bm=bm, bn=bn, bk=bk,
                      interpret=True)
    want = _jit_ref(ref.int8_bmm_qk_ref)(q, k, s_q, s_k, scale, g=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_int8_bmm_shared_kv_batch():
    """GQA: a q-side batch that is rep x the kv-side batch gathers the
    SHARED kv tile via the b // rep index map — bit-identical to feeding
    materialized kv copies."""
    B, rep, M, N, D = 2, 3, 9, 11, 8
    q, _, s_q, s_k, scale = _qk_case(B * rep, M, N, D, G=2, seed=11)
    k = jax.random.normal(jax.random.PRNGKey(12), (B, N, D)) * 2
    k_rep = jnp.repeat(k, rep, axis=0)
    out = int8_bmm_qk(q, k, s_q, s_k, scale, g=1, interpret=True)
    want = int8_bmm_qk(q, k_rep, s_q, s_k, scale, g=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    codes, _, s1, s_v, scale1, scale2 = _pv_case(B * rep, M, N, D, G=2,
                                                 seed=13)
    v = jax.random.normal(jax.random.PRNGKey(14), (B, N, D))
    out = int8_bmm_pv(codes, v, s_v, scale1, scale2, g=0, interpret=True)
    want = int8_bmm_pv(codes, jnp.repeat(v, rep, axis=0), s_v, scale1,
                       scale2, g=0, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_int8_attention_gqa_no_materialized_kv():
    """ops.int8_attention with G>1 query groups equals the composed
    oracle fed materialized kv copies (the kernels avoid the copies)."""
    B, Sq, Skv, Hk, Gq, hd = 2, 6, 10, 2, 3, 8
    qk_qp, pv_qp = _attn_qparams(2, seed=6)
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(k1, (B, Sq, Hk, Gq, hd)) * 2
    k = jax.random.normal(k2, (B, Skv, Hk, hd)) * 2
    v = jax.random.normal(k3, (B, Skv, Hk, hd))
    out = ops.int8_attention(q, k, v, qk_pack, pv_pack, scale=hd ** -0.5,
                             tgroup=1)
    BHG = B * Hk * Gq
    qf = q.transpose(0, 2, 3, 1, 4).reshape(BHG, Sq, hd)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Hk, Gq, Skv, hd)).reshape(BHG, Skv, hd)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Hk, Gq, Skv, hd)).reshape(BHG, Skv, hd)
    want = _jit_ref(ref.int8_attention_ref)(qf, kf, vf, qk_pack, pv_pack,
                                            scale=hd ** -0.5, g=1)
    want = want.reshape(B, Hk, Gq, Sq, hd).transpose(0, 3, 1, 2, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_int8_bmm_qk_matches_unfused_pipeline():
    """Fused == standalone symmetric quantize + jnp s32 batched matmul."""
    B, M, N, D = 2, 24, 40, 16
    q, k, s_q, s_k, scale = _qk_case(B, M, N, D, G=2, seed=7)
    g = 1
    q8 = ref.sym_quantize_int8_ref(q, s_q[g, 0])
    k8 = ref.sym_quantize_int8_ref(k, s_k[g, 0])
    acc = jax.lax.dot_general(q8.astype(jnp.int32), k8.astype(jnp.int32),
                              (((2,), (2,)), ((0,), (0,))),
                              preferred_element_type=jnp.int32)
    unfused = acc.astype(jnp.float32) * scale[g, 0]
    fused = int8_bmm_qk(q, k, s_q, s_k, scale, g=g, interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


# ---------------------------------------------------------------------------
# softmax -> MRQ codes
# ---------------------------------------------------------------------------
def test_codes_decode_to_fidelity_qdq():
    """Region-signed codes are a LOSSLESS encoding of the fidelity
    quant-dequant: decode(codes) == mrq_softmax_qdq(softmax(scores))."""
    scores = jax.random.normal(jax.random.PRNGKey(3), (4, 9, 31)) * 5.0
    s1 = jnp.asarray([[1e-3], [4e-3]], jnp.float32)
    for g in range(2):
        codes = softmax_mrq_codes(scores, s1, g=g, interpret=True)
        dec = ref.mrq_codes_decode_ref(codes, s1, g=g)
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        np.testing.assert_array_equal(
            np.asarray(dec), np.asarray(mrq_softmax_qdq(p, s1[g, 0], 8)))


def test_codes_region2_range_fits_signed_byte():
    """A saturated row (one prob ~= 1) must hit region-2 code 2^{k-1} =
    128 — representable only because the encoding NEGATES region-2."""
    scores = jnp.array([[40.0, 0.0, 0.0, 0.0]])
    s1 = jnp.asarray([[1e-3]], jnp.float32)
    codes = np.asarray(softmax_mrq_codes(scores, s1, g=0, interpret=True))
    assert codes[0, 0] == -128                  # region 2, code 128
    dec = ref.mrq_codes_decode_ref(codes, s1, g=0)
    assert float(dec[0, 0]) == 1.0


# ---------------------------------------------------------------------------
# batched dual-region P·V
# ---------------------------------------------------------------------------
def test_int8_bmm_pv_matches_two_region_decomposition():
    """The dual-accumulator kernel reproduces the unfused two-region
    decomposition (separate region matmuls, combined in fp)."""
    B, M, N, D = 2, 16, 24, 8
    codes, v, s1, s_v, scale1, scale2 = _pv_case(B, M, N, D, G=2, seed=5)
    g = 1

    @jax.jit
    def two_pass(codes, v):
        c = codes.astype(jnp.int32)
        v8 = ref.sym_quantize_int8_ref(v, s_v[g, 0]).astype(jnp.int32)
        dims = (((2,), (1,)), ((0,), (0,)))
        y1 = jax.lax.dot_general(jnp.maximum(c, 0), v8, dims,
                                 preferred_element_type=jnp.int32)
        y2 = jax.lax.dot_general(jnp.maximum(-c, 0), v8, dims,
                                 preferred_element_type=jnp.int32)
        return (y1.astype(jnp.float32) * scale1[g, 0]
                + y2.astype(jnp.float32) * scale2[g, 0])

    fused = int8_bmm_pv(codes, v, s_v, scale1, scale2, g=g, interpret=True)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(two_pass(codes, v)))


# ---------------------------------------------------------------------------
# TGQ packing: group sweep bit-identical to per-group repacking
# ---------------------------------------------------------------------------
def _attn_qparams(G, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    qk = {"x": TGQ(SymQ(scale=jnp.linspace(0.01, 0.05, G), bits=8)),
          "b": TGQ(SymQ(scale=jnp.linspace(0.02, 0.06, G), bits=8))}
    pv = {"x": TGQ(MRQSoftmaxQ(s1=jnp.geomspace(3e-4, 6e-3, G), bits=8)),
          "b": TGQ(SymQ(scale=jnp.linspace(0.01, 0.04, G), bits=8))}
    return qk, pv


def test_tgq_attention_pack_group_sweep():
    """Every group g of the stacked attention packs is bit-identical to
    repacking the scalar group-g quantizers on their own."""
    G = 5
    qk_qp, pv_qp = _attn_qparams(G)
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    assert qk_pack["groups"] == G and pv_pack["groups"] == G

    B, Sq, Hk, Gq, hd = 2, 9, 3, 1, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (B, Sq, Hk, Gq, hd)) * 2
    k = jax.random.normal(k2, (B, Sq, Hk, hd)) * 2
    v = jax.random.normal(k3, (B, Sq, Hk, hd))
    for g in range(G):
        qk_g = ops.pack_int8_qk(
            {"x": qk_qp["x"].select(g), "b": qk_qp["b"].select(g)})
        pv_g = ops.pack_int8_pv(
            {"x": pv_qp["x"].select(g), "b": pv_qp["b"].select(g)})
        assert qk_g["groups"] == 1 and pv_g["groups"] == 1
        y_tgq = ops.int8_attention(q, k, v, qk_pack, pv_pack,
                                   scale=hd ** -0.5, tgroup=g)
        y_repack = ops.int8_attention(q, k, v, qk_g, pv_g, scale=hd ** -0.5)
        np.testing.assert_array_equal(np.asarray(y_tgq), np.asarray(y_repack))


def test_pack_broadcasts_mixed_group_counts():
    """Per-tensor (G=1) v/k quantizers broadcast against TGQ probs/q —
    the HO-search output shape (per-tensor SymQ + TGQ softmax)."""
    G = 4
    qk_qp = {"x": TGQ(SymQ(scale=jnp.linspace(0.01, 0.05, G), bits=8)),
             "b": SymQ(scale=jnp.float32(0.03), bits=8)}
    pv_qp = {"x": TGQ(MRQSoftmaxQ(s1=jnp.geomspace(3e-4, 6e-3, G), bits=8)),
             "b": SymQ(scale=jnp.float32(0.02), bits=8)}
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    assert qk_pack["groups"] == G and pv_pack["groups"] == G
    assert qk_pack["s_k"].shape == (G, 1)
    assert pv_pack["scale2"].shape == (G, 1)


def test_pack_rejects_non_symmetric_operands():
    from repro.core.quantizers import UniformQ
    assert ops.pack_int8_qk({"x": UniformQ(jnp.float32(0.1), 3.0, 8),
                             "b": SymQ(jnp.float32(0.1), 8)}) is None
    assert ops.pack_int8_pv({"x": SymQ(jnp.float32(0.1), 8),
                             "b": SymQ(jnp.float32(0.1), 8)}) is None


# ---------------------------------------------------------------------------
# whole-block equivalence: kernels == composed oracle == fake-quant seams
# ---------------------------------------------------------------------------
def test_int8_attention_matches_composed_oracle():
    """ops.int8_attention over the GQA layout == the flattened composition
    of the three jitted oracles (incl. mask + softmax scale folding)."""
    B, Sq, Skv, Hk, Gq, hd = 2, 7, 11, 2, 2, 8
    G = 3
    qk_qp, pv_qp = _attn_qparams(G, seed=2)
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(k1, (B, Sq, Hk, Gq, hd)) * 2
    k = jax.random.normal(k2, (B, Skv, Hk, hd)) * 2
    v = jax.random.normal(k3, (B, Skv, Hk, hd))
    mask = jax.random.bernoulli(k4, 0.8, (B, 1, 1, Sq, Skv))
    scale = hd ** -0.5

    out = ops.int8_attention(q, k, v, qk_pack, pv_pack, mask=mask,
                             scale=scale, tgroup=1)

    BHG = B * Hk * Gq
    qf = q.transpose(0, 2, 3, 1, 4).reshape(BHG, Sq, hd)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Hk, Gq, Skv, hd)).reshape(BHG, Skv, hd)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Hk, Gq, Skv, hd)).reshape(BHG, Skv, hd)
    mf = jnp.broadcast_to(mask, (B, Hk, Gq, Sq, Skv)).reshape(BHG, Sq, Skv)
    want = _jit_ref(ref.int8_attention_ref)(qf, kf, vf, qk_pack, pv_pack,
                                            mask=mf, scale=scale, g=1)
    want = want.reshape(B, Hk, Gq, Sq, hd).transpose(0, 3, 1, 2, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_quant_context_attention_routes_through_kernels():
    """QuantContext(kernel=True, attn_impl='composed').attention with both
    packs present takes the composed int8 path; without kernel it
    composes the fake-quant seams, and the two agree closely (same
    quantizers, int vs fp arithmetic). The default attn_impl='flash'
    routing is covered in tests/test_flash_attn.py."""
    G = 4
    qk_qp, pv_qp = _attn_qparams(G, seed=3)
    qparams = {"attn/qk": dict(qk_qp, int8_qk=ops.pack_int8_qk(qk_qp)),
               "attn/pv": dict(pv_qp, int8_pv=ops.pack_int8_pv(pv_qp))}
    B, S, Hk, hd = 2, 8, 2, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(k1, (B, S, Hk, 1, hd))
    k = jax.random.normal(k2, (B, S, Hk, hd))
    v = jax.random.normal(k3, (B, S, Hk, hd))

    calls = []
    orig = ops.int8_attention

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    ops.int8_attention, restore = spy, orig
    try:
        for g in range(G):
            y_kern = QuantContext(qparams=qparams, kernel=True,
                                  attn_impl="composed", tgroup=g).attention(
                "attn", q, k, v, scale=hd ** -0.5)
            y_fake = QuantContext(qparams=qparams, tgroup=g).attention(
                "attn", q, k, v, scale=hd ** -0.5)
            np.testing.assert_allclose(np.asarray(y_kern),
                                       np.asarray(y_fake),
                                       rtol=1e-4, atol=1e-4)
    finally:
        ops.int8_attention = restore
    assert len(calls) == G, "kernel=True must lower the attention seam"

    # missing packs -> fall back to the composed fake-quant seams
    no_pack = {"attn/qk": dict(qk_qp), "attn/pv": dict(pv_qp)}
    y_fb = QuantContext(qparams=no_pack, kernel=True, tgroup=0).attention(
        "attn", q, k, v, scale=hd ** -0.5)
    y_ref = QuantContext(qparams=no_pack, tgroup=0).attention(
        "attn", q, k, v, scale=hd ** -0.5)
    np.testing.assert_array_equal(np.asarray(y_fb), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# serving: one compiled executable with int8 attention inside the scan
# ---------------------------------------------------------------------------
def test_engine_w8a8_runs_int8_attention_compile_once(tiny_dit, monkeypatch):
    """The engine's w8a8 step executable with attn_impl='composed' runs
    QK^T, softmax->MRQ codes, and P·V through the three kernels, traces
    ONCE across all timestep groups of the scan, and produces finite
    samples (the flash default's single-kernel contract is asserted in
    tests/test_flash_attn.py)."""
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.kernels import ops as kops
    from repro.models import dit_apply
    from repro.serving import GenRequest, ServeEngine
    from repro.serving.quickcal import range_calibrate

    cfg, p = tiny_dit
    dif = DiffusionCfg(T=40, tgq_groups=4)
    sched = make_schedule(dif)
    qp, weights = range_calibrate(p, cfg, dif, sched, n_per_group=1, batch=1)
    qp2 = kops.convert_for_kernels(qp, weights)
    n_qk = sum(1 for v in qp2.values() if "int8_qk" in v)
    n_pv = sum(1 for v in qp2.values() if "int8_pv" in v)
    assert n_qk == cfg.n_layers and n_pv == cfg.n_layers, \
        "range calibration must pack every block's attention"
    assert all(v["int8_pv"]["groups"] == dif.tgq_groups
               for v in qp2.values() if "int8_pv" in v)
    from repro.core import QuantContext
    ctx = QuantContext(qparams=qp2, kernel=True, attn_impl="composed")

    calls = {"qk": 0, "sm": 0, "pv": 0}
    for key, fname in (("qk", "int8_bmm_qk"), ("sm", "softmax_mrq_codes"),
                       ("pv", "int8_bmm_pv")):
        orig = getattr(kops, fname)
        monkeypatch.setattr(kops, fname, functools.partial(
            lambda orig, key, *a, **kw: (
                calls.__setitem__(key, calls[key] + 1), orig(*a, **kw))[1],
            orig, key))

    traces = []
    orig_apply = dit_apply

    def traced_apply(*a, **kw):
        traces.append(1)
        return orig_apply(*a, **kw)

    import repro.serving.engine as eng_mod
    monkeypatch.setattr(eng_mod, "dit_apply", traced_apply)

    eng = ServeEngine(p, cfg, dif, sched, ctx=ctx, microbatch=2,
                      step_buckets=(4,))
    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=4,
                       cfg_scale=1.5, seed=40 + i) for i in range(2)]
    res = eng.serve(reqs)
    # steps=4 over T=40 with 4 groups crosses timestep groups; the scan
    # body (and the kernels inside it) must have traced exactly once.
    assert len(traces) == 1, "sampler retraced across timestep groups"
    assert calls["qk"] == cfg.n_layers, calls
    assert calls["sm"] == cfg.n_layers, calls
    assert calls["pv"] == cfg.n_layers, calls
    s = np.stack([res[i].sample for i in range(2)])
    assert np.isfinite(s).all()


# ---------------------------------------------------------------------------
# modeled probs-traffic floor (the structural saving codes buy)
# ---------------------------------------------------------------------------
def test_attention_traffic_model_floors():
    from benchmarks.kernel_micro import traffic_attention_probs
    # DiT-XL/2-shaped attention: 256 tokens, 16 heads, hd 72
    t = traffic_attention_probs(BH=16, S=256, D=72)
    # acceptance floor: >=2x less probs traffic for the fused codes path
    assert t["probs_unfused"] / t["probs_fused"] >= 2.0
    # int8 write + int8 read vs fp32 write + fp32 read is exactly 4x
    assert t["probs_unfused"] / t["probs_fused"] == 4.0
    # and the whole attention tail (softmax -> out) must win too
    assert t["unfused"] / t["fused"] >= 1.5
