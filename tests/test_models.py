"""Model assembly invariants: scan==loop, prefill/decode==full forward,
for every family; DiT structure; whisper enc-dec."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelCfg, lm_init, lm_apply, lm_prefill, lm_decode_step, lm_generate,
    encdec_init, encode, decode_train, encdec_prefill, encdec_decode_step,
    DiTCfg, dit_init, dit_apply, patchify, unpatchify,
)

DENSE = ModelCfg(name="t", family="dense", n_layers=2, d_model=64, vocab=128,
                 n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                 mlp_act="swiglu")
# capacity_factor high enough that no token drops: the prefill==decode
# invariant only holds without dropping (decode never drops its 1 token).
MOE_MLA = ModelCfg(name="m", family="moe", n_layers=2, d_model=64, vocab=128,
                   attn_type="mla", n_heads=4, kv_lora=32, q_lora=32,
                   nope_dim=16, rope_dim=8, v_dim=16, moe=True, n_experts=8,
                   top_k=2, n_shared=1, d_expert=32, d_ff=0,
                   capacity_factor=8.0)
SSM = ModelCfg(name="s", family="ssm", n_layers=2, d_model=64, vocab=128,
               attn_type="none", block_type="ssm_only", ssm=True, d_inner=128,
               ssm_state=16, ssm_head_dim=32, ssm_chunk=8, d_ff=0,
               pos_embed="none")
HYMBA = ModelCfg(name="h", family="hybrid", n_layers=3, d_model=64, vocab=128,
                 n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                 block_type="hymba", ssm=True, d_inner=128, ssm_state=8,
                 ssm_head_dim=32, ssm_chunk=8, window=8, global_layers=(0, 2),
                 n_meta=4)


@pytest.mark.parametrize("cfg", [DENSE, MOE_MLA, SSM, HYMBA],
                         ids=["dense", "moe_mla", "ssm", "hymba"])
def test_scan_equals_loop(cfg):
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    loop, _ = lm_apply(p, cfg, toks)
    scan, _ = lm_apply(p, dataclasses.replace(cfg, scan_layers=True), toks)
    np.testing.assert_allclose(loop, scan, atol=2e-5)
    remat, _ = lm_apply(
        p, dataclasses.replace(cfg, scan_layers=True, remat=True), toks)
    np.testing.assert_allclose(loop, remat, atol=2e-5)


@pytest.mark.parametrize("cfg", [DENSE, MOE_MLA, SSM, HYMBA],
                         ids=["dense", "moe_mla", "ssm", "hymba"])
def test_prefill_decode_match_full(cfg):
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    full, _ = lm_apply(p, cfg, toks)
    lg, cache = lm_prefill(p, cfg, toks[:, :16], max_len=17)
    np.testing.assert_allclose(lg[:, 0], full[:, 15], atol=1e-3)
    lg2, _ = lm_decode_step(p, cfg, toks[:, 16:17], cache, 16)
    np.testing.assert_allclose(lg2[:, 0], full[:, 16], atol=1e-3)


def test_generate_greedy_deterministic():
    p = lm_init(jax.random.PRNGKey(0), DENSE)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    a = lm_generate(p, DENSE, prompt, 6, max_len=14)
    b = lm_generate(p, DENSE, prompt, 6, max_len=14)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)


def test_qchunk_matches_plain():
    cfg = dataclasses.replace(DENSE, q_chunk=4)
    p = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    a, _ = lm_apply(p, cfg, toks)
    b, _ = lm_apply(p, dataclasses.replace(cfg, attn_impl="qchunk"), toks)
    np.testing.assert_allclose(a, b, atol=2e-5)


def test_moe_aux_losses_finite_and_positive():
    p = lm_init(jax.random.PRNGKey(0), MOE_MLA)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    _, aux = lm_apply(p, MOE_MLA, toks)
    assert float(aux["aux_loss"]) > 0
    assert np.isfinite(float(aux["aux_loss"]))


# ---------------------------------------------------------------------------
# whisper enc-dec
# ---------------------------------------------------------------------------
WHISPER = ModelCfg(name="w", family="audio", n_layers=2, d_model=64,
                   vocab=128, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                   mlp_act="gelu", norm="layernorm", qkv_bias=True,
                   encdec=True, n_enc_layers=2, enc_seq=30,
                   pos_embed="learned", max_seq=64)


def test_encdec_prefill_decode_consistency():
    p = encdec_init(jax.random.PRNGKey(0), WHISPER)
    frames = jax.random.normal(jax.random.PRNGKey(2), (2, 30, 64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, 128)
    mem = encode(p, WHISPER, frames)
    full = decode_train(p, WHISPER, toks, mem)
    lg, cache = encdec_prefill(p, WHISPER, toks[:, :16], frames, max_len=17)
    np.testing.assert_allclose(lg[:, 0], full[:, 15], atol=1e-4)
    lg2, _ = encdec_decode_step(p, WHISPER, toks[:, 16:17], cache, 16)
    np.testing.assert_allclose(lg2[:, 0], full[:, 16], atol=1e-4)


# ---------------------------------------------------------------------------
# DiT
# ---------------------------------------------------------------------------
def test_patchify_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 4))
    t = patchify(x, 2)
    assert t.shape == (2, 16, 16)
    np.testing.assert_allclose(unpatchify(t, 2, 8, 4), x, atol=1e-7)


def test_dit_adaln_zero_identity_at_init():
    """adaLN-Zero: zero-init gates -> output == final-layer(x) == 0."""
    cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
                 n_heads=4, n_classes=8)
    p = dit_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 4))
    eps = dit_apply(p, cfg, x, jnp.array([3, 7]), jnp.array([0, 1]))
    np.testing.assert_allclose(eps, 0.0, atol=1e-6)


def test_dit_scan_equals_loop(tiny_dit):
    cfg, p = tiny_dit
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 4))
    t, y = jnp.array([3, 7]), jnp.array([0, 1])
    a = dit_apply(p, cfg, x, t, y)
    b = dit_apply(p, dataclasses.replace(cfg, scan_layers=True), x, t, y)
    np.testing.assert_allclose(a, b, atol=1e-5)
    assert bool(jnp.any(a != 0))


def test_dit_conditioning_matters(tiny_dit):
    cfg, p = tiny_dit
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 4))
    e1 = dit_apply(p, cfg, x, jnp.array([5]), jnp.array([0]))
    e2 = dit_apply(p, cfg, x, jnp.array([90]), jnp.array([0]))
    e3 = dit_apply(p, cfg, x, jnp.array([5]), jnp.array([3]))
    assert float(jnp.abs(e1 - e2).max()) > 1e-7      # t-dependence
    assert float(jnp.abs(e1 - e3).max()) > 1e-7      # class-dependence
