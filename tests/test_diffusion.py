"""DDPM substrate: schedule identities, respacing, sampler determinism,
TGQ group threading."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import (
    DiffusionCfg, ddpm_loss, ddpm_sample, ddpm_sample_python, make_schedule,
    q_sample, respaced_schedule, respaced_timesteps, tgroup_of,
)


def test_schedule_identities():
    cfg = DiffusionCfg(T=1000)
    s = make_schedule(cfg)
    np.testing.assert_allclose(s["alphas"], 1 - s["betas"], rtol=1e-6)
    np.testing.assert_allclose(s["abar"], jnp.cumprod(s["alphas"]), rtol=1e-5)
    assert float(s["abar"][-1]) < 0.01          # near-total noise at T
    assert float(s["abar"][0]) > 0.99


def test_cosine_schedule_valid():
    s = make_schedule(DiffusionCfg(T=100, schedule="cosine"))
    assert np.all(np.asarray(s["betas"]) > 0)
    assert np.all(np.asarray(s["betas"]) < 1)


def test_q_sample_snr_decreases():
    cfg = DiffusionCfg(T=100)
    s = make_schedule(cfg)
    x0 = jnp.ones((1, 4, 4, 2))
    noise = jax.random.normal(jax.random.PRNGKey(0), x0.shape)
    lo = q_sample(s, x0, jnp.array([5]), noise)
    hi = q_sample(s, x0, jnp.array([95]), noise)
    # signal fraction at t=95 much lower than at t=5
    assert float(jnp.abs(hi - noise).mean()) < float(jnp.abs(lo - noise).mean())


def test_respacing_covers_endpoints():
    ts = respaced_timesteps(1000, 100)
    assert ts[0] == 999 and ts[-1] == 0
    assert len(ts) == 100
    assert np.all(np.diff(ts) < 0)


def test_respaced_schedule_consistent():
    cfg = DiffusionCfg(T=1000)
    s = make_schedule(cfg)
    use = respaced_timesteps(1000, 50)
    rs = respaced_schedule(s, use)
    np.testing.assert_allclose(
        rs["abar"], np.asarray(s["abar"])[use[::-1]], rtol=1e-5)


def test_tgroup_of_partition():
    assert int(tgroup_of(jnp.int32(0), 100, 10)) == 0
    assert int(tgroup_of(jnp.int32(99), 100, 10)) == 9
    gs = [int(tgroup_of(jnp.int32(t), 250, 10)) for t in range(250)]
    counts = np.bincount(gs)
    assert len(counts) == 10
    assert counts.min() == 25 and counts.max() == 25


def test_samplers_agree_and_deterministic(tiny_dit):
    cfg, p = tiny_dit
    from repro.models import dit_apply
    dif = DiffusionCfg(T=100, tgq_groups=10)
    s = make_schedule(dif)
    eps = lambda x, t, y, ctx: dit_apply(p, cfg, x, t, y)
    y = jnp.array([1, 2])
    key = jax.random.PRNGKey(5)
    a = ddpm_sample(eps, dif, s, (2, 8, 8, 4), y, key, steps=10)
    b = ddpm_sample(eps, dif, s, (2, 8, 8, 4), y, key, steps=10)
    c = ddpm_sample_python(eps, dif, s, (2, 8, 8, 4), y, key, steps=10)
    np.testing.assert_allclose(a, b, atol=0)
    np.testing.assert_allclose(a, c, atol=1e-4)


def test_sampler_threads_tgroups(tiny_dit):
    cfg, p = tiny_dit
    from repro.models import dit_apply
    seen = []

    class SpyCtx:
        tgroup = None
        def with_tgroup(self, g):
            seen.append(int(g))
            return self

    dif = DiffusionCfg(T=100, tgq_groups=5)
    s = make_schedule(dif)
    eps = lambda x, t, y, ctx: dit_apply(p, cfg, x, t, y)
    ddpm_sample_python(eps, dif, s, (1, 8, 8, 4), jnp.array([0]),
                       jax.random.PRNGKey(0), steps=10, ctx=SpyCtx())
    assert len(seen) == 10
    assert seen[0] == 4 and seen[-1] == 0       # descending t -> groups
    assert set(seen) == {0, 1, 2, 3, 4}


def test_ddpm_loss_finite(tiny_dit):
    cfg, p = tiny_dit
    from repro.models import dit_apply
    dif = DiffusionCfg(T=100)
    s = make_schedule(dif)
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (4, 8, 8, 4))
    l = ddpm_loss(lambda x, t, y: dit_apply(p, cfg, x, t, y), s, x0,
                  jnp.array([5, 25, 50, 95]), jnp.array([0, 1, 2, 3]), key)
    assert np.isfinite(float(l))
