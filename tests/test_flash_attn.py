"""Flash-style fused int8 MRQ attention (`kernels/flash_attn_mrq.py`) —
structural and integration tests (the kernel-vs-oracle and
flash-vs-composed shape x bits x group sweeps live in
tests/test_kernel_conformance.py):

- flash vs the COMPOSED three-kernel exactness oracle: bit-tight when
  one kv tile holds the whole row (the online path degenerates to plain
  softmax), and within the documented `ref.flash_vs_composed_atol`
  contract across mixed group repacks and hand-built w6a6 packs;
- the ragged-sequence NEG_INF regression (S=77-style odd lengths whose
  zero-padded kv lanes would otherwise poison the online max);
- mask + GQA equivalence through `ops.flash_attention`;
- `QuantContext.attn_impl` routing ('flash' default / 'composed' /
  invalid), and the engine contract: with the flash default, exactly ONE
  attention kernel fires per block inside a step executable that traces
  once across all timestep groups.

All Pallas calls run in interpret mode on CPU. Kernel-vs-oracle
comparisons allow a few f32 ulp (multi-tile accumulator updates may fuse
differently under jit than the oracle's unrolled loop); flash-vs-composed
uses the documented tolerance contract.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.contexts import QuantContext
from repro.core.quantizers import MRQSoftmaxQ, SymQ, TGQ
from repro.kernels import flash_attn_mrq, int8_bmm_pv, int8_bmm_qk, \
    softmax_mrq_codes
from repro.kernels import ops, ref


def _attn_qparams(G, seed=0):
    qk = {"x": TGQ(SymQ(scale=jnp.linspace(0.01, 0.05, G), bits=8)),
          "b": TGQ(SymQ(scale=jnp.linspace(0.02, 0.06, G), bits=8))}
    pv = {"x": TGQ(MRQSoftmaxQ(s1=jnp.geomspace(3e-4, 6e-3, G), bits=8)),
          "b": TGQ(SymQ(scale=jnp.linspace(0.01, 0.04, G), bits=8))}
    return qk, pv


def _packs(G, seed=0):
    qk_qp, pv_qp = _attn_qparams(G, seed)
    return ops.pack_int8_qk(qk_qp), ops.pack_int8_pv(pv_qp)


def _case(B, M, N, D, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, M, D)) * 2.0
    k = jax.random.normal(k2, (B, N, D)) * 2.0
    v = jax.random.normal(k3, (B, N, D)) * 1.5
    return q, k, v


def _flash(q, k, v, qk_pack, pv_pack, g, scale, bn, bits=8):
    return flash_attn_mrq(
        q, k, v, qk_pack["s_q"], qk_pack["s_k"],
        qk_pack["scale"] * jnp.float32(scale), pv_pack["s1"],
        pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
        g_qk=g, g_pv=g, bits=bits, bn=bn, interpret=True)


def _composed(q, k, v, qk_pack, pv_pack, g, scale, bits=8):
    """The composed three-KERNEL path on flattened operands."""
    scores = int8_bmm_qk(q, k, qk_pack["s_q"], qk_pack["s_k"],
                         qk_pack["scale"] * jnp.float32(scale), g=g,
                         bits=bits, interpret=True)
    codes = softmax_mrq_codes(scores, pv_pack["s1"], g=g, bits=bits,
                              interpret=True)
    return int8_bmm_pv(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                       pv_pack["scale2"], g=g, bits=bits, interpret=True)


# ---------------------------------------------------------------------------
# flash vs composed: exactness when one tile holds the row, the documented
# tolerance contract when the online rescale actually runs
# ---------------------------------------------------------------------------
def test_flash_single_tile_matches_composed():
    """bn >= Skv: the online softmax degenerates to the plain row softmax
    (one max, one denominator), so flash reproduces the composed
    three-kernel output to f32 ulp."""
    B, M, N, D = 2, 24, 40, 16
    qk_pack, pv_pack = _packs(G=2)
    q, k, v = _case(B, M, N, D, seed=1)
    for g in (0, 1):
        out = _flash(q, k, v, qk_pack, pv_pack, g, D ** -0.5, bn=128)
        want = _composed(q, k, v, qk_pack, pv_pack, g, D ** -0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=0, atol=1e-5)


def test_flash_vs_composed_mixed_group_repack():
    """Per-tensor (G=1) qk pack against a TGQ pv pack — the HO-search
    output shape — resolves each side's group independently and stays
    within tolerance; the stacked packs are equivalent to repacking the
    selected group alone."""
    G = 4
    qk_qp = {"x": SymQ(scale=jnp.float32(0.03), bits=8),
             "b": SymQ(scale=jnp.float32(0.04), bits=8)}
    pv_qp = {"x": TGQ(MRQSoftmaxQ(s1=jnp.geomspace(4e-4, 5e-3, G), bits=8)),
             "b": TGQ(SymQ(scale=jnp.linspace(0.01, 0.04, G), bits=8))}
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    assert qk_pack["groups"] == 1 and pv_pack["groups"] == G
    B, M, N, D, bn = 2, 9, 45, 8, 16
    q, k, v = _case(B, M, N, D, seed=3)
    for g in range(G):
        out = flash_attn_mrq(
            q, k, v, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * jnp.float32(D ** -0.5), pv_pack["s1"],
            pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
            g_qk=0, g_pv=g, bn=bn, interpret=True)
        pv_g = ops.pack_int8_pv(
            {"x": pv_qp["x"].select(g), "b": pv_qp["b"].select(g)})
        repack = flash_attn_mrq(
            q, k, v, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * jnp.float32(D ** -0.5), pv_g["s1"],
            pv_g["s_v"], pv_g["scale1"], pv_g["scale2"],
            g_qk=0, g_pv=0, bn=bn, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(repack))
        # composed with the same per-side groups (qk g=0, pv g): compose
        # the kernels directly since each takes one g per call
        scores = int8_bmm_qk(q, k, qk_pack["s_q"], qk_pack["s_k"],
                             qk_pack["scale"] * jnp.float32(D ** -0.5),
                             g=0, interpret=True)
        codes = softmax_mrq_codes(scores, pv_pack["s1"], g=g,
                                  interpret=True)
        want = int8_bmm_pv(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                           pv_pack["scale2"], g=g, interpret=True)
        diff = float(jnp.max(jnp.abs(out - want)))
        assert diff <= ref.flash_vs_composed_atol(pv_pack, g, N)


def test_flash_w6a6_within_tolerance():
    """The bit-width knob threads through every stage (q/k/v code range,
    region split, s2 = 1/2^{k-1}); w6a6 flash matches w6a6 composed
    within the bits-aware contract."""
    B, M, N, D, bn = 2, 11, 50, 8, 16
    bits = 6
    s_q = jnp.full((1, 1), 0.08, jnp.float32)
    s_k = jnp.full((1, 1), 0.09, jnp.float32)
    s1 = jnp.full((1, 1), 8e-3, jnp.float32)
    s_v = jnp.full((1, 1), 0.07, jnp.float32)
    half = 2 ** (bits - 1)
    qk_pack = {"s_q": s_q, "s_k": s_k, "scale": s_q * s_k, "groups": 1}
    pv_pack = {"s1": s1, "s_v": s_v, "scale1": s1 * s_v,
               "scale2": (1.0 / half) * s_v, "groups": 1}
    q, k, v = _case(B, M, N, D, seed=4)
    out = _flash(q, k, v, qk_pack, pv_pack, 0, D ** -0.5, bn, bits=bits)
    want = _composed(q, k, v, qk_pack, pv_pack, 0, D ** -0.5, bits=bits)
    diff = float(jnp.max(jnp.abs(out - want)))
    atol = ref.flash_vs_composed_atol(pv_pack, 0, N, bits=bits)
    assert diff <= atol, (diff, atol)


# ---------------------------------------------------------------------------
# ragged sequences: NEG_INF lane masking BEFORE the online max
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N", [77, 33, 130])
def test_flash_ragged_odd_lengths(N):
    """S not a multiple of the kv tile: padded lanes must be NEG_INF
    masked before the running-max update. The regression construction
    makes every REAL score strongly negative, so an unmasked zero-padded
    lane (int8 codes 0 -> score exactly 0) would capture the row max,
    collapse every real exp() toward zero and poison the denominator —
    producing O(1) garbage instead of the composed output."""
    B, M, D, bn = 2, 9, 8, 32
    qk_pack, pv_pack = _packs(G=2)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(N), 3)
    q = jax.random.normal(k1, (B, M, D)) * 2.0
    # shift k so q·k^T lands far below zero for every real lane
    k = jax.random.normal(k2, (B, N, D)) * 0.5 - 2.0 * jnp.sign(
        q.sum(axis=(1, 2), keepdims=True))
    v = jax.random.normal(k3, (B, N, D))
    for g in (0, 1):
        out = _flash(q, k, v, qk_pack, pv_pack, g, 1.0, bn)
        want = _composed(q, k, v, qk_pack, pv_pack, g, 1.0)
        assert float(jnp.min(ref.int8_bmm_qk_ref(
            q, k, qk_pack["s_q"], qk_pack["s_k"], qk_pack["scale"],
            g=g).max(axis=-1))) < -0.5, "regression needs negative scores"
        diff = float(jnp.max(jnp.abs(out - want)))
        assert diff <= ref.flash_vs_composed_atol(pv_pack, g, N), diff
        # and the probabilities still sum to ~1 through the quantizer:
        # a poisoned denominator would shrink the output toward zero
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=0.2, atol=0.05)


def test_flash_user_mask_matches_composed():
    """ops.flash_attention with a boolean mask (streamed as int8 lanes)
    == ops.int8_attention with the same mask, within tolerance."""
    B, Sq, Skv, Hk, Gq, hd = 2, 7, 21, 2, 2, 8
    qk_qp, pv_qp = _attn_qparams(3, seed=5)
    qk_pack, pv_pack = ops.pack_int8_qk(qk_qp), ops.pack_int8_pv(pv_qp)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(6), 4)
    q = jax.random.normal(k1, (B, Sq, Hk, Gq, hd)) * 2
    k = jax.random.normal(k2, (B, Skv, Hk, hd)) * 2
    v = jax.random.normal(k3, (B, Skv, Hk, hd))
    mask = jax.random.bernoulli(k4, 0.7, (B, 1, 1, Sq, Skv))
    mask = mask.at[..., :1].set(True)            # no fully-masked rows
    out = ops.flash_attention(q, k, v, qk_pack, pv_pack, mask=mask,
                              scale=hd ** -0.5, tgroup=1)
    want = ops.int8_attention(q, k, v, qk_pack, pv_pack, mask=mask,
                              scale=hd ** -0.5, tgroup=1)
    atol = ref.flash_vs_composed_atol(pv_pack, 1, Skv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=0, atol=atol)

    # multi-tile mask streaming (kernel-level: bn < Skv, int8 mask lanes
    # NEG_INF'd ahead of the online max alongside the ragged lanes)
    BHG = B * Hk * Gq
    qf = q.transpose(0, 2, 3, 1, 4).reshape(BHG, Sq, hd)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Hk, Gq, Skv, hd)).reshape(BHG, Skv, hd)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Hk, Gq, Skv, hd)).reshape(BHG, Skv, hd)
    mf = jnp.broadcast_to(mask, (B, Hk, Gq, Sq, Skv)).reshape(BHG, Sq, Skv)
    out_t = flash_attn_mrq(
        qf, kf, vf, qk_pack["s_q"], qk_pack["s_k"],
        qk_pack["scale"] * jnp.float32(hd ** -0.5), pv_pack["s1"],
        pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
        g_qk=1, g_pv=1, mask=mf, bn=8, interpret=True)
    out_t = out_t.reshape(B, Hk, Gq, Sq, hd).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(want),
                               rtol=0, atol=atol)


def test_flash_gqa_shared_kv():
    """rep query-group batches share each kv head via the b // rep index
    map — identical to feeding materialized kv copies."""
    B, rep, M, N, D, bn = 2, 3, 9, 20, 8, 8
    qk_pack, pv_pack = _packs(G=2)
    q, _, v_ = _case(B * rep, M, N, D, seed=7)
    k = jax.random.normal(jax.random.PRNGKey(8), (B, N, D)) * 2
    v = jax.random.normal(jax.random.PRNGKey(9), (B, N, D))
    out = _flash(q, k, v, qk_pack, pv_pack, 1, D ** -0.5, bn)
    want = _flash(q, jnp.repeat(k, rep, axis=0), jnp.repeat(v, rep, axis=0),
                  qk_pack, pv_pack, 1, D ** -0.5, bn)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# QuantContext attn_impl routing
# ---------------------------------------------------------------------------
def test_quant_context_attn_impl_routing(monkeypatch):
    qk_qp, pv_qp = _attn_qparams(2, seed=10)
    qparams = {"attn/qk": dict(qk_qp, int8_qk=ops.pack_int8_qk(qk_qp)),
               "attn/pv": dict(pv_qp, int8_pv=ops.pack_int8_pv(pv_qp))}
    B, S, Hk, hd = 1, 6, 2, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(k1, (B, S, Hk, 1, hd))
    k = jax.random.normal(k2, (B, S, Hk, hd))
    v = jax.random.normal(k3, (B, S, Hk, hd))

    calls = {"flash": 0, "composed": 0}
    orig_f, orig_c = ops.flash_attention, ops.int8_attention
    monkeypatch.setattr(ops, "flash_attention", lambda *a, **kw: (
        calls.__setitem__("flash", calls["flash"] + 1), orig_f(*a, **kw))[1])
    monkeypatch.setattr(ops, "int8_attention", lambda *a, **kw: (
        calls.__setitem__("composed", calls["composed"] + 1),
        orig_c(*a, **kw))[1])

    y_flash = QuantContext(qparams=qparams, kernel=True, tgroup=0).attention(
        "attn", q, k, v, scale=hd ** -0.5)      # default impl == flash
    assert calls == {"flash": 1, "composed": 0}
    y_comp = QuantContext(qparams=qparams, kernel=True, tgroup=0,
                          attn_impl="composed").attention(
        "attn", q, k, v, scale=hd ** -0.5)
    assert calls == {"flash": 1, "composed": 1}
    # single kv tile at this size: the two impls agree to f32 ulp
    np.testing.assert_allclose(np.asarray(y_flash), np.asarray(y_comp),
                               rtol=0, atol=1e-5)
    with pytest.raises(ValueError, match="attn_impl"):
        QuantContext(qparams=qparams, kernel=True,
                     attn_impl="fused").attention(
            "attn", q, k, v, scale=hd ** -0.5)


# ---------------------------------------------------------------------------
# serving: ONE attention kernel per block, one trace across all groups
# ---------------------------------------------------------------------------
def test_engine_flash_compile_once_single_attention_kernel(tiny_dit,
                                                           monkeypatch):
    """With the flash default, the engine's w8a8 step executable lowers
    each block's attention to exactly ONE kernel (`flash_attn_mrq`) —
    the composed trio must not fire at all — traced ONCE across all
    timestep groups of the scan, with finite samples."""
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.kernels import ops as kops
    from repro.serving import GenRequest, ServeEngine
    from repro.serving.quickcal import range_calibrate

    cfg, p = tiny_dit
    dif = DiffusionCfg(T=40, tgq_groups=4)
    sched = make_schedule(dif)
    qp, weights = range_calibrate(p, cfg, dif, sched, n_per_group=1, batch=1)
    qp2 = kops.convert_for_kernels(qp, weights)
    ctx = QuantContext(qparams=qp2, kernel=True)          # flash default

    calls = {"flash": 0, "qk": 0, "sm": 0, "pv": 0}
    for key, fname in (("flash", "flash_attn_mrq"), ("qk", "int8_bmm_qk"),
                       ("sm", "softmax_mrq_codes"), ("pv", "int8_bmm_pv")):
        orig = getattr(kops, fname)
        monkeypatch.setattr(kops, fname, functools.partial(
            lambda orig, key, *a, **kw: (
                calls.__setitem__(key, calls[key] + 1), orig(*a, **kw))[1],
            orig, key))

    traces = []
    from repro.models import dit_apply as orig_apply
    import repro.serving.engine as eng_mod
    monkeypatch.setattr(eng_mod, "dit_apply", lambda *a, **kw: (
        traces.append(1), orig_apply(*a, **kw))[1])

    eng = ServeEngine(p, cfg, dif, sched, ctx=ctx, microbatch=2,
                      step_buckets=(4,))
    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=4,
                       cfg_scale=1.5, seed=70 + i) for i in range(2)]
    res = eng.serve(reqs)
    assert len(traces) == 1, "sampler retraced across timestep groups"
    assert calls["flash"] == cfg.n_layers, calls
    assert calls["qk"] == calls["sm"] == calls["pv"] == 0, \
        f"composed kernels fired alongside flash: {calls}"
    s = np.stack([res[i].sample for i in range(2)])
    assert np.isfinite(s).all()


# ---------------------------------------------------------------------------
# modeled traffic: the (S,S) round-trip is eliminated
# ---------------------------------------------------------------------------
def test_flash_traffic_floor():
    from repro.kernels.flash_attn_mrq import DEFAULT_BM
    from benchmarks.kernel_micro import traffic_attention_flash
    # DiT-XL/2 attention: 256 tokens, 16 heads, hd 72 — one q-tile at
    # the kernel's default bm, so K/V genuinely stream from HBM once
    assert DEFAULT_BM >= 256
    t = traffic_attention_flash(BH=16, S=256, D=72)
    # acceptance floor: >= 3x whole-attention traffic cut at S >= 256
    assert t["composed"] / t["flash"] >= 3.0
    # what was eliminated is exactly the (S,S) scores (f32 write+read)
    # + codes (int8 write+read) round-trip
    assert t["scores_codes_eliminated"] == 16 * 256 * 256 * 10
    assert t["composed"] - t["flash"] == t["scores_codes_eliminated"]
    # flash reads q/k/v and writes out, each once, in f32
    assert t["flash"] == 4 * 16 * 256 * 72 * 4

    # the model charges kv RE-READS honestly when bm < S (the kernel
    # re-fetches every k/v tile once per q-tile): 2 q-tiles at bm=128
    t2 = traffic_attention_flash(BH=16, S=256, D=72, bm=128)
    assert t2["flash"] == 16 * 256 * 72 * 4 * (2 + 2 * 2)
    assert t2["composed"] == t["composed"]
    # still a large win, but smaller — and never overstated
    assert t["composed"] / t2["flash"] < t["composed"] / t["flash"]
    assert t["composed"] / t2["flash"] >= 2.0
