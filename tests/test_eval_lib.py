"""The promoted eval library (`repro.quant.eval`): explicit asset-cache
keying (the regression that forced the promotion — the predecessor
cached under a bare string, so different configs/seeds shared stale
latents and feature nets), and the grouped sampler's equivalence to the
fused one under a constant per-group context map (the property that
makes mixed-allocation FD scores comparable to uniform trials')."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.diffusion import DiffusionCfg
from repro.models import DiTCfg
from repro.nn.ctx import FPContext
from repro.quant import QuantRecipe, quantize
from repro.quant import eval as qeval

DIF = DiffusionCfg(T=40, tgq_groups=4)


# ---------------------------------------------------------------------------
# asset cache keying
# ---------------------------------------------------------------------------
def test_asset_cache_hit_same_key(tiny_dit):
    cfg, _ = tiny_dit
    a = qeval.eval_assets(cfg, n_real=32)
    b = qeval.eval_assets(cfg, n_real=32)
    assert a[0] is b[0] and a[2] is b[2]           # one build, shared


def test_asset_cache_distinguishes_seeds(tiny_dit):
    """The regression: the predecessor keyed its cache by the bare
    string "assets", so a second caller with a different data seed (or
    size, or model) was served the FIRST caller's latents and feature
    nets. The promoted cache keys by the full build identity."""
    cfg, _ = tiny_dit
    a_real, _, a_net, _ = qeval.eval_assets(cfg, n_real=32, data_seed=1)
    b_real, _, b_net, _ = qeval.eval_assets(cfg, n_real=32, data_seed=2)
    assert a_real is not b_real
    assert not np.allclose(a_real, b_real)         # different draws
    c_real, _, c_net, _ = qeval.eval_assets(cfg, n_real=32, data_seed=1,
                                            net_seed=7)
    assert c_real is not a_net and c_net is not a_net  # new net, new entry


def test_asset_cache_distinguishes_model_cfg(tiny_dit):
    cfg, _ = tiny_dit
    other = dataclasses.replace(cfg, img_size=16)
    a_real, *_ = qeval.eval_assets(cfg, n_real=16)
    b_real, *_ = qeval.eval_assets(other, n_real=16)
    assert a_real.shape != b_real.shape            # sized by ITS config


def test_asset_cache_clear(tiny_dit):
    cfg, _ = tiny_dit
    a = qeval.eval_assets(cfg, n_real=16)
    qeval.clear_eval_caches()
    b = qeval.eval_assets(cfg, n_real=16)
    assert a[0] is not b[0]
    np.testing.assert_allclose(a[0], b[0])         # same key -> same build


def test_score_shape(tiny_dit):
    cfg, params = tiny_dit
    gen, _ = qeval.generate(params, cfg, DIF, steps=2, n=8, batch=8)
    s = qeval.score(gen, cfg, n_real=32)
    assert set(s) == {"FD", "sFD", "IS*"}
    assert all(np.isfinite(v) for v in s.values())


# ---------------------------------------------------------------------------
# grouped sampler == fused sampler under a constant context map
# ---------------------------------------------------------------------------
def test_generate_grouped_matches_generate_constant_ctx(tiny_dit):
    cfg, params = tiny_dit
    gen, labels = qeval.generate(params, cfg, DIF, ctx=FPContext(),
                                 steps=4, n=8, seed=3, batch=8)
    gen_g, labels_g = qeval.generate_grouped(
        params, cfg, DIF, [FPContext()] * DIF.tgq_groups,
        steps=4, n=8, seed=3, batch=8)
    np.testing.assert_array_equal(labels, labels_g)
    # same arithmetic, python loop vs lax.scan: the repo's sampler-
    # equivalence bound (test_diffusion.py) is 1e-4
    np.testing.assert_allclose(gen, gen_g, atol=1e-4)


def test_generate_grouped_quantized_map(tiny_dit):
    """A genuinely mixed map runs: w8a8 on even groups, w4a4 on odd —
    and produces output that differs from either uniform context (the
    allocation is doing something)."""
    cfg, params = tiny_dit
    ctx8 = quantize(params, cfg, DIF,
                    QuantRecipe(bits="w8a8", n_per_group=1, calib_batch=1)
                    ).context(kernel=False)
    ctx4 = quantize(params, cfg, DIF,
                    QuantRecipe(bits="w4a4", n_per_group=1, calib_batch=1)
                    ).context(kernel=False)
    cmap = [ctx8 if g % 2 == 0 else ctx4 for g in range(DIF.tgq_groups)]
    mixed, _ = qeval.generate_grouped(params, cfg, DIF, cmap, steps=4,
                                      n=4, seed=3, batch=4)
    uni8, _ = qeval.generate_grouped(params, cfg, DIF,
                                     [ctx8] * DIF.tgq_groups, steps=4,
                                     n=4, seed=3, batch=4)
    assert mixed.shape == uni8.shape
    assert not np.allclose(mixed, uni8, atol=1e-6)


def test_noise_mse_per_group_ctx(tiny_dit):
    """Per-group context specs score each group under ITS context: an
    FP context in group g zeroes group g's MSE while quantized groups
    stay nonzero."""
    cfg, params = tiny_dit
    ctx4 = quantize(params, cfg, DIF,
                    QuantRecipe(bits="w4a4", n_per_group=1, calib_batch=1)
                    ).context(kernel=False)
    cmap = [FPContext()] + [ctx4] * (DIF.tgq_groups - 1)
    by_group = qeval.noise_mse_by_group(params, cfg, DIF, cmap, n=8)
    assert len(by_group) == DIF.tgq_groups
    assert by_group[0] == pytest.approx(0.0, abs=1e-12)
    assert all(v > 0 for v in by_group[1:])
    uniform = qeval.noise_mse_by_group(params, cfg, DIF, ctx4, n=8)
    np.testing.assert_allclose(uniform[1:], by_group[1:], rtol=1e-6)
