"""Cross-bit kernel conformance suite: EVERY kernel family against its
``ref.py`` oracle over one shared grid — bit-widths (w8a8 / w6a6 / w4a4)
x TGQ group counts G in {1, 3, 5} x ragged shapes (incl. the CLIP-style
S = 77) x mask / GQA. This file replaces the per-family copy-pasted
sweep loops that used to live in test_kernels_fused.py /
test_kernels_attn.py / test_flash_attn.py (those files keep their
structural and integration tests: block-shape overrides, TGQ repacking
equivalence, QuantContext routing, compile-once engine contracts).

Cases are built through the REAL pack builders (``kernels.ops.pack_*``),
so the suite conformance-tests the bits-driven packing layer together
with the kernels. All Pallas calls run in interpret mode on CPU.

Tolerance registry — the documented per-path numeric contract:

  - Byte-code paths (fused/MRQ linear at 8 and 6 bits, the composed
    attention trio at every bit-width): integer accumulation with one fp
    epilogue. Asserted BIT-IDENTICAL to the *jitted* oracle (the kernels
    execute under jit, where XLA may contract the epilogue multiply-add
    into an FMA; the eager ref dispatches op-by-op and can differ by
    1 ulp).
  - Flash vs its tile-faithful oracle: single-kv-tile runs are exact;
    multi-tile runs reassociate the online max/denominator rescale under
    jit fusion, leaving ~1 f32 ulp per rescale (atol 1e-5).
  - Packed-int4 linear family: the per-K-group dequantization
    accumulates in f32 once per K step; the oracle replays the same
    group order, leaving a few f32 ulp of reassociation slack (atol
    1e-4, observed ~0).
  - Flash packed-kv (bits=4): the nibble pre-pass is value-identical to
    quantizing in-kernel, so packed vs unpacked flash is BIT-IDENTICAL.
  - Flash vs composed: the online-rescale rounding contract, bounded by
    ``ref.flash_vs_composed_atol`` (dynamic in the pv pack and kv
    length).
  - Vector-tgroup variants (per-row group vectors, the mixed-timestep
    batched path): a CONSTANT group vector ``full((B,), g)`` is asserted
    BIT-IDENTICAL to the scalar-prefetch sibling, mixed vectors conform
    to the per-row ``*_vec_ref`` jitted oracles at the parent family's
    tolerance, and the ops wrappers' batched dispatch is asserted
    bit-identical to stacking per-slot scalar-tgroup calls.
  - Prologue/epilogue fusions (channel-balance prescale, adaLN
    norm-modulate, gate+residual — each alone and all three combined):
    the fused kernels inherit the parent family's tolerance against the
    ``*_fused_ref`` jitted oracles — BIT-IDENTICAL for the byte-code
    linears (the prologue/epilogue run in the same f32 op order the
    oracle jits), atol 1e-4 for the packed-int4 family (per-K-group f32
    accumulation, observed ~2e-6).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import (
    ChannelQ, MRQSignedQ, MRQSoftmaxQ, SymQ, TGQ, UniformQ,
    channel_scale_from_absmax, weight_absmax,
)
from repro.kernels import (
    flash_attn_mrq, int8_bmm_pv, int8_bmm_qk, pack_int4, softmax_mrq_codes,
    unpack_int4,
)
from repro.kernels import ops, ref
from repro.kernels.flash_attn_mrq import flash_attn_mrq_vec
from repro.kernels.int8_bmm import int8_bmm_pv_vec, int8_bmm_qk_vec
from repro.kernels.softmax_mrq import softmax_mrq_codes_vec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # optional dep
    HAVE_HYPOTHESIS = False

BITS = {"w8a8": 8, "w6a6": 6, "w4a4": 4}
GROUPS = (1, 3, 5)

# (M, K, N) — MXU-aligned, ragged, sub-tile, and multi-K-tile shapes
MM_SHAPES = [(8, 16, 8), (64, 96, 80), (7, 13, 5), (130, 257, 129),
             (1, 5, 3), (64, 512, 96)]
# (B, Sq, Skv, D, bn) — batched attention incl. ragged S=77 and 1-row q
ATTN_SHAPES = [(1, 8, 8, 8, 128), (3, 7, 13, 5, 8), (1, 130, 129, 17, 64),
               (2, 77, 77, 24, 32), (2, 1, 5, 3, 8)]

# atol per conformance path; 0.0 means bit-identical to the jitted oracle
TOLERANCES = {
    "linear": 0.0,              # int8/int6 fused linear (s32 accumulation)
    "linear_mrq": 0.0,          # int8/int6 single-pass MRQ linear
    "int4_linear": 1e-4,        # f32 per-K-group accumulation
    "int4_linear_mrq": 1e-4,
    "attn_qk": 0.0,             # composed trio: integer kernels
    "attn_codes": 0.0,
    "attn_pv": 0.0,
    "flash": 1e-5,              # vs the tile-faithful jitted oracle
    "flash_packed_kv": 0.0,     # packed vs unpacked 4-bit flash
    "vec_const": 0.0,           # constant group vector == scalar prefetch
    "linear_vec": 0.0,          # mixed vector vs the per-row jitted oracle
    "linear_mrq_vec": 0.0,
    "int4_linear_vec": 1e-4,
    "int4_linear_mrq_vec": 1e-4,
    "attn_qk_vec": 0.0,
    "attn_codes_vec": 0.0,
    "attn_pv_vec": 0.0,
    "flash_vec": 1e-5,
    "linear_fused": 0.0,        # prologue/epilogue fusions: byte-code
    "linear_mrq_fused": 0.0,    # linears stay bit-identical
    "int4_linear_fused": 1e-4,
    "int4_linear_mrq_fused": 1e-4,
    "linear_fused_vec": 0.0,
    "linear_mrq_fused_vec": 0.0,
    "int4_linear_fused_vec": 1e-4,
    "int4_linear_mrq_fused_vec": 1e-4,
}


def _jit_ref(fn, **static):
    return jax.jit(functools.partial(fn, **static))


def _assert_conforms(path, got, want):
    got, want = np.asarray(got), np.asarray(want)
    if TOLERANCES[path] == 0.0:
        np.testing.assert_array_equal(got, want, err_msg=path)
    else:
        np.testing.assert_allclose(got, want, rtol=0,
                                   atol=TOLERANCES[path], err_msg=path)


# ---------------------------------------------------------------------------
# case builders (through the real quantizers + pack builders)
# ---------------------------------------------------------------------------
def _uniform_linear_case(M, K, N, G, bits, seed):
    half = 2 ** (bits - 1)
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (M, K)) * 2.0
    w = jax.random.normal(kw, (K, N)) * 0.05
    bias = jax.random.normal(kb, (N,))
    qp = {"x": TGQ(UniformQ(scale=jnp.linspace(0.01, 0.05, G),
                            zero=jnp.round(jnp.linspace(0.7 * half,
                                                        1.17 * half, G)),
                            bits=bits)),
          "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), bits),
                        bits)}
    return x, w, bias, qp


def _mrq_linear_case(M, K, N, G, bits, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
    x = jax.nn.gelu(jax.random.normal(kx, (M, K)) * 1.5)
    w = jax.random.normal(kw, (K, N)) * 0.05
    bias = jax.random.normal(kb, (N,))
    qp = {"x": TGQ(MRQSignedQ(s_neg=jnp.geomspace(1e-4, 2e-3, G),
                              s_pos=jnp.geomspace(1e-3, 2e-2, G),
                              bits=bits)),
          "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), bits),
                        bits)}
    return x, w, bias, qp


def _attn_qparams(G, bits, seed=0):
    qk = {"x": TGQ(SymQ(scale=jnp.linspace(0.01, 0.05, G), bits=bits)),
          "b": TGQ(SymQ(scale=jnp.linspace(0.02, 0.06, G), bits=bits))}
    pv = {"x": TGQ(MRQSoftmaxQ(s1=jnp.geomspace(3e-4, 6e-3, G), bits=bits)),
          "b": TGQ(SymQ(scale=jnp.linspace(0.01, 0.04, G), bits=bits))}
    return qk, pv


def _attn_case(B, Sq, Skv, D, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, Sq, D)) * 2.0
    k = jax.random.normal(k2, (B, Skv, D)) * 2.0
    v = jax.random.normal(k3, (B, Skv, D)) * 1.5
    return q, k, v


def _g_probes(G):
    return (0,) if G == 1 else (0, G - 1)


# ---------------------------------------------------------------------------
# fused linear family (uniform activations)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", MM_SHAPES, ids=lambda s: "x".join(map(
    str, s)))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_linear_conformance(bname, shape):
    bits = BITS[bname]
    M, K, N = shape
    for G in GROUPS:
        x, w, bias, qp = _uniform_linear_case(M, K, N, G, bits,
                                              seed=M * K + N + G)
        if bits == 4:
            pack = ops.pack_int4_linear(qp, w)
            assert pack is not None and pack["bits"] == 4
            want_fn = _jit_ref(ref.int4_matmul_fq_ref,
                               group_k=pack["group_k"])
            for g in _g_probes(G):
                got = ops.int4_linear(x, pack, bias=bias, tgroup=g)
                want = want_fn(x, pack["wp"], pack["sx"], pack["zx"],
                               pack["scale"], pack["corr"], bias, g=g)
                _assert_conforms("int4_linear", got, want)
        else:
            pack = ops.pack_int8_linear(qp, w)
            assert pack is not None and pack["bits"] == bits
            want_fn = _jit_ref(ref.int8_matmul_fq_ref, bits=bits)
            for g in _g_probes(G):
                got = ops.int8_linear(x, pack, bias=bias, tgroup=g)
                want = want_fn(x, pack["wq"], pack["sx"], pack["zx"],
                               pack["scale"], pack["corr"], bias, g=g)
                _assert_conforms("linear", got, want)


@pytest.mark.parametrize("shape", MM_SHAPES, ids=lambda s: "x".join(map(
    str, s)))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_linear_mrq_conformance(bname, shape):
    bits = BITS[bname]
    M, K, N = shape
    for G in GROUPS:
        x, w, bias, qp = _mrq_linear_case(M, K, N, G, bits,
                                          seed=M + K * N + G)
        if bits == 4:
            pack = ops.pack_int4_mrq_linear(qp, w)
            assert pack is not None and pack["bits"] == 4
            want_fn = _jit_ref(ref.int4_matmul_mrq_fq_ref,
                               group_k=pack["group_k"])
            for g in _g_probes(G):
                got = ops.int4_linear_mrq(x, pack, bias=bias, tgroup=g)
                want = want_fn(x, pack["wp"], pack["s_neg"], pack["s_pos"],
                               pack["scale_neg"], pack["scale_pos"], bias,
                               g=g)
                _assert_conforms("int4_linear_mrq", got, want)
        else:
            pack = ops.pack_int8_mrq_linear(qp, w)
            assert pack is not None and pack["bits"] == bits
            want_fn = _jit_ref(ref.int8_matmul_mrq_fq_ref, bits=bits)
            for g in _g_probes(G):
                got = ops.int8_linear_mrq(x, pack, bias=bias, tgroup=g)
                want = want_fn(x, pack["wq"], pack["s_neg"], pack["s_pos"],
                               pack["scale_neg"], pack["scale_pos"], bias,
                               g=g)
                _assert_conforms("linear_mrq", got, want)


# ---------------------------------------------------------------------------
# prologue/epilogue fusions on the linear families: channel-balance
# prescale (ps), adaLN norm-modulate (nm), gate+residual (gr)
# ---------------------------------------------------------------------------
FUSION_SHAPES = [(8, 16, 8), (7, 13, 5), (130, 257, 129), (64, 512, 96)]


def _fusion_operands(M, K, N, seed):
    """Per-batch adaLN rows + a positive channel-balance vector. B is a
    proper divisor of M so the row->batch map exercises row grouping
    (M=7 makes every row its own batch)."""
    B = next(b for b in (4, 3, 2, 7, 1) if M % b == 0)
    ks = jax.random.split(jax.random.PRNGKey(seed + 101), 5)
    ps = jnp.exp(jax.random.uniform(ks[0], (K,), minval=-1.0, maxval=1.0))
    nm = (jax.random.normal(ks[1], (B, K)) * 0.5,
          jax.random.normal(ks[2], (B, K)) * 0.2)
    gr = (jax.random.normal(ks[3], (B, N)) * 0.8,
          jax.random.normal(ks[4], (M, N)))
    bv = jnp.repeat(jnp.arange(B, dtype=jnp.int32), M // B)
    return ps, nm, gr, bv


_FUSION_COMBOS = ("ps", "nm", "gr", "all")     # each alone + all three


@pytest.mark.parametrize("shape", FUSION_SHAPES, ids=lambda s: "x".join(map(
    str, s)))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_linear_fusion_conformance(bname, shape):
    """Fused-prologue/epilogue uniform linears through the ops dispatch
    (pack built WITH ``x_prescale`` for the ps combos) vs the
    ``*_fused_ref`` jitted oracles, scalar-prefetch and mixed-vector
    tgroup paths."""
    bits = BITS[bname]
    M, K, N = shape
    ps, nm, gr, bv = _fusion_operands(M, K, N, seed=M + K + N)
    for G in GROUPS:
        x, w, bias, qp = _uniform_linear_case(M, K, N, G, bits,
                                              seed=M * K + N + G)
        qp_ps = dict(qp, x_prescale=ps)
        if bits == 4:
            pack = ops.pack_int4_linear(qp, w)
            pack_ps = ops.pack_int4_linear(qp_ps, w)
            lin, path = ops.int4_linear, "int4_linear_fused"
            want_fn = _jit_ref(ref.int4_matmul_fq_fused_ref,
                               group_k=pack["group_k"])
            vec_fn = _jit_ref(ref.int4_matmul_fq_vec_fused_ref,
                              group_k=pack["group_k"])
            wargs = ("wp", "sx", "zx", "scale", "corr")
        else:
            pack = ops.pack_int8_linear(qp, w)
            pack_ps = ops.pack_int8_linear(qp_ps, w)
            lin, path = ops.int8_linear, "linear_fused"
            want_fn = _jit_ref(ref.int8_matmul_fq_fused_ref, bits=bits)
            vec_fn = _jit_ref(ref.int8_matmul_fq_vec_fused_ref, bits=bits)
            wargs = ("wq", "sx", "zx", "scale", "corr")
        np.testing.assert_array_equal(np.asarray(pack_ps["x_prescale"]),
                                      np.asarray(ps))
        g = G - 1
        for combo in _FUSION_COMBOS:
            p = pack_ps if combo in ("ps", "all") else pack
            nm_i = nm if combo in ("nm", "all") else None
            gr_i = gr if combo in ("gr", "all") else None
            got = lin(x, p, bias=bias, tgroup=g, norm_mod=nm_i,
                      gate_residual=gr_i)
            want = want_fn(x, *(p[a] for a in wargs), bias, g=g,
                           ps=p.get("x_prescale"), nm=nm_i, gr=gr_i, bv=bv)
            _assert_conforms(path, got, want)
        if G > 1:
            gv = _mix_rows(M, G)
            got = lin(x, pack_ps, bias=bias, tgroup=gv, norm_mod=nm,
                      gate_residual=gr)
            want = vec_fn(x, *(pack_ps[a] for a in wargs), bias, gv=gv,
                          ps=ps, nm=nm, gr=gr, bv=bv)
            _assert_conforms(path + "_vec", got, want)


@pytest.mark.parametrize("shape", FUSION_SHAPES, ids=lambda s: "x".join(map(
    str, s)))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_linear_mrq_fusion_conformance(bname, shape):
    """Same fusion sweep on the single-pass MRQ linears — the prologue
    runs BEFORE the sign split (the balance vector is positive, so the
    region assignment is untouched)."""
    bits = BITS[bname]
    M, K, N = shape
    ps, nm, gr, bv = _fusion_operands(M, K, N, seed=M * 2 + K + N)
    for G in GROUPS:
        x, w, bias, qp = _mrq_linear_case(M, K, N, G, bits,
                                          seed=M + K * N + G)
        qp_ps = dict(qp, x_prescale=ps)
        if bits == 4:
            pack = ops.pack_int4_mrq_linear(qp, w)
            pack_ps = ops.pack_int4_mrq_linear(qp_ps, w)
            lin, path = ops.int4_linear_mrq, "int4_linear_mrq_fused"
            want_fn = _jit_ref(ref.int4_matmul_mrq_fq_fused_ref,
                               group_k=pack["group_k"])
            vec_fn = _jit_ref(ref.int4_matmul_mrq_fq_vec_fused_ref,
                              group_k=pack["group_k"])
            wargs = ("wp", "s_neg", "s_pos", "scale_neg", "scale_pos")
        else:
            pack = ops.pack_int8_mrq_linear(qp, w)
            pack_ps = ops.pack_int8_mrq_linear(qp_ps, w)
            lin, path = ops.int8_linear_mrq, "linear_mrq_fused"
            want_fn = _jit_ref(ref.int8_matmul_mrq_fq_fused_ref, bits=bits)
            vec_fn = _jit_ref(ref.int8_matmul_mrq_fq_vec_fused_ref,
                              bits=bits)
            wargs = ("wq", "s_neg", "s_pos", "scale_neg", "scale_pos")
        g = G - 1
        for combo in _FUSION_COMBOS:
            p = pack_ps if combo in ("ps", "all") else pack
            nm_i = nm if combo in ("nm", "all") else None
            gr_i = gr if combo in ("gr", "all") else None
            got = lin(x, p, bias=bias, tgroup=g, norm_mod=nm_i,
                      gate_residual=gr_i)
            want = want_fn(x, *(p[a] for a in wargs), bias, g=g,
                           ps=p.get("x_prescale"), nm=nm_i, gr=gr_i, bv=bv)
            _assert_conforms(path, got, want)
        if G > 1:
            gv = _mix_rows(M, G)
            got = lin(x, pack_ps, bias=bias, tgroup=gv, norm_mod=nm,
                      gate_residual=gr)
            want = vec_fn(x, *(pack_ps[a] for a in wargs), bias, gv=gv,
                          ps=ps, nm=nm, gr=gr, bv=bv)
            _assert_conforms(path + "_vec", got, want)


# ---------------------------------------------------------------------------
# composed attention trio (QK^T -> softmax-MRQ codes -> P·V)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", ATTN_SHAPES, ids=lambda s: "x".join(map(
    str, s[:4])))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_attention_composed_conformance(bname, shape):
    bits = BITS[bname]
    B, Sq, Skv, D, _ = shape
    for G in GROUPS:
        qk_qp, pv_qp = _attn_qparams(G, bits, seed=sum(shape) + G)
        qk_pack = ops.pack_int8_qk(qk_qp)
        pv_pack = ops.pack_int8_pv(pv_qp)
        assert qk_pack["bits"] == bits and pv_pack["bits"] == bits
        q, k, v = _attn_case(B, Sq, Skv, D, seed=sum(shape) + G)
        qk_ref = _jit_ref(ref.int8_bmm_qk_ref, bits=bits)
        sm_ref = _jit_ref(ref.softmax_mrq_codes_ref, bits=bits)
        pv_ref = _jit_ref(ref.int8_bmm_pv_ref, bits=bits)
        for g in _g_probes(G):
            scores = int8_bmm_qk(q, k, qk_pack["s_q"], qk_pack["s_k"],
                                 qk_pack["scale"], g=g, bits=bits,
                                 interpret=True)
            _assert_conforms("attn_qk", scores,
                             qk_ref(q, k, qk_pack["s_q"], qk_pack["s_k"],
                                    qk_pack["scale"], g=g))
            codes = softmax_mrq_codes(scores, pv_pack["s1"], g=g, bits=bits,
                                      interpret=True)
            assert codes.dtype == jnp.int8
            _assert_conforms("attn_codes", codes,
                             sm_ref(scores, pv_pack["s1"], g=g))
            out = int8_bmm_pv(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                              pv_pack["scale2"], g=g, bits=bits,
                              interpret=True)
            _assert_conforms("attn_pv", out,
                             pv_ref(codes, v, pv_pack["s_v"],
                                    pv_pack["scale1"], pv_pack["scale2"],
                                    g=g))


# ---------------------------------------------------------------------------
# flash attention (single fused kernel; packed-kv at 4 bits)
# ---------------------------------------------------------------------------
def _flash(q, k, v, qk_pack, pv_pack, g, scale, bn, bits, packed_kv=False):
    return flash_attn_mrq(
        q, k, v, qk_pack["s_q"], qk_pack["s_k"], qk_pack["scale"] * scale,
        pv_pack["s1"], pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
        g_qk=g, g_pv=g, bits=bits, packed_kv=packed_kv, bn=bn,
        interpret=True)


@pytest.mark.parametrize("shape", ATTN_SHAPES, ids=lambda s: "x".join(map(
    str, s[:4])))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_flash_conformance(bname, shape):
    bits = BITS[bname]
    B, Sq, Skv, D, bn = shape
    scale = D ** -0.5
    for G in GROUPS:
        qk_qp, pv_qp = _attn_qparams(G, bits, seed=sum(shape) + G)
        qk_pack = ops.pack_int8_qk(qk_qp)
        pv_pack = ops.pack_int8_pv(pv_qp)
        q, k, v = _attn_case(B, Sq, Skv, D, seed=sum(shape) + 7 * G)
        want_fn = _jit_ref(ref.flash_attn_mrq_ref, bits=bits, bn=bn,
                           scale=scale)
        for g in _g_probes(G):
            got = _flash(q, k, v, qk_pack, pv_pack, g, scale, bn, bits,
                         packed_kv=(bits == 4))
            want = want_fn(q, k, v, qk_pack, pv_pack, g_qk=g, g_pv=g)
            _assert_conforms("flash", got, want)
            if bits == 4:
                # the nibble pre-pass must be value-identical to in-kernel
                # quantization: packed-kv == unpacked bit-for-bit
                unpacked = _flash(q, k, v, qk_pack, pv_pack, g, scale, bn,
                                  bits, packed_kv=False)
                _assert_conforms("flash_packed_kv", got, unpacked)


@pytest.mark.parametrize("G", GROUPS)
@pytest.mark.parametrize("bname", sorted(BITS))
def test_flash_vs_composed_tolerance(bname, G):
    """Flash == the composed trio within ``ref.flash_vs_composed_atol``
    (the online-rescale rounding contract), at every bit-width and TGQ
    group — multi-kv-tile so the online path actually rescales."""
    bits = BITS[bname]
    B, Sq, Skv, D, bn = 2, 77, 77, 24, 32
    scale = D ** -0.5
    qk_qp, pv_qp = _attn_qparams(G, bits, seed=17 + G)
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    q, k, v = _attn_case(B, Sq, Skv, D, seed=29 + G)
    composed_fn = _jit_ref(ref.int8_attention_ref, bits=bits, scale=scale)
    for g in _g_probes(G):
        got = _flash(q, k, v, qk_pack, pv_pack, g, scale, bn, bits,
                     packed_kv=(bits == 4))
        composed = composed_fn(q, k, v, qk_pack, pv_pack, g=g)
        atol = ref.flash_vs_composed_atol(pv_pack, g, Skv, bits=bits)
        diff = float(jnp.max(jnp.abs(got - composed)))
        assert diff <= atol, (bname, G, g, diff, atol)


@pytest.mark.parametrize("bname", sorted(BITS))
def test_flash_mask_and_gqa_conformance(bname):
    """Mask: flash with a boolean mask matches the masked oracle. GQA: a
    q batch of rep x the kv batch gathers the shared kv tile via b//rep —
    bit-identical to feeding materialized kv copies. Both per bit-width
    (packed-kv on at 4 bits)."""
    bits = BITS[bname]
    G, scale, bn = 3, 24 ** -0.5, 32
    qk_qp, pv_qp = _attn_qparams(G, bits, seed=5)
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    packed = bits == 4

    B, Sq, Skv, D = 2, 33, 77, 24
    q, k, v = _attn_case(B, Sq, Skv, D, seed=31)
    mask = jax.random.bernoulli(jax.random.PRNGKey(6), 0.8, (B, Sq, Skv))
    mask = mask.at[:, :, 0].set(True)          # no fully-masked rows
    got = flash_attn_mrq(
        q, k, v, qk_pack["s_q"], qk_pack["s_k"], qk_pack["scale"] * scale,
        pv_pack["s1"], pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
        g_qk=1, g_pv=1, mask=mask, bits=bits, packed_kv=packed, bn=bn,
        interpret=True)
    want = _jit_ref(ref.flash_attn_mrq_ref, bits=bits, bn=bn, scale=scale)(
        q, k, v, qk_pack, pv_pack, mask=mask, g_qk=1, g_pv=1)
    _assert_conforms("flash", got, want)

    rep = 3
    qg, _, _ = _attn_case(B * rep, Sq, Skv, D, seed=37)
    shared = _flash(qg, k, v, qk_pack, pv_pack, 1, scale, bn, bits,
                    packed_kv=packed)
    copied = _flash(qg, jnp.repeat(k, rep, axis=0),
                    jnp.repeat(v, rep, axis=0), qk_pack, pv_pack, 1, scale,
                    bn, bits, packed_kv=packed)
    np.testing.assert_array_equal(np.asarray(shared), np.asarray(copied))


# ---------------------------------------------------------------------------
# vector-tgroup variants: per-row group vectors (mixed-timestep batches)
# ---------------------------------------------------------------------------
def _mix_rows(n, G, salt=0):
    """Deterministic per-row group vector hitting every group in [0, G)."""
    return jnp.asarray((np.arange(n) * 7 + salt) % G, jnp.int32)


def _flash_vec(q, k, v, qk_pack, pv_pack, gv, scale, bn, bits,
               packed_kv=False):
    return flash_attn_mrq_vec(
        q, k, v, qk_pack["s_q"], qk_pack["s_k"], qk_pack["scale"] * scale,
        pv_pack["s1"], pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
        g_qk=gv, g_pv=gv, bits=bits, packed_kv=packed_kv, bn=bn,
        interpret=True)


@pytest.mark.parametrize("shape", MM_SHAPES, ids=lambda s: "x".join(map(
    str, s)))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_linear_vector_tgroup_conformance(bname, shape):
    """Vector-tgroup linears through the ops dispatch: a CONSTANT per-row
    group vector ``full((M,), g)`` is bit-identical to the scalar-prefetch
    sibling, and a MIXED vector matches the per-row jitted oracle."""
    bits = BITS[bname]
    M, K, N = shape
    for G in GROUPS:
        x, w, bias, qp = _uniform_linear_case(M, K, N, G, bits,
                                              seed=M * K + N + G)
        if bits == 4:
            pack = ops.pack_int4_linear(qp, w)
            fwd = functools.partial(ops.int4_linear, x, pack, bias=bias)
            vec_ref = _jit_ref(ref.int4_matmul_fq_vec_ref,
                               group_k=pack["group_k"])
            args = (x, pack["wp"], pack["sx"], pack["zx"], pack["scale"],
                    pack["corr"], bias)
            path = "int4_linear_vec"
        else:
            pack = ops.pack_int8_linear(qp, w)
            fwd = functools.partial(ops.int8_linear, x, pack, bias=bias)
            vec_ref = _jit_ref(ref.int8_matmul_fq_vec_ref, bits=bits)
            args = (x, pack["wq"], pack["sx"], pack["zx"], pack["scale"],
                    pack["corr"], bias)
            path = "linear_vec"
        for g in _g_probes(G):
            _assert_conforms("vec_const",
                             fwd(tgroup=jnp.full((M,), g, jnp.int32)),
                             fwd(tgroup=g))
        if G > 1:
            gv = _mix_rows(M, G)
            _assert_conforms(path, fwd(tgroup=gv), vec_ref(*args, gv=gv))


@pytest.mark.parametrize("shape", MM_SHAPES, ids=lambda s: "x".join(map(
    str, s)))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_linear_mrq_vector_tgroup_conformance(bname, shape):
    bits = BITS[bname]
    M, K, N = shape
    for G in GROUPS:
        x, w, bias, qp = _mrq_linear_case(M, K, N, G, bits,
                                          seed=M + K * N + G)
        if bits == 4:
            pack = ops.pack_int4_mrq_linear(qp, w)
            fwd = functools.partial(ops.int4_linear_mrq, x, pack, bias=bias)
            vec_ref = _jit_ref(ref.int4_matmul_mrq_fq_vec_ref,
                               group_k=pack["group_k"])
            args = (x, pack["wp"], pack["s_neg"], pack["s_pos"],
                    pack["scale_neg"], pack["scale_pos"], bias)
            path = "int4_linear_mrq_vec"
        else:
            pack = ops.pack_int8_mrq_linear(qp, w)
            fwd = functools.partial(ops.int8_linear_mrq, x, pack, bias=bias)
            vec_ref = _jit_ref(ref.int8_matmul_mrq_fq_vec_ref, bits=bits)
            args = (x, pack["wq"], pack["s_neg"], pack["s_pos"],
                    pack["scale_neg"], pack["scale_pos"], bias)
            path = "linear_mrq_vec"
        for g in _g_probes(G):
            _assert_conforms("vec_const",
                             fwd(tgroup=jnp.full((M,), g, jnp.int32)),
                             fwd(tgroup=g))
        if G > 1:
            gv = _mix_rows(M, G)
            _assert_conforms(path, fwd(tgroup=gv), vec_ref(*args, gv=gv))


@pytest.mark.parametrize("shape", ATTN_SHAPES, ids=lambda s: "x".join(map(
    str, s[:4])))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_attention_composed_vector_tgroup_conformance(bname, shape):
    """Composed trio with per-batch-row group vectors: constant vector ==
    scalar prefetch bit-for-bit at every stage; mixed vectors match the
    per-row jitted oracles."""
    bits = BITS[bname]
    B, Sq, Skv, D, _ = shape
    for G in GROUPS:
        qk_qp, pv_qp = _attn_qparams(G, bits, seed=sum(shape) + G)
        qk_pack = ops.pack_int8_qk(qk_qp)
        pv_pack = ops.pack_int8_pv(pv_qp)
        q, k, v = _attn_case(B, Sq, Skv, D, seed=sum(shape) + G)
        for g in _g_probes(G):
            gv = jnp.full((B,), g, jnp.int32)
            scores = int8_bmm_qk(q, k, qk_pack["s_q"], qk_pack["s_k"],
                                 qk_pack["scale"], g=g, bits=bits,
                                 interpret=True)
            _assert_conforms(
                "vec_const",
                int8_bmm_qk_vec(q, k, qk_pack["s_q"], qk_pack["s_k"],
                                qk_pack["scale"], gv=gv, bits=bits,
                                interpret=True),
                scores)
            rows = jnp.broadcast_to(gv[:, None], scores.shape[:-1])
            codes = softmax_mrq_codes(scores, pv_pack["s1"], g=g, bits=bits,
                                      interpret=True)
            _assert_conforms(
                "vec_const",
                softmax_mrq_codes_vec(scores, pv_pack["s1"], gv=rows,
                                      bits=bits, interpret=True),
                codes)
            _assert_conforms(
                "vec_const",
                int8_bmm_pv_vec(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                                pv_pack["scale2"], gv=gv, bits=bits,
                                interpret=True),
                int8_bmm_pv(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                            pv_pack["scale2"], g=g, bits=bits,
                            interpret=True))
        if G > 1:
            gv = _mix_rows(B, G)
            scores = int8_bmm_qk_vec(q, k, qk_pack["s_q"], qk_pack["s_k"],
                                     qk_pack["scale"], gv=gv, bits=bits,
                                     interpret=True)
            _assert_conforms(
                "attn_qk_vec", scores,
                _jit_ref(ref.int8_bmm_qk_vec_ref, bits=bits)(
                    q, k, qk_pack["s_q"], qk_pack["s_k"], qk_pack["scale"],
                    gv=gv))
            rows = jnp.broadcast_to(gv[:, None], scores.shape[:-1])
            codes = softmax_mrq_codes_vec(scores, pv_pack["s1"], gv=rows,
                                          bits=bits, interpret=True)
            assert codes.dtype == jnp.int8
            _assert_conforms(
                "attn_codes_vec", codes,
                _jit_ref(ref.softmax_mrq_codes_vec_ref, bits=bits)(
                    scores, pv_pack["s1"], gv=rows))
            out = int8_bmm_pv_vec(codes, v, pv_pack["s_v"],
                                  pv_pack["scale1"], pv_pack["scale2"],
                                  gv=gv, bits=bits, interpret=True)
            _assert_conforms(
                "attn_pv_vec", out,
                _jit_ref(ref.int8_bmm_pv_vec_ref, bits=bits)(
                    codes, v, pv_pack["s_v"], pv_pack["scale1"],
                    pv_pack["scale2"], gv=gv))


@pytest.mark.parametrize("shape", ATTN_SHAPES, ids=lambda s: "x".join(map(
    str, s[:4])))
@pytest.mark.parametrize("bname", sorted(BITS))
def test_flash_vector_tgroup_conformance(bname, shape):
    bits = BITS[bname]
    B, Sq, Skv, D, bn = shape
    scale = D ** -0.5
    for G in GROUPS:
        qk_qp, pv_qp = _attn_qparams(G, bits, seed=sum(shape) + G)
        qk_pack = ops.pack_int8_qk(qk_qp)
        pv_pack = ops.pack_int8_pv(pv_qp)
        q, k, v = _attn_case(B, Sq, Skv, D, seed=sum(shape) + 7 * G)
        for g in _g_probes(G):
            gv = jnp.full((B,), g, jnp.int32)
            _assert_conforms(
                "vec_const",
                _flash_vec(q, k, v, qk_pack, pv_pack, gv, scale, bn, bits,
                           packed_kv=(bits == 4)),
                _flash(q, k, v, qk_pack, pv_pack, g, scale, bn, bits,
                       packed_kv=(bits == 4)))
        if G > 1:
            gv = _mix_rows(B, G)
            got = _flash_vec(q, k, v, qk_pack, pv_pack, gv, scale, bn, bits,
                             packed_kv=(bits == 4))
            want = _jit_ref(ref.flash_attn_mrq_vec_ref, bits=bits, bn=bn,
                            scale=scale)(q, k, v, qk_pack, pv_pack,
                                         g_qk=gv, g_pv=gv)
            _assert_conforms("flash_vec", got, want)
            if bits == 4:
                unpacked = _flash_vec(q, k, v, qk_pack, pv_pack, gv, scale,
                                      bn, bits, packed_kv=False)
                _assert_conforms("flash_packed_kv", got, unpacked)


@pytest.mark.parametrize("bname", sorted(BITS))
def test_ops_vector_tgroup_matches_per_slot(bname):
    """The ops-layer contract of the vector-tgroup batched path: ONE call
    over a batch whose slots sit at different timestep groups is bit-
    identical to stacking per-slot scalar-tgroup calls — for the linear
    wrappers (3-D activations, group rows expanded per slot) and both
    attention wrappers (slot-major B·Hk·G row expansion)."""
    bits = BITS[bname]
    G = 3
    tg = jnp.asarray([2, 0, 1], jnp.int32)               # B = 3 slots
    B, T, K, N = 3, 6, 32, 24
    x2, w, bias, qp = _uniform_linear_case(B * T, K, N, G, bits, seed=13)
    x3 = x2.reshape(B, T, K)
    if bits == 4:
        pack = ops.pack_int4_linear(qp, w)
        lin = functools.partial(ops.int4_linear, pack=pack, bias=bias)
    else:
        pack = ops.pack_int8_linear(qp, w)
        lin = functools.partial(ops.int8_linear, pack=pack, bias=bias)
    got = lin(x3, tgroup=tg)
    want = jnp.concatenate([lin(x3[b:b + 1], tgroup=int(tg[b]))
                            for b in range(B)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    Sq, Skv, Hk, Gq, hd = 9, 13, 2, 2, 8
    qk_qp, pv_qp = _attn_qparams(G, bits, seed=3)
    qk_pack = ops.pack_int8_qk(qk_qp)
    pv_pack = ops.pack_int8_pv(pv_qp)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(kq, (B, Sq, Hk, Gq, hd)) * 1.5
    k = jax.random.normal(kk, (B, Skv, Hk, hd)) * 1.5
    v = jax.random.normal(kv, (B, Skv, Hk, hd))
    for attn in (ops.int8_attention, ops.flash_attention):
        got = attn(q, k, v, qk_pack, pv_pack, scale=hd ** -0.5, tgroup=tg)
        want = jnp.concatenate([
            attn(q[b:b + 1], k[b:b + 1], v[b:b + 1], qk_pack, pv_pack,
                 scale=hd ** -0.5, tgroup=int(tg[b])) for b in range(B)])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# end-to-end acceptance: a w4a4 artifact serves through the packed-int4
# kernels, compiled once, agreeing with its own fake-quant oracle
# ---------------------------------------------------------------------------
def test_engine_w4a4_serves_packed_int4_compile_once(tiny_dit, monkeypatch):
    """ServeEngine with a w4a4 QuantArtifact lowers every packed linear
    onto `int4_matmul_fq` / `int4_matmul_mrq_fq` (counted inside the
    scan body), traces ONCE across all timestep groups, and the samples
    agree with the fake-quant oracle on the same artifact.  At
    d_model <= group_k the per-K-group weight scales coincide with the
    per-channel fake-quant scales, so the only divergence is f32
    accumulation order inside the kernel — atol 1e-4 on samples whose
    std is ~1.  (Models with K > group_k genuinely refine the weights
    per group; their oracle is the kernel-vs-ref sweep above, not a
    sample-level identity.)"""
    from repro.diffusion import DiffusionCfg, make_schedule
    from repro.kernels import ops as kops
    from repro.models import dit_apply
    from repro.quant import QuantRecipe, quantize
    from repro.serving import GenRequest, ServeEngine

    cfg, p = tiny_dit
    dif = DiffusionCfg(T=40, tgq_groups=4)
    sched = make_schedule(dif)
    art = quantize(p, cfg, dif, QuantRecipe(bits="w4a4", method="range",
                                            n_per_group=1, calib_batch=1))
    assert art.has_kernel_packs
    n_int4 = sum(1 for qp in art.qparams.values()
                 if "int4" in qp or "int4_mrq" in qp)
    assert n_int4 > 0, "w4a4 quantize() must emit packed-int4 linears"
    assert not any("int8" in qp or "int8_mrq" in qp
                   for qp in art.qparams.values()), \
        "w4a4 linears must not take the byte-code kernels"

    calls = {"fq": 0, "mrq": 0}
    for key, fname in (("fq", "int4_matmul_fq"), ("mrq",
                                                  "int4_matmul_mrq_fq")):
        orig = getattr(kops, fname)
        monkeypatch.setattr(kops, fname, functools.partial(
            lambda orig, key, *a, **kw: (
                calls.__setitem__(key, calls[key] + 1), orig(*a, **kw))[1],
            orig, key))

    traces = []
    orig_apply = dit_apply

    def traced_apply(*a, **kw):
        traces.append(1)
        return orig_apply(*a, **kw)

    import repro.serving.engine as eng_mod
    monkeypatch.setattr(eng_mod, "dit_apply", traced_apply)

    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=4,
                       cfg_scale=1.5, seed=40 + i) for i in range(2)]
    eng = ServeEngine(p, cfg, dif, sched, ctx=art.context(), microbatch=2,
                      step_buckets=(4,))
    res = eng.serve(reqs)
    assert len(traces) == 1, "sampler retraced across timestep groups"
    assert calls["fq"] > 0, "int4 uniform kernel never fired"
    assert calls["mrq"] > 0, "int4 MRQ (post-GELU fc2) kernel never fired"
    n_fq, n_mrq = calls["fq"], calls["mrq"]
    kern = np.stack([res[i].sample for i in range(2)])
    assert np.isfinite(kern).all()

    eng_fake = ServeEngine(p, cfg, dif, sched, ctx=art.context(kernel=False),
                           microbatch=2, step_buckets=(4,))
    res_fake = eng_fake.serve(reqs)
    assert calls["fq"] == n_fq and calls["mrq"] == n_mrq, \
        "fake-quant oracle must not touch the int4 kernels"
    fake = np.stack([res_fake[i].sample for i in range(2)])
    np.testing.assert_allclose(kern, fake, rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# nibble packing: exhaustive byte sweep + property-based round-trips
# ---------------------------------------------------------------------------
def test_nibble_split_exhaustive_bytes():
    """Every one of the 256 byte patterns splits into two codes in
    [-8, 7] and re-packs to the identical byte — the sign-extension
    ((u ^ 8) - 8) has no wrap/overflow corner anywhere in its domain."""
    from repro.kernels import nibble_split
    bytes_all = jnp.arange(-128, 128, dtype=jnp.int32).astype(jnp.int8)
    lo, hi = nibble_split(bytes_all)
    assert int(lo.min()) >= -8 and int(lo.max()) <= 7
    assert int(hi.min()) >= -8 and int(hi.max()) <= 7
    interleaved = jnp.stack([lo, hi], axis=1).reshape(-1).astype(jnp.int8)
    repacked = pack_int4(interleaved)
    np.testing.assert_array_equal(np.asarray(repacked),
                                  np.asarray(bytes_all))


def test_pack_int4_odd_length_pads_inert_zero():
    codes = jnp.array([[-8, 7], [3, -1], [5, 2]], jnp.int8)    # odd K=3
    packed = pack_int4(codes)                                  # (2, 2)
    assert packed.shape == (2, 2)
    full = unpack_int4(packed)                                 # (4, 2)
    np.testing.assert_array_equal(np.asarray(full[3]), np.zeros(2))
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed, k=3)),
                                  np.asarray(codes))


_hyp_skip = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                               reason="hypothesis not installed")

if HAVE_HYPOTHESIS:
    @_hyp_skip
    @settings(max_examples=60, deadline=None)
    @given(k=st.integers(1, 40), n=st.integers(1, 9),
           axis=st.sampled_from([0, 1, -1]),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_nibble_roundtrip_property(k, n, axis, seed):
        """pack -> unpack identity over random int4 tensors along any
        axis, including odd lengths (one inert zero-pad row)."""
        rng = np.random.default_rng(seed)
        codes = rng.integers(-8, 8, size=(k, n)).astype(np.int8)
        dim = codes.shape[axis]
        packed = pack_int4(jnp.asarray(codes), axis=axis)
        assert packed.shape[axis if axis >= 0 else packed.ndim + axis] \
            == (dim + 1) // 2
        out = unpack_int4(packed, k=dim, axis=axis)
        np.testing.assert_array_equal(np.asarray(out), codes)

    @_hyp_skip
    @settings(max_examples=30, deadline=None)
    @given(k=st.integers(1, 30), n=st.integers(1, 6),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_packed_dequant_matches_unpacked_property(k, n, seed):
        """Dequantizing through the packed representation loses nothing:
        unpack(pack(codes)) * scale == codes * scale elementwise."""
        rng = np.random.default_rng(seed)
        codes = rng.integers(-8, 8, size=(k, n)).astype(np.int8)
        scale = rng.uniform(1e-4, 1e-1, size=(1, n)).astype(np.float32)
        via_pack = np.asarray(unpack_int4(pack_int4(jnp.asarray(codes)),
                                          k=k)).astype(np.float32) * scale
        np.testing.assert_array_equal(via_pack,
                                      codes.astype(np.float32) * scale)
else:                                          # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_nibble_roundtrip_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_packed_dequant_matches_unpacked_property():
        pass
