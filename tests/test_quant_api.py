"""The unified quantization API (`repro.quant`): recipe validation, the
shared timestep-group resolution contract, artifact save -> load in a
FRESH process with bit-identical served samples (range and ho recipes at
w8a8, plus the packed-int4 w4a4 deployment point), recipe-mismatch load
errors, the no-silent-fake-quant serving contract, and the CLI
cold-start acceptance (`--load-artifact` serves with no calibration,
samples bit-identical to the calibrating process)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import DiffusionCfg, make_schedule
from repro.quant import (
    QuantArtifact, QuantRecipe, group_boundaries, quantize, resolve_group,
)
from repro.serving import GenRequest, ServeEngine

DIF = DiffusionCfg(T=40, tgq_groups=4)

RANGE_RECIPE = QuantRecipe(bits="w8a8", method="range", n_per_group=1,
                           calib_batch=1)
HO_RECIPE = QuantRecipe(bits="w8a8", method="ho", rounds=1, n_alpha=4,
                        n_per_group=2, calib_batch=2)


# ---------------------------------------------------------------------------
# recipe
# ---------------------------------------------------------------------------
def test_recipe_validation_and_roundtrip():
    with pytest.raises(ValueError, match="bits"):
        QuantRecipe(bits="w3a3")
    with pytest.raises(ValueError, match="method"):
        QuantRecipe(method="minmax")
    r = QuantRecipe(bits="w6a6", method="ho",
                    skip_patterns=["router", "final"])
    assert (r.wbits, r.abits) == (6, 6)
    # every named bit-width is kernel-real: w8a8/w6a6 on the byte-code
    # int8 family, w4a4 on the nibble-packed int4 family
    assert all(QuantRecipe(bits=b).kernel_deployable
               for b in ("w8a8", "w6a6", "w4a4"))
    assert r.skip_patterns == ("router", "final")     # list normalized
    assert QuantRecipe.from_dict(r.to_dict()) == r
    with pytest.raises(ValueError, match="unknown QuantRecipe fields"):
        QuantRecipe.from_dict({"bits": "w8a8", "frobnicate": 1})
    d = RANGE_RECIPE.diff(HO_RECIPE)
    assert "method" in d and d["method"] == ("range", "ho")
    assert "bits" not in d


def test_recipe_attn_impl():
    with pytest.raises(ValueError, match="attn_impl"):
        QuantRecipe(attn_impl="fused")
    r = QuantRecipe(attn_impl="composed")
    assert QuantRecipe.from_dict(r.to_dict()) == r
    assert QuantRecipe().attn_impl == "flash"              # serving default
    assert "attn_impl" in QuantRecipe().diff(r)
    # a lowering choice, not a calibration one: valid under both methods
    assert QuantRecipe(method="range", attn_impl="composed").attn_impl \
        == "composed"


def test_recipe_content_hash_stable_and_order_invariant():
    r = QuantRecipe(bits="w6a6", method="ho", skip_patterns=["a", "b"])
    assert r.content_hash() == r.content_hash()
    assert len(r.content_hash()) == 16
    # canonical JSON sorts keys: a recipe rebuilt from its dict in ANY
    # key order (and through list->tuple normalization) hashes the same
    d = r.to_dict()
    reordered = {k: d[k] for k in sorted(d, reverse=True)}
    assert QuantRecipe.from_dict(reordered).content_hash() \
        == r.content_hash()
    # equal recipes hash equal regardless of construction path
    assert QuantRecipe(method="ho", bits="w6a6",
                       skip_patterns=("a", "b")).content_hash() \
        == r.content_hash()


def test_recipe_content_hash_changes_on_any_field():
    """Exhaustive: perturbing EVERY field changes the hash — the
    property that makes it safe as the autotune ledger key (two trials
    collide iff they are the same trial)."""
    import dataclasses as dc
    base = QuantRecipe()
    perturbed = {
        "bits": "w4a4", "method": "ho", "use_mrq": False,
        "use_tgq": False, "tgq_groups": 7, "use_fisher": False,
        "rounds": 5, "n_alpha": 11, "max_rows_per_batch": 128,
        "fisher_norm": "global", "bias_correct": True,
        "channel_balance": True, "balance_alpha": 0.7,
        "n_per_group": 9, "calib_batch": 9,
        "skip_patterns": ("router", "x"), "weight_only_patterns": ("y",),
        "attn_impl": "composed", "seed": 123,
    }
    fields = {f.name for f in dc.fields(QuantRecipe)}
    assert set(perturbed) == fields, "perturbation map must cover every field"
    for name, value in perturbed.items():
        assert value != getattr(base, name), name
        changed = dc.replace(base, **{name: value})
        assert changed.content_hash() != base.content_hash(), \
            f"hash blind to field {name}"


def test_artifact_records_recipe_hash(tiny_dit, tmp_path):
    """quantize() stamps meta['recipe_hash'] (the autotune ledger key)
    and it survives save -> load."""
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, RANGE_RECIPE)
    assert art.meta["recipe_hash"] == RANGE_RECIPE.content_hash()
    art.save(str(tmp_path / "a"))
    loaded = QuantArtifact.load(str(tmp_path / "a"))
    assert loaded.meta["recipe_hash"] == RANGE_RECIPE.content_hash()
    assert loaded.recipe.content_hash() == loaded.meta["recipe_hash"]


def test_recipe_matches_ptq_config():
    """The 'ho' dispatch must reproduce PTQConfig semantics exactly —
    the recipe is a rename, not a re-tune."""
    from repro.core import PTQConfig
    r = QuantRecipe(bits="w6a6", method="ho", rounds=2, n_alpha=7,
                    use_mrq=False, bias_correct=True, seed=3)
    cfg = r.ptq_config(tgq_groups=5)
    assert cfg == PTQConfig(wbits=6, abits=6, rounds=2, n_alpha=7,
                            use_mrq=False, bias_correct=True, seed=3,
                            tgq_groups=5)


# ---------------------------------------------------------------------------
# shared group resolution (quickcal borrow == kernel clamp contract)
# ---------------------------------------------------------------------------
def test_resolve_group_nearest():
    assert resolve_group(2, calibrated=[0, 2, 3]) == 2     # exact wins
    assert resolve_group(2, calibrated=[0, 3]) == 3        # nearest
    assert resolve_group(9, calibrated=[0, 3]) == 3
    assert resolve_group(2, calibrated=[1, 3]) == 1        # tie -> smaller
    with pytest.raises(ValueError, match="empty"):
        resolve_group(0, calibrated=[])


def test_resolve_group_clamp():
    assert resolve_group(None, 4) == 0                     # no group info
    assert resolve_group(3, 1) == 0                        # per-tensor pack
    assert int(resolve_group(2, 4)) == 2
    assert int(resolve_group(9, 4)) == 3                   # clamped
    assert int(resolve_group(-1, 4)) == 0
    # traced (the sampler's scan threads a traced tgroup)
    traced = jax.jit(lambda g: resolve_group(g, 4))(jnp.int32(7))
    assert int(traced) == 3
    with pytest.raises(ValueError, match="n_groups"):
        resolve_group(2)


def test_group_boundaries_cover_chain():
    bounds = group_boundaries(T=40, G=4)
    assert bounds == [(0, 10), (10, 20), (20, 30), (30, 40)]
    bounds = group_boundaries(T=10, G=3)                   # ragged
    assert bounds[0][0] == 0 and bounds[-1][1] == 10
    assert all(lo < hi for lo, hi in bounds)
    assert all(bounds[i][1] == bounds[i + 1][0]
               for i in range(len(bounds) - 1))


# ---------------------------------------------------------------------------
# artifact consumption
# ---------------------------------------------------------------------------
def test_w6a6_artifact_packs_bits_tagged_int8_kernels(tiny_dit):
    """w6a6 lowers onto the SAME byte-code int8 kernel family as w8a8 —
    packs carry bits=6 and the kernel context auto-selects."""
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, QuantRecipe(bits="w6a6", method="range",
                                            n_per_group=1, calib_batch=1))
    assert art.has_kernel_packs
    assert art.context().kernel is True
    for qp in art.qparams.values():
        for key in ("int8", "int8_mrq", "int8_qk", "int8_pv"):
            if key in qp:
                assert qp[key]["bits"] == 6, key


def test_w4a4_artifact_packs_nibble_int4_kernels(tiny_dit):
    """w4a4 packs the nibble-coded int4 family: payload bytes hold two
    codes each (wp has K/2 rows), scales/corr carry the per-K-group axis,
    and the attention packs tag bits=4."""
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, QuantRecipe(bits="w4a4", method="range",
                                            n_per_group=1, calib_batch=1))
    assert art.has_kernel_packs
    assert art.context().kernel is True
    n_int4 = 0
    for qp in art.qparams.values():
        assert "int8" not in qp and "int8_mrq" not in qp
        for key in ("int4", "int4_mrq"):
            if key in qp:
                n_int4 += 1
                pk = qp[key]
                assert pk["bits"] == 4
                assert pk["wp"].dtype == np.int8
                # two nibbles per byte along K (padded to the group tile)
                kp = -pk["group_k"] * (-pk["k"] // pk["group_k"])
                assert pk["wp"].shape[0] == kp // 2
                sc = pk["scale"] if key == "int4" else pk["scale_neg"]
                assert sc.ndim == 3                        # (G, nk, N)
                assert sc.shape[1] == kp // pk["group_k"]
        for key in ("int8_qk", "int8_pv"):
            if key in qp:
                assert qp[key]["bits"] == 4
    assert n_int4 > 0
    assert "packed-int4" in art.summary()


def test_serve_cli_names_fake_quant_fallback(tiny_dit):
    """Regression: `--quantize w4a4` used to silently serve fake-quant.
    Now every kernel-less quantized serve warns by name, and pack-carrying
    artifacts (all three bit-widths) warn nothing."""
    from repro.launch.serve import fake_quant_fallback_warning
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, QuantRecipe(bits="w4a4", method="range",
                                            n_per_group=1, calib_batch=1))
    assert fake_quant_fallback_warning(art) is None        # kernel path on
    stripped = QuantArtifact(
        qparams={n: {k: v for k, v in qp.items()
                     if k not in ("int4", "int4_mrq", "int8_qk", "int8_pv")}
                 for n, qp in art.qparams.items()},
        recipe=art.recipe, meta=art.meta)
    assert not stripped.has_kernel_packs
    msg = fake_quant_fallback_warning(stripped)
    assert msg is not None and "FAKE-QUANT" in msg and "w4a4" in msg


def test_range_method_rejects_ho_only_knobs(tiny_dit):
    """method='range' must not silently record knobs its pipeline cannot
    honor — the artifact's recipe has to describe what actually ran."""
    cfg, p = tiny_dit
    for bad in (dict(skip_patterns=("attn",)), dict(use_mrq=False),
                dict(use_tgq=False), dict(weight_only_patterns=("fc",)),
                dict(rounds=2), dict(n_alpha=8), dict(bias_correct=True)):
        with pytest.raises(ValueError, match="cannot honor"):
            quantize(p, cfg, DIF, QuantRecipe(method="range", n_per_group=1,
                                              calib_batch=1, **bad))


def test_calib_data_group_tag_validation(tiny_dit):
    cfg, p = tiny_dit
    fake_calib = [({"xt": None}, 0), ({"xt": None}, 7)]   # tag 7 >= G=4
    with pytest.raises(ValueError, match="out of range"):
        quantize(p, cfg, DIF, QuantRecipe(method="ho"),
                 calib_data=fake_calib)
    # overriding the group count with caller-built calib is ambiguous
    with pytest.raises(ValueError, match="overrides"):
        quantize(p, cfg, DIF, QuantRecipe(method="ho", tgq_groups=2),
                 calib_data=[({"xt": None}, 0)])


def test_recipe_tgq_groups_overrides_dif(tiny_dit):
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, QuantRecipe(bits="w8a8", method="range",
                                            tgq_groups=2, n_per_group=1,
                                            calib_batch=1))
    assert art.meta["tgq_groups"] == 2
    assert art.dif_cfg().tgq_groups == 2
    assert len(art.meta["tgq_group_boundaries"]) == 2
    assert any(v.get("int8", {}).get("groups") == 2
               for v in art.qparams.values())


def test_artifact_params_hash_binding(tiny_dit, tmp_path):
    """quantize() records the fp-params content hash; from_artifact and
    load(params=...) fail fast on any other params tree (the
    wrong-checkpoint guard); hash-less (older) artifacts skip the check."""
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, RANGE_RECIPE)
    ph = art.params_hash
    assert ph is not None and ph["n_leaves"] > 0 and ph["digest"]
    art.check_params(p)                                    # the right tree
    ServeEngine.from_artifact(p, art, microbatch=2, step_buckets=(2,))

    bad = jax.tree.map(lambda a: a, p)
    bad["final"]["w"] = bad["final"]["w"] + 1e-3           # one leaf off
    with pytest.raises(ValueError, match="content hash mismatch"):
        ServeEngine.from_artifact(bad, art, microbatch=2, step_buckets=(2,))
    with pytest.raises(ValueError, match="1/"):            # counts bad leaves
        art.check_params(bad)

    # the hash survives save -> load; load(params=...) runs the check
    path = str(tmp_path / "art")
    art.save(path)
    art2 = QuantArtifact.load(path, params=p)
    assert art2.params_hash == ph
    with pytest.raises(ValueError, match="content hash mismatch"):
        QuantArtifact.load(path, params=bad)

    # artifacts from before hashes were recorded have nothing to check
    art2.meta.pop("params_hash")
    art2.check_params(bad)                                 # no raise

    # a structurally different tree reports the leaf-count mismatch
    with pytest.raises(ValueError, match="leaves"):
        art.check_params({"only": p["final"]["w"]})


def test_artifact_recipe_mismatch_raises(tiny_dit, tmp_path):
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, RANGE_RECIPE)
    path = str(tmp_path / "art")
    art.save(path)
    with pytest.raises(ValueError, match="recipe mismatch.*method"):
        QuantArtifact.load(path, expect_recipe=HO_RECIPE)
    # matching recipe loads fine
    assert QuantArtifact.load(
        path, expect_recipe=RANGE_RECIPE).recipe == RANGE_RECIPE
    with pytest.raises(FileNotFoundError, match="artifact"):
        QuantArtifact.load(str(tmp_path / "nope"))


def test_artifact_detects_json_shard_mismatch(tiny_dit, tmp_path):
    """An interrupted overwrite (old artifact.json paired with new leaf
    shards) must fail loudly, not decode new leaves under a stale spec."""
    import json as _json
    cfg, p = tiny_dit
    art = quantize(p, cfg, DIF, RANGE_RECIPE)
    path = str(tmp_path / "art")
    art.save(path)
    doc_path = os.path.join(path, "artifact.json")
    with open(doc_path) as f:
        doc = _json.load(f)
    doc["leaf_hashes"] = {k: "0" * 16 for k in doc["leaf_hashes"]}
    with open(doc_path, "w") as f:
        _json.dump(doc, f)
    with pytest.raises(ValueError, match="interrupted overwrite"):
        QuantArtifact.load(path)


# ---------------------------------------------------------------------------
# save -> load in a FRESH process -> bit-identical served samples
# ---------------------------------------------------------------------------
_PARAMS_SRC = r"""
import jax
from repro.models import DiTCfg, dit_init
cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
             n_heads=4, n_classes=8)
p = dit_init(jax.random.PRNGKey(0), cfg)
p["blocks"] = jax.tree.map(
    lambda a: a + jax.random.normal(jax.random.PRNGKey(1), a.shape) * 0.01,
    p["blocks"])
"""

_LOAD_AND_SERVE_SRC = _PARAMS_SRC + r"""
import sys
import numpy as np
from repro.quant import QuantArtifact
from repro.serving import GenRequest, ServeEngine

for path, out in zip(sys.argv[1::2], sys.argv[2::2]):
    art = QuantArtifact.load(path)
    eng = ServeEngine.from_artifact(p, art, microbatch=2, step_buckets=(4,))
    res = eng.serve([GenRequest(request_id=i, label=i % 8, steps=4,
                                cfg_scale=1.5, seed=600 + i)
                     for i in range(2)])
    np.save(out, np.stack([res[i].sample for i in range(2)]))
print("SERVED")
"""


def _exec_params():
    ns = {}
    exec(compile(_PARAMS_SRC, "<params>", "exec"), ns)
    return ns["cfg"], ns["p"]


def _serve_in_memory(p, art):
    eng = ServeEngine.from_artifact(p, art, microbatch=2, step_buckets=(4,))
    res = eng.serve([GenRequest(request_id=i, label=i % 8, steps=4,
                                cfg_scale=1.5, seed=600 + i)
                     for i in range(2)])
    return np.stack([res[i].sample for i in range(2)])


def test_artifact_roundtrip_fresh_process_bit_identical(tmp_path):
    """The cold-start guarantee, for both calibration methods at w8a8
    AND the packed-int4 deployment point: an artifact saved here and
    loaded in a subprocess serves samples bit-identical to the in-memory
    artifact (same requests/seeds) — for w4a4 that round-trips the
    nibble-packed payload bytes and (G, nk, N) group scales exactly."""
    cfg, p = _exec_params()
    w4_recipe = QuantRecipe(bits="w4a4", method="range", n_per_group=1,
                            calib_batch=1)
    jobs = []
    for name, recipe in (("range", RANGE_RECIPE), ("ho", HO_RECIPE),
                         ("w4a4", w4_recipe)):
        art = quantize(p, cfg, DIF, recipe)
        assert art.has_kernel_packs, name
        in_mem = _serve_in_memory(p, art)
        path = str(tmp_path / f"art_{name}")
        art.save(path)
        jobs.append((name, path, str(tmp_path / f"{name}.npy"), in_mem))

    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    argv = [a for _, path, out, _ in jobs for a in (path, out)]
    r = subprocess.run([sys.executable, "-c", _LOAD_AND_SERVE_SRC, *argv],
                       env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SERVED" in r.stdout
    for name, _, out, in_mem in jobs:
        fresh = np.load(out)
        assert np.array_equal(in_mem, fresh), \
            f"{name}: fresh-process serve diverged from in-memory artifact"


# ---------------------------------------------------------------------------
# CLI acceptance: --load-artifact cold-start, zero calibration
# ---------------------------------------------------------------------------
def test_serve_cli_load_artifact_no_calibration_bit_identical(tmp_path):
    """`python -m repro.launch.serve --quantize w8a8 --load-artifact X`
    serves WITHOUT running any calibration and its samples are
    bit-identical to the serve that calibrated in-process with the same
    recipe and seed."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    art = str(tmp_path / "cli_art")
    base = [sys.executable, "-m", "repro.launch.serve", "--arch", "dit-xl-2",
            "--smoke", "--requests", "2", "--microbatch", "2", "--steps",
            "2", "--quantize", "w8a8", "--seed", "0"]
    a_npy, b_npy = str(tmp_path / "a.npy"), str(tmp_path / "b.npy")

    r1 = subprocess.run(base + ["--save-artifact", art,
                                "--dump-samples", a_npy],
                        env=env, capture_output=True, text=True, timeout=560)
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert "range-calibrated" in r1.stdout

    r2 = subprocess.run(base + ["--load-artifact", art,
                                "--dump-samples", b_npy],
                        env=env, capture_output=True, text=True, timeout=560)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "no calibration run" in r2.stdout
    assert "calibrated" not in r2.stdout.replace("no calibration run", "")
    assert np.array_equal(np.load(a_npy), np.load(b_npy)), \
        "cold-started serve diverged from the calibrating serve"

    # bits mismatch between the flag and the stored artifact fails fast
    mismatch = [x if x != "w8a8" else "w6a6" for x in base]
    r3 = subprocess.run(mismatch + ["--load-artifact", art],
                        env=env, capture_output=True, text=True, timeout=560)
    assert r3.returncode != 0
    assert "w6a6" in (r3.stdout + r3.stderr)
