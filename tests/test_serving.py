"""Sharded batched serving subsystem: request coalescing, CFG-paired
batching (bit-identical to separate forwards), per-request-keyed sampler
(batch-composition invariance — the property that makes padding and
sharding safe), engine end-to-end fp + fused-int8, multi-device
shard_map identity (subprocess), and the modeled throughput floor."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import DiffusionCfg, ddpm_sample_paired, make_schedule
from repro.models import dit_apply
from repro.quant import QuantRecipe, quantize
from repro.serving import (
    GenRequest, RequestScheduler, ServeEngine, bucket_steps, coalesce,
)

DIF = DiffusionCfg(T=40, tgq_groups=4)


# ---------------------------------------------------------------------------
# batching / scheduling (pure)
# ---------------------------------------------------------------------------
def test_bucket_steps():
    assert bucket_steps(10, (25, 50, 100)) == 25
    assert bucket_steps(25, (25, 50, 100)) == 25
    assert bucket_steps(26, (25, 50, 100)) == 50
    assert bucket_steps(999, (25, 50, 100)) == 100


def test_coalesce_shapes_padding_and_coverage():
    reqs = [GenRequest(request_id=i, label=i, steps=s, cfg_scale=1.0, seed=i)
            for i, s in enumerate([20, 20, 20, 40, 40])]
    mbs = coalesce(reqs, batch=2, step_buckets=(25, 50))
    assert [mb.steps for mb in mbs] == [25, 25, 50]
    assert all(mb.batch == 2 for mb in mbs)
    # padding only on the trailing partial batch of each bucket
    assert [mb.n_padded for mb in mbs] == [0, 1, 0]
    served = [rid for mb in mbs for rid in mb.request_ids]
    assert sorted(served) == [0, 1, 2, 3, 4]
    # padded slots are marked invalid and carry benign params
    tail = mbs[1]
    assert tail.valid.tolist() == [True, False]
    assert tail.guidance[1] == 1.0


def test_scheduler_submit_all_keeps_ids_unique():
    """Engine results are keyed by request id — submit() after
    submit_all() must never mint a duplicate."""
    sch = RequestScheduler(microbatch=2, step_buckets=(25,))
    sch.submit_all([GenRequest(request_id=0, label=1, steps=25),
                    GenRequest(request_id=7, label=2, steps=25)])
    rid = sch.submit(label=3, steps=25)
    assert rid == 8
    ids = [r.request_id for r in sch.pending]
    assert len(ids) == len(set(ids))
    with pytest.raises(ValueError, match="duplicate request ids"):
        sch.submit_all([GenRequest(request_id=7, label=0, steps=25)])
    assert len(sch.pending) == 3                  # rejected batch not queued


def test_scheduler_run_validates_before_draining(tiny_dit):
    """A scheduler/engine config mismatch must raise BEFORE the queue is
    flushed — pending requests survive for a corrected retry."""
    cfg, p = tiny_dit
    eng = ServeEngine(p, cfg, DIF, microbatch=2, step_buckets=(4,))
    sch = RequestScheduler(microbatch=4, step_buckets=(4,))
    sch.submit(label=1, steps=4)
    with pytest.raises(ValueError, match="microbatch"):
        sch.run(eng)
    assert len(sch.pending) == 1
    sch2 = RequestScheduler(microbatch=2, step_buckets=(4, 8))
    sch2.submit(label=1, steps=8)
    with pytest.raises(ValueError, match="buckets"):
        sch2.run(eng)
    assert len(sch2.pending) == 1


def test_scheduler_partial_flush_policy():
    sch = RequestScheduler(microbatch=4, step_buckets=(25,))
    for i in range(6):
        sch.submit(label=i, steps=25)
    full = sch.flush(partial=False)           # only the full batch leaves
    assert len(full) == 1 and full[0].n_padded == 0
    assert len(sch.pending) == 2              # remainder stays queued
    drained = sch.flush(partial=True)
    assert len(drained) == 1 and drained[0].n_padded == 2
    assert sch.pending == []


# ---------------------------------------------------------------------------
# CFG pairing: one 2B forward == two separate forwards, bit for bit
# ---------------------------------------------------------------------------
def test_cfg_paired_forward_bit_identical(tiny_dit):
    cfg, p = tiny_dit
    key = jax.random.PRNGKey(5)
    B = 3
    x = jax.random.normal(key, (B, cfg.img_size, cfg.img_size, cfg.in_ch))
    t = jnp.full((B,), 7, jnp.int32)
    y = jnp.arange(B, dtype=jnp.int32)
    null = jnp.full((B,), cfg.n_classes, jnp.int32)

    paired = dit_apply(p, cfg, jnp.concatenate([x, x]),
                       jnp.concatenate([t, t]), jnp.concatenate([y, null]))
    eps_c, eps_u = jnp.split(paired, 2)
    np.testing.assert_array_equal(np.asarray(eps_c),
                                  np.asarray(dit_apply(p, cfg, x, t, y)))
    np.testing.assert_array_equal(np.asarray(eps_u),
                                  np.asarray(dit_apply(p, cfg, x, t, null)))


# ---------------------------------------------------------------------------
# per-request keys: a sample depends only on its own request
# ---------------------------------------------------------------------------
def _eps(p, cfg):
    return lambda x, t, y, c: dit_apply(p, cfg, x, t, y, ctx=c)


def test_paired_sampler_batch_invariant(tiny_dit):
    cfg, p = tiny_dit
    sched = make_schedule(DIF)
    shape3 = (3, cfg.img_size, cfg.img_size, cfg.in_ch)
    y = jnp.asarray([1, 4, 2], jnp.int32)
    seeds = jnp.asarray([11, 12, 13], jnp.uint32)
    gsc = jnp.asarray([1.0, 1.5, 0.0], jnp.float32)
    batched = ddpm_sample_paired(_eps(p, cfg), DIF, sched, shape3, y, seeds,
                                 gsc, null_label=cfg.n_classes, steps=4)
    for i in range(3):
        alone = ddpm_sample_paired(
            _eps(p, cfg), DIF, sched, (1,) + shape3[1:], y[i:i + 1],
            seeds[i:i + 1], gsc[i:i + 1], null_label=cfg.n_classes, steps=4)
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(alone[0]))


def test_guidance_one_matches_conditional_sampling(tiny_dit):
    """s=1 must reduce to eps_c: eps_u + 1*(eps_c - eps_u)."""
    cfg, p = tiny_dit
    sched = make_schedule(DIF)
    shape = (2, cfg.img_size, cfg.img_size, cfg.in_ch)
    y = jnp.asarray([3, 0], jnp.int32)
    out = ddpm_sample_paired(
        _eps(p, cfg), DIF, sched, shape, y, jnp.asarray([7, 8], jnp.uint32),
        jnp.ones((2,), jnp.float32), null_label=cfg.n_classes, steps=4)
    assert bool(jnp.all(jnp.isfinite(out)))
    # and s=0 is unconditional: labels must not matter
    out0a = ddpm_sample_paired(
        _eps(p, cfg), DIF, sched, shape, y, jnp.asarray([7, 8], jnp.uint32),
        jnp.zeros((2,), jnp.float32), null_label=cfg.n_classes, steps=4)
    out0b = ddpm_sample_paired(
        _eps(p, cfg), DIF, sched, shape, 1 - y,
        jnp.asarray([7, 8], jnp.uint32), jnp.zeros((2,), jnp.float32),
        null_label=cfg.n_classes, steps=4)
    np.testing.assert_allclose(np.asarray(out0a), np.asarray(out0b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_engine_fp_end_to_end(tiny_dit):
    cfg, p = tiny_dit
    sched = make_schedule(DIF)
    eng = ServeEngine(p, cfg, DIF, sched, mesh=_mesh11(), microbatch=2,
                      step_buckets=(4, 8))
    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=s,
                       cfg_scale=1.5, seed=50 + i)
            for i, s in enumerate([4, 4, 4, 8, 8])]
    res = eng.serve(reqs)
    assert sorted(res) == [0, 1, 2, 3, 4]
    assert res[0].steps == 4 and res[3].steps == 8
    # one compile per step bucket, padding only on the two bucket tails
    assert sorted(eng.stats["compiled_buckets"]) == [4, 8]
    assert eng.stats["microbatches"] == 3
    assert eng.stats["padded_slots"] == 1
    # engine result == calling the paired sampler directly
    direct = ddpm_sample_paired(
        _eps(p, cfg), DIF, sched, (2, cfg.img_size, cfg.img_size, cfg.in_ch),
        jnp.asarray([0, 1], jnp.int32), jnp.asarray([50, 51], jnp.uint32),
        jnp.full((2,), 1.5, jnp.float32), null_label=cfg.n_classes, steps=4)
    np.testing.assert_array_equal(res[0].sample, np.asarray(direct[0]))
    np.testing.assert_array_equal(res[1].sample, np.asarray(direct[1]))


def test_engine_microbatch_validation(tiny_dit):
    cfg, p = tiny_dit
    eng = ServeEngine(p, cfg, DIF, microbatch=2, step_buckets=(4,))
    with pytest.raises(ValueError, match="slots"):
        eng.run_microbatch(coalesce([GenRequest(0, 0, 4)], 4, (4,))[0])
    with pytest.raises(ValueError, match="buckets"):
        eng.run_microbatch(coalesce([GenRequest(0, 0, 8)], 2, (8,))[0])
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(p, cfg, DIF, mesh=_fake_mesh4(), microbatch=3,
                    step_buckets=(4,))


def _fake_mesh4():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 1)
    return FakeMesh()


def test_engine_w8a8_kernel_path(tiny_dit, monkeypatch):
    """Quantized serving through the engine: TGQ-packed fused int8 kernels
    fire under the shard_map'd scan, samples are finite, and mesh vs
    no-mesh execution is bit-identical."""
    from repro.kernels import ops as kops

    cfg, p = tiny_dit
    sched = make_schedule(DIF)
    art = quantize(p, cfg, DIF,
                   QuantRecipe(bits="w8a8", method="range", n_per_group=1,
                               calib_batch=1), sched=sched)
    qp2 = art.qparams
    n_pack = sum(1 for v in qp2.values() if "int8" in v or "int8_mrq" in v)
    assert n_pack >= 5, "range calibration must pack the DiT linears"
    assert any(v.get("int8", {}).get("groups") == DIF.tgq_groups
               for v in qp2.values()), "packs must be time-grouped"
    ctx = art.context()
    assert ctx.kernel, "w8a8 artifact must default to the kernel path"

    calls = []
    orig = kops.int8_matmul_fq
    monkeypatch.setattr(kops, "int8_matmul_fq",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])

    reqs = [GenRequest(request_id=i, label=i % cfg.n_classes, steps=4,
                       cfg_scale=1.5, seed=90 + i) for i in range(2)]
    eng = ServeEngine(p, cfg, DIF, sched, ctx=ctx, mesh=_mesh11(),
                      microbatch=2, step_buckets=(4,))
    res = eng.serve(reqs)
    assert len(calls) >= 1, "fused int8 kernel was not traced"
    s = np.stack([res[i].sample for i in range(2)])
    assert np.isfinite(s).all()

    eng_nomesh = ServeEngine(p, cfg, DIF, sched, ctx=ctx, microbatch=2,
                             step_buckets=(4,))
    res2 = eng_nomesh.serve(reqs)
    for i in range(2):
        np.testing.assert_array_equal(res[i].sample, res2[i].sample)


# ---------------------------------------------------------------------------
# multi-device: sharded w8a8 == single-device w8a8 (subprocess; this test
# process is pinned to 1 CPU device by conftest)
# ---------------------------------------------------------------------------
_SHARDED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == 2, jax.device_count()
from repro.diffusion import DiffusionCfg, make_schedule
from repro.models import DiTCfg, dit_init
from repro.quant import QuantRecipe, quantize
from repro.serving import GenRequest, ServeEngine

cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=32, n_layers=2,
             n_heads=4, n_classes=8)
p = dit_init(jax.random.PRNGKey(0), cfg)
p = jax.tree.map(
    lambda a: a + jax.random.normal(jax.random.PRNGKey(1), a.shape) * 0.01, p)
dif = DiffusionCfg(T=40, tgq_groups=4)
sched = make_schedule(dif)
art = quantize(p, cfg, dif, QuantRecipe(bits="w8a8", method="range",
                                        n_per_group=1, calib_batch=1),
               sched=sched)
reqs = [GenRequest(request_id=i, label=i % 8, steps=4, cfg_scale=1.5,
                   seed=300 + i) for i in range(4)]
out = {}
for nd in (2, 1):
    mesh = jax.make_mesh((nd, 1), ("data", "model"))
    eng = ServeEngine.from_artifact(p, art, sched=sched, mesh=mesh,
                                    microbatch=4, step_buckets=(4,))
    out[nd] = eng.serve(reqs)
ok = all(np.array_equal(out[2][i].sample, out[1][i].sample)
         for i in range(4))
print("IDENTICAL" if ok else "MISMATCH")
"""


def test_sharded_w8a8_identical_to_single_device():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "IDENTICAL" in r.stdout, (r.stdout, r.stderr[-2000:])


# ---------------------------------------------------------------------------
# modeled serving throughput floor (acceptance: >=1.5x at batch == n_dev)
# ---------------------------------------------------------------------------
def test_modeled_throughput_floor():
    from benchmarks.serve_throughput import XL2, modeled_requests_per_sec
    for n_dev in (4, 8):
        fp = modeled_requests_per_sec(XL2, n_dev, n_dev, 100, "fp")
        q8 = modeled_requests_per_sec(XL2, n_dev, n_dev, 100, "int8")
        qc = modeled_requests_per_sec(XL2, n_dev, n_dev, 100,
                                      "int8_composed")
        assert q8["req_per_s"] / fp["req_per_s"] >= 1.5
        # flash attention (the serving default) removes the modeled (S,S)
        # scores/codes round-trip — the honest end-to-end ratio must beat
        # the composed three-kernel path's ~1.9x
        assert qc["req_per_s"] / fp["req_per_s"] >= 1.5
        assert q8["req_per_s"] > qc["req_per_s"]
        assert q8["req_per_s"] / fp["req_per_s"] >= 1.9
