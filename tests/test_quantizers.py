"""Unit + property tests for the quantizer primitives (§III-C math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; the rest of the module runs
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass
            skipped.__name__ = f.__name__
            return skipped
        return deco

    def settings(*_a, **_k):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.core.quantizers import (
    ChannelQ, MRQSignedQ, MRQSoftmaxQ, TGQ, UniformQ,
    channel_scale_from_absmax, mrq_signed_qdq, mrq_softmax_qdq, symmetric_qdq,
    uniform_params_from_range, uniform_qdq, weight_absmax,
)

BITS = (8, 6, 4)


# ---------------------------------------------------------------------------
# uniform affine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", BITS)
def test_uniform_roundtrip_error_bound(bits):
    x = jnp.linspace(-3.0, 5.0, 1001)
    s, z = uniform_params_from_range(x.min(), x.max(), bits)
    xh = uniform_qdq(x, s, z, bits)
    assert float(jnp.max(jnp.abs(xh - x))) <= float(s) / 2 + 1e-6


@pytest.mark.parametrize("bits", BITS)
def test_uniform_idempotent(bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 2
    s, z = uniform_params_from_range(x.min(), x.max(), bits)
    x1 = uniform_qdq(x, s, z, bits)
    x2 = uniform_qdq(x1, s, z, bits)
    np.testing.assert_allclose(x1, x2, atol=1e-6)


@given(lo=st.floats(-10, -0.01), hi=st.floats(0.01, 10),
       bits=st.sampled_from(BITS))
@settings(max_examples=30, deadline=None)
def test_uniform_grid_size(lo, hi, bits):
    """At most 2^k distinct output values (k-bit code)."""
    x = jnp.linspace(lo, hi, 4097)
    s, z = uniform_params_from_range(jnp.float32(lo), jnp.float32(hi), bits)
    xh = np.unique(np.asarray(uniform_qdq(x, s, z, bits)))
    assert len(xh) <= 2 ** bits


def test_symmetric_odd():
    x = jnp.linspace(-0.9, 0.9, 101)
    xh = symmetric_qdq(x, 0.01, 8)
    np.testing.assert_allclose(xh, -symmetric_qdq(-x, 0.01, 8), atol=1e-7)


# ---------------------------------------------------------------------------
# MRQ softmax (two-region)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", BITS)
def test_mrq_softmax_small_value_resolution(bits):
    """The whole point of MRQ: near-zero probs keep resolution s1 << s2."""
    half = 2 ** (bits - 1)
    s1 = 1.0 / (half * half)                  # much finer than 1/half
    # interior of R1 (the boundary cell [half-1, half)*s1 rounds up into R2)
    small = jnp.linspace(0, (half - 1) * s1 * 0.99, 100)
    err_mrq = jnp.abs(mrq_softmax_qdq(small, s1, bits) - small)
    s_uni, z_uni = uniform_params_from_range(
        jnp.float32(0), jnp.float32(1), bits)
    err_uni = jnp.abs(uniform_qdq(small, s_uni, z_uni, bits) - small)
    assert float(err_mrq.max()) <= s1 / 2 + 1e-7
    assert float(err_mrq.mean()) < float(err_uni.mean())


@pytest.mark.parametrize("bits", BITS)
def test_mrq_softmax_range(bits):
    x = jnp.linspace(0, 1, 1001)
    half = 2 ** (bits - 1)
    xh = mrq_softmax_qdq(x, 0.3 / half, bits)
    assert float(xh.min()) >= 0.0
    assert float(xh.max()) <= 1.0 + 1e-6
    # large values use the fixed step s2 = 1/half
    big = x[x > 0.5]
    err_big = jnp.abs(mrq_softmax_qdq(big, 0.3 / half, bits) - big)
    assert float(err_big.max()) <= (1.0 / half) / 2 + 1e-6


@given(s1=st.floats(1e-5, 3e-3), bits=st.sampled_from(BITS))
@settings(max_examples=20, deadline=None)
def test_mrq_softmax_monotone_within_regions(s1, bits):
    """Monotone within each region; the R1/R2 seam may step by <= s2/2
    (inherent to the two-region construction — region is picked by
    threshold, not by best representation)."""
    half = 2 ** (bits - 1)
    thr = half * s1
    x = jnp.linspace(0, 1, 2049)
    xh = np.asarray(mrq_softmax_qdq(x, s1, bits))
    xn = np.asarray(x)
    for region in (xn < thr, xn >= thr):
        if region.sum() > 1:
            assert np.all(np.diff(xh[region]) >= -1e-7)
    assert np.all(np.diff(xh) >= -(1.0 / half) / 2 - 1e-7)


# ---------------------------------------------------------------------------
# MRQ signed (post-GELU/SiLU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", BITS)
def test_mrq_signed_sign_and_bounds(bits):
    x = jnp.linspace(-0.5, 6.0, 1001)
    g = jax.nn.gelu(x)                         # bounded negative lobe
    s_neg = float(-g.min()) / 2 ** (bits - 1)
    s_pos = float(g.max()) / 2 ** (bits - 1)
    gh = mrq_signed_qdq(g, s_neg, s_pos, bits)
    assert float((gh * g < -1e-9).sum()) == 0          # sign preserved
    err_neg = jnp.abs(gh - g)[g < 0]
    assert float(err_neg.max()) <= s_neg / 2 + 1e-6    # fine negative grid


def test_mrq_signed_beats_symmetric_uniform_on_gelu():
    """With SEARCHED step sizes (as Algorithm 1 does) MRQ dominates searched
    SYMMETRIC uniform quantization — the hardware-relevant single-scale
    format for MXU matmul inputs — on a post-GELU distribution (paper Fig
    2b). (Against asymmetric uniform WITH a zero point the gap closes;
    MRQ's value is fine negative resolution without zero-point machinery.
    Measured and noted in DESIGN.md.)"""
    from repro.core.quantizers import symmetric_qdq
    x = jax.random.normal(jax.random.PRNGKey(1), (16384,)) * 0.5
    g = np.asarray(jax.nn.gelu(x))
    bits = 6
    half = 2 ** (bits - 1)
    alphas = np.linspace(0.2, 1.15, 16)

    neg0, pos0 = -g.min() / half, g.max() / half
    mrq_err = min(
        float(np.mean((np.asarray(mrq_signed_qdq(g, a * neg0, b * pos0,
                                                 bits)) - g) ** 2))
        for a in alphas for b in alphas)
    sym_err = min(
        float(np.mean((np.asarray(
            symmetric_qdq(g, a * np.abs(g).max() / (half - 1), bits))
            - g) ** 2))
        for a in alphas)
    assert mrq_err < sym_err


# ---------------------------------------------------------------------------
# per-channel weights + TGQ
# ---------------------------------------------------------------------------
def test_channel_quant_per_channel_scales():
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    w = w * jnp.logspace(-2, 1, 16)[None, :]           # wildly varying columns
    q_pc = ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8)
    s_pt = channel_scale_from_absmax(jnp.max(jnp.abs(w)), 8)
    err_pc = jnp.mean((q_pc(w) - w) ** 2)
    err_pt = jnp.mean((symmetric_qdq(w, s_pt, 8) - w) ** 2)
    assert float(err_pc) < float(err_pt)


def test_tgq_group_selection():
    qs = TGQ(inner=MRQSoftmaxQ(s1=jnp.array([1e-4, 1e-3, 1e-2]), bits=8))
    x = jnp.linspace(0, 0.01, 64)
    outs = [np.asarray(qs(x, g)) for g in range(3)]
    assert not np.allclose(outs[0], outs[1])
    assert not np.allclose(outs[1], outs[2])
    # traced group index works under jit
    f = jax.jit(lambda g: qs(x, g))
    np.testing.assert_allclose(f(jnp.int32(1)), outs[1], atol=1e-7)


def test_quantizers_are_pytrees():
    qs = [UniformQ(jnp.float32(0.1), jnp.float32(3), 8),
          ChannelQ(jnp.ones((1, 4)), 8),
          MRQSoftmaxQ(jnp.float32(1e-3), 8),
          MRQSignedQ(jnp.float32(1e-3), jnp.float32(2e-3), 8),
          TGQ(MRQSoftmaxQ(jnp.ones(4) * 1e-3, 8))]
    for q in qs:
        leaves = jax.tree.leaves(q)
        assert len(leaves) >= 1
        q2 = jax.tree.map(lambda a: a, q)
        assert type(q2) is type(q)
