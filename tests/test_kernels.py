"""Pallas kernels vs pure-jnp oracles: shape/dtype/bits sweeps in
interpret mode (kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import act_mrq, int8_matmul, softmax_mrq
from repro.kernels import ops, ref


MM_SHAPES = [(8, 16, 8), (64, 96, 80), (128, 256, 128), (7, 13, 5),
             (130, 257, 129), (256, 512, 384)]


@pytest.mark.parametrize("shape", MM_SHAPES)
def test_int8_matmul_vs_ref(shape):
    M, K, N = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(M * K + N))
    xq = jax.random.randint(k1, (M, K), -128, 128, jnp.int32).astype(jnp.int8)
    wq = jax.random.randint(k2, (K, N), -128, 128, jnp.int32).astype(jnp.int8)
    scale = jax.random.uniform(k1, (N,)) * 0.01 + 1e-4
    corr = 3 * jnp.sum(wq.astype(jnp.int32), axis=0)
    bias = jax.random.normal(k2, (N,))
    out = int8_matmul(xq, wq, scale, corr, bias, interpret=True)
    want = ref.int8_matmul_ref(xq, wq, scale, corr, bias)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("block", [(32, 64, 64), (128, 128, 256)])
def test_int8_matmul_block_shapes(block):
    bm, bn, bk = block
    xq = jax.random.randint(jax.random.PRNGKey(0), (100, 300), -128, 128,
                            jnp.int32).astype(jnp.int8)
    wq = jax.random.randint(jax.random.PRNGKey(1), (300, 90), -128, 128,
                            jnp.int32).astype(jnp.int8)
    scale = jnp.full((90,), 1e-3)
    corr = jnp.zeros((90,), jnp.int32)
    out = int8_matmul(xq, wq, scale, corr, bm=bm, bn=bn, bk=bk,
                      interpret=True)
    want = ref.int8_matmul_ref(xq, wq, scale, corr)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_out_dtype(out_dtype):
    xq = jnp.ones((16, 32), jnp.int8)
    wq = jnp.ones((32, 16), jnp.int8)
    out = int8_matmul(xq, wq, jnp.ones(16) * 0.5, jnp.zeros(16, jnp.int32),
                      out_dtype=out_dtype, interpret=True)
    assert out.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(out, np.float32), 16.0)


SM_SHAPES = [(4, 16), (2, 3, 64), (2, 4, 8, 32), (5, 100)]


@pytest.mark.parametrize("shape", SM_SHAPES)
@pytest.mark.parametrize("bits", [8, 6])
def test_softmax_mrq_vs_ref(shape, bits):
    s = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape) * 4
    s1 = 0.25 / 2 ** (bits - 1)
    out = softmax_mrq(s, s1, bits=bits, interpret=True)
    want = ref.softmax_mrq_ref(s, s1, bits)
    np.testing.assert_allclose(out, want, atol=1e-6)


@pytest.mark.parametrize("kind", ["gelu", "silu"])
@pytest.mark.parametrize("bits", [8, 6])
@pytest.mark.parametrize("shape", [(16, 100), (3, 5, 130), (64, 512),
                                   (2048, 1024)])
def test_act_mrq_vs_ref(kind, bits, shape):
    x = jax.random.normal(jax.random.PRNGKey(bits), shape) * 3
    out = np.asarray(act_mrq(x, 0.005, 0.03, bits=bits, kind=kind,
                             interpret=True))
    want = np.asarray(ref.act_mrq_ref(x, 0.005, 0.03, bits, kind))
    # a 1-ulp difference in the activation can flip a round-half-even
    # boundary -> allow one-step error on a vanishing fraction of elements
    diff = np.abs(out - want)
    assert diff.max() <= 0.03 + 1e-6
    assert (diff > 1e-6).mean() < 1e-4


def test_quantize_int8_codes_signed():
    x = jnp.linspace(-1, 1, 101)
    s = jnp.float32(2.0 / 255)
    z = jnp.round(-(-1.0) / s)
    q = ops.quantize_int8(x, s, z)
    assert q.dtype == jnp.int8
    deq = (q.astype(jnp.float32) - (z - 128)) * s
    assert float(jnp.abs(deq - x).max()) <= float(s) / 2 + 1e-6


def test_int8_linear_matches_fakequant():
    from repro.core.contexts import QuantContext
    from repro.core.quantizers import (ChannelQ, UniformQ,
                                       channel_scale_from_absmax,
                                       uniform_params_from_range,
                                       weight_absmax)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 17, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48)) * 0.05
    s, z = uniform_params_from_range(x.min(), x.max(), 8)
    qp = {"lin": {
        "x": UniformQ(s, z, 8),
        "w": ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8),
    }}
    y_fake = QuantContext(qparams=qp).linear("lin", x, w)
    qp2 = ops.convert_for_kernels(qp, {"lin": np.asarray(w)})
    assert "int8" in qp2["lin"]
    y_kern = QuantContext(qparams=qp2, kernel=True).linear("lin", x, w)
    np.testing.assert_allclose(y_fake, y_kern, rtol=1e-4, atol=1e-4)


def test_mrq_input_ops_not_packed():
    """MRQ-input linears must stay on the fake-quant path (two-region codes
    do not fold into one MXU scale)."""
    from repro.core.quantizers import MRQSignedQ, ChannelQ
    qp = {"fc2": {"x": MRQSignedQ(jnp.float32(1e-3), jnp.float32(2e-3), 8),
                  "w": ChannelQ(jnp.ones((1, 8)), 8)}}
    out = ops.convert_for_kernels(qp, {"fc2": np.ones((4, 8), np.float32)})
    assert "int8" not in out["fc2"]


def test_int8_linear_mrq_matches_fakequant():
    """MRQ-input linears deploy as two masked int8 matmuls (DESIGN §4)."""
    from repro.core.contexts import QuantContext
    from repro.core.quantizers import (ChannelQ, MRQSignedQ,
                                       channel_scale_from_absmax,
                                       weight_absmax)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 9, 48))
    g = jax.nn.gelu(x)                                   # MRQ-shaped input
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 32)) * 0.05
    qx = MRQSignedQ(s_neg=jnp.float32(-float(g.min()) / 128),
                    s_pos=jnp.float32(float(g.max()) / 128), bits=8)
    qw = ChannelQ(channel_scale_from_absmax(weight_absmax(w), 8), 8)
    qp = {"fc2": {"x": qx, "w": qw}}
    y_fake = QuantContext(qparams=qp).linear("fc2", g, w)
    qp2 = ops.convert_for_kernels(qp, {"fc2": np.asarray(w)})
    assert "int8_mrq" in qp2["fc2"]
    y_kern = QuantContext(qparams=qp2, kernel=True).linear("fc2", g, w)
    np.testing.assert_allclose(np.asarray(y_fake), np.asarray(y_kern),
                               rtol=1e-3, atol=2e-3)
