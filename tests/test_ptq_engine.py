"""PTQ engine integration: op discovery, calibration capture, fisher
alignment, HO search, TGQ grouping, and the Table-III ablation ordering
on a tiny DiT."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CalibrationContext, PTQConfig, QuantContext, RecordingContext,
    build_dit_calibration, dit_loss_fn, run_ptq,
)
from repro.core.baselines import SCHEMES
from repro.core.fisher import discover_tap_shapes, make_fisher_fn
from repro.core.quantizers import TGQ
from repro.diffusion import DiffusionCfg, make_schedule
from repro.models import dit_apply


@pytest.fixture(scope="module")
def dit_setup(tiny_dit):
    cfg, p = tiny_dit
    dif = DiffusionCfg(T=100, tgq_groups=4)
    sched = make_schedule(dif)
    x0 = lambda n, k: jax.random.normal(k, (n, 8, 8, 4))
    calib = build_dit_calibration(p, cfg, dif, sched, x0,
                                  jax.random.PRNGKey(3), n_per_group=8,
                                  batch=4)
    return cfg, p, dif, sched, calib


def test_recording_discovers_ops_and_provenance(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    rec = RecordingContext()
    dit_loss_fn(p, cfg)(rec, calib[0][0])
    names = set(rec.registry)
    assert "blk0/qkv" in names and "blk1/fc2" in names
    assert rec.registry["blk0/attn/pv"].a_kind == "post_softmax"
    assert rec.registry["blk0/fc2"].a_kind == "post_gelu"
    assert rec.registry["blk0/attn/qk"].a_kind == "plain"
    assert rec.registry["blk0/attn/pv"].kind == "einsum"


def test_fisher_taps_match_finite_difference(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    loss = dit_loss_fn(p, cfg)
    batch = calib[0][0]
    shapes = discover_tap_shapes(loss, batch)
    fisher = make_fisher_fn(loss, shapes)
    g = fisher(batch)
    name = "blk0/fc1"
    # finite difference on a single tap coordinate
    from repro.core.contexts import TapContext
    taps0 = {n: jnp.zeros(s, d) for n, (s, d) in shapes.items()}
    eps = 1e-3
    idx = (0, 3, 5)
    tp = dict(taps0)
    tp[name] = taps0[name].at[idx].set(eps)
    tm = dict(taps0)
    tm[name] = taps0[name].at[idx].set(-eps)
    lp = float(loss(TapContext(taps=tp), batch))
    lm = float(loss(TapContext(taps=tm), batch))
    fd = (lp - lm) / (2 * eps)
    np.testing.assert_allclose(float(g[name][idx]), fd, rtol=0.05, atol=1e-5)


def test_tgq_params_are_grouped(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    qp, _ = run_ptq(dit_loss_fn(p, cfg), calib,
                    PTQConfig(tgq_groups=4, n_alpha=6, rounds=1))
    pv = qp["blk0/attn/pv"]
    assert isinstance(pv["x"], TGQ)
    assert pv["x"].inner.s1.shape == (4,)


def test_quant_context_skips_unquantized_ops(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    ctx = QuantContext(qparams={})
    b = calib[0][0]
    fp = dit_apply(p, cfg, b["xt"], b["t"], b["y"])
    q = dit_apply(p, cfg, b["xt"], b["t"], b["y"], ctx=ctx)
    np.testing.assert_allclose(fp, q, atol=1e-6)


@pytest.mark.slow
def test_ablation_ordering_w6a6(dit_setup):
    """Table III: baseline >= +HO >= +HO+MRQ >= TQ-DiT in quantized-output
    error (allowing small noise at this toy scale)."""
    cfg, p, dif, sched, calib = dit_setup
    loss = dit_loss_fn(p, cfg)
    evalb = build_dit_calibration(p, cfg, dif, sched,
                                  lambda n, k: jax.random.normal(k, (n, 8, 8, 4)),
                                  jax.random.PRNGKey(77), n_per_group=8,
                                  batch=8)

    def eval_mse(qp):
        ctx = QuantContext(qparams=qp)
        tot = 0.0
        for b, g in evalb:
            fp = dit_apply(p, cfg, b["xt"], b["t"], b["y"])
            qt = dit_apply(p, cfg, b["xt"], b["t"], b["y"],
                           ctx=ctx.with_tgroup(g))
            tot += float(jnp.mean((fp - qt) ** 2))
        return tot / len(evalb)

    errs = {}
    for name in ["baseline", "+HO", "+HO+MRQ", "tq_dit"]:
        qcfg = SCHEMES[name](6, 6, tgq_groups=4, n_alpha=8, rounds=2)
        qp, _ = run_ptq(loss, calib, qcfg)
        errs[name] = eval_mse(qp)
    assert errs["tq_dit"] <= errs["baseline"] * 1.05
    assert errs["+HO+MRQ"] <= errs["baseline"] * 1.05


def test_w8a8_much_better_than_w4a4(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    loss = dit_loss_fn(p, cfg)
    b = calib[0][0]
    fp = dit_apply(p, cfg, b["xt"], b["t"], b["y"])

    def err(bits):
        qp, _ = run_ptq(loss, calib[:4],
                        PTQConfig(wbits=bits, abits=bits, tgq_groups=4,
                                  n_alpha=6, rounds=1))
        ctx = QuantContext(qparams=qp).with_tgroup(calib[0][1])
        q = dit_apply(p, cfg, b["xt"], b["t"], b["y"], ctx=ctx)
        return float(jnp.mean((fp - q) ** 2))

    assert err(8) < err(4)


def test_bias_correction_reduces_mean_shift(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    loss = dit_loss_fn(p, cfg)
    qp_plain, _ = run_ptq(loss, calib[:4],
                          PTQConfig(wbits=4, abits=4, use_fisher=False,
                                    use_mrq=False, use_tgq=False, n_alpha=6,
                                    rounds=1))
    qp_bc, _ = run_ptq(loss, calib[:4],
                       PTQConfig(wbits=4, abits=4, use_fisher=False,
                                 use_mrq=False, use_tgq=False,
                                 bias_correct=True, n_alpha=6, rounds=1))
    assert any("out_bias" in v for v in qp_bc.values())
    b = calib[0][0]
    fp = dit_apply(p, cfg, b["xt"], b["t"], b["y"])
    q1 = dit_apply(p, cfg, b["xt"], b["t"], b["y"],
                   ctx=QuantContext(qparams=qp_plain))
    q2 = dit_apply(p, cfg, b["xt"], b["t"], b["y"],
                   ctx=QuantContext(qparams=qp_bc))
    # bias correction should not hurt the mean error
    assert abs(float((q2 - fp).mean())) <= abs(float((q1 - fp).mean())) + 1e-4


def test_channel_balance_sets_prescale(dit_setup):
    cfg, p, dif, sched, calib = dit_setup
    qp, _ = run_ptq(dit_loss_fn(p, cfg), calib[:4],
                    PTQConfig(channel_balance=True, use_mrq=False,
                              use_tgq=False, n_alpha=6, rounds=1))
    assert any("x_prescale" in v and v["x_prescale"] is not None
               for v in qp.values())
