"""Pointwise-feedforward layers: dense MLP (GELU / SwiGLU) and MoE.

The MoE uses a sort-based "dropping" dispatch (argsort tokens by expert,
capacity-truncated, batched expert matmuls) — the production JAX pattern
whose cost is dominated by expert FLOPs, unlike dense one-hot dispatch
whose dispatch einsum would dominate at hundreds of experts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.ctx import FPContext
from repro.nn.layers import linear_init

_FP = FPContext()


# --------------------------------------------------------------------------
# Dense MLP
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLPCfg:
    d_model: int
    d_ff: int
    act: str = "swiglu"          # 'gelu' | 'swiglu'
    bias: bool = False


def mlp_init(key, cfg: MLPCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {
            "fc1": linear_init(ks[0], cfg.d_model, cfg.d_ff, bias=cfg.bias, dtype=dtype),
            "fc2": linear_init(ks[1], cfg.d_ff, cfg.d_model, bias=cfg.bias, dtype=dtype),
        }
    return {
        "gate": linear_init(ks[0], cfg.d_model, cfg.d_ff, bias=cfg.bias, dtype=dtype),
        "up": linear_init(ks[1], cfg.d_model, cfg.d_ff, bias=cfg.bias, dtype=dtype),
        "down": linear_init(ks[2], cfg.d_ff, cfg.d_model, bias=cfg.bias, dtype=dtype),
    }


def mlp_apply(p, cfg: MLPCfg, x, *, ctx=_FP, name="mlp"):
    if cfg.act == "gelu":
        h = ctx.linear(f"{name}/fc1", x, p["fc1"]["w"], p["fc1"].get("b"))
        h = jax.nn.gelu(h, approximate=True)
        h = ctx.act(f"{name}/gelu", h, "post_gelu")
        return ctx.linear(f"{name}/fc2", h, p["fc2"]["w"], p["fc2"].get("b"))
    g = ctx.linear(f"{name}/gate", x, p["gate"]["w"], p["gate"].get("b"))
    u = ctx.linear(f"{name}/up", x, p["up"]["w"], p["up"].get("b"))
    g = jax.nn.silu(g)
    g = ctx.act(f"{name}/silu", g, "post_silu")
    return ctx.linear(f"{name}/down", g * u, p["down"]["w"], p["down"].get("b"))


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_expert: int                # per-expert hidden dim
    n_experts: int               # routed experts
    top_k: int
    n_shared: int = 0            # shared experts (each of size d_expert)
    capacity_factor: float = 1.25
    groups: int = 1              # dispatch groups; set = dp shards so the
                                 # group axis shards cleanly on ("pod","data")
    act: str = "swiglu"
    norm_topk: bool = True       # renormalize top-k gates to sum 1
    aux_loss_coef: float = 0.01
    # EP dispatch sharding constraint (batch_axes, ep_axis): pins the
    # (G, E, C, d) expert buffer to G@batch_axes x E@ep_axis — the
    # all-to-all token-routing layout — instead of leaving GSPMD to
    # resolve the scatter with giant cross-device collectives. Set by the
    # launch layer when groups == dp size.
    shard_spec: Optional[tuple] = None


def moe_init(key, cfg: MoECfg, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert
    w = init.normal(0.02)
    p = {
        "router": {"w": w(ks[0], (d, E), jnp.float32)},   # router kept fp32
        "gate": w(ks[1], (E, d, f), dtype),
        "up": w(ks[2], (E, d, f), dtype),
        "down": w(ks[3], (E, f, d), dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(
            ks[4], MLPCfg(d, cfg.n_shared * f, act=cfg.act), dtype)
    return p


def _capacity(n_tokens: int, cfg: MoECfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    # round up to a multiple of 8 for TPU-friendly layouts; floor at top_k.
    c = max(c, cfg.top_k, 1)
    return int(-8 * (-c // 8))


def moe_apply(p, cfg: MoECfg, x, *, ctx=_FP, name="moe"):
    """x: (B, S, d) -> (y, aux) with aux = {'aux_loss', 'router_z'}.

    Dispatch: tokens grouped into ``cfg.groups`` groups; within each group
    tokens are argsorted by expert id, capacity-truncated, gathered into an
    (E, C) buffer, run through batched expert matmuls, and combined back
    with top-k gate weights. Dropped tokens fall through via the shared
    experts / residual (standard dropping semantics).
    """
    B, S, d = x.shape
    E, K, G = cfg.n_experts, cfg.top_k, cfg.groups
    T = B * S
    assert T % G == 0, f"tokens {T} not divisible by moe groups {G}"
    N = T // G
    xt = x.reshape(G, N, d)

    # ---- routing (fp32) ----------------------------------------------------
    logits = ctx.linear(f"{name}/router", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,N,E)
    gates, eidx = jax.lax.top_k(probs, K)                       # (G,N,K)
    if cfg.norm_topk:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch-style) + router z-loss
    me = jnp.mean(probs, axis=1)                                # (G,E)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=2), axis=1)
    aux_loss = cfg.aux_loss_coef * E * jnp.mean(jnp.sum(me * ce, axis=-1))
    router_z = 1e-3 * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch -------------------------------------------------
    C = _capacity(N, cfg)
    slot_expert = eidx.reshape(G, N * K)                        # slot = token*K + j
    order = jnp.argsort(slot_expert, axis=1, stable=True)       # (G,NK)
    sorted_expert = jnp.take_along_axis(slot_expert, order, axis=1)
    # rank of each sorted slot within its expert run
    counts = jax.vmap(lambda se: jnp.bincount(se, length=E))(sorted_expert)
    seg_start = jnp.cumsum(counts, axis=1) - counts             # (G,E)
    rank = (jnp.arange(N * K)[None, :]
            - jnp.take_along_axis(seg_start, sorted_expert, axis=1))
    keep = rank < C
    dest = jnp.where(keep, sorted_expert * C + rank, E * C)     # E*C = trash slot

    # scatter tokens into (E*C [+1 trash]) buffer
    token_of_sorted = order // K                                # (G,NK)
    src = jnp.take_along_axis(xt, token_of_sorted[..., None], axis=1)  # (G,NK,d)
    if cfg.shard_spec is not None:
        from jax.sharding import PartitionSpec as _P
        bt, ep = cfg.shard_spec
        src = jax.lax.with_sharding_constraint(src, _P(bt, None, None))
    buf = jnp.zeros((G, E * C + 1, d), x.dtype).at[
        jnp.arange(G)[:, None], dest].set(src, mode="drop")
    xb = buf[:, : E * C].reshape(G, E, C, d)
    if cfg.shard_spec is not None:
        # pin the all-to-all routing layout: groups on the DP axes, experts
        # on the EP axis (tokens cross devices exactly once).
        xb = jax.lax.with_sharding_constraint(
            xb, _P(cfg.shard_spec[0], cfg.shard_spec[1], None, None))

    # ---- expert computation (batched over E) --------------------------------
    if cfg.act == "swiglu":
        g = ctx.einsum(f"{name}/gate", "gecd,edf->gecf", xb, p["gate"], b_is_weight=True)
        u = ctx.einsum(f"{name}/up", "gecd,edf->gecf", xb, p["up"], b_is_weight=True)
        g = jax.nn.silu(g)
        g = ctx.act(f"{name}/silu", g, "post_silu")
        h = g * u
    else:
        h = ctx.einsum(f"{name}/gate", "gecd,edf->gecf", xb, p["gate"], b_is_weight=True)
        h = jax.nn.gelu(h, approximate=True)
        h = ctx.act(f"{name}/gelu", h, "post_gelu")
    yb = ctx.einsum(f"{name}/down", "gecf,efd->gecd", h, p["down"], b_is_weight=True)
    if cfg.shard_spec is not None:
        from jax.sharding import PartitionSpec as _P
        yb = jax.lax.with_sharding_constraint(
            yb, _P(cfg.shard_spec[0], cfg.shard_spec[1], None, None))
    yb = yb.reshape(G, E * C, d)

    # ---- combine -------------------------------------------------------------
    # invert the sort permutation: dest_by_slot[g, slot] = buffer position
    dest_by_slot = jnp.zeros((G, N * K), jnp.int32).at[
        jnp.arange(G)[:, None], order].set(dest.astype(jnp.int32))
    slot_ok = dest_by_slot < E * C
    y_slot = jnp.take_along_axis(
        yb, jnp.minimum(dest_by_slot, E * C - 1)[..., None], axis=1)  # (G,NK,d)
    y_slot = jnp.where(slot_ok[..., None], y_slot, 0.0)
    gk = gates.reshape(G, N * K).astype(x.dtype)
    y = jnp.sum((y_slot * gk[..., None]).reshape(G, N, K, d), axis=2)

    if cfg.n_shared:
        y = y + mlp_apply(p["shared"], MLPCfg(d, cfg.n_shared * cfg.d_expert,
                                              act=cfg.act),
                          xt, ctx=ctx, name=f"{name}/shared")
    y = y.reshape(B, S, d)
    return y, {"aux_loss": aux_loss, "router_z": router_z}
