"""Core layers: linear, embedding, norms, RoPE, positional/timestep embeds."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import initializers as init
from repro.nn.ctx import FPContext

_FP = FPContext()


# --------------------------------------------------------------------------
# Linear / Embedding
# --------------------------------------------------------------------------
def linear_init(key, d_in, d_out, bias=True, dtype=jnp.float32, w_init=None):
    w_init = w_init or init.normal(0.02)
    p = {"w": w_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(p, x, ctx=_FP, name="linear"):
    return ctx.linear(name, x, p["w"], p.get("b"))


def embedding_init(key, vocab, d, dtype=jnp.float32, stddev=0.02):
    return {"emb": init.normal(stddev)(key, (vocab, d), dtype)}


def embedding_apply(p, ids):
    return jnp.take(p["emb"], ids, axis=0)


def embedding_logits(p, x, ctx=_FP, name="lm_head"):
    """Tied-embedding output projection."""
    return ctx.linear(name, x, p["emb"].T)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def layernorm_init(key, d, dtype=jnp.float32, affine=True):
    if not affine:
        return {}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p, x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if p:
        y = y * p["scale"] + p["bias"]
    return y


def rmsnorm_init(key, d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"]


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim, theta=10000.0):
    """Inverse frequencies for RoPE; shape (head_dim//2,)."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def rope_apply(x, positions, inv_freq):
    """Apply rotary embedding.

    x: (..., S, n_heads, head_dim); positions: (..., S) int32.
    Uses the "split-half" convention (GPT-NeoX / llama style).
    """
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# DiT positional / conditioning embeddings
# --------------------------------------------------------------------------
def sincos_2d(d, grid_h, grid_w):
    """Fixed 2D sin-cos positional embedding, (grid_h*grid_w, d)."""
    assert d % 4 == 0
    def _1d(dim, pos):
        omega = 1.0 / 10000 ** (np.arange(dim // 2, dtype=np.float64) / (dim / 2.0))
        out = np.einsum("p,f->pf", pos, omega)
        return np.concatenate([np.sin(out), np.cos(out)], axis=1)
    gh = np.arange(grid_h, dtype=np.float64)
    gw = np.arange(grid_w, dtype=np.float64)
    eh = _1d(d // 2, np.repeat(gh, grid_w))
    ew = _1d(d // 2, np.tile(gw, grid_h))
    return jnp.asarray(np.concatenate([eh, ew], axis=1), dtype=jnp.float32)


def timestep_embedding(t, d, max_period=10000.0):
    """DDPM sinusoidal timestep embedding. t: (B,) -> (B, d)."""
    half = d // 2
    freqs = jnp.exp(-np.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    emb = jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)
    if d % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb
