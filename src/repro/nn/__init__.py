"""Minimal functional NN substrate (no flax dependency).

Modules are plain functions: ``*_init(key, ...) -> params`` returning a
pytree of arrays, and ``*_apply(params, x, ...) -> y``. Every matmul-like
op and every quantization-relevant activation routes through an
:class:`~repro.nn.ctx.OpContext`, which is the interception point used by
the TQ-DiT PTQ engine (calibration capture, fake-quant, int8 kernels).
"""
from repro.nn.ctx import OpContext, FPContext
from repro.nn import initializers
from repro.nn.layers import (
    linear_init, linear_apply,
    embedding_init, embedding_apply,
    layernorm_init, layernorm_apply,
    rmsnorm_init, rmsnorm_apply,
    rope_freqs, rope_apply,
    sincos_2d, timestep_embedding,
)
from repro.nn.attention import attention_init, attention_apply, mla_init, mla_apply
from repro.nn.mlp import mlp_init, mlp_apply, moe_init, moe_apply
from repro.nn.ssm import ssd_init, ssd_apply
