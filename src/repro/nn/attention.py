"""Attention layers: GQA (plain / q-chunked / windowed / decode) and MLA.

All quantization-relevant matmuls route through the OpContext:
  - ``{name}/qk``  : Q·K^T          (activation × activation)
  - ``{name}/pv``  : P·V            (post-softmax activation × activation)
  - ``{name}/{q,k,v,o,...}`` : the projections (activation × weight)
and the post-softmax probabilities pass through ``ctx.act(..., 'post_softmax')``
— the tensor TQ-DiT's MRQ + TGQ quantize.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.ctx import FPContext, NEG_INF
from repro.nn.layers import linear_init, rmsnorm_init, rmsnorm_apply, rope_freqs, rope_apply

_FP = FPContext()


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    window: Optional[int] = None        # sliding-window size (None = global)
    q_chunk: int = 512                  # q-tile for the chunked impl
    out_bias: bool = False
    n_meta: int = 0                     # learnable prefix (meta) tokens (hymba)
    # sequence-parallel attention: (batch_axes, seq_axis) mesh names, e.g.
    # (("data",), "model"). Shards the q/scores/probs SEQ dim over the TP
    # axis — the cure for head counts that do not divide the TP degree,
    # where GSPMD otherwise all-reduces the quadratic (S,S) scores
    # (measured: qwen2.5-14b train, DESIGN §7). Set by the launch layer.
    sp_spec: Optional[tuple] = None


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def attention_init(key, cfg: AttnCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    H, Hk, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "q": linear_init(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": linear_init(ks[1], d, Hk * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": linear_init(ks[2], d, Hk * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": linear_init(ks[3], H * hd, d, bias=cfg.out_bias, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(ks[4], hd, dtype)
        p["k_norm"] = rmsnorm_init(ks[5], hd, dtype)
    if cfg.n_meta:
        p["meta"] = init.normal(0.02)(ks[6], (cfg.n_meta, d), dtype)
    return p


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _project_qkv(p, cfg, x, kv_x, positions, kv_positions, ctx, name):
    """Project and shape q:(B,S,Hk,G,hd) k,v:(B,Skv,Hk,hd); apply rope/norm."""
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hk
    q = ctx.linear(f"{name}/q", x, p["q"]["w"], p["q"].get("b"))
    k = ctx.linear(f"{name}/k", kv_x, p["k"]["w"], p["k"].get("b"))
    v = ctx.linear(f"{name}/v", kv_x, p["v"]["w"], p["v"].get("b"))
    q = q.reshape(B, S, Hk * G, hd)
    k = k.reshape(B, kv_x.shape[1], Hk, hd)
    v = v.reshape(B, kv_x.shape[1], Hk, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    if cfg.rope:
        inv = rope_freqs(hd, cfg.rope_theta)
        q = rope_apply(q, positions, inv)
        k = rope_apply(k, kv_positions, inv)
    q = q.reshape(B, S, Hk, G, hd)
    return q, k, v


def _sdpa(q, k, v, mask, ctx, name, scale):
    """Grouped scaled-dot-product attention.

    q: (B,Sq,Hk,G,hd); k,v: (B,Skv,Hk,hd); mask: broadcastable to
    (B,Hk,G,Sq,Skv) boolean (True = attend) or None.

    The body lives on the context's ``attention`` seam (shared with the
    DiT block): the default composes the ``{name}/qk`` einsum, the
    post-softmax act hook and the ``{name}/pv`` einsum; quantized serving
    contexts lower the whole block to the int8 attention kernels.
    """
    return ctx.attention(name, q, k, v, mask=mask, scale=scale)


def _causal_mask(q_pos, k_pos, window=None):
    """(…,Sq,Skv) boolean mask from absolute positions."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


# --------------------------------------------------------------------------
# forward (train / prefill) — plain and q-chunked
# --------------------------------------------------------------------------
_UNSET = object()


def attention_apply(p, cfg: AttnCfg, x, *, ctx=_FP, name="attn",
                    positions=None, causal=True, kv_x=None,
                    kv_positions=None, impl="plain", window=_UNSET):
    """Full-sequence attention. Returns y:(B,S,d).

    kv_x: if given, cross-attention onto that memory (no causal mask).
    impl: 'plain' materializes (Sq,Skv) scores; 'qchunk' tiles queries to
    bound transient memory for long sequences.
    window: overrides cfg.window for masking; may be a TRACED scalar
    (hybrid archs vary the window per layer under lax.scan).
    """
    window = cfg.window if window is _UNSET else window
    B, S, _ = x.shape
    cross = kv_x is not None
    if kv_x is None:
        kv_x = x
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if kv_positions is None:
        kv_positions = (jnp.broadcast_to(jnp.arange(kv_x.shape[1]), (B, kv_x.shape[1]))
                        if not cross else jnp.zeros((B, kv_x.shape[1]), jnp.int32))
    q, k, v = _project_qkv(p, cfg, x, kv_x, positions, kv_positions, ctx, name)
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = hd ** -0.5

    if cfg.sp_spec is not None and S > 1 and not cross:
        from jax.sharding import PartitionSpec as _P
        bt, sx = cfg.sp_spec
        q = jax.lax.with_sharding_constraint(
            q, _P(bt, sx, None, None, None))          # (B, Sq, Hk, G, hd)
        k = jax.lax.with_sharding_constraint(k, _P(bt, None, None, None))
        v = jax.lax.with_sharding_constraint(v, _P(bt, None, None, None))

    # learnable meta-token KV prefix (hymba): attended by every query.
    n_meta = cfg.n_meta if not cross else 0
    if n_meta:
        meta = jnp.broadcast_to(p["meta"], (B, cfg.n_meta, cfg.d_model)).astype(x.dtype)
        mk = ctx.linear(f"{name}/k", meta, p["k"]["w"], p["k"].get("b"))
        mv = ctx.linear(f"{name}/v", meta, p["v"]["w"], p["v"].get("b"))
        mk = mk.reshape(B, n_meta, Hk, hd)
        mv = mv.reshape(B, n_meta, Hk, hd)
        if cfg.qk_norm:
            mk = rmsnorm_apply(p["k_norm"], mk)
        k = jnp.concatenate([mk, k], axis=1)
        v = jnp.concatenate([mv, v], axis=1)
        kv_positions = jnp.concatenate(
            [jnp.zeros((B, n_meta), kv_positions.dtype), kv_positions], axis=1)

    masked = causal or (window is not None)

    def _mask_for(qpos):
        if cross:
            return None
        m = _causal_mask(qpos, kv_positions, window)       # (B,Sq,Skv)
        if n_meta:
            m = m.at[..., :n_meta].set(True)               # meta always visible
        return m[:, None, None]                            # (B,1,1,Sq,Skv)

    if impl == "plain" or S <= cfg.q_chunk:
        out = _sdpa(q, k, v, _mask_for(positions) if masked else None,
                    ctx, name, scale)
    elif impl == "qchunk":
        C = cfg.q_chunk
        assert S % C == 0, f"seq {S} not divisible by q_chunk {C}"
        qc = q.reshape(B, S // C, C, Hk, H // Hk, hd)
        pc = positions.reshape(B, S // C, C)

        def one_chunk(args):
            qi, pi = args   # (B,C,Hk,G,hd), (B,C)
            m = _mask_for(pi) if masked and not cross else None
            return _sdpa(qi, k, v, m, ctx, name, scale)

        # map over q-chunks keeps the (C, Skv) score tile bounded.
        out = jax.lax.map(one_chunk, (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(pc, 1, 0)))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hk, H // Hk, hd)
    else:
        raise ValueError(impl)

    out = out.reshape(B, S, H * hd)
    if cfg.sp_spec is not None and S > 1 and not cross:
        # restore the batch-sharded layout before the o-projection so SP
        # stays confined to the quadratic attention internals — leaving the
        # residual S-sharded collides with TP-sharded MLP/vocab dims on the
        # same mesh axis and forces (B,S,ff)/(B,S,V) gathers (measured).
        from jax.sharding import PartitionSpec as _P
        out = jax.lax.with_sharding_constraint(
            out, _P(cfg.sp_spec[0], None, None))
    return ctx.linear(f"{name}/o", out, p["o"]["w"], p["o"].get("b"))


# --------------------------------------------------------------------------
# KV cache (decode)
# --------------------------------------------------------------------------
def kv_cache_init(cfg: AttnCfg, batch, max_len, dtype=jnp.float32):
    """Ring buffer of size ``window`` when sliding-window, else ``max_len``."""
    size = min(cfg.window, max_len) if cfg.window else max_len
    Hk, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, Hk, hd), dtype),
        "v": jnp.zeros((batch, size, Hk, hd), dtype),
    }


def attention_prefill(p, cfg: AttnCfg, x, *, ctx=_FP, name="attn", positions=None,
                      impl="qchunk", max_len=None, window=_UNSET,
                      full_cache=False):
    """Run forward attention AND build the decode cache. Returns (y, cache).

    full_cache=True allocates a full ``max_len`` cache even when windowed
    (hybrid archs stack windowed + global layer caches uniformly)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    y = attention_apply(p, cfg, x, ctx=ctx, name=name, positions=positions,
                        impl=impl, window=window)
    # recompute k/v once more for the cache (cheap relative to attention).
    _, k, v = _project_qkv(p, cfg, x, x, positions, positions, ctx, name)
    ring = cfg.window and not full_cache
    size = min(cfg.window, max_len or S) if ring else (max_len or S)
    if ring and S > size:
        k, v = k[:, -size:], v[:, -size:]
    elif size > S:
        pad = size - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return y, {"k": k, "v": v}


def attention_decode(p, cfg: AttnCfg, x, cache, index, *, ctx=_FP, name="attn",
                     window=_UNSET):
    """One decode step. x:(B,1,d); index: scalar int32 absolute position of
    the new token. Ring-buffer writes when sliding-window (static
    cfg.window); a dynamic ``window`` (possibly traced, full-size cache)
    only tightens the mask. Returns (y, cache).
    """
    dyn_window = None if window is _UNSET else window
    B = x.shape[0]
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, x, pos, pos, ctx, name)
    size = cache["k"].shape[1]
    slot = (index % size) if cfg.window else index
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    # absolute positions held in each cache slot
    slots = jnp.arange(size)
    if cfg.window:
        # ring: slot s holds the most recent position p with p % size == s, p <= index
        k_pos = index - ((index - slots) % size)
    else:
        k_pos = slots
    valid = (k_pos >= 0) & (k_pos <= index)
    if cfg.window:
        valid &= k_pos > index - cfg.window
    if dyn_window is not None:
        valid &= k_pos > index - dyn_window
    mask = valid[None, None, None, None, :]     # (1,1,1,1,size)

    if cfg.n_meta:
        meta = jnp.broadcast_to(p["meta"], (B, cfg.n_meta, cfg.d_model)).astype(x.dtype)
        mk = ctx.linear(f"{name}/k", meta, p["k"]["w"], p["k"].get("b")).reshape(B, cfg.n_meta, Hk, hd)
        mv = ctx.linear(f"{name}/v", meta, p["v"]["w"], p["v"].get("b")).reshape(B, cfg.n_meta, Hk, hd)
        if cfg.qk_norm:
            mk = rmsnorm_apply(p["k_norm"], mk)
        k_att = jnp.concatenate([mk, k], axis=1)
        v_att = jnp.concatenate([mv, v], axis=1)
        mask = jnp.concatenate(
            [jnp.ones((1, 1, 1, 1, cfg.n_meta), bool), mask], axis=-1)
    else:
        k_att, v_att = k, v

    out = _sdpa(q, k_att, v_att, mask, ctx, name, hd ** -0.5)
    out = out.reshape(B, 1, H * hd)
    y = ctx.linear(f"{name}/o", out, p["o"]["w"], p["o"].get("b"))
    return y, {"k": k, "v": v}


def cross_attention_cache(p, cfg: AttnCfg, memory, *, ctx=_FP, name="xattn"):
    """Precompute cross-attention K/V from encoder memory (whisper decode)."""
    B, S, _ = memory.shape
    Hk, hd = cfg.n_kv_heads, cfg.head_dim
    k = ctx.linear(f"{name}/k", memory, p["k"]["w"], p["k"].get("b")).reshape(B, S, Hk, hd)
    v = ctx.linear(f"{name}/v", memory, p["v"]["w"], p["v"].get("b")).reshape(B, S, Hk, hd)
    if cfg.qk_norm:
        k = rmsnorm_apply(p["k_norm"], k)
    return {"k": k, "v": v}


def cross_attention_decode(p, cfg: AttnCfg, x, xcache, *, ctx=_FP, name="xattn"):
    """Cross-attention for one (or few) decoder positions against fixed memory."""
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = ctx.linear(f"{name}/q", x, p["q"]["w"], p["q"].get("b")).reshape(B, S, Hk, H // Hk, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
    out = _sdpa(q, xcache["k"], xcache["v"], None, ctx, name, hd ** -0.5)
    out = out.reshape(B, S, H * hd)
    return ctx.linear(f"{name}/o", out, p["o"]["w"], p["o"].get("b"))


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 family)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLACfg:
    d_model: int
    n_heads: int
    kv_lora: int = 512
    q_lora: int = 0          # 0 = direct q projection
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 10000.0
    q_chunk: int = 512


def mla_init(key, cfg: MLACfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    H, d = cfg.n_heads, cfg.d_model
    qd = cfg.nope_dim + cfg.rope_dim
    p = {}
    if cfg.q_lora:
        p["q_a"] = linear_init(ks[0], d, cfg.q_lora, bias=False, dtype=dtype)
        p["q_a_norm"] = rmsnorm_init(ks[1], cfg.q_lora, dtype)
        p["q_b"] = linear_init(ks[2], cfg.q_lora, H * qd, bias=False, dtype=dtype)
    else:
        p["q"] = linear_init(ks[0], d, H * qd, bias=False, dtype=dtype)
    p["kv_a"] = linear_init(ks[3], d, cfg.kv_lora + cfg.rope_dim, bias=False, dtype=dtype)
    p["kv_a_norm"] = rmsnorm_init(ks[4], cfg.kv_lora, dtype)
    p["kv_b"] = linear_init(ks[5], cfg.kv_lora, H * (cfg.nope_dim + cfg.v_dim),
                            bias=False, dtype=dtype)
    p["o"] = linear_init(ks[6], H * cfg.v_dim, d, bias=False, dtype=dtype)
    return p


def _mla_q(p, cfg, x, positions, ctx, name):
    B, S, _ = x.shape
    H = cfg.n_heads
    if cfg.q_lora:
        cq = ctx.linear(f"{name}/q_a", x, p["q_a"]["w"])
        cq = rmsnorm_apply(p["q_a_norm"], cq)
        q = ctx.linear(f"{name}/q_b", cq, p["q_b"]["w"])
    else:
        q = ctx.linear(f"{name}/q", x, p["q"]["w"])
    q = q.reshape(B, S, H, cfg.nope_dim + cfg.rope_dim)
    q_nope, q_pe = q[..., : cfg.nope_dim], q[..., cfg.nope_dim:]
    q_pe = rope_apply(q_pe, positions, rope_freqs(cfg.rope_dim, cfg.rope_theta))
    return q_nope, q_pe


def _mla_ckv(p, cfg, x, positions, ctx, name):
    B, S, _ = x.shape
    ckv = ctx.linear(f"{name}/kv_a", x, p["kv_a"]["w"])
    c_kv, k_pe = ckv[..., : cfg.kv_lora], ckv[..., cfg.kv_lora:]
    c_kv = rmsnorm_apply(p["kv_a_norm"], c_kv)
    k_pe = rope_apply(k_pe[:, :, None, :], positions,
                      rope_freqs(cfg.rope_dim, cfg.rope_theta))[:, :, 0, :]
    return c_kv, k_pe


def mla_apply(p, cfg: MLACfg, x, *, ctx=_FP, name="mla", positions=None,
              causal=True, impl="plain"):
    """Materialized MLA (train / prefill)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_pe = _mla_q(p, cfg, x, positions, ctx, name)
    c_kv, k_pe = _mla_ckv(p, cfg, x, positions, ctx, name)
    kv = ctx.linear(f"{name}/kv_b", c_kv, p["kv_b"]["w"])
    kv = kv.reshape(B, S, H, cfg.nope_dim + cfg.v_dim)
    k_nope, v = kv[..., : cfg.nope_dim], kv[..., cfg.nope_dim:]
    scale = (cfg.nope_dim + cfg.rope_dim) ** -0.5

    def _attend(qn, qp, qpos):
        s = (ctx.einsum(f"{name}/qk_nope", "bqhd,bkhd->bhqk", qn, k_nope)
             + ctx.einsum(f"{name}/qk_pe", "bqhd,bkd->bhqk", qp, k_pe)) * scale
        if causal:
            m = _causal_mask(qpos, positions)[:, None]
            s = jnp.where(m, s, NEG_INF)
        pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
        pr = ctx.act(f"{name}/probs", pr, "post_softmax")
        return ctx.einsum(f"{name}/pv", "bhqk,bkhd->bqhd", pr, v)

    if impl == "plain" or S <= cfg.q_chunk:
        out = _attend(q_nope, q_pe, positions)
    else:
        C = cfg.q_chunk
        qn = jnp.moveaxis(q_nope.reshape(B, S // C, C, H, cfg.nope_dim), 1, 0)
        qp = jnp.moveaxis(q_pe.reshape(B, S // C, C, H, cfg.rope_dim), 1, 0)
        pp = jnp.moveaxis(positions.reshape(B, S // C, C), 1, 0)
        out = jax.lax.map(lambda a: _attend(*a), (qn, qp, pp))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, cfg.v_dim)
    out = out.reshape(B, S, H * cfg.v_dim)
    return ctx.linear(f"{name}/o", out, p["o"]["w"])


def mla_cache_init(cfg: MLACfg, batch, max_len, dtype=jnp.float32):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.rope_dim), dtype),
    }


def mla_prefill(p, cfg: MLACfg, x, *, ctx=_FP, name="mla", positions=None,
                impl="qchunk", max_len=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    y = mla_apply(p, cfg, x, ctx=ctx, name=name, positions=positions, impl=impl)
    c_kv, k_pe = _mla_ckv(p, cfg, x, positions, ctx, name)
    if max_len and max_len > S:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, max_len - S), (0, 0)))
        k_pe = jnp.pad(k_pe, ((0, 0), (0, max_len - S), (0, 0)))
    return y, {"c_kv": c_kv, "k_pe": k_pe}


def mla_decode(p, cfg: MLACfg, x, cache, index, *, ctx=_FP, name="mla"):
    """Absorbed-matmul decode: queries are folded into the latent (kv_lora)
    space so attention runs against the *compressed* cache — the
    production MLA decode path (no per-step K/V materialization).
    """
    B = x.shape[0]
    H = cfg.n_heads
    pos = jnp.full((B, 1), index, jnp.int32)
    q_nope, q_pe = _mla_q(p, cfg, x, pos, ctx, name)          # (B,1,H,*)
    c_new, kpe_new = _mla_ckv(p, cfg, x, pos, ctx, name)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, index, 0))
    k_pe = jax.lax.dynamic_update_slice(cache["k_pe"], kpe_new, (0, index, 0))

    wkv = p["kv_b"]["w"].reshape(cfg.kv_lora, H, cfg.nope_dim + cfg.v_dim)
    w_k = wkv[..., : cfg.nope_dim]          # (lora, H, nope)
    w_v = wkv[..., cfg.nope_dim:]           # (lora, H, v)
    # absorb: q_abs[b,1,h,lora] = q_nope · w_k^T
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_k)
    scale = (cfg.nope_dim + cfg.rope_dim) ** -0.5
    s = (ctx.einsum(f"{name}/qk_nope", "bqhl,bkl->bhqk", q_abs, c_kv)
         + ctx.einsum(f"{name}/qk_pe", "bqhd,bkd->bhqk", q_pe, k_pe)) * scale
    valid = jnp.arange(c_kv.shape[1]) <= index
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    pr = ctx.act(f"{name}/probs", pr, "post_softmax")
    ctx_lat = ctx.einsum(f"{name}/pv", "bhqk,bkl->bqhl", pr, c_kv)
    out = jnp.einsum("bqhl,lhd->bqhd", ctx_lat, w_v).reshape(B, 1, H * cfg.v_dim)
    y = ctx.linear(f"{name}/o", out, p["o"]["w"])
    return y, {"c_kv": c_kv, "k_pe": k_pe}
