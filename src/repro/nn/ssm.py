"""Mamba-2 SSD (state-space duality) mixer, chunked-scan implementation.

Forward runs the SSD algorithm: quadratic attention-like computation
inside fixed-size chunks, linear recurrence across chunks (carried by a
``lax.scan``), which is the production formulation (Dao & Gu 2024,
arXiv:2405.21060). Decode is the O(1) per-token recurrence with a
depthwise-conv ring buffer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.ctx import FPContext
from repro.nn.layers import linear_init, rmsnorm_init

_FP = FPContext()


@dataclasses.dataclass(frozen=True)
class SSDCfg:
    d_model: int
    d_inner: int                 # = n_heads * head_dim
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def n_heads(self):
        return self.d_inner // self.head_dim

    @property
    def conv_ch(self):
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssd_init(key, cfg: SSDCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + H
    # dt bias init so softplus(dt_bias) spans [dt_min, dt_max] (mamba init)
    u = jax.random.uniform(ks[2], (H,))
    dt = jnp.exp(u * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min)) + jnp.log(cfg.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": linear_init(ks[0], cfg.d_model, d_in_proj, bias=False, dtype=dtype),
        "conv_w": init.normal(0.2)(ks[1], (cfg.d_conv, cfg.conv_ch), dtype),
        "conv_b": jnp.zeros((cfg.conv_ch,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(ks[3], cfg.d_inner, dtype),
        "out_proj": linear_init(ks[4], cfg.d_inner, cfg.d_model, bias=False, dtype=dtype),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d. xBC: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1]] * w[i] for i in range(K))
    return out + b


def _split_proj(cfg, zxbcdt):
    H = cfg.n_heads
    gs = cfg.n_groups * cfg.d_state
    z, xBC, dt = jnp.split(zxbcdt, [cfg.d_inner, cfg.d_inner + cfg.conv_ch], axis=-1)
    return z, xBC, dt  # dt: (..., H)


def _segsum(a):
    """a: (..., Q) -> (..., Q, Q); out[q,k] = sum_{i=k+1..q} a_i (q>=k) else -inf."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_apply(p, cfg: SSDCfg, x, *, ctx=_FP, name="ssd", initial_state=None,
              return_state=False):
    """Full-sequence SSD. x: (B,S,d). Returns y (and final state if asked).

    State = {'h': (B,H,P,N), 'conv': (B,d_conv-1,conv_ch)}.
    """
    B, S, _ = x.shape
    H, P, N, Gs, Q = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups, cfg.chunk
    if S % Q:
        # pad to a chunk multiple; padded tail only pollutes the final state,
        # so the stateless path slices it off and the stateful path forbids it.
        assert not return_state, f"seq {S} % chunk {Q} != 0 with return_state"
        pad = Q - S % Q
        y = ssd_apply(p, cfg, jnp.pad(x, ((0, 0), (0, pad), (0, 0))), ctx=ctx,
                      name=name, initial_state=initial_state)
        return y[:, :S]
    nc = S // Q

    zxbcdt = ctx.linear(f"{name}/in_proj", x, p["in_proj"]["w"])
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    conv_tail = xBC[:, S - (cfg.d_conv - 1):, :]          # for decode handoff
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, Bc, Cc = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + Gs * N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)

    # chunked reshapes
    xs = xs.reshape(B, nc, Q, H, P)
    Bc = Bc.reshape(B, nc, Q, Gs, N)
    Cc = Cc.reshape(B, nc, Q, Gs, N)
    dt = dt.reshape(B, nc, Q, H)
    hpg = H // Gs                                         # heads per group

    dA = dt * A                                           # (B,nc,Q,H)
    xdt = xs * dt[..., None].astype(xs.dtype)

    h0 = (initial_state["h"] if initial_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))

    def chunk_step(h, inp):
        xc, bc, cc, dac = inp        # (B,Q,H,P) (B,Q,Gs,N) (B,Q,Gs,N) (B,Q,H)
        cs = jnp.cumsum(dac, axis=1)                       # (B,Q,H)
        L = jnp.exp(_segsum(jnp.moveaxis(dac, 1, -1)))     # (B,H,Q,Q)
        CB = jnp.einsum("bqgn,bkgn->bgqk", cc, bc)         # (B,Gs,Q,Q)
        CB = jnp.repeat(CB, hpg, axis=1)                   # (B,H,Q,Q)
        Yd = jnp.einsum("bhqk,bkhp->bqhp", (CB * L).astype(xc.dtype), xc)
        # contribution of carried state, and this chunk's state update
        ccr = jnp.repeat(cc, hpg, axis=2)                  # (B,Q,H,N)
        bcr = jnp.repeat(bc, hpg, axis=2)
        sdec = jnp.exp(cs).astype(xc.dtype)                # (B,Q,H)
        Yo = jnp.einsum("bqhn,bhpn,bqh->bqhp", ccr, h.astype(xc.dtype), sdec)
        decay_state = jnp.exp(cs[:, -1:, :] - cs).astype(xc.dtype)
        new_contrib = jnp.einsum("bqhn,bqh,bqhp->bhpn", bcr, decay_state, xc)
        chunk_decay = jnp.exp(cs[:, -1, :])                # (B,H)
        h_new = h * chunk_decay[..., None, None] + new_contrib.astype(jnp.float32)
        return h_new, Yd + Yo

    xs_c = jnp.moveaxis(xdt, 1, 0)
    Bc_c = jnp.moveaxis(Bc, 1, 0)
    Cc_c = jnp.moveaxis(Cc, 1, 0)
    dA_c = jnp.moveaxis(dA, 1, 0)
    h_fin, Y = jax.lax.scan(chunk_step, h0, (xs_c, Bc_c, Cc_c, dA_c))
    Y = jnp.moveaxis(Y, 0, 1).reshape(B, S, H, P)
    Y = Y + (p["D"][:, None].astype(Y.dtype) * xs.reshape(B, S, H, P))

    # gated RMSNorm then output projection
    y = Y.reshape(B, S, cfg.d_inner) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(y.dtype)) * p["norm"]["scale"]
    out = ctx.linear(f"{name}/out_proj", y, p["out_proj"]["w"])
    if return_state:
        return out, {"h": h_fin, "conv": conv_tail}
    return out


def ssd_state_init(cfg: SSDCfg, batch, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_ch), dtype),
    }


def ssd_decode(p, cfg: SSDCfg, x, state, *, ctx=_FP, name="ssd"):
    """One-token recurrence. x: (B,1,d). Returns (y, state)."""
    B = x.shape[0]
    H, P, N, Gs = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    zxbcdt = ctx.linear(f"{name}/in_proj", x, p["in_proj"]["w"])
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    z, xBC, dt_raw = z[:, 0], xBC[:, 0], dt_raw[:, 0]

    window = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC_a = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(xBC_a, [cfg.d_inner, cfg.d_inner + Gs * N], axis=-1)
    xs = xs.reshape(B, H, P)
    Bc = Bc.reshape(B, Gs, N)
    Cc = Cc.reshape(B, Gs, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])     # (B,H)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                                # (B,H)
    Bh = jnp.repeat(Bc, H // Gs, axis=1)                                # (B,H,N)
    Ch = jnp.repeat(Cc, H // Gs, axis=1)
    h = (state["h"] * da[..., None, None]
         + jnp.einsum("bhn,bhp,bh->bhpn", Bh.astype(jnp.float32),
                      xs.astype(jnp.float32), dt))
    y = jnp.einsum("bhpn,bhn->bhp", h.astype(xs.dtype), Ch)
    y = y + p["D"][:, None].astype(y.dtype) * xs
    y = y.reshape(B, 1, cfg.d_inner) * jax.nn.silu(z)[:, None, :]
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(y.dtype)) * p["norm"]["scale"]
    out = ctx.linear(f"{name}/out_proj", y, p["out_proj"]["w"])
    return out, {"h": h, "conv": window[:, 1:]}
