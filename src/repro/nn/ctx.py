"""Op context — the interception seam between models and the PTQ engine.

Every model in ``repro.models`` routes matmul-like computations and
quantization-relevant activations through an :class:`OpContext`:

- ``linear(name, x, w, b)``      — activation × weight projections,
- ``einsum(name, spec, a, b)``   — activation × activation MatMuls
                                   (attention QK^T and P·V),
- ``act(name, x, kind)``         — identity hook on distributions the paper
                                   treats specially (``post_softmax``,
                                   ``post_gelu``, ``post_silu``),
- ``attention(name, q, k, v)``   — the whole QK^T → softmax → P·V block.
                                   The DEFAULT implementation composes the
                                   three seams above (so recording /
                                   calibration / tap contexts keep seeing
                                   the individual ``{name}/qk``,
                                   ``{name}/probs`` and ``{name}/pv`` ops),
                                   while ``QuantContext(kernel=True)``
                                   overrides it to lower the block onto the
                                   int8 attention Pallas kernels — exactly
                                   how ``ctx.linear`` sites lower to
                                   ``int8_matmul_fq``.

``FPContext`` is the no-op full-precision implementation. The PTQ engine
(`repro.core`) provides:

- ``CalibrationContext`` — records activation ranges / histograms and
  (in a second pass) Fisher weights per op name,
- ``QuantContext``       — applies the calibrated quantizers, either as
  simulated quant-dequant (fidelity experiments) or via the int8 Pallas
  kernels (deployment path),

without any change to model code. ``name`` uniquely identifies the op
within a layer; when models run their blocks in a Python loop the layer
index is baked into the name (``blk3/attn/qk``), and when they run under
``lax.scan`` the name is layer-invariant and contexts receive stacked
per-layer parameters plus a traced ``layer`` index (see
``OpContext.at_layer``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9          # additive mask value for attention scores


@dataclasses.dataclass
class OpContext:
    """Base class. ``tgroup`` is the TGQ timestep-group index — a traced
    scalar, a per-slot (B,) int32 VECTOR (vector-tgroup batched path: one
    forward over a batch whose slots sit at different timesteps; quantized
    contexts gather each batch row's group params), or None outside
    diffusion. ``layer`` is the current layer index when the caller runs
    blocks under ``lax.scan`` (traced scalar) or a concrete int.
    """

    tgroup: Optional[Any] = None
    layer: Optional[Any] = None

    def at_layer(self, layer) -> "OpContext":
        return dataclasses.replace(self, layer=layer)

    def with_tgroup(self, tgroup) -> "OpContext":
        return dataclasses.replace(self, tgroup=tgroup)

    # -- op seams ----------------------------------------------------------
    def linear(self, name: str, x, w, b=None):
        raise NotImplementedError

    def einsum(self, name: str, spec: str, a, b, b_is_weight: bool = False):
        """General matmul seam. ``b_is_weight`` marks operand b as a
        parameter tensor (e.g. stacked per-expert weights) so quantized
        contexts use a weight quantizer (per-channel) for it."""
        raise NotImplementedError

    def act(self, name: str, x, kind: str):
        raise NotImplementedError

    def attention(self, name: str, q, k, v, *, mask=None, scale=1.0):
        """Grouped scaled-dot-product attention seam.

        q: (B, Sq, Hk, G, hd); k, v: (B, Skv, Hk, hd); ``mask``
        broadcastable to (B, Hk, G, Sq, Skv) boolean (True = attend) or
        None. Returns (B, Sq, Hk, G, hd).

        This default composes the three fine-grained seams — the op
        names ``{name}/qk``, ``{name}/probs``, ``{name}/pv`` are the
        contract every PTQ context keys on. Contexts that lower the
        whole block to a fused kernel override this method but keep the
        same names for their packed parameters.
        """
        scores = self.einsum(f"{name}/qk", "bqhgd,bkhd->bhgqk", q, k) * scale
        if mask is not None:
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(q.dtype)
        probs = self.act(f"{name}/probs", probs, "post_softmax")
        return self.einsum(f"{name}/pv", "bhgqk,bkhd->bqhgd", probs, v)


@dataclasses.dataclass
class FPContext(OpContext):
    """Full-precision passthrough (the default for training and FP eval)."""

    def linear(self, name, x, w, b=None):
        y = x @ w
        if b is not None:
            y = y + b
        return y

    def einsum(self, name, spec, a, b, b_is_weight=False):
        return jnp.einsum(spec, a, b)

    def act(self, name, x, kind):
        return x
