"""Op context — the interception seam between models and the PTQ engine.

Every model in ``repro.models`` routes matmul-like computations and
quantization-relevant activations through an :class:`OpContext`:

- ``linear(name, x, w, b)``      — activation × weight projections,
- ``einsum(name, spec, a, b)``   — activation × activation MatMuls
                                   (attention QK^T and P·V),
- ``act(name, x, kind)``         — identity hook on distributions the paper
                                   treats specially (``post_softmax``,
                                   ``post_gelu``, ``post_silu``),
- ``attention(name, q, k, v)``   — the whole QK^T → softmax → P·V block.
                                   The DEFAULT implementation composes the
                                   three seams above (so recording /
                                   calibration / tap contexts keep seeing
                                   the individual ``{name}/qk``,
                                   ``{name}/probs`` and ``{name}/pv`` ops),
                                   while ``QuantContext(kernel=True)``
                                   overrides it to lower the block onto the
                                   int8 attention Pallas kernels — exactly
                                   how ``ctx.linear`` sites lower to
                                   ``int8_matmul_fq``.

``FPContext`` is the no-op full-precision implementation. The PTQ engine
(`repro.core`) provides:

- ``CalibrationContext`` — records activation ranges / histograms and
  (in a second pass) Fisher weights per op name,
- ``QuantContext``       — applies the calibrated quantizers, either as
  simulated quant-dequant (fidelity experiments) or via the int8 Pallas
  kernels (deployment path),

without any change to model code. ``name`` uniquely identifies the op
within a layer; when models run their blocks in a Python loop the layer
index is baked into the name (``blk3/attn/qk``), and when they run under
``lax.scan`` the name is layer-invariant and contexts receive stacked
per-layer parameters plus a traced ``layer`` index (see
``OpContext.at_layer``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9          # additive mask value for attention scores


def apply_norm_mod(x, norm_mod, eps: float = 1e-6):
    """Reference adaLN norm-modulate chain for the ``ctx.linear`` seam.

    ``norm_mod = (shift, scale)`` with per-BATCH (B, K) rows; x carries a
    leading batch axis. Computes the non-affine layernorm (the exact op
    sequence of ``layers.layernorm_apply`` — mean, var, ``lax.rsqrt(var +
    eps)``) followed by ``y * (1 + scale) + shift``. Contexts that do NOT
    lower to kernels run this in fp; ``QuantContext(kernel=True)`` passes
    the rows to the fused kernels, whose VMEM prologue replays the same
    ops (bit-identical — asserted by the conformance suite)."""
    if norm_mod is None:
        return x
    shift, scale = norm_mod
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    bshape = (shift.shape[0],) + (1,) * (x.ndim - 2) + (shift.shape[-1],)
    return y * (1.0 + scale.reshape(bshape)) + shift.reshape(bshape)


def apply_gate_residual(y, gate_residual):
    """Reference adaLN gate + residual epilogue for ``ctx.linear``.

    ``gate_residual = (gate, residual)`` with gate (B, N) rows and a
    y-shaped residual: returns ``residual + gate * y``. The kernel path
    fuses this into the dequant epilogue ahead of the single HBM write."""
    if gate_residual is None:
        return y
    gate, res = gate_residual
    bshape = (gate.shape[0],) + (1,) * (y.ndim - 2) + (gate.shape[-1],)
    return res + gate.reshape(bshape) * y


@dataclasses.dataclass
class OpContext:
    """Base class. ``tgroup`` is the TGQ timestep-group index — a traced
    scalar, a per-slot (B,) int32 VECTOR (vector-tgroup batched path: one
    forward over a batch whose slots sit at different timesteps; quantized
    contexts gather each batch row's group params), or None outside
    diffusion. ``layer`` is the current layer index when the caller runs
    blocks under ``lax.scan`` (traced scalar) or a concrete int.
    """

    tgroup: Optional[Any] = None
    layer: Optional[Any] = None

    def at_layer(self, layer) -> "OpContext":
        return dataclasses.replace(self, layer=layer)

    def with_tgroup(self, tgroup) -> "OpContext":
        return dataclasses.replace(self, tgroup=tgroup)

    # -- op seams ----------------------------------------------------------
    def linear(self, name: str, x, w, b=None, norm_mod=None,
               gate_residual=None):
        """Projection seam. ``norm_mod=(shift, scale)`` asks the context
        to apply the adaLN layernorm-modulate chain to x first;
        ``gate_residual=(gate, residual)`` asks it to finish with
        ``residual + gate * y``. Passing them through the seam (instead
        of computing them in the model) lets kernel-lowering contexts
        fuse both into the matmul's VMEM prologue/epilogue; every other
        context applies the fp reference helpers above."""
        raise NotImplementedError

    def einsum(self, name: str, spec: str, a, b, b_is_weight: bool = False):
        """General matmul seam. ``b_is_weight`` marks operand b as a
        parameter tensor (e.g. stacked per-expert weights) so quantized
        contexts use a weight quantizer (per-channel) for it."""
        raise NotImplementedError

    def act(self, name: str, x, kind: str):
        raise NotImplementedError

    def attention(self, name: str, q, k, v, *, mask=None, scale=1.0):
        """Grouped scaled-dot-product attention seam.

        q: (B, Sq, Hk, G, hd); k, v: (B, Skv, Hk, hd); ``mask``
        broadcastable to (B, Hk, G, Sq, Skv) boolean (True = attend) or
        None. Returns (B, Sq, Hk, G, hd).

        This default composes the three fine-grained seams — the op
        names ``{name}/qk``, ``{name}/probs``, ``{name}/pv`` are the
        contract every PTQ context keys on. Contexts that lower the
        whole block to a fused kernel override this method but keep the
        same names for their packed parameters.
        """
        scores = self.einsum(f"{name}/qk", "bqhgd,bkhd->bhgqk", q, k) * scale
        if mask is not None:
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(q.dtype)
        probs = self.act(f"{name}/probs", probs, "post_softmax")
        return self.einsum(f"{name}/pv", "bhgqk,bkhd->bqhgd", probs, v)


@dataclasses.dataclass
class FPContext(OpContext):
    """Full-precision passthrough (the default for training and FP eval)."""

    def linear(self, name, x, w, b=None, norm_mod=None, gate_residual=None):
        x = apply_norm_mod(x, norm_mod)
        y = x @ w
        if b is not None:
            y = y + b
        return apply_gate_residual(y, gate_residual)

    def einsum(self, name, spec, a, b, b_is_weight=False):
        return jnp.einsum(spec, a, b)

    def act(self, name, x, kind):
        return x
