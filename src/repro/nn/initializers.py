"""Parameter initializers (jax.nn.initializers wrappers with sane defaults)."""
import jax
import jax.numpy as jnp


def normal(stddev=0.02):
    def init(key, shape, dtype=jnp.float32):
        return (jax.random.normal(key, shape) * stddev).astype(dtype)
    return init


def truncated_normal(stddev=0.02):
    def init(key, shape, dtype=jnp.float32):
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(dtype)
    return init


def zeros(key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def xavier_uniform():
    def init(key, shape, dtype=jnp.float32):
        fan_in, fan_out = shape[0], shape[-1]
        limit = (6.0 / (fan_in + fan_out)) ** 0.5
        return jax.random.uniform(key, shape, minval=-limit, maxval=limit).astype(dtype)
    return init


def lecun_normal():
    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[0]
        return (jax.random.normal(key, shape) * (1.0 / fan_in) ** 0.5).astype(dtype)
    return init
