"""Trial scoring: two-stage quality evaluation + modeled throughput.

Every trial gets a CHEAP stage-1 score first — quantized-vs-FP noise
prediction MSE per TGQ timestep group (one forward per group; no
sampling, no feature nets). Only survivors of the stage-1 gate run
stage 2: full respaced-DDPM generation scored with the FD / sFD /
IS-proxy stack (``repro.quant.eval``), the expensive part of a sweep.
The gate (:func:`select_survivors`) is a deterministic pure function of
ALL stage-1 results, so a resumed sweep reaches the identical verdicts:

- every trial with ``noise_mse <= prune_factor * best`` survives,
- the ``keep_at_least`` lowest-MSE trials always survive, and
- the max-modeled-throughput trial always survives — the frontier's
  fast endpoint must be quality-scored or the Pareto set would be
  missing it by construction, not by evidence.

Throughput never needs stage gating: it is the serving roofline
(``benchmarks.serve_throughput.modeled_goodput``), a closed-form
function of the recipe — the SAME model the serving benchmark tables
are built from, so frontier throughput and ``BENCH_serve.json`` agree
by construction. Mixed (per-group bit) trials charge each respaced
denoising step at its group's kernel path and sum.

The AdaTSQ-style allocator lives here too: :func:`sensitivity_by_bits`
reads each uniform component's per-group stage-1 MSE as the sensitivity
signal, and :func:`allocate_bits` greedily upgrades the group with the
best MSE-drop-per-bit until the mean-bit budget is spent.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Sequence

import numpy as np

from repro.quant import eval as qeval
from repro.quant.recipe import BITS


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """The evaluation protocol — every knob that shapes a trial's score.

    Hash-guarded in the ledger header: scores taken under different
    protocols are not comparable, so a resume under a changed protocol
    must fail fast rather than mix them.

    steps/n_gen/gen_batch/gen_seed : stage-2 generation.
    n_real/data_seed/net_seed/pipe_seed/pipe_noise : scoring assets
        (see ``repro.quant.eval.eval_assets``).
    n_mse/mse_seed : stage-1 noise-MSE sampling.
    prune_factor/keep_at_least : the stage-1 gate (module docstring).
    serve_* : the modeled serving point every trial's throughput is
        charged at (devices, slots per device, denoising steps).
    """
    steps: int = 12
    n_gen: int = 64
    gen_batch: int = 32
    gen_seed: int = 123
    n_real: int = 512
    data_seed: int = 999
    net_seed: int = 1234
    pipe_seed: int = 11
    pipe_noise: float = 0.3
    n_mse: int = 64
    mse_seed: int = 55
    prune_factor: float = 50.0
    keep_at_least: int = 2
    serve_n_dev: int = 4
    serve_b_local: int = 1
    serve_steps: int = 100

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def content_hash(self) -> str:
        doc = json.dumps(self.to_dict(), sort_keys=True,
                         separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# stage 1: cheap per-group noise-MSE
# ---------------------------------------------------------------------------
def stage1(params, model_cfg, dif_cfg, ctx, ecfg: EvalConfig) -> dict:
    """Per-group + mean quantized-vs-FP noise MSE. ``ctx`` may be a
    per-group context spec (mixed allocation)."""
    by_group = qeval.noise_mse_by_group(
        params, model_cfg, dif_cfg, ctx, n=ecfg.n_mse, seed=ecfg.mse_seed,
        pipe_seed=ecfg.pipe_seed, pipe_noise=ecfg.pipe_noise)
    return {"noise_mse": float(np.mean(by_group)),
            "noise_mse_by_group": [float(v) for v in by_group]}


# ---------------------------------------------------------------------------
# stage 2: generation + FD / sFD / IS-proxy
# ---------------------------------------------------------------------------
def stage2(params, model_cfg, dif_cfg, ctx, ecfg: EvalConfig) -> dict:
    """Full sample-and-score. A per-group context spec routes through
    the grouped sampler (equal to the fused one within float tolerance
    for a constant map, so mixed and uniform FDs share one protocol)."""
    if isinstance(ctx, (dict, list, tuple)):
        gen, _ = qeval.generate_grouped(
            params, model_cfg, dif_cfg, ctx, steps=ecfg.steps,
            n=ecfg.n_gen, seed=ecfg.gen_seed, batch=ecfg.gen_batch)
    else:
        gen, _ = qeval.generate(
            params, model_cfg, dif_cfg, ctx=ctx, steps=ecfg.steps,
            n=ecfg.n_gen, seed=ecfg.gen_seed, batch=ecfg.gen_batch)
    return qeval.score(gen, model_cfg, n_real=ecfg.n_real,
                       data_seed=ecfg.data_seed, net_seed=ecfg.net_seed,
                       pipe_seed=ecfg.pipe_seed, pipe_noise=ecfg.pipe_noise)


# ---------------------------------------------------------------------------
# stage-1 gate
# ---------------------------------------------------------------------------
def select_survivors(mse_by_key: Dict[str, float],
                     req_per_s_by_key: Dict[str, float],
                     ecfg: EvalConfig) -> List[str]:
    """The keys advancing to stage 2 (deterministic; see module
    docstring). Sorted for stable iteration/ledger order."""
    if not mse_by_key:
        return []
    best = min(mse_by_key.values())
    keep = {k for k, v in mse_by_key.items()
            if v <= ecfg.prune_factor * best}
    by_mse = sorted(mse_by_key, key=lambda k: (mse_by_key[k], k))
    keep.update(by_mse[:max(ecfg.keep_at_least, 0)])
    # the fast endpoint always advances (ties: lower MSE, then key)
    keep.add(min(req_per_s_by_key,
                 key=lambda k: (-req_per_s_by_key[k], mse_by_key[k], k)))
    return sorted(keep)


# ---------------------------------------------------------------------------
# AdaTSQ-style sensitivity + greedy bit allocation
# ---------------------------------------------------------------------------
def sensitivity_by_bits(stage1_by_bits: Dict[str, dict]) -> Dict[str, List[float]]:
    """{bits level -> per-group noise MSE} from the uniform components'
    stage-1 records — the allocator's input. Free by construction: the
    components are themselves trials, so their per-group vectors are
    already in the ledger before any mixed trial runs."""
    return {b: list(rec["noise_mse_by_group"])
            for b, rec in stage1_by_bits.items()}


def mean_bits(allocation: Sequence[str]) -> float:
    return float(np.mean([BITS[b][0] for b in allocation]))


def allocate_bits(sens: Dict[str, List[float]], budget: float) -> List[str]:
    """Greedy per-group bit assignment under a mean-bit budget.

    Start every group at the lowest level; repeatedly upgrade the group
    with the best sensitivity drop per added bit (one level at a time)
    while the mean stays within ``budget``. Upgrades continue even at a
    measured gain of ~0 — more bits are a-priori no worse, and leaving
    budget unspent would make the budget axis meaningless. Deterministic
    (ties: lower group index), so resumed sweeps re-derive the identical
    allocation."""
    levels = sorted(sens, key=lambda b: BITS[b][0])
    if len(levels) < 2:
        raise ValueError(f"allocation needs >= 2 bits levels, got {levels}")
    G = len(sens[levels[0]])
    if any(len(v) != G for v in sens.values()):
        raise ValueError("sensitivity vectors disagree on group count: "
                         f"{ {b: len(v) for b, v in sens.items()} }")
    alloc = [0] * G                                   # level index per group
    wb = [BITS[b][0] for b in levels]
    total = wb[0] * G
    while True:
        best = None                                   # (gain, -g) max
        for g in range(G):
            lv = alloc[g]
            if lv + 1 >= len(levels):
                continue
            if (total + wb[lv + 1] - wb[lv]) / G > budget + 1e-9:
                continue
            gain = (sens[levels[lv]][g] - sens[levels[lv + 1]][g]) \
                / (wb[lv + 1] - wb[lv])
            if best is None or (gain, -g) > best[0]:
                best = ((gain, -g), g)
        if best is None:
            return [levels[i] for i in alloc]
        g = best[1]
        total += wb[alloc[g] + 1] - wb[alloc[g]]
        alloc[g] += 1


# ---------------------------------------------------------------------------
# modeled throughput (the roofline the serving benchmarks use)
# ---------------------------------------------------------------------------
def _serve():
    try:
        from benchmarks import serve_throughput
    except ImportError as e:                          # pragma: no cover
        raise ImportError(
            "repro.autotune charges throughput through "
            "benchmarks.serve_throughput — run from the repository root "
            "so the benchmarks/ package is importable") from e
    return serve_throughput


def uniform_throughput(recipe, ecfg: EvalConfig,
                       serve_cfg=None) -> Dict[str, float]:
    """Modeled goodput of one uniform recipe at the eval's serving
    point. ``serve_cfg`` (a DiTCfg) defaults to the benchmark's
    DiT-XL/2 serving workload."""
    st = _serve()
    return st.modeled_goodput(
        recipe, cfg=serve_cfg if serve_cfg is not None else st.XL2,
        n_dev=ecfg.serve_n_dev, b_local=ecfg.serve_b_local,
        steps=ecfg.serve_steps)


def mixed_throughput(allocation: Sequence[str], attn_impl: str,
                     dif_cfg, ecfg: EvalConfig,
                     serve_cfg=None) -> Dict[str, float]:
    """Modeled goodput of a per-group bit allocation: every respaced
    denoising step is charged at ITS group's kernel-path step cost, so
    a chain spending most steps in low-bit groups models faster than
    the uniform high-bit recipe and slower than uniform low-bit."""
    from repro.diffusion.ddpm import respaced_timesteps, tgroup_of
    st = _serve()
    cfg = serve_cfg if serve_cfg is not None else st.XL2
    paths = {b: st.recipe_model_path(_Bits(b, attn_impl))
             for b in set(allocation)}
    t_of_path = {p: st.modeled_dit_step(cfg, ecfg.serve_b_local, p)["time_s"]
                 for p in set(paths.values())}
    use_ts = respaced_timesteps(dif_cfg.T, ecfg.serve_steps)
    total = 0.0
    for t in use_ts:
        g = int(tgroup_of(int(t), dif_cfg.T, dif_cfg.tgq_groups))
        total += t_of_path[paths[allocation[g]]]
    batch = ecfg.serve_b_local * ecfg.serve_n_dev
    return {"req_per_s": batch / total,
            "ms_per_step": total / len(use_ts) * 1e3,
            "path": "+".join(sorted(set(paths.values()))),
            "mean_bits": mean_bits(allocation)}


@dataclasses.dataclass(frozen=True)
class _Bits:
    """Duck-typed stand-in with the two fields ``recipe_model_path``
    reads — avoids fabricating a full QuantRecipe per lookup."""
    bits: str
    attn_impl: str
