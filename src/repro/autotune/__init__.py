"""repro.autotune — resumable recipe auto-search over the quantization
artifact API, emitting the quality-vs-throughput Pareto frontier.

    from repro.autotune import SearchSpace, EvalConfig, run_autotune

    space = SearchSpace(bits=("w8a8", "w6a6", "w4a4"),
                        tgq_groups=(None, 5), bit_budgets=(6.0,))
    result = run_autotune(params, model_cfg, dif_cfg, space,
                          EvalConfig(), "experiments/autotune")
    for p in result.frontier:
        print(p["label"], p["req_per_s"], p["FD"], p["artifact"])

Pieces (see ``docs/autotune.md``): ``space`` expands the declarative
axes into content-hash-keyed trials; ``evaluate`` is the two-stage
scorer (cheap noise-MSE gate, then FD/sFD/IS-proxy for survivors) plus
the AdaTSQ-style per-timestep-group bit allocator and the roofline
throughput model; ``driver`` runs the sweep against an append-only
JSONL ledger so a killed sweep resumes with completed trials as cache
hits; ``pareto`` computes the frontier; ``report`` renders it.
CLI: ``python -m repro.launch.autotune``.
"""
from repro.autotune.driver import AutotuneResult, load_trial_artifact, \
    read_ledger, run as run_autotune
from repro.autotune.evaluate import EvalConfig, allocate_bits, \
    mean_bits, mixed_throughput, select_survivors, sensitivity_by_bits, \
    uniform_throughput
from repro.autotune.pareto import dominates, is_strict_tradeoff, \
    pareto_frontier
from repro.autotune.space import SearchSpace, Trial, expand

__all__ = [
    "AutotuneResult", "EvalConfig", "SearchSpace", "Trial",
    "allocate_bits", "dominates", "expand", "is_strict_tradeoff",
    "load_trial_artifact", "mean_bits", "mixed_throughput",
    "pareto_frontier", "read_ledger", "run_autotune", "select_survivors",
    "sensitivity_by_bits", "uniform_throughput",
]
