"""Declarative recipe search space -> concrete trial list.

A ``SearchSpace`` names the axes the sweep varies (bit-widths,
calibration methods, TGQ group counts, and — under 'ho' only — the
MRQ/TGQ structure switches); :func:`expand` takes the cartesian product,
drops combinations ``quantize()`` would reject, and dedupes by recipe
content hash so the driver never runs the same calibration twice.

The knob asymmetry is inherited from the API, not invented here:
``quantize(method='range')`` REJECTS non-default HO-only fields
(``use_mrq``/``use_tgq``/``rounds``/``n_alpha``/...), so those axes
expand only under 'ho' while 'range' rows always carry the full default
MRQ+TGQ structure. Encoding that rule in expansion (rather than letting
trials fail at run time) keeps the ledger free of dead entries.

Besides uniform-precision trials the space can request AdaTSQ-style
MIXED trials (``bit_budgets``): one trial per mean-bit budget, realized
at evaluation time by scoring each TGQ timestep group's noise-MSE
sensitivity per component bit-width and greedily assigning bits under
the budget (``repro.autotune.evaluate.allocate_bits``). A mixed trial
carries the full set of uniform component recipes it composes; its
ledger key hashes the budget plus the component hashes, so it cache-hits
on resume exactly like a uniform trial.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import List, Optional, Tuple

from repro.quant.recipe import ATTN_IMPLS, BITS, METHODS, QuantRecipe


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The axes of one sweep. Tuples are alternatives (cartesian
    product); scalars are shared by every trial.

    bits / methods / tgq_groups : swept axes. ``tgq_groups`` entries of
        ``None`` inherit the DiffusionCfg's group count.
    use_mrq / use_tgq : structure switches — swept ONLY under 'ho'
        (range rows pin both True; see module docstring).
    bit_budgets : mean weight-bit budgets for AdaTSQ-style mixed trials
        (empty = uniform-only sweep). Requires >= 2 bits levels.
    attn_impl / seed / n_per_group / calib_batch : shared trial knobs.
    ho_rounds / ho_n_alpha : search effort for 'ho' rows (the recipe
        defaults are table-grade; sweeps usually want them smaller).
    """
    bits: Tuple[str, ...] = ("w8a8", "w6a6", "w4a4")
    methods: Tuple[str, ...] = ("range",)
    tgq_groups: Tuple[Optional[int], ...] = (None,)
    use_mrq: Tuple[bool, ...] = (True,)
    use_tgq: Tuple[bool, ...] = (True,)
    bit_budgets: Tuple[float, ...] = ()
    attn_impl: str = "flash"
    seed: int = 0
    n_per_group: int = 4
    calib_batch: int = 4
    ho_rounds: int = 2
    ho_n_alpha: int = 8

    def __post_init__(self):
        for f in ("bits", "methods", "tgq_groups", "use_mrq", "use_tgq",
                  "bit_budgets"):
            object.__setattr__(self, f, tuple(getattr(self, f)))
        bad = [b for b in self.bits if b not in BITS]
        if bad:
            raise ValueError(f"unknown bits levels {bad}; "
                             f"supported: {sorted(BITS)}")
        bad = [m for m in self.methods if m not in METHODS]
        if bad:
            raise ValueError(f"unknown methods {bad}; "
                             f"supported: {list(METHODS)}")
        if self.attn_impl not in ATTN_IMPLS:
            raise ValueError(f"unknown attn_impl {self.attn_impl!r}; "
                             f"supported: {list(ATTN_IMPLS)}")
        if not (self.bits and self.methods and self.tgq_groups):
            raise ValueError("bits, methods and tgq_groups must each "
                             "have at least one entry")
        if self.bit_budgets and len(set(self.bits)) < 2:
            raise ValueError("bit_budgets (mixed trials) need >= 2 "
                             "distinct bits levels to allocate between")
        wb = sorted(BITS[b][0] for b in set(self.bits))
        for budget in self.bit_budgets:
            if not wb[0] <= float(budget) <= wb[-1]:
                raise ValueError(
                    f"bit budget {budget} outside the achievable mean-bit "
                    f"range [{wb[0]}, {wb[-1]}] of levels {sorted(set(self.bits))}")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for f in ("bits", "methods", "tgq_groups", "use_mrq", "use_tgq",
                  "bit_budgets"):
            d[f] = list(d[f])
        return d

    def content_hash(self) -> str:
        """Identity of the sweep definition — written into the ledger
        header so a resume against a DIFFERENT space fails fast instead
        of silently mixing trial sets."""
        doc = json.dumps(self.to_dict(), sort_keys=True,
                         separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Trial:
    """One ledger-keyed unit of work.

    kind='uniform': ``recipe`` is the full QuantRecipe; the key is its
    content hash. kind='mixed': ``budget`` is the mean weight-bit
    budget and ``components`` the uniform recipes (sorted by wbits)
    whose artifacts the allocation composes; the key hashes budget +
    component hashes, so it inherits content-identity from them.
    """
    kind: str
    label: str
    recipe: Optional[QuantRecipe] = None
    budget: Optional[float] = None
    components: Tuple[QuantRecipe, ...] = ()

    def key(self) -> str:
        if self.kind == "uniform":
            return self.recipe.content_hash()
        doc = json.dumps(
            {"kind": "mixed", "budget": float(self.budget),
             "components": [r.content_hash() for r in self.components]},
            sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "label": self.label, "key": self.key()}
        if self.kind == "uniform":
            d["recipe"] = self.recipe.to_dict()
        else:
            d["budget"] = float(self.budget)
            d["components"] = [r.to_dict() for r in self.components]
        return d


def _label(recipe: QuantRecipe) -> str:
    parts = [recipe.bits, recipe.method]
    if recipe.tgq_groups is not None:
        parts.append(f"G{recipe.tgq_groups}")
    if recipe.method == "ho":
        if not recipe.use_mrq:
            parts.append("nomrq")
        if not recipe.use_tgq:
            parts.append("notgq")
    return "/".join(parts)


def expand(space: SearchSpace) -> List[Trial]:
    """The concrete trial list: uniform recipes (deduped by content
    hash, grid order preserved) followed by one mixed trial per bit
    budget. Mixed components are the *default-structure* recipe of each
    distinct bits level under the space's first method/group setting —
    guaranteed (by construction here) to also appear as uniform trials,
    so the driver has their artifacts and per-group sensitivities in
    hand before any mixed trial runs."""
    trials: List[Trial] = []
    seen = set()

    def add_uniform(recipe: QuantRecipe) -> QuantRecipe:
        t = Trial(kind="uniform", label=_label(recipe), recipe=recipe)
        if t.key() not in seen:
            seen.add(t.key())
            trials.append(t)
        return recipe

    components = {}                       # bits -> component recipe
    for method in space.methods:
        for groups in space.tgq_groups:
            for bits in space.bits:
                if method == "range":
                    r = add_uniform(QuantRecipe(
                        bits=bits, method="range", tgq_groups=groups,
                        attn_impl=space.attn_impl, seed=space.seed,
                        n_per_group=space.n_per_group,
                        calib_batch=space.calib_batch))
                    components.setdefault((bits, groups), r)
                else:
                    for mrq in space.use_mrq:
                        for tgq in space.use_tgq:
                            r = add_uniform(QuantRecipe(
                                bits=bits, method="ho", tgq_groups=groups,
                                use_mrq=mrq, use_tgq=tgq,
                                rounds=space.ho_rounds,
                                n_alpha=space.ho_n_alpha,
                                attn_impl=space.attn_impl, seed=space.seed,
                                n_per_group=space.n_per_group,
                                calib_batch=space.calib_batch))
                            if mrq and tgq:
                                components.setdefault((bits, groups), r)

    if space.bit_budgets:
        g0 = space.tgq_groups[0]
        missing = sorted(b for b in set(space.bits)
                         if (b, g0) not in components)
        if missing:
            raise ValueError(
                f"mixed trials need a full-structure component recipe per "
                f"bits level, but {missing} never expanded with "
                "use_mrq=use_tgq=True — add True to those axes")
        comps = sorted(
            {b: components[(b, g0)] for b in set(space.bits)}.values(),
            key=lambda r: r.wbits)
        for budget in space.bit_budgets:
            trials.append(Trial(
                kind="mixed", label=f"mixed-b{float(budget):g}",
                budget=float(budget), components=tuple(comps)))
    return trials
