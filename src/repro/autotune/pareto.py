"""Pareto dominance over recipe trial points.

The autotune deliverable is not one recipe but the quality-vs-throughput
FRONTIER: every trial lands at (modeled requests/sec, FD), and a trial
is worth reporting iff no other trial is at least as good on both axes
and strictly better on one. Objectives are named dict keys so the same
functions serve tests, the driver, and any future objective mix (e.g.
adding an IS* axis); throughput-like keys are maximized, quality-like
keys (distances) minimized.

Guarantees (property-tested in ``tests/test_autotune.py``):

- no frontier point is dominated by ANY input point,
- every excluded point is dominated by some frontier point,
- the result is invariant under input permutation (deterministic sort
  plus stable tie-breaking on the ``key`` field when present),
- exact objective duplicates are collapsed to one representative, so a
  frontier sorted by falling throughput has STRICTLY improving quality —
  the shape ``launch/autotune.py`` asserts before emitting it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple


def _get(p, k) -> float:
    v = p[k]
    if v is None:
        raise ValueError(f"point {p.get('key', p)!r} has no value for "
                         f"objective {k!r}")
    return float(v)


def dominates(a: Dict, b: Dict, *, maximize: Sequence[str],
              minimize: Sequence[str]) -> bool:
    """True iff ``a`` is >= ``b`` on every objective and > on at least
    one (maximize keys: larger is better; minimize keys: smaller)."""
    ge = all(_get(a, k) >= _get(b, k) for k in maximize) and \
        all(_get(a, k) <= _get(b, k) for k in minimize)
    strict = any(_get(a, k) > _get(b, k) for k in maximize) or \
        any(_get(a, k) < _get(b, k) for k in minimize)
    return ge and strict


def objective_tuple(p: Dict, maximize: Sequence[str],
                    minimize: Sequence[str]) -> Tuple[float, ...]:
    """Sort key: maximized objectives negated so ascending sort walks the
    frontier from the fastest point toward the highest-quality one."""
    return tuple([-_get(p, k) for k in maximize]
                 + [_get(p, k) for k in minimize])


def pareto_frontier(points: Sequence[Dict], *,
                    maximize: Sequence[str] = ("req_per_s",),
                    minimize: Sequence[str] = ("FD",)) -> List[Dict]:
    """The non-dominated subset, sorted by falling first-maximize key.

    Exact duplicates (equal on EVERY objective) keep one representative
    — chosen by the smallest ``key`` field, so the result is stable
    under permutation of the input list."""
    pts = list(points)
    front = [p for p in pts
             if not any(dominates(q, p, maximize=maximize,
                                  minimize=minimize) for q in pts)]
    # collapse exact-objective duplicates deterministically
    by_obj: Dict[Tuple[float, ...], Dict] = {}
    for p in front:
        t = objective_tuple(p, maximize, minimize)
        cur = by_obj.get(t)
        if cur is None or str(p.get("key", "")) < str(cur.get("key", "")):
            by_obj[t] = p
    return [by_obj[t] for t in sorted(by_obj)]


def is_strict_tradeoff(frontier: Sequence[Dict], *,
                       maximize: str = "req_per_s",
                       minimize: str = "FD") -> bool:
    """True iff walking the frontier from fastest to slowest, quality
    STRICTLY improves at every step — the shape a correct frontier must
    have once duplicates are collapsed."""
    for a, b in zip(frontier, frontier[1:]):
        if not (_get(a, maximize) > _get(b, maximize)
                and _get(a, minimize) > _get(b, minimize)):
            return False
    return True
