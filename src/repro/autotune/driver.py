"""The resumable sweep driver: expand -> calibrate -> gate -> score ->
Pareto frontier, with every completed unit of work durable on disk.

Layout under ``out_dir``::

    ledger.jsonl            append-only trial ledger (the resume state)
    artifacts/<key>/        one saved QuantArtifact per uniform trial,
                            or ``mixed.json`` for a mixed trial
    BENCH_autotune.json     machine-readable sweep result
    report.md               the human-readable report

The ledger is JSONL with three row kinds. A ``header`` row pins the
space/eval-protocol content hashes plus the model/diffusion configs — a
resume under ANY changed input fails fast instead of silently mixing
incomparable scores. A ``stage1`` row marks one trial calibrated
(artifact saved) and stage-1 scored; a ``final`` row marks it fully
resolved (stage-2 scored or pruned). Rows are keyed by the trial's
CONTENT hash (``QuantRecipe.content_hash()``; mixed trials hash budget +
component hashes), not by grid position — reordering or widening the
space never invalidates completed work that still appears in it.

Resume semantics: a killed sweep restarts by re-expanding the space and
replaying the ledger. Trials with a ``final`` row are full cache hits
(no quantize, no sampling, no scoring); trials with only a ``stage1``
row skip calibration and reload their artifact from disk for stage 2; a
half-written trailing line (the kill landed mid-append) is ignored.
Because the stage-1 gate and the bit allocator are deterministic pure
functions of ledger contents, the resumed run reaches the identical
frontier — property-tested in ``tests/test_autotune.py`` and asserted
by ``make autotune-smoke``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional

from repro.quant import QuantArtifact, quantize
from repro.quant import eval as qeval  # noqa: F401  (re-export for tests)

from repro.autotune.evaluate import EvalConfig, allocate_bits, \
    mixed_throughput, select_survivors, sensitivity_by_bits, stage1, \
    stage2, uniform_throughput
from repro.autotune.pareto import is_strict_tradeoff, pareto_frontier
from repro.autotune.space import SearchSpace, Trial, expand
from repro.autotune import report as report_mod

LEDGER = "ledger.jsonl"
ARTIFACTS = "artifacts"


@dataclasses.dataclass
class AutotuneResult:
    records: List[dict]          # one final row per trial, ledger order
    frontier: List[dict]         # Pareto-optimal points, fastest first
    strict_tradeoff: bool        # quality strictly improves as req/s falls
    cache_hits: int              # trials resolved entirely from the ledger
    stage1_hits: int             # trials whose stage-1 came from the ledger
    recomputed: int              # trials that ran quantize+stage1 this run
    pruned: int
    stopped_early: bool          # max_new_stage1 kill-switch tripped
    out_dir: str


# ---------------------------------------------------------------------------
# ledger I/O
# ---------------------------------------------------------------------------
def _ledger_path(out_dir: str) -> str:
    return os.path.join(out_dir, LEDGER)


def read_ledger(out_dir: str) -> List[dict]:
    """Parse the ledger, tolerating a truncated trailing line (a kill
    mid-append leaves one; everything before it is intact because rows
    are appended with a flush per row)."""
    path = _ledger_path(out_dir)
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                break                                  # truncated tail
    return rows


def _append(out_dir: str, row: dict) -> None:
    with open(_ledger_path(out_dir), "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
        f.flush()


def _header(space: SearchSpace, ecfg: EvalConfig, model_cfg,
            dif_cfg) -> dict:
    return {"kind": "header", "version": 1,
            "space_hash": space.content_hash(),
            "eval_hash": ecfg.content_hash(),
            "space": space.to_dict(), "eval": ecfg.to_dict(),
            "model": {"class": type(model_cfg).__name__,
                      "cfg": dataclasses.asdict(model_cfg)},
            "dif": dataclasses.asdict(dif_cfg)}


def _check_header(existing: dict, fresh: dict, out_dir: str) -> None:
    for field in ("space_hash", "eval_hash", "model", "dif"):
        if existing.get(field) != fresh[field]:
            raise ValueError(
                f"ledger at {out_dir} was written under a different "
                f"{field.replace('_hash', '')} "
                f"({existing.get(field)!r} != {fresh[field]!r}); scores "
                "would not be comparable — use a fresh --out dir")


# ---------------------------------------------------------------------------
# per-trial helpers
# ---------------------------------------------------------------------------
def _effective_dif(dif_cfg, trial: Trial):
    recipe = trial.recipe if trial.kind == "uniform" \
        else trial.components[0]
    if recipe.tgq_groups is not None \
            and recipe.tgq_groups != dif_cfg.tgq_groups:
        return dataclasses.replace(dif_cfg, tgq_groups=recipe.tgq_groups)
    return dif_cfg


def _artifact_dir(out_dir: str, key: str) -> str:
    return os.path.join(out_dir, ARTIFACTS, key)


def load_trial_artifact(out_dir: str, record: dict):
    """The saved artifact behind one ledger record: a ``QuantArtifact``
    for uniform trials; for mixed trials the composite doc (allocation +
    per-bits component artifact paths) with every component loaded."""
    path = os.path.join(out_dir, record["artifact"])
    if record["trial"]["kind"] == "uniform":
        return QuantArtifact.load(path)
    with open(os.path.join(path, "mixed.json")) as f:
        doc = json.load(f)
    doc["loaded_components"] = {
        b: QuantArtifact.load(os.path.join(out_dir, rel))
        for b, rel in doc["components"].items()}
    return doc


class _TrialRunner:
    """Phase logic for one sweep, holding in-memory artifacts so a trial
    calibrated this run is not re-read from disk for stage 2."""

    def __init__(self, params, model_cfg, dif_cfg, space, ecfg, out_dir,
                 provenance, log):
        self.params, self.model_cfg, self.dif_cfg = params, model_cfg, dif_cfg
        self.space, self.ecfg, self.out_dir = space, ecfg, out_dir
        self.provenance, self.log = provenance, log
        self.artifacts: Dict[str, QuantArtifact] = {}   # trial key -> loaded

    def _artifact_for(self, trial: Trial, s1_row: dict) -> QuantArtifact:
        key = trial.key()
        if key not in self.artifacts:
            self.artifacts[key] = QuantArtifact.load(
                os.path.join(self.out_dir, s1_row["artifact"]))
        return self.artifacts[key]

    def _component_rows(self, trial: Trial, s1: Dict[str, dict]):
        rows = {}
        for comp in trial.components:
            row = s1.get(comp.content_hash())
            if row is None:                            # pragma: no cover
                raise RuntimeError(
                    f"mixed trial {trial.label} ordered before its "
                    f"component {comp.bits} — expand() broke its ordering "
                    "contract")
            rows[comp.bits] = row
        return rows

    def _mixed_ctx(self, trial: Trial, allocation: List[str],
                   s1: Dict[str, dict]):
        ctx_of_bits = {}
        for comp in trial.components:
            if comp.bits in set(allocation):
                comp_trial = Trial(kind="uniform", label="", recipe=comp)
                art = self._artifact_for(comp_trial, s1[comp.content_hash()])
                ctx_of_bits[comp.bits] = art.context(kernel=False)
        return [ctx_of_bits[b] for b in allocation]

    # -- phase A: calibrate + stage 1 ---------------------------------------
    def ensure_stage1(self, trial: Trial, s1: Dict[str, dict]) -> dict:
        key, t0 = trial.key(), time.time()
        dif = _effective_dif(self.dif_cfg, trial)
        rel = os.path.join(ARTIFACTS, key)
        row = {"kind": "stage1", "key": key, "label": trial.label,
               "trial": trial.to_dict(), "artifact": rel}
        if trial.kind == "uniform":
            art = quantize(self.params, self.model_cfg, self.dif_cfg,
                           trial.recipe, provenance=self.provenance)
            art.save(_artifact_dir(self.out_dir, key))
            self.artifacts[key] = art
            row.update(stage1(self.params, self.model_cfg, dif,
                              art.context(kernel=False), self.ecfg))
        else:
            comp_rows = self._component_rows(trial, s1)
            sens = sensitivity_by_bits(comp_rows)
            allocation = allocate_bits(sens, trial.budget)
            row["allocation"] = allocation
            ctx = self._mixed_ctx(trial, allocation, s1)
            row.update(stage1(self.params, self.model_cfg, dif, ctx,
                              self.ecfg))
            os.makedirs(_artifact_dir(self.out_dir, key), exist_ok=True)
            doc = {"kind": "mixed", "budget": trial.budget,
                   "allocation": allocation,
                   "components": {c.bits: os.path.join(
                       ARTIFACTS, c.content_hash())
                       for c in trial.components},
                   "component_hashes": {c.bits: c.content_hash()
                                        for c in trial.components}}
            with open(os.path.join(_artifact_dir(self.out_dir, key),
                                   "mixed.json"), "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
        row["wall_s"] = round(time.time() - t0, 3)
        _append(self.out_dir, row)
        return row

    # -- throughput (closed-form; never cached) -----------------------------
    def throughput(self, trial: Trial, s1_row: dict) -> Dict[str, float]:
        if trial.kind == "uniform":
            return uniform_throughput(trial.recipe, self.ecfg)
        return mixed_throughput(
            s1_row["allocation"], trial.components[0].attn_impl,
            _effective_dif(self.dif_cfg, trial), self.ecfg)

    # -- phase C: stage 2 ---------------------------------------------------
    def finalize(self, trial: Trial, s1_row: dict, survived: bool,
                 s1: Dict[str, dict]) -> dict:
        key, t0 = trial.key(), time.time()
        dif = _effective_dif(self.dif_cfg, trial)
        metrics = {"noise_mse": s1_row["noise_mse"],
                   "noise_mse_by_group": s1_row["noise_mse_by_group"]}
        metrics.update(self.throughput(trial, s1_row))
        if survived:
            if trial.kind == "uniform":
                ctx = self._artifact_for(trial, s1_row).context(kernel=False)
            else:
                ctx = self._mixed_ctx(trial, s1_row["allocation"], s1)
            metrics.update(stage2(self.params, self.model_cfg, dif, ctx,
                                  self.ecfg))
        row = {"kind": "final", "key": key, "label": trial.label,
               "trial": trial.to_dict(), "artifact": s1_row["artifact"],
               "status": "ok" if survived else "pruned",
               "metrics": metrics}
        if "allocation" in s1_row:
            row["allocation"] = s1_row["allocation"]
        row["wall_s"] = round(time.time() - t0, 3)
        _append(self.out_dir, row)
        return row


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------
def run(params, model_cfg, dif_cfg, space: SearchSpace, ecfg: EvalConfig,
        out_dir: str, *, provenance: Optional[dict] = None,
        log: Callable[[str], None] = print,
        max_new_stage1: Optional[int] = None) -> AutotuneResult:
    """Run (or resume) one sweep. ``max_new_stage1`` stops the run after
    that many NEWLY-computed stage-1 trials — the test hook simulating a
    killed sweep without killing the process (no outputs are written for
    such a partial run)."""
    os.makedirs(os.path.join(out_dir, ARTIFACTS), exist_ok=True)
    trials = expand(space)
    fresh_header = _header(space, ecfg, model_cfg, dif_cfg)
    rows = read_ledger(out_dir)
    if rows:
        if rows[0].get("kind") != "header":            # pragma: no cover
            raise ValueError(f"ledger at {out_dir} has no header row")
        _check_header(rows[0], fresh_header, out_dir)
    else:
        _append(out_dir, fresh_header)

    s1 = {r["key"]: r for r in rows if r.get("kind") == "stage1"}
    finals = {r["key"]: r for r in rows if r.get("kind") == "final"}
    runner = _TrialRunner(params, model_cfg, dif_cfg, space, ecfg,
                          out_dir, provenance, log)

    # -- phase A: every trial calibrated + stage-1 scored -------------------
    new_s1 = 0
    stage1_hits = 0
    for trial in trials:
        key = trial.key()
        if key in s1:
            stage1_hits += 1
            continue
        if max_new_stage1 is not None and new_s1 >= max_new_stage1:
            log(f"[autotune] stopping early after {new_s1} new stage-1 "
                "trials (max_new_stage1)")
            return AutotuneResult(
                records=[], frontier=[], strict_tradeoff=False,
                cache_hits=len(finals), stage1_hits=stage1_hits,
                recomputed=new_s1, pruned=0, stopped_early=True,
                out_dir=out_dir)
        log(f"[autotune] stage1 {trial.label} ({key})")
        s1[key] = runner.ensure_stage1(trial, s1)
        new_s1 += 1

    # -- phase B: the deterministic gate ------------------------------------
    mse = {t.key(): s1[t.key()]["noise_mse"] for t in trials}
    req = {t.key(): runner.throughput(t, s1[t.key()])["req_per_s"]
           for t in trials}
    survivors = set(select_survivors(mse, req, ecfg))

    # -- phase C: stage 2 for survivors, final rows for everyone ------------
    records, cache_hits = [], 0
    for trial in trials:
        key = trial.key()
        if key in finals:
            cache_hits += 1
            records.append(finals[key])
            continue
        verdict = "stage2" if key in survivors else "pruned"
        log(f"[autotune] {verdict} {trial.label} ({key})")
        records.append(runner.finalize(trial, s1[key], key in survivors,
                                       s1))

    # -- frontier + outputs --------------------------------------------------
    points = [_point(r) for r in records if r["status"] == "ok"]
    frontier = pareto_frontier(points)
    result = AutotuneResult(
        records=records, frontier=frontier,
        strict_tradeoff=is_strict_tradeoff(frontier),
        cache_hits=cache_hits, stage1_hits=stage1_hits,
        recomputed=new_s1,
        pruned=sum(1 for r in records if r["status"] == "pruned"),
        stopped_early=False, out_dir=out_dir)
    write_outputs(result, fresh_header)
    return result


def _point(record: dict) -> dict:
    m = record["metrics"]
    p = {"key": record["key"], "label": record["label"],
         "kind": record["trial"]["kind"], "artifact": record["artifact"],
         "req_per_s": m["req_per_s"], "ms_per_step": m["ms_per_step"],
         "path": m.get("path"), "noise_mse": m["noise_mse"],
         "FD": m["FD"], "sFD": m["sFD"], "IS*": m["IS*"]}
    if record["trial"]["kind"] == "uniform":
        p["bits"] = record["trial"]["recipe"]["bits"]
    else:
        p["allocation"] = record["allocation"]
        p["mean_bits"] = m.get("mean_bits")
    return p


def write_outputs(result: AutotuneResult, header: dict) -> None:
    """BENCH_autotune.json + report.md. Deterministic given the ledger
    (wall-clock fields stay in the ledger only), so a fully-cache-hit
    resume rewrites byte-identical outputs."""
    doc = {
        "meta": {k: header[k] for k in ("space", "eval", "model", "dif",
                                        "space_hash", "eval_hash")},
        "n_trials": len(result.records),
        "n_pruned": result.pruned,
        "strict_tradeoff": result.strict_tradeoff,
        "trials": [{k: v for k, v in r.items() if k != "wall_s"}
                   for r in result.records],
        "frontier": result.frontier,
    }
    with open(os.path.join(result.out_dir, "BENCH_autotune.json"),
              "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    with open(os.path.join(result.out_dir, "report.md"), "w") as f:
        f.write(report_mod.render_report(doc))
