"""Markdown rendering of one sweep result (pure: doc dict -> str).

The doc is exactly the ``BENCH_autotune.json`` payload the driver
writes, so the report can be regenerated from the JSON alone — and a
fully-cache-hit resume rewrites it byte-identically.
"""
from __future__ import annotations

from typing import List


def _fmt(v, nd: int = 3) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def _table(headers: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    out += ["| " + " | ".join(r) + " |" for r in rows]
    return out


def _bits_of(rec: dict) -> str:
    if rec["trial"]["kind"] == "uniform":
        return rec["trial"]["recipe"]["bits"]
    alloc = rec.get("allocation") or []
    return "[" + " ".join(b.replace("w", "").split("a")[0]
                          for b in alloc) + "]"


def render_report(doc: dict) -> str:
    meta = doc["meta"]
    model = meta["model"]["cfg"]
    lines = [
        "# Autotune sweep report",
        "",
        f"Model: `{meta['model']['class']}` d_model={model['d_model']} "
        f"layers={model['n_layers']} img={model['img_size']} — "
        f"T={meta['dif']['T']}, {meta['dif']['tgq_groups']} TGQ groups.",
        f"Space `{meta['space_hash']}` × eval protocol "
        f"`{meta['eval_hash']}`: {doc['n_trials']} trials, "
        f"{doc['n_pruned']} pruned at stage 1.",
        "",
        "## Pareto frontier (fastest → highest quality)",
        "",
    ]
    rows = [[p["label"], _fmt(p.get("bits") or
                              "mean " + _fmt(p.get("mean_bits"), 2) + "b"),
             _fmt(p["req_per_s"], 2), _fmt(p["ms_per_step"], 2),
             _fmt(p["FD"]), _fmt(p["sFD"]), _fmt(p["IS*"]),
             _fmt(p["noise_mse"], 5), f"`{p['artifact']}`"]
            for p in doc["frontier"]]
    lines += _table(["recipe", "bits", "req/s", "ms/step", "FD", "sFD",
                     "IS*", "noise-MSE", "artifact"], rows)
    lines += [
        "",
        "Strict quality-vs-throughput trade-off along the frontier: "
        + ("**yes** — FD strictly improves as modeled req/s falls."
           if doc["strict_tradeoff"] else
           "**no** (duplicate objective values survived — inspect "
           "trials)."),
        "",
        "## All trials",
        "",
    ]
    rows = []
    for r in sorted(doc["trials"],
                    key=lambda r: -r["metrics"]["req_per_s"]):
        m = r["metrics"]
        rows.append([r["label"], _bits_of(r), r["status"],
                     _fmt(m["req_per_s"], 2), _fmt(m.get("FD")),
                     _fmt(m["noise_mse"], 5), r["key"]])
    lines += _table(["recipe", "bits", "status", "req/s", "FD",
                     "noise-MSE", "ledger key"], rows)

    mixed = [r for r in doc["trials"] if r["trial"]["kind"] == "mixed"]
    if mixed:
        lines += ["", "## Mixed-precision allocations", "",
                  "Per-TGQ-group weight bits chosen greedily from the "
                  "components' per-group noise-MSE sensitivity under "
                  "each mean-bit budget:", ""]
        rows = [[r["label"], _fmt(r["trial"]["budget"], 2),
                 " ".join(b.replace("w", "").split("a")[0]
                          for b in r["allocation"])]
                for r in mixed]
        lines += _table(["trial", "budget (mean bits)",
                         "bits per group g0..gG"], rows)
    lines += ["", "Every `ok` trial's artifact loads with "
              "`QuantArtifact.load(<out_dir>/artifacts/<key>)`; mixed "
              "trials store `mixed.json` naming their component "
              "artifacts. Resume by re-running the same command — "
              "completed trials cache-hit from `ledger.jsonl`.", ""]
    return "\n".join(lines)
