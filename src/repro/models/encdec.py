"""Encoder-decoder transformer (Whisper-style backbone).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: model inputs are precomputed frame embeddings
``frames: (B, enc_seq, d_model)``. Decoder is a standard causal
transformer with cross-attention into the encoder memory; GELU MLPs and
LayerNorm (Whisper convention), learned decoder positions, fixed
sinusoidal encoder positions.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.ctx import FPContext
from repro.nn.attention import (
    attention_init, attention_apply, attention_prefill, attention_decode,
    kv_cache_init, cross_attention_cache, cross_attention_decode,
)
from repro.nn.layers import (
    embedding_init, embedding_apply, embedding_logits,
    layernorm_init, layernorm_apply, sincos_2d,
)
from repro.nn.mlp import mlp_init, mlp_apply
from repro.models.config import ModelCfg
from repro.models.lm import ce_loss

_FP = FPContext()


def _sincos_1d(d, n):
    import numpy as np
    omega = 1.0 / 10000 ** (np.arange(d // 2, dtype=np.float64) / (d / 2.0))
    out = np.einsum("p,f->pf", np.arange(n, dtype=np.float64), omega)
    return jnp.asarray(
        np.concatenate([np.sin(out), np.cos(out)], axis=1), jnp.float32)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def enc_block_init(key, cfg: ModelCfg):
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "norm1": layernorm_init(ks[0], cfg.d_model, dt),
        "attn": attention_init(ks[1], cfg.attn_cfg(), dt),
        "norm2": layernorm_init(ks[2], cfg.d_model, dt),
        "mlp": mlp_init(ks[3], cfg.mlp_cfg(), dt),
    }


def dec_block_init(key, cfg: ModelCfg):
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    return {
        "norm1": layernorm_init(ks[0], cfg.d_model, dt),
        "attn": attention_init(ks[1], cfg.attn_cfg(), dt),
        "norm_x": layernorm_init(ks[2], cfg.d_model, dt),
        "xattn": attention_init(ks[3], cfg.attn_cfg(cross=True), dt),
        "norm2": layernorm_init(ks[4], cfg.d_model, dt),
        "mlp": mlp_init(ks[5], cfg.mlp_cfg(), dt),
    }


def enc_block_apply(p, cfg: ModelCfg, x, *, ctx=_FP, name="enc"):
    h = layernorm_apply(p["norm1"], x)
    x = x + attention_apply(p["attn"], cfg.attn_cfg(), h, ctx=ctx,
                            name=f"{name}/attn", causal=False, window=None)
    h = layernorm_apply(p["norm2"], x)
    x = x + mlp_apply(p["mlp"], cfg.mlp_cfg(), h, ctx=ctx, name=f"{name}/mlp")
    return x


def dec_block_apply(p, cfg: ModelCfg, x, memory, *, ctx=_FP, name="dec",
                    positions=None):
    h = layernorm_apply(p["norm1"], x)
    x = x + attention_apply(p["attn"], cfg.attn_cfg(), h, ctx=ctx,
                            name=f"{name}/attn", positions=positions)
    h = layernorm_apply(p["norm_x"], x)
    x = x + attention_apply(p["xattn"], cfg.attn_cfg(cross=True), h, ctx=ctx,
                            name=f"{name}/xattn", kv_x=memory, causal=False)
    h = layernorm_apply(p["norm2"], x)
    x = x + mlp_apply(p["mlp"], cfg.mlp_cfg(), h, ctx=ctx, name=f"{name}/mlp")
    return x


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------
def encdec_init(key, cfg: ModelCfg):
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": embedding_init(ks[2], cfg.vocab, cfg.d_model, dt),
        "dec_pos": init.normal(0.01)(ks[3], (cfg.max_seq, cfg.d_model), dt),
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "enc_norm": layernorm_init(ks[4], cfg.d_model, dt),
        "dec_norm": layernorm_init(ks[5], cfg.d_model, dt),
    }


def encode(p, cfg: ModelCfg, frames, *, ctx=_FP):
    """frames: (B, enc_seq, d) precomputed embeddings (frontend stub)."""
    x = frames.astype(cfg.jdtype)
    x = x + _sincos_1d(cfg.d_model, frames.shape[1]).astype(cfg.jdtype)[None]
    if cfg.scan_layers:
        def body(h, xs):
            bp, li = xs
            return enc_block_apply(bp, cfg, h, ctx=ctx.at_layer(li), name="enc"), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (p["enc_blocks"], jnp.arange(cfg.n_enc_layers)))
    else:
        for i in range(cfg.n_enc_layers):
            bp = jax.tree.map(lambda a: a[i], p["enc_blocks"])
            x = enc_block_apply(bp, cfg, x, ctx=ctx.at_layer(i), name=f"enc{i}")
    return layernorm_apply(p["enc_norm"], x)


def decode_train(p, cfg: ModelCfg, tokens, memory, *, ctx=_FP):
    """Teacher-forced decoder forward to logits."""
    B, S = tokens.shape
    x = embedding_apply(p["embed"], tokens).astype(cfg.jdtype)
    x = x + p["dec_pos"][:S][None]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.scan_layers:
        def body(h, xs):
            bp, li = xs
            return dec_block_apply(bp, cfg, h, memory, ctx=ctx.at_layer(li),
                                   name="dec", positions=positions), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (p["dec_blocks"], jnp.arange(cfg.n_layers)))
    else:
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], p["dec_blocks"])
            x = dec_block_apply(bp, cfg, x, memory, ctx=ctx.at_layer(i),
                                name=f"dec{i}", positions=positions)
    x = layernorm_apply(p["dec_norm"], x)
    return embedding_logits(p["embed"], x, ctx=ctx, name="lm_head")


def encdec_loss_fn(p, cfg: ModelCfg, batch, *, ctx=_FP):
    """batch: {'frames': (B,enc_seq,d), 'tokens': (B,S), 'labels': (B,S)}."""
    memory = encode(p, cfg, batch["frames"], ctx=ctx)
    logits = decode_train(p, cfg, batch["tokens"], memory, ctx=ctx)
    loss = ce_loss(logits, batch["labels"])
    return loss, {"ce": loss}


# ---------------------------------------------------------------------------
# serving: prefill + decode with self-KV cache and fixed cross-KV cache
# ---------------------------------------------------------------------------
def encdec_cache_init(cfg: ModelCfg, batch, max_len, dtype=None):
    dtype = dtype or cfg.jdtype
    one_kv = kv_cache_init(cfg.attn_cfg(), batch, max_len, dtype)
    one_x = {
        "k": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    L = cfg.n_layers
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), t)
    return {"kv": stack(one_kv), "xkv": stack(one_x)}


def encdec_prefill(p, cfg: ModelCfg, tokens, frames, *, ctx=_FP, max_len=None):
    """Encode memory, precompute cross K/V, prefill decoder self-cache."""
    B, S = tokens.shape
    max_len = max_len or S
    memory = encode(p, cfg, frames, ctx=ctx)
    x = embedding_apply(p["embed"], tokens).astype(cfg.jdtype)
    x = x + p["dec_pos"][:S][None]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def one_layer(bp, h, li, name):
        hh = layernorm_apply(bp["norm1"], h)
        ya, kv = attention_prefill(bp["attn"], cfg.attn_cfg(), hh,
                                   ctx=ctx.at_layer(li), name=f"{name}/attn",
                                   positions=positions, max_len=max_len)
        h = h + ya
        hh = layernorm_apply(bp["norm_x"], h)
        xkv = cross_attention_cache(bp["xattn"], cfg.attn_cfg(cross=True),
                                    memory, ctx=ctx.at_layer(li), name=f"{name}/xattn")
        h = h + cross_attention_decode(bp["xattn"], cfg.attn_cfg(cross=True), hh,
                                       xkv, ctx=ctx.at_layer(li), name=f"{name}/xattn")
        hh = layernorm_apply(bp["norm2"], h)
        h = h + mlp_apply(bp["mlp"], cfg.mlp_cfg(), hh, ctx=ctx.at_layer(li),
                          name=f"{name}/mlp")
        return h, {"kv": kv, "xkv": xkv}

    if cfg.scan_layers:
        def body(h, xs):
            bp, li = xs
            return one_layer(bp, h, li, "dec")
        if cfg.remat:
            body = jax.checkpoint(body)
        x, cache = jax.lax.scan(body, x, (p["dec_blocks"], jnp.arange(cfg.n_layers)))
    else:
        caches = []
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], p["dec_blocks"])
            x, c = one_layer(bp, x, i, f"dec{i}")
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    x = layernorm_apply(p["dec_norm"], x[:, -1:])
    return embedding_logits(p["embed"], x, ctx=ctx, name="lm_head"), cache


def encdec_decode_step(p, cfg: ModelCfg, token, cache, index, *, ctx=_FP):
    """One decoder step against self-KV + fixed cross-KV caches."""
    x = embedding_apply(p["embed"], token).astype(cfg.jdtype)
    x = x + jax.lax.dynamic_slice_in_dim(p["dec_pos"], index, 1, axis=0)[None]

    def one_layer(bp, h, c, li, name):
        hh = layernorm_apply(bp["norm1"], h)
        ya, kv = attention_decode(bp["attn"], cfg.attn_cfg(), hh, c["kv"], index,
                                  ctx=ctx.at_layer(li), name=f"{name}/attn")
        h = h + ya
        hh = layernorm_apply(bp["norm_x"], h)
        h = h + cross_attention_decode(bp["xattn"], cfg.attn_cfg(cross=True), hh,
                                       c["xkv"], ctx=ctx.at_layer(li),
                                       name=f"{name}/xattn")
        hh = layernorm_apply(bp["norm2"], h)
        h = h + mlp_apply(bp["mlp"], cfg.mlp_cfg(), hh, ctx=ctx.at_layer(li),
                          name=f"{name}/mlp")
        return h, {"kv": kv, "xkv": c["xkv"]}

    if cfg.scan_layers:
        def body(h, xs):
            bp, c, li = xs
            return one_layer(bp, h, c, li, "dec")
        x, cache = jax.lax.scan(
            body, x, (p["dec_blocks"], cache, jnp.arange(cfg.n_layers)))
    else:
        new = []
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], p["dec_blocks"])
            c = jax.tree.map(lambda a: a[i], cache)
            x, c = one_layer(bp, x, c, i, f"dec{i}")
            new.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new)

    x = layernorm_apply(p["dec_norm"], x)
    return embedding_logits(p["embed"], x, ctx=ctx, name="lm_head"), cache
