"""Model assembly: decoder-only LMs (all assigned families), the Whisper
encoder-decoder backbone, and the DiT diffusion transformer."""
from repro.models.config import ModelCfg
from repro.models.lm import (
    lm_init, lm_apply, lm_loss_fn, lm_prefill, lm_decode_step, lm_cache_init,
    lm_generate, ce_loss,
)
from repro.models.encdec import (
    encdec_init, encode, decode_train, encdec_loss_fn, encdec_prefill,
    encdec_decode_step, encdec_cache_init,
)
from repro.models.dit import (
    DiTCfg, dit_init, dit_apply, dit_apply_cfg_guidance, patchify, unpatchify,
)
