"""Decoder-only LM assembly for every assigned architecture family.

One parameter layout for all families: per-layer params are ALWAYS stacked
with a leading ``L`` axis (built with ``jax.vmap`` over layer keys), which
gives a uniform checkpoint format and lets ``cfg.scan_layers`` switch
between a ``lax.scan`` over layers (compile-time O(1), used by the
multi-pod dry-run) and a Python loop (used by the PTQ engine, which wants
layer-distinct op names such as ``blk3/attn/qk``).

Step functions:
  - ``loss_fn`` / ``train-step builders`` — next-token CE (+ MoE aux),
  - ``prefill``   — full-sequence forward building the decode cache,
  - ``decode_step`` — one token against the cache (KV / SSM state / both).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.ctx import FPContext
from repro.nn.attention import (
    AttnCfg, attention_init, attention_apply, attention_decode,
    attention_prefill, kv_cache_init, mla_init, mla_apply, mla_prefill,
    mla_decode, mla_cache_init,
)
from repro.nn.layers import (
    embedding_init, embedding_apply, embedding_logits,
    layernorm_init, layernorm_apply, rmsnorm_init, rmsnorm_apply,
    linear_init,
)
from repro.nn.mlp import mlp_init, mlp_apply, moe_init, moe_apply
from repro.nn.ssm import (
    ssd_init, ssd_apply, ssd_decode, ssd_state_init,
)
from repro.models.config import ModelCfg

_FP = FPContext()


# ---------------------------------------------------------------------------
# norms (dispatch on cfg.norm)
# ---------------------------------------------------------------------------
def _norm_init(key, cfg: ModelCfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return layernorm_init(key, d, cfg.jdtype)
    return rmsnorm_init(key, d, cfg.jdtype)


def _norm_apply(p, cfg: ModelCfg, x):
    if cfg.norm == "layernorm":
        return layernorm_apply(p, x)
    return rmsnorm_apply(p, x)


# ---------------------------------------------------------------------------
# single block: init
# ---------------------------------------------------------------------------
def block_init(key, cfg: ModelCfg):
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    p: Dict[str, Any] = {"norm1": _norm_init(ks[0], cfg)}
    if cfg.block_type in ("attn_mlp", "hymba"):
        if cfg.attn_type == "mla":
            p["attn"] = mla_init(ks[1], cfg.mla_cfg(), dt)
        else:
            p["attn"] = attention_init(ks[1], cfg.attn_cfg(window=cfg.window), dt)
        if cfg.block_type == "attn_mlp":
            p["norm2"] = _norm_init(ks[2], cfg)
            if cfg.moe:
                p["mlp"] = moe_init(ks[3], cfg.moe_cfg(), dt)
            elif cfg.d_ff:
                p["mlp"] = mlp_init(ks[3], cfg.mlp_cfg(), dt)
    if cfg.block_type in ("ssm_only", "hymba"):
        p["ssm"] = ssd_init(ks[4], cfg.ssd_cfg(), dt)
        if cfg.block_type == "hymba":
            # per-branch output norms for head fusion (Hymba §3.2)
            p["attn_out_norm"] = rmsnorm_init(ks[5], cfg.d_model, dt)
            p["ssm_out_norm"] = rmsnorm_init(ks[6], cfg.d_model, dt)
            p["norm2"] = _norm_init(ks[2], cfg)
            p["mlp"] = mlp_init(ks[3], cfg.mlp_cfg(), dt)
    if cfg.block_type == "ssm_only" and cfg.d_ff:
        p["norm2"] = _norm_init(ks[2], cfg)
        p["mlp"] = mlp_init(ks[3], cfg.mlp_cfg(), dt)
    return p


# ---------------------------------------------------------------------------
# single block: forward (full sequence)
# ---------------------------------------------------------------------------
def _mixer_fwd(p, cfg: ModelCfg, x, *, ctx, name, positions, window, impl):
    """Token mixer (full sequence). window: dynamic per-layer window (may be
    a traced scalar under scan-over-layers) or None for plain causal."""
    if cfg.attn_type == "mla":
        return mla_apply(p["attn"], cfg.mla_cfg(), x, ctx=ctx, name=f"{name}/attn",
                         positions=positions, impl=impl)
    acfg = cfg.attn_cfg(window=None)
    return attention_apply(p["attn"], acfg, x, ctx=ctx, name=f"{name}/attn",
                           positions=positions, impl=impl, window=window)


def _mlp_fwd(p, cfg: ModelCfg, x, *, ctx, name):
    if cfg.moe:
        return moe_apply(p["mlp"], cfg.moe_cfg(), x, ctx=ctx, name=f"{name}/moe")
    y = mlp_apply(p["mlp"], cfg.mlp_cfg(), x, ctx=ctx, name=f"{name}/mlp")
    return y, {"aux_loss": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


def block_apply(p, cfg: ModelCfg, x, *, ctx=_FP, name="blk", positions=None,
                window=None, impl=None):
    """Full-sequence block forward. Returns (x, aux)."""
    impl = impl or cfg.attn_impl
    aux = {"aux_loss": jnp.float32(0.0), "router_z": jnp.float32(0.0)}
    h = _norm_apply(p["norm1"], cfg, x)
    if cfg.block_type == "attn_mlp":
        x = x + _mixer_fwd(p, cfg, h, ctx=ctx, name=name, positions=positions,
                           window=window, impl=impl)
        if "mlp" in p:
            h2 = _norm_apply(p["norm2"], cfg, x)
            y, aux = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
            x = x + y
    elif cfg.block_type == "ssm_only":
        x = x + ssd_apply(p["ssm"], cfg.ssd_cfg(), h, ctx=ctx, name=f"{name}/ssm")
        if "mlp" in p:
            h2 = _norm_apply(p["norm2"], cfg, x)
            y, aux = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
            x = x + y
    elif cfg.block_type == "hymba":
        ya = _mixer_fwd(p, cfg, h, ctx=ctx, name=name, positions=positions,
                        window=window, impl=impl)
        ys = ssd_apply(p["ssm"], cfg.ssd_cfg(), h, ctx=ctx, name=f"{name}/ssm")
        ya = rmsnorm_apply(p["attn_out_norm"], ya)
        ys = rmsnorm_apply(p["ssm_out_norm"], ys)
        x = x + 0.5 * (ya + ys)                       # mean-fused parallel heads
        h2 = _norm_apply(p["norm2"], cfg, x)
        y, aux = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
        x = x + y
    else:
        raise ValueError(cfg.block_type)
    return x, aux


# ---------------------------------------------------------------------------
# single block: prefill / decode (cache-carrying)
# ---------------------------------------------------------------------------
def block_cache_init(cfg: ModelCfg, batch, max_len, dtype=None):
    """Decode cache for ONE layer (stacked by the model-level init)."""
    dtype = dtype or cfg.jdtype
    c: Dict[str, Any] = {}
    if cfg.block_type in ("attn_mlp", "hymba"):
        if cfg.attn_type == "mla":
            c["kv"] = mla_cache_init(cfg.mla_cfg(), batch, max_len, dtype)
        else:
            # uniform cache size across layers so stacking works; sliding-
            # window layers mask within the full buffer (hybrid archs mix
            # windowed + global layers under one scan).
            acfg = cfg.attn_cfg(window=None)
            c["kv"] = kv_cache_init(acfg, batch, max_len, dtype)
    if cfg.block_type in ("ssm_only", "hymba"):
        c["ssm"] = ssd_state_init(cfg.ssd_cfg(), batch, dtype)
    return c


def block_prefill(p, cfg: ModelCfg, x, *, ctx=_FP, name="blk", positions=None,
                  window=None, max_len=None, impl=None):
    """Forward + cache build. Returns (x, cache)."""
    impl = impl or cfg.attn_impl
    cache: Dict[str, Any] = {}
    h = _norm_apply(p["norm1"], cfg, x)
    if cfg.block_type in ("attn_mlp", "hymba"):
        if cfg.attn_type == "mla":
            ya, cache["kv"] = mla_prefill(p["attn"], cfg.mla_cfg(), h, ctx=ctx,
                                          name=f"{name}/attn", positions=positions,
                                          impl=impl, max_len=max_len)
        else:
            # uniform full-size cache (see block_cache_init); window only
            # tightens the attention mask.
            acfg = cfg.attn_cfg(window=None)
            ya, cache["kv"] = attention_prefill(
                p["attn"], acfg, h, ctx=ctx, name=f"{name}/attn",
                positions=positions, impl=impl, max_len=max_len,
                window=window, full_cache=True)
    if cfg.block_type == "attn_mlp":
        x = x + ya
        if "mlp" in p:
            h2 = _norm_apply(p["norm2"], cfg, x)
            y, _ = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
            x = x + y
    elif cfg.block_type == "ssm_only":
        ys, cache["ssm"] = ssd_apply(p["ssm"], cfg.ssd_cfg(), h, ctx=ctx,
                                     name=f"{name}/ssm", return_state=True)
        x = x + ys
        if "mlp" in p:
            h2 = _norm_apply(p["norm2"], cfg, x)
            y, _ = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
            x = x + y
    elif cfg.block_type == "hymba":
        ys, cache["ssm"] = ssd_apply(p["ssm"], cfg.ssd_cfg(), h, ctx=ctx,
                                     name=f"{name}/ssm", return_state=True)
        ya = rmsnorm_apply(p["attn_out_norm"], ya)
        ys = rmsnorm_apply(p["ssm_out_norm"], ys)
        x = x + 0.5 * (ya + ys)
        h2 = _norm_apply(p["norm2"], cfg, x)
        y, _ = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
        x = x + y
    return x, cache


def block_decode(p, cfg: ModelCfg, x, cache, index, *, ctx=_FP, name="blk",
                 window=None):
    """One-token decode. x: (B,1,d). Returns (x, cache)."""
    h = _norm_apply(p["norm1"], cfg, x)
    new_cache: Dict[str, Any] = {}
    if cfg.block_type in ("attn_mlp", "hymba"):
        if cfg.attn_type == "mla":
            ya, new_cache["kv"] = mla_decode(p["attn"], cfg.mla_cfg(), h,
                                             cache["kv"], index, ctx=ctx,
                                             name=f"{name}/attn")
        else:
            acfg = cfg.attn_cfg(window=None)
            ya, new_cache["kv"] = attention_decode(
                p["attn"], acfg, h, cache["kv"], index, ctx=ctx,
                name=f"{name}/attn",
                **({} if window is None else {"window": window}))
    if cfg.block_type == "attn_mlp":
        x = x + ya
        if "mlp" in p:
            h2 = _norm_apply(p["norm2"], cfg, x)
            y, _ = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
            x = x + y
    elif cfg.block_type == "ssm_only":
        ys, new_cache["ssm"] = ssd_decode(p["ssm"], cfg.ssd_cfg(), h,
                                          cache["ssm"], ctx=ctx, name=f"{name}/ssm")
        x = x + ys
        if "mlp" in p:
            h2 = _norm_apply(p["norm2"], cfg, x)
            y, _ = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
            x = x + y
    elif cfg.block_type == "hymba":
        ys, new_cache["ssm"] = ssd_decode(p["ssm"], cfg.ssd_cfg(), h,
                                          cache["ssm"], ctx=ctx, name=f"{name}/ssm")
        ya = rmsnorm_apply(p["attn_out_norm"], ya)
        ys = rmsnorm_apply(p["ssm_out_norm"], ys)
        x = x + 0.5 * (ya + ys)
        h2 = _norm_apply(p["norm2"], cfg, x)
        y, _ = _mlp_fwd(p, cfg, h2, ctx=ctx, name=name)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# model-level: init / windows / forward
# ---------------------------------------------------------------------------
def lm_init(key, cfg: ModelCfg):
    """Params: {'embed', 'blocks' (stacked L), 'final_norm', ['head']}."""
    k_emb, k_blocks, k_norm, k_head, k_pos = jax.random.split(key, 5)
    p: Dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.vocab, cfg.d_model, cfg.jdtype),
        "final_norm": _norm_init(k_norm, cfg),
    }
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    p["blocks"] = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    if not cfg.tie_embeddings:
        p["head"] = linear_init(k_head, cfg.d_model, cfg.vocab, bias=False,
                                dtype=cfg.jdtype)
    if cfg.pos_embed == "learned":
        p["pos"] = init.normal(0.01)(k_pos, (cfg.max_seq, cfg.d_model), cfg.jdtype)
    return p


def layer_windows(cfg: ModelCfg, seq_hint: int):
    """Per-layer attention window sizes (None = all global)."""
    if cfg.window is None:
        return None
    big = max(seq_hint * 2, cfg.max_seq)
    ws = [cfg.window] * cfg.n_layers
    for g in cfg.global_layers:
        ws[g] = big
    return jnp.asarray(ws, jnp.int32)


def _layer_params(blocks, i):
    return jax.tree.map(lambda a: a[i], blocks)


def _embed_in(p, cfg, tokens):
    x = embedding_apply(p["embed"], tokens).astype(cfg.jdtype)
    if cfg.pos_embed == "learned":
        S = tokens.shape[1]
        x = x + p["pos"][:S][None]
    return x


def _logits_out(p, cfg, x, ctx):
    x = _norm_apply(p["final_norm"], cfg, x)
    if cfg.tie_embeddings:
        return embedding_logits(p["embed"], x, ctx=ctx, name="lm_head")
    return ctx.linear("lm_head", x, p["head"]["w"])


def lm_apply(p, cfg: ModelCfg, tokens, *, ctx=_FP, positions=None):
    """Full forward to logits. tokens: (B,S) int32. Returns (logits, aux)."""
    x = _embed_in(p, cfg, tokens)
    wins = layer_windows(cfg, tokens.shape[1])

    if cfg.scan_layers:
        def body(carry, xs):
            h, aux_l, aux_z = carry
            bp, w, li = xs
            bctx = ctx.at_layer(li)
            h, aux = block_apply(bp, cfg, h, ctx=bctx, name="blk",
                                 positions=positions,
                                 window=(w if wins is not None else None))
            return (h, aux_l + aux["aux_loss"], aux_z + aux["router_z"]), None

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (p["blocks"],
              wins if wins is not None else jnp.zeros((cfg.n_layers,), jnp.int32),
              jnp.arange(cfg.n_layers))
        (x, aux_loss, router_z), _ = jax.lax.scan(
            body, (x, jnp.float32(0.0), jnp.float32(0.0)), xs)
    else:
        aux_loss = jnp.float32(0.0)
        router_z = jnp.float32(0.0)
        for i in range(cfg.n_layers):
            bp = _layer_params(p["blocks"], i)
            w = None if wins is None else wins[i]
            x, aux = block_apply(bp, cfg, x, ctx=ctx.at_layer(i), name=f"blk{i}",
                                 positions=positions, window=w)
            aux_loss = aux_loss + aux["aux_loss"]
            router_z = router_z + aux["router_z"]

    logits = _logits_out(p, cfg, x, ctx)
    return logits, {"aux_loss": aux_loss, "router_z": router_z}


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------
def ce_loss(logits, labels, ignore_id=-1):
    """Mean next-token cross-entropy; labels already shifted by caller.

    Vocab-parallel formulation: the label logit is extracted with an
    iota-mask REDUCTION (not take_along_axis) and the logsumexp reduces
    over the (possibly TP-sharded) vocab axis, so GSPMD lowers both to
    partial reductions + tiny (B,S) all-reduces instead of all-gathering
    the full (B,S,V) logits (measured 37 GiB/device on qwen2.5-14b
    train_4k before this change; EXPERIMENTS §Perf).
    """
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None].clip(0), lg, 0.0),
                 axis=-1)
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss_fn(p, cfg: ModelCfg, batch, *, ctx=_FP):
    logits, aux = lm_apply(p, cfg, batch["tokens"], ctx=ctx)
    loss = ce_loss(logits, batch["labels"])
    return loss + aux["aux_loss"] + aux["router_z"], {
        "ce": loss, "aux_loss": aux["aux_loss"]}


# ---------------------------------------------------------------------------
# prefill / decode at model level
# ---------------------------------------------------------------------------
def lm_cache_init(cfg: ModelCfg, batch, max_len, dtype=None):
    one = block_cache_init(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def lm_prefill(p, cfg: ModelCfg, tokens, *, ctx=_FP, max_len=None):
    """Returns (logits_last, cache). cache leaves stacked (L, ...)."""
    B, S = tokens.shape
    max_len = max_len or S
    x = _embed_in(p, cfg, tokens)
    wins = layer_windows(cfg, max_len)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    if cfg.scan_layers:
        def body(h, xs):
            bp, w, li = xs
            h, cache = block_prefill(bp, cfg, h, ctx=ctx.at_layer(li), name="blk",
                                     positions=positions,
                                     window=(w if wins is not None else None),
                                     max_len=max_len)
            return h, cache
        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (p["blocks"],
              wins if wins is not None else jnp.zeros((cfg.n_layers,), jnp.int32),
              jnp.arange(cfg.n_layers))
        x, cache = jax.lax.scan(body, x, xs)
    else:
        caches = []
        for i in range(cfg.n_layers):
            bp = _layer_params(p["blocks"], i)
            w = None if wins is None else wins[i]
            x, c = block_prefill(bp, cfg, x, ctx=ctx.at_layer(i), name=f"blk{i}",
                                 positions=positions, window=w, max_len=max_len)
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    logits = _logits_out(p, cfg, x[:, -1:], ctx)
    return logits, cache


def lm_decode_step(p, cfg: ModelCfg, token, cache, index, *, ctx=_FP):
    """One decode step. token: (B,1) int32; index: scalar absolute position.
    Returns (logits (B,1,V), cache)."""
    x = embedding_apply(p["embed"], token).astype(cfg.jdtype)
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(p["pos"], index, 1, axis=0)[None]
    wins = layer_windows(cfg, int(cache_len(cfg, cache)))

    if cfg.scan_layers:
        def body(h, xs):
            bp, c, w, li = xs
            h, c = block_decode(bp, cfg, h, c, index, ctx=ctx.at_layer(li),
                                name="blk",
                                window=(w if wins is not None else None))
            return h, c
        xs = (p["blocks"], cache,
              wins if wins is not None else jnp.zeros((cfg.n_layers,), jnp.int32),
              jnp.arange(cfg.n_layers))
        x, cache = jax.lax.scan(body, x, xs)
    else:
        new = []
        for i in range(cfg.n_layers):
            bp = _layer_params(p["blocks"], i)
            c = jax.tree.map(lambda a: a[i], cache)
            w = None if wins is None else wins[i]
            x, c = block_decode(bp, cfg, x, c, index, ctx=ctx.at_layer(i),
                                name=f"blk{i}", window=w)
            new.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new)

    logits = _logits_out(p, cfg, x, ctx)
    return logits, cache


def cache_len(cfg: ModelCfg, cache) -> int:
    if cfg.block_type == "ssm_only":
        return cfg.max_seq
    key = "kv"
    sub = cache[key]
    leaf = sub["k"] if "k" in sub else sub["c_kv"]
    return leaf.shape[2]  # (L, B, S, ...)


def lm_generate(p, cfg: ModelCfg, prompt, n_new, *, ctx=_FP, max_len=None,
                greedy=True, key=None, temperature=1.0):
    """Autoregressive generation loop (lax.scan over steps)."""
    B, S = prompt.shape
    max_len = max_len or (S + n_new)
    logits, cache = lm_prefill(p, cfg, prompt, ctx=ctx, max_len=max_len)
    tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    def step(carry, i):
        tok, cache, k = carry
        lg, cache = lm_decode_step(p, cfg, tok[:, None], cache, S + i, ctx=ctx)
        lg = lg[:, 0]
        if greedy:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        else:
            k, sub = jax.random.split(k)
            nxt = jax.random.categorical(sub, lg / temperature).astype(jnp.int32)
        return (nxt, cache, k), nxt

    key = key if key is not None else jax.random.PRNGKey(0)
    (_, cache, _), toks = jax.lax.scan(
        step, (tok0, cache, key), jnp.arange(n_new))
    return jnp.moveaxis(toks, 0, 1)  # (B, n_new)
