"""Unified model configuration covering every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.nn.attention import AttnCfg, MLACfg
from repro.nn.mlp import MLPCfg, MoECfg
from repro.nn.ssm import SSDCfg


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                    # dense|moe|ssm|hybrid|audio|vlm|dit
    n_layers: int
    d_model: int
    vocab: int

    # ---- attention -------------------------------------------------------
    attn_type: str = "gqa"         # gqa|mla|none
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()   # layers with global attn when window set
    n_meta: int = 0
    # MLA
    kv_lora: int = 0
    q_lora: int = 0
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128

    # ---- feedforward -----------------------------------------------------
    d_ff: int = 0
    mlp_act: str = "swiglu"
    mlp_bias: bool = False
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1

    # ---- SSM (mamba2 / hymba) ---------------------------------------------
    ssm: bool = False
    d_inner: int = 0
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # ---- block & embedding layout ------------------------------------------
    block_type: str = "attn_mlp"   # attn_mlp|ssm_only|hymba
    norm: str = "rmsnorm"          # rmsnorm|layernorm
    tie_embeddings: bool = True
    pos_embed: str = "rope"        # rope|learned|sincos_fixed
    max_seq: int = 8192

    # ---- encoder-decoder (whisper) -------------------------------------------
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500            # precomputed frame embeddings (frontend stub)

    # ---- runtime -----------------------------------------------------------
    dtype: str = "float32"
    scan_layers: bool = False
    remat: bool = False
    attn_impl: str = "plain"       # plain|qchunk
    q_chunk: int = 512
    grad_accum: int = 1            # microbatches per train step
    attn_sp: Optional[tuple] = None  # SP attention (batch_axes, seq_axis)
    moe_shard: Optional[tuple] = None  # EP dispatch pin (batch_axes, ep_axis)

    # --- derived nn-layer configs ---------------------------------------------
    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    def attn_cfg(self, window=None, cross=False) -> AttnCfg:
        return AttnCfg(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm and not cross,
            rope=(self.pos_embed == "rope") and not cross,
            rope_theta=self.rope_theta, window=window,
            q_chunk=self.q_chunk, out_bias=self.qkv_bias,
            n_meta=self.n_meta if not cross else 0,
            sp_spec=self.attn_sp)

    def mla_cfg(self) -> MLACfg:
        return MLACfg(
            d_model=self.d_model, n_heads=self.n_heads, kv_lora=self.kv_lora,
            q_lora=self.q_lora, nope_dim=self.nope_dim, rope_dim=self.rope_dim,
            v_dim=self.v_dim, rope_theta=self.rope_theta, q_chunk=self.q_chunk)

    def mlp_cfg(self) -> MLPCfg:
        return MLPCfg(self.d_model, self.d_ff, act=self.mlp_act, bias=self.mlp_bias)

    def moe_cfg(self, groups=None) -> MoECfg:
        return MoECfg(
            d_model=self.d_model, d_expert=self.d_expert,
            n_experts=self.n_experts, top_k=self.top_k, n_shared=self.n_shared,
            capacity_factor=self.capacity_factor,
            groups=groups or self.moe_groups, act=self.mlp_act,
            shard_spec=self.moe_shard)

    def ssd_cfg(self) -> SSDCfg:
        return SSDCfg(
            d_model=self.d_model, d_inner=self.d_inner, d_state=self.ssm_state,
            head_dim=self.ssm_head_dim, n_groups=self.ssm_groups,
            chunk=self.ssm_chunk)

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        if self.block_type in ("attn_mlp", "hymba"):
            if self.attn_type == "gqa":
                per += d * self.n_heads * self.head_dim * 2          # q, o
                per += d * self.n_kv_heads * self.head_dim * 2       # k, v
            elif self.attn_type == "mla":
                qd = self.nope_dim + self.rope_dim
                per += (self.q_lora and (d * self.q_lora + self.q_lora * self.n_heads * qd)
                        or d * self.n_heads * qd)
                per += d * (self.kv_lora + self.rope_dim)
                per += self.kv_lora * self.n_heads * (self.nope_dim + self.v_dim)
                per += self.n_heads * self.v_dim * d
            if self.moe:
                per += d * self.n_experts                            # router
                per += self.n_experts * 3 * d * self.d_expert
                per += self.n_shared * 3 * d * self.d_expert
            elif self.d_ff:
                per += d * self.d_ff * (2 if self.mlp_act == "gelu" else 3)
        if self.block_type in ("ssm_only", "hymba"):
            di, gs, ns = self.d_inner, self.ssm_groups, self.ssm_state
            per += d * (2 * di + 2 * gs * ns + di // self.ssm_head_dim)
            per += di * d
        if self.encdec:
            # encoder layers: MHA + MLP (counted with same formula)
            enc = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
            enc += d * self.d_ff * 2
            n += self.n_enc_layers * enc
            per += d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2  # cross-attn
        return n + L * per

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        full = self.n_params()
        routed_all = L * self.n_experts * 3 * d * self.d_expert
        routed_act = L * self.top_k * 3 * d * self.d_expert
        return full - routed_all + routed_act
