"""Diffusion Transformer (DiT, Peebles & Xie 2023) with adaLN-Zero.

Faithful block structure to DiT-XL/2: patchify -> N blocks of
[adaLN-modulated MHSA, adaLN-modulated GELU-MLP] -> adaLN final layer ->
unpatchify, conditioned on (timestep, class) embeddings. Every
quantization-relevant op routes through the OpContext, and the context's
``tgroup`` field carries the TGQ timestep-group index during sampling.

The model operates on latents (B, H, W, C) — for the paper that is the
32x32x4 SD-VAE latent of a 256x256 image; our CPU-scale experiments use
smaller synthetic latents with identical code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.ctx import FPContext
from repro.nn.layers import (
    linear_init, sincos_2d, timestep_embedding,
    embedding_init, embedding_apply,
)

_FP = FPContext()


@dataclasses.dataclass(frozen=True)
class DiTCfg:
    img_size: int = 32            # latent spatial size
    in_ch: int = 4                # latent channels
    patch: int = 2
    d_model: int = 1152
    n_layers: int = 28
    n_heads: int = 16
    mlp_ratio: float = 4.0
    n_classes: int = 1000
    dtype: str = "float32"
    scan_layers: bool = False
    remat: bool = False
    # classifier-free guidance null class handled as extra embedding row
    class_dropout: float = 0.1

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def n_tokens(self):
        return (self.img_size // self.patch) ** 2

    @property
    def d_ff(self):
        return int(self.d_model * self.mlp_ratio)

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    @property
    def patch_dim(self):
        return self.patch * self.patch * self.in_ch

    def n_params(self) -> int:
        d = self.d_model
        per = 4 * d * d + 2 * d * self.d_ff + 6 * d * d  # attn + mlp + adaLN
        n = self.patch_dim * d + d * self.patch_dim      # in/out proj
        n += (self.n_classes + 1) * d + 256 * d + d * d  # class + t embed MLP
        return n + self.n_layers * per


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(key, cfg: DiTCfg):
    ks = jax.random.split(key, 7)
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.jdtype
    w = init.normal(0.02)
    return {
        "qkv": {"w": w(ks[0], (d, 3 * d), dt), "b": jnp.zeros((3 * d,), dt)},
        "proj": {"w": w(ks[1], (d, d), dt), "b": jnp.zeros((d,), dt)},
        "fc1": {"w": w(ks[2], (d, f), dt), "b": jnp.zeros((f,), dt)},
        "fc2": {"w": w(ks[3], (f, d), dt), "b": jnp.zeros((d,), dt)},
        # adaLN-Zero: 6 modulation vectors from conditioning; zero-init so
        # each residual branch starts as identity (DiT §3.2).
        "ada": {"w": jnp.zeros((d, 6 * d), dt), "b": jnp.zeros((6 * d,), dt)},
    }


def dit_init(key, cfg: DiTCfg):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    dt = cfg.jdtype
    w = init.normal(0.02)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    grid = cfg.img_size // cfg.patch
    return {
        "x_proj": {"w": w(ks[1], (cfg.patch_dim, d), dt),
                   "b": jnp.zeros((d,), dt)},
        "pos": sincos_2d(d, grid, grid).astype(dt),      # fixed, non-trainable
        "t_mlp1": {"w": w(ks[2], (256, d), dt), "b": jnp.zeros((d,), dt)},
        "t_mlp2": {"w": w(ks[3], (d, d), dt), "b": jnp.zeros((d,), dt)},
        "y_embed": embedding_init(ks[4], cfg.n_classes + 1, d, dt),
        "blocks": jax.vmap(lambda k: _block_init(k, cfg))(layer_keys),
        "final_ada": {"w": jnp.zeros((d, 2 * d), dt), "b": jnp.zeros((2 * d,), dt)},
        "final": {"w": jnp.zeros((d, cfg.patch_dim), dt),
                  "b": jnp.zeros((cfg.patch_dim,), dt)},
    }


# ---------------------------------------------------------------------------
# patchify
# ---------------------------------------------------------------------------
def patchify(x, patch):
    """(B,H,W,C) -> (B, (H/p)*(W/p), p*p*C)"""
    B, H, W, C = x.shape
    p = patch
    x = x.reshape(B, H // p, p, W // p, p, C)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(B, (H // p) * (W // p), p * p * C)


def unpatchify(x, patch, img_size, ch):
    B, N, _ = x.shape
    p, g = patch, img_size // patch
    x = x.reshape(B, g, g, p, p, ch)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(B, img_size, img_size, ch)


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------
def dit_block_apply(p, cfg: DiTCfg, x, c, *, ctx=_FP, name="blk"):
    """x: (B,N,d); c: (B,d) conditioning. adaLN-Zero MHSA + MLP.

    The adaLN elementwise chains ride the ``ctx.linear`` fusion seams
    instead of being computed here: ``norm_mod=(shift, scale)`` hands the
    layernorm-modulate chain to the qkv/fc1 matmul (fused into the
    kernel's quantize prologue under ``QuantContext(kernel=True)``) and
    ``gate_residual=(gate, x)`` hands the ``x + g * o`` residual add to
    the proj/fc2 matmul's epilogue — no normalized or pre-gate fp tensor
    round-trips HBM on the kernel path."""
    B, N, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    mod = ctx.linear(f"{name}/ada", jax.nn.silu(c), p["ada"]["w"], p["ada"]["b"])
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)

    # --- MHSA ---------------------------------------------------------------
    qkv = ctx.linear(f"{name}/qkv", x, p["qkv"]["w"], p["qkv"]["b"],
                     norm_mod=(sh1, sc1))
    q, k, v = jnp.split(qkv.reshape(B, N, 3, H, hd), 3, axis=2)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]          # (B,N,H,hd)
    # GQA-general layout with one query per kv head (G=1): the attention
    # seam (QK^T -> softmax -> MRQ hook -> P·V) is shared with
    # repro.nn.attention and lowers to the int8 attention kernels under
    # QuantContext(kernel=True). Op names stay {name}/attn/{qk,probs,pv}.
    o = ctx.attention(f"{name}/attn", q.reshape(B, N, H, 1, hd), k, v,
                      scale=hd ** -0.5)
    x = ctx.linear(f"{name}/proj", o.reshape(B, N, d), p["proj"]["w"],
                   p["proj"]["b"], gate_residual=(g1, x))

    # --- MLP ------------------------------------------------------------------
    h = ctx.linear(f"{name}/fc1", x, p["fc1"]["w"], p["fc1"]["b"],
                   norm_mod=(sh2, sc2))
    h = jax.nn.gelu(h, approximate=True)
    h = ctx.act(f"{name}/gelu", h, "post_gelu")
    x = ctx.linear(f"{name}/fc2", h, p["fc2"]["w"], p["fc2"]["b"],
                   gate_residual=(g2, x))
    return x


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def dit_apply(p, cfg: DiTCfg, x, t, y, *, ctx=_FP):
    """Noise prediction. x: (B,H,W,C) latents; t: (B,) int timesteps;
    y: (B,) int class labels (cfg.n_classes = null/uncond row)."""
    B = x.shape[0]
    tok = patchify(x.astype(cfg.jdtype), cfg.patch)
    h = ctx.linear("x_proj", tok, p["x_proj"]["w"], p["x_proj"]["b"])
    h = h + p["pos"][None]

    temb = timestep_embedding(t, 256).astype(cfg.jdtype)
    temb = ctx.linear("t_mlp1", temb, p["t_mlp1"]["w"], p["t_mlp1"]["b"])
    temb = jax.nn.silu(temb)
    temb = ctx.linear("t_mlp2", temb, p["t_mlp2"]["w"], p["t_mlp2"]["b"])
    yemb = embedding_apply(p["y_embed"], y).astype(cfg.jdtype)
    c = temb + yemb

    if cfg.scan_layers:
        def body(carry, xs):
            bp, li = xs
            return dit_block_apply(bp, cfg, carry, c, ctx=ctx.at_layer(li),
                                   name="blk"), None
        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, (p["blocks"], jnp.arange(cfg.n_layers)))
    else:
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], p["blocks"])
            h = dit_block_apply(bp, cfg, h, c, ctx=ctx.at_layer(i), name=f"blk{i}")

    mod = ctx.linear("final_ada", jax.nn.silu(c), p["final_ada"]["w"],
                     p["final_ada"]["b"])
    sh, sc = jnp.split(mod, 2, axis=-1)
    out = ctx.linear("final", h, p["final"]["w"], p["final"]["b"],
                     norm_mod=(sh, sc))
    return unpatchify(out, cfg.patch, cfg.img_size, cfg.in_ch)


def dit_apply_cfg_guidance(p, cfg: DiTCfg, x, t, y, scale, *, ctx=_FP):
    """Classifier-free guidance: eps = eps_u + s * (eps_c - eps_u)."""
    null = jnp.full_like(y, cfg.n_classes)
    xx = jnp.concatenate([x, x])
    tt = jnp.concatenate([t, t])
    yy = jnp.concatenate([y, null])
    eps = dit_apply(p, cfg, xx, tt, yy, ctx=ctx)
    eps_c, eps_u = jnp.split(eps, 2)
    return eps_u + scale * (eps_c - eps_u)
