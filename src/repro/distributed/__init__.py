from repro.distributed.sharding import (
    param_specs, param_shardings, batch_spec, batch_axes, replicated,
    logical_axes, bind_logical, dp_size, request_spec,
)
