"""Parameter/activation sharding rules (DP / FSDP / TP / EP / SP).

Logical-axis design: every parameter path maps to a tuple of LOGICAL axes
for its trailing dims (leading dims — e.g. the stacked-layer L axis —
replicate). Logical axes then bind to mesh axes:

    tp / ep / vocab -> "model"       (tensor / expert / vocab parallel)
    fsdp            -> "data"        (ZeRO-3 weight sharding, on for >=3B)
    batch           -> ("pod","data") on the multi-pod mesh, else ("data",)

A divisibility guard drops any axis that does not evenly divide the dim
(e.g. whisper's vocab 51865 on 16-way model) — the tensor replicates on
that axis instead of failing to lower.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (pattern, trailing-dim logical axes) — first match wins.
RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # --- embeddings / heads ---------------------------------------------------
    # NOTE: never FSDP-shard the d_model dim of embedding/head tables: it is
    # the CONTRACTION dim of the logits matmul, and GSPMD then computes
    # partial logits with a REPLICATED batch and all-reduces the full
    # (B,S,V/16) tensor over "data" (measured 37 GiB/device/layer-step on
    # qwen2.5-14b train_4k; EXPERIMENTS §Perf). Vocab-sharded tables are
    # ~100 MB/device — replicating the d dim is free by comparison.
    ("embed/emb",      ("vocab", None)),
    ("head/w",         (None, "vocab")),
    ("y_embed/emb",    (None, None)),
    ("dec_pos",        (None, None)),
    ("pos",            (None, None)),
    # --- attention (GQA) --------------------------------------------------------
    ("attn/q/w",       ("fsdp", "tp")),
    ("attn/k/w",       ("fsdp", "tp")),
    ("attn/v/w",       ("fsdp", "tp")),
    ("attn/o/w",       ("tp", "fsdp")),
    ("attn/q/b",       ("tp",)),
    ("attn/k/b",       ("tp",)),
    ("attn/v/b",       ("tp",)),
    ("attn/o/b",       (None,)),
    ("attn/meta",      (None, None)),
    ("xattn/q/w",      ("fsdp", "tp")),
    ("xattn/k/w",      ("fsdp", "tp")),
    ("xattn/v/w",      ("fsdp", "tp")),
    ("xattn/o/w",      ("tp", "fsdp")),
    ("xattn/q/b",      ("tp",)),
    ("xattn/k/b",      ("tp",)),
    ("xattn/v/b",      ("tp",)),
    ("xattn/o/b",      (None,)),
    # --- attention (MLA) ---------------------------------------------------------
    ("attn/q_a/w",     ("fsdp", None)),
    ("attn/q_b/w",     (None, "tp")),
    ("attn/kv_a/w",    ("fsdp", None)),
    ("attn/kv_b/w",    (None, "tp")),
    # --- MoE (raw (E, d, f) arrays) — EP on experts -------------------------------
    ("router/w",       (None, None)),
    ("mlp/shared/gate/w", ("fsdp", "tp")),
    ("mlp/shared/up/w",   ("fsdp", "tp")),
    ("mlp/shared/down/w", ("tp", "fsdp")),
    ("mlp/gate/w",     ("fsdp", "tp")),      # dense MLP (nested dict)
    ("mlp/up/w",       ("fsdp", "tp")),
    ("mlp/down/w",     ("tp", "fsdp")),
    ("mlp/fc1/w",      ("fsdp", "tp")),
    ("mlp/fc2/w",      ("tp", "fsdp")),
    ("mlp/fc1/b",      ("tp",)),
    ("mlp/fc2/b",      (None,)),
    # MoE expert stacks: EP on experts + FSDP on d (gate/up) / f (down).
    # NOTE (measured, EXPERIMENTS §Perf kimi round 2): moving FSDP OFF the
    # contraction dims (f for gate/up, d for down) REGRESSED 6x — GSPMD
    # then partial-sums the (E,C,*) expert outputs over "data" instead of
    # gathering the (much smaller) weight shards. Unlike the dense lm_head
    # (where the fix won 7.7x), the expert weight gather IS the cheaper
    # resolution here, and the cost model picks it. Hypothesis refuted;
    # original rules kept.
    ("mlp/gate",       ("ep", "fsdp", None)),
    ("mlp/up",         ("ep", "fsdp", None)),
    ("mlp/down",       ("ep", None, "fsdp")),
    # --- SSM -----------------------------------------------------------------------
    ("ssm/in_proj/w",  ("fsdp", "tp")),
    ("ssm/out_proj/w", ("tp", "fsdp")),
    ("ssm/conv_w",     (None, "tp")),
    ("ssm/conv_b",     ("tp",)),
    ("ssm/dt_bias",    (None,)),
    ("ssm/A_log",      (None,)),
    ("ssm/D",          (None,)),
    ("ssm/norm",       (None,)),
    # --- DiT --------------------------------------------------------------------------
    ("qkv/w",          ("fsdp", "tp")),
    ("qkv/b",          ("tp",)),
    ("proj/w",         ("tp", "fsdp")),
    ("proj/b",         (None,)),
    ("ada/w",          ("fsdp", "tp")),
    ("ada/b",          ("tp",)),
    ("fc1/w",          ("fsdp", "tp")),
    ("fc1/b",          ("tp",)),
    ("fc2/w",          ("tp", "fsdp")),
    ("fc2/b",          (None,)),
    ("x_proj",         (None, None)),
    ("final_ada",      (None, None)),
    ("final",          (None, None)),
    ("t_mlp",          (None, None)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes(path_str: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, trailing in RULES:
        if pat in path_str:
            if len(trailing) > ndim:
                trailing = trailing[-ndim:]
            return (None,) * (ndim - len(trailing)) + tuple(trailing)
    return (None,) * ndim


def bind_logical(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                 mesh: Mesh, fsdp: bool) -> P:
    """Logical axes -> PartitionSpec with a divisibility guard."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for ax, dim in zip(axes, shape):
        mesh_ax: Any = None
        if ax in ("tp", "ep", "vocab"):
            mesh_ax = "model"
        elif ax == "fsdp" and fsdp:
            mesh_ax = "data"
        elif ax == "batch":
            mesh_ax = (("pod", "data") if "pod" in sizes else ("data",))
        if mesh_ax is not None:
            n = (np.prod([sizes[a] for a in mesh_ax])
                 if isinstance(mesh_ax, tuple) else sizes[mesh_ax])
            if dim % int(n) != 0:
                mesh_ax = None                     # replicate: not divisible
        out.append(mesh_ax)
    return P(*out)


def param_specs(params, mesh: Mesh, fsdp: bool = False):
    """Pytree of PartitionSpec matching ``params``."""
    def per(path, leaf):
        ps = _path_str(path)
        return bind_logical(logical_axes(ps, np.ndim(leaf)),
                            np.shape(leaf), mesh, fsdp)
    return jax.tree_util.tree_map_with_path(per, params)


def param_shardings(params, mesh: Mesh, fsdp: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, fsdp))


def batch_axes(mesh: Mesh) -> Any:
    """The data-parallel super-axis for activation batch dims."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh: Mesh, ndim: int, batch_dim: int = 0,
               seq_dim: Optional[int] = None, seq_axis: Optional[str] = None
               ) -> P:
    """Activation spec: batch dim on the DP super-axis; optional sequence
    sharding (SP) of ``seq_dim`` on ``seq_axis``."""
    out: list = [None] * ndim
    out[batch_dim] = batch_axes(mesh)
    if seq_dim is not None and seq_axis is not None:
        out[seq_dim] = seq_axis
    return P(*out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# serving (data-parallel microbatch execution)
# ---------------------------------------------------------------------------
def dp_size(mesh: Mesh) -> int:
    """Number of data-parallel shards: the product of the DP super-axis
    sizes. Serving microbatches must be a multiple of this so each device
    receives an equal, fixed-shape slice."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in batch_axes(mesh)]))


def request_spec(mesh: Mesh) -> P:
    """Spec for per-request 1-D arrays (labels / seeds / guidance scales):
    sharded on the DP super-axis, matching ``batch_spec`` for the latents
    they generate."""
    return P(batch_axes(mesh))
