from repro.data.synthetic import TokenPipeline, LatentPipeline, prefetch
