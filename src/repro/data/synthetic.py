"""Seeded synthetic data pipelines (no external datasets in the container).

Token streams (LMs): a class of order-2 Markov sources with per-stream
mixing — enough structure that CE training visibly learns, fully
deterministic per (seed, host) so multi-host sharding never duplicates
samples.

Latents (DiT): class-conditional spatially-structured Gaussian mixtures —
each class is a fixed smooth pattern (low-frequency Fourier mix) plus
scaled noise. Classes are linearly separable in feature space, so the
FD / IS-proxy metrics (repro.core.metrics) produce meaningful orderings.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    batch: int                      # per-host batch
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    order: int = 2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 512)     # transition table over a vocab head
        self._v = v
        # sparse-ish row-stochastic transition logits
        self._trans = rng.normal(0, 1.5, (v, v)).astype(np.float32)

    def batches(self, key: Optional[jax.Array] = None) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (host-sharded)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.n_hosts + self.host_id)
        v = self._v
        toks = np.empty((self.batch, self.seq_len), np.int64)
        toks[:, 0] = rng.integers(0, v, self.batch)
        logits = self._trans
        for t in range(1, self.seq_len):
            row = logits[toks[:, t - 1] % v]
            row = row - row.max(axis=1, keepdims=True)
            p = np.exp(row)
            p /= p.sum(axis=1, keepdims=True)
            cum = p.cumsum(axis=1)
            u = rng.random((self.batch, 1))
            toks[:, t] = (u < cum).argmax(axis=1)
        toks = toks % self.vocab
        labels = np.concatenate(
            [toks[:, 1:], np.full((self.batch, 1), -1, np.int64)], axis=1)
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "labels": jnp.asarray(labels, jnp.int32)}


# ---------------------------------------------------------------------------
# DiT latent pipeline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LatentPipeline:
    img_size: int
    channels: int
    n_classes: int
    seed: int = 0
    noise: float = 0.35
    n_modes: int = 4                 # Fourier modes per class pattern

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        H = self.img_size
        yy, xx = np.meshgrid(np.arange(H), np.arange(H), indexing="ij")
        pats = []
        for _ in range(self.n_classes):
            pat = np.zeros((H, H, self.channels), np.float32)
            for _ in range(self.n_modes):
                fx, fy = rng.uniform(0.5, 2.5, 2)
                ph = rng.uniform(0, 2 * np.pi, self.channels)
                amp = rng.uniform(0.4, 1.0, self.channels)
                for c in range(self.channels):
                    pat[..., c] += amp[c] * np.sin(
                        2 * np.pi * (fx * xx + fy * yy) / H + ph[c])
            pats.append(pat / max(self.n_modes, 1) * 1.6)
        self.patterns = np.stack(pats)           # (K, H, H, C)

    def sample(self, n: int, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x0 (n,H,H,C), labels (n,))."""
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (n,), 0, self.n_classes)
        base = jnp.asarray(self.patterns)[y]
        eps = jax.random.normal(k2, base.shape) * self.noise
        return base + eps, y

    def x0_source(self, n: int, key) -> jnp.ndarray:
        return self.sample(n, key)[0]

    def labeled_set(self, n: int, key) -> Tuple[np.ndarray, np.ndarray]:
        x, y = self.sample(n, key)
        return np.asarray(x), np.asarray(y)


# ---------------------------------------------------------------------------
# double-buffered prefetch
# ---------------------------------------------------------------------------
def prefetch(iterator: Iterator, depth: int = 2) -> Iterator:
    """Host-side prefetch: keeps ``depth`` batches materialized ahead
    (device transfer overlaps the previous step's compute)."""
    import collections
    import threading
    import queue

    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def producer():
        try:
            for item in iterator:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
