from repro.optim.optimizers import (
    Optimizer, adamw, adafactor, apply_updates, cosine_schedule,
    constant_schedule, clip_by_global_norm, global_norm, accumulate_grads,
    compress_grads_int8, init_error_state,
)
