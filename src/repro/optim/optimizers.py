"""Optimizers: AdamW and Adafactor (factored second moment), plus global-
norm clipping, schedules, gradient accumulation, and int8 gradient
compression with error feedback.

Functional optax-style API without the optax dependency:
  opt = adamw(lr=...); state = opt.init(params);
  updates, state = opt.update(grads, state, params); params += updates.

Adafactor keeps O(n+m) second-moment state per (n,m) matrix — required
for the 1T-parameter MoE assignments where full Adam state would not fit
512 x 16GB HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params) -> (updates, state)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.float32(lr_val)


# ---------------------------------------------------------------------------
# global-norm clip
# ---------------------------------------------------------------------------
def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — factored 2nd moment, no 1st moment
# ---------------------------------------------------------------------------
def adafactor(lr: Callable | float, eps: float = 1e-30,
              clip_threshold: float = 1.0, decay: float = 0.8,
              weight_decay: float = 0.0,
              max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(per, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** -decay
        lr_t = lr_fn(step)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nvv = beta * v["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(nvv) + eps)
                nv = {"v": nvv}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        updates = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return updates, {"step": step, "v": new_v}

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------
def accumulate_grads(loss_and_grad_fn: Callable, params, batches):
    """Average grads over a leading microbatch axis via lax.scan.
    batches: pytree with leading (n_micro, ...) axes."""
    def body(carry, mb):
        acc, loss_acc = carry
        (loss, aux), g = loss_and_grad_fn(params, mb)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, loss_acc + loss), aux

    n = jax.tree.leaves(batches)[0].shape[0]
    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc, loss_sum), aux = jax.lax.scan(body, (zero, jnp.float32(0.0)), batches)
    grads = jax.tree.map(lambda a: a / n, acc)
    return grads, loss_sum / n, aux


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------
def compress_grads_int8(grads, error_state):
    """Quantize gradients to int8 (per-leaf symmetric scale) with error
    feedback: the residual is carried to the next step so compression
    noise is unbiased over time. Used to halve DP all-reduce bytes (the
    reduce happens on the int8-representable values; scales ride along).
    Returns (decompressed_grads, new_error_state)."""
    def per(g, e):
        g = g.astype(jnp.float32) + e
        s = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(g / s), -127, 127)
        deq = q * s
        return deq, g - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    outs = [per(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
