from repro.checkpoint.ckpt import (
    content_hash, save, save_async, wait_async, restore, latest_step,
)
