from repro.checkpoint.ckpt import (
    save, save_async, wait_async, restore, latest_step,
)
