"""Fault-tolerant checkpointing: atomic npz-shard checkpoints with a
manifest, latest-pointer resume, async background saves, and keep-K
retention — the checkpoint/restart half of the fault-tolerance story
(a preempted pod restarts from ``latest`` and continues).

Layout:
  <dir>/step_000100/
      manifest.json            # step, tree structure, shard index, hashes
      shard_00000.npz          # flattened leaves, chunked ~512MB
      _COMMITTED               # written LAST -> crash-safe atomicity
  <dir>/latest                 # text file: name of newest committed step
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _tree_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def content_hash(tree: Any) -> dict:
    """Content identity of a pytree WITHOUT writing it to disk — the same
    sha256[:16] convention the shard manifests use, computed per leaf
    over (dtype, shape, raw bytes) in flatten order plus one combined
    digest. ``QuantArtifact`` records this for the fp params a
    quantization was calibrated against, so a serving process fails fast
    on a wrong-checkpoint mismatch instead of silently sampling garbage.
    """
    flat, _ = _tree_paths(tree)
    leaves = []
    combined = hashlib.sha256()
    for leaf in flat:
        a = np.ascontiguousarray(np.asarray(leaf))
        h = hashlib.sha256()
        h.update(str(a.dtype).encode())
        h.update(str(tuple(a.shape)).encode())
        h.update(a.tobytes())
        leaves.append(h.hexdigest()[:16])
        combined.update(h.digest())
    return {"n_leaves": len(flat), "leaves": leaves,
            "digest": combined.hexdigest()[:16]}


def save(path: str, step: int, tree: Any, keep: int = 3,
         shard_bytes: int = _SHARD_BYTES) -> str:
    """Synchronous atomic save. Returns the checkpoint directory."""
    name = f"step_{step:08d}"
    final = os.path.join(path, name)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    flat, treedef = _tree_paths(tree)
    arrays = [np.asarray(l) for l in flat]

    shards, cur, cur_bytes = [], {}, 0
    index = {}
    for i, a in enumerate(arrays):
        if cur_bytes + a.nbytes > shard_bytes and cur:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[f"leaf_{i}"] = a
        index[str(i)] = len(shards)
        cur_bytes += a.nbytes
    shards.append(cur)

    hashes = {}
    for si, sh in enumerate(shards):
        fn = os.path.join(tmp, f"shard_{si:05d}.npz")
        np.savez(fn, **sh)
        with open(fn, "rb") as f:
            hashes[f"shard_{si:05d}.npz"] = hashlib.sha256(
                f.read()).hexdigest()[:16]

    manifest = {
        "step": step,
        "n_leaves": len(arrays),
        "index": index,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
        else None,
        "hashes": hashes,
        "dtypes": [str(a.dtype) for a in arrays],
        "shapes": [list(a.shape) for a in arrays],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    with open(os.path.join(path, "latest.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(path, "latest.tmp"), os.path.join(path, "latest"))

    _retain(path, keep)
    return final


_ASYNC_THREAD: Optional[threading.Thread] = None


def save_async(path: str, step: int, tree: Any, keep: int = 3) -> None:
    """Background-thread save. Blocks only on a still-running previous
    save (single-flight), then snapshots to host and returns."""
    global _ASYNC_THREAD
    if _ASYNC_THREAD is not None and _ASYNC_THREAD.is_alive():
        _ASYNC_THREAD.join()
    host_tree = jax.tree.map(lambda a: np.asarray(a), tree)   # device->host now
    _ASYNC_THREAD = threading.Thread(
        target=save, args=(path, step, host_tree, keep), daemon=True)
    _ASYNC_THREAD.start()


def wait_async() -> None:
    if _ASYNC_THREAD is not None and _ASYNC_THREAD.is_alive():
        _ASYNC_THREAD.join()


def latest_step(path: str) -> Optional[int]:
    try:
        with open(os.path.join(path, "latest")) as f:
            name = f.read().strip()
        if os.path.exists(os.path.join(path, name, "_COMMITTED")):
            return int(name.split("_")[1])
    except (FileNotFoundError, ValueError, IndexError):
        pass
    # fall back to scanning (latest pointer lost)
    best = None
    if os.path.isdir(path):
        for d in os.listdir(path):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(path, d, "_COMMITTED")):
                s = int(d.split("_")[1])
                best = s if best is None else max(best, s)
    return best


def _shard_leaves(manifest: dict, shard_idx: int) -> list:
    """Leaf indices stored in shard ``shard_idx`` (manifest order)."""
    return [int(i) for i, si in manifest["index"].items()
            if int(si) == shard_idx]


def verify_shards(path: str, step: Optional[int] = None) -> None:
    """Integrity-check every npz shard of a committed checkpoint against
    the manifest's recorded sha256[:16] content hashes.

    A flipped byte in a shard otherwise surfaces as a cryptic
    numpy/zlib/zip exception deep inside ``np.load`` (or worse, decodes to
    silently wrong values in the uncompressed regions) far from the
    checkpoint path. This names the offending shard file AND the leaves it
    carries (index/dtype/shape), so the error points at what is actually
    lost. Raises ``ValueError`` on corruption, ``FileNotFoundError`` on a
    missing/truncated-away shard.
    """
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    for si_name in sorted(manifest["hashes"]):
        fn = os.path.join(d, si_name)
        if not os.path.exists(fn):
            raise FileNotFoundError(
                f"checkpoint shard {fn} is missing (manifest lists it)")
        with open(fn, "rb") as f:
            got = hashlib.sha256(f.read()).hexdigest()[:16]
        want = manifest["hashes"][si_name]
        if got == want:
            continue
        si = int(si_name[len("shard_"):-len(".npz")])
        leaves = _shard_leaves(manifest, si)
        desc = ", ".join(
            f"leaf {i} ({manifest['dtypes'][i]}"
            f"{tuple(manifest['shapes'][i])})" for i in leaves[:8])
        more = f", … {len(leaves) - 8} more" if len(leaves) > 8 else ""
        raise ValueError(
            f"checkpoint shard {fn} is corrupted: content hash {got} != "
            f"manifest {want}; expected leaves: {desc}{more}")


def restore(path: str, like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(flat_like), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(flat_like)}"
    cache = {}
    out = []
    for i, proto in enumerate(flat_like):
        si = manifest["index"][str(i)]
        if si not in cache:
            fn = os.path.join(d, f"shard_{si:05d}.npz")
            try:
                cache[si] = np.load(fn)
            except Exception as e:
                raise ValueError(
                    f"checkpoint shard {fn} failed to load "
                    f"({type(e).__name__}: {e}) — run "
                    "checkpoint.ckpt.verify_shards for an integrity "
                    "report") from e
        a = cache[si][f"leaf_{i}"]
        assert list(a.shape) == list(proto.shape), \
            f"leaf {i}: ckpt {a.shape} vs model {proto.shape}"
        out.append(jnp.asarray(a, dtype=proto.dtype))
    return jax.tree.unflatten(treedef, out)


def _retain(path: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_") and os.path.exists(
            os.path.join(path, d, "_COMMITTED")))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)
