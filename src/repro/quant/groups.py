"""Timestep-group resolution — ONE helper, one contract.

Two code paths used to hand-roll the TGQ group lookup with subtly
different semantics: the serving packs in ``kernels/ops.py`` clamped a
(possibly traced) group index into the pack's range, while
``serving/quickcal.py`` borrowed the *nearest calibrated* group for
groups the calibration set never hit. :func:`resolve_group` is now the
single implementation of both:

- **exact/clamp** (``calibrated=None``): the serving side. ``g`` may be a
  traced jnp scalar (the sampler threads it through ``lax.scan``);
  returns ``g`` clamped into ``[0, n_groups)``. ``g=None`` (no group
  info, e.g. non-diffusion eval) and ``n_groups == 1`` (per-tensor pack)
  both resolve to group 0.
- **nearest** (``calibrated`` given): the calibration side. ``g`` is a
  Python int; returns the member of ``calibrated`` closest to ``g`` — an
  exact match wins when present, ties break toward the SMALLER group
  (matching ``min(..., key=abs(x - g))`` over a sorted sequence, the
  historical behaviour every stacked-(G,) qparam was built with).

``group_boundaries`` exposes the calibration-time group edges
``G_i = [i*T//G, (i+1)*T//G)`` — recorded in artifact provenance so a
loaded artifact documents which timesteps each stacked row covers.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp


def resolve_group(g, n_groups: Optional[int] = None, *,
                  calibrated: Optional[Sequence[int]] = None):
    """Resolve a TGQ timestep-group index. See the module docstring for
    the exact-vs-nearest contract."""
    if calibrated is not None:
        if not len(calibrated):
            raise ValueError("resolve_group: empty `calibrated` sequence")
        return min(calibrated, key=lambda x: abs(int(x) - int(g)))
    if n_groups is None:
        raise ValueError("resolve_group: need n_groups (or calibrated=)")
    if g is None or n_groups == 1:
        return 0
    return jnp.clip(jnp.asarray(g, jnp.int32), 0, n_groups - 1)


def group_boundaries(T: int, G: int) -> List[Tuple[int, int]]:
    """[(lo, hi)) original-chain timestep range of each TGQ group — the
    ranges ``build_dit_calibration`` draws from and ``tgroup_of`` maps
    back onto (g(t) = floor(t*G/T))."""
    return [(g * T // G, (g + 1) * T // G) for g in range(G)]
