"""`quantize()` — the single public quantization entrypoint.

One call replaces the four hand-wired chains the repo grew
(``run_ptq``/``range_calibrate`` -> qparams dict + captured weights ->
``convert_for_kernels`` -> ``make_quant_context``):

    recipe = QuantRecipe(bits="w8a8", method="range")
    artifact = quantize(params, dcfg, dif, recipe)
    engine = ServeEngine.from_artifact(params, artifact, mesh=mesh)
    # ... later, in a fresh process (no recalibration):
    artifact.save("/ckpts/dit_w8a8")
    artifact = QuantArtifact.load("/ckpts/dit_w8a8")

Dispatch is by ``recipe.method``: 'range' runs
``serving.quickcal.range_calibrate`` (seconds; structurally correct TGQ
ranges), 'ho' runs the paper's full Algorithm 1
(``core.ptq.run_ptq`` — Fisher taps + alternating candidate search).
Either way, results are packed for the Pallas kernel family matching the
recipe's bit-width (``kernels.ops.convert_for_kernels``: w8a8/w6a6 ->
fused int8 kernels, w4a4 -> nibble-packed int4 kernels) before the
artifact is built, so ``artifact.context()`` serves through the
deployment path by default.

Internal dispatch imports are deferred into the function body:
``kernels.ops`` and ``serving.quickcal`` themselves import
``repro.quant.groups``, and top-level imports here would cycle.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import dataclasses

import jax

from repro.quant.artifact import ARTIFACT_VERSION, QuantArtifact
from repro.quant.groups import group_boundaries
from repro.quant.recipe import QuantRecipe


def quantize(params, model_cfg, dif_cfg, recipe: QuantRecipe,
             calib_data: Optional[List[Tuple[Dict[str, Any], int]]] = None,
             *, sched=None, provenance: Optional[dict] = None
             ) -> QuantArtifact:
    """Calibrate + search + pack in one call; returns a QuantArtifact.

    params / model_cfg : the DiT model (``model_cfg`` a ``DiTCfg``).
    dif_cfg            : ``DiffusionCfg``; ``recipe.tgq_groups`` (if set)
                         overrides its group count — the artifact records
                         the effective configs either way.
    calib_data         : optional Phase-1 batches ``[(batch_dict, group)]``
                         (``core.calib.build_dit_calibration`` output) for
                         the 'ho' method. ``None`` builds a synthetic
                         Gaussian-latent set sized by
                         ``recipe.n_per_group`` / ``recipe.calib_batch``.
                         The 'range' method always draws its own capture
                         set (its protocol is part of the method).
    sched              : diffusion schedule (built from ``dif_cfg`` if
                         omitted).
    provenance         : caller-supplied metadata recorded verbatim under
                         ``meta["provenance"]`` — git sha, timestamp,
                         arch label, cluster name. The API does not guess
                         these (no clock/VCS access here); deployments
                         that want them pass them in.
    """
    from repro.diffusion import make_schedule

    if recipe.tgq_groups is not None \
            and recipe.tgq_groups != dif_cfg.tgq_groups:
        if calib_data is not None:
            # the batches' group tags were computed under the CALLER's
            # group boundaries; reinterpreting them under a different G
            # would silently miscalibrate every stacked row.
            raise ValueError(
                f"recipe.tgq_groups={recipe.tgq_groups} overrides "
                f"dif_cfg.tgq_groups={dif_cfg.tgq_groups} but calib_data "
                "was supplied — build the calibration under the intended "
                "group count (set dif_cfg.tgq_groups) instead")
        dif_cfg = dataclasses.replace(dif_cfg, tgq_groups=recipe.tgq_groups)
    if calib_data is not None:
        bad = sorted({int(tg) for _, tg in calib_data
                      if not 0 <= int(tg) < dif_cfg.tgq_groups})
        if bad:
            raise ValueError(
                f"calib_data group tags {bad} out of range for "
                f"tgq_groups={dif_cfg.tgq_groups}")
    if recipe.method == "range":
        defaults = QuantRecipe()
        unsupported = [f for f in ("skip_patterns", "weight_only_patterns",
                                   "use_mrq", "use_tgq", "use_fisher",
                                   "rounds", "n_alpha", "fisher_norm",
                                   "bias_correct", "channel_balance",
                                   "balance_alpha")
                       if getattr(recipe, f) != getattr(defaults, f)]
        if unsupported:
            # range_calibrate has no such knobs; embedding them in the
            # artifact's recipe would record a calibration that never
            # happened — and the load-time expect_recipe guard would then
            # ratify the false description (or spuriously reject a true
            # one). A range recipe keeps every HO-only field at default.
            raise ValueError(
                f"QuantRecipe(method='range') cannot honor {unsupported}: "
                "the range pipeline always quantizes every op with the "
                "full MRQ+TGQ structure and runs no search — use "
                "method='ho' for these knobs")
    sched = sched if sched is not None else make_schedule(dif_cfg)
    key = jax.random.PRNGKey(recipe.seed)

    if recipe.method == "range":
        from repro.serving.quickcal import range_calibrate
        qparams, weights = range_calibrate(
            params, model_cfg, dif_cfg, sched, key,
            wbits=recipe.wbits, abits=recipe.abits,
            n_per_group=recipe.n_per_group, batch=recipe.calib_batch,
            max_rows=recipe.max_rows_per_batch)
        calib_stats: Dict[str, Any] = {"n_quantized": len(qparams)}
    else:                                               # "ho"
        from repro.core.calib import build_dit_calibration, dit_loss_fn
        from repro.core.ptq import run_ptq
        if calib_data is None:
            x0 = lambda n, k: jax.random.normal(
                k, (n, model_cfg.img_size, model_cfg.img_size,
                    model_cfg.in_ch))
            calib_data = build_dit_calibration(
                params, model_cfg, dif_cfg, sched, x0, key,
                n_per_group=recipe.n_per_group, batch=recipe.calib_batch)
        qparams, report = run_ptq(dit_loss_fn(params, model_cfg),
                                  calib_data,
                                  recipe.ptq_config(dif_cfg.tgq_groups))
        weights = report.pop("weights")     # full fp copy — never persisted
        calib_stats = {k: v for k, v in report.items()
                       if isinstance(v, (int, float, str))}

    if recipe.kernel_deployable:
        from repro.kernels.ops import convert_for_kernels
        qparams = convert_for_kernels(qparams, weights)

    from repro.checkpoint import ckpt
    meta = {
        "format_version": ARTIFACT_VERSION,
        "model": {"class": type(model_cfg).__name__,
                  "cfg": dataclasses.asdict(model_cfg)},
        # content identity of the fp tree this calibration ran against —
        # from_artifact / load(params=...) fail fast on any other params
        "params_hash": ckpt.content_hash(params),
        "dif": dataclasses.asdict(dif_cfg),
        "tgq_groups": dif_cfg.tgq_groups,
        "tgq_group_boundaries": [list(b) for b in group_boundaries(
            dif_cfg.T, dif_cfg.tgq_groups)],
        "calib": calib_stats,
        # content identity of the recipe itself — the autotune ledger key,
        # recorded so a loaded artifact names the exact configuration
        # that produced it without re-deriving the hash
        "recipe_hash": recipe.content_hash(),
        "provenance": dict(provenance or {}),
    }
    return QuantArtifact(qparams=qparams, recipe=recipe, meta=meta)
