"""`QuantArtifact` — calibrated quantization state as a first-class,
serializable value.

The artifact is everything a serving process needs to cold-start a
quantized deployment WITHOUT rerunning calibration: the per-op ``qparams``
(quantizer pytrees plus the packed kernel parameters — int8/int6 byte
codes or nibble-packed int4 weight payloads), the :class:`QuantRecipe`
that produced
them, and provenance metadata (model/diffusion configs, TGQ group
boundaries, calibration stats, caller-supplied git sha / timestamp).

On-disk layout (``artifact.save(path)``)::

    <path>/artifact.json        # version, recipe, meta, structure spec
    <path>/step_00000000/       # array leaves via checkpoint/ckpt.py
        manifest.json           #   (atomic npz shards, _COMMITTED marker)
        shard_00000.npz
    <path>/latest

Array leaves ride the repo's fault-tolerant checkpoint machinery
(`repro.checkpoint.ckpt`); the *structure* — which quantizer class wraps
which arrays, pack dict keys, meta fields like ``bits`` — is encoded to a
JSON spec by this module, so ``QuantArtifact.load`` reconstructs the
exact pytree in a fresh process with no pickle and no reliance on jax
treedef protos. Round-trips are bit-exact (dtypes preserved through the
npz shards), which is what makes loaded-artifact serving sample-identical
to in-memory serving (asserted in ``tests/test_quant_api.py``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core.quantizers import (
    ChannelQ, MRQSignedQ, MRQSoftmaxQ, SymQ, TGQ, UniformQ,
)
from repro.quant.recipe import QuantRecipe

ARTIFACT_VERSION = 1
_ARTIFACT_JSON = "artifact.json"

# the quantizer containers an artifact may carry; encoded by class name +
# per-field spec so load() never needs pickle
_QUANTIZERS = {c.__name__: c for c in
               (UniformQ, SymQ, ChannelQ, MRQSoftmaxQ, MRQSignedQ, TGQ)}


# ---------------------------------------------------------------------------
# structure spec: tree -> (json spec, flat array leaves)
# ---------------------------------------------------------------------------
def _encode(obj: Any, leaves: List[np.ndarray]) -> dict:
    if obj is None:
        return {"k": "none"}
    if isinstance(obj, bool) or isinstance(obj, (int, float, str)) and \
            not isinstance(obj, np.generic):
        return {"k": "py", "v": obj}
    if isinstance(obj, dict):
        if not all(isinstance(k, str) for k in obj):
            raise TypeError("artifact dicts must be str-keyed")
        return {"k": "dict", "items": {k: _encode(v, leaves)
                                       for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        return {"k": "tuple" if isinstance(obj, tuple) else "list",
                "items": [_encode(v, leaves) for v in obj]}
    if type(obj).__name__ in _QUANTIZERS and dataclasses.is_dataclass(obj):
        return {"k": "q", "cls": type(obj).__name__,
                "fields": {f.name: _encode(getattr(obj, f.name), leaves)
                           for f in dataclasses.fields(obj)}}
    if isinstance(obj, (np.ndarray, np.generic, jax.Array)):
        leaves.append(np.asarray(obj))
        return {"k": "arr", "i": len(leaves) - 1}
    raise TypeError(f"cannot serialize {type(obj).__name__} into a "
                    "QuantArtifact (supported: dict/list/tuple, scalars, "
                    f"arrays, {sorted(_QUANTIZERS)})")


def _decode(spec: dict, leaves: List[Any]) -> Any:
    k = spec["k"]
    if k == "none":
        return None
    if k == "py":
        return spec["v"]
    if k == "dict":
        return {key: _decode(s, leaves) for key, s in spec["items"].items()}
    if k in ("list", "tuple"):
        seq = [_decode(s, leaves) for s in spec["items"]]
        return tuple(seq) if k == "tuple" else seq
    if k == "q":
        cls = _QUANTIZERS[spec["cls"]]
        return cls(**{n: _decode(s, leaves)
                      for n, s in spec["fields"].items()})
    if k == "arr":
        return leaves[spec["i"]]
    raise ValueError(f"unknown artifact spec node kind {k!r}")


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class QuantArtifact:
    """qparams + recipe + provenance. See the module docstring.

    ``meta`` keys written by :func:`repro.quant.quantize`:
      model        {"class": "DiTCfg", "cfg": {...}}     (reconstructable)
      dif          {...DiffusionCfg fields...}
      tgq_groups   effective G; tgq_group_boundaries: [[lo, hi), ...]
      calib        pipeline stats (n_quantized, wall_s, ... — no tensors)
      provenance   caller-supplied (git sha, timestamp, arch label, ...)
    """
    qparams: Dict[str, dict]
    recipe: QuantRecipe
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- consumption --------------------------------------------------------
    @property
    def has_kernel_packs(self) -> bool:
        return any(any(p in qp for p in ("int8", "int8_mrq", "int4",
                                         "int4_mrq", "int8_qk", "int8_pv"))
                   for qp in self.qparams.values())

    def fallback_ops(self) -> List[str]:
        """Op names that would take the fake-quant path under
        ``context(kernel=True)`` — quantized matmul ops whose qparams
        carry NO kernel pack. Empty list == every quantized matmul in
        the artifact lowers onto a Pallas kernel (the zero-fallback
        deployment contract; ``launch.serve`` names these ops in its
        fallback warning). Activation-only entries (softmax/GELU hooks)
        are not matmuls and are never counted."""
        out: List[str] = []
        for name in sorted(self.qparams):
            qp = self.qparams[name]
            if name.endswith("/qk"):
                if "int8_qk" not in qp:
                    out.append(name)
            elif name.endswith("/pv"):
                if "int8_pv" not in qp:
                    out.append(name)
            elif "w" in qp and not any(
                    p in qp for p in ("int8", "int8_mrq", "int4",
                                      "int4_mrq")):
                out.append(name)
        return out

    def context(self, kernel: Optional[bool] = None,
                attn_impl: Optional[str] = None):
        """The op context serving this artifact — replaces
        ``make_quant_context``. ``kernel=None`` auto-selects the fused
        int8 kernel path exactly when the artifact carries packs;
        ``attn_impl=None`` uses the recipe's recorded attention lowering
        ('flash' fused single-kernel / 'composed' three-kernel oracle —
        both consume the same packs, so overriding is always safe)."""
        from repro.core.contexts import QuantContext
        if kernel is None:
            kernel = self.has_kernel_packs
        if kernel and not self.has_kernel_packs:
            raise ValueError(
                "artifact has no kernel packs (recipe "
                f"{self.recipe.bits}/{self.recipe.method}); serve it with "
                "kernel=False (fake-quant) or re-quantize with a "
                "kernel-deployable recipe")
        if attn_impl is None:
            attn_impl = self.recipe.attn_impl
        return QuantContext(qparams=self.qparams, kernel=kernel,
                            attn_impl=attn_impl)

    # -- model identity -----------------------------------------------------
    @property
    def params_hash(self) -> Optional[dict]:
        """The fp-params content hash recorded at quantize() time
        (``checkpoint.ckpt.content_hash``), or None for artifacts written
        before hashes were recorded."""
        return self.meta.get("params_hash")

    def check_params(self, params) -> None:
        """Fail fast if ``params`` is not the fp tree this artifact was
        calibrated against. Artifacts without a recorded hash (older
        format) pass — there is nothing to check against."""
        want = self.params_hash
        if want is None:
            return
        got = ckpt.content_hash(params)
        if got["digest"] == want["digest"]:
            return
        if got["n_leaves"] != want["n_leaves"]:
            raise ValueError(
                f"params mismatch: artifact was calibrated against a tree "
                f"with {want['n_leaves']} leaves, got {got['n_leaves']} — "
                "wrong checkpoint for this artifact?")
        n_bad = sum(1 for a, b in zip(got["leaves"], want["leaves"])
                    if a != b)
        raise ValueError(
            f"params content hash mismatch: {n_bad}/{want['n_leaves']} "
            f"leaves differ from the fp params this artifact was "
            f"calibrated against (digest {got['digest']} != "
            f"{want['digest']}) — wrong checkpoint for this artifact?")

    def model_cfg(self):
        m = self.meta.get("model") or {}
        if m.get("class") != "DiTCfg":
            raise ValueError(f"artifact has no DiTCfg metadata (model = "
                             f"{m.get('class')!r})")
        from repro.models.dit import DiTCfg
        return DiTCfg(**m["cfg"])

    def dif_cfg(self):
        if "dif" not in self.meta:
            raise ValueError("artifact has no DiffusionCfg metadata")
        from repro.diffusion import DiffusionCfg
        return DiffusionCfg(**self.meta["dif"])

    def summary(self) -> str:
        n8 = sum(1 for qp in self.qparams.values()
                 if "int8" in qp or "int8_mrq" in qp)
        n4 = sum(1 for qp in self.qparams.values()
                 if "int4" in qp or "int4_mrq" in qp)
        n_attn = sum(1 for qp in self.qparams.values() if "int8_qk" in qp)
        packs = f"{n8} int8 linear packs"
        if n4:
            packs = f"{n4} packed-int4 linear packs"
        return (f"QuantArtifact({self.recipe.bits}/{self.recipe.method}: "
                f"{len(self.qparams)} ops, {packs}, "
                f"{n_attn} int8 attention blocks, "
                f"G={self.meta.get('tgq_groups')})")

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Save under ``path`` (a directory). Returns ``path``.

        Leaf shards commit first (atomically, via ckpt's ``_COMMITTED``
        rename), then ``artifact.json`` replaces atomically. The json
        records the shard checksums from the ckpt manifest, so a crash
        BETWEEN the two steps when overwriting an existing artifact
        (old json + new shards) is detected at load time instead of
        silently decoding new leaves under a stale spec/recipe.
        """
        leaves: List[np.ndarray] = []
        spec = _encode(self.qparams, leaves)
        os.makedirs(path, exist_ok=True)
        step_dir = ckpt.save(path, step=0, tree=leaves, keep=1)
        with open(os.path.join(step_dir, "manifest.json")) as f:
            leaf_hashes = json.load(f)["hashes"]
        doc = {
            "version": ARTIFACT_VERSION,
            "recipe": self.recipe.to_dict(),
            "meta": self.meta,
            "n_leaves": len(leaves),
            "leaf_hashes": leaf_hashes,
            "spec": spec,
        }
        tmp = os.path.join(path, _ARTIFACT_JSON + ".tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, os.path.join(path, _ARTIFACT_JSON))
        return path

    @classmethod
    def load(cls, path: str, expect_recipe: Optional[QuantRecipe] = None,
             params=None) -> "QuantArtifact":
        """Load from ``path``. With ``expect_recipe``, raise ``ValueError``
        if the stored recipe differs (field-by-field diff in the message)
        — the cold-start guard against serving a stale/mismatched
        deployment artifact. With ``params``, additionally verify the fp
        tree against the artifact's recorded content hash
        (:meth:`check_params`) — the wrong-checkpoint guard
        (``ServeEngine.from_artifact`` runs the same check)."""
        doc_path = os.path.join(path, _ARTIFACT_JSON)
        if not os.path.exists(doc_path):
            raise FileNotFoundError(f"no quantization artifact at {path} "
                                    f"(missing {_ARTIFACT_JSON})")
        with open(doc_path) as f:
            doc = json.load(f)
        if doc.get("version") != ARTIFACT_VERSION:
            raise ValueError(f"artifact version {doc.get('version')} != "
                             f"supported {ARTIFACT_VERSION}")
        recipe = QuantRecipe.from_dict(doc["recipe"])
        if expect_recipe is not None and expect_recipe != recipe:
            raise ValueError(
                "artifact recipe mismatch: "
                + "; ".join(f"{k}: artifact={a!r} expected={b!r}"
                            for k, (a, b) in recipe.diff(expect_recipe)
                            .items()))

        step = ckpt.latest_step(path)
        if step is None:
            raise FileNotFoundError(f"artifact at {path} has no committed "
                                    "leaf checkpoint")
        with open(os.path.join(path, f"step_{step:08d}",
                               "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["hashes"] != doc["leaf_hashes"]:
            raise ValueError(
                f"artifact at {path} is inconsistent: artifact.json does "
                "not match the committed leaf checkpoint (interrupted "
                "overwrite?) — re-save the artifact")
        like = [jax.ShapeDtypeStruct(tuple(s), np.dtype(d))
                for s, d in zip(manifest["shapes"], manifest["dtypes"])]
        if len(like) != doc["n_leaves"]:
            raise ValueError(f"leaf count drift at {path}: spec "
                             f"{doc['n_leaves']} vs ckpt {len(like)}")
        # fail-fast on bit-rot BEFORE np.load touches the shards: a corrupt
        # byte otherwise surfaces as a cryptic zip/zlib exception (or
        # silently wrong leaves) far from the artifact path
        ckpt.verify_shards(path, step=step)
        leaves = ckpt.restore(path, like, step=step) if like else []
        qparams = _decode(doc["spec"], list(leaves))
        art = cls(qparams=qparams, recipe=recipe, meta=doc["meta"])
        if params is not None:
            art.check_params(params)
        return art
