"""`QuantRecipe` — the one frozen description of HOW to quantize.

Before the unified API the same knobs lived in three places:
``core.ptq.PTQConfig`` (the HO pipeline), ``core.search.SearchCfg``
(derived from it), and the ad-hoc kwargs of
``serving.quickcal.range_calibrate`` (bits, samples per group). A recipe
collapses all of them into one hashable, JSON-round-trippable value that

- ``repro.quant.quantize`` dispatches on (``method`` picks the pipeline,
  every other field parameterizes it),
- ``QuantArtifact`` embeds verbatim, so a loaded artifact can be checked
  against the recipe a deployment expects (`QuantArtifact.load(path,
  expect_recipe=...)`).

Bit-widths are named (``w8a8``/``w6a6``/``w4a4``) rather than two free
ints because those are the repo's supported deployment points — every
one of them is kernel-real: w8a8/w6a6 run the fused int8 kernel family
(byte codes, only the clip range differs), w4a4 the nibble-packed int4
family with per-K-group weight scales.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Tuple

BITS = {"w8a8": (8, 8), "w6a6": (6, 6), "w4a4": (4, 4)}
METHODS = ("range", "ho")
ATTN_IMPLS = ("flash", "composed")


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """One frozen value describing a quantization run end to end.

    bits    : 'w8a8' | 'w6a6' | 'w4a4' (weight/activation bit-widths).
    method  : 'range' — min/max calibration in seconds (serving bring-up;
              ``serving.quickcal.range_calibrate``); 'ho' — the paper's
              full Hessian-guided candidate search (``core.ptq.run_ptq``).
    use_mrq / use_tgq / tgq_groups : the paper's multi-region quantizers
              and time-grouped parameters. ``tgq_groups=None`` inherits
              the DiffusionCfg's group count (the usual case — the groups
              must agree with the sampler threading them).
    use_fisher / rounds / n_alpha / max_rows_per_batch / fisher_norm /
    bias_correct / channel_balance / balance_alpha : HO-search knobs
              (ignored by 'range'); see ``core.ptq.PTQConfig``.
    n_per_group / calib_batch : Phase-1 calibration sampling (both
              methods) when the caller does not supply ``calib_data``.
    skip_patterns / weight_only_patterns : op-name substrings excluded
              from (activation) quantization. 'ho' only — together with
              ``use_mrq``/``use_tgq``, ``quantize()`` REJECTS non-default
              values under method='range' (that pipeline has no such
              knobs, and silently recording them in the artifact would
              describe a calibration that never happened).
    attn_impl : how w8a8 serving lowers the attention seam — 'flash'
              (default: one fused Pallas kernel, no (S,S) HBM
              round-trip) or 'composed' (the three-kernel exactness
              oracle). A serving-lowering choice, not a calibration
              one — both impls consume the identical packs — but it
              rides the recipe so an artifact records the lowering its
              deployment was validated against (both methods honor it).
    seed    : base PRNG seed for calibration draws and row subsampling.
    """
    bits: str = "w8a8"
    method: str = "range"
    use_mrq: bool = True
    use_tgq: bool = True
    tgq_groups: Optional[int] = None
    use_fisher: bool = True
    rounds: int = 3
    n_alpha: int = 20
    max_rows_per_batch: int = 256
    fisher_norm: str = "batch"
    bias_correct: bool = False
    channel_balance: bool = False
    balance_alpha: float = 0.5
    n_per_group: int = 4
    calib_batch: int = 4
    skip_patterns: Tuple[str, ...] = ("router",)
    weight_only_patterns: Tuple[str, ...] = ()
    attn_impl: str = "flash"
    seed: int = 0

    def __post_init__(self):
        if self.bits not in BITS:
            raise ValueError(
                f"QuantRecipe.bits must be one of {sorted(BITS)}, "
                f"got {self.bits!r}")
        if self.method not in METHODS:
            raise ValueError(
                f"QuantRecipe.method must be one of {METHODS}, "
                f"got {self.method!r}")
        if self.attn_impl not in ATTN_IMPLS:
            raise ValueError(
                f"QuantRecipe.attn_impl must be one of {ATTN_IMPLS}, "
                f"got {self.attn_impl!r}")
        # frozen dataclass: normalize list -> tuple via object.__setattr__
        for f in ("skip_patterns", "weight_only_patterns"):
            object.__setattr__(self, f, tuple(getattr(self, f)))

    @property
    def wbits(self) -> int:
        return BITS[self.bits][0]

    @property
    def abits(self) -> int:
        return BITS[self.bits][1]

    @property
    def kernel_deployable(self) -> bool:
        """Every named bit-width lowers onto a Pallas kernel family:
        w8a8/w6a6 on the fused int8 kernels (byte codes, narrower clip
        range at 6 bits), w4a4 on the packed-int4 kernels (two nibbles
        per byte, per-K-group weight scales)."""
        return self.bits in BITS

    def ptq_config(self, tgq_groups: int):
        """The equivalent ``PTQConfig`` for the 'ho' pipeline."""
        from repro.core.ptq import PTQConfig
        return PTQConfig(
            wbits=self.wbits, abits=self.abits, rounds=self.rounds,
            n_alpha=self.n_alpha, use_fisher=self.use_fisher,
            use_mrq=self.use_mrq, use_tgq=self.use_tgq,
            tgq_groups=tgq_groups,
            max_rows_per_batch=self.max_rows_per_batch,
            skip_patterns=self.skip_patterns,
            weight_only_patterns=self.weight_only_patterns,
            fisher_norm=self.fisher_norm, bias_correct=self.bias_correct,
            channel_balance=self.channel_balance,
            balance_alpha=self.balance_alpha, seed=self.seed)

    # -- serialization (artifact metadata + mismatch checks) ---------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for f in ("skip_patterns", "weight_only_patterns"):
            d[f] = list(d[f])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "QuantRecipe":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown QuantRecipe fields: {sorted(unknown)} "
                             "(artifact written by a newer version?)")
        return cls(**d)

    def diff(self, other: "QuantRecipe") -> dict:
        """{field: (self_value, other_value)} for every differing field."""
        a, b = self.to_dict(), other.to_dict()
        return {k: (a[k], b[k]) for k in a if a[k] != b[k]}

    # -- content identity ---------------------------------------------------
    def canonical_json(self) -> str:
        """The recipe as canonical JSON: keys sorted, no whitespace.
        Field *declaration* order never leaks in, so the serialization —
        and therefore :meth:`content_hash` — is stable across dataclass
        reorderings and across dicts built in any key order."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def content_hash(self) -> str:
        """Stable content digest of the frozen recipe (sha256 of
        :meth:`canonical_json`, first 16 hex chars).

        Two recipes hash equal iff they are field-for-field equal; any
        single field change changes the hash (tested exhaustively in
        ``tests/test_quant_api.py``). This is the identity
        ``repro.autotune`` keys its trial ledger by — a resumed sweep
        recognizes a completed trial by recipe content, not by position
        in the grid — and ``quantize()`` records it under
        ``meta["recipe_hash"]`` so a saved artifact names the exact
        configuration that produced it."""
        return hashlib.sha256(
            self.canonical_json().encode()).hexdigest()[:16]
