"""Quality-evaluation stack for quantized DiT artifacts — the library
behind the table benchmarks and the autotune driver.

Promoted out of ``benchmarks/common.py`` so that non-script consumers
(``repro.autotune``) can score a ``QuantArtifact``'s context without
importing a benchmark module that hard-codes one model. Everything here
is parameterized by the (frozen, hashable) model / diffusion configs and
the seeds that define the evaluation protocol:

- :func:`eval_assets` — real latents + feature net + class proxy for the
  FD / sFD / IS-proxy metrics (`repro.core.metrics`), cached under an
  EXPLICIT key of every input that shapes the assets. The predecessor
  cached under the bare string ``"assets"``, so two callers with
  different model configs or seeds silently shared stale latents and
  feature nets — the regression ``tests/test_eval_lib.py`` pins the fix.
- :func:`generate` — sample n latents through the (possibly quantized)
  model with the repo's respaced DDPM sampler.
- :func:`generate_grouped` — the same chain with a PER-TIMESTEP-GROUP
  context (mixed-precision evaluation: AdaTSQ-style bit allocations run
  group g's denoising steps under group g's quantization). With a
  constant context map it matches :func:`generate` to float tolerance
  (same arithmetic, python loop instead of ``lax.scan`` — the same
  1e-4 bound the repo's sampler-equivalence test uses), the property
  that makes mixed-allocation FD scores comparable to the uniform
  trials' (asserted in ``tests/test_eval_lib.py``).
- :func:`score` — FD / sFD / IS* against the cached assets.
- :func:`noise_mse` / :func:`noise_mse_by_group` — quantized-vs-FP noise
  prediction MSE, overall or per TGQ group. The per-group vector is the
  sensitivity signal the autotune bit allocator consumes, and the cheap
  stage-1 gate of its two-stage evaluator.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import ClassProxy, FeatureNet, fd_score, sfd_score, \
    inception_score_proxy
from repro.data import LatentPipeline
from repro.diffusion import DiffusionCfg, ddpm_sample, make_schedule, \
    q_sample
from repro.diffusion.ddpm import respaced_schedule, respaced_timesteps, \
    tgroup_of
from repro.models import dit_apply
from repro.nn.ctx import FPContext

# a per-group context spec: one context for every group, or an explicit
# group -> context mapping (dict keyed by int, or a G-long sequence)
CtxOfGroup = Union[Dict[int, object], List[object], Tuple[object, ...]]


def make_pipeline(model_cfg, *, pipe_seed: int = 11,
                  pipe_noise: float = 0.3) -> LatentPipeline:
    """The synthetic latent data source matching ``model_cfg``'s shape."""
    return LatentPipeline(model_cfg.img_size, model_cfg.in_ch,
                          model_cfg.n_classes, seed=pipe_seed,
                          noise=pipe_noise)


# ---------------------------------------------------------------------------
# eval assets (real set + feature nets), cached under an explicit key
# ---------------------------------------------------------------------------
_ASSET_CACHE: Dict[tuple, tuple] = {}


def asset_cache_key(model_cfg, n_real: int, data_seed: int, net_seed: int,
                    pipe_seed: int, pipe_noise: float) -> tuple:
    """The full identity of one assets build. ``model_cfg`` is a frozen
    dataclass (hashable); every other field is a scalar. Two calls share
    a cache entry iff they would have built identical assets."""
    return (model_cfg, int(n_real), int(data_seed), int(net_seed),
            int(pipe_seed), float(pipe_noise))


def eval_assets(model_cfg, *, n_real: int = 1024, data_seed: int = 999,
                net_seed: int = 1234, pipe_seed: int = 11,
                pipe_noise: float = 0.3):
    """(real latents, labels, feature net, class proxy) — cached per
    :func:`asset_cache_key`."""
    key = asset_cache_key(model_cfg, n_real, data_seed, net_seed,
                          pipe_seed, pipe_noise)
    if key not in _ASSET_CACHE:
        pipe = make_pipeline(model_cfg, pipe_seed=pipe_seed,
                             pipe_noise=pipe_noise)
        real, labels = pipe.labeled_set(n_real, jax.random.PRNGKey(data_seed))
        net = FeatureNet.make(int(np.prod(real.shape[1:])), seed=net_seed)
        proxy = ClassProxy.fit(real, labels, model_cfg.n_classes)
        _ASSET_CACHE[key] = (real, labels, net, proxy)
    return _ASSET_CACHE[key]


def clear_eval_caches() -> None:
    _ASSET_CACHE.clear()


def score(gen: np.ndarray, model_cfg, *, n_real: int = 1024,
          data_seed: int = 999, net_seed: int = 1234, pipe_seed: int = 11,
          pipe_noise: float = 0.3) -> dict:
    """FD / sFD / IS* of ``gen`` against the cached real assets."""
    real, _, net, proxy = eval_assets(
        model_cfg, n_real=n_real, data_seed=data_seed, net_seed=net_seed,
        pipe_seed=pipe_seed, pipe_noise=pipe_noise)
    return {
        "FD": round(fd_score(real, gen, net), 3),
        "sFD": round(sfd_score(real, gen), 3),
        "IS*": round(inception_score_proxy(gen, proxy), 3),
    }


# ---------------------------------------------------------------------------
# sampling through a (possibly quantized) model
# ---------------------------------------------------------------------------
def _eps_fn(params, model_cfg) -> Callable:
    return lambda x, t, y, c: dit_apply(params, model_cfg, x, t, y, ctx=c)


def generate(params, model_cfg, dif_cfg: DiffusionCfg, *, ctx=None,
             steps: int = 50, n: int = 128, seed: int = 123,
             batch: int = 64, sched=None) -> Tuple[np.ndarray, np.ndarray]:
    """Sample ``n`` latents (+ labels) with the respaced DDPM sampler."""
    ctx = ctx or FPContext()
    sched = sched if sched is not None else make_schedule(dif_cfg)
    eps = _eps_fn(params, model_cfg)
    outs, labels = [], []
    key = jax.random.PRNGKey(seed)
    for s in range(0, n, batch):
        b = min(batch, n - s)
        key, k1, k2 = jax.random.split(key, 3)
        y = jax.random.randint(k1, (b,), 0, model_cfg.n_classes)
        x = ddpm_sample(eps, dif_cfg, sched,
                        (b, model_cfg.img_size, model_cfg.img_size,
                         model_cfg.in_ch), y, k2, steps=steps, ctx=ctx)
        outs.append(np.asarray(x))
        labels.append(np.asarray(y))
    return np.concatenate(outs), np.concatenate(labels)


def _ctx_at(ctx_of_group: CtxOfGroup, g: int):
    if isinstance(ctx_of_group, dict):
        return ctx_of_group[g]
    return ctx_of_group[g]


def _sample_grouped(eps_fn, dif_cfg: DiffusionCfg, sched, shape, y, key,
                    steps: int, ctx_of_group: CtxOfGroup):
    """``ddpm_sample`` unrolled in python with a PER-GROUP context.

    The timestep group of every respaced step is static (the chain is
    fixed up front), so each step can run under the context its group's
    bit-width dictates — the inference side of a per-timestep-group bit
    allocation. Key splitting and update arithmetic mirror
    ``ddpm_sample`` exactly, so a constant ``ctx_of_group`` reproduces
    it to the scan-vs-python-loop float tolerance (1e-4, the same bound
    ``tests/test_diffusion.py`` holds ``ddpm_sample_python`` to;
    asserted in ``tests/test_eval_lib.py``)."""
    use_ts = respaced_timesteps(dif_cfg.T, steps)         # descending
    rsched = respaced_schedule(sched, use_ts)
    n = len(use_ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i                                   # respaced index
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), dif_cfg.T, dif_cfg.tgq_groups))
        ctx = _ctx_at(ctx_of_group, g)
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        noise = jax.random.normal(kn, shape, jnp.float32)
        nonzero = jnp.float32(1.0 if idx > 0 else 0.0)
        x = mean + nonzero * jnp.sqrt(rsched["post_var"][idx]) * noise
    return x


def generate_grouped(params, model_cfg, dif_cfg: DiffusionCfg,
                     ctx_of_group: CtxOfGroup, *, steps: int = 50,
                     n: int = 128, seed: int = 123, batch: int = 64,
                     sched=None) -> Tuple[np.ndarray, np.ndarray]:
    """:func:`generate` with a per-TGQ-group context (mixed precision)."""
    sched = sched if sched is not None else make_schedule(dif_cfg)
    eps = _eps_fn(params, model_cfg)
    outs, labels = [], []
    key = jax.random.PRNGKey(seed)
    for s in range(0, n, batch):
        b = min(batch, n - s)
        key, k1, k2 = jax.random.split(key, 3)
        y = jax.random.randint(k1, (b,), 0, model_cfg.n_classes)
        x = _sample_grouped(eps, dif_cfg, sched,
                            (b, model_cfg.img_size, model_cfg.img_size,
                             model_cfg.in_ch), y, k2, steps, ctx_of_group)
        outs.append(np.asarray(x))
        labels.append(np.asarray(y))
    return np.concatenate(outs), np.concatenate(labels)


# ---------------------------------------------------------------------------
# noise-prediction MSE (the cheap stage-1 signal + sensitivity vector)
# ---------------------------------------------------------------------------
def noise_mse_by_group(params, model_cfg, dif_cfg: DiffusionCfg, ctx, *,
                       n: int = 128, seed: int = 55, pipe_seed: int = 11,
                       pipe_noise: float = 0.3) -> List[float]:
    """Quantized-vs-FP noise prediction MSE, one value per TGQ group.

    ``ctx`` may also be a per-group context spec (see
    :data:`CtxOfGroup`) — group g's MSE is then measured under group g's
    context, which is how a mixed bit allocation is scored."""
    sched = make_schedule(dif_cfg)
    pipe = make_pipeline(model_cfg, pipe_seed=pipe_seed,
                         pipe_noise=pipe_noise)
    key = jax.random.PRNGKey(seed)
    G = dif_cfg.tgq_groups
    out = []
    for g in range(G):
        key, k1, k2, k3 = jax.random.split(key, 4)
        x0, y = pipe.sample(max(n // G, 1), k1)
        t = jax.random.randint(k2, (x0.shape[0],), g * dif_cfg.T // G,
                               (g + 1) * dif_cfg.T // G)
        noise = jax.random.normal(k3, x0.shape)
        xt = q_sample(sched, x0, t, noise)
        gctx = _ctx_at(ctx, g) if isinstance(ctx, (dict, list, tuple)) \
            else ctx
        fp = dit_apply(params, model_cfg, xt, t, y)
        qt = dit_apply(params, model_cfg, xt, t, y, ctx=gctx.with_tgroup(g))
        out.append(float(jnp.mean((fp - qt) ** 2)))
    return out


def noise_mse(params, model_cfg, dif_cfg: DiffusionCfg, ctx, *,
              n: int = 128, seed: int = 55, pipe_seed: int = 11,
              pipe_noise: float = 0.3) -> float:
    """Mean of :func:`noise_mse_by_group` — the scalar the quality tables
    report and the autotune stage-1 gate thresholds."""
    return float(np.mean(noise_mse_by_group(
        params, model_cfg, dif_cfg, ctx, n=n, seed=seed,
        pipe_seed=pipe_seed, pipe_noise=pipe_noise)))
