"""The unified quantization API: recipe in, serializable artifact out.

    QuantRecipe -> quantize() -> QuantArtifact
                                   .context()        serve / evaluate
                                   .save(path)       persist calibration
    QuantArtifact.load(path)  ->   cold-start a deployment, no recalib

This package is the ONE public surface for producing and consuming
quantization state; the pipelines underneath
(``core.ptq.run_ptq`` — the paper's Algorithm 1;
``serving.quickcal.range_calibrate`` — range-only bring-up;
``kernels.ops.convert_for_kernels`` — int8 kernel packing) stay where
they are as implementation, dispatched by ``recipe.method``/``bits``.

``groups`` also hosts the shared timestep-group resolution helper
(:func:`resolve_group`) used by both the calibration side (nearest-group
borrow) and the serving packs (traced clamp) — one contract, one
implementation.
"""
from repro.quant.groups import group_boundaries, resolve_group
from repro.quant.recipe import BITS, METHODS, QuantRecipe
from repro.quant.artifact import ARTIFACT_VERSION, QuantArtifact
from repro.quant.api import quantize

__all__ = [
    "ARTIFACT_VERSION", "BITS", "METHODS", "QuantArtifact", "QuantRecipe",
    "group_boundaries", "quantize", "resolve_group",
]
