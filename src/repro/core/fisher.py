"""Hessian-guided optimization (HO) — Fisher weight computation (§III-B).

The pre-activation Hessian is approximated by the diagonal empirical
Fisher diag((dL/dz)^2) (Eq. 15). We obtain dL/dz for EVERY op output z in
one backward pass by injecting additive zero "taps" at each op output and
differentiating the loss w.r.t. the taps — no framework surgery, fully
jittable.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contexts import ShapeContext, TapContext, stable_seed


def discover_tap_shapes(loss_fn: Callable, batch) -> Dict[str, tuple]:
    """One forward through the loss with a ShapeContext. Returns
    {op_name: (shape, dtype)} for every op output."""
    ctx = ShapeContext()
    loss_fn(ctx, batch)
    return ctx.shapes


def make_fisher_fn(loss_fn: Callable, tap_shapes: Dict[str, tuple],
                   jit: bool = True):
    """Returns fisher(batch) -> {name: dL/dz array} (NOT squared)."""
    def zero_taps():
        return {n: jnp.zeros(s, d) for n, (s, d) in tap_shapes.items()}

    def grads(taps, batch):
        def f(t):
            return loss_fn(TapContext(taps=t), batch)
        return jax.grad(f)(taps)

    if jit:
        grads = jax.jit(grads)

    def fisher(batch):
        return grads(zero_taps(), batch)

    return fisher


def subsample_rows_like(g, max_rows: int, seed: int) -> np.ndarray:
    """Mirror of CalibrationContext._subsample_rows: flatten leading dims to
    rows and take the SAME seeded subset so fisher rows align with the
    stored activation rows of the corresponding op."""
    g = np.asarray(g)
    rows = g.reshape(-1, g.shape[-1])
    if rows.shape[0] > max_rows:
        rng = np.random.default_rng(seed)
        idx = rng.choice(rows.shape[0], max_rows, replace=False)
        rows = rows[idx]
    return rows
