"""OpContext implementations for the PTQ engine.

Pipeline (Algorithm 1):
  1. ``RecordingContext``    — one FP forward; discovers every quantizable
     op, its einsum spec, shapes, and input PROVENANCE (whether operand A
     is a marked post-softmax / post-GELU / post-SiLU tensor).
  2. ``CalibrationContext``  — eager FP forwards over the calibration set;
     stores (batch-subsampled) operand tensors per op, tagged with the
     TGQ timestep group.
  3. ``TapContext``          — jitted forward with additive zero "taps" on
     every op output; ``jax.grad`` w.r.t. the taps yields exactly
     dL/dz^(l), the Fisher weights of Hessian-guided optimization.
  4. ``QuantContext``        — applies the calibrated quantizers
     (simulated quant-dequant). ``kernel=True`` routes packed linears
     through the int8/int6/packed-int4 Pallas kernels instead.

Provenance tracking uses tensor identity: ``act(name, x, kind)`` marks
``id(x)`` so the directly-consuming matmul knows its operand is the
specially-distributed tensor the paper treats with MRQ/TGQ. This works
both eagerly (concrete arrays) and under a single trace (tracer ids are
stable within a trace).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.ctx import OpContext, apply_gate_residual, apply_norm_mod
from repro.core.quantizers import TGQ, apply_quantizer


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str                    # 'linear' | 'einsum'
    spec: Optional[str] = None   # einsum spec (einsum ops)
    b_is_weight: bool = False    # einsum operand b is a parameter tensor
    a_kind: str = "plain"        # 'plain' | 'post_softmax' | 'post_gelu' | 'post_silu'
    x_shape: tuple = ()
    w_shape: tuple = ()
    out_shape: tuple = ()
    n_calls: int = 0             # calls per forward (shared-name ops)


@dataclasses.dataclass
class RecordingContext(OpContext):
    """Discovers the op graph. Execution is full-precision.

    ``acts`` records every act hook (name -> kind). Hooks whose tensor is
    DIRECTLY consumed by a matmul (post-softmax probs, post-GELU hidden)
    are quantized at the consumer (where the HO objective lives); hooks
    that feed elementwise ops first (SwiGLU's silu gate, multiplied by
    ``up`` before the down-proj) are quantized AT THE HOOK — the paper's
    two-lobe asymmetry exists on the silu output, not on the product.
    """
    registry: Dict[str, OpInfo] = dataclasses.field(default_factory=dict)
    acts: Dict[str, str] = dataclasses.field(default_factory=dict)
    _marks: Dict[int, str] = dataclasses.field(default_factory=dict)

    def _reg(self, name, **kw):
        if name in self.registry:
            self.registry[name].n_calls += 1
            return self.registry[name]
        info = OpInfo(name=name, **kw)
        info.n_calls = 1
        self.registry[name] = info
        return info

    def linear(self, name, x, w, b=None, norm_mod=None, gate_residual=None):
        # norm_mod is applied BEFORE registering: the op's quantizable
        # input is the modulated tensor (what the matmul consumes), same
        # as when the model computed the chain itself. The a_kind mark is
        # looked up on the ORIGINAL tensor — fusion sites with norm_mod
        # have plain inputs (the post-GELU fc2 site carries only
        # gate_residual, which leaves x untouched).
        a_kind = self._marks.get(id(x), "plain")
        x = apply_norm_mod(x, norm_mod)
        self._reg(name, kind="linear", a_kind=a_kind,
                  x_shape=tuple(x.shape), w_shape=tuple(w.shape))
        y = x @ w
        if b is not None:
            y = y + b
        self.registry[name].out_shape = tuple(y.shape)
        return apply_gate_residual(y, gate_residual)

    def einsum(self, name, spec, a, b, b_is_weight=False):
        self._reg(name, kind="einsum", spec=spec, b_is_weight=b_is_weight,
                  a_kind=self._marks.get(id(a), "plain"),
                  x_shape=tuple(a.shape), w_shape=tuple(b.shape))
        y = jnp.einsum(spec, a, b)
        self.registry[name].out_shape = tuple(y.shape)
        return y

    def act(self, name, x, kind):
        self._marks[id(x)] = kind
        self.acts[name] = kind
        return x


# ---------------------------------------------------------------------------
# calibration capture
# ---------------------------------------------------------------------------
def stable_seed(name: str, base: int = 0) -> int:
    """Deterministic per-op seed (hash() is salted per process)."""
    import zlib
    return base + (zlib.crc32(name.encode()) & 0xFFFF)


def _subsample_rows(x, max_rows, seed):
    """Flatten leading dims to rows and subsample; returns np.ndarray."""
    x = np.asarray(x)
    rows = x.reshape(-1, x.shape[-1])
    if rows.shape[0] > max_rows:
        rng = np.random.default_rng(seed)
        idx = rng.choice(rows.shape[0], max_rows, replace=False)
        rows = rows[idx]
    return rows


@dataclasses.dataclass
class CalibrationContext(OpContext):
    """Stores calibration tensors per op. Run EAGERLY (not under jit).

    store[name] = list of dicts per batch:
      linear: {'x': rows, 'g': fisher rows or None, 'tg': int}
      einsum: {'a': array, 'b': array (unless b_is_weight), 'g': ..., 'tg': int}
    Weights are captured once in ``weights[name]``.
    """
    registry: Dict[str, OpInfo] = dataclasses.field(default_factory=dict)
    store: Dict[str, List[dict]] = dataclasses.field(default_factory=dict)
    act_store: Dict[str, List[np.ndarray]] = dataclasses.field(
        default_factory=dict)
    hook_acts: frozenset = frozenset()    # act names quantized at the hook
    weights: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    max_rows_per_batch: int = 256
    max_batch_sub: int = 4        # batch-dim subsample for einsum operands
    _marks: Dict[int, str] = dataclasses.field(default_factory=dict)
    _seen: set = dataclasses.field(default_factory=set)
    seed: int = 0

    def begin_batch(self):
        """Reset per-forward dedup (only the FIRST call site of a shared
        op name is stored, matching the fisher tap alignment)."""
        self._seen.clear()

    def _tg(self):
        return int(self.tgroup) if self.tgroup is not None else 0

    def linear(self, name, x, w, b=None, norm_mod=None, gate_residual=None):
        # Calibration captures the MODULATED tensor — the one the matmul
        # (and the fused kernel's quantize prologue) actually consumes.
        x = apply_norm_mod(x, norm_mod)
        if name not in self._seen:
            self._seen.add(name)
            if name not in self.weights:
                self.weights[name] = np.asarray(w)
            rows = _subsample_rows(x, self.max_rows_per_batch,
                                   stable_seed(name, self.seed))
            self.store.setdefault(name, []).append({"x": rows, "tg": self._tg()})
        y = x @ w
        if b is not None:
            y = y + b
        return apply_gate_residual(y, gate_residual)

    def einsum(self, name, spec, a, b, b_is_weight=False):
        if name not in self._seen:
            self._seen.add(name)
            sub = slice(0, self.max_batch_sub)
            rec = {"a": np.asarray(a[sub]), "tg": self._tg()}
            if b_is_weight:
                if name not in self.weights:
                    self.weights[name] = np.asarray(b)
            else:
                rec["b"] = np.asarray(b[sub])
            self.store.setdefault(name, []).append(rec)
        return jnp.einsum(spec, a, b)

    def act(self, name, x, kind):
        self._marks[id(x)] = kind
        if name in self.hook_acts and name not in self._seen:
            self._seen.add(name)
            self.act_store.setdefault(name, []).append(_subsample_rows(
                x, self.max_rows_per_batch, stable_seed(name, self.seed)))
        return x


# ---------------------------------------------------------------------------
# fisher taps
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TapContext(OpContext):
    """Adds ``taps[name]`` to every op output; grad w.r.t. taps = dL/dz."""
    taps: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def _tap(self, name, y):
        t = self.taps.get(name)
        # shape guard: ops sharing a name across call sites with different
        # shapes (e.g. meta-token KV) only tap the recorded-shape site.
        if t is not None and tuple(t.shape) == tuple(y.shape):
            y = y + t
        return y

    def linear(self, name, x, w, b=None, norm_mod=None, gate_residual=None):
        x = apply_norm_mod(x, norm_mod)
        y = x @ w
        if b is not None:
            y = y + b
        # tap the PRE-gate matmul output: dL/dz is defined on the op's
        # own output, exactly as when the model gated outside the seam.
        return apply_gate_residual(self._tap(name, y), gate_residual)

    def einsum(self, name, spec, a, b, b_is_weight=False):
        return self._tap(name, jnp.einsum(spec, a, b))

    def act(self, name, x, kind):
        return x


@dataclasses.dataclass
class ShapeContext(OpContext):
    """Records op OUTPUT shapes only (to build zero taps)."""
    shapes: Dict[str, tuple] = dataclasses.field(default_factory=dict)

    def linear(self, name, x, w, b=None, norm_mod=None, gate_residual=None):
        x = apply_norm_mod(x, norm_mod)
        y = x @ w
        if b is not None:
            y = y + b
        self.shapes.setdefault(name, (tuple(y.shape), y.dtype))
        return apply_gate_residual(y, gate_residual)

    def einsum(self, name, spec, a, b, b_is_weight=False):
        y = jnp.einsum(spec, a, b)
        self.shapes.setdefault(name, (tuple(y.shape), y.dtype))
        return y

    def act(self, name, x, kind):
        return x


# ---------------------------------------------------------------------------
# quantized execution
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class QuantContext(OpContext):
    """Applies calibrated quantizers (fake-quant by default).

    qparams[name] = {
      'w': ChannelQ | None,            # weight / operand-b quantizer
      'x': UniformQ | MRQ* | TGQ | None,  # input / operand-a quantizer
      'x_prescale': array | None,      # PTQ4DiT-like channel balancing
      'out_bias': array | None,        # PTQD-like bias correction
    }
    kernel=True routes packed linears through the fused Pallas kernels
    ('int8' pack -> fused-quantize matmul at 8 or 6 bits, 'int8_mrq' pack
    -> single-pass MRQ matmul, 'int4' / 'int4_mrq' packs -> the
    nibble-packed int4 family with per-K-group weight scales) and whole
    attention blocks through the int8 attention kernels (the
    ``attention`` seam lowers when the op's '/qk' qparams carry an
    'int8_qk' pack and its '/pv' qparams an 'int8_pv' pack; the packs'
    ``bits`` tag sets the code range, and 4-bit flash streams
    nibble-packed kv); the TGQ timestep group (``self.tgroup``, possibly
    traced) is resolved inside the kernels — no per-group repacking or
    retracing.

    ``attn_impl`` picks the attention lowering (kernel=True only):
    'flash' (default) runs the whole block as ONE Pallas kernel —
    ``kernels.flash_attn_mrq``: int8 QK^T -> online softmax -> MRQ codes
    -> dual-region P·V with the (S, S) scores/codes never touching HBM;
    'composed' keeps the three-kernel chain (``int8_bmm_qk`` ->
    ``softmax_mrq_codes`` -> ``int8_bmm_pv``) — the exactness oracle the
    flash path is toleranced against (``ref.flash_vs_composed_atol``).
    """
    qparams: Dict[str, dict] = dataclasses.field(default_factory=dict)
    kernel: bool = False
    attn_impl: str = "flash"

    def _q_in(self, qp, x):
        q = qp.get("x")
        pre = qp.get("x_prescale")
        if pre is not None:
            x = x / pre
        x = apply_quantizer(q, x, tgroup=self.tgroup)
        return x

    def _q_w(self, qp, w):
        pre = qp.get("x_prescale")
        if pre is not None:
            # fold the balancing factor into the weight's input dim
            w = w * pre.reshape((-1,) + (1,) * (w.ndim - 1)) if w.ndim >= 1 else w
        return apply_quantizer(qp.get("w"), w, tgroup=self.tgroup)

    @staticmethod
    def _fold_out_bias(b, ob, gate_residual):
        """When the gate+residual epilogue is fused, the PTQD bias
        correction must land INSIDE the gate — fold it into the matmul
        bias (``residual + gate * (y + ob)``). Unfused, it stays a
        post-add. Returns (bias, post_add)."""
        if ob is None or gate_residual is None:
            return b, ob
        return (ob if b is None else b + ob), None

    def linear(self, name, x, w, b=None, norm_mod=None, gate_residual=None):
        qp = self.qparams.get(name)
        if qp is None:
            x = apply_norm_mod(x, norm_mod)
            y = x @ w
            y = y + b if b is not None else y
            return apply_gate_residual(y, gate_residual)
        if self.kernel:
            # All four pack families fuse the adaLN chains: norm_mod
            # runs in the kernels' quantize prologue, gate_residual in
            # the dequant epilogue (single HBM write).
            for key, fn in (("int8", "int8_linear"),
                            ("int8_mrq", "int8_linear_mrq"),
                            ("int4", "int4_linear"),
                            ("int4_mrq", "int4_linear_mrq")):
                if qp.get(key) is not None:
                    from repro.kernels import ops as kops
                    bias, ob = self._fold_out_bias(b, qp.get("out_bias"),
                                                   gate_residual)
                    y = getattr(kops, fn)(
                        x, qp[key], bias=bias, tgroup=self.tgroup,
                        norm_mod=norm_mod, gate_residual=gate_residual)
                    return y + ob if ob is not None else y
        x = apply_norm_mod(x, norm_mod)
        x = self._q_in(qp, x)
        w = self._q_w(qp, w)
        y = x @ w
        if b is not None:
            y = y + b
        ob = qp.get("out_bias")
        y = y + ob if ob is not None else y
        return apply_gate_residual(y, gate_residual)

    def einsum(self, name, spec, a, b, b_is_weight=False):
        qp = self.qparams.get(name)
        if qp is None:
            return jnp.einsum(spec, a, b)
        a = self._q_in(qp, a)
        bq = qp.get("w") if b_is_weight else qp.get("b")
        b = apply_quantizer(bq, b, tgroup=self.tgroup)
        y = jnp.einsum(spec, a, b)
        ob = qp.get("out_bias")
        return y + ob if ob is not None else y

    def attention(self, name, q, k, v, *, mask=None, scale=1.0):
        # The attention seam lowers to the int8 Pallas kernels exactly
        # like ctx.linear sites: when serving packs exist for BOTH
        # matmuls, the whole block runs int8 with the probs never in HBM
        # as fp — as ONE flash kernel (attn_impl='flash', scores/codes
        # never in HBM at all) or the composed three-kernel chain
        # (attn_impl='composed'). Otherwise fall back to the composed
        # fake-quant seams (OpContext default).
        if self.kernel:
            qk_qp = self.qparams.get(f"{name}/qk") or {}
            pv_qp = self.qparams.get(f"{name}/pv") or {}
            if (qk_qp.get("int8_qk") is not None
                    and pv_qp.get("int8_pv") is not None):
                from repro.kernels import ops as kops
                if self.attn_impl == "flash":
                    return kops.flash_attention(
                        q, k, v, qk_qp["int8_qk"], pv_qp["int8_pv"],
                        mask=mask, scale=scale, tgroup=self.tgroup)
                if self.attn_impl != "composed":
                    raise ValueError(
                        f"QuantContext.attn_impl must be 'flash' or "
                        f"'composed', got {self.attn_impl!r}")
                return kops.int8_attention(
                    q, k, v, qk_qp["int8_qk"], pv_qp["int8_pv"], mask=mask,
                    scale=scale, tgroup=self.tgroup)
        return OpContext.attention(self, name, q, k, v, mask=mask,
                                   scale=scale)

    def act(self, name, x, kind):
        # post-softmax / post-GELU quantize at the consuming matmul (where
        # the HO objective is defined); hook-quantized acts (SwiGLU silu
        # gates, which feed an elementwise product first) quantize here.
        qp = self.qparams.get(name)
        if qp is not None and "act" in qp:
            return apply_quantizer(qp["act"], x, tgroup=self.tgroup)
        return x
