"""TQ-DiT PTQ driver — Algorithm 1 end to end.

Phase 1 (calibration data) is supplied by the caller (for DiT:
``repro.core.calib.build_dit_calibration`` draws n samples per timestep
group; for LMs: token batches). Phase 2 runs FP forwards storing
activations and one tap-backward per batch for the Fisher weights.
Phase 3 runs the HO candidate search per op (TGQ+MRQ for post-softmax
MatMuls, MRQ for post-GELU/SiLU inputs, symmetric per-tensor for
attention q/k/v einsum operands, uniform elsewhere).

The result is a ``qparams`` dict consumed by
:class:`repro.core.contexts.QuantContext`. For int8 deployment the dict
(together with ``report["weights"]``) feeds
``kernels.ops.convert_for_kernels``, which packs every eligible linear
('int8'/'int8_mrq') AND every attention einsum pair ('int8_qk' on
``attn/qk``, 'int8_pv' on ``attn/pv``) — the serving bundle the fused
int8 kernels gather per timestep group at sample time.

This module is the 'ho' pipeline BEHIND the unified API: prefer
``repro.quant.quantize(params, cfg, dif, QuantRecipe(method="ho"))``,
which runs this driver, packs the kernels, and returns a serializable
``QuantArtifact``. ``run_ptq`` stays public for research loops that want
the raw (qparams, report) pair (ablation sweeps, custom calibration).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contexts import (
    CalibrationContext, QuantContext, RecordingContext, stable_seed,
)
from repro.core.fisher import (
    discover_tap_shapes, make_fisher_fn, subsample_rows_like,
)
from repro.core.search import SearchCfg, search_einsum, search_linear


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    wbits: int = 8
    abits: int = 8
    rounds: int = 3
    n_alpha: int = 20
    use_fisher: bool = True          # HO (vs plain MSE)
    use_mrq: bool = True             # multi-region quantizers
    use_tgq: bool = True             # time-grouped post-softmax params
    tgq_groups: int = 10             # G
    max_rows_per_batch: int = 256
    max_batch_sub: int = 4
    skip_patterns: Tuple[str, ...] = ("router",)
    weight_only_patterns: Tuple[str, ...] = ()
    # 'batch' normalizes each calibration batch's Fisher to unit mean per
    # op. The empirical Fisher scales with the squared residual, which for
    # a well-trained DDPM is SMALL at high-noise timesteps — raw weighting
    # therefore under-weights exactly the samples with the widest input
    # ranges and over-clips them (measured: x_proj clip 0.080 vs 0.100,
    # +36% end-to-end noise MSE). Normalization keeps the useful
    # channel/token sensitivity signal and drops the cross-timestep
    # magnitude artifact. 'raw' reproduces the unnormalized objective.
    fisher_norm: str = "batch"
    bias_correct: bool = False       # PTQD-like output correction
    channel_balance: bool = False    # PTQ4DiT-like salience balancing
    balance_alpha: float = 0.5
    seed: int = 0

    def search_cfg(self) -> SearchCfg:
        return SearchCfg(wbits=self.wbits, abits=self.abits, rounds=self.rounds,
                         n_alpha=self.n_alpha, use_fisher=self.use_fisher,
                         use_mrq=self.use_mrq, use_tgq=self.use_tgq,
                         tgq_groups=self.tgq_groups)


def _skip(name: str, patterns) -> bool:
    return any(p in name for p in patterns)


def run_ptq(loss_fn: Callable, calib_batches: List[Tuple[Any, int]],
            cfg: PTQConfig) -> Tuple[Dict[str, dict], Dict[str, Any]]:
    """Run Algorithm 1.

    loss_fn(ctx, batch) -> scalar task loss (Eq. 11 for DiT; CE for LMs).
      The model forward must route ops through ``ctx``.
    calib_batches: [(batch, tgroup_index)] — Phase-1 output.

    Returns (qparams, report).
    """
    t0 = time.perf_counter()
    report: Dict[str, Any] = {}

    # ---- Phase 2a: op discovery ---------------------------------------------
    rec = RecordingContext()
    loss_fn(rec, calib_batches[0][0])
    registry = rec.registry
    report["n_ops"] = len(registry)
    # act hooks not directly consumed by a matmul (SwiGLU silu gates) get
    # quantized at the hook; the two-lobe MRQ lives on the silu output.
    consumed_kinds = {i.a_kind for i in registry.values()}
    hook_acts = frozenset(
        n for n, kind in rec.acts.items()
        if kind == "post_silu" and cfg.use_mrq)

    # ---- Phase 2b: calibration capture ---------------------------------------
    cal = CalibrationContext(registry=registry, hook_acts=hook_acts,
                             max_rows_per_batch=cfg.max_rows_per_batch,
                             max_batch_sub=cfg.max_batch_sub, seed=cfg.seed)
    for batch, tg in calib_batches:
        cal.begin_batch()
        loss_fn(dataclasses.replace(cal, tgroup=tg), batch)

    # ---- Phase 2c: fisher taps (HO) -------------------------------------------
    fish: Dict[str, List[Optional[np.ndarray]]] = {n: [] for n in registry}
    if cfg.use_fisher:
        shapes = discover_tap_shapes(loss_fn, calib_batches[0][0])
        fisher_fn = make_fisher_fn(loss_fn, shapes)
        for batch, tg in calib_batches:
            g = fisher_fn(batch)
            for name, info in registry.items():
                if name not in g:
                    fish[name].append(None)
                    continue
                garr = np.asarray(g[name])
                if cfg.fisher_norm == "batch":
                    rms = np.sqrt(np.mean(np.square(garr))) + 1e-20
                    garr = garr / rms
                if info.kind == "linear":
                    fish[name].append(subsample_rows_like(
                        garr, cfg.max_rows_per_batch,
                        stable_seed(name, cfg.seed)))
                else:
                    fish[name].append(garr[: cfg.max_batch_sub])
    else:
        for name in registry:
            fish[name] = [None] * len(calib_batches)

    t_capture = time.perf_counter() - t0

    # ---- Phase 3: per-op candidate search --------------------------------------
    scfg = cfg.search_cfg()
    qparams: Dict[str, dict] = {}
    for name, info in registry.items():
        if _skip(name, cfg.skip_patterns) or name not in cal.store:
            continue
        weight_only = _skip(name, cfg.weight_only_patterns)
        if info.kind == "linear":
            xs = [r["x"] for r in cal.store[name]]
            prescale = None
            if cfg.channel_balance:
                prescale = _balance_vector(
                    np.concatenate(xs, 0), cal.weights[name], cfg.balance_alpha)
            qparams[name] = search_linear(
                info, xs, fish[name], cal.weights[name], scfg,
                weight_only=weight_only, prescale=prescale,
                tgs=[r["tg"] for r in cal.store[name]])
        else:
            qparams[name] = search_einsum(
                info, cal.store[name], fish[name], scfg,
                w=cal.weights.get(name), weight_only=weight_only)

    # hook-quantized activations (MRQ-SiLU): plain-MSE grid over stored
    # samples — the downstream projection's own HO search covers the
    # joint error (DESIGN §5, MRQ-GELU -> SiLU transfer).
    from repro.core.search import search_hook_act
    for name in sorted(cal.act_store):
        qparams[name] = {"act": search_hook_act(cal.act_store[name], scfg)}

    # ---- optional PTQD-like bias correction -------------------------------------
    if cfg.bias_correct:
        for name, info in registry.items():
            if name not in qparams or info.kind != "linear":
                continue
            qp = qparams[name]
            X = jnp.asarray(np.concatenate(
                [r["x"] for r in cal.store[name]], 0), jnp.float32)
            W = jnp.asarray(cal.weights[name], jnp.float32)
            qctx = QuantContext(qparams={name: qp})
            yq = qctx.linear(name, X, W)
            qp["out_bias"] = jnp.mean(X @ W - yq, axis=0)

    calib_bytes = sum(
        sum((r.get("x", np.zeros(0)).nbytes if "x" in r else
             r["a"].nbytes + r.get("b", np.zeros(0)).nbytes)
            for r in recs)
        for recs in cal.store.values())
    calib_bytes += sum(sum(0 if g is None else g.nbytes for g in gl)
                       for gl in fish.values())

    report.update({
        "wall_s": time.perf_counter() - t0,
        "capture_s": t_capture,
        # attention blocks whose serving packs can be complete: BOTH the
        # /qk and /pv einsum of the block were quantized (QuantContext
        # takes the int8 attention path only when both packs exist)
        "n_attention_einsums": sum(
            1 for n, i in registry.items()
            if i.kind == "einsum" and n.endswith("/qk")
            and n in qparams and n[:-3] + "/pv" in qparams),
        "search_s": time.perf_counter() - t0 - t_capture,
        "calib_bytes": int(calib_bytes),
        "n_quantized": len(qparams),
        "n_batches": len(calib_batches),
        # FP weights captured in Phase 2b, keyed by op name — exactly the
        # second argument kernels.ops.convert_for_kernels wants, so int8
        # deployment needs no second capture pass. In-process use only:
        # anything that serializes the report should drop this key (see
        # benchmarks/common.py) — it is a full weight copy.
        "weights": dict(cal.weights),
    })
    return qparams, report


def _balance_vector(X: np.ndarray, W: np.ndarray, alpha: float) -> np.ndarray:
    """PTQ4DiT/SmoothQuant-style per-input-channel salience balancing:
    s_j = max|X_j|^a / max|W_j|^(1-a)."""
    ax = np.maximum(np.max(np.abs(X), axis=0), 1e-5)
    aw = np.maximum(np.max(np.abs(W), axis=1), 1e-5)
    s = ax ** alpha / aw ** (1 - alpha)
    return np.clip(s / np.sqrt(np.median(s ** 2) + 1e-12), 0.1, 10.0)


def make_quant_context(qparams: Dict[str, dict], kernel: bool = False
                       ) -> QuantContext:
    """DEPRECATED shim for out-of-tree callers.

    The unified API replaced this: ``repro.quant.quantize`` returns a
    ``QuantArtifact`` whose ``.context(kernel=...)`` is the execution
    context (and which saves/loads, so calibration survives the process).
    For a raw qparams dict, construct ``QuantContext(qparams=qp,
    kernel=...)`` directly.
    """
    import warnings
    warnings.warn(
        "make_quant_context is deprecated: use repro.quant.quantize(...)."
        "context(...) (or QuantContext(qparams=..., kernel=...) for a raw "
        "qparams dict)", DeprecationWarning, stacklevel=2)
    return QuantContext(qparams=qparams, kernel=kernel)
