"""TQ-DiT core — the paper's contribution: time-aware post-training
quantization for diffusion transformers (MRQ + TGQ + HO, Algorithm 1)."""
from repro.core.quantizers import (
    UniformQ, ChannelQ, MRQSoftmaxQ, MRQSignedQ, TGQ,
    uniform_qdq, symmetric_qdq, mrq_softmax_qdq, mrq_signed_qdq,
    apply_quantizer, uniform_params_from_range, channel_scale_from_absmax,
    weight_absmax,
)
from repro.core.contexts import (
    OpInfo, RecordingContext, CalibrationContext, TapContext, ShapeContext,
    QuantContext, stable_seed,
)
from repro.core.fisher import discover_tap_shapes, make_fisher_fn
from repro.core.search import SearchCfg, search_linear, search_einsum
from repro.core.ptq import PTQConfig, run_ptq, make_quant_context
from repro.core.calib import (
    build_dit_calibration, dit_loss_fn, build_lm_calibration, lm_loss_fn,
)
from repro.core import baselines
from repro.core import metrics
