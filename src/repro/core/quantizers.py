"""Quantizer primitives for TQ-DiT.

Fake-quant (quantize-dequantize) functions plus the parameter containers
the PTQ engine calibrates. All are simple pytree dataclasses so they can
be captured inside jitted serving functions, checkpointed, and stacked
along a leading TGQ-group axis.

Conventions:
  - weights: per-output-channel SYMMETRIC int-k (matches the MXU s8 path
    of the int8 Pallas kernel — no weight zero-point),
  - activations: per-tensor ASYMMETRIC affine (scale + zero point),
  - attention q/k/v (activation x activation operands): per-tensor
    SYMMETRIC (``SymQ``) so both sides of QK^T and P.V feed the MXU s8
    path without a zero-point correction,
  - post-softmax: MRQ two-region [0, 2^{k-1}s1) / [2^{k-1}s1, 1] with the
    paper's fixed s2 = 1/2^{k-1} (§III-C),
  - post-GELU/SiLU: MRQ signed two-region with independent negative /
    positive step sizes (§III-C),
  - TGQ: any activation quantizer stacked along a leading (G,) axis,
    selected by the diffusion timestep group (§III-A).

Region select is branch-free (mask + where): TPU VPU has no per-element
divergence, so both regions are computed and selected on 8x128 lanes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# primitive fake-quant math
# ---------------------------------------------------------------------------
def _round(x):
    return jnp.round(x)


def uniform_qdq(x, scale, zero, bits: int):
    """Asymmetric affine: xhat = s*(clip(round(x/s)+z, 0, 2^k-1) - z)."""
    n = 2 ** bits - 1
    q = jnp.clip(_round(x / scale) + zero, 0, n)
    return scale * (q - zero)


def symmetric_qdq(x, scale, bits: int):
    """Symmetric signed: q in [-2^{k-1}, 2^{k-1}-1] (int-k two's complement)."""
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jnp.clip(_round(x / scale), lo, hi)
    return scale * q


def sym_act_qdq(x, scale, bits: int):
    """Symmetric per-tensor activation quant-dequant with the WEIGHT code
    range [-(2^{k-1}-1), 2^{k-1}-1] — matches the int8 attention kernels'
    in-VMEM prologue (no zero point, no -128 code)."""
    hi = 2 ** (bits - 1) - 1
    q = jnp.clip(_round(x / scale), -hi, hi)
    return scale * q


def mrq_softmax_qdq(x, s1, bits: int):
    """Two-region quantizer for post-softmax values in [0, 1] (§III-C).

    R1 = [0, 2^{k-1} s1) with searched step s1 (k-1 bit codes);
    R2 = [2^{k-1} s1, 1] with fixed step s2 = 1/2^{k-1}.
    """
    half = 2 ** (bits - 1)
    s2 = 1.0 / half
    thr = half * s1
    q1 = jnp.clip(_round(x / s1), 0, half - 1) * s1
    q2 = jnp.clip(_round(x / s2), 0, half) * s2
    return jnp.where(x < thr, q1, q2)


def mrq_signed_qdq(x, s_neg, s_pos, bits: int):
    """Two-region quantizer for post-GELU/SiLU (§III-C).

    R1 = [-2^{k-1} s_neg, 0] (bounded negative lobe), R2 = [0, 2^{k-1} s_pos),
    with independently calibrated step sizes.
    """
    half = 2 ** (bits - 1)
    qn = jnp.clip(_round(x / s_neg), -half, 0) * s_neg
    qp = jnp.clip(_round(x / s_pos), 0, half - 1) * s_pos
    return jnp.where(x < 0, qn, qp)


# ---------------------------------------------------------------------------
# parameter containers (pytrees)
# ---------------------------------------------------------------------------
@partial(jax.tree_util.register_dataclass,
         data_fields=["scale", "zero"], meta_fields=["bits"])
@dataclasses.dataclass
class UniformQ:
    """Per-tensor asymmetric activation quantizer. scale/zero may carry a
    leading TGQ group axis (select with .at_group)."""
    scale: Any
    zero: Any
    bits: int = 8

    def __call__(self, x):
        return uniform_qdq(x, self.scale, self.zero, self.bits)


@partial(jax.tree_util.register_dataclass,
         data_fields=["scale"], meta_fields=["bits"])
@dataclasses.dataclass
class SymQ:
    """Per-tensor SYMMETRIC activation quantizer — the attention q/k/v
    operand format (codes feed the MXU s8 path of the int8 attention
    kernels directly, so there is no zero point to correct in a batched
    epilogue). ``scale`` may carry a leading TGQ group axis."""
    scale: Any
    bits: int = 8

    def __call__(self, x):
        return sym_act_qdq(x, self.scale, self.bits)


@partial(jax.tree_util.register_dataclass,
         data_fields=["scale"], meta_fields=["bits", "axes"])
@dataclasses.dataclass
class ChannelQ:
    """Per-output-channel symmetric weight quantizer. ``axes`` is the set
    of REDUCED axes used at calibration (kept broadcastable in scale)."""
    scale: Any
    bits: int = 8
    axes: tuple = ()

    def __call__(self, w):
        return symmetric_qdq(w, self.scale, self.bits)


@partial(jax.tree_util.register_dataclass,
         data_fields=["s1"], meta_fields=["bits"])
@dataclasses.dataclass
class MRQSoftmaxQ:
    s1: Any
    bits: int = 8

    def __call__(self, x):
        return mrq_softmax_qdq(x, self.s1, self.bits)


@partial(jax.tree_util.register_dataclass,
         data_fields=["s_neg", "s_pos"], meta_fields=["bits"])
@dataclasses.dataclass
class MRQSignedQ:
    s_neg: Any
    s_pos: Any
    bits: int = 8

    def __call__(self, x):
        return mrq_signed_qdq(x, self.s_neg, self.s_pos, self.bits)


@partial(jax.tree_util.register_dataclass,
         data_fields=["inner"], meta_fields=[])
@dataclasses.dataclass
class TGQ:
    """Time-grouped wrapper: ``inner`` holds a quantizer whose array leaves
    are stacked (G, ...); ``select(g)`` gathers group g (g may be traced)."""
    inner: Any

    def select(self, g):
        return jax.tree.map(lambda a: jnp.take(a, g, axis=0), self.inner)

    def __call__(self, x, g=None):
        q = self.inner if g is None else self.select(g)
        return q(x)


def apply_quantizer(q, x, tgroup=None):
    """Dispatch helper: applies q to x, resolving TGQ group selection.

    ``tgroup`` may be a per-slot (B,) VECTOR (vector-tgroup batched
    path): each stacked (G,) param leaf gathers per slot to (B,) and is
    reshaped to broadcast along x's leading batch axis — slot b's rows
    fake-quantize with slot b's group params, matching the per-row
    gather inside the ``*_vec`` serving kernels."""
    if q is None:
        return x
    if isinstance(q, TGQ):
        if tgroup is None:
            # no group info (e.g. non-diffusion eval): use group 0
            tgroup = 0
        if getattr(tgroup, "ndim", 0) == 1:
            B = tgroup.shape[0]
            sel = q.select(tgroup)          # leaves (G,) -> (B,)
            sel = jax.tree.map(
                lambda a: jnp.reshape(a, (B,) + (1,) * (x.ndim - 1)), sel)
            return sel(x)
        return q(x, tgroup)
    return q(x)


# ---------------------------------------------------------------------------
# calibration helpers: closed-form initial params from ranges
# ---------------------------------------------------------------------------
def uniform_params_from_range(lo, hi, bits: int):
    """(scale, zero) covering [lo, hi]."""
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, 0.0)
    scale = jnp.maximum((hi - lo) / (2 ** bits - 1), 1e-8)
    zero = _round(-lo / scale)
    return scale, zero


def channel_scale_from_absmax(absmax, bits: int):
    return jnp.maximum(absmax / (2 ** (bits - 1) - 1), 1e-8)


def sym_scale_from_absmax(absmax, bits: int):
    """Per-tensor symmetric step covering [-absmax, absmax]."""
    return jnp.maximum(jnp.asarray(absmax, jnp.float32)
                       / (2 ** (bits - 1) - 1), 1e-8)


def weight_absmax(w, channel_axis: int = -1):
    """Per-output-channel absmax, keepdims (broadcastable against w)."""
    axes = tuple(i for i in range(w.ndim) if i != channel_axis % w.ndim)
    return jnp.max(jnp.abs(w), axis=axes, keepdims=True)
