"""Phase 1 of Algorithm 1 — calibration dataset construction with time
grouping (§III-A), plus the loss closures used for calibration capture and
Fisher backprop.

Default protocol: tuples (x_t, t, y) are built by FORWARD diffusion of
dataset latents with a KNOWN noise target, so the DDPM loss (Eq. 11) and
its gradients are exactly defined for every tuple. Timesteps are drawn
uniformly within each group G_i = [(i-1)T/G, iT/G); n samples per group.

An alternative sampler-trajectory harvest (Q-Diffusion protocol) is
available via ``harvest_trajectory=True``; it reuses
``repro.diffusion.collect_xt_dataset`` and pairs each harvested x_t with a
synthetic forward-consistent noise target.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion import (
    DiffusionCfg, collect_xt_dataset, make_schedule, q_sample, tgroup_of,
)
from repro.models.dit import DiTCfg, dit_apply


def build_dit_calibration(params, dcfg: DiTCfg, dif: DiffusionCfg, sched,
                          x0_source: Callable[[int, Any], jnp.ndarray],
                          key, n_per_group: int = 32, batch: int = 8,
                          n_classes: Optional[int] = None,
                          harvest_trajectory: bool = False,
                          steps: Optional[int] = None
                          ) -> List[Tuple[Dict[str, Any], int]]:
    """Returns [(batch_dict, group)] with n_per_group samples per group.

    x0_source(n, key) -> (n, H, W, C) latents from the data pipeline.
    batch_dict = {'xt', 't', 'y', 'noise'}.
    """
    G, T = dif.tgq_groups, dif.T
    n_classes = n_classes or dcfg.n_classes
    out: List[Tuple[Dict[str, Any], int]] = []

    if harvest_trajectory:
        eps_fn = lambda x, t, y, ctx: dit_apply(params, dcfg, x, t, y)
        for g in range(G):
            key, k1, k2 = jax.random.split(key, 3)
            want = np.array([int((g + 0.5) * T / G)])
            y = jax.random.randint(k1, (n_per_group,), 0, n_classes)
            shape = (n_per_group, dcfg.img_size, dcfg.img_size, dcfg.in_ch)
            tuples = collect_xt_dataset(eps_fn, dif, sched, shape, y, k2,
                                        steps or T, want)
            for xt, t, yy in tuples:
                key, kn = jax.random.split(key)
                noise = jax.random.normal(kn, xt.shape)
                for s in range(0, n_per_group, batch):
                    sl = slice(s, s + batch)
                    out.append(({"xt": jnp.asarray(xt[sl]),
                                 "t": jnp.full((xt[sl].shape[0],), t, jnp.int32),
                                 "y": jnp.asarray(yy[sl]),
                                 "noise": noise[sl]}, g))
        return out

    for g in range(G):
        lo, hi = g * T // G, (g + 1) * T // G
        for s in range(0, n_per_group, batch):
            b = min(batch, n_per_group - s)
            key, k1, k2, k3, k4 = jax.random.split(key, 5)
            x0 = x0_source(b, k1)
            t = jax.random.randint(k2, (b,), lo, hi)
            y = jax.random.randint(k3, (b,), 0, n_classes)
            noise = jax.random.normal(k4, x0.shape)
            xt = q_sample(sched, x0, t, noise)
            out.append(({"xt": xt, "t": t, "y": y, "noise": noise}, g))
    return out


def dit_loss_fn(params, dcfg: DiTCfg) -> Callable:
    """DDPM noise-prediction loss (Eq. 11) routing ops through ctx."""
    def loss(ctx, batch):
        eps = dit_apply(params, dcfg, batch["xt"], batch["t"], batch["y"],
                        ctx=ctx)
        return jnp.mean(jnp.square(eps - batch["noise"]))
    return loss


def build_lm_calibration(token_batches: List[jnp.ndarray]
                         ) -> List[Tuple[Dict[str, Any], int]]:
    """LM calibration: [(batch, 0)] — no diffusion timestep, so a single
    TGQ group (the technique's time axis is inapplicable; DESIGN §5)."""
    out = []
    for toks in token_batches:
        labels = jnp.concatenate(
            [toks[:, 1:], jnp.full((toks.shape[0], 1), -1, toks.dtype)], axis=1)
        out.append(({"tokens": toks, "labels": labels}, 0))
    return out


def lm_loss_fn(params, cfg) -> Callable:
    from repro.models.lm import lm_loss_fn as _lm_loss

    def loss(ctx, batch):
        l, _ = _lm_loss(params, cfg, batch, ctx=ctx)
        return l
    return loss
