"""Baseline PTQ schemes (§IV-A) expressed as PTQConfig presets.

All schemes consume the SAME calibration protocol so comparisons isolate
the quantizer/optimizer design, matching the paper's setup ("the same
number of calibration samples for all baseline schemes"):

  - baseline      — uniform quantizers, plain-MSE search (ablation row a)
  - q_diffusion   — Q-Diffusion-like: time-distributed calibration +
                    uniform quantizers with MSE search (on DiT)
  - ptqd          — PTQD-like: baseline + quantization-noise bias
                    correction on linear outputs
  - ptq4dit       — PTQ4DiT-like: salience-based channel balancing
                    (activation<->weight magnitude redistribution) before
                    MSE search; heavier calibration (Table IV)
  - tq_dit        — the paper: HO + MRQ + TGQ
  - ablations     — +HO, +HO+MRQ rows of Table III
"""
from __future__ import annotations

from repro.core.ptq import PTQConfig


def baseline(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    return PTQConfig(wbits=w, abits=a, use_fisher=False, use_mrq=False,
                     use_tgq=False, **kw)


def q_diffusion(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    # time-distributed calibration is supplied by Phase 1; quantizer side
    # is uniform + MSE.
    return PTQConfig(wbits=w, abits=a, use_fisher=False, use_mrq=False,
                     use_tgq=False, **kw)


def ptqd(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    return PTQConfig(wbits=w, abits=a, use_fisher=False, use_mrq=False,
                     use_tgq=False, bias_correct=True, **kw)


def ptq4dit(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    # salience redistribution + larger capture (the benchmark feeds it a
    # bigger calibration set per Table IV's overhead comparison).
    kw.setdefault("max_rows_per_batch", 1024)
    return PTQConfig(wbits=w, abits=a, use_fisher=True, use_mrq=False,
                     use_tgq=False, channel_balance=True, **kw)


def tq_dit(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    return PTQConfig(wbits=w, abits=a, use_fisher=True, use_mrq=True,
                     use_tgq=True, **kw)


def ablation_ho(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    return PTQConfig(wbits=w, abits=a, use_fisher=True, use_mrq=False,
                     use_tgq=False, **kw)


def ablation_ho_mrq(w: int = 8, a: int = 8, **kw) -> PTQConfig:
    return PTQConfig(wbits=w, abits=a, use_fisher=True, use_mrq=True,
                     use_tgq=False, **kw)


SCHEMES = {
    "baseline": baseline,
    "q_diffusion": q_diffusion,
    "ptqd": ptqd,
    "ptq4dit": ptq4dit,
    "tq_dit": tq_dit,
    "+HO": ablation_ho,
    "+HO+MRQ": ablation_ho_mrq,
}
