"""Generation-quality metrics (CPU-scale stand-ins for FID / sFID / IS).

FID's math is the Fréchet distance between Gaussians fitted to features;
we keep the math and swap InceptionV3 for a FIXED seeded random-projection
feature net (two-layer tanh MLP), which preserves orderings between
quantization schemes — the quantity Tables I-III compare. sFID's
spatial sensitivity is approximated by extracting features from spatial
patches. IS is replaced by a class-separation proxy: a Gaussian
class-conditional classifier is fitted on REAL features, and
IS* = exp(E_x KL(p(y|x) || p(y))) is computed on generated samples —
identical formula to IS with the fitted classifier standing in for
Inception's.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
import scipy.linalg


# ---------------------------------------------------------------------------
# feature extractor (fixed random projection net)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FeatureNet:
    w1: np.ndarray
    w2: np.ndarray

    @staticmethod
    def make(in_dim: int, hidden: int = 256, out: int = 64, seed: int = 1234):
        rng = np.random.default_rng(seed)
        w1 = rng.normal(0, 1.0 / np.sqrt(in_dim), (in_dim, hidden))
        w2 = rng.normal(0, 1.0 / np.sqrt(hidden), (hidden, out))
        return FeatureNet(w1=w1.astype(np.float32), w2=w2.astype(np.float32))

    def __call__(self, x: np.ndarray) -> np.ndarray:
        """x: (N, ...) -> (N, out)."""
        flat = np.asarray(x, np.float32).reshape(x.shape[0], -1)
        h = np.tanh(flat @ self.w1)
        return h @ self.w2


def spatial_features(x: np.ndarray, net: FeatureNet, patches: int = 2
                     ) -> np.ndarray:
    """sFID-style: features per spatial quadrant, concatenated stats dims."""
    N, H, W = x.shape[0], x.shape[1], x.shape[2]
    hs, ws = H // patches, W // patches
    feats = []
    for i in range(patches):
        for j in range(patches):
            feats.append(net(x[:, i * hs:(i + 1) * hs, j * ws:(j + 1) * ws]))
    return np.concatenate(feats, axis=1)


# ---------------------------------------------------------------------------
# Fréchet distance
# ---------------------------------------------------------------------------
def gaussian_stats(f: np.ndarray):
    mu = f.mean(axis=0)
    cov = np.cov(f, rowvar=False)
    return mu, cov


def frechet_distance(mu1, cov1, mu2, cov2, eps: float = 1e-6) -> float:
    """||mu1-mu2||^2 + Tr(C1 + C2 - 2 (C1 C2)^{1/2}) — identical to FID."""
    diff = mu1 - mu2
    covmean, _ = scipy.linalg.sqrtm(cov1 @ cov2, disp=False)
    if not np.isfinite(covmean).all():
        off = eps * np.eye(cov1.shape[0])
        covmean, _ = scipy.linalg.sqrtm((cov1 + off) @ (cov2 + off), disp=False)
    covmean = np.real(covmean)
    return float(diff @ diff + np.trace(cov1) + np.trace(cov2)
                 - 2 * np.trace(covmean))


def fd_score(real: np.ndarray, gen: np.ndarray, net: Optional[FeatureNet] = None
             ) -> float:
    """FID stand-in on raw sample tensors (N,H,W,C)."""
    net = net or FeatureNet.make(int(np.prod(real.shape[1:])))
    m1, c1 = gaussian_stats(net(real))
    m2, c2 = gaussian_stats(net(gen))
    return frechet_distance(m1, c1, m2, c2)


def sfd_score(real: np.ndarray, gen: np.ndarray, seed: int = 77) -> float:
    """sFID stand-in: Fréchet distance over spatial-patch features."""
    H, W, C = real.shape[1:]
    net = FeatureNet.make((H // 2) * (W // 2) * C, seed=seed)
    m1, c1 = gaussian_stats(spatial_features(real, net))
    m2, c2 = gaussian_stats(spatial_features(gen, net))
    return frechet_distance(m1, c1, m2, c2)


# ---------------------------------------------------------------------------
# IS proxy: Gaussian class-conditional classifier fitted on real data
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClassProxy:
    net: FeatureNet
    means: np.ndarray            # (K, F)
    prec: np.ndarray             # shared precision (F, F)
    logdet: float

    @staticmethod
    def fit(real: np.ndarray, labels: np.ndarray, n_classes: int,
            net: Optional[FeatureNet] = None, ridge: float = 1e-3):
        net = net or FeatureNet.make(int(np.prod(real.shape[1:])))
        f = net(real)
        means = np.stack([
            f[labels == k].mean(axis=0) if np.any(labels == k)
            else f.mean(axis=0)
            for k in range(n_classes)])
        centered = f - means[labels]
        cov = np.cov(centered, rowvar=False) + ridge * np.eye(f.shape[1])
        prec = np.linalg.inv(cov)
        sign, logdet = np.linalg.slogdet(cov)
        return ClassProxy(net=net, means=means, prec=prec, logdet=float(logdet))

    def posterior(self, x: np.ndarray) -> np.ndarray:
        f = self.net(x)                                  # (N, F)
        d = f[:, None, :] - self.means[None]             # (N, K, F)
        logp = -0.5 * np.einsum("nkf,fg,nkg->nk", d, self.prec, d)
        logp -= logp.max(axis=1, keepdims=True)
        p = np.exp(logp)
        return p / p.sum(axis=1, keepdims=True)


def inception_score_proxy(gen: np.ndarray, proxy: ClassProxy) -> float:
    """exp(E_x KL(p(y|x) || p(y))) with the fitted class-conditional model."""
    p = proxy.posterior(gen)                             # (N, K)
    marg = p.mean(axis=0, keepdims=True)
    kl = np.sum(p * (np.log(p + 1e-12) - np.log(marg + 1e-12)), axis=1)
    return float(np.exp(kl.mean()))
