"""Batched int8 matmul Pallas kernels for the attention hot path.

Attention is the memory-bound quadratic half of a DiT block and — before
this module — the last full-precision island in the W8A8 serving path:
QK^T and P·V ran as fp einsums and the post-softmax MRQ quantizer
dequantized the probabilities back to fp before P·V. Two kernels close
the gap:

``int8_bmm_qk``
    scores[b] = (q8[b] @ k8[b]^T) * (s_q[g] * s_k[g] * alpha). Both
    operands are ACTIVATIONS quantized with per-tensor SYMMETRIC steps
    in the fused prologue (fp tile -> s8 codes in VMEM, no zero point,
    so no correction term in the batched epilogue). ``alpha`` — the
    softmax 1/sqrt(hd) — is folded into the stacked scale row, so the
    dequantized scores are written to HBM exactly once.

``int8_bmm_pv``
    out[b] = (P[b] @ v8[b]) with P consumed DIRECTLY as the
    region-signed int8 codes emitted by ``softmax_mrq_codes`` (see
    ``kernels/softmax_mrq.py``): code c >= 0 is a region-1 (fine step
    s1) prob code, c < 0 stores the NEGATED region-2 (coarse step
    s2 = 1/2^{k-1}) code. The kernel splits the code tile into the two
    non-negative region magnitudes in VMEM and feeds TWO s32
    accumulators against ONE read of the v tile (quantized in the same
    prologue style), mirroring ``int8_matmul_mrq_fq``'s dual-region
    structure; the epilogue recombines with the per-region scales
    s1[g]*s_v[g] and s2*s_v[g]. The probabilities therefore never exist
    in HBM as floats — codes out of the softmax kernel, codes into P·V.

TGQ exactly as in ``int8_fused``: every activation-side parameter is
stacked along a leading (G,) group axis and the timestep group ``g`` —
a traced scalar inside the ``ddpm_sample`` lax.scan — is
scalar-prefetched; the per-group row is gathered by the BlockSpec index
maps, so the whole sampling loop stays ONE compiled executable.

Tiling: grid (B, M/bm, N/bn, K/bk) with the contraction innermost and a
leading batch axis (one (b, h) attention matrix per batch step);
(bm, bn) s32 accumulator(s) in VMEM scratch. Non-aligned shapes are
zero-padded; padded contraction columns quantize to code 0 and
contribute nothing.

GQA: the q-side batch may be a multiple of the k/v-side batch (G query
groups per kv head). The kernels gather the SHARED kv tile with a
``b // rep`` batch index map instead of asking the caller to materialize
G HBM copies of k/v — each kv head streams from HBM once per group
schedule, and q-side batches that share a kv head reuse the same tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.int8_matmul import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, \
    _ceil, _pad_to


def _sym_codes(x, scale, half):
    """fp tile -> symmetric s8 codes in VMEM (weight code range, no -128)."""
    return jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                    -(half - 1), half - 1).astype(jnp.int8)


def _qk_kernel(g_ref, q_ref, k_ref, sq_ref, sk_ref, scale_ref, o_ref,
               acc_ref, *, nk: int, half: int):
    """Grid body for ``int8_bmm_qk`` at grid point (b, m, n, d).

    Refs arrive as VMEM tiles gathered by the index maps: q (1, bm, bd)
    fp, k (1, bn, bd) fp, and the group-``g`` rows of the stacked (G, 1)
    params. ``acc_ref`` is a persistent (bm, bn) s32 scratch zeroed at
    d == 0 and epilogued at d == nk - 1 (d innermost). ``g_ref`` feeds
    the index maps only.
    """
    del g_ref
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q8 = _sym_codes(q_ref[0], sq_ref[0, 0], half)
    k8 = _sym_codes(k_ref[0], sk_ref[0, 0], half)
    acc_ref[...] += jax.lax.dot_general(
        q8.astype(jnp.int32), k8.astype(jnp.int32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(d == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...].astype(jnp.float32)
                    * scale_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_bmm_qk(q, k, s_q, s_k, scale, g=None, *, bits=8, bm=DEFAULT_BM,
                bn=DEFAULT_BN, bk=DEFAULT_BK, out_dtype=jnp.float32,
                interpret=False):
    """scores[B,M,N] = (q8 @ k8^T) * scale[g], q8/k8 symmetric s8 codes.

    q: (B, M, D) float, k: (Bk, N, D) float (contraction over D = head
    dim) with B = rep * Bk — the GQA layout where ``rep`` query-group
    batches share each kv head; the kernel gathers the shared k tile via
    a ``b // rep`` index map (no materialized copies). s_q/s_k: (G, 1)
    f32 per-tensor symmetric steps; scale: (G, 1) f32 combined
    s_q[g]*s_k[g]*alpha (alpha = the softmax scale, folded by the
    caller). g is the TGQ group — python int or traced scalar
    (scalar-prefetched, gathered by the index maps; no retrace across
    groups).
    """
    B, M, D = q.shape
    B2, N, D2 = k.shape
    assert D == D2 and B % B2 == 0, (q.shape, k.shape)
    rep = B // B2
    G = s_q.shape[0]
    assert s_k.shape == (G, 1) and scale.shape == (G, 1), \
        (s_q.shape, s_k.shape, scale.shape)
    half = 2 ** (bits - 1)
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(D))
    Mp, Np, Dp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(D, bk_)

    if g is None:
        g = 0
    q = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, Dp - D)))
    k = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, Np - N), (0, Dp - D)))

    nk = Dp // bk_
    grid = (B, Mp // bm_, Np // bn_, nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda b, m, n, d, g: (b, m, d)),
            pl.BlockSpec((1, bn_, bk_),
                         lambda b, m, n, d, g: (b // rep, n, d)),  # shared kv
            pl.BlockSpec((1, 1), lambda b, m, n, d, g: (g[0], 0)),   # s_q[g]
            pl.BlockSpec((1, 1), lambda b, m, n, d, g: (g[0], 0)),   # s_k[g]
            pl.BlockSpec((1, 1), lambda b, m, n, d, g: (g[0], 0)),   # scale[g]
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda b, m, n, d, g: (b, m, n)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_qk_kernel, nk=nk, half=half),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Mp, Np), out_dtype),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), q, k,
      s_q.astype(jnp.float32), s_k.astype(jnp.float32),
      scale.astype(jnp.float32))
    return out[:, :M, :N]


def _pv_kernel(g_ref, c_ref, v_ref, sv_ref, scale1_ref, scale2_ref, o_ref,
               acc1_ref, acc2_ref, *, nk: int, half: int):
    """Grid body for ``int8_bmm_pv`` at grid point (b, m, d, n).

    The prob-code tile (1, bm, bn) is split by SIGN into the two region
    magnitude tiles (region 1: c, region 2: -c — disjoint support by
    construction of the encoding) feeding dual s32 accumulators against
    a single read of the v tile, which is quantized in the prologue with
    the group-``g`` symmetric step. Epilogue recombines with the
    per-region combined scales. n (the Skv contraction) is innermost.
    """
    del g_ref
    n = pl.program_id(3)

    @pl.when(n == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    c = c_ref[0].astype(jnp.int32)
    c1 = jnp.maximum(c, 0)                    # region-1 codes [0, half-1]
    c2 = jnp.maximum(-c, 0)                   # region-2 codes [0, half]
    v8 = _sym_codes(v_ref[0], sv_ref[0, 0], half).astype(jnp.int32)
    dims = (((1,), (0,)), ((), ()))           # ONE v-tile read, two dots
    acc1_ref[...] += jax.lax.dot_general(c1, v8, dims,
                                         preferred_element_type=jnp.int32)
    acc2_ref[...] += jax.lax.dot_general(c2, v8, dims,
                                         preferred_element_type=jnp.int32)

    @pl.when(n == nk - 1)
    def _epilogue():
        y = (acc1_ref[...].astype(jnp.float32) * scale1_ref[0, 0]
             + acc2_ref[...].astype(jnp.float32) * scale2_ref[0, 0])
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_bmm_pv(codes, v, s_v, scale1, scale2, g=None, *, bits=8,
                bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                out_dtype=jnp.float32, interpret=False):
    """out[B,M,D] = scale1[g]*(c1 @ v8) + scale2[g]*(c2 @ v8).

    codes: (B, M, N) int8 region-signed MRQ prob codes (c >= 0: region-1
    code, c < 0: negated region-2 code — the ``softmax_mrq_codes``
    output); v: (Bv, N, D) float with B = rep * Bv (GQA: ``rep``
    query-group batches share each v head, gathered via a ``b // rep``
    index map), quantized in-kernel with s_v[g].
    s_v: (G, 1) f32; scale1/scale2: (G, 1) f32 combined region*value
    scales (s1[g]*s_v[g] and s2*s_v[g], s2 = 1/2^{k-1}).
    """
    B, M, N = codes.shape
    B2, N2, D = v.shape
    assert N == N2 and B % B2 == 0, (codes.shape, v.shape)
    rep = B // B2
    G = s_v.shape[0]
    assert scale1.shape == (G, 1) and scale2.shape == (G, 1), \
        (s_v.shape, scale1.shape, scale2.shape)
    half = 2 ** (bits - 1)
    bm_, bd_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(D)), min(bk, _ceil(N))
    Mp, Dp, Np = _pad_to(M, bm_), _pad_to(D, bd_), _pad_to(N, bn_)

    if g is None:
        g = 0
    codes = jnp.pad(codes, ((0, 0), (0, Mp - M), (0, Np - N)))
    v = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, Np - N), (0, Dp - D)))

    nk = Np // bn_
    grid = (B, Mp // bm_, Dp // bd_, nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bn_), lambda b, m, d, n, g: (b, m, n)),
            pl.BlockSpec((1, bn_, bd_),
                         lambda b, m, d, n, g: (b // rep, n, d)),  # shared kv
            pl.BlockSpec((1, 1), lambda b, m, d, n, g: (g[0], 0)),  # s_v[g]
            pl.BlockSpec((1, 1), lambda b, m, d, n, g: (g[0], 0)),  # scale1
            pl.BlockSpec((1, 1), lambda b, m, d, n, g: (g[0], 0)),  # scale2
        ],
        out_specs=pl.BlockSpec((1, bm_, bd_), lambda b, m, d, n, g: (b, m, d)),
        scratch_shapes=[pltpu.VMEM((bm_, bd_), jnp.int32),
                        pltpu.VMEM((bm_, bd_), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_pv_kernel, nk=nk, half=half),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Mp, Dp), out_dtype),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), codes, v,
      s_v.astype(jnp.float32), scale1.astype(jnp.float32),
      scale2.astype(jnp.float32))
    return out[:, :M, :D]


# ---------------------------------------------------------------------------
# vector-tgroup variants: per-BATCH-ROW groups via a (B,) prefetch vector
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_bmm_qk_vec(q, k, s_q, s_k, scale, gv=None, *, bits=8, bm=DEFAULT_BM,
                    bn=DEFAULT_BN, bk=DEFAULT_BK, out_dtype=jnp.float32,
                    interpret=False):
    """``int8_bmm_qk`` with a per-batch-row group vector gv (B,) int32.

    The kernel BODY (``_qk_kernel``) is unchanged; the batch axis leads
    the grid, so the whole (B,) vector rides as the prefetched array and
    each param index map picks ``(g[b], 0)`` — batch row b's params
    stream per grid row, k/v sharing (GQA ``b // rep``) untouched. A
    constant gv is bit-identical to the scalar path.
    """
    B, M, D = q.shape
    B2, N, D2 = k.shape
    assert D == D2 and B % B2 == 0, (q.shape, k.shape)
    rep = B // B2
    G = s_q.shape[0]
    assert s_k.shape == (G, 1) and scale.shape == (G, 1), \
        (s_q.shape, s_k.shape, scale.shape)
    half = 2 ** (bits - 1)
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(D))
    Mp, Np, Dp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(D, bk_)

    gv = (jnp.zeros((B,), jnp.int32) if gv is None
          else jnp.asarray(gv, jnp.int32).reshape(B))
    q = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, Dp - D)))
    k = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, Np - N), (0, Dp - D)))

    nk = Dp // bk_
    grid = (B, Mp // bm_, Np // bn_, nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda b, m, n, d, g: (b, m, d)),
            pl.BlockSpec((1, bn_, bk_),
                         lambda b, m, n, d, g: (b // rep, n, d)),  # shared kv
            pl.BlockSpec((1, 1), lambda b, m, n, d, g: (g[b], 0)),  # s_q[g_b]
            pl.BlockSpec((1, 1), lambda b, m, n, d, g: (g[b], 0)),  # s_k[g_b]
            pl.BlockSpec((1, 1), lambda b, m, n, d, g: (g[b], 0)),  # scale
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda b, m, n, d, g: (b, m, n)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_qk_kernel, nk=nk, half=half),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Mp, Np), out_dtype),
        interpret=interpret,
    )(gv, q, k, s_q.astype(jnp.float32), s_k.astype(jnp.float32),
      scale.astype(jnp.float32))
    return out[:, :M, :N]


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_bmm_pv_vec(codes, v, s_v, scale1, scale2, gv=None, *, bits=8,
                    bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                    out_dtype=jnp.float32, interpret=False):
    """``int8_bmm_pv`` with a per-batch-row group vector gv (B,) int32
    (same contract as ``int8_bmm_qk_vec``)."""
    B, M, N = codes.shape
    B2, N2, D = v.shape
    assert N == N2 and B % B2 == 0, (codes.shape, v.shape)
    rep = B // B2
    G = s_v.shape[0]
    assert scale1.shape == (G, 1) and scale2.shape == (G, 1), \
        (s_v.shape, scale1.shape, scale2.shape)
    half = 2 ** (bits - 1)
    bm_, bd_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(D)), min(bk, _ceil(N))
    Mp, Dp, Np = _pad_to(M, bm_), _pad_to(D, bd_), _pad_to(N, bn_)

    gv = (jnp.zeros((B,), jnp.int32) if gv is None
          else jnp.asarray(gv, jnp.int32).reshape(B))
    codes = jnp.pad(codes, ((0, 0), (0, Mp - M), (0, Np - N)))
    v = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, Np - N), (0, Dp - D)))

    nk = Np // bn_
    grid = (B, Mp // bm_, Dp // bd_, nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bn_), lambda b, m, d, n, g: (b, m, n)),
            pl.BlockSpec((1, bn_, bd_),
                         lambda b, m, d, n, g: (b // rep, n, d)),  # shared kv
            pl.BlockSpec((1, 1), lambda b, m, d, n, g: (g[b], 0)),  # s_v[g_b]
            pl.BlockSpec((1, 1), lambda b, m, d, n, g: (g[b], 0)),  # scale1
            pl.BlockSpec((1, 1), lambda b, m, d, n, g: (g[b], 0)),  # scale2
        ],
        out_specs=pl.BlockSpec((1, bm_, bd_), lambda b, m, d, n, g: (b, m, d)),
        scratch_shapes=[pltpu.VMEM((bm_, bd_), jnp.int32),
                        pltpu.VMEM((bm_, bd_), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_pv_kernel, nk=nk, half=half),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Mp, Dp), out_dtype),
        interpret=interpret,
    )(gv, codes, v, s_v.astype(jnp.float32), scale1.astype(jnp.float32),
      scale2.astype(jnp.float32))
    return out[:, :M, :D]
