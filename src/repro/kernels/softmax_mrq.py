"""Fused softmax -> MRQ two-region quantization Pallas kernels.

The paper quantizes post-softmax attention probabilities with MRQ
(§III-C). Fusing the quantizer into the softmax epilogue means the
probability tile never round-trips to HBM in full precision. Two
variants:

``softmax_mrq``
    The fidelity variant: emits the quant-DEQUANTIZED fp tile (feeds a
    full-precision P·V, halves the probs traffic vs a separate qdq
    pass).

``softmax_mrq_codes``
    The deployment variant: emits the int8 CODES the ``int8_bmm_pv``
    kernel consumes directly, with the two MRQ regions packed into one
    signed byte — code c >= 0 is the region-1 (fine step s1) code,
    c < 0 stores the NEGATED region-2 (coarse step s2 = 1/2^{k-1})
    code, so region-2's full [0, 2^{k-1}] code range fits. The only
    overlap, c == 0, dequantizes to exactly 0 under either region, so
    the encoding is lossless. ``s1`` is TGQ-stacked (G, 1) and the
    timestep group is scalar-prefetched like the int8 matmul kernels —
    one compiled executable across all groups. Probs traffic drops
    4x: int8 write + int8 read instead of fp32 write + fp32 read.

Region select is branch-free (both-region compute + mask select), which
vectorizes on the 8x128 VPU lanes — the TPU adaptation of the paper's
per-element region branch.

Tiling: rows of the (R, C) score matrix are tiled (br rows per step);
each step holds the full C (key) extent in VMEM for an exact softmax
(rows up to C = 32k fit: 128 x 32k x 4B = 16MB/2 with br=64; default
br=256 targets C <= 4k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, s1_ref, o_ref, *, bits: int):
    x = s_ref[...].astype(jnp.float32)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    half = 2 ** (bits - 1)
    s1 = s1_ref[0, 0]
    s2 = 1.0 / half
    q1 = jnp.clip(jnp.round(p / s1), 0, half - 1) * s1
    q2 = jnp.clip(jnp.round(p / s2), 0, half) * s2
    o_ref[...] = jnp.where(p < half * s1, q1, q2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "br", "out_dtype",
                                             "interpret"))
def softmax_mrq(scores, s1, *, bits: int = 8, br: int = 256,
                out_dtype=jnp.float32, interpret=False):
    """Row-softmax over the LAST axis then MRQ quant-dequant.

    scores: (..., C); s1: scalar (already TGQ-selected for the current
    timestep group). Returns quantized probabilities, same shape.
    """
    shape = scores.shape
    C = shape[-1]
    R = 1
    for d in shape[:-1]:
        R *= d
    x = scores.reshape(R, C)
    br_ = min(br, max(8, R))
    Rp = -br_ * (-R // br_)
    x = jnp.pad(x, ((0, Rp - R), (0, 0)))
    s1 = jnp.asarray(s1, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(Rp // br_,),
        in_specs=[
            pl.BlockSpec((br_, C), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br_, C), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, C), out_dtype),
        interpret=interpret,
    )(x, s1)
    return out[:R].reshape(shape)


def _codes_kernel(g_ref, s_ref, s1_ref, o_ref, *, bits: int):
    """Softmax rows then emit region-signed int8 MRQ codes (no dequant)."""
    del g_ref                       # consumed by the s1 index map
    x = s_ref[...].astype(jnp.float32)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    half = 2 ** (bits - 1)
    s1 = s1_ref[0, 0]
    s2 = 1.0 / half
    q1 = jnp.clip(jnp.round(p / s1), 0, half - 1)
    q2 = jnp.clip(jnp.round(p / s2), 0, half)
    o_ref[...] = jnp.where(p < half * s1, q1, -q2).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "br", "interpret"))
def softmax_mrq_codes(scores, s1, g=None, *, bits: int = 8, br: int = 256,
                      interpret=False):
    """Row-softmax over the LAST axis then MRQ quantization to CODES.

    scores: (..., C); s1: (G, 1) f32 TGQ-stacked region-1 steps; g: the
    timestep group (python int or traced scalar — scalar-prefetched, so
    a traced g changes which s1 row streams in, never the executable).
    Returns int8 region-signed codes, same shape as ``scores``: c >= 0
    is a region-1 code (value c*s1), c < 0 a negated region-2 code
    (value -c*s2). ``int8_bmm_pv`` consumes these directly.
    """
    shape = scores.shape
    C = shape[-1]
    R = 1
    for d in shape[:-1]:
        R *= d
    x = scores.reshape(R, C)
    br_ = min(br, max(8, R))
    Rp = -br_ * (-R // br_)
    x = jnp.pad(x, ((0, Rp - R), (0, 0)))
    G = s1.shape[0]
    assert s1.shape == (G, 1), s1.shape
    if g is None:
        g = 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Rp // br_,),
        in_specs=[
            pl.BlockSpec((br_, C), lambda r, g: (r, 0)),
            pl.BlockSpec((1, 1), lambda r, g: (g[0], 0)),     # s1[g]
        ],
        out_specs=pl.BlockSpec((br_, C), lambda r, g: (r, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_codes_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, C), jnp.int8),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), x, s1.astype(jnp.float32))
    return out[:R].reshape(shape)


def _codes_vec_kernel(gv_ref, s_ref, s1_ref, o_ref, *, bits: int):
    """Vector-tgroup ``_codes_kernel``: each ROW quantizes with its own
    group's s1, gathered from the full (G, 1) stack via the exact one-hot
    product (deferred import dodges the int8_fused <-> softmax cycle risk
    at package init — there is none today, but keep the dep one-way)."""
    from repro.kernels.int8_fused import _gather_rows, _onehot_rows
    x = s_ref[...].astype(jnp.float32)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    half = 2 ** (bits - 1)
    G = s1_ref.shape[0]
    ohf = _onehot_rows(gv_ref, G).astype(jnp.float32)
    s1_row = _gather_rows(ohf, s1_ref, jnp.float32)       # (br, 1)
    s2 = 1.0 / half
    q1 = jnp.clip(jnp.round(p / s1_row), 0, half - 1)
    q2 = jnp.clip(jnp.round(p / s2), 0, half)
    o_ref[...] = jnp.where(p < half * s1_row, q1, -q2).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "br", "interpret"))
def softmax_mrq_codes_vec(scores, s1, gv=None, *, bits: int = 8,
                          br: int = 256, interpret=False):
    """``softmax_mrq_codes`` with a per-ROW group vector.

    scores: (..., C); gv: int32 with shape ``scores.shape[:-1]`` (one
    group per softmax row — batched callers pass the slot's group
    repeated over heads/queries). The full (G, 1) s1 stack streams and
    each row gathers its own step in VMEM; a constant gv is bit-identical
    to the scalar-prefetch path.
    """
    shape = scores.shape
    C = shape[-1]
    R = 1
    for d in shape[:-1]:
        R *= d
    x = scores.reshape(R, C)
    br_ = min(br, max(8, R))
    Rp = -br_ * (-R // br_)
    x = jnp.pad(x, ((0, Rp - R), (0, 0)))
    G = s1.shape[0]
    assert s1.shape == (G, 1), s1.shape
    gv = (jnp.zeros((R,), jnp.int32) if gv is None
          else jnp.asarray(gv, jnp.int32).reshape(R))
    gv = jnp.pad(gv, (0, Rp - R)).reshape(Rp, 1)

    out = pl.pallas_call(
        functools.partial(_codes_vec_kernel, bits=bits),
        grid=(Rp // br_,),
        in_specs=[
            pl.BlockSpec((br_, 1), lambda r: (r, 0)),         # gv rows
            pl.BlockSpec((br_, C), lambda r: (r, 0)),
            pl.BlockSpec((G, 1), lambda r: (0, 0)),           # s1 stack
        ],
        out_specs=pl.BlockSpec((br_, C), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, C), jnp.int8),
        interpret=interpret,
    )(gv, x, s1.astype(jnp.float32))
    return out[:R].reshape(shape)
