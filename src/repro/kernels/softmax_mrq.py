"""Fused softmax -> MRQ two-region quantization Pallas kernel.

The paper quantizes post-softmax attention probabilities with MRQ
(§III-C). Fusing the quantizer into the softmax epilogue means the
probability tile never round-trips to HBM in full precision — on a
memory-bound attention step this halves the probs traffic (bf16 -> int8
codes in deployment; here the fidelity variant emits the dequantized
tile that directly feeds the P.V matmul).

Region select is branch-free (both-region compute + mask select), which
vectorizes on the 8x128 VPU lanes — the TPU adaptation of the paper's
per-element region branch.

Tiling: rows of the (R, C) score matrix are tiled (br rows per step);
each step holds the full C (key) extent in VMEM for an exact softmax
(rows up to C = 32k fit: 128 x 32k x 4B = 16MB/2 with br=64; default
br=256 targets C <= 4k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, s1_ref, o_ref, *, bits: int):
    x = s_ref[...].astype(jnp.float32)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    half = 2 ** (bits - 1)
    s1 = s1_ref[0, 0]
    s2 = 1.0 / half
    q1 = jnp.clip(jnp.round(p / s1), 0, half - 1) * s1
    q2 = jnp.clip(jnp.round(p / s2), 0, half) * s2
    o_ref[...] = jnp.where(p < half * s1, q1, q2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "br", "out_dtype",
                                             "interpret"))
def softmax_mrq(scores, s1, *, bits: int = 8, br: int = 256,
                out_dtype=jnp.float32, interpret=False):
    """Row-softmax over the LAST axis then MRQ quant-dequant.

    scores: (..., C); s1: scalar (already TGQ-selected for the current
    timestep group). Returns quantized probabilities, same shape.
    """
    shape = scores.shape
    C = shape[-1]
    R = 1
    for d in shape[:-1]:
        R *= d
    x = scores.reshape(R, C)
    br_ = min(br, max(8, R))
    Rp = -br_ * (-R // br_)
    x = jnp.pad(x, ((0, Rp - R), (0, 0)))
    s1 = jnp.asarray(s1, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(Rp // br_,),
        in_specs=[
            pl.BlockSpec((br_, C), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br_, C), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, C), out_dtype),
        interpret=interpret,
    )(x, s1)
    return out[:R].reshape(shape)
