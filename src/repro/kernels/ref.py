"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` computes exactly what the corresponding kernel must produce;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizers import mrq_signed_qdq, mrq_softmax_qdq


def quantize_int8_ref(x, scale, zero):
    """Uniform affine int8 codes: q = clip(round(x/s)+z-128, -128, 127).

    Codes are stored SIGNED (two's complement, offset by 128 from the
    unsigned convention) so the MXU s8 path applies; the effective zero
    point becomes (z - 128)."""
    q = jnp.clip(jnp.round(x / scale) + zero - 128, -128, 127)
    return q.astype(jnp.int8)


def int8_matmul_ref(xq, wq, scale, corr, bias=None, out_dtype=jnp.float32):
    """y = (xq @ wq - corr) * scale (+ bias).

    xq: (M,K) int8; wq: (K,N) int8; scale: (N,) f32 combined s_x*s_w;
    corr: (N,) int32 zero-point correction z_x_eff * colsum(wq).
    """
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = (acc - corr[None, :]).astype(jnp.float32) * scale[None, :]
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


def softmax_mrq_ref(scores, s1, bits: int, out_dtype=jnp.float32):
    """Row softmax (last axis, f32 accumulation) then MRQ two-region
    quant-dequant (§III-C)."""
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return mrq_softmax_qdq(p, s1, bits).astype(out_dtype)


def act_mrq_ref(x, s_neg, s_pos, bits: int, kind: str = "gelu",
                out_dtype=jnp.float32):
    """GELU/SiLU (f32) then MRQ signed two-region quant-dequant."""
    xf = x.astype(jnp.float32)
    h = jax.nn.gelu(xf, approximate=True) if kind == "gelu" else jax.nn.silu(xf)
    return mrq_signed_qdq(h, s_neg, s_pos, bits).astype(out_dtype)
