"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` computes exactly what the corresponding kernel must produce;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizers import mrq_signed_qdq, mrq_softmax_qdq


def quantize_int8_ref(x, scale, zero, bits: int = 8):
    """Uniform affine codes: q = clip(round(x/s)+z-h, -h, h-1), h=2^{b-1}.

    Codes are stored SIGNED (two's complement, offset by half the code
    range from the unsigned convention) so the MXU s8 path applies; the
    effective zero point becomes (z - 2^{b-1}). Sub-byte widths keep the
    same convention inside int8 storage (6-bit: [-32, 31]; 4-bit:
    [-8, 7], nibble-packed downstream)."""
    half = 2 ** (bits - 1)
    q = jnp.clip(jnp.round(x / scale) + zero - half, -half, half - 1)
    return q.astype(jnp.int8)


def int8_matmul_ref(xq, wq, scale, corr, bias=None, out_dtype=jnp.float32):
    """y = (xq @ wq - corr) * scale (+ bias).

    xq: (M,K) int8; wq: (K,N) int8; scale: (N,) f32 combined s_x*s_w;
    corr: (N,) int32 zero-point correction z_x_eff * colsum(wq).
    """
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = (acc - corr[None, :]).astype(jnp.float32) * scale[None, :]
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


def int8_matmul_fq_ref(x, wq, sx, zx, scale, corr, bias=None, g=0,
                       bits: int = 8, out_dtype=jnp.float32):
    """Fused-quantize matmul oracle: quantize x with group-g params, then
    the int8 matmul + dequant epilogue.

    x: (M,K) float; wq: (K,N) int8; sx/zx: (G,1) f32; scale: (G,N) f32;
    corr: (G,N) i32; g: group index (int or traced scalar).
    """
    sx_g = jnp.take(sx, g, axis=0)[0]
    zx_g = jnp.take(zx, g, axis=0)[0]
    xq = quantize_int8_ref(x.astype(jnp.float32), sx_g, zx_g, bits)
    return int8_matmul_ref(xq, wq, jnp.take(scale, g, axis=0),
                           jnp.take(corr, g, axis=0), bias=bias,
                           out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# packed-int4 linears (per-K-group weight scales, f32 group accumulation)
# ---------------------------------------------------------------------------
def int4_matmul_fq_ref(x, wp, sx, zx, scale, corr, bias=None, g=0,
                       group_k: int = 256, out_dtype=jnp.float32):
    """Oracle for ``int4_matmul_fq``: unpack nibbles, quantize x at 4
    bits with the group-g affine params, then replay the kernel's
    GROUP-ORDERED f32 accumulation — each K group's s32 partial is
    corrected and dequantized with its own (nk, N) scale row before the
    next group is added, matching the kernel's per-K-step dequant.

    wp: (Kp/2, N) int8 packed; scale: (G, nk, N) f32; corr: (G, nk, N)
    i32 with nk = Kp / group_k.
    """
    from repro.kernels.int4_packed import unpack_int4
    M, K = x.shape
    Kp, N = 2 * wp.shape[0], wp.shape[1]
    nk = Kp // group_k
    sx_g = jnp.take(sx, g, axis=0)[0]
    zx_g = jnp.take(zx, g, axis=0)[0]
    xq = quantize_int8_ref(x.astype(jnp.float32), sx_g, zx_g, bits=4)
    xq = jnp.pad(xq, ((0, 0), (0, Kp - K))).astype(jnp.int32)
    w = unpack_int4(wp).astype(jnp.int32)
    scale_g = jnp.take(scale, g, axis=0)
    corr_g = jnp.take(corr, g, axis=0)
    acc = jnp.zeros((M, N), jnp.float32)
    for kg in range(nk):
        sl = slice(kg * group_k, (kg + 1) * group_k)
        partial = jax.lax.dot_general(
            xq[:, sl], w[sl], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + ((partial - corr_g[kg][None, :]).astype(jnp.float32)
                     * scale_g[kg][None, :])
    if bias is not None:
        acc = acc + bias[None, :].astype(jnp.float32)
    return acc.astype(out_dtype)


def int4_matmul_mrq_fq_ref(x, wp, s_neg, s_pos, scale_neg, scale_pos,
                           bias=None, g=0, group_k: int = 256,
                           out_dtype=jnp.float32):
    """Oracle for ``int4_matmul_mrq_fq``: 4-bit twin-region codes
    (disjoint support by sign), nibble-unpacked weights, and the kernel's
    group-ordered f32 accumulation with per-region per-K-group scales.
    """
    from repro.kernels.int4_packed import unpack_int4
    half = 8
    M, K = x.shape
    Kp, N = 2 * wp.shape[0], wp.shape[1]
    nk = Kp // group_k
    xf = x.astype(jnp.float32)
    sn = jnp.take(s_neg, g, axis=0)[0]
    sp = jnp.take(s_pos, g, axis=0)[0]
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn), -half, 0), 0
                   ).astype(jnp.int32)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp), 0, half - 1)
                   ).astype(jnp.int32)
    qn = jnp.pad(qn, ((0, 0), (0, Kp - K)))
    qp = jnp.pad(qp, ((0, 0), (0, Kp - K)))
    w = unpack_int4(wp).astype(jnp.int32)
    sn_g = jnp.take(scale_neg, g, axis=0)
    sp_g = jnp.take(scale_pos, g, axis=0)
    dims = (((1,), (0,)), ((), ()))
    acc = jnp.zeros((M, N), jnp.float32)
    for kg in range(nk):
        sl = slice(kg * group_k, (kg + 1) * group_k)
        pn = jax.lax.dot_general(qn[:, sl], w[sl], dims,
                                 preferred_element_type=jnp.int32)
        pp = jax.lax.dot_general(qp[:, sl], w[sl], dims,
                                 preferred_element_type=jnp.int32)
        acc = acc + (pn.astype(jnp.float32) * sn_g[kg][None, :]
                     + pp.astype(jnp.float32) * sp_g[kg][None, :])
    if bias is not None:
        acc = acc + bias[None, :].astype(jnp.float32)
    return acc.astype(out_dtype)


def int8_matmul_mrq_fq_ref(x, wq, s_neg, s_pos, scale_neg, scale_pos,
                           bias=None, g=0, bits: int = 8,
                           out_dtype=jnp.float32):
    """Single-pass MRQ matmul oracle: two-region codes (disjoint support,
    selected by sign), one logical W traversal, per-region dequant.

    x: (M,K) float; wq: (K,N) int8; s_neg/s_pos: (G,1) f32 region steps;
    scale_neg/scale_pos: (G,N) f32 combined region*weight scales.
    """
    half = 2 ** (bits - 1)
    xf = x.astype(jnp.float32)
    sn = jnp.take(s_neg, g, axis=0)[0]
    sp = jnp.take(s_pos, g, axis=0)[0]
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn), -half, 0), 0
                   ).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp), 0, half - 1)
                   ).astype(jnp.int8)
    dims = (((1,), (0,)), ((), ()))
    acc_n = jax.lax.dot_general(qn.astype(jnp.int32), wq.astype(jnp.int32),
                                dims, preferred_element_type=jnp.int32)
    acc_p = jax.lax.dot_general(qp.astype(jnp.int32), wq.astype(jnp.int32),
                                dims, preferred_element_type=jnp.int32)
    y = (acc_n.astype(jnp.float32) * jnp.take(scale_neg, g, axis=0)[None]
         + acc_p.astype(jnp.float32) * jnp.take(scale_pos, g, axis=0)[None])
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# prologue/epilogue fusion oracles (adaLN norm-modulate, channel-balance
# prescale, gate+residual) — see ``int8_fused``'s fusion contract
# ---------------------------------------------------------------------------
def fused_prologue_ref(x, nm=None, ps=None, bv=None, eps: float = 1e-6):
    """What the kernels' VMEM prologue computes before quantizing.

    ``nm = (shift, scale)`` per-batch (B, K) adaLN rows with ``bv`` the
    (M,) row->batch map: non-affine layernorm (mean, var, ``rsqrt(var +
    eps)``) then ``y * (1 + scale[bv]) + shift[bv]``. ``ps`` is the (K,)
    channel-balance vector, applied as a DIVIDE after the modulate (the
    fake-quant ``_q_in`` order). x: (M, K) rows."""
    x = x.astype(jnp.float32)
    if nm is not None:
        sh, sc = nm
        bv = jnp.asarray(bv, jnp.int32)
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
        x = (x * (1.0 + jnp.take(jnp.asarray(sc, jnp.float32), bv, axis=0))
             + jnp.take(jnp.asarray(sh, jnp.float32), bv, axis=0))
    if ps is not None:
        x = x / jnp.asarray(ps, jnp.float32)[None, :]
    return x


def fused_epilogue_ref(y, gr=None, bv=None):
    """What the kernels' dequant epilogue computes after the bias add:
    ``gr = (gate, residual)`` with gate (B, N) rows, residual (M, N), and
    ``bv`` the (M,) row->batch map — ``residual + gate[bv] * y``."""
    if gr is not None:
        gate, res = gr
        bv = jnp.asarray(bv, jnp.int32)
        y = (jnp.asarray(res, jnp.float32)
             + jnp.take(jnp.asarray(gate, jnp.float32), bv, axis=0) * y)
    return y


def int8_matmul_fq_fused_ref(x, wq, sx, zx, scale, corr, bias=None, g=0,
                             ps=None, nm=None, gr=None, bv=None,
                             bits: int = 8, out_dtype=jnp.float32):
    """``int8_matmul_fq`` with fusions: prologue -> fq oracle -> epilogue."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int8_matmul_fq_ref(xf, wq, sx, zx, scale, corr, bias=bias, g=g,
                           bits=bits)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int8_matmul_mrq_fq_fused_ref(x, wq, s_neg, s_pos, scale_neg, scale_pos,
                                 bias=None, g=0, ps=None, nm=None, gr=None,
                                 bv=None, bits: int = 8,
                                 out_dtype=jnp.float32):
    """``int8_matmul_mrq_fq`` with fusions (prologue before the sign
    split — the balance vector is positive, so regions are unchanged)."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int8_matmul_mrq_fq_ref(xf, wq, s_neg, s_pos, scale_neg, scale_pos,
                               bias=bias, g=g, bits=bits)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int4_matmul_fq_fused_ref(x, wp, sx, zx, scale, corr, bias=None, g=0,
                             ps=None, nm=None, gr=None, bv=None,
                             group_k: int = 256, out_dtype=jnp.float32):
    """``int4_matmul_fq`` with fusions."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int4_matmul_fq_ref(xf, wp, sx, zx, scale, corr, bias=bias, g=g,
                           group_k=group_k)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int4_matmul_mrq_fq_fused_ref(x, wp, s_neg, s_pos, scale_neg, scale_pos,
                                 bias=None, g=0, ps=None, nm=None, gr=None,
                                 bv=None, group_k: int = 256,
                                 out_dtype=jnp.float32):
    """``int4_matmul_mrq_fq`` with fusions."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int4_matmul_mrq_fq_ref(xf, wp, s_neg, s_pos, scale_neg, scale_pos,
                               bias=bias, g=g, group_k=group_k)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int8_matmul_fq_vec_fused_ref(x, wq, sx, zx, scale, corr, bias=None,
                                 gv=None, ps=None, nm=None, gr=None, bv=None,
                                 bits: int = 8, out_dtype=jnp.float32):
    """Vector-tgroup sibling of ``int8_matmul_fq_fused_ref``."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int8_matmul_fq_vec_ref(xf, wq, sx, zx, scale, corr, bias=bias,
                               gv=gv, bits=bits)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int8_matmul_mrq_fq_vec_fused_ref(x, wq, s_neg, s_pos, scale_neg,
                                     scale_pos, bias=None, gv=None, ps=None,
                                     nm=None, gr=None, bv=None,
                                     bits: int = 8, out_dtype=jnp.float32):
    """Vector-tgroup sibling of ``int8_matmul_mrq_fq_fused_ref``."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int8_matmul_mrq_fq_vec_ref(xf, wq, s_neg, s_pos, scale_neg,
                                   scale_pos, bias=bias, gv=gv, bits=bits)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int4_matmul_fq_vec_fused_ref(x, wp, sx, zx, scale, corr, bias=None,
                                 gv=None, ps=None, nm=None, gr=None, bv=None,
                                 group_k: int = 256, out_dtype=jnp.float32):
    """Vector-tgroup sibling of ``int4_matmul_fq_fused_ref``."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int4_matmul_fq_vec_ref(xf, wp, sx, zx, scale, corr, bias=bias,
                               gv=gv, group_k=group_k)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def int4_matmul_mrq_fq_vec_fused_ref(x, wp, s_neg, s_pos, scale_neg,
                                     scale_pos, bias=None, gv=None, ps=None,
                                     nm=None, gr=None, bv=None,
                                     group_k: int = 256,
                                     out_dtype=jnp.float32):
    """Vector-tgroup sibling of ``int4_matmul_mrq_fq_fused_ref``."""
    xf = fused_prologue_ref(x, nm=nm, ps=ps, bv=bv)
    y = int4_matmul_mrq_fq_vec_ref(xf, wp, s_neg, s_pos, scale_neg,
                                   scale_pos, bias=bias, gv=gv,
                                   group_k=group_k)
    return fused_epilogue_ref(y, gr=gr, bv=bv).astype(out_dtype)


def softmax_mrq_ref(scores, s1, bits: int, out_dtype=jnp.float32):
    """Row softmax (last axis, f32 accumulation) then MRQ two-region
    quant-dequant (§III-C)."""
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return mrq_softmax_qdq(p, s1, bits).astype(out_dtype)


# ---------------------------------------------------------------------------
# int8 attention (batched kernels)
# ---------------------------------------------------------------------------
def sym_quantize_int8_ref(x, scale, bits: int = 8):
    """Symmetric s8 codes over the weight code range [-(h-1), h-1]."""
    hi = 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -hi, hi
                    ).astype(jnp.int8)


def int8_bmm_qk_ref(q, k, s_q, s_k, scale, g=0, bits: int = 8,
                    out_dtype=jnp.float32):
    """Batched symmetric QK^T oracle: quantize both activation operands
    with group-g per-tensor steps, s32 batched matmul, scalar dequant.

    q: (B,M,D), k: (B,N,D) float; s_q/s_k/scale: (G,1) f32 (scale is the
    combined s_q[g]*s_k[g]*alpha the kernel applies in its epilogue).
    """
    q8 = sym_quantize_int8_ref(q, jnp.take(s_q, g, axis=0)[0], bits)
    k8 = sym_quantize_int8_ref(k, jnp.take(s_k, g, axis=0)[0], bits)
    acc = jax.lax.dot_general(
        q8.astype(jnp.int32), k8.astype(jnp.int32),
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32)
            * jnp.take(scale, g, axis=0)[0]).astype(out_dtype)


def softmax_mrq_codes_ref(scores, s1, g=0, bits: int = 8):
    """Row softmax then region-signed int8 MRQ codes: c >= 0 is a
    region-1 code (step s1[g]), c < 0 the NEGATED region-2 code (step
    s2 = 1/2^{k-1}; negation fits region-2's [0, 2^{k-1}] range in a
    signed byte). c == 0 is shared but dequantizes to 0 either way."""
    half = 2 ** (bits - 1)
    s1_g = jnp.take(jnp.asarray(s1, jnp.float32), g, axis=0)[0]
    s2 = 1.0 / half
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    q1 = jnp.clip(jnp.round(p / s1_g), 0, half - 1)
    q2 = jnp.clip(jnp.round(p / s2), 0, half)
    return jnp.where(p < half * s1_g, q1, -q2).astype(jnp.int8)


def mrq_codes_decode_ref(codes, s1, g=0, bits: int = 8):
    """Dequantize region-signed prob codes back to fp probabilities.
    Equals ``mrq_softmax_qdq`` applied to the same softmax rows."""
    half = 2 ** (bits - 1)
    s1_g = jnp.take(jnp.asarray(s1, jnp.float32), g, axis=0)[0]
    c = codes.astype(jnp.float32)
    return jnp.where(c >= 0, c * s1_g, -c * (1.0 / half))


def int8_bmm_pv_ref(codes, v, s_v, scale1, scale2, g=0, bits: int = 8,
                    out_dtype=jnp.float32):
    """Batched dual-region P·V oracle consuming region-signed prob codes.

    codes: (B,M,N) int8; v: (B,N,D) float; s_v/scale1/scale2: (G,1) f32
    (scale1 = s1[g]*s_v[g], scale2 = s2*s_v[g]).
    """
    c = codes.astype(jnp.int32)
    c1 = jnp.maximum(c, 0)
    c2 = jnp.maximum(-c, 0)
    v8 = sym_quantize_int8_ref(v, jnp.take(s_v, g, axis=0)[0], bits
                               ).astype(jnp.int32)
    dims = (((2,), (1,)), ((0,), (0,)))
    acc1 = jax.lax.dot_general(c1, v8, dims,
                               preferred_element_type=jnp.int32)
    acc2 = jax.lax.dot_general(c2, v8, dims,
                               preferred_element_type=jnp.int32)
    y = (acc1.astype(jnp.float32) * jnp.take(scale1, g, axis=0)[0]
         + acc2.astype(jnp.float32) * jnp.take(scale2, g, axis=0)[0])
    return y.astype(out_dtype)


def int8_attention_ref(q, k, v, qk_pack, pv_pack, mask=None, scale=1.0,
                       g=0, bits: int = 8, out_dtype=jnp.float32):
    """Full int8 attention oracle over FLATTENED (BHG, S, hd) operands:
    symmetric QK^T -> mask -> softmax-to-codes -> dual-region P·V.
    Exactly the composition ``kernels.ops.int8_attention`` runs."""
    from repro.nn.ctx import NEG_INF
    scores = int8_bmm_qk_ref(q, k, qk_pack["s_q"], qk_pack["s_k"],
                             qk_pack["scale"] * scale, g=g, bits=bits)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    codes = softmax_mrq_codes_ref(scores, pv_pack["s1"], g=g, bits=bits)
    return int8_bmm_pv_ref(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                           pv_pack["scale2"], g=g, bits=bits,
                           out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# flash-style fused attention (single kernel, no (S,S) HBM round-trip)
# ---------------------------------------------------------------------------
def flash_attn_mrq_ref(q, k, v, qk_pack, pv_pack, mask=None, scale=1.0,
                       g_qk=0, g_pv=0, bits: int = 8, bn: int = 128,
                       out_dtype=jnp.float32):
    """Tile-faithful oracle for ``flash_attn_mrq`` over FLATTENED
    (B, S, hd) operands (kv materialized per q batch — the kernel's
    ``b // rep`` GQA gather is equivalence-tested separately).

    Replays the kernel's exact per-kv-tile recurrence — int8 QK^T,
    NEG_INF lane masking BEFORE the online max, running max/denominator,
    MRQ two-region codes against the running normalization, dual-region
    integer P·V with the fp rescale — so kernel vs oracle comparisons are
    (jitted) bit-exact, the same contract as the composed kernels.
    """
    from repro.nn.ctx import NEG_INF
    from repro.kernels.int8_matmul import _ceil
    B, M, D = q.shape
    N = k.shape[1]
    half = 2 ** (bits - 1)
    bn_ = min(bn, _ceil(N))                    # the kernel's tile rounding
    Np = -bn_ * (-N // bn_)

    sq_g = jnp.take(qk_pack["s_q"], g_qk, axis=0)[0]
    sk_g = jnp.take(qk_pack["s_k"], g_qk, axis=0)[0]
    qs_g = jnp.take(qk_pack["scale"], g_qk, axis=0)[0] * scale
    s1_g = jnp.take(pv_pack["s1"], g_pv, axis=0)[0]
    sv_g = jnp.take(pv_pack["s_v"], g_pv, axis=0)[0]
    sc1_g = jnp.take(pv_pack["scale1"], g_pv, axis=0)[0]
    sc2_g = jnp.take(pv_pack["scale2"], g_pv, axis=0)[0]
    s2 = 1.0 / half

    q8 = sym_quantize_int8_ref(q, sq_g, bits).astype(jnp.int32)
    k8 = sym_quantize_int8_ref(
        jnp.pad(k.astype(jnp.float32), ((0, 0), (0, Np - N), (0, 0))),
        sk_g, bits).astype(jnp.int32)
    v8 = sym_quantize_int8_ref(
        jnp.pad(v.astype(jnp.float32), ((0, 0), (0, Np - N), (0, 0))),
        sv_g, bits).astype(jnp.int32)
    if mask is not None:
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, Np - N)))

    m_run = jnp.full((B, M, 1), -1e30, jnp.float32)
    l_run = jnp.zeros((B, M, 1), jnp.float32)
    acc1 = jnp.zeros((B, M, D), jnp.float32)
    acc2 = jnp.zeros((B, M, D), jnp.float32)
    col = jnp.arange(Np)
    for n0 in range(0, Np, bn_):
        kt = k8[:, n0:n0 + bn_]
        vt = v8[:, n0:n0 + bn_]
        s = jax.lax.dot_general(
            q8, kt, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.int32).astype(jnp.float32) * qs_g
        s = jnp.where(col[n0:n0 + bn_][None, None, :] < N, s, NEG_INF)
        if mask is not None:
            s = jnp.where(mask[:, :, n0:n0 + bn_], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        e = jnp.exp(s - m_new)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(e, axis=-1, keepdims=True)
        p = e / l_new
        region1 = p < half * s1_g
        c1 = jnp.where(region1, jnp.clip(jnp.round(p / s1_g), 0, half - 1),
                       0.0).astype(jnp.int32)
        c2 = jnp.where(region1, 0.0, jnp.clip(jnp.round(p / s2), 0, half)
                       ).astype(jnp.int32)
        dims = (((2,), (1,)), ((0,), (0,)))
        d1 = jax.lax.dot_general(c1, vt, dims,
                                 preferred_element_type=jnp.int32)
        d2 = jax.lax.dot_general(c2, vt, dims,
                                 preferred_element_type=jnp.int32)
        rho = corr * l_run / l_new
        acc1 = acc1 * rho + d1.astype(jnp.float32)
        acc2 = acc2 * rho + d2.astype(jnp.float32)
        m_run, l_run = m_new, l_new
    return (acc1 * sc1_g + acc2 * sc2_g).astype(out_dtype)


def flash_vs_composed_atol(pv_pack, g, n_kv: int, bits: int = 8) -> float:
    """The documented flash ≡ composed tolerance contract (worst case).

    Both paths dequantize each probability to within half a step of the
    true softmax value; the flash path's codes round against the RUNNING
    normalization, but the running estimate times the subsequent rescale
    factors equals the final normalized probability exactly in real
    arithmetic, and every rescale factor is <= 1 — so the per-element
    dequantized-probability divergence between the two paths is bounded
    by one coarse step ``s2 = 1/2^{k-1}`` (fine-region elements are
    tighter). Each output element sums ``n_kv`` such probabilities
    against dequantized values of magnitude <= (2^{k-1}-1)·s_v[g]:

        |flash - composed| <= n_kv · s2 · (2^{k-1}-1) · s_v[g]

    This is deliberately loose (worst case, every code off by a full
    region-2 step in the same direction); the sweeps in
    ``tests/test_flash_attn.py`` additionally assert the observed error
    sits far inside it.
    """
    import numpy as np
    half = 2 ** (bits - 1)
    s_v = float(np.asarray(jnp.take(pv_pack["s_v"], g, axis=0))[0])
    return n_kv * (1.0 / half) * (half - 1) * s_v


def act_mrq_ref(x, s_neg, s_pos, bits: int, kind: str = "gelu",
                out_dtype=jnp.float32):
    """GELU/SiLU (f32) then MRQ signed two-region quant-dequant."""
    xf = x.astype(jnp.float32)
    h = jax.nn.gelu(xf, approximate=True) if kind == "gelu" else jax.nn.silu(xf)
    return mrq_signed_qdq(h, s_neg, s_pos, bits).astype(out_dtype)


# ---------------------------------------------------------------------------
# vector-tgroup oracles: per-row / per-batch-row group indices
# ---------------------------------------------------------------------------
def int8_matmul_fq_vec_ref(x, wq, sx, zx, scale, corr, bias=None, gv=None,
                           bits: int = 8, out_dtype=jnp.float32):
    """Per-row oracle for ``int8_matmul_fq_vec``: row i quantizes with
    sx[gv[i]]/zx[gv[i]] and dequantizes with scale[gv[i]]/corr[gv[i]]."""
    M = x.shape[0]
    gv = jnp.zeros((M,), jnp.int32) if gv is None else jnp.asarray(gv)
    sx_r = jnp.take(sx, gv, axis=0)                       # (M, 1)
    zx_r = jnp.take(zx, gv, axis=0)                       # (M, 1)
    xq = quantize_int8_ref(x.astype(jnp.float32), sx_r, zx_r, bits)
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = ((acc - jnp.take(corr, gv, axis=0)).astype(jnp.float32)
         * jnp.take(scale, gv, axis=0))
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


def int8_matmul_mrq_fq_vec_ref(x, wq, s_neg, s_pos, scale_neg, scale_pos,
                               bias=None, gv=None, bits: int = 8,
                               out_dtype=jnp.float32):
    """Per-row oracle for ``int8_matmul_mrq_fq_vec``."""
    half = 2 ** (bits - 1)
    M = x.shape[0]
    gv = jnp.zeros((M,), jnp.int32) if gv is None else jnp.asarray(gv)
    xf = x.astype(jnp.float32)
    sn_r = jnp.take(s_neg, gv, axis=0)                    # (M, 1)
    sp_r = jnp.take(s_pos, gv, axis=0)                    # (M, 1)
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn_r), -half, 0), 0
                   ).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp_r), 0, half - 1)
                   ).astype(jnp.int8)
    dims = (((1,), (0,)), ((), ()))
    acc_n = jax.lax.dot_general(qn.astype(jnp.int32), wq.astype(jnp.int32),
                                dims, preferred_element_type=jnp.int32)
    acc_p = jax.lax.dot_general(qp.astype(jnp.int32), wq.astype(jnp.int32),
                                dims, preferred_element_type=jnp.int32)
    y = (acc_n.astype(jnp.float32) * jnp.take(scale_neg, gv, axis=0)
         + acc_p.astype(jnp.float32) * jnp.take(scale_pos, gv, axis=0))
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


def int4_matmul_fq_vec_ref(x, wp, sx, zx, scale, corr, bias=None, gv=None,
                           group_k: int = 256, out_dtype=jnp.float32):
    """Per-row oracle for ``int4_matmul_fq_vec`` — the kernel's
    group-ordered f32 accumulation with per-row scale/corr rows."""
    from repro.kernels.int4_packed import unpack_int4
    M, K = x.shape
    Kp, N = 2 * wp.shape[0], wp.shape[1]
    nk = Kp // group_k
    gv = jnp.zeros((M,), jnp.int32) if gv is None else jnp.asarray(gv)
    sx_r = jnp.take(sx, gv, axis=0)                       # (M, 1)
    zx_r = jnp.take(zx, gv, axis=0)
    xq = quantize_int8_ref(x.astype(jnp.float32), sx_r, zx_r, bits=4)
    xq = jnp.pad(xq, ((0, 0), (0, Kp - K))).astype(jnp.int32)
    w = unpack_int4(wp).astype(jnp.int32)
    scale_r = jnp.take(scale, gv, axis=0)                 # (M, nk, N)
    corr_r = jnp.take(corr, gv, axis=0)
    acc = jnp.zeros((M, N), jnp.float32)
    for kg in range(nk):
        sl = slice(kg * group_k, (kg + 1) * group_k)
        partial = jax.lax.dot_general(
            xq[:, sl], w[sl], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + ((partial - corr_r[:, kg]).astype(jnp.float32)
                     * scale_r[:, kg])
    if bias is not None:
        acc = acc + bias[None, :].astype(jnp.float32)
    return acc.astype(out_dtype)


def int4_matmul_mrq_fq_vec_ref(x, wp, s_neg, s_pos, scale_neg, scale_pos,
                               bias=None, gv=None, group_k: int = 256,
                               out_dtype=jnp.float32):
    """Per-row oracle for ``int4_matmul_mrq_fq_vec``."""
    from repro.kernels.int4_packed import unpack_int4
    half = 8
    M, K = x.shape
    Kp, N = 2 * wp.shape[0], wp.shape[1]
    nk = Kp // group_k
    gv = jnp.zeros((M,), jnp.int32) if gv is None else jnp.asarray(gv)
    xf = x.astype(jnp.float32)
    sn_r = jnp.take(s_neg, gv, axis=0)                    # (M, 1)
    sp_r = jnp.take(s_pos, gv, axis=0)
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn_r), -half, 0), 0
                   ).astype(jnp.int32)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp_r), 0, half - 1)
                   ).astype(jnp.int32)
    qn = jnp.pad(qn, ((0, 0), (0, Kp - K)))
    qp = jnp.pad(qp, ((0, 0), (0, Kp - K)))
    w = unpack_int4(wp).astype(jnp.int32)
    sn_g = jnp.take(scale_neg, gv, axis=0)                # (M, nk, N)
    sp_g = jnp.take(scale_pos, gv, axis=0)
    dims = (((1,), (0,)), ((), ()))
    acc = jnp.zeros((M, N), jnp.float32)
    for kg in range(nk):
        sl = slice(kg * group_k, (kg + 1) * group_k)
        pn = jax.lax.dot_general(qn[:, sl], w[sl], dims,
                                 preferred_element_type=jnp.int32)
        pp = jax.lax.dot_general(qp[:, sl], w[sl], dims,
                                 preferred_element_type=jnp.int32)
        acc = acc + (pn.astype(jnp.float32) * sn_g[:, kg]
                     + pp.astype(jnp.float32) * sp_g[:, kg])
    if bias is not None:
        acc = acc + bias[None, :].astype(jnp.float32)
    return acc.astype(out_dtype)


def int8_bmm_qk_vec_ref(q, k, s_q, s_k, scale, gv=None, bits: int = 8,
                        out_dtype=jnp.float32):
    """Per-batch-row oracle for ``int8_bmm_qk_vec`` (q and k batches
    equal here — GQA sharing is equivalence-tested at the kernel level)."""
    B = q.shape[0]
    gv = jnp.zeros((B,), jnp.int32) if gv is None else jnp.asarray(gv)
    sq_b = jnp.take(s_q, gv, axis=0)[:, :, None]          # (B, 1, 1)
    sk_b = jnp.take(s_k, gv, axis=0)[:, :, None]
    q8 = sym_quantize_int8_ref(q, sq_b, bits)
    k8 = sym_quantize_int8_ref(k, sk_b, bits)
    acc = jax.lax.dot_general(
        q8.astype(jnp.int32), k8.astype(jnp.int32),
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32)
            * jnp.take(scale, gv, axis=0)[:, :, None]).astype(out_dtype)


def softmax_mrq_codes_vec_ref(scores, s1, gv=None, bits: int = 8):
    """Per-row oracle for ``softmax_mrq_codes_vec``: gv has shape
    ``scores.shape[:-1]`` (one group per softmax row)."""
    half = 2 ** (bits - 1)
    if gv is None:
        gv = jnp.zeros(scores.shape[:-1], jnp.int32)
    s1_r = jnp.take(jnp.asarray(s1, jnp.float32), jnp.asarray(gv), axis=0)
    s2 = 1.0 / half
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    q1 = jnp.clip(jnp.round(p / s1_r), 0, half - 1)
    q2 = jnp.clip(jnp.round(p / s2), 0, half)
    return jnp.where(p < half * s1_r, q1, -q2).astype(jnp.int8)


def int8_bmm_pv_vec_ref(codes, v, s_v, scale1, scale2, gv=None,
                        bits: int = 8, out_dtype=jnp.float32):
    """Per-batch-row oracle for ``int8_bmm_pv_vec``."""
    B = codes.shape[0]
    gv = jnp.zeros((B,), jnp.int32) if gv is None else jnp.asarray(gv)
    c = codes.astype(jnp.int32)
    c1 = jnp.maximum(c, 0)
    c2 = jnp.maximum(-c, 0)
    sv_b = jnp.take(s_v, gv, axis=0)[:, :, None]          # (B, 1, 1)
    v8 = sym_quantize_int8_ref(v, sv_b, bits).astype(jnp.int32)
    dims = (((2,), (1,)), ((0,), (0,)))
    acc1 = jax.lax.dot_general(c1, v8, dims,
                               preferred_element_type=jnp.int32)
    acc2 = jax.lax.dot_general(c2, v8, dims,
                               preferred_element_type=jnp.int32)
    y = (acc1.astype(jnp.float32) * jnp.take(scale1, gv, axis=0)[:, :, None]
         + acc2.astype(jnp.float32) * jnp.take(scale2, gv, axis=0)[:, :, None])
    return y.astype(out_dtype)


def int8_attention_vec_ref(q, k, v, qk_pack, pv_pack, mask=None, scale=1.0,
                           gv=None, bits: int = 8, out_dtype=jnp.float32):
    """Composed per-batch-row int8 attention oracle over FLATTENED
    (BHG, S, hd) operands — the vector sibling of ``int8_attention_ref``;
    exactly the composition ``kernels.ops.int8_attention`` runs when the
    tgroup is a per-slot vector."""
    from repro.nn.ctx import NEG_INF
    B, M, _ = q.shape
    gv = jnp.zeros((B,), jnp.int32) if gv is None else jnp.asarray(gv)
    scores = int8_bmm_qk_vec_ref(q, k, qk_pack["s_q"], qk_pack["s_k"],
                                 qk_pack["scale"] * scale, gv=gv, bits=bits)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    rows_gv = jnp.broadcast_to(gv[:, None], (B, M))
    codes = softmax_mrq_codes_vec_ref(scores, pv_pack["s1"], gv=rows_gv,
                                      bits=bits)
    return int8_bmm_pv_vec_ref(codes, v, pv_pack["s_v"], pv_pack["scale1"],
                               pv_pack["scale2"], gv=gv, bits=bits,
                               out_dtype=out_dtype)


def flash_attn_mrq_vec_ref(q, k, v, qk_pack, pv_pack, mask=None, scale=1.0,
                           g_qk=None, g_pv=None, bits: int = 8,
                           bn: int = 128, out_dtype=jnp.float32):
    """Tile-faithful per-batch-row oracle for ``flash_attn_mrq_vec``:
    the recurrence of ``flash_attn_mrq_ref`` with every group-gathered
    scalar widened to a (B, 1, 1) per-batch-row column."""
    from repro.nn.ctx import NEG_INF
    from repro.kernels.int8_matmul import _ceil
    B, M, D = q.shape
    N = k.shape[1]
    half = 2 ** (bits - 1)
    bn_ = min(bn, _ceil(N))
    Np = -bn_ * (-N // bn_)
    g_qk = jnp.zeros((B,), jnp.int32) if g_qk is None else jnp.asarray(g_qk)
    g_pv = jnp.zeros((B,), jnp.int32) if g_pv is None else jnp.asarray(g_pv)

    sq_g = jnp.take(qk_pack["s_q"], g_qk, axis=0)[:, :, None]      # (B,1,1)
    sk_g = jnp.take(qk_pack["s_k"], g_qk, axis=0)[:, :, None]
    qs_g = jnp.take(qk_pack["scale"], g_qk, axis=0)[:, :, None] * scale
    s1_g = jnp.take(pv_pack["s1"], g_pv, axis=0)[:, :, None]
    sv_g = jnp.take(pv_pack["s_v"], g_pv, axis=0)[:, :, None]
    sc1_g = jnp.take(pv_pack["scale1"], g_pv, axis=0)[:, :, None]
    sc2_g = jnp.take(pv_pack["scale2"], g_pv, axis=0)[:, :, None]
    s2 = 1.0 / half

    q8 = sym_quantize_int8_ref(q, sq_g, bits).astype(jnp.int32)
    k8 = sym_quantize_int8_ref(
        jnp.pad(k.astype(jnp.float32), ((0, 0), (0, Np - N), (0, 0))),
        sk_g, bits).astype(jnp.int32)
    v8 = sym_quantize_int8_ref(
        jnp.pad(v.astype(jnp.float32), ((0, 0), (0, Np - N), (0, 0))),
        sv_g, bits).astype(jnp.int32)
    if mask is not None:
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, Np - N)))

    m_run = jnp.full((B, M, 1), -1e30, jnp.float32)
    l_run = jnp.zeros((B, M, 1), jnp.float32)
    acc1 = jnp.zeros((B, M, D), jnp.float32)
    acc2 = jnp.zeros((B, M, D), jnp.float32)
    col = jnp.arange(Np)
    for n0 in range(0, Np, bn_):
        kt = k8[:, n0:n0 + bn_]
        vt = v8[:, n0:n0 + bn_]
        s = jax.lax.dot_general(
            q8, kt, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.int32).astype(jnp.float32) * qs_g
        s = jnp.where(col[n0:n0 + bn_][None, None, :] < N, s, NEG_INF)
        if mask is not None:
            s = jnp.where(mask[:, :, n0:n0 + bn_], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        e = jnp.exp(s - m_new)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(e, axis=-1, keepdims=True)
        p = e / l_new
        region1 = p < half * s1_g
        c1 = jnp.where(region1, jnp.clip(jnp.round(p / s1_g), 0, half - 1),
                       0.0).astype(jnp.int32)
        c2 = jnp.where(region1, 0.0, jnp.clip(jnp.round(p / s2), 0, half)
                       ).astype(jnp.int32)
        dims = (((2,), (1,)), ((0,), (0,)))
        d1 = jax.lax.dot_general(c1, vt, dims,
                                 preferred_element_type=jnp.int32)
        d2 = jax.lax.dot_general(c2, vt, dims,
                                 preferred_element_type=jnp.int32)
        rho = corr * l_run / l_new
        acc1 = acc1 * rho + d1.astype(jnp.float32)
        acc2 = acc2 * rho + d2.astype(jnp.float32)
        m_run, l_run = m_new, l_new
    return (acc1 * sc1_g + acc2 * sc2_g).astype(out_dtype)
