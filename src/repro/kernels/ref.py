"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` computes exactly what the corresponding kernel must produce;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizers import mrq_signed_qdq, mrq_softmax_qdq


def quantize_int8_ref(x, scale, zero):
    """Uniform affine int8 codes: q = clip(round(x/s)+z-128, -128, 127).

    Codes are stored SIGNED (two's complement, offset by 128 from the
    unsigned convention) so the MXU s8 path applies; the effective zero
    point becomes (z - 128)."""
    q = jnp.clip(jnp.round(x / scale) + zero - 128, -128, 127)
    return q.astype(jnp.int8)


def int8_matmul_ref(xq, wq, scale, corr, bias=None, out_dtype=jnp.float32):
    """y = (xq @ wq - corr) * scale (+ bias).

    xq: (M,K) int8; wq: (K,N) int8; scale: (N,) f32 combined s_x*s_w;
    corr: (N,) int32 zero-point correction z_x_eff * colsum(wq).
    """
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = (acc - corr[None, :]).astype(jnp.float32) * scale[None, :]
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


def int8_matmul_fq_ref(x, wq, sx, zx, scale, corr, bias=None, g=0,
                       out_dtype=jnp.float32):
    """Fused-quantize matmul oracle: quantize x with group-g params, then
    the int8 matmul + dequant epilogue.

    x: (M,K) float; wq: (K,N) int8; sx/zx: (G,1) f32; scale: (G,N) f32;
    corr: (G,N) i32; g: group index (int or traced scalar).
    """
    sx_g = jnp.take(sx, g, axis=0)[0]
    zx_g = jnp.take(zx, g, axis=0)[0]
    xq = quantize_int8_ref(x.astype(jnp.float32), sx_g, zx_g)
    return int8_matmul_ref(xq, wq, jnp.take(scale, g, axis=0),
                           jnp.take(corr, g, axis=0), bias=bias,
                           out_dtype=out_dtype)


def int8_matmul_mrq_fq_ref(x, wq, s_neg, s_pos, scale_neg, scale_pos,
                           bias=None, g=0, bits: int = 8,
                           out_dtype=jnp.float32):
    """Single-pass MRQ matmul oracle: two-region codes (disjoint support,
    selected by sign), one logical W traversal, per-region dequant.

    x: (M,K) float; wq: (K,N) int8; s_neg/s_pos: (G,1) f32 region steps;
    scale_neg/scale_pos: (G,N) f32 combined region*weight scales.
    """
    half = 2 ** (bits - 1)
    xf = x.astype(jnp.float32)
    sn = jnp.take(s_neg, g, axis=0)[0]
    sp = jnp.take(s_pos, g, axis=0)[0]
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn), -half, 0), 0
                   ).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp), 0, half - 1)
                   ).astype(jnp.int8)
    dims = (((1,), (0,)), ((), ()))
    acc_n = jax.lax.dot_general(qn.astype(jnp.int32), wq.astype(jnp.int32),
                                dims, preferred_element_type=jnp.int32)
    acc_p = jax.lax.dot_general(qp.astype(jnp.int32), wq.astype(jnp.int32),
                                dims, preferred_element_type=jnp.int32)
    y = (acc_n.astype(jnp.float32) * jnp.take(scale_neg, g, axis=0)[None]
         + acc_p.astype(jnp.float32) * jnp.take(scale_pos, g, axis=0)[None])
    if bias is not None:
        y = y + bias[None, :].astype(jnp.float32)
    return y.astype(out_dtype)


def softmax_mrq_ref(scores, s1, bits: int, out_dtype=jnp.float32):
    """Row softmax (last axis, f32 accumulation) then MRQ two-region
    quant-dequant (§III-C)."""
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return mrq_softmax_qdq(p, s1, bits).astype(out_dtype)


def act_mrq_ref(x, s_neg, s_pos, bits: int, kind: str = "gelu",
                out_dtype=jnp.float32):
    """GELU/SiLU (f32) then MRQ signed two-region quant-dequant."""
    xf = x.astype(jnp.float32)
    h = jax.nn.gelu(xf, approximate=True) if kind == "gelu" else jax.nn.silu(xf)
    return mrq_signed_qdq(h, s_neg, s_pos, bits).astype(out_dtype)
