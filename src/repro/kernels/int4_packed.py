"""Packed-int4 fused serving kernels (the deployed W4A4 hot path).

Weights are stored TWO signed 4-bit codes per byte — byte ``i`` of a
column holds row ``2i`` in its low nibble and row ``2i + 1`` in its high
nibble — so the weight operand streams from HBM at half the int8 byte
count (a 2x weight-traffic cut on top of the int8 win, the dominant term
for the weight-bound ada/qkv/fc linears). The nibbles are widened to s8
codes in the VMEM prologue with two arithmetic shifts per byte
(sign-extension via ``((p & 0xF) ^ 8) - 8``) and fed to the MXU as s8xs8
dots, exactly like the int8 family; the MXU never sees a 4-bit operand.

Accuracy at 4 bits needs finer weight granularity than the int8 path's
per-output-channel scale (Q-DiT's observation): weights here carry
**per-(K-group, output-channel)** scales. The contraction axis is split
into groups of ``group_k`` rows — chosen at pack time to equal the
kernel's K tile, so one grid step is exactly one scale group — and the
s32 partial product of each K step is dequantized into a persistent
**f32** accumulator with that group's scale row before the next step:

    acc_f32 += (dot_s32(xq, unpack(wp)) - corr[g, k]) * scale[g, k]

``int4_matmul_fq``
    Affine 4-bit activations (uniform zero-point quantizer, the W4A4
    recipe's activation side): the fp tile is quantized in VMEM with the
    TGQ group-``g`` step ``clip(round(x/sx) + zx - 8, -8, 7)``, and the
    per-K-group zero-point correction ``corr[g, k] = z_eff[g] *
    colsum(codes[k-group])`` is subtracted before dequantization.

``int4_matmul_mrq_fq``
    Single-pass MRQ twin-region deployment at 4 bits (post-GELU fc2):
    the sign mask splits the activation tile into the two disjoint code
    tiles, ONE unpacked weight tile feeds two s32 dots, and both partial
    products are dequantized into one f32 accumulator with the region's
    per-K-group scale.

TGQ rides the same scalar-prefetch contract as ``int8_fused``: all
activation-side params are (G, ·)-stacked, ``g`` is a traced scalar
gathered by the BlockSpec index maps (scale/corr are (G, nk, N) with
``(g[0], k, n)`` maps), so the DDPM scan still compiles ONCE.

``int4_matmul_fq_vec`` / ``int4_matmul_mrq_fq_vec`` are the
vector-tgroup variants (see ``int8_fused``): a per-ROW (M,) group vector
replaces the scalar prefetch, the (G, 1, bn) param slices of EVERY group
stream per K step, and each row gathers its own group's params in VMEM
via the exact one-hot product — one nibble-packed weight stream covers a
batch mixing timestep groups.

Prologue/epilogue fusions: the whole family shares ``int8_fused``'s
optional norm-modulate prologue (``nm``), channel-balance prescale
(``ps``) and gate+residual epilogue (``gr``) — see that module's
docstring. The prologue runs before the quantize (and, for MRQ, before
the sign split); the epilogue gates + adds the residual tile onto the
f32 accumulator after the bias, ahead of the single HBM write.

Padding: K is padded to a multiple of ``group_k`` at pack time; padded
weight rows pack to code 0 and their column sums are not counted in
``corr``, so padded x columns (which quantize to the zero point) meet
zero codes and contribute nothing — the int8 padding argument, per group.

Tolerance contract: unlike the int8 family (integer accumulation, one
f32 epilogue — bit-exact vs the oracle), the per-K-group dequantization
accumulates in f32 once per K step. The oracle (`ref.int4_matmul_fq_ref`)
replays the same group-ordered accumulation; kernel-vs-oracle agreement
is a few f32 ulp (see the conformance suite's tolerance registry), not
bit-exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.int8_fused import (
    _fusion_epilogue, _fusion_prologue, _fusion_specs_args, _gather_rows,
    _onehot_rows, _prep_fusions, _unpack_fusion_refs,
)
from repro.kernels.int8_matmul import (
    DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, _ceil, _pad_to,
)


def pack_int4(codes, axis=0):
    """Pack signed 4-bit codes two-per-byte along ``axis``.

    codes: int tensor of values in [-8, 7]. Rows ``2i``/``2i + 1`` along
    ``axis`` land in byte ``i``'s low/high nibble. An odd length is
    zero-padded by one row (code 0 dequantizes to 0 — inert).
    Returns int8 of the same shape with ``axis`` halved (rounded up).
    """
    c = jnp.moveaxis(jnp.asarray(codes), axis, 0)
    if c.shape[0] % 2:
        c = jnp.concatenate([c, jnp.zeros((1,) + c.shape[1:], c.dtype)], 0)
    u = c.astype(jnp.int32) & 0xF
    byte = u[0::2] | (u[1::2] << 4)
    byte = jnp.where(byte > 127, byte - 256, byte).astype(jnp.int8)
    return jnp.moveaxis(byte, 0, axis)


def nibble_split(packed):
    """One packed int8 tensor -> (low, high) sign-extended s4-in-s32 codes.

    The sign extension is branch-free: ``(u ^ 8) - 8`` maps the 4-bit
    two's-complement pattern u in [0, 15] onto [-8, 7].
    """
    p = jnp.asarray(packed).astype(jnp.int32)
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    return lo, hi


def unpack_int4(packed, k=None, axis=0):
    """Inverse of ``pack_int4``: interleave nibbles back to s8 codes.

    ``k`` trims the unpacked ``axis`` back to the pre-padding length.
    """
    p = jnp.moveaxis(jnp.asarray(packed), axis, 0)
    lo, hi = nibble_split(p)
    out = jnp.stack([lo, hi], axis=1).reshape((2 * p.shape[0],) + p.shape[1:])
    if k is not None:
        out = out[:k]
    return jnp.moveaxis(out.astype(jnp.int8), 0, axis)


def _unpack_w(w_ref, bk):
    """VMEM prologue: (bk/2, bn) packed bytes -> (bk, bn) s32 codes."""
    lo, hi = nibble_split(w_ref[...])
    return jnp.stack([lo, hi], axis=1).reshape(bk, w_ref.shape[-1])


def _fq4_kernel(g_ref, *refs, nk: int, bk: int, half: int,
                has_ps: bool = False, has_nm: bool = False,
                has_gr: bool = False):
    """Grid body for ``int4_matmul_fq`` at grid point (m, n, k).

    One K step == one weight-scale group: the (bk/2, bn) packed tile is
    widened to (bk, bn) s8-range codes, dotted against the in-VMEM
    quantized x tile, and the s32 partial is corrected + dequantized into
    the persistent f32 ``acc_ref`` with THIS group's (1, 1, bn) scale row
    before the next step overwrites the tiles. Optional fusion refs
    follow ``bias`` (``_unpack_fusion_refs`` order).
    """
    del g_ref  # consumed by the index maps (per-group row gather)
    x_ref, w_ref, sx_ref, zx_ref, scale_ref, corr_ref, bias_ref = refs[:7]
    o_ref, acc_ref = refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-2], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sx = sx_ref[0, 0]
    zx = zx_ref[0, 0]
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    xq = jnp.clip(jnp.round(xf / sx) + zx - half,
                  -half, half - 1).astype(jnp.int8)
    w = _unpack_w(w_ref, bk)
    partial = jax.lax.dot_general(
        xq.astype(jnp.int32), w,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    acc_ref[...] += ((partial - corr_ref[0, 0][None, :]).astype(jnp.float32)
                     * scale_ref[0, 0][None, :])

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + bias_ref[...]
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group_k", "bm", "bn",
                                             "out_dtype", "interpret"))
def int4_matmul_fq(x, wp, sx, zx, scale, corr, bias=None, g=None, *,
                   ps=None, nm=None, gr=None, bv=None,
                   group_k=DEFAULT_BK, bm=DEFAULT_BM, bn=DEFAULT_BN,
                   out_dtype=jnp.float32, interpret=False):
    """y[M,N] = sum_k (q4(x_k; sx[g], zx[g]) @ s4(wp_k) - corr[g,k]) * scale[g,k].

    x: (M, K) float. wp: (Kp/2, N) int8 nibble-packed weight codes with
    Kp = nk * group_k >= K (pack-time padding; padded rows are code 0).
    sx/zx: (G, 1) f32 4-bit affine activation params. scale: (G, nk, N)
    f32 combined sx[g] * sw[kgroup, channel]; corr: (G, nk, N) i32
    per-K-group zero-point corrections. ``group_k`` is the pack-time
    K-group size and MUST equal the kernel's K tile (it is the K tile).
    g as in ``int8_matmul_fq``: python int or traced scalar.
    Optional ``ps``/``nm``/``gr``/``bv`` fusions as ``int8_matmul_fq``.
    """
    M, K = x.shape
    Kp = 2 * wp.shape[0]
    N = wp.shape[1]
    assert Kp % group_k == 0 and Kp >= K, (Kp, group_k, K)
    nk = Kp // group_k
    G = scale.shape[0]
    assert sx.shape == (G, 1) and zx.shape == (G, 1), (sx.shape, zx.shape)
    assert scale.shape == (G, nk, N) and corr.shape == (G, nk, N), \
        (scale.shape, corr.shape, (G, nk, N))
    bm_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(N))
    Mp, Np = _pad_to(M, bm_), _pad_to(N, bn_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if g is None:
        g = 0
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(wp, ((0, 0), (0, Np - N)))
    scale = jnp.pad(scale.astype(jnp.float32), ((0, 0), (0, 0), (0, Np - N)))
    corr = jnp.pad(corr.astype(jnp.int32), ((0, 0), (0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    grid = (Mp // bm_, Np // bn_, nk)
    # Same scalar-prefetch TGQ gather as int8_matmul_fq, with one more
    # gathered axis: scale/corr are (G, nk, N) and each K step pulls its
    # own (g, k) row — the per-group weight scales ride the grid, not the
    # executable, so one compile still covers all timestep groups.
    fspecs, fargs = _fusion_specs_args(
        has_g=True, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=group_k, bn_=bn_)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, group_k), lambda m, n, k, g: (m, k)),   # x
            pl.BlockSpec((group_k // 2, bn_),
                         lambda m, n, k, g: (k, n)),         # packed W
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),        # sx[g]
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),        # zx[g]
            pl.BlockSpec((1, 1, bn_),
                         lambda m, n, k, g: (g[0], k, n)),   # scale[g, k]
            pl.BlockSpec((1, 1, bn_),
                         lambda m, n, k, g: (g[0], k, n)),   # corr[g, k]
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (0, n)),         # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k, g: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_fq4_kernel, nk=nk, bk=group_k, half=8,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), x, wp,
      sx.astype(jnp.float32), zx.astype(jnp.float32), scale, corr, bias,
      *fargs)
    return out[:M, :N]


def _mrq4_kernel(g_ref, *refs, nk: int, bk: int, half: int,
                 has_ps: bool = False, has_nm: bool = False,
                 has_gr: bool = False):
    """Grid body for ``int4_matmul_mrq_fq`` at grid point (m, n, k).

    MRQ twin-region split as in ``int8_fused._mrq_kernel`` — ONE unpacked
    weight tile, two s32 dots — but both partials are dequantized into a
    single f32 accumulator with this K-group's per-region scale rows
    (there is no zero point, so no correction term). The fusion prologue
    runs before the sign split.
    """
    del g_ref
    x_ref, w_ref, sn_ref, sp_ref, scale_n_ref, scale_p_ref, bias_ref = \
        refs[:7]
    o_ref, acc_ref = refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-2], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn_ref[0, 0]), -half, 0),
                   0).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp_ref[0, 0]), 0, half - 1)
                   ).astype(jnp.int8)
    w = _unpack_w(w_ref, bk)                  # ONE weight-tile read, two dots
    dims = (((1,), (0,)), ((), ()))
    pn = jax.lax.dot_general(qn.astype(jnp.int32), w, dims,
                             preferred_element_type=jnp.int32)
    pp = jax.lax.dot_general(qp.astype(jnp.int32), w, dims,
                             preferred_element_type=jnp.int32)
    acc_ref[...] += (pn.astype(jnp.float32) * scale_n_ref[0, 0][None, :]
                     + pp.astype(jnp.float32) * scale_p_ref[0, 0][None, :])

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + bias_ref[...]
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group_k", "bm", "bn",
                                             "out_dtype", "interpret"))
def int4_matmul_mrq_fq(x, wp, s_neg, s_pos, scale_neg, scale_pos, bias=None,
                       g=None, *, ps=None, nm=None, gr=None, bv=None,
                       group_k=DEFAULT_BK, bm=DEFAULT_BM,
                       bn=DEFAULT_BN, out_dtype=jnp.float32, interpret=False):
    """Single-pass MRQ matmul on nibble-packed weights, per-K-group scales.

    y = sum_k s_neg[g]*sw[k]*(qn_k @ w_k) + s_pos[g]*sw[k]*(qp_k @ w_k)
    (+ bias). Operand layout as ``int4_matmul_fq`` but with the twin
    region steps s_neg/s_pos (G, 1) and scales scale_neg/scale_pos
    (G, nk, N). Optional ``ps``/``nm``/``gr``/``bv`` fusions as
    ``int8_matmul_fq``.
    """
    M, K = x.shape
    Kp = 2 * wp.shape[0]
    N = wp.shape[1]
    assert Kp % group_k == 0 and Kp >= K, (Kp, group_k, K)
    nk = Kp // group_k
    G = scale_neg.shape[0]
    assert s_neg.shape == (G, 1) and s_pos.shape == (G, 1)
    assert scale_neg.shape == (G, nk, N) and scale_pos.shape == (G, nk, N)
    bm_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(N))
    Mp, Np = _pad_to(M, bm_), _pad_to(N, bn_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if g is None:
        g = 0
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(wp, ((0, 0), (0, Np - N)))
    scale_neg = jnp.pad(scale_neg.astype(jnp.float32),
                        ((0, 0), (0, 0), (0, Np - N)))
    scale_pos = jnp.pad(scale_pos.astype(jnp.float32),
                        ((0, 0), (0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    grid = (Mp // bm_, Np // bn_, nk)
    fspecs, fargs = _fusion_specs_args(
        has_g=True, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=group_k, bn_=bn_)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, group_k), lambda m, n, k, g: (m, k)),   # x
            pl.BlockSpec((group_k // 2, bn_),
                         lambda m, n, k, g: (k, n)),         # packed W
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),     # s_neg[g]
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),     # s_pos[g]
            pl.BlockSpec((1, 1, bn_),
                         lambda m, n, k, g: (g[0], k, n)),   # scale_neg[g, k]
            pl.BlockSpec((1, 1, bn_),
                         lambda m, n, k, g: (g[0], k, n)),   # scale_pos[g, k]
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (0, n)),         # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k, g: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_mrq4_kernel, nk=nk, bk=group_k, half=8,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), x, wp,
      s_neg.astype(jnp.float32), s_pos.astype(jnp.float32),
      scale_neg, scale_pos, bias, *fargs)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# vector-tgroup variants: per-ROW group indices, one packed weight stream
# ---------------------------------------------------------------------------
def _fq4_vec_kernel(gv_ref, *refs, nk: int, bk: int, half: int,
                    has_ps: bool = False, has_nm: bool = False,
                    has_gr: bool = False):
    """Vector-tgroup body for ``int4_matmul_fq``: the (G, 1, bn) stacks of
    THIS K step's scales/corrections stream for every group; each row
    gathers its own group's values with the exact one-hot product before
    the per-step dequantized accumulation."""
    x_ref, w_ref, sx_ref, zx_ref, scale_ref, corr_ref, bias_ref = refs[:7]
    o_ref, acc_ref = refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-2], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G = sx_ref.shape[0]
    oh = _onehot_rows(gv_ref, G)
    ohf = oh.astype(jnp.float32)
    sx_row = _gather_rows(ohf, sx_ref, jnp.float32)      # (bm, 1)
    zx_row = _gather_rows(ohf, zx_ref, jnp.float32)      # (bm, 1)
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    xq = jnp.clip(
        jnp.round(xf / sx_row) + zx_row - half,
        -half, half - 1).astype(jnp.int8)
    w = _unpack_w(w_ref, bk)
    partial = jax.lax.dot_general(
        xq.astype(jnp.int32), w,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    scale_k = scale_ref[...][:, 0, :]                    # (G, bn)
    corr_k = corr_ref[...][:, 0, :]                      # (G, bn)
    scale_row = jax.lax.dot_general(
        ohf, scale_k.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    corr_row = jax.lax.dot_general(
        oh.astype(jnp.int32), corr_k.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    acc_ref[...] += (partial - corr_row).astype(jnp.float32) * scale_row

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + bias_ref[...]
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group_k", "bm", "bn",
                                             "out_dtype", "interpret"))
def int4_matmul_fq_vec(x, wp, sx, zx, scale, corr, bias=None, gv=None, *,
                       ps=None, nm=None, gr=None, bv=None,
                       group_k=DEFAULT_BK, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       out_dtype=jnp.float32, interpret=False):
    """``int4_matmul_fq`` with a per-ROW group vector gv (M,) int32.

    The nibble-packed weight streams ONCE for the whole mixed-group
    batch; per K step the (G, 1, bn) scale/corr slices of every group
    ride along. A constant gv is bit-identical to the scalar path (same
    elementwise ops, same f32 accumulation order). Optional ``ps``/
    ``nm``/``gr``/``bv`` fusions as ``int8_matmul_fq``.
    """
    M, K = x.shape
    Kp = 2 * wp.shape[0]
    N = wp.shape[1]
    assert Kp % group_k == 0 and Kp >= K, (Kp, group_k, K)
    nk = Kp // group_k
    G = scale.shape[0]
    assert sx.shape == (G, 1) and zx.shape == (G, 1), (sx.shape, zx.shape)
    assert scale.shape == (G, nk, N) and corr.shape == (G, nk, N), \
        (scale.shape, corr.shape, (G, nk, N))
    bm_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(N))
    Mp, Np = _pad_to(M, bm_), _pad_to(N, bn_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if gv is None:
        gv = jnp.zeros((M,), jnp.int32)
    gv = jnp.pad(jnp.asarray(gv, jnp.int32), (0, Mp - M)).reshape(Mp, 1)
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(wp, ((0, 0), (0, Np - N)))
    scale = jnp.pad(scale.astype(jnp.float32), ((0, 0), (0, 0), (0, Np - N)))
    corr = jnp.pad(corr.astype(jnp.int32), ((0, 0), (0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    grid = (Mp // bm_, Np // bn_, nk)
    fspecs, fargs = _fusion_specs_args(
        has_g=False, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=group_k, bn_=bn_)
    out = pl.pallas_call(
        functools.partial(_fq4_vec_kernel, nk=nk, bk=group_k, half=8,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, 1), lambda m, n, k: (m, 0)),          # gv rows
            pl.BlockSpec((bm_, group_k), lambda m, n, k: (m, k)),    # x
            pl.BlockSpec((group_k // 2, bn_),
                         lambda m, n, k: (k, n)),          # packed W
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),            # sx stack
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),            # zx stack
            pl.BlockSpec((G, 1, bn_),
                         lambda m, n, k: (0, k, n)),       # scale[:, k]
            pl.BlockSpec((G, 1, bn_),
                         lambda m, n, k: (0, k, n)),       # corr[:, k]
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),          # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(gv, x, wp, sx.astype(jnp.float32), zx.astype(jnp.float32),
      scale, corr, bias, *fargs)
    return out[:M, :N]


def _mrq4_vec_kernel(gv_ref, *refs, nk: int, bk: int, half: int,
                     has_ps: bool = False, has_nm: bool = False,
                     has_gr: bool = False):
    """Vector-tgroup body for ``int4_matmul_mrq_fq``: per-row twin-region
    steps, ONE unpacked weight tile, per-row per-K-group region scales."""
    x_ref, w_ref, sn_ref, sp_ref, scale_n_ref, scale_p_ref, bias_ref = \
        refs[:7]
    o_ref, acc_ref = refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-2], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G = sn_ref.shape[0]
    ohf = _onehot_rows(gv_ref, G).astype(jnp.float32)
    sn_row = _gather_rows(ohf, sn_ref, jnp.float32)      # (bm, 1)
    sp_row = _gather_rows(ohf, sp_ref, jnp.float32)      # (bm, 1)
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn_row), -half, 0),
                   0).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp_row), 0, half - 1)
                   ).astype(jnp.int8)
    w = _unpack_w(w_ref, bk)                  # ONE weight-tile read, two dots
    dims = (((1,), (0,)), ((), ()))
    pn = jax.lax.dot_general(qn.astype(jnp.int32), w, dims,
                             preferred_element_type=jnp.int32)
    pp = jax.lax.dot_general(qp.astype(jnp.int32), w, dims,
                             preferred_element_type=jnp.int32)
    scale_n_row = jax.lax.dot_general(
        ohf, scale_n_ref[...][:, 0, :].astype(jnp.float32), dims,
        preferred_element_type=jnp.float32)
    scale_p_row = jax.lax.dot_general(
        ohf, scale_p_ref[...][:, 0, :].astype(jnp.float32), dims,
        preferred_element_type=jnp.float32)
    acc_ref[...] += (pn.astype(jnp.float32) * scale_n_row
                     + pp.astype(jnp.float32) * scale_p_row)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + bias_ref[...]
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group_k", "bm", "bn",
                                             "out_dtype", "interpret"))
def int4_matmul_mrq_fq_vec(x, wp, s_neg, s_pos, scale_neg, scale_pos,
                           bias=None, gv=None, *, ps=None, nm=None, gr=None,
                           bv=None, group_k=DEFAULT_BK,
                           bm=DEFAULT_BM, bn=DEFAULT_BN,
                           out_dtype=jnp.float32, interpret=False):
    """``int4_matmul_mrq_fq`` with a per-ROW group vector gv (M,) int32
    (one-weight-read contract as ``int4_matmul_fq_vec``)."""
    M, K = x.shape
    Kp = 2 * wp.shape[0]
    N = wp.shape[1]
    assert Kp % group_k == 0 and Kp >= K, (Kp, group_k, K)
    nk = Kp // group_k
    G = scale_neg.shape[0]
    assert s_neg.shape == (G, 1) and s_pos.shape == (G, 1)
    assert scale_neg.shape == (G, nk, N) and scale_pos.shape == (G, nk, N)
    bm_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(N))
    Mp, Np = _pad_to(M, bm_), _pad_to(N, bn_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if gv is None:
        gv = jnp.zeros((M,), jnp.int32)
    gv = jnp.pad(jnp.asarray(gv, jnp.int32), (0, Mp - M)).reshape(Mp, 1)
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(wp, ((0, 0), (0, Np - N)))
    scale_neg = jnp.pad(scale_neg.astype(jnp.float32),
                        ((0, 0), (0, 0), (0, Np - N)))
    scale_pos = jnp.pad(scale_pos.astype(jnp.float32),
                        ((0, 0), (0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    grid = (Mp // bm_, Np // bn_, nk)
    fspecs, fargs = _fusion_specs_args(
        has_g=False, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=group_k, bn_=bn_)
    out = pl.pallas_call(
        functools.partial(_mrq4_vec_kernel, nk=nk, bk=group_k, half=8,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, 1), lambda m, n, k: (m, 0)),          # gv rows
            pl.BlockSpec((bm_, group_k), lambda m, n, k: (m, k)),    # x
            pl.BlockSpec((group_k // 2, bn_),
                         lambda m, n, k: (k, n)),          # packed W
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),         # s_neg stack
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),         # s_pos stack
            pl.BlockSpec((G, 1, bn_),
                         lambda m, n, k: (0, k, n)),       # scale_neg[:, k]
            pl.BlockSpec((G, 1, bn_),
                         lambda m, n, k: (0, k, n)),       # scale_pos[:, k]
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),          # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(gv, x, wp, s_neg.astype(jnp.float32), s_pos.astype(jnp.float32),
      scale_neg, scale_pos, bias, *fargs)
    return out[:M, :N]
