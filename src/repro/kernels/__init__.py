"""Pallas TPU kernels for the paper's compute hot spots:

  - int8_matmul_fq     — fused quantize->W8A8 MXU matmul->dequant (TGQ-
                         aware: per-group params gathered in-kernel),
  - int8_matmul_mrq_fq — single-pass MRQ matmul (one W traversal, dual
                         region accumulators),
  - int8_matmul        — W8A8 matmul over PRE-quantized codes (unfused
                         baseline; still used for einsum-style operands),
  - int8_bmm_qk        — batched symmetric int8 QK^T (attention scores),
  - int8_bmm_pv        — batched dual-region int8 P·V consuming the
                         region-signed MRQ prob codes directly,
  - flash_attn_mrq     — flash-style fused attention: int8 QK^T ->
                         online softmax -> MRQ codes -> dual-region P·V
                         in ONE kernel (no (S,S) HBM round-trip; the
                         serving default, attn_impl="flash"; at 4 bits a
                         packed-kv variant streams nibble-packed k/v),
  - int4_matmul_fq     — packed-int4 (W4A4) fused matmul: nibble weights
                         widen in the VMEM prologue, per-K-group scales
                         (Q-DiT), f32 accumulation,
  - int4_matmul_mrq_fq — packed-int4 single-pass MRQ matmul,
  - softmax_mrq        — fused softmax -> MRQ two-region quant-dequant,
  - softmax_mrq_codes  — fused softmax -> MRQ int8 CODES (deployment:
                         feeds int8_bmm_pv; probs never hit HBM as fp),
  - act_mrq            — fused GELU/SiLU -> MRQ signed quantization.

``ops`` exposes jit'd wrappers (interpret=True on CPU); ``ref`` holds the
pure-jnp oracles tests compare against.
"""
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.int8_fused import int8_matmul_fq, int8_matmul_mrq_fq
from repro.kernels.int4_packed import (
    int4_matmul_fq, int4_matmul_mrq_fq, nibble_split, pack_int4, unpack_int4,
)
from repro.kernels.int8_bmm import int8_bmm_pv, int8_bmm_qk
from repro.kernels.flash_attn_mrq import flash_attn_mrq
from repro.kernels.softmax_mrq import softmax_mrq, softmax_mrq_codes
from repro.kernels.act_mrq import act_mrq
from repro.kernels import ops, ref
