"""Pallas TPU kernels for the paper's compute hot spots:

  - int8_matmul  — W8A8 MXU matmul with fused dequant epilogue,
  - softmax_mrq  — fused softmax -> MRQ two-region quantization,
  - act_mrq      — fused GELU/SiLU -> MRQ signed quantization.

``ops`` exposes jit'd wrappers (interpret=True on CPU); ``ref`` holds the
pure-jnp oracles tests compare against.
"""
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.softmax_mrq import softmax_mrq
from repro.kernels.act_mrq import act_mrq
from repro.kernels import ops, ref
