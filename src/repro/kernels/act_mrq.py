"""Fused GELU/SiLU -> MRQ signed two-region quantization Pallas kernel.

The paper's post-GELU MRQ (§III-C) fused into the activation epilogue:
the MLP hidden tile is activated and quantized in VMEM before it is
written back, saving one full HBM round trip of the (tokens, d_ff)
tensor — the largest activation in the block.

Elementwise op: 2-D tiling (bm, bn) aligned to the 8x128 VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, sn_ref, sp_ref, o_ref, *, bits: int, kind: str):
    x = x_ref[...].astype(jnp.float32)
    if kind == "gelu":
        h = jax.nn.gelu(x, approximate=True)
    elif kind == "silu":
        h = jax.nn.silu(x)
    else:
        raise ValueError(kind)
    half = 2 ** (bits - 1)
    sn = sn_ref[0, 0]
    sp = sp_ref[0, 0]
    qn = jnp.clip(jnp.round(h / sn), -half, 0) * sn
    qp = jnp.clip(jnp.round(h / sp), 0, half - 1) * sp
    o_ref[...] = jnp.where(h < 0, qn, qp).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "kind", "bm", "bn",
                                             "out_dtype", "interpret"))
def act_mrq(x, s_neg, s_pos, *, bits: int = 8, kind: str = "gelu",
            bm: int = 256, bn: int = 512, out_dtype=jnp.float32,
            interpret=False):
    """act(x) then MRQ signed quant-dequant. x: any shape (>=1d)."""
    shape = x.shape
    N = shape[-1]
    R = 1
    for d in shape[:-1]:
        R *= d
    xm = x.reshape(R, N)
    bm_ = min(bm, max(8, R))
    bn_ = min(bn, max(128, N)) if N >= 128 else N
    Rp = -bm_ * (-R // bm_)
    Np = -bn_ * (-N // bn_)
    xm = jnp.pad(xm, ((0, Rp - R), (0, Np - N)))
    sn = jnp.asarray(s_neg, jnp.float32).reshape(1, 1)
    sp = jnp.asarray(s_pos, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, kind=kind),
        grid=(Rp // bm_, Np // bn_),
        in_specs=[
            pl.BlockSpec((bm_, bn_), lambda m, n: (m, n)),
            pl.BlockSpec((1, 1), lambda m, n: (0, 0)),
            pl.BlockSpec((1, 1), lambda m, n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Rp, Np), out_dtype),
        interpret=interpret,
    )(xm, sn, sp)
    return out[:R, :N].reshape(shape)
