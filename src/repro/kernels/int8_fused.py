"""Fused single-pass int8 serving kernels (the deployed W8A8 hot path).

Two kernels replace the old quantize -> int8_matmul -> (add) chain:

``int8_matmul_fq``
    Takes FP activations and quantizes each (bm, bk) tile **in VMEM**
    immediately before it is fed to the MXU — the standalone
    ``quantize_int8`` pass (an extra fp32 read + int8 write of the full
    activation through HBM) disappears. The epilogue applies the
    zero-point correction, the combined per-output-channel scale and the
    bias, so the FP result is written to HBM exactly once.

``int8_matmul_mrq_fq``
    Single-pass deployment of the MRQ two-region (PTQ4ViT-style twin
    uniform) input quantizer. The old path ran TWO full int8 matmuls
    (negative-region codes, positive-region codes) — 2x weight HBM
    traffic plus two (M, N) fp32 intermediates and an add. Here each
    weight tile is read once; the sign mask splits the activation tile
    into the two region codes in VMEM and feeds TWO s32 accumulators,
    each epilogued with its region scale. Weight traffic halves and the
    intermediates never exist.

TGQ (time-grouped quantization, the paper's §III-A) lives *inside* the
kernels: every activation-side parameter is stacked along a leading
(G,) group axis and the timestep group ``g`` — a traced scalar inside
the ``ddpm_sample`` lax.scan — is scalar-prefetched; the per-group row
is gathered by the BlockSpec index maps (``(g[0], n)``). The whole
sampling loop therefore stays ONE compiled executable with the int8
kernels inside; no per-group repacking or retracing.

``int8_matmul_fq_vec`` / ``int8_matmul_mrq_fq_vec`` are the
**vector-tgroup** variants: instead of one scalar-prefetched group, a
per-ROW ``(M,)`` int32 group vector rides as a (M, 1) VMEM operand and
the FULL (G, ·) param stacks stream in; each row gathers its own group's
params inside the kernel via an exact one-hot product (f32 one-hot
matmul is bit-exact — exactly one 1.0·value term, the rest exact zeros —
and the s32 ``corr`` gather uses an integer dot so values beyond f32's
24-bit exact-integer range survive). A batch mixing slots at different
timesteps therefore runs as ONE call that streams the weights exactly
once; a constant group vector is bit-identical to the scalar-prefetch
sibling (asserted in tests/test_kernel_conformance.py).

Prologue/epilogue fusions (shared by the whole fused-linear family,
including ``int4_packed``): every kernel optionally absorbs the fp
elementwise chains that used to round-trip through HBM around it.

``nm`` (norm-modulate prologue)
    The kernel takes the PRE-norm activation plus per-row layernorm
    stats (mu, 1/sigma — computed by the wrapper on the unpadded rows
    with the exact ``nn.layers.layernorm_apply`` ops) and the per-batch
    adaLN (shift, scale) rows; it replays ``(x - mu) * rsig`` then
    ``x * (1 + scale) + shift`` in VMEM right before the quantize, so
    the normalized/modulated tensor never exists in HBM. Per-batch rows
    are gathered per x row via the exact one-hot product against a
    (M, 1) row->batch index operand.

``gr`` (gate+residual epilogue)
    The dequantized output tile is scaled by the per-batch adaLN gate
    row and added to a streamed residual tile before the single HBM
    write — the separate ``x + g[:, None, :] * o`` pass disappears.

``ps`` (channel-balance prescale prologue)
    The channel-balance ``x_prescale`` divide (``x / ps`` — a DIVIDE,
    matching the fake-quant calibration bitwise) runs in the prologue
    between the modulate and the quantize; the matching ``w * ps`` fold
    happens at pack time, so channel-balanced ops run on real kernels.

All three are static specializations (absent fusions add no operands
and leave the original kernels byte-for-byte unchanged), and all three
compose with both the scalar-prefetch and vector-tgroup group gathers —
the DDPM scan still compiles ONCE with fusions active.

Tiling matches ``int8_matmul``: grid (M/bm, N/bn, K/bk), k innermost,
MXU-aligned blocks, s32 accumulator(s) in VMEM scratch. Non-aligned
shapes are zero-padded; padded K columns of x quantize to the zero
point but meet zero-padded weight rows, so they contribute nothing
(fusion operands pad inertly too: shift/scale with 0, prescale with 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.int8_matmul import (
    DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, _ceil, _pad_to,
)


# ---------------------------------------------------------------------------
# in-VMEM row gathers (shared by the vector-tgroup and fusion paths)
# ---------------------------------------------------------------------------
def _onehot_rows(gv_ref, n_groups: int):
    """(bm, 1) int32 group-index tile -> (bm, G) bool one-hot."""
    gv = gv_ref[...]
    iota = jax.lax.broadcasted_iota(jnp.int32, (gv.shape[0], n_groups), 1)
    return gv == iota


def _gather_rows(oh, param_ref, dtype):
    """Per-row gather of a (G, ·) param stack via a one-hot product.

    Exactly one term per output element is 1·value and the rest are exact
    zeros, so the f32 product is bit-exact; the int32 path uses an integer
    dot because s32 corr values can exceed f32's exact-integer range.
    """
    return jax.lax.dot_general(
        oh.astype(dtype), param_ref[...].astype(dtype),
        (((1,), (0,)), ((), ())), preferred_element_type=dtype)


# ---------------------------------------------------------------------------
# prologue/epilogue fusion plumbing (shared with int4_packed)
# ---------------------------------------------------------------------------
def _unpack_fusion_refs(refs, *, has_ps: bool, has_nm: bool, has_gr: bool):
    """Split the conditional fusion operand refs appended after ``bias``.

    Order (present-only): ps, bv, mu, rsig, shift, scale, gate, resid.
    Returns an 8-tuple with ``None`` for absent operands.
    """
    it = iter(refs)
    ps = next(it) if has_ps else None
    bv = next(it) if (has_nm or has_gr) else None
    mu = rsig = sh = sc = None
    if has_nm:
        mu, rsig, sh, sc = next(it), next(it), next(it), next(it)
    gate = res = None
    if has_gr:
        gate, res = next(it), next(it)
    return ps, bv, mu, rsig, sh, sc, gate, res


def _fusion_prologue(xf, ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref):
    """Replay, in VMEM and in the fake-quant path's exact op order, the
    elementwise chain ahead of the quantize: layernorm (per-row stats
    pre-computed by the wrapper) -> adaLN modulate (per-batch rows
    gathered by the exact one-hot product) -> channel-balance divide."""
    if mu_ref is not None:
        xf = (xf - mu_ref[...]) * rsig_ref[...]
        ohb = _onehot_rows(bv_ref, sh_ref.shape[0])
        sh_rows = _gather_rows(ohb, sh_ref, jnp.float32)
        sc_rows = _gather_rows(ohb, sc_ref, jnp.float32)
        xf = xf * (1.0 + sc_rows) + sh_rows
    if ps_ref is not None:
        xf = xf / ps_ref[...]
    return xf


def _fusion_epilogue(y, bv_ref, gate_ref, res_ref):
    """gate+residual epilogue: y -> resid + gate_rows * y before the
    single HBM write (per-batch gate rows gathered by one-hot)."""
    if gate_ref is not None:
        ohb = _onehot_rows(bv_ref, gate_ref.shape[0])
        gate_rows = _gather_rows(ohb, gate_ref, jnp.float32)
        y = res_ref[...] + gate_rows * y
    return y


def _prep_fusions(x, ps, nm, gr, bv, *, M, K, N, Mp, Kp, Np):
    """Pad/shape the optional fusion operands for the kernel call.

    ps : (K,) f32 channel-balance divisors (padded with 1 — inert).
    nm : (shift, scale) per-batch (B, K) adaLN modulate rows; the
         layernorm row stats are computed HERE on the unpadded ``x``
         with the exact ``layernorm_apply`` ops (mean/var/rsqrt,
         eps=1e-6), so the fused path is bit-identical to the unfused
         norm -> modulate chain.
    gr : (gate, resid) — (B, N) gate rows + (M, N) residual.
    bv : (M,) int32 row -> batch index (required by nm/gr).

    Returns (ps2, bv2, nm_rows, gr_rows) ready to append as operands.
    """
    f32 = jnp.float32
    ps2 = None
    if ps is not None:
        ps2 = jnp.pad(jnp.asarray(ps, f32).reshape(1, K),
                      ((0, 0), (0, Kp - K)), constant_values=1.0)
    bv2 = None
    if nm is not None or gr is not None:
        assert bv is not None, "norm_mod/gate_residual need a row->batch map"
        bv2 = jnp.pad(jnp.asarray(bv, jnp.int32), (0, Mp - M)).reshape(Mp, 1)
    nm_rows = None
    if nm is not None:
        sh, sc = nm
        xf = x.astype(f32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        rsig = jax.lax.rsqrt(var + 1e-6)
        nm_rows = (jnp.pad(mu, ((0, Mp - M), (0, 0))),
                   jnp.pad(rsig, ((0, Mp - M), (0, 0))),
                   jnp.pad(sh.astype(f32), ((0, 0), (0, Kp - K))),
                   jnp.pad(sc.astype(f32), ((0, 0), (0, Kp - K))))
    gr_rows = None
    if gr is not None:
        gate, res = gr
        gr_rows = (jnp.pad(gate.astype(f32), ((0, 0), (0, Np - N))),
                   jnp.pad(res.astype(f32), ((0, Mp - M), (0, Np - N))))
    return ps2, bv2, nm_rows, gr_rows


def _fusion_specs_args(*, has_g: bool, ps, bv, nm_rows, gr_rows,
                       bm_, bk_, bn_):
    """(in_specs, operands) for the present fusion inputs, in the
    ``_unpack_fusion_refs`` order. ``has_g`` selects index-map arity
    (scalar-prefetch grids take a trailing g argument)."""
    def im(f):
        return (lambda m, n, k, g: f(m, n, k)) if has_g else f
    specs, args = [], []
    if ps is not None:
        specs.append(pl.BlockSpec((1, bk_), im(lambda m, n, k: (0, k))))
        args.append(ps)
    if bv is not None:
        specs.append(pl.BlockSpec((bm_, 1), im(lambda m, n, k: (m, 0))))
        args.append(bv)
    if nm_rows is not None:
        mu, rsig, sh, sc = nm_rows
        B = sh.shape[0]
        specs += [pl.BlockSpec((bm_, 1), im(lambda m, n, k: (m, 0))),
                  pl.BlockSpec((bm_, 1), im(lambda m, n, k: (m, 0))),
                  pl.BlockSpec((B, bk_), im(lambda m, n, k: (0, k))),
                  pl.BlockSpec((B, bk_), im(lambda m, n, k: (0, k)))]
        args += [mu, rsig, sh, sc]
    if gr_rows is not None:
        gate, res = gr_rows
        B = gate.shape[0]
        specs += [pl.BlockSpec((B, bn_), im(lambda m, n, k: (0, n))),
                  pl.BlockSpec((bm_, bn_), im(lambda m, n, k: (m, n)))]
        args += [gate, res]
    return specs, args


def _fq_kernel(g_ref, *refs, nk: int, half: int, has_ps: bool = False,
               has_nm: bool = False, has_gr: bool = False):
    """Grid body for ``int8_matmul_fq`` at grid point (m, n, k).

    Refs arrive as VMEM tiles already gathered by the BlockSpec index
    maps: x (bm, bk) fp32, w (bk, bn) int8, and the TGQ-resolved rows of
    the activation-side params — sx/zx (1, 1) and scale/corr (1, bn) are
    the group-``g`` slices of the stacked (G, ·) arrays (see the
    ``(g[0], n)`` index maps below), so the body itself is group-agnostic.
    ``acc_ref`` is a persistent (bm, bn) s32 scratch: zeroed at k == 0,
    accumulated over the K-traversal (k innermost), epilogued at
    k == nk - 1. ``g_ref`` itself is unused here — prefetched scalars
    exist to feed index maps. Optional fusion refs follow ``bias``
    (``_unpack_fusion_refs`` order); absent fusions leave the body
    identical to the unfused original.
    """
    del g_ref  # consumed by the index maps (per-group row gather)
    x_ref, w_ref, sx_ref, zx_ref, scale_ref, corr_ref, bias_ref = refs[:7]
    o_ref, acc_ref = refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-2], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # fused-quantize prologue: fp tile -> signed codes in VMEM (the byte
    # range is [-half, half-1] — 8-bit uses the full s8 range, 6-bit
    # codes live in [-32, 31] inside the same int8 bytes)
    sx = sx_ref[0, 0]
    zx = zx_ref[0, 0]
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    xq = jnp.clip(jnp.round(xf / sx) + zx - half,
                  -half, half - 1).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        xq.astype(jnp.int32), w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...] - corr_ref[...]
        y = acc.astype(jnp.float32) * scale_ref[...] + bias_ref[...]
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_matmul_fq(x, wq, sx, zx, scale, corr, bias=None, g=None, *,
                   ps=None, nm=None, gr=None, bv=None, bits=8,
                   bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                   out_dtype=jnp.float32, interpret=False):
    """y[M,N] = (q(x; sx[g], zx[g]) @ wq - corr[g]) * scale[g] (+ bias).

    x: (M,K) float, wq: (K,N) int8. Activation-side params are stacked
    along a leading TGQ group axis: sx/zx (G,1) f32, scale (G,N) f32
    (s_x[g]*s_w per channel), corr (G,N) i32 (z_eff[g]*colsum(wq)).
    g is the group index — python int or traced scalar (scalar-prefetched,
    gathered by the BlockSpec index maps; no retrace across groups).
    ``bits`` sets the code range (8 -> [-128, 127], 6 -> [-32, 31]);
    sub-byte widths keep byte storage here — the nibble-PACKED weight
    path lives in ``int4_packed``.

    Optional fusions (see module docstring): ``ps`` (K,) channel-balance
    divisors, ``nm=(shift, scale)`` (B,K) adaLN modulate rows (x must be
    PRE-norm), ``gr=(gate, resid)`` ((B,N), (M,N)) gate+residual
    epilogue, ``bv`` (M,) int32 row->batch index (required by nm/gr).
    """
    half = 2 ** (bits - 1)
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    G = scale.shape[0]
    assert sx.shape == (G, 1) and zx.shape == (G, 1), (sx.shape, zx.shape)
    assert corr.shape == (G, N), (corr.shape, (G, N))
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(K))
    Mp, Np, Kp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(K, bk_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if g is None:
        g = 0
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    scale = jnp.pad(scale.astype(jnp.float32), ((0, 0), (0, Np - N)))
    corr = jnp.pad(corr.astype(jnp.int32), ((0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    nk = Kp // bk_
    grid = (Mp // bm_, Np // bn_, nk)
    # TGQ group gather: ``g`` rides as the single prefetched scalar (it is
    # read on the HOST side of the pipeline, before tiles stream in), and
    # every activation-side param picks its block row with ``g[0]`` — the
    # DMA engine fetches only group g's row of each stacked (G, ·) array.
    # A traced g (the tgroup inside ddpm_sample's scan) therefore changes
    # WHICH rows stream in, never the executable: one compile covers all
    # timestep groups.
    fspecs, fargs = _fusion_specs_args(
        has_g=True, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=bk_, bn_=bn_)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, n, k, g: (m, k)),    # x tile
            pl.BlockSpec((bk_, bn_), lambda m, n, k, g: (k, n)),    # W tile
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),     # sx[g]
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),     # zx[g]
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (g[0], n)),   # scale[g]
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (g[0], n)),   # corr[g]
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (0, n)),      # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k, g: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_fq_kernel, nk=nk, half=half,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), x, wq,
      sx.astype(jnp.float32), zx.astype(jnp.float32), scale, corr, bias,
      *fargs)
    return out[:M, :N]


def _mrq_kernel(g_ref, *refs, nk: int, half: int, has_ps: bool = False,
                has_nm: bool = False, has_gr: bool = False):
    """Grid body for ``int8_matmul_mrq_fq`` at grid point (m, n, k).

    Same tiling/prefetch contract as ``_fq_kernel`` (group-``g`` rows of
    the stacked (G, ·) params are pre-gathered by the index maps), but
    with the MRQ twin-region structure: the fp32 x tile is split by sign
    into two DISJOINT int8 code tiles (each element is zero in exactly
    one), both multiplied against the SAME weight tile — one VMEM-resident
    W read feeding two s32 accumulators — and the epilogue recombines them
    with their per-region scales. That is what collapses the old
    two-matmul MRQ deployment into a single W traversal. The fusion
    prologue (norm-modulate, prescale) runs BEFORE the sign split, so the
    region selection sees the same values the fake-quant path would.
    """
    del g_ref
    x_ref, w_ref, sn_ref, sp_ref, scale_n_ref, scale_p_ref, bias_ref = \
        refs[:7]
    o_ref, acc_n_ref, acc_p_ref = refs[-3], refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-3], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_n_ref[...] = jnp.zeros_like(acc_n_ref)
        acc_p_ref[...] = jnp.zeros_like(acc_p_ref)

    # region split in VMEM: sign mask -> two disjoint int8 code tiles
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn_ref[0, 0]), -half, 0),
                   0).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp_ref[0, 0]), 0, half - 1)
                   ).astype(jnp.int8)
    w = w_ref[...].astype(jnp.int32)          # ONE weight-tile read, two dots
    dims = (((1,), (0,)), ((), ()))
    acc_n_ref[...] += jax.lax.dot_general(qn.astype(jnp.int32), w, dims,
                                          preferred_element_type=jnp.int32)
    acc_p_ref[...] += jax.lax.dot_general(qp.astype(jnp.int32), w, dims,
                                          preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = (acc_n_ref[...].astype(jnp.float32) * scale_n_ref[...]
             + acc_p_ref[...].astype(jnp.float32) * scale_p_ref[...]
             + bias_ref[...])
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_matmul_mrq_fq(x, wq, s_neg, s_pos, scale_neg, scale_pos, bias=None,
                       g=None, *, ps=None, nm=None, gr=None, bv=None, bits=8,
                       bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                       out_dtype=jnp.float32, interpret=False):
    """Single-pass MRQ matmul: one traversal of wq, dual s32 accumulators.

    y = s_neg[g]*s_w*(qn @ wq) + s_pos[g]*s_w*(qp @ wq) (+ bias) where
    qn/qp are the negative/positive two-region codes of x (disjoint
    support, selected by sign). s_neg/s_pos: (G,1) f32 region steps;
    scale_neg/scale_pos: (G,N) f32 combined region*weight scales.
    Optional ``ps``/``nm``/``gr``/``bv`` fusions as ``int8_matmul_fq``
    (the prologue runs before the sign split; prescale divisors are
    positive, so region selection is unchanged).
    """
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    G = scale_neg.shape[0]
    assert s_neg.shape == (G, 1) and s_pos.shape == (G, 1)
    assert scale_pos.shape == (G, N)
    half = 2 ** (bits - 1)
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(K))
    Mp, Np, Kp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(K, bk_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if g is None:
        g = 0
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    scale_neg = jnp.pad(scale_neg.astype(jnp.float32), ((0, 0), (0, Np - N)))
    scale_pos = jnp.pad(scale_pos.astype(jnp.float32), ((0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    nk = Kp // bk_
    grid = (Mp // bm_, Np // bn_, nk)
    # Same scalar-prefetch group gather as int8_matmul_fq (see the comment
    # there); here the gathered rows are the two region step sizes and the
    # two combined region*weight scale rows.
    fspecs, fargs = _fusion_specs_args(
        has_g=True, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=bk_, bn_=bn_)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, n, k, g: (m, k)),    # x tile
            pl.BlockSpec((bk_, bn_), lambda m, n, k, g: (k, n)),    # W tile
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),     # s_neg[g]
            pl.BlockSpec((1, 1), lambda m, n, k, g: (g[0], 0)),     # s_pos[g]
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (g[0], n)),   # scale_neg
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (g[0], n)),   # scale_pos
            pl.BlockSpec((1, bn_), lambda m, n, k, g: (0, n)),      # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k, g: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32),
                        pltpu.VMEM((bm_, bn_), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_mrq_kernel, nk=nk, half=half,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(jnp.asarray(g, jnp.int32).reshape(1), x, wq,
      s_neg.astype(jnp.float32), s_pos.astype(jnp.float32),
      scale_neg, scale_pos, bias, *fargs)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# vector-tgroup variants: per-ROW group indices, one weight stream
# ---------------------------------------------------------------------------
def _fq_vec_kernel(gv_ref, *refs, nk: int, half: int, has_ps: bool = False,
                   has_nm: bool = False, has_gr: bool = False):
    """Vector-tgroup body: same math as ``_fq_kernel`` but each ROW of the
    x tile quantizes/dequantizes with its own group's params, gathered
    in VMEM from the full (G, ·) stacks (no scalar prefetch, no per-group
    weight re-stream)."""
    x_ref, w_ref, sx_ref, zx_ref, scale_ref, corr_ref, bias_ref = refs[:7]
    o_ref, acc_ref = refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-2], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G = sx_ref.shape[0]
    ohf = _onehot_rows(gv_ref, G).astype(jnp.float32)
    sx_row = _gather_rows(ohf, sx_ref, jnp.float32)      # (bm, 1)
    zx_row = _gather_rows(ohf, zx_ref, jnp.float32)      # (bm, 1)
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    xq = jnp.clip(
        jnp.round(xf / sx_row) + zx_row - half,
        -half, half - 1).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        xq.astype(jnp.int32), w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        oh = _onehot_rows(gv_ref, G)
        scale_row = _gather_rows(oh, scale_ref, jnp.float32)   # (bm, bn)
        corr_row = _gather_rows(oh, corr_ref, jnp.int32)       # (bm, bn)
        acc = acc_ref[...] - corr_row
        y = acc.astype(jnp.float32) * scale_row + bias_ref[...]
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_matmul_fq_vec(x, wq, sx, zx, scale, corr, bias=None, gv=None, *,
                       ps=None, nm=None, gr=None, bv=None, bits=8,
                       bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                       out_dtype=jnp.float32, interpret=False):
    """``int8_matmul_fq`` with a per-ROW group vector.

    gv: (M,) int32 — row i quantizes with sx[gv[i]]/zx[gv[i]] and
    dequantizes with scale[gv[i]]/corr[gv[i]]. The weight matrix streams
    ONCE for the whole mixed-group batch; the full (G, ·) param stacks
    ride along instead (G ≤ ~10, negligible next to W). A constant gv is
    bit-identical to the scalar-prefetch path. Optional ``ps``/``nm``/
    ``gr``/``bv`` fusions as ``int8_matmul_fq``.
    """
    half = 2 ** (bits - 1)
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    G = scale.shape[0]
    assert sx.shape == (G, 1) and zx.shape == (G, 1), (sx.shape, zx.shape)
    assert corr.shape == (G, N), (corr.shape, (G, N))
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(K))
    Mp, Np, Kp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(K, bk_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if gv is None:
        gv = jnp.zeros((M,), jnp.int32)
    gv = jnp.pad(jnp.asarray(gv, jnp.int32), (0, Mp - M)).reshape(Mp, 1)
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    scale = jnp.pad(scale.astype(jnp.float32), ((0, 0), (0, Np - N)))
    corr = jnp.pad(corr.astype(jnp.int32), ((0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    nk = Kp // bk_
    grid = (Mp // bm_, Np // bn_, nk)
    fspecs, fargs = _fusion_specs_args(
        has_g=False, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=bk_, bn_=bn_)
    out = pl.pallas_call(
        functools.partial(_fq_vec_kernel, nk=nk, half=half,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, 1), lambda m, n, k: (m, 0)),     # gv rows
            pl.BlockSpec((bm_, bk_), lambda m, n, k: (m, k)),   # x tile
            pl.BlockSpec((bk_, bn_), lambda m, n, k: (k, n)),   # W tile
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),       # sx stack
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),       # zx stack
            pl.BlockSpec((G, bn_), lambda m, n, k: (0, n)),     # scale stack
            pl.BlockSpec((G, bn_), lambda m, n, k: (0, n)),     # corr stack
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),     # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(gv, x, wq, sx.astype(jnp.float32), zx.astype(jnp.float32),
      scale, corr, bias, *fargs)
    return out[:M, :N]


def _mrq_vec_kernel(gv_ref, *refs, nk: int, half: int, has_ps: bool = False,
                    has_nm: bool = False, has_gr: bool = False):
    """Vector-tgroup body for the MRQ twin-region matmul: per-row region
    steps from the one-hot gather, one W read feeding both accumulators."""
    x_ref, w_ref, sn_ref, sp_ref, scale_n_ref, scale_p_ref, bias_ref = \
        refs[:7]
    o_ref, acc_n_ref, acc_p_ref = refs[-3], refs[-2], refs[-1]
    ps_ref, bv_ref, mu_ref, rsig_ref, sh_ref, sc_ref, gate_ref, res_ref = \
        _unpack_fusion_refs(refs[7:-3], has_ps=has_ps, has_nm=has_nm,
                            has_gr=has_gr)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_n_ref[...] = jnp.zeros_like(acc_n_ref)
        acc_p_ref[...] = jnp.zeros_like(acc_p_ref)

    G = sn_ref.shape[0]
    ohf = _onehot_rows(gv_ref, G).astype(jnp.float32)
    sn_row = _gather_rows(ohf, sn_ref, jnp.float32)      # (bm, 1)
    sp_row = _gather_rows(ohf, sp_ref, jnp.float32)      # (bm, 1)
    xf = _fusion_prologue(x_ref[...].astype(jnp.float32), ps_ref, bv_ref,
                          mu_ref, rsig_ref, sh_ref, sc_ref)
    neg = xf < 0
    qn = jnp.where(neg, jnp.clip(jnp.round(xf / sn_row), -half, 0),
                   0).astype(jnp.int8)
    qp = jnp.where(neg, 0, jnp.clip(jnp.round(xf / sp_row), 0, half - 1)
                   ).astype(jnp.int8)
    w = w_ref[...].astype(jnp.int32)          # ONE weight-tile read, two dots
    dims = (((1,), (0,)), ((), ()))
    acc_n_ref[...] += jax.lax.dot_general(qn.astype(jnp.int32), w, dims,
                                          preferred_element_type=jnp.int32)
    acc_p_ref[...] += jax.lax.dot_general(qp.astype(jnp.int32), w, dims,
                                          preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        ohe = _onehot_rows(gv_ref, G).astype(jnp.float32)
        scale_n_row = _gather_rows(ohe, scale_n_ref, jnp.float32)
        scale_p_row = _gather_rows(ohe, scale_p_ref, jnp.float32)
        y = (acc_n_ref[...].astype(jnp.float32) * scale_n_row
             + acc_p_ref[...].astype(jnp.float32) * scale_p_row
             + bias_ref[...])
        y = _fusion_epilogue(y, bv_ref, gate_ref, res_ref)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_matmul_mrq_fq_vec(x, wq, s_neg, s_pos, scale_neg, scale_pos,
                           bias=None, gv=None, *, ps=None, nm=None, gr=None,
                           bv=None, bits=8, bm=DEFAULT_BM, bn=DEFAULT_BN,
                           bk=DEFAULT_BK, out_dtype=jnp.float32,
                           interpret=False):
    """``int8_matmul_mrq_fq`` with a per-ROW group vector (see
    ``int8_matmul_fq_vec`` for the one-weight-read contract)."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    G = scale_neg.shape[0]
    assert s_neg.shape == (G, 1) and s_pos.shape == (G, 1)
    assert scale_pos.shape == (G, N)
    half = 2 ** (bits - 1)
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(K))
    Mp, Np, Kp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(K, bk_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    if gv is None:
        gv = jnp.zeros((M,), jnp.int32)
    gv = jnp.pad(jnp.asarray(gv, jnp.int32), (0, Mp - M)).reshape(Mp, 1)
    ps2, bv2, nm_rows, gr_rows = _prep_fusions(
        x, ps, nm, gr, bv, M=M, K=K, N=N, Mp=Mp, Kp=Kp, Np=Np)
    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Kp - K)))
    wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    scale_neg = jnp.pad(scale_neg.astype(jnp.float32), ((0, 0), (0, Np - N)))
    scale_pos = jnp.pad(scale_pos.astype(jnp.float32), ((0, 0), (0, Np - N)))
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    nk = Kp // bk_
    grid = (Mp // bm_, Np // bn_, nk)
    fspecs, fargs = _fusion_specs_args(
        has_g=False, ps=ps2, bv=bv2, nm_rows=nm_rows, gr_rows=gr_rows,
        bm_=bm_, bk_=bk_, bn_=bn_)
    out = pl.pallas_call(
        functools.partial(_mrq_vec_kernel, nk=nk, half=half,
                          has_ps=ps2 is not None, has_nm=nm_rows is not None,
                          has_gr=gr_rows is not None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, 1), lambda m, n, k: (m, 0)),     # gv rows
            pl.BlockSpec((bm_, bk_), lambda m, n, k: (m, k)),   # x tile
            pl.BlockSpec((bk_, bn_), lambda m, n, k: (k, n)),   # W tile
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),       # s_neg stack
            pl.BlockSpec((G, 1), lambda m, n, k: (0, 0)),       # s_pos stack
            pl.BlockSpec((G, bn_), lambda m, n, k: (0, n)),     # scale_neg
            pl.BlockSpec((G, bn_), lambda m, n, k: (0, n)),     # scale_pos
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),     # bias
        ] + fspecs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32),
                        pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(gv, x, wq, s_neg.astype(jnp.float32), s_pos.astype(jnp.float32),
      scale_neg, scale_pos, bias, *fargs)
    return out[:M, :N]
