"""Public jit'd wrappers around the Pallas kernels + the int8 deployment
converter that turns calibrated ``qparams`` + FP weights into packed int8
parameters consumed by ``QuantContext(kernel=True)``.

On this CPU container the wrappers run with ``interpret=True`` (kernel
body executed in Python for correctness); on a real TPU backend the same
calls compile to Mosaic. ``INTERPRET`` flips automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import ChannelQ, MRQSignedQ, UniformQ
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.softmax_mrq import softmax_mrq
from repro.kernels.act_mrq import act_mrq
from repro.kernels import ref

INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# int8 deployment path
# ---------------------------------------------------------------------------
def pack_int8_linear(qp: Dict[str, Any], w: np.ndarray) -> Optional[dict]:
    """Pack one linear op for the int8 kernel. Requires a per-tensor
    UniformQ activation quantizer and a ChannelQ weight quantizer (ops
    with MRQ-signed inputs use pack_int8_mrq_linear's two-matmul
    decomposition instead; see DESIGN §4)."""
    if not isinstance(qp.get("x"), UniformQ) or not isinstance(
            qp.get("w"), ChannelQ):
        return None
    wq_q: ChannelQ = qp["w"]
    xq_q: UniformQ = qp["x"]
    if np.asarray(xq_q.scale).ndim != 0 or wq_q.bits != 8 or xq_q.bits != 8:
        return None
    sw = jnp.asarray(wq_q.scale, jnp.float32).reshape(-1)     # (N,)
    w = jnp.asarray(w, jnp.float32)
    if sw.shape[0] != w.shape[-1] or w.ndim != 2:
        return None
    codes = jnp.clip(jnp.round(w / sw[None, :]), -127, 127).astype(jnp.int8)
    z_eff = jnp.round(xq_q.zero).astype(jnp.int32) - 128
    corr = z_eff * jnp.sum(codes.astype(jnp.int32), axis=0)
    return {
        "wq": codes,
        "scale": sw * jnp.asarray(xq_q.scale, jnp.float32),
        "corr": corr,
        "sx": jnp.asarray(xq_q.scale, jnp.float32),
        "zx": jnp.asarray(xq_q.zero, jnp.float32),
    }


def pack_int8_mrq_linear(qp: Dict[str, Any], w: np.ndarray) -> Optional[dict]:
    """Pack a linear whose input is MRQ-signed (post-GELU fc2): the
    two-region codes decompose into TWO int8 matmuls —
    y = s_neg*(qn_masked @ Wq)*sw + s_pos*(qp_masked @ Wq)*sw —
    the PTQ4ViT twin-uniform deployment trick on the MXU (DESIGN §4)."""
    if not isinstance(qp.get("x"), MRQSignedQ) or not isinstance(
            qp.get("w"), ChannelQ):
        return None
    wq_q: ChannelQ = qp["w"]
    xq_q: MRQSignedQ = qp["x"]
    if wq_q.bits != 8 or xq_q.bits != 8:
        return None
    sw = jnp.asarray(wq_q.scale, jnp.float32).reshape(-1)
    w = jnp.asarray(w, jnp.float32)
    if w.ndim != 2 or sw.shape[0] != w.shape[-1]:
        return None
    codes = jnp.clip(jnp.round(w / sw[None, :]), -127, 127).astype(jnp.int8)
    return {
        "wq": codes,
        "scale_neg": sw * jnp.asarray(xq_q.s_neg, jnp.float32),
        "scale_pos": sw * jnp.asarray(xq_q.s_pos, jnp.float32),
        "s_neg": jnp.asarray(xq_q.s_neg, jnp.float32),
        "s_pos": jnp.asarray(xq_q.s_pos, jnp.float32),
    }


def convert_for_kernels(qparams: Dict[str, dict],
                        weights: Dict[str, np.ndarray]) -> Dict[str, dict]:
    """Adds an 'int8' / 'int8_mrq' pack to every eligible linear op."""
    out = {}
    for name, qp in qparams.items():
        qp = dict(qp)
        if name in weights:
            pack = pack_int8_linear(qp, weights[name])
            if pack is not None:
                qp["int8"] = pack
            else:
                mpack = pack_int8_mrq_linear(qp, weights[name])
                if mpack is not None:
                    qp["int8_mrq"] = mpack
        out[name] = qp
    return out


def quantize_int8(x, scale, zero):
    """fp -> signed int8 codes (elementwise; XLA fuses this into the
    producer — a separate Pallas kernel buys nothing on TPU)."""
    return ref.quantize_int8_ref(x, scale, zero)


def int8_linear(x, pack: dict, bias=None, out_dtype=None):
    """Quantize x on the fly and run the int8 Pallas matmul."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    xm = x.reshape(-1, shape[-1])
    xq = quantize_int8(xm, pack["sx"], pack["zx"])
    y = int8_matmul(xq, pack["wq"], pack["scale"], pack["corr"],
                    bias=None if bias is None else jnp.asarray(bias, jnp.float32),
                    out_dtype=out_dtype, interpret=INTERPRET)
    return y.reshape(shape[:-1] + (pack["wq"].shape[1],))


def int8_linear_mrq(x, pack: dict, bias=None, out_dtype=None):
    """MRQ-input linear as two masked int8 matmuls (region codes kept
    int8; region select is the sign of x)."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    xm = x.reshape(-1, shape[-1]).astype(jnp.float32)
    half = 128
    neg_mask = xm < 0
    qn = jnp.where(neg_mask,
                   jnp.clip(jnp.round(xm / pack["s_neg"]), -half, 0),
                   0).astype(jnp.int8)
    qp = jnp.where(neg_mask, 0,
                   jnp.clip(jnp.round(xm / pack["s_pos"]), 0, half - 1)
                   ).astype(jnp.int8)
    zero_corr = jnp.zeros((pack["wq"].shape[1],), jnp.int32)
    yn = int8_matmul(qn, pack["wq"], pack["scale_neg"], zero_corr,
                     out_dtype=jnp.float32, interpret=INTERPRET)
    yp = int8_matmul(qp, pack["wq"], pack["scale_pos"], zero_corr,
                     bias=None if bias is None
                     else jnp.asarray(bias, jnp.float32),
                     out_dtype=jnp.float32, interpret=INTERPRET)
    return (yn + yp).astype(out_dtype).reshape(
        shape[:-1] + (pack["wq"].shape[1],))


# ---------------------------------------------------------------------------
# fused activation kernels (public API)
# ---------------------------------------------------------------------------
def softmax_mrq_op(scores, s1, bits: int = 8, out_dtype=jnp.float32):
    return softmax_mrq(scores, s1, bits=bits, out_dtype=out_dtype,
                       interpret=INTERPRET)


def act_mrq_op(x, s_neg, s_pos, bits: int = 8, kind: str = "gelu",
               out_dtype=jnp.float32):
    return act_mrq(x, s_neg, s_pos, bits=bits, kind=kind, out_dtype=out_dtype,
                   interpret=INTERPRET)
