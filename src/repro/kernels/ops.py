"""Public jit'd wrappers around the Pallas kernels + the int8 deployment
converter that turns calibrated ``qparams`` + FP weights into packed int8
parameters consumed by ``QuantContext(kernel=True)``.

Serving path (single fused kernel family, see ``int8_fused``):

  - plain / TGQ-uniform inputs  -> ``int8_matmul_fq``   (fused-quantize
    prologue; no standalone quantize pass through HBM),
  - MRQ-signed (post-GELU) inputs -> ``int8_matmul_mrq_fq`` (single W
    traversal, dual region accumulators; replaces the two-matmul
    decomposition),
  - attention (activation x activation) -> ``flash_attention`` (the
    serving default, ``attn_impl="flash"``): the whole block as ONE
    ``flash_attn_mrq`` kernel — int8 QK^T, online softmax, MRQ codes and
    dual-region P·V with the (S, S) scores/codes never touching HBM; or
    ``int8_attention`` (``attn_impl="composed"``, the exactness oracle):
    symmetric QK^T (``int8_bmm_qk``), softmax straight to region-signed
    MRQ codes (``softmax_mrq_codes``), and dual-region P·V consuming the
    codes directly (``int8_bmm_pv``) — the probabilities never exist in
    HBM as floats. Both consume the SAME packs, built by
    ``pack_int8_qk`` / ``pack_int8_pv`` from the calibrated ``attn/qk``
    and ``attn/pv`` einsum qparams.

Bit-widths: the pack builders are bits-driven — w8a8 and w6a6 pack for
the byte-code ``int8_*`` kernel family (6-bit codes ride in full int8
bytes; only the code range changes), while w4a4 packs for the
nibble-PACKED ``int4_*`` family (``int4_packed``: two weight codes per
byte, per-K-group weight scales à la Q-DiT, and a packed-kv flash
variant). Every pack records its ``"bits"`` and the wrappers thread it
to the kernels as a static argument.

Activation-side parameters are packed STACKED along a leading (G,) TGQ
group axis — per-tensor quantizers pack as G=1 — and the timestep group
is a traced scalar resolved inside the kernels, so ``ddpm_sample``'s
lax.scan stays one compiled executable.

Channel-balanced ops (``x_prescale`` from HO's balance search) pack like
everything else: the balance divide folds into the kernels' quantize
prologue (the pack stores ``x_prescale`` and the wrappers thread it as
``ps=``) and its inverse folds into the weight codes at pack time
(``w * ps[:, None]`` — the calibrated ``ChannelQ`` saw exactly that
product, so the codes are unchanged). The linear wrappers additionally
accept the adaLN ``norm_mod=(shift, scale)`` / ``gate_residual=(gate,
residual)`` fusion seams (see ``int8_fused``), so the layernorm-modulate
chain before a matmul and the gate-scaled residual add after it run in
VMEM instead of round-tripping fp activations through HBM.

On this CPU container the wrappers run with ``interpret=True`` (kernel
body executed in Python for correctness); on a real TPU backend the same
calls compile to Mosaic. ``INTERPRET`` flips automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import (
    ChannelQ, MRQSignedQ, MRQSoftmaxQ, SymQ, TGQ, UniformQ,
)
from repro.quant.groups import resolve_group
from repro.kernels.int8_matmul import DEFAULT_BK, _ceil, int8_matmul
from repro.kernels.int8_fused import (
    int8_matmul_fq, int8_matmul_fq_vec, int8_matmul_mrq_fq,
    int8_matmul_mrq_fq_vec,
)
from repro.kernels.int4_packed import (
    int4_matmul_fq, int4_matmul_fq_vec, int4_matmul_mrq_fq,
    int4_matmul_mrq_fq_vec, pack_int4, unpack_int4,
)
from repro.kernels.int8_bmm import (
    int8_bmm_pv, int8_bmm_pv_vec, int8_bmm_qk, int8_bmm_qk_vec,
)
from repro.kernels.flash_attn_mrq import flash_attn_mrq, flash_attn_mrq_vec
from repro.kernels.softmax_mrq import (
    softmax_mrq, softmax_mrq_codes, softmax_mrq_codes_vec,
)
from repro.kernels.act_mrq import act_mrq
from repro.kernels import ref

INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# int8 deployment path
# ---------------------------------------------------------------------------
def _unwrap_tgq(q):
    """Returns (inner_quantizer, is_tgq)."""
    if isinstance(q, TGQ):
        return q.inner, True
    return q, False


def _stack_param(p, is_tgq) -> jnp.ndarray:
    """Activation param -> (G, 1) f32 column (G=1 for per-tensor)."""
    a = jnp.asarray(p, jnp.float32)
    if not is_tgq:
        if a.ndim != 0:
            raise ValueError(f"per-tensor param must be scalar, got {a.shape}")
        return a.reshape(1, 1)
    if a.ndim != 1:
        raise ValueError(f"TGQ param must be stacked (G,), got {a.shape}")
    return a.reshape(-1, 1)


def _weight_codes(wq_q: ChannelQ, w, half: int = 128) -> Optional[tuple]:
    """(codes (K,N) int8, sw (N,) f32) or None if not a packable 2D linear.

    ``half`` follows the weight bit-width: 8-bit codes clip to ±127,
    6-bit to ±31 (stored in full int8 bytes either way)."""
    sw = jnp.asarray(wq_q.scale, jnp.float32).reshape(-1)
    w = jnp.asarray(w, jnp.float32)
    if w.ndim != 2 or sw.shape[0] != w.shape[-1]:
        return None
    codes = jnp.clip(jnp.round(w / sw[None, :]), -(half - 1), half - 1
                     ).astype(jnp.int8)
    return codes, sw


def _prescale_vec(qp: Dict[str, Any], w) -> Optional[jnp.ndarray]:
    """The op's channel-balance vector as a flat (K,) f32, or None.

    HO's balance search calibrated this op's quantizers on ``x / ps`` and
    ``w * ps`` — the kernels replay the divide in their quantize prologue
    (bitwise the fake-quant ``_q_in`` step; a multiply-by-inverse would
    drift by ulps) and the pack builders bake the multiply into the
    weight codes."""
    ps = qp.get("x_prescale")
    if ps is None:
        return None
    ps = jnp.asarray(ps, jnp.float32).reshape(-1)
    w = jnp.asarray(w)
    if w.ndim == 2 and ps.shape[0] != w.shape[0]:
        raise ValueError(
            f"x_prescale length {ps.shape[0]} != weight K {w.shape[0]}")
    return ps


def _balanced_w(w, ps: Optional[jnp.ndarray]):
    """Fold the balance multiply into the weight the codes are built from
    — the calibrated ``ChannelQ`` saw exactly ``w * ps``, so the codes
    (and the pack-time per-group absmax rescale at 4 bits) match what
    calibration measured."""
    if ps is None:
        return w
    return jnp.asarray(w, jnp.float32) * ps[:, None]


def pack_int8_linear(qp: Dict[str, Any], w: np.ndarray) -> Optional[dict]:
    """Pack one linear op for the fused int8 kernel. Accepts a per-tensor
    ``UniformQ`` or a time-grouped ``TGQ(UniformQ)`` activation quantizer
    and a ``ChannelQ`` weight quantizer. TGQ packs as stacked (G, ·)
    scale/zero/corr arrays gathered per-group inside the kernel.
    Bits-driven: 8- and 6-bit recipes pack here (byte codes, only the
    code range differs); 4-bit goes to ``pack_int4_linear``.

    Channel-balanced ops pack too: the quantizers were calibrated on
    x / ps and w * ps, so the weight codes are built from ``w * ps`` (the
    very tensor the ``ChannelQ`` saw) and the pack records ``x_prescale``
    for the kernel's in-prologue divide — no fake-quant fallback."""
    xq_q, is_tgq = _unwrap_tgq(qp.get("x"))
    if not isinstance(xq_q, UniformQ) or not isinstance(qp.get("w"), ChannelQ):
        return None
    wq_q: ChannelQ = qp["w"]
    bits = int(wq_q.bits)
    if bits not in (6, 8) or xq_q.bits != bits:
        return None
    half = 2 ** (bits - 1)
    try:
        sx = _stack_param(xq_q.scale, is_tgq)              # (G, 1)
        zx = _stack_param(xq_q.zero, is_tgq)               # (G, 1)
    except ValueError:
        return None
    ps = _prescale_vec(qp, w)
    cw = _weight_codes(wq_q, _balanced_w(w, ps), half)
    if cw is None:
        return None
    codes, sw = cw
    colsum = jnp.sum(codes.astype(jnp.int32), axis=0)      # (N,)
    z_eff = jnp.round(zx).astype(jnp.int32) - half         # (G, 1)
    pack = {
        "wq": codes,
        "sx": sx,
        "zx": zx,
        "scale": sx * sw[None, :],                          # (G, N)
        "corr": z_eff * colsum[None, :],                    # (G, N)
        "groups": int(sx.shape[0]),
        "bits": bits,
    }
    if ps is not None:
        pack["x_prescale"] = ps
    return pack


def pack_int8_mrq_linear(qp: Dict[str, Any], w: np.ndarray) -> Optional[dict]:
    """Pack a linear whose input is MRQ-signed (post-GELU fc2) — per-tensor
    ``MRQSignedQ`` or time-grouped ``TGQ(MRQSignedQ)`` — for the
    single-pass MRQ kernel (one W traversal, dual region accumulators).
    Channel-balanced ops pack with the prescale folded — see
    ``pack_int8_linear`` (the balance vector is positive, so the MRQ sign
    split is unaffected by the in-prologue divide)."""
    xq_q, is_tgq = _unwrap_tgq(qp.get("x"))
    if not isinstance(xq_q, MRQSignedQ) or not isinstance(
            qp.get("w"), ChannelQ):
        return None
    wq_q: ChannelQ = qp["w"]
    bits = int(wq_q.bits)
    if bits not in (6, 8) or xq_q.bits != bits:
        return None
    try:
        s_neg = _stack_param(xq_q.s_neg, is_tgq)           # (G, 1)
        s_pos = _stack_param(xq_q.s_pos, is_tgq)           # (G, 1)
    except ValueError:
        return None
    ps = _prescale_vec(qp, w)
    cw = _weight_codes(wq_q, _balanced_w(w, ps), 2 ** (bits - 1))
    if cw is None:
        return None
    codes, sw = cw
    pack = {
        "wq": codes,
        "s_neg": s_neg,
        "s_pos": s_pos,
        "scale_neg": s_neg * sw[None, :],                   # (G, N)
        "scale_pos": s_pos * sw[None, :],                   # (G, N)
        "groups": int(s_neg.shape[0]),
        "bits": bits,
    }
    if ps is not None:
        pack["x_prescale"] = ps
    return pack


# ---------------------------------------------------------------------------
# packed-int4 deployment path (nibble weights, per-K-group scales)
# ---------------------------------------------------------------------------
def _int4_group_codes(wq_q: ChannelQ, w) -> Optional[tuple]:
    """(codes3 (nk, group_k, N) int8 in [-7, 7], sw (nk, N) f32, group_k)
    or None if not a packable 2D linear.

    4-bit weights need finer granularity than one scale per output
    channel (Q-DiT): the K axis is re-scaled per group of ``group_k``
    rows — group_k is chosen to equal the int4 kernel's K tile, so each
    grid step is exactly one scale group. The calibrated per-channel
    ``wq_q.scale`` is superseded by the pack-time per-group absmax/7
    (a strict refinement: every group scale <= the channel scale)."""
    w = jnp.asarray(w, jnp.float32)
    sw_cal = jnp.asarray(wq_q.scale, jnp.float32).reshape(-1)
    if w.ndim != 2 or sw_cal.shape[0] != w.shape[-1]:
        return None
    K, N = w.shape
    group_k = min(DEFAULT_BK, _ceil(K))
    Kp = -group_k * (-K // group_k)
    nk = Kp // group_k
    w3 = jnp.pad(w, ((0, Kp - K), (0, 0))).reshape(nk, group_k, N)
    sw = jnp.maximum(jnp.max(jnp.abs(w3), axis=1), 1e-8) / 7.0   # (nk, N)
    codes3 = jnp.clip(jnp.round(w3 / sw[:, None, :]), -7, 7).astype(jnp.int8)
    return codes3, sw, group_k


def pack_int4_linear(qp: Dict[str, Any], w: np.ndarray) -> Optional[dict]:
    """Pack one linear op for ``int4_matmul_fq``: ``UniformQ`` /
    ``TGQ(UniformQ)`` activations + ``ChannelQ`` weights at 4 bits.
    Weights are nibble-packed two-per-byte; scale/corr carry the extra
    per-K-group axis (G, nk, N). Channel-balanced ops pack with the
    prescale folded (see ``pack_int8_linear``); the per-K-group absmax
    rescale runs on the balanced weight, matching calibration."""
    xq_q, is_tgq = _unwrap_tgq(qp.get("x"))
    if not isinstance(xq_q, UniformQ) or not isinstance(qp.get("w"), ChannelQ):
        return None
    wq_q: ChannelQ = qp["w"]
    if wq_q.bits != 4 or xq_q.bits != 4:
        return None
    try:
        sx = _stack_param(xq_q.scale, is_tgq)              # (G, 1)
        zx = _stack_param(xq_q.zero, is_tgq)               # (G, 1)
    except ValueError:
        return None
    ps = _prescale_vec(qp, w)
    gc = _int4_group_codes(wq_q, _balanced_w(w, ps))
    if gc is None:
        return None
    codes3, sw, group_k = gc
    N = codes3.shape[-1]
    colsum = jnp.sum(codes3.astype(jnp.int32), axis=1)     # (nk, N)
    z_eff = jnp.round(zx).astype(jnp.int32) - 8            # (G, 1)
    pack = {
        "wp": pack_int4(codes3.reshape(-1, N)),             # (Kp/2, N)
        "sx": sx,
        "zx": zx,
        "scale": sx[:, :, None] * sw[None],                 # (G, nk, N)
        "corr": z_eff[:, :, None] * colsum[None],           # (G, nk, N)
        "groups": int(sx.shape[0]),
        "group_k": int(group_k),
        "k": int(jnp.asarray(w).shape[0]),
        "bits": 4,
    }
    if ps is not None:
        pack["x_prescale"] = ps
    return pack


def pack_int4_mrq_linear(qp: Dict[str, Any], w: np.ndarray) -> Optional[dict]:
    """Pack an MRQ-signed-input linear (post-GELU fc2) for
    ``int4_matmul_mrq_fq``: nibble-packed weights, per-region per-K-group
    scales (G, nk, N), no zero-point correction. Channel-balanced ops
    pack with the prescale folded (see ``pack_int8_linear``)."""
    xq_q, is_tgq = _unwrap_tgq(qp.get("x"))
    if not isinstance(xq_q, MRQSignedQ) or not isinstance(
            qp.get("w"), ChannelQ):
        return None
    wq_q: ChannelQ = qp["w"]
    if wq_q.bits != 4 or xq_q.bits != 4:
        return None
    try:
        s_neg = _stack_param(xq_q.s_neg, is_tgq)           # (G, 1)
        s_pos = _stack_param(xq_q.s_pos, is_tgq)           # (G, 1)
    except ValueError:
        return None
    ps = _prescale_vec(qp, w)
    gc = _int4_group_codes(wq_q, _balanced_w(w, ps))
    if gc is None:
        return None
    codes3, sw, group_k = gc
    N = codes3.shape[-1]
    pack = {
        "wp": pack_int4(codes3.reshape(-1, N)),             # (Kp/2, N)
        "s_neg": s_neg,
        "s_pos": s_pos,
        "scale_neg": s_neg[:, :, None] * sw[None],          # (G, nk, N)
        "scale_pos": s_pos[:, :, None] * sw[None],          # (G, nk, N)
        "groups": int(s_neg.shape[0]),
        "group_k": int(group_k),
        "k": int(jnp.asarray(w).shape[0]),
        "bits": 4,
    }
    if ps is not None:
        pack["x_prescale"] = ps
    return pack


def _broadcast_groups(*cols):
    """Broadcast (1,1)/(G,1) stacked param columns to a common (G,1)."""
    G = max(int(c.shape[0]) for c in cols)
    out = []
    for c in cols:
        if c.shape[0] not in (1, G):
            return None
        out.append(jnp.broadcast_to(c, (G, 1)))
    return tuple(out) + (G,)


def pack_int8_qk(qp: Dict[str, Any]) -> Optional[dict]:
    """Pack an attention QK^T einsum for ``int8_bmm_qk``. Wants SYMMETRIC
    per-tensor quantizers on both activation operands — ``SymQ`` or
    time-grouped ``TGQ(SymQ)`` (group counts may differ; (1,·) params
    broadcast against the larger G)."""
    xq_q, x_tgq = _unwrap_tgq(qp.get("x"))
    bq_q, b_tgq = _unwrap_tgq(qp.get("b"))
    if not isinstance(xq_q, SymQ) or not isinstance(bq_q, SymQ):
        return None
    if xq_q.bits != bq_q.bits or xq_q.bits not in (4, 6, 8):
        return None
    try:
        s_q = _stack_param(xq_q.scale, x_tgq)              # (Gq, 1)
        s_k = _stack_param(bq_q.scale, b_tgq)              # (Gk, 1)
    except ValueError:
        return None
    bc = _broadcast_groups(s_q, s_k)
    if bc is None:
        return None
    s_q, s_k, G = bc
    return {
        "s_q": s_q,
        "s_k": s_k,
        "scale": s_q * s_k,                                 # (G, 1)
        "groups": G,
        "bits": int(xq_q.bits),
    }


def pack_int8_pv(qp: Dict[str, Any]) -> Optional[dict]:
    """Pack an attention P·V einsum for ``softmax_mrq_codes`` +
    ``int8_bmm_pv``: the probs side must be ``MRQSoftmaxQ`` (or
    ``TGQ(MRQSoftmaxQ)``), the value side ``SymQ`` / ``TGQ(SymQ)``."""
    xq_q, x_tgq = _unwrap_tgq(qp.get("x"))
    bq_q, b_tgq = _unwrap_tgq(qp.get("b"))
    if not isinstance(xq_q, MRQSoftmaxQ) or not isinstance(bq_q, SymQ):
        return None
    if xq_q.bits != bq_q.bits or xq_q.bits not in (4, 6, 8):
        return None
    try:
        s1 = _stack_param(xq_q.s1, x_tgq)                  # (Gp, 1)
        s_v = _stack_param(bq_q.scale, b_tgq)              # (Gv, 1)
    except ValueError:
        return None
    bc = _broadcast_groups(s1, s_v)
    if bc is None:
        return None
    s1, s_v, G = bc
    s2 = 1.0 / (2 ** (xq_q.bits - 1))
    return {
        "s1": s1,
        "s_v": s_v,
        "scale1": s1 * s_v,                                 # (G, 1)
        "scale2": s2 * s_v,                                 # (G, 1)
        "groups": G,
        "bits": int(xq_q.bits),
    }


def convert_for_kernels(qparams: Dict[str, dict],
                        weights: Dict[str, np.ndarray]) -> Dict[str, dict]:
    """Adds an 'int8' / 'int8_mrq' (byte codes, 8- or 6-bit) or 'int4' /
    'int4_mrq' (nibble-packed, per-K-group scales) pack to every eligible
    linear op and an 'int8_qk' / 'int8_pv' pack (bits-tagged, 8/6/4) to
    every eligible attention einsum — ``QuantContext(kernel=True)``
    dispatches on whichever pack key is present; the attention path fires
    exactly when BOTH attention packs of an op are present. The bit-width
    is read off the op's own quantizers, so one call handles w8a8, w6a6,
    and w4a4 recipes alike."""
    out = {}
    for name, qp in qparams.items():
        qp = dict(qp)
        if name in weights:
            for key, builder in (("int8", pack_int8_linear),
                                 ("int8_mrq", pack_int8_mrq_linear),
                                 ("int4", pack_int4_linear),
                                 ("int4_mrq", pack_int4_mrq_linear)):
                pack = builder(qp, weights[name])
                if pack is not None:
                    qp[key] = pack
                    break
        if name.endswith("/qk"):
            qpack = pack_int8_qk(qp)
            if qpack is not None:
                qp["int8_qk"] = qpack
        elif name.endswith("/pv"):
            ppack = pack_int8_pv(qp)
            if ppack is not None:
                qp["int8_pv"] = ppack
        out[name] = qp
    return out


def quantize_int8(x, scale, zero):
    """fp -> signed int8 codes (elementwise). Retained for the UNFUSED
    baseline and tests; the serving path quantizes inside
    ``int8_matmul_fq`` and never materializes these codes in HBM."""
    return ref.quantize_int8_ref(x, scale, zero)


def _group_index(pack: dict, tgroup):
    """Resolve the (possibly traced) TGQ group into a safe kernel index —
    the exact/clamp half of the shared ``repro.quant.groups`` contract.
    ``tgroup`` may also be a per-slot (B,) VECTOR (vector-tgroup batched
    path): the clamp maps elementwise and the wrappers below dispatch to
    the ``*_vec`` kernels, which stream the weights ONCE for the whole
    mixed-timestep batch and gather per-row activation params in VMEM."""
    return resolve_group(tgroup, pack["groups"])


def _is_vec(g) -> bool:
    """True when a resolved group index is a per-slot (B,) vector rather
    than a scalar (python int or traced 0-d)."""
    return getattr(g, "ndim", 0) == 1


def _rows_vec(g, n_rows: int):
    """Expand a per-slot (B,) group vector to one entry per matmul ROW.

    ``x.reshape(-1, K)`` keeps token rows batch-major contiguous, so slot
    b owns rows [b*rows_per_slot, (b+1)*rows_per_slot)."""
    B = int(g.shape[0])
    if n_rows % B != 0:
        raise ValueError(
            f"vector tgroup: {n_rows} matmul rows not divisible by "
            f"{B} slots")
    return jnp.repeat(jnp.asarray(g, jnp.int32), n_rows // B)


def _as_vec(g, B: int):
    """Lift a scalar group (e.g. a per-tensor G=1 pack resolving to 0) to
    a constant (B,) vector so it can ride the vector kernels alongside a
    genuinely mixed sibling pack. Constant vectors are bit-identical to
    the scalar-prefetch path (asserted by the conformance suite)."""
    if _is_vec(g):
        return jnp.asarray(g, jnp.int32)
    return jnp.full((B,), jnp.asarray(g, jnp.int32))


def _fusion_kwargs(pack: dict, xm, norm_mod, gate_residual) -> dict:
    """Kernel-side ``ps``/``nm``/``gr``/``bv`` operands for one linear.

    ``norm_mod = (shift, scale)`` and ``gate_residual = (gate, residual)``
    carry per-BATCH (B, ·) adaLN rows (the residual is x-shaped). Matmul
    rows stay batch-major under ``x.reshape(-1, K)``, so the row->batch
    map the kernels gather with is a plain repeat. The channel-balance
    prescale rides the pack itself (``pack_int8_linear``)."""
    kw = {}
    ps = pack.get("x_prescale")
    if ps is not None:
        kw["ps"] = ps
    if norm_mod is None and gate_residual is None:
        return kw
    ref_rows = norm_mod[0] if norm_mod is not None else gate_residual[0]
    B = int(ref_rows.shape[0])
    n_rows = int(xm.shape[0])
    if n_rows % B != 0:
        raise ValueError(
            f"fusion rows: {n_rows} matmul rows not divisible by batch {B}")
    kw["bv"] = jnp.repeat(jnp.arange(B, dtype=jnp.int32), n_rows // B)
    if norm_mod is not None:
        sh, sc = norm_mod
        kw["nm"] = (jnp.asarray(sh, jnp.float32), jnp.asarray(sc, jnp.float32))
    if gate_residual is not None:
        gate, res = gate_residual
        res = jnp.asarray(res, jnp.float32)
        kw["gr"] = (jnp.asarray(gate, jnp.float32),
                    res.reshape(-1, res.shape[-1]))
    return kw


def int8_linear(x, pack: dict, bias=None, out_dtype=None, tgroup=None,
                norm_mod=None, gate_residual=None):
    """Fused quantize->matmul->dequant serving linear (TGQ-aware).

    ``tgroup`` may be a per-slot (B,) vector: the whole mixed-timestep
    batch then runs as ONE ``int8_matmul_fq_vec`` call — weights stream
    once, each row gathers its own group's quant params in VMEM.
    ``norm_mod``/``gate_residual`` fuse the surrounding adaLN elementwise
    chains into the kernel (see ``_fusion_kwargs``)."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    xm = x.reshape(-1, shape[-1])
    g = _group_index(pack, tgroup)
    bias_f = None if bias is None else jnp.asarray(bias, jnp.float32)
    fkw = _fusion_kwargs(pack, xm, norm_mod, gate_residual)
    if _is_vec(g):
        y = int8_matmul_fq_vec(
            xm, pack["wq"], pack["sx"], pack["zx"], pack["scale"],
            pack["corr"], bias=bias_f, gv=_rows_vec(g, xm.shape[0]),
            bits=pack.get("bits", 8), out_dtype=out_dtype,
            interpret=INTERPRET, **fkw)
    else:
        y = int8_matmul_fq(
            xm, pack["wq"], pack["sx"], pack["zx"], pack["scale"],
            pack["corr"], bias=bias_f, g=g, bits=pack.get("bits", 8),
            out_dtype=out_dtype, interpret=INTERPRET, **fkw)
    return y.reshape(shape[:-1] + (pack["wq"].shape[1],))


def int8_linear_mrq(x, pack: dict, bias=None, out_dtype=None, tgroup=None,
                    norm_mod=None, gate_residual=None):
    """MRQ-input serving linear: single-pass kernel (one W traversal,
    in-kernel sign masking, dual region accumulators)."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    xm = x.reshape(-1, shape[-1])
    g = _group_index(pack, tgroup)
    bias_f = None if bias is None else jnp.asarray(bias, jnp.float32)
    fkw = _fusion_kwargs(pack, xm, norm_mod, gate_residual)
    if _is_vec(g):
        y = int8_matmul_mrq_fq_vec(
            xm, pack["wq"], pack["s_neg"], pack["s_pos"],
            pack["scale_neg"], pack["scale_pos"], bias=bias_f,
            gv=_rows_vec(g, xm.shape[0]), bits=pack.get("bits", 8),
            out_dtype=out_dtype, interpret=INTERPRET, **fkw)
    else:
        y = int8_matmul_mrq_fq(
            xm, pack["wq"], pack["s_neg"], pack["s_pos"],
            pack["scale_neg"], pack["scale_pos"], bias=bias_f, g=g,
            bits=pack.get("bits", 8), out_dtype=out_dtype,
            interpret=INTERPRET, **fkw)
    return y.reshape(shape[:-1] + (pack["wq"].shape[1],))


def int4_linear(x, pack: dict, bias=None, out_dtype=None, tgroup=None,
                norm_mod=None, gate_residual=None):
    """Packed-int4 serving linear: nibble weights widen in the VMEM
    prologue, f32 accumulation with per-K-group dequant (TGQ-aware)."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    xm = x.reshape(-1, shape[-1])
    g = _group_index(pack, tgroup)
    bias_f = None if bias is None else jnp.asarray(bias, jnp.float32)
    fkw = _fusion_kwargs(pack, xm, norm_mod, gate_residual)
    if _is_vec(g):
        y = int4_matmul_fq_vec(
            xm, pack["wp"], pack["sx"], pack["zx"], pack["scale"],
            pack["corr"], bias=bias_f, gv=_rows_vec(g, xm.shape[0]),
            group_k=pack["group_k"], out_dtype=out_dtype,
            interpret=INTERPRET, **fkw)
    else:
        y = int4_matmul_fq(
            xm, pack["wp"], pack["sx"], pack["zx"], pack["scale"],
            pack["corr"], bias=bias_f, g=g, group_k=pack["group_k"],
            out_dtype=out_dtype, interpret=INTERPRET, **fkw)
    return y.reshape(shape[:-1] + (pack["wp"].shape[1],))


def int4_linear_mrq(x, pack: dict, bias=None, out_dtype=None, tgroup=None,
                    norm_mod=None, gate_residual=None):
    """Packed-int4 MRQ-input serving linear (one nibble-weight traversal,
    dual region dots, per-K-group dequant)."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    xm = x.reshape(-1, shape[-1])
    g = _group_index(pack, tgroup)
    bias_f = None if bias is None else jnp.asarray(bias, jnp.float32)
    fkw = _fusion_kwargs(pack, xm, norm_mod, gate_residual)
    if _is_vec(g):
        y = int4_matmul_mrq_fq_vec(
            xm, pack["wp"], pack["s_neg"], pack["s_pos"],
            pack["scale_neg"], pack["scale_pos"], bias=bias_f,
            gv=_rows_vec(g, xm.shape[0]), group_k=pack["group_k"],
            out_dtype=out_dtype, interpret=INTERPRET, **fkw)
    else:
        y = int4_matmul_mrq_fq(
            xm, pack["wp"], pack["s_neg"], pack["s_pos"],
            pack["scale_neg"], pack["scale_pos"], bias=bias_f, g=g,
            group_k=pack["group_k"], out_dtype=out_dtype,
            interpret=INTERPRET, **fkw)
    return y.reshape(shape[:-1] + (pack["wp"].shape[1],))


# ---------------------------------------------------------------------------
# int8 attention (the serving attention hot path)
# ---------------------------------------------------------------------------
def int8_attention(q, k, v, qk_pack: dict, pv_pack: dict, *, mask=None,
                   scale=1.0, tgroup=None, out_dtype=None):
    """End-to-end int8 grouped SDPA: QK^T -> fused softmax-MRQ -> P·V.

    q: (B, Sq, Hk, G, hd); k, v: (B, Skv, Hk, hd); mask broadcastable to
    (B, Hk, G, Sq, Skv) boolean or None; ``scale`` is the softmax
    1/sqrt(hd), folded into the QK^T dequant epilogue. Returns
    (B, Sq, Hk, G, hd). The probabilities travel between the softmax and
    P·V kernels as int8 region-signed codes — never as fp through HBM.
    ``tgroup`` may be a traced scalar (resolved per-pack; each kernel
    gathers its group row via scalar prefetch, so the surrounding
    ``ddpm_sample`` scan compiles once).
    """
    out_dtype = out_dtype or q.dtype
    B, Sq, Hk, G, hd = q.shape
    Skv = k.shape[1]
    BHG = B * Hk * G
    g_qk = _group_index(qk_pack, tgroup)
    g_pv = _group_index(pv_pack, tgroup)

    # GQA without materialized copies: q flattens to (B*Hk*G, ...) but k/v
    # stay (B*Hk, ...) — the kernels' b // rep batch index maps gather the
    # kv head shared by every query group, so k/v HBM traffic does not
    # scale with G.
    qf = q.transpose(0, 2, 3, 1, 4).reshape(BHG, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, hd)

    vec = _is_vec(g_qk) or _is_vec(g_pv)
    qk_bits = int(qk_pack.get("bits", 8))
    pv_bits = int(pv_pack.get("bits", 8))
    if vec:
        # Per-slot group vectors: one kernel call for the whole
        # mixed-timestep batch. q rows are slot-major after the transpose
        # (slot b owns Hk*G consecutive batch rows), so the per-slot
        # vector repeats Hk*G times; a scalar sibling pack (G=1) rides
        # along as a constant vector (bit-identical to scalar prefetch).
        gq = jnp.repeat(_as_vec(g_qk, B), Hk * G)              # (BHG,)
        gp = jnp.repeat(_as_vec(g_pv, B), Hk * G)              # (BHG,)
        scores = int8_bmm_qk_vec(
            qf, kf, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * jnp.float32(scale), gv=gq,
            bits=qk_bits, interpret=INTERPRET)
    else:
        scores = int8_bmm_qk(
            qf, kf, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * jnp.float32(scale), g=g_qk,
            bits=qk_bits, interpret=INTERPRET)
    scores = scores.reshape(B, Hk, G, Sq, Skv)
    if mask is not None:
        from repro.nn.ctx import NEG_INF
        scores = jnp.where(mask, scores, NEG_INF)

    if vec:
        rows_gv = jnp.broadcast_to(
            _as_vec(g_pv, B)[:, None, None, None], (B, Hk, G, Sq))
        codes = softmax_mrq_codes_vec(scores, pv_pack["s1"], gv=rows_gv,
                                      bits=pv_bits, interpret=INTERPRET)
        out = int8_bmm_pv_vec(
            codes.reshape(BHG, Sq, Skv), vf, pv_pack["s_v"],
            pv_pack["scale1"], pv_pack["scale2"], gv=gp, bits=pv_bits,
            out_dtype=out_dtype, interpret=INTERPRET)
    else:
        codes = softmax_mrq_codes(scores, pv_pack["s1"], g=g_pv,
                                  bits=pv_bits, interpret=INTERPRET)
        out = int8_bmm_pv(
            codes.reshape(BHG, Sq, Skv), vf, pv_pack["s_v"],
            pv_pack["scale1"], pv_pack["scale2"], g=g_pv, bits=pv_bits,
            out_dtype=out_dtype, interpret=INTERPRET)
    return out.reshape(B, Hk, G, Sq, hd).transpose(0, 3, 1, 2, 4)


def flash_attention(q, k, v, qk_pack: dict, pv_pack: dict, *, mask=None,
                    scale=1.0, tgroup=None, out_dtype=None):
    """Flash-style int8 grouped SDPA: ONE kernel per (batch·head, q-tile),
    no (S, S) scores/codes HBM round-trip.

    Same contract and packs as :func:`int8_attention` (which remains the
    composed three-kernel exactness oracle — ``attn_impl="composed"``):
    q: (B, Sq, Hk, G, hd); k, v: (B, Skv, Hk, hd); mask broadcastable to
    (B, Hk, G, Sq, Skv) boolean or None; ``scale`` folded into the QK^T
    dequant scale. The two pack sides resolve their TGQ groups
    independently (different group counts allowed) and both indices ride
    one scalar-prefetch vector, so the surrounding ``ddpm_sample`` scan
    still compiles once. Flash ≡ composed within
    ``ref.flash_vs_composed_atol`` (the online-rescale rounding
    contract); kv tiles stream with NEG_INF lane masking applied before
    the online max, so ragged Skv (e.g. S = 77) is exact.
    """
    out_dtype = out_dtype or q.dtype
    B, Sq, Hk, G, hd = q.shape
    Skv = k.shape[1]
    BHG = B * Hk * G
    g_qk = _group_index(qk_pack, tgroup)
    g_pv = _group_index(pv_pack, tgroup)

    qf = q.transpose(0, 2, 3, 1, 4).reshape(BHG, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hk, Skv, hd)
    mf = None
    if mask is not None:
        mf = jnp.broadcast_to(mask, (B, Hk, G, Sq, Skv)
                              ).reshape(BHG, Sq, Skv)

    bits = int(qk_pack.get("bits", 8))
    if _is_vec(g_qk) or _is_vec(g_pv):
        # Vector-tgroup batched path: slot-major (BHG,) group vectors,
        # one flash call for the whole mixed-timestep batch (weights and
        # kv stream once; each batch row's params gather from the full
        # (G, ·) stacks via the per-row prefetch index maps).
        out = flash_attn_mrq_vec(
            qf, kf, vf, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * jnp.float32(scale), pv_pack["s1"],
            pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
            g_qk=jnp.repeat(_as_vec(g_qk, B), Hk * G),
            g_pv=jnp.repeat(_as_vec(g_pv, B), Hk * G),
            mask=mf, bits=bits, packed_kv=(bits == 4),
            out_dtype=out_dtype, interpret=INTERPRET)
    else:
        out = flash_attn_mrq(
            qf, kf, vf, qk_pack["s_q"], qk_pack["s_k"],
            qk_pack["scale"] * jnp.float32(scale), pv_pack["s1"],
            pv_pack["s_v"], pv_pack["scale1"], pv_pack["scale2"],
            g_qk=g_qk, g_pv=g_pv, mask=mf, bits=bits,
            packed_kv=(bits == 4), out_dtype=out_dtype,
            interpret=INTERPRET)
    return out.reshape(B, Hk, G, Sq, hd).transpose(0, 3, 1, 2, 4)


# ---------------------------------------------------------------------------
# fused activation kernels (public API)
# ---------------------------------------------------------------------------
def softmax_mrq_op(scores, s1, bits: int = 8, out_dtype=jnp.float32):
    return softmax_mrq(scores, s1, bits=bits, out_dtype=out_dtype,
                       interpret=INTERPRET)


def act_mrq_op(x, s_neg, s_pos, bits: int = 8, kind: str = "gelu",
               out_dtype=jnp.float32):
    return act_mrq(x, s_neg, s_pos, bits=bits, kind=kind, out_dtype=out_dtype,
                   interpret=INTERPRET)
