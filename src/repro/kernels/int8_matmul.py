"""W8A8 int8 matmul Pallas kernel with fused dequant epilogue.

TPU mapping of the paper's int8 inference path: the MXU consumes s8xs8
tiles accumulating in s32 VREGs; the epilogue applies the zero-point
correction, the combined per-output-channel scale (s_x * s_w), and the
bias — so the dequantized tile is written to HBM exactly once (no
separate dequant kernel as in the CUDA reference flow).

Tiling: grid (M/bm, N/bn, K/bk), k innermost. x tile (bm,bk) and w tile
(bk,bn) stream through VMEM; the (bm,bn) s32 accumulator lives in VMEM
scratch. Block dims default to MXU-aligned multiples of 128 (bm 128,
bn 128, bk 256 -> ~160KB VMEM working set, well under the ~16MB/core
budget, leaving room for double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _kernel(x_ref, w_ref, scale_ref, corr_ref, bias_ref, o_ref, acc_ref, *,
            nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...] - corr_ref[...]            # zero-point correction
        y = acc.astype(jnp.float32) * scale_ref[...]
        y = y + bias_ref[...]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def int8_matmul(xq, wq, scale, corr, bias=None, *, bm=DEFAULT_BM,
                bn=DEFAULT_BN, bk=DEFAULT_BK, out_dtype=jnp.float32,
                interpret=False):
    """y[M,N] = (xq @ wq - corr) * scale (+ bias).

    xq: (M,K) int8, wq: (K,N) int8, scale: (N,) f32 (s_x*s_w per channel),
    corr: (N,) int32 (z_eff * colsum(wq)), bias: (N,) f32 or None.
    Shapes need not be block-aligned; inputs are zero-padded (int8 zero
    pads contribute zx*0 handled inside corr of the REAL columns only —
    padding columns are sliced away).
    """
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2, (xq.shape, wq.shape)
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(K))
    Mp, Np, Kp = _pad_to(M, bm_), _pad_to(N, bn_), _pad_to(K, bk_)

    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    xq = jnp.pad(xq, ((0, Mp - M), (0, Kp - K)))
    wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    scale = jnp.pad(scale.astype(jnp.float32), (0, Np - N)).reshape(1, Np)
    corr = jnp.pad(corr.astype(jnp.int32), (0, Np - N)).reshape(1, Np)
    bias = jnp.pad(bias.astype(jnp.float32), (0, Np - N)).reshape(1, Np)

    nk = Kp // bk_
    grid = (Mp // bm_, Np // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk_, bn_), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(xq, wq, scale, corr, bias)
    return out[:M, :N]


def _ceil(x, to=8):
    return max(to, -to * (-x // to))


def _pad_to(x, b):
    return -b * (-x // b)
