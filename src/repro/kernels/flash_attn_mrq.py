"""Flash-style fused int8 MRQ attention: QK^T -> online softmax -> MRQ
prob codes -> P·V in ONE Pallas kernel — the (S, S) scores and prob-code
tensors never touch HBM.

The composed int8 attention path (``int8_bmm_qk`` -> ``softmax_mrq_codes``
-> ``int8_bmm_pv``) serves fully int8 but still round-trips the full
(BH, S, S) f32 scores and int8 prob codes through HBM — the dominant
remaining attention traffic. This kernel streams K/V tiles per
(batch·head, q-tile) grid point and keeps the whole quadratic
intermediate in VMEM:

1. **int8 QK^T** — the q and k tiles are quantized with the group-``g``
   symmetric per-tensor steps in the VMEM prologue (same ``SymQ``
   contract as ``int8_bmm_qk``); the s32 MXU product dequantizes with the
   combined ``s_q[g]·s_k[g]·alpha`` scale into an f32 (bm, bn) score tile
   that never leaves VMEM.
2. **Ragged / user masking BEFORE the online max** — kv lanes past the
   true sequence length (S not a multiple of the k-tile) and user-masked
   lanes are set to ``NEG_INF`` *before* the running-max update.
   Unmasked, a padded lane's int8 score of exactly 0 would win the row
   max whenever the real scores are negative and poison both the max and
   the denominator (``exp(NEG_INF - m)`` underflows to exactly 0.0 in
   f32, so masked lanes contribute nothing downstream).
3. **Online softmax** — running row max ``m`` and denominator ``l`` in
   VMEM scratch, the standard flash recurrence
   ``m' = max(m, rowmax(s))``, ``l' = l·exp(m - m') + rowsum(exp(s - m'))``.
4. **MRQ two-region prob codes per tile** — the paper's §III-C
   post-softmax quantizer, applied to the tile's *running-normalized*
   probability estimate ``p̃ = exp(s - m')/l'`` against the calibrated
   per-group region-1 step ``s1[g]``: region 1 (fine step ``s1``) where
   ``p̃ < 2^{k-1}·s1``, region 2 (coarse step ``s2 = 1/2^{k-1}``) above.
   The two disjoint region-magnitude tiles are exactly the operands the
   composed path transports as region-signed bytes — here they are formed
   and consumed inside VMEM.
5. **Dual-region P·V with fp running-rescale** — each region tile
   multiplies the in-VMEM-quantized v tile on the MXU into an s32
   product, accumulated into two f32 region accumulators with the flash
   rescale ``rho = exp(m - m')·l/l'`` applied to the previously
   accumulated contributions. Because ``p̃·(Π rho) == exp(s - m_fin)/l_fin``
   exactly in real arithmetic, the only divergence from the composed
   path is that each tile's codes ROUND against the running normalization
   instead of the final one — the rescale then shrinks that (already
   ≤ step/2) rounding error by ``Π rho <= 1``. See
   ``ref.flash_vs_composed_atol`` for the documented tolerance contract.
6. **Epilogue** — ``out = scale1[g]·acc1 + scale2[g]·acc2`` with
   ``scale1 = s1[g]·s_v[g]``, ``scale2 = s2·s_v[g]`` (the ``int8_bmm_pv``
   epilogue scales), written to HBM exactly once.

TGQ exactly as in the composed kernels: every activation-side parameter
is stacked along a leading (G,) group axis and the timestep groups — a
``(2,)`` i32 vector ``[g_qk, g_pv]``, possibly traced inside the
``ddpm_sample`` lax.scan — are scalar-prefetched; the BlockSpec index
maps gather the per-group rows, so the whole sampling loop stays ONE
compiled executable (the qk-side and pv-side packs may carry different
group counts — each side clamps its own index).

GQA as in ``int8_bmm``: the q-side batch may be ``rep`` times the
k/v-side batch; the shared kv tile is gathered via a ``b // rep`` index
map — no materialized copies, and kv HBM traffic does not scale with the
number of query groups.

Traffic: q is read from HBM once in fp, the output written once, and
the K/V stream is re-fetched once per q-tile (the standard flash trade:
``ceil(M/bm)`` reads each — exactly ONE at DiT-serving sequence lengths,
since the default q-tile ``bm = 256`` covers DiT-XL/2's S = 256). The
(S, S) scores/codes round-trip — ``BH·S²·10`` bytes on the composed
path — is eliminated entirely: ≥3x whole-attention traffic cut at
DiT-XL/2 shapes (``benchmarks/kernel_micro.py::traffic_attention_flash``
charges the kv re-reads honestly).

Grid: (B, M/bm, N/bn) with the kv axis innermost; the running stats and
both accumulators live in VMEM scratch persisting across the kv axis.
The optional boolean mask streams as int8 0/1 tiles (1 byte/elt — still
no fp quadratic tensor through HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.int4_packed import nibble_split, pack_int4
from repro.kernels.int8_bmm import _sym_codes
from repro.kernels.int8_matmul import _ceil, _pad_to

# q-tile covers DiT-XL/2's full S = 256, so K/V stream from HBM exactly
# once there; VMEM stays small (q/acc1/acc2 tiles: 3 x 256 x hd f32).
DEFAULT_BM = 256
DEFAULT_BN = 128
_M_INIT = -1e30         # below any masked score; exp(_M_INIT - m) == 0.0


def _flash_kernel(g_ref, *refs, nkv: int, half: int, n_real: int, bn: int,
                  neg_inf: float, has_mask: bool, packed_kv: bool = False,
                  bd: int = 0):
    """Grid body at (b, m, n) — n (the kv tile) innermost.

    ``refs`` unpacks to the tile refs (q, k, v[, mask8]), the group-``g``
    rows of the stacked (G, 1) params (s_q, s_k, qk_scale, s1, s_v,
    scale1, scale2), the output ref and the four VMEM scratch refs
    (running max / denominator as (bm, 128) lane-broadcast stats, two
    (bm, D) f32 region accumulators). ``g_ref`` ([g_qk, g_pv]) feeds the
    index maps only.

    ``packed_kv``: k/v tiles arrive as (bn, bd/2) nibble-PACKED
    pre-quantized 4-bit codes (the W4A4 path's one-time pack pass) and
    are widened to s8-range codes here instead of running ``_sym_codes``
    — halving the kv bytes streamed per q-tile.
    """
    del g_ref
    if has_mask:
        (q_ref, k_ref, v_ref, mask_ref, sq_ref, sk_ref, qs_ref, s1_ref,
         sv_ref, sc1_ref, sc2_ref, o_ref, m_ref, l_ref, acc1_ref,
         acc2_ref) = refs
    else:
        (q_ref, k_ref, v_ref, sq_ref, sk_ref, qs_ref, s1_ref, sv_ref,
         sc1_ref, sc2_ref, o_ref, m_ref, l_ref, acc1_ref, acc2_ref) = refs
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _M_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    # -- int8 QK^T for this tile (scores stay in VMEM) ----------------------
    q8 = _sym_codes(q_ref[0], sq_ref[0, 0], half)
    if packed_kv:                # widen two-nibbles-per-byte codes in VMEM
        lo, hi = nibble_split(k_ref[0])
        k8 = jnp.stack([lo, hi], axis=2).reshape(k_ref.shape[1], bd)
    else:
        k8 = _sym_codes(k_ref[0], sk_ref[0, 0], half).astype(jnp.int32)
    s = jax.lax.dot_general(
        q8.astype(jnp.int32), k8,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32) * qs_ref[0, 0]

    # -- NEG_INF masking BEFORE the online max ------------------------------
    # Ragged kv: lanes past the true length get the additive mask now —
    # a padded lane's exact-0 int8 score must never enter the running max
    # or denominator.
    col = n * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < n_real, s, neg_inf)
    if has_mask:
        s = jnp.where(mask_ref[0] != 0, s, neg_inf)

    # -- online softmax update ----------------------------------------------
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    e = jnp.exp(s - m_new)                           # (bm, bn)
    corr = jnp.exp(m_prev - m_new)                   # (bm, 1)
    l_new = l_prev * corr + jnp.sum(e, axis=-1, keepdims=True)

    # -- MRQ two-region codes against the running normalization -------------
    p = e / l_new
    s1 = s1_ref[0, 0]
    s2 = 1.0 / half
    region1 = p < half * s1
    c1 = jnp.where(region1, jnp.clip(jnp.round(p / s1), 0, half - 1), 0.0
                   ).astype(jnp.int32)
    c2 = jnp.where(region1, 0.0, jnp.clip(jnp.round(p / s2), 0, half)
                   ).astype(jnp.int32)

    # -- dual-region P·V with fp running-rescale ----------------------------
    if packed_kv:
        lo_v, hi_v = nibble_split(v_ref[0])
        v8 = jnp.stack([lo_v, hi_v], axis=2).reshape(v_ref.shape[1], bd)
    else:
        v8 = _sym_codes(v_ref[0], sv_ref[0, 0], half).astype(jnp.int32)
    dims = (((1,), (0,)), ((), ()))                  # ONE v-tile read
    d1 = jax.lax.dot_general(c1, v8, dims, preferred_element_type=jnp.int32)
    d2 = jax.lax.dot_general(c2, v8, dims, preferred_element_type=jnp.int32)
    rho = corr * l_prev / l_new                      # <= 1; 0 at n == 0
    acc1_ref[...] = acc1_ref[...] * rho + d1.astype(jnp.float32)
    acc2_ref[...] = acc2_ref[...] * rho + d2.astype(jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(n == nkv - 1)
    def _epilogue():
        y = acc1_ref[...] * sc1_ref[0, 0] + acc2_ref[...] * sc2_ref[0, 0]
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "packed_kv", "bm", "bn",
                                             "out_dtype", "interpret"))
def flash_attn_mrq(q, k, v, s_q, s_k, qk_scale, s1, s_v, scale1, scale2,
                   g_qk=None, g_pv=None, mask=None, *, bits=8,
                   packed_kv=False, bm=DEFAULT_BM, bn=DEFAULT_BN,
                   out_dtype=jnp.float32, interpret=False):
    """out[B,M,D] = MRQ-quantized softmax(q8 k8^T · qk_scale[g]) @ v8 —
    one kernel, no (S, S) HBM round-trip.

    q: (B, M, D) float; k, v: (Bk, N, D) float with B = rep · Bk (GQA —
    the shared kv head is gathered via a ``b // rep`` index map).
    s_q/s_k: (Gq, 1) f32 symmetric steps; qk_scale: (Gq, 1) combined
    ``s_q[g]·s_k[g]·alpha`` (alpha = the softmax scale, folded by the
    caller). s1/s_v/scale1/scale2: (Gp, 1) f32 — the ``int8_pv`` pack
    params (``scale1 = s1·s_v``, ``scale2 = s2·s_v``). g_qk / g_pv: the
    TGQ groups for each pack side — python ints or traced scalars
    (scalar-prefetched together; no retrace across groups). mask:
    optional (B, M, N) boolean (True = attend), streamed as int8 tiles.

    ``packed_kv`` (4-bit only): k/v are quantized with the group-g steps
    and nibble-packed along D in ONE jnp pre-pass; the kernel then
    streams half the kv bytes per q-tile and widens nibbles in its
    prologue. The trade is honest: the pack pass reads kv in fp and
    writes the packed codes once, so it wins when kv is re-streamed
    (ceil(M/bm) > 1, long S) and is neutral at one q-tile — see
    ``benchmarks/kernel_micro.traffic_attention_flash_packed``.
    Numerics are IDENTICAL to the unpacked 4-bit path (same symmetric
    codes, formed once instead of per tile), so the same oracle and
    flash-vs-composed tolerance contract apply.
    """
    B, M, D = q.shape
    B2, N, D2 = k.shape
    assert D == D2 and k.shape == v.shape and B % B2 == 0, \
        (q.shape, k.shape, v.shape)
    rep = B // B2
    Gq, Gp = s_q.shape[0], s1.shape[0]
    assert s_k.shape == (Gq, 1) and qk_scale.shape == (Gq, 1), \
        (s_q.shape, s_k.shape, qk_scale.shape)
    assert s_v.shape == (Gp, 1) and scale1.shape == (Gp, 1) \
        and scale2.shape == (Gp, 1), (s1.shape, s_v.shape)
    half = 2 ** (bits - 1)
    bm_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(N))
    bd_ = _ceil(D)
    Mp, Np = _pad_to(M, bm_), _pad_to(N, bn_)

    g = jnp.stack([jnp.asarray(0 if g_qk is None else g_qk, jnp.int32),
                   jnp.asarray(0 if g_pv is None else g_pv, jnp.int32)])
    q = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, bd_ - D)))
    k = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, Np - N), (0, bd_ - D)))
    v = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, Np - N), (0, bd_ - D)))

    kv_bd = bd_
    if packed_kv:
        assert bits == 4, "packed_kv streams nibbles: 4-bit codes only"
        # one-time quantize+pack pass (jnp): group-g symmetric codes,
        # two per byte along D. Padded lanes/dims are code 0 — inert.
        sk_g = jnp.take(s_k.astype(jnp.float32), g[0], axis=0)[0]
        sv_g = jnp.take(s_v.astype(jnp.float32), g[1], axis=0)[0]
        k = pack_int4(_sym_codes(k, sk_g, half), axis=-1)
        v = pack_int4(_sym_codes(v, sv_g, half), axis=-1)
        kv_bd = bd_ // 2

    has_mask = mask is not None
    operands = [q, k, v]
    in_specs = [
        pl.BlockSpec((1, bm_, bd_), lambda b, m, n, g: (b, m, 0)),
        pl.BlockSpec((1, bn_, kv_bd),
                     lambda b, m, n, g: (b // rep, n, 0)),   # shared kv
        pl.BlockSpec((1, bn_, kv_bd),
                     lambda b, m, n, g: (b // rep, n, 0)),   # shared kv
    ]
    if has_mask:
        assert mask.shape == (B, M, N), (mask.shape, (B, M, N))
        mask8 = jnp.pad(mask.astype(jnp.int8),
                        ((0, 0), (0, Mp - M), (0, Np - N)))
        operands.append(mask8)
        in_specs.append(
            pl.BlockSpec((1, bm_, bn_), lambda b, m, n, g: (b, m, n)))
    qk_row = lambda b, m, n, g: (g[0], 0)                    # qk-side group
    pv_row = lambda b, m, n, g: (g[1], 0)                    # pv-side group
    operands += [s_q.astype(jnp.float32), s_k.astype(jnp.float32),
                 qk_scale.astype(jnp.float32), s1.astype(jnp.float32),
                 s_v.astype(jnp.float32), scale1.astype(jnp.float32),
                 scale2.astype(jnp.float32)]
    in_specs += [pl.BlockSpec((1, 1), qk_row)] * 3 \
        + [pl.BlockSpec((1, 1), pv_row)] * 4

    # the one masking value, shared with the composed path and the oracle
    # (deferred import: repro.nn pulls in model layers at package init)
    from repro.nn.ctx import NEG_INF

    nkv = Np // bn_
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Mp // bm_, nkv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm_, bd_), lambda b, m, n, g: (b, m, 0)),
        scratch_shapes=[pltpu.VMEM((bm_, 128), jnp.float32),   # running max
                        pltpu.VMEM((bm_, 128), jnp.float32),   # running denom
                        pltpu.VMEM((bm_, bd_), jnp.float32),   # region-1 acc
                        pltpu.VMEM((bm_, bd_), jnp.float32)],  # region-2 acc
    )
    out = pl.pallas_call(
        functools.partial(_flash_kernel, nkv=nkv, half=half, n_real=N,
                          bn=bn_, neg_inf=NEG_INF, has_mask=has_mask,
                          packed_kv=packed_kv, bd=bd_),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Mp, bd_), out_dtype),
        interpret=interpret,
    )(g, *operands)
    return out[:, :M, :D]


@functools.partial(jax.jit, static_argnames=("bits", "packed_kv", "bm", "bn",
                                             "out_dtype", "interpret"))
def flash_attn_mrq_vec(q, k, v, s_q, s_k, qk_scale, s1, s_v, scale1, scale2,
                       g_qk=None, g_pv=None, mask=None, *, bits=8,
                       packed_kv=False, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       out_dtype=jnp.float32, interpret=False):
    """Vector-tgroup ``flash_attn_mrq``: per-BATCH-ROW group vectors.

    g_qk / g_pv: (B,) int32 — batch row ``b`` runs with its own groups'
    params. The kernel BODY is ``_flash_kernel`` unchanged; only the
    prefetch layout differs — the two vectors ride concatenated as one
    (2B,) prefetched array and the param index maps pick ``(g[b], 0)`` /
    ``(g[B + b], 0)``, so each grid row DMAs exactly its group's (1, 1)
    param rows (the per-group gather stays in the index maps; weights —
    here the kv stream — are untouched by the group mix). Constant
    vectors are bit-identical to scalar ``g_qk``/``g_pv``.

    GQA: q rows sharing a kv row (``b // rep``) must share a group —
    true by construction when rows are slots (``ops.flash_attention``
    repeats each slot's group over its heads/query-groups); ``packed_kv``
    uses kv row ``j``'s group ``g[j * rep]`` for the one-time pack pass.
    """
    B, M, D = q.shape
    B2, N, D2 = k.shape
    assert D == D2 and k.shape == v.shape and B % B2 == 0, \
        (q.shape, k.shape, v.shape)
    rep = B // B2
    Gq, Gp = s_q.shape[0], s1.shape[0]
    assert s_k.shape == (Gq, 1) and qk_scale.shape == (Gq, 1), \
        (s_q.shape, s_k.shape, qk_scale.shape)
    assert s_v.shape == (Gp, 1) and scale1.shape == (Gp, 1) \
        and scale2.shape == (Gp, 1), (s1.shape, s_v.shape)
    half = 2 ** (bits - 1)
    bm_, bn_ = min(bm, _ceil(M)), min(bn, _ceil(N))
    bd_ = _ceil(D)
    Mp, Np = _pad_to(M, bm_), _pad_to(N, bn_)

    gqk = (jnp.zeros((B,), jnp.int32) if g_qk is None
           else jnp.asarray(g_qk, jnp.int32).reshape(B))
    gpv = (jnp.zeros((B,), jnp.int32) if g_pv is None
           else jnp.asarray(g_pv, jnp.int32).reshape(B))
    g = jnp.concatenate([gqk, gpv])                          # (2B,)
    q = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, bd_ - D)))
    k = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, Np - N), (0, bd_ - D)))
    v = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, Np - N), (0, bd_ - D)))

    kv_bd = bd_
    if packed_kv:
        assert bits == 4, "packed_kv streams nibbles: 4-bit codes only"
        # one-time quantize+pack pass with PER-KV-ROW group steps: kv row
        # j serves q rows [j*rep, (j+1)*rep) which share a group (slots),
        # so row j packs with g[j*rep]'s step.
        gk_kv = gqk.reshape(B2, rep)[:, 0]
        gp_kv = gpv.reshape(B2, rep)[:, 0]
        sk_g = jnp.take(s_k.astype(jnp.float32), gk_kv, axis=0)[:, :, None]
        sv_g = jnp.take(s_v.astype(jnp.float32), gp_kv, axis=0)[:, :, None]
        k = pack_int4(_sym_codes(k, sk_g, half), axis=-1)
        v = pack_int4(_sym_codes(v, sv_g, half), axis=-1)
        kv_bd = bd_ // 2

    has_mask = mask is not None
    operands = [q, k, v]
    in_specs = [
        pl.BlockSpec((1, bm_, bd_), lambda b, m, n, g: (b, m, 0)),
        pl.BlockSpec((1, bn_, kv_bd),
                     lambda b, m, n, g: (b // rep, n, 0)),   # shared kv
        pl.BlockSpec((1, bn_, kv_bd),
                     lambda b, m, n, g: (b // rep, n, 0)),   # shared kv
    ]
    if has_mask:
        assert mask.shape == (B, M, N), (mask.shape, (B, M, N))
        mask8 = jnp.pad(mask.astype(jnp.int8),
                        ((0, 0), (0, Mp - M), (0, Np - N)))
        operands.append(mask8)
        in_specs.append(
            pl.BlockSpec((1, bm_, bn_), lambda b, m, n, g: (b, m, n)))
    qk_row = lambda b, m, n, g: (g[b], 0)                # row b's qk group
    pv_row = lambda b, m, n, g: (g[B + b], 0)            # row b's pv group
    operands += [s_q.astype(jnp.float32), s_k.astype(jnp.float32),
                 qk_scale.astype(jnp.float32), s1.astype(jnp.float32),
                 s_v.astype(jnp.float32), scale1.astype(jnp.float32),
                 scale2.astype(jnp.float32)]
    in_specs += [pl.BlockSpec((1, 1), qk_row)] * 3 \
        + [pl.BlockSpec((1, 1), pv_row)] * 4

    from repro.nn.ctx import NEG_INF

    nkv = Np // bn_
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Mp // bm_, nkv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm_, bd_), lambda b, m, n, g: (b, m, 0)),
        scratch_shapes=[pltpu.VMEM((bm_, 128), jnp.float32),   # running max
                        pltpu.VMEM((bm_, 128), jnp.float32),   # running denom
                        pltpu.VMEM((bm_, bd_), jnp.float32),   # region-1 acc
                        pltpu.VMEM((bm_, bd_), jnp.float32)],  # region-2 acc
    )
    out = pl.pallas_call(
        functools.partial(_flash_kernel, nkv=nkv, half=half, n_real=N,
                          bn=bn_, neg_inf=NEG_INF, has_mask=has_mask,
                          packed_kv=packed_kv, bd=bd_),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Mp, bd_), out_dtype),
        interpret=interpret,
    )(g, *operands)
    return out[:, :M, :D]
