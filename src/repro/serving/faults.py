"""Deterministic fault injection + graceful degradation for the async engine.

Chaos testing a compiled-sampler service needs faults that are (a)
deterministic — the retry-determinism contract is "bit-identical to the
uninjected run", which is unverifiable against random faults — and (b)
injected at the same seams real faults hit: poisoned latents after a
chunk, dispatch-time executable failures, wall-clock stalls. The
:class:`FaultInjector` sits on exactly those seams inside
``AsyncServeEngine.pump``; production engines run with ``injector=None``
and pay one ``is None`` check per seam.

The degradation ladder (:func:`degrade_context`) is the engine-fault
response: when a dispatch raises, the engine steps the op context down one
rung — fused flash attention -> the composed three-kernel chain -> fake
quant (no Pallas at all) — rebuilds the chunk executable, and retries the
SAME chunk (slot state is only mutated after a successful blocking read,
so a failed dispatch is side-effect free). Each rung trades speed for a
smaller trusted surface; each step is logged with a reason in
``engine.stats['degradations']``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


class EngineFault(RuntimeError):
    """Raised when a dispatch keeps failing after the degradation ladder is
    exhausted — the engine cannot make progress on ANY context."""


class FaultInjected(RuntimeError):
    """An injected dispatch/slot failure (chaos tests only)."""


class FakeClock:
    """Injectable monotonic clock — deadline/stall tests advance time
    explicitly instead of sleeping (deterministic, instant)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def advance(self, dt: float) -> None:
        self.t += float(dt)

    def __call__(self) -> float:
        return self.t


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    kind:
      'nan'            poison request ``request_id``'s latent when its scan
                       position crosses ``at_step`` (a NaN burst mid-chain);
                       ``sticky`` re-fires on every retry (unrecoverable).
      'slot_error'     like 'nan' but modelling a non-numeric per-slot
                       failure (bad DMA, corrupt slot state).
      'dispatch_error' raise FaultInjected out of dispatch number
                       ``at_dispatch`` — exercises the degradation ladder.
      'stall'          advance the engine clock by ``seconds`` before
                       dispatch ``at_dispatch`` — exercises deadlines
                       (with a FakeClock; never sleeps).
    """
    kind: str
    request_id: Optional[int] = None
    at_step: int = 0
    at_dispatch: Optional[int] = None
    sticky: bool = False
    seconds: float = 0.0


class FaultInjector:
    """Deterministic schedule of faults, consumed as the engine hits the
    matching seams. ``fired`` logs ``(dispatch_idx, fault)`` for assertions.
    """

    def __init__(self, faults: List[Fault], clock: Optional[FakeClock] = None):
        self.pending = list(faults)
        self.clock = clock
        self.fired: List[Tuple[int, Fault]] = []

    def _take(self, pred) -> Optional[Fault]:
        for i, f in enumerate(self.pending):
            if pred(f):
                if not f.sticky:
                    self.pending.pop(i)
                return f
        return None

    def before_dispatch(self, dispatch_idx: int) -> None:
        """Dispatch seam: stalls advance the fake clock, dispatch errors
        raise (the engine's ladder catches them)."""
        st = self._take(lambda f: f.kind == "stall"
                        and f.at_dispatch == dispatch_idx)
        if st is not None:
            self.fired.append((dispatch_idx, st))
            if self.clock is None:
                raise ValueError("stall fault needs a FakeClock")
            self.clock.advance(st.seconds)
        de = self._take(lambda f: f.kind == "dispatch_error"
                        and (f.at_dispatch is None
                             or f.at_dispatch == dispatch_idx))
        if de is not None:
            self.fired.append((dispatch_idx, de))
            raise FaultInjected(
                f"injected dispatch error at dispatch {dispatch_idx}")

    def poison(self, dispatch_idx: int, request_id: int, pos_before: int,
               pos_after: int) -> Optional[Fault]:
        """Post-chunk seam: returns the fault poisoning ``request_id`` if
        its scan position crossed ``at_step`` in this chunk."""
        f = self._take(lambda f: f.kind in ("nan", "slot_error")
                       and f.request_id == request_id
                       and pos_before <= f.at_step < pos_after)
        if f is not None:
            self.fired.append((dispatch_idx, f))
        return f


def degrade_context(ctx) -> Optional[Tuple[object, str]]:
    """One rung down the ladder, or None when already at the bottom.

    flash attn -> composed three-kernel chain -> fake-quant (kernel=False).
    Only meaningful for kernel-path QuantContexts; fp / fake-quant contexts
    have no rung below them.
    """
    kernel = getattr(ctx, "kernel", False)
    if not kernel:
        return None
    if getattr(ctx, "attn_impl", None) == "flash":
        return (dataclasses.replace(ctx, attn_impl="composed"),
                "flash attention -> composed three-kernel chain")
    return (dataclasses.replace(ctx, kernel=False),
            "fused int8 kernels -> fake-quant (simulated quantization)")
