"""The serving engine: compiled, sharded microbatch execution.

One :class:`ServeEngine` owns

- the model (params + DiTCfg) and the execution context — ``FPContext``
  for fp32, a fake-quant ``QuantContext`` for fidelity serving, or
  ``QuantContext(kernel=True)`` with int8-packed qparams for the fused
  Pallas deployment path,
- the diffusion setup (``DiffusionCfg`` + schedule),
- a data-parallel mesh: the paired sampler is wrapped in ``shard_map``
  with params replicated (``P()``) and every per-request array sharded on
  the DP super-axis (``repro.distributed.request_spec``). The model
  forward has no cross-sample communication, so serving scales linearly
  across the "data" axis and each device runs the SAME executable a
  single-device engine would — bit-identical samples either way
  (``benchmarks/serve_throughput.py`` asserts this).
- a cache of compiled executables, one per step bucket. TGQ group
  selection happens inside the fused kernels (scalar-prefetched group
  index), so all timestep groups share one executable; only a new step
  bucket triggers a compile. With int8-packed qparams the executable
  contains the WHOLE quantized block: fused int8 linears AND the int8
  attention path — by default ONE flash-style kernel per block
  (``kernels.flash_attn_mrq``: int8 QK^T -> online softmax -> MRQ codes
  -> P·V, the (S,S) scores/codes never touching HBM), or the composed
  three-kernel chain under ``attn_impl="composed"`` — so the DDPM scan
  stays one compiled program with no fp attention island inside.

``check_rep=False`` on the shard_map is required: pallas_call has no
replication rule, and the body is embarrassingly data-parallel anyway.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.diffusion import DiffusionCfg, ddpm_sample_paired, make_schedule
from repro.diffusion.ddpm import (
    ddpm_chunk_slots, ddpm_init_latent, make_slot_schedule,
)
from repro.distributed import batch_spec, dp_size, replicated, request_spec
from repro.models import DiTCfg, dit_apply
from repro.nn.ctx import FPContext
from repro.serving import lifecycle as lc
from repro.serving.batching import (
    DEFAULT_STEP_BUCKETS, GenRequest, GenResult, MicroBatch, bucket_steps,
    coalesce,
)
from repro.serving.faults import EngineFault, degrade_context
from repro.serving.scheduler import validate_label


class ServeEngine:
    """Executes fixed-shape microbatches of DiT generation requests.

    Parameters
    ----------
    params, dcfg : the DiT model.
    dif, sched   : diffusion config + schedule (sched built if omitted).
    ctx          : op context (default fp32). Pass a quantization
                   artifact's ``artifact.context()`` for the fused-int8
                   serving path — or build the whole engine with
                   :meth:`from_artifact`.
    mesh         : data-parallel mesh (``make_serving_mesh()``). None runs
                   un-sharded on the default device.
    microbatch   : slots per microbatch; must divide by the mesh's DP size.
    step_buckets : allowed scan lengths (compile keys).
    """

    def __init__(self, params, dcfg: DiTCfg, dif: DiffusionCfg,
                 sched=None, *, ctx=None, mesh: Optional[Mesh] = None,
                 microbatch: int = 8,
                 step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS,
                 clip_x0: Optional[float] = None):
        self.dcfg = dcfg
        self.dif = dif
        self.sched = sched if sched is not None else make_schedule(dif)
        self.ctx = ctx if ctx is not None else FPContext()
        self.mesh = mesh
        self.microbatch = int(microbatch)
        self.step_buckets = tuple(sorted(int(b) for b in step_buckets))
        self.clip_x0 = clip_x0
        if mesh is not None:
            nd = dp_size(mesh)
            if self.microbatch % nd != 0:
                raise ValueError(
                    f"microbatch {self.microbatch} not divisible by the "
                    f"mesh's {nd} data-parallel shards")
            params = jax.device_put(params, replicated(mesh))
        self.params = params
        self._fns: Dict[int, Any] = {}          # step bucket -> compiled fn
        self.stats: Dict[str, Any] = {
            "compiled_buckets": [], "microbatches": 0, "requests": 0,
            "padded_slots": 0, "wall_s": 0.0,
        }

    @classmethod
    def from_artifact(cls, params, artifact, *, kernel=None,
                      attn_impl: Optional[str] = None, sched=None,
                      mesh: Optional[Mesh] = None, microbatch: int = 8,
                      step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS,
                      clip_x0: Optional[float] = None) -> "ServeEngine":
        """Quantized engine straight from a ``repro.quant.QuantArtifact``
        — the cold-start path: ``QuantArtifact.load(path)`` then this, no
        calibration in the serving process.

        The artifact supplies the model/diffusion configs and the op
        context (``artifact.context(kernel=..., attn_impl=...)``: fused
        int8 kernels when packs exist, fake-quant otherwise;
        ``attn_impl=None`` serves the attention lowering the artifact's
        recipe records — 'flash' single-kernel by default, 'composed'
        for the three-kernel oracle); ``params`` are the fp model
        weights (artifacts carry quantizer state and int8 weight codes,
        never the fp tree). Two identity guards fail fast here rather
        than as garbage samples inside the compiled sampler: a d_model
        mismatch against the artifact's recorded config, and — when the
        artifact records an fp-params content hash — any params tree
        other than the one the calibration ran against
        (``artifact.check_params``).
        """
        artifact.check_params(params)
        dcfg = artifact.model_cfg()
        d_model = params.get("x_proj", {}).get("w", None) if isinstance(
            params, dict) else None
        if d_model is not None and d_model.shape[-1] != dcfg.d_model:
            raise ValueError(
                f"params d_model {d_model.shape[-1]} != artifact's recorded "
                f"DiTCfg.d_model {dcfg.d_model} — wrong checkpoint for this "
                "artifact?")
        return cls(params, dcfg, artifact.dif_cfg(), sched,
                   ctx=artifact.context(kernel=kernel, attn_impl=attn_impl),
                   mesh=mesh, microbatch=microbatch,
                   step_buckets=step_buckets, clip_x0=clip_x0)

    # -- executable construction -------------------------------------------
    def _build(self, steps: int):
        dcfg, dif, sched = self.dcfg, self.dif, self.sched
        ctx, clip = self.ctx, self.clip_x0
        null_label = dcfg.n_classes                # the extra embedding row

        def run(params, labels, seeds, guidance):
            eps = lambda x, t, y, c: dit_apply(params, dcfg, x, t, y, ctx=c)
            shape = (labels.shape[0], dcfg.img_size, dcfg.img_size,
                     dcfg.in_ch)
            return ddpm_sample_paired(eps, dif, sched, shape, labels, seeds,
                                      guidance, null_label=null_label,
                                      steps=steps, ctx=ctx, clip_x0=clip)

        if self.mesh is not None:
            rspec = request_spec(self.mesh)
            run = shard_map(run, mesh=self.mesh,
                            in_specs=(P(), rspec, rspec, rspec),
                            out_specs=batch_spec(self.mesh, 4),
                            check_rep=False)
        return jax.jit(run)

    def _fn(self, steps: int):
        if steps not in self._fns:
            self._fns[steps] = self._build(steps)
            self.stats["compiled_buckets"].append(steps)
        return self._fns[steps]

    # -- execution ----------------------------------------------------------
    def run_microbatch(self, mb: MicroBatch) -> np.ndarray:
        """Run one microbatch; returns (B, H, W, C) samples (incl. padding
        slots — callers drop them via ``mb.valid``)."""
        if mb.batch != self.microbatch:
            raise ValueError(
                f"microbatch has {mb.batch} slots, engine expects "
                f"{self.microbatch}")
        if mb.steps not in self.step_buckets:
            raise ValueError(f"steps {mb.steps} not in configured buckets "
                             f"{self.step_buckets}")
        out = self._fn(mb.steps)(self.params, jnp.asarray(mb.labels),
                                 jnp.asarray(mb.seeds),
                                 jnp.asarray(mb.guidance))
        return np.asarray(jax.block_until_ready(out))

    def run(self, microbatches: Sequence[MicroBatch]
            ) -> Dict[int, GenResult]:
        """Run microbatches in order; returns {request_id: GenResult}."""
        results: Dict[int, GenResult] = {}
        for mb in microbatches:
            t0 = time.perf_counter()
            samples = self.run_microbatch(mb)
            dt = time.perf_counter() - t0
            for slot, rid in enumerate(mb.request_ids):
                results[rid] = GenResult(
                    request_id=rid, sample=samples[slot], steps=mb.steps,
                    microbatch=mb.batch, wall_s=dt,
                    requested_steps=(mb.requested_steps[slot]
                                     if slot < len(mb.requested_steps)
                                     else None))
            self.stats["microbatches"] += 1
            self.stats["requests"] += mb.n_valid
            self.stats["padded_slots"] += mb.n_padded
            self.stats["wall_s"] += dt
        return results

    def serve(self, requests: Sequence[GenRequest]) -> Dict[int, GenResult]:
        """Convenience: coalesce + run a request list in one call."""
        return self.run(coalesce(requests, self.microbatch,
                                 self.step_buckets))


class AsyncServeEngine:
    """Continuous-batching engine: a slot pool advanced ``chunk`` steps per
    compiled dispatch, with a full request-lifecycle robustness layer.

    Where :class:`ServeEngine` buckets requests by step count and runs each
    bucket's whole chain in one blocking call, this engine keeps a pool of
    ``microbatch`` in-flight slots, each carrying its own
    ``(pos, bucket, label, seed, guidance)`` state, and every dispatch
    advances ALL active slots ``chunk`` denoising steps — requests at
    different timesteps, even different step buckets, share ONE compiled
    executable (TGQ resolves the timestep group as a traced scalar inside
    the kernels; see ``ddpm_chunk_slots``). Finished slots are swapped out
    and queued requests admitted at the next chunk boundary, so a 25-step
    request never waits for a 100-step neighbour to drain.

    Robustness layer (``repro.serving.lifecycle`` / ``.faults``):

    - bounded-queue admission: ``submit`` rejects with a structured
      ``queue_full`` / ``bad_label`` outcome instead of dropping;
    - per-request deadlines + ``cancel``: checked at chunk boundaries, the
      slot is freed and the request ends ``CANCELLED`` (a request that
      FINISHES by the boundary still delivers ``OK``);
    - NaN/Inf quarantine: a post-chunk on-device finiteness guard flags
      only the poisoned slot; it is reset and retried with the SAME
      ``fold_in(PRNGKey(seed), step)`` keys — bit-identical on success —
      and ends ``FAILED`` with a ``nan_poisoned`` error after
      ``max_retries``;
    - degradation ladder on dispatch faults: flash attn -> composed
      kernels -> fake-quant, each step logged; ladder exhausted =>
      every live request fails structured and :class:`EngineFault` raises.

    Scale-out: with ``mesh`` the slot pool is SHARDED across the
    data-parallel mesh exactly like the sync path's microbatches — the
    chunk executable runs under shard_map with params replicated and
    every per-slot array on ``request_spec``, so slot ``s`` lives on
    device ``s // (microbatch/dp)`` and admission into a slot is
    admission onto that device's shard. The batched vector-tgroup
    forward (``ddpm_chunk_slots``) has no cross-slot communication, so
    each device runs the same executable a single-device pool would —
    samples stay bit-identical. ``pipeline >= 2`` adds dispatch-ahead:
    the next chunk is enqueued on the current chunk's device-resident
    outputs BEFORE the host blocks on the small (B,) position/bad reads,
    keeping the device busy while the host resolves the boundary;
    the speculative chunk is drained whenever the boundary mutates slot
    state (admission, completion, cancel/deadline, quarantine reset,
    degradation), so the lifecycle state machine and the NaN-retry
    bit-identity contract are byte-for-byte those of ``pipeline=1``.

    Slot state lives on device; per-chunk host traffic is two (B,)
    arrays (positions + bad flags) — the full latent is pulled once per
    request, at completion. ``clock`` is injectable
    (``faults.FakeClock``) so deadline tests never sleep.
    """

    # a freed slot parks at pos >= every bucket length: bucket 0, pos n_max
    def __init__(self, params, dcfg: DiTCfg, dif: DiffusionCfg,
                 sched=None, *, ctx=None, mesh: Optional[Mesh] = None,
                 microbatch: int = 4,
                 step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS,
                 chunk: int = 4, pipeline: int = 2, max_queue: int = 64,
                 max_retries: int = 2,
                 deadline_s: Optional[float] = None, clock=time.monotonic,
                 injector=None, clip_x0: Optional[float] = None):
        self.dcfg = dcfg
        self.dif = dif
        self.sched = sched if sched is not None else make_schedule(dif)
        self.ctx = ctx if ctx is not None else FPContext()
        self.mesh = mesh
        self.microbatch = int(microbatch)
        self.step_buckets = tuple(sorted(int(b) for b in step_buckets))
        self.chunk = int(chunk)
        self.pipeline = max(1, int(pipeline))
        self.max_queue = int(max_queue)
        self.max_retries = int(max_retries)
        self.deadline_s = deadline_s
        self.clip_x0 = clip_x0
        self._clock = clock
        self._injector = injector
        if mesh is not None:
            nd = dp_size(mesh)
            if self.microbatch % nd != 0:
                raise ValueError(
                    f"microbatch {self.microbatch} not divisible by the "
                    f"mesh's {nd} data-parallel shards — each device needs "
                    "an equal, fixed-shape slice of the slot pool")
            params = jax.device_put(params, replicated(mesh))
        self.params = params

        self._slot_sched = make_slot_schedule(dif, self.sched,
                                              self.step_buckets)
        self._n_of = np.asarray(self._slot_sched["n_of"])
        self._n_max = int(self._n_of.max())
        self._bucket_idx = {b: i for i, b in
                            enumerate(self._slot_sched["buckets"])}
        B = self.microbatch
        sshape = (dcfg.img_size, dcfg.img_size, dcfg.in_ch)
        self._x = jnp.zeros((B,) + sshape, jnp.float32)
        self._pos = jnp.full((B,), self._n_max, jnp.int32)   # all free
        self._bk = jnp.zeros((B,), jnp.int32)
        self._y = jnp.zeros((B,), jnp.int32)
        self._seeds = jnp.zeros((B,), jnp.uint32)
        self._gs = jnp.ones((B,), jnp.float32)

        self._slot_rid: List[Optional[int]] = [None] * B
        self._pos_host = np.full((B,), self._n_max, np.int64)
        self.queue: deque = deque()                  # request ids, FIFO
        self.records: Dict[int, lc.RequestRecord] = {}
        self.outcomes: Dict[int, lc.RequestOutcome] = {}
        self._next_id = 0
        self._warned_roundings: set = set()
        self._t0 = clock()

        self.stats: Dict[str, Any] = {
            "dispatches": 0, "chunk_traces": 0, "degradations": [],
            "admitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "cancelled": 0, "retries": 0, "queue_peak": 0,
        }
        self._pending = None            # dispatch-ahead in-flight chunk
        self._chunk_fn = self._build_chunk()
        self._init_fn = jax.jit(
            lambda seed, n: ddpm_init_latent(seed, n, sshape))

    @classmethod
    def from_artifact(cls, params, artifact, *, kernel=None,
                      attn_impl: Optional[str] = None, sched=None,
                      **kw) -> "AsyncServeEngine":
        """Async engine from a ``QuantArtifact`` (same identity guards as
        ``ServeEngine.from_artifact``)."""
        artifact.check_params(params)
        return cls(params, artifact.model_cfg(), artifact.dif_cfg(), sched,
                   ctx=artifact.context(kernel=kernel, attn_impl=attn_impl),
                   **kw)

    # -- executable construction -------------------------------------------
    def _build_chunk(self):
        dcfg, dif, S = self.dcfg, self.dif, self._slot_sched
        ctx, clip, chunk = self.ctx, self.clip_x0, self.chunk
        null_label = dcfg.n_classes
        stats = self.stats

        def run(params, x, pos, bk, y, seeds, gs):
            stats["chunk_traces"] += 1      # python side effect: counts
            eps = lambda xx, t, yy, c: dit_apply(   # TRACES, not dispatches
                params, dcfg, xx, t, yy, ctx=c)
            return ddpm_chunk_slots(eps, dif, S, x, pos, bk, y, seeds, gs,
                                    null_label=null_label, chunk=chunk,
                                    ctx=ctx, clip_x0=clip)

        if self.mesh is not None:
            rspec = request_spec(self.mesh)
            run = shard_map(run, mesh=self.mesh,
                            in_specs=(P(), batch_spec(self.mesh, 4), rspec,
                                      rspec, rspec, rspec, rspec),
                            out_specs=(batch_spec(self.mesh, 4), rspec,
                                       rspec),
                            check_rep=False)
        return jax.jit(run)

    # -- admission ----------------------------------------------------------
    def _reject(self, req: GenRequest, code: str, message: str) -> int:
        now = self._clock()
        rec = lc.RequestRecord(request=req, status=lc.REJECTED,
                               submit_ts=now, finish_ts=now,
                               error=lc.FaultInfo(code=code, message=message))
        self.records[req.request_id] = rec
        self.outcomes[req.request_id] = lc.outcome_of(rec, None, now)
        self.stats["rejected"] += 1
        return req.request_id

    def submit_request(self, req: GenRequest) -> int:
        """Admission control for a pre-built request: validates the label,
        applies bounded-queue backpressure, and either queues the request
        or records a structured ``REJECTED`` outcome (never raises, never
        drops silently). Returns the request id either way."""
        rid = req.request_id
        if rid in self.records:
            raise ValueError(f"duplicate request id {rid}")
        try:
            validate_label(req.label, self.dcfg.n_classes, rid)
        except ValueError as e:
            return self._reject(req, lc.BAD_LABEL, str(e))
        if len(self.queue) >= self.max_queue:
            return self._reject(
                req, lc.QUEUE_FULL,
                f"request {rid}: queue full ({self.max_queue} waiting) — "
                "retry with backoff")
        now = self._clock()
        dl = req.deadline_s if req.deadline_s is not None else self.deadline_s
        rec = lc.RequestRecord(
            request=req, submit_ts=now,
            deadline_ts=(now + dl) if dl is not None else None)
        rec.log(now, "queued")
        self.records[rid] = rec
        self.queue.append(rid)
        self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                       len(self.queue))
        return rid

    def submit(self, label: int, steps: int = 50, cfg_scale: float = 1.0,
               seed: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Build + submit one request; returns its id. Check
        ``outcomes[rid]`` for an immediate structured rejection."""
        rid = self._next_id
        self._next_id += 1
        bucketed = bucket_steps(steps, self.step_buckets)
        if bucketed != int(steps) and int(steps) not in self._warned_roundings:
            self._warned_roundings.add(int(steps))
            warnings.warn(
                f"requested {int(steps)} sampler steps rounded to bucket "
                f"{bucketed} (step_buckets={self.step_buckets}); "
                "RequestOutcome.requested_steps records the original ask",
                stacklevel=2)
        return self.submit_request(GenRequest(
            request_id=rid, label=int(label), steps=bucketed,
            cfg_scale=float(cfg_scale),
            seed=int(seed) if seed is not None else rid,
            requested_steps=int(steps), deadline_s=deadline_s))

    def cancel(self, rid: int) -> bool:
        """Request cancellation. Queued requests resolve at admission;
        running ones free their slot at the next chunk boundary. Returns
        False if the request is already terminal."""
        rec = self.records.get(rid)
        if rec is None or rec.status in lc.TERMINAL:
            return False
        rec.cancel_requested = True
        return True

    # -- slot management ----------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [s for s, rid in enumerate(self._slot_rid) if rid is None]

    def _place(self, slot: int, rec: lc.RequestRecord) -> None:
        self._drain_pipeline()          # pool mutates: in-flight chunk stale
        req = rec.request
        bi = self._bucket_idx[bucket_steps(req.steps, self.step_buckets)]
        n = int(self._n_of[bi])
        x0 = self._init_fn(jnp.uint32(req.seed), jnp.int32(n))
        self._x = self._x.at[slot].set(x0)
        self._pos = self._pos.at[slot].set(0)
        self._bk = self._bk.at[slot].set(bi)
        self._y = self._y.at[slot].set(req.label)
        self._seeds = self._seeds.at[slot].set(jnp.uint32(req.seed))
        self._gs = self._gs.at[slot].set(req.cfg_scale)
        self._slot_rid[slot] = req.request_id
        self._pos_host[slot] = 0
        rec.slot = slot
        if rec.admit_ts is None:       # retries keep the original admit time
            rec.admit_ts = self._clock()
            self.stats["admitted"] += 1
        rec.status = lc.RUNNING
        rec.log(self._clock(), f"slot {slot}")

    def _release(self, slot: int) -> None:
        self._drain_pipeline()          # pool mutates: in-flight chunk stale
        self._x = self._x.at[slot].set(0.0)   # clear poison from the pool
        self._pos = self._pos.at[slot].set(self._n_max)
        self._bk = self._bk.at[slot].set(0)
        self._slot_rid[slot] = None
        self._pos_host[slot] = self._n_max

    def _finish(self, rec: lc.RequestRecord, status: str,
                sample: Optional[np.ndarray],
                error: Optional[lc.FaultInfo] = None) -> None:
        now = self._clock()
        rec.status = status
        rec.error = error
        rec.finish_ts = now
        rec.log(now, status)
        if rec.slot is not None:
            self._release(rec.slot)
            rec.slot = None
        self.outcomes[rec.request.request_id] = lc.outcome_of(
            rec, sample, now)
        key = {lc.OK: "completed", lc.FAILED: "failed",
               lc.CANCELLED: "cancelled"}[status]
        self.stats[key] += 1

    def _admit(self) -> None:
        free = self._free_slots()
        while free and self.queue:
            rid = self.queue.popleft()
            rec = self.records[rid]
            now = self._clock()
            if rec.cancel_requested:
                self._finish(rec, lc.CANCELLED, None, lc.FaultInfo(
                    code=lc.CANCELLED_BY_USER,
                    message=f"request {rid} cancelled while queued"))
                continue
            if rec.deadline_ts is not None and now > rec.deadline_ts:
                self._finish(rec, lc.CANCELLED, None, lc.FaultInfo(
                    code=lc.DEADLINE,
                    message=f"request {rid} deadline passed after "
                            f"{now - rec.submit_ts:.3f}s in queue"))
                continue
            self._place(free.pop(0), rec)

    # -- the pump ------------------------------------------------------------
    @property
    def active(self) -> int:
        return sum(1 for r in self._slot_rid if r is not None)

    def _fail_all_live(self, error: lc.FaultInfo) -> None:
        for rid in list(self.queue):
            self._finish(self.records[rid], lc.FAILED, None, error)
        self.queue.clear()
        for slot, rid in enumerate(self._slot_rid):
            if rid is not None:
                self._finish(self.records[rid], lc.FAILED, None, error)

    def _drain_pipeline(self) -> None:
        """Discard any dispatch-ahead chunk: its inputs no longer match
        the slot pool (admission, release, quarantine reset, or a
        degradation rebuilt the executable)."""
        self._pending = None

    def _dispatch(self):
        """One chunk dispatch with the degradation ladder and dispatch-ahead
        pipelining. Slot state is only replaced AFTER the blocking reads
        succeed, so a failed dispatch (trace error, kernel fault, injected)
        is side-effect free and the same chunk can be retried on a degraded
        context. With ``pipeline >= 2`` the NEXT chunk is enqueued on this
        chunk's device-resident outputs BEFORE the host blocks on the small
        (B,) reads — two dispatches in flight, host boundary work overlapped
        with device compute. The speculative chunk is only consumed if this
        boundary mutates no slot state; every mutating path drains it
        (``_drain_pipeline``), so fault/deadline/quarantine semantics are
        exactly those of ``pipeline=1``."""
        while True:
            self.stats["dispatches"] += 1
            try:
                if self._injector is not None:
                    self._injector.before_dispatch(self.stats["dispatches"])
                if self._pending is not None:
                    x, pos, bad = self._pending
                    self._pending = None
                else:
                    x, pos, bad = self._chunk_fn(
                        self.params, self._x, self._pos, self._bk, self._y,
                        self._seeds, self._gs)
                if self.pipeline >= 2:
                    # dispatch-ahead: enqueue the next chunk on the async
                    # dispatch queue now; pump() drains it if this chunk's
                    # boundary mutates any slot
                    self._pending = self._chunk_fn(
                        self.params, x, pos, self._bk, self._y,
                        self._seeds, self._gs)
                # block on the SMALL outputs only; x stays device-resident
                pos_h = np.array(pos)      # writable copy: retries reset it
                bad_h = np.array(bad)
                return x, pos_h, bad_h
            except Exception as e:            # noqa: BLE001 — ladder seam
                self._drain_pipeline()
                down = degrade_context(self.ctx)
                if down is None:
                    err = lc.FaultInfo(
                        code=lc.ENGINE_FAULT,
                        message=f"dispatch failed with no degradation rung "
                                f"left: {type(e).__name__}: {e}")
                    self._fail_all_live(err)
                    raise EngineFault(err.message) from e
                self.ctx, reason = down
                self.stats["degradations"].append(
                    {"reason": reason, "error": f"{type(e).__name__}: {e}"})
                self._chunk_fn = self._build_chunk()

    def pump(self) -> bool:
        """One engine cycle: admit -> dispatch one chunk -> resolve slots.
        Returns False when there was nothing to do (pool empty and queue
        empty after admission)."""
        self._admit()
        if self.active == 0:
            return False
        x, pos_h, bad_h = self._dispatch()
        didx = self.stats["dispatches"]
        now = self._clock()

        for slot, rid in enumerate(self._slot_rid):
            if rid is None:
                continue
            rec = self.records[rid]
            n = int(self._n_of[self._bucket_idx[
                bucket_steps(rec.request.steps, self.step_buckets)]])
            p_before, p_after = int(self._pos_host[slot]), int(pos_h[slot])
            poisoned = bool(bad_h[slot])
            fault = None
            if self._injector is not None:
                fault = self._injector.poison(didx, rid, p_before, p_after)
                if fault is not None:
                    x = x.at[slot].set(jnp.nan)   # poison ONLY this slot
                    poisoned = True
            if poisoned:
                step = fault.at_step if fault is not None else p_before
                code = (lc.SLOT_ERROR if fault is not None
                        and fault.kind == "slot_error" else lc.NAN_POISONED)
                if rec.retries >= self.max_retries:
                    self._x = x   # keep the pool consistent before release
                    self._finish(rec, lc.FAILED, None, lc.FaultInfo(
                        code=code, step=step, retries=rec.retries,
                        message=f"request {rid}: non-finite latent at scan "
                                f"position ~{step}; gave up after "
                                f"{rec.retries} retries"))
                    x = self._x
                    continue
                # quarantine: reset THIS slot to scan position 0 with the
                # same fold_in(PRNGKey(seed), i) keys — the retry replays
                # the identical trajectory, bit-identical on success
                rec.retries += 1
                self.stats["retries"] += 1
                rec.log(now, f"quarantined@{step} retry {rec.retries}")
                self._drain_pipeline()  # slot resets: in-flight chunk stale
                x = x.at[slot].set(self._init_fn(
                    jnp.uint32(rec.request.seed), jnp.int32(n)))
                pos_h[slot] = 0
                continue
            if p_after >= n:                      # finished: the ONE place
                self._x = x                       # the full latent leaves
                sample = np.asarray(self._x[slot])     # the device
                self._finish(rec, lc.OK, sample)
                x = self._x
                continue
            if rec.cancel_requested:
                self._x = x
                self._finish(rec, lc.CANCELLED, None, lc.FaultInfo(
                    code=lc.CANCELLED_BY_USER, step=p_after,
                    message=f"request {rid} cancelled at chunk boundary"))
                x = self._x
                continue
            if rec.deadline_ts is not None and now > rec.deadline_ts:
                self._x = x
                self._finish(rec, lc.CANCELLED, None, lc.FaultInfo(
                    code=lc.DEADLINE, step=p_after,
                    message=f"request {rid}: deadline exceeded at chunk "
                            f"boundary (scan position {p_after}/{n})"))
                x = self._x
                continue

        self._x = x
        self._pos = jnp.asarray(pos_h, jnp.int32)
        for slot, rid in enumerate(self._slot_rid):
            if rid is not None:
                self._pos_host[slot] = int(pos_h[slot])
        return True

    def run_until_drained(self, max_pumps: int = 100_000
                          ) -> Dict[int, lc.RequestOutcome]:
        """Pump until every submitted request is terminal."""
        pumps = 0
        while self.queue or self.active:
            if not self.pump():
                break
            pumps += 1
            if pumps > max_pumps:
                raise EngineFault(
                    f"async loop did not drain within {max_pumps} pumps — "
                    f"{self.active} slots active, {len(self.queue)} queued")
        return self.outcomes

    def serve(self, requests: Sequence[GenRequest]
              ) -> Dict[int, lc.RequestOutcome]:
        """Submit pre-built requests (keeping their ids) and drain."""
        for r in requests:
            self.submit_request(r)
        return self.run_until_drained()

    def metrics(self) -> Dict[str, Any]:
        """Lifecycle metrics over everything terminal so far."""
        wall = self._clock() - self._t0
        return lc.summarize(list(self.outcomes.values()), wall)
