"""The serving engine: compiled, sharded microbatch execution.

One :class:`ServeEngine` owns

- the model (params + DiTCfg) and the execution context — ``FPContext``
  for fp32, a fake-quant ``QuantContext`` for fidelity serving, or
  ``QuantContext(kernel=True)`` with int8-packed qparams for the fused
  Pallas deployment path,
- the diffusion setup (``DiffusionCfg`` + schedule),
- a data-parallel mesh: the paired sampler is wrapped in ``shard_map``
  with params replicated (``P()``) and every per-request array sharded on
  the DP super-axis (``repro.distributed.request_spec``). The model
  forward has no cross-sample communication, so serving scales linearly
  across the "data" axis and each device runs the SAME executable a
  single-device engine would — bit-identical samples either way
  (``benchmarks/serve_throughput.py`` asserts this).
- a cache of compiled executables, one per step bucket. TGQ group
  selection happens inside the fused kernels (scalar-prefetched group
  index), so all timestep groups share one executable; only a new step
  bucket triggers a compile. With int8-packed qparams the executable
  contains the WHOLE quantized block: fused int8 linears AND the int8
  attention path — by default ONE flash-style kernel per block
  (``kernels.flash_attn_mrq``: int8 QK^T -> online softmax -> MRQ codes
  -> P·V, the (S,S) scores/codes never touching HBM), or the composed
  three-kernel chain under ``attn_impl="composed"`` — so the DDPM scan
  stays one compiled program with no fp attention island inside.

``check_rep=False`` on the shard_map is required: pallas_call has no
replication rule, and the body is embarrassingly data-parallel anyway.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.diffusion import DiffusionCfg, ddpm_sample_paired, make_schedule
from repro.distributed import batch_spec, dp_size, replicated, request_spec
from repro.models import DiTCfg, dit_apply
from repro.nn.ctx import FPContext
from repro.serving.batching import (
    DEFAULT_STEP_BUCKETS, GenRequest, GenResult, MicroBatch, coalesce,
)


class ServeEngine:
    """Executes fixed-shape microbatches of DiT generation requests.

    Parameters
    ----------
    params, dcfg : the DiT model.
    dif, sched   : diffusion config + schedule (sched built if omitted).
    ctx          : op context (default fp32). Pass a quantization
                   artifact's ``artifact.context()`` for the fused-int8
                   serving path — or build the whole engine with
                   :meth:`from_artifact`.
    mesh         : data-parallel mesh (``make_serving_mesh()``). None runs
                   un-sharded on the default device.
    microbatch   : slots per microbatch; must divide by the mesh's DP size.
    step_buckets : allowed scan lengths (compile keys).
    """

    def __init__(self, params, dcfg: DiTCfg, dif: DiffusionCfg,
                 sched=None, *, ctx=None, mesh: Optional[Mesh] = None,
                 microbatch: int = 8,
                 step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS,
                 clip_x0: Optional[float] = None):
        self.dcfg = dcfg
        self.dif = dif
        self.sched = sched if sched is not None else make_schedule(dif)
        self.ctx = ctx if ctx is not None else FPContext()
        self.mesh = mesh
        self.microbatch = int(microbatch)
        self.step_buckets = tuple(sorted(int(b) for b in step_buckets))
        self.clip_x0 = clip_x0
        if mesh is not None:
            nd = dp_size(mesh)
            if self.microbatch % nd != 0:
                raise ValueError(
                    f"microbatch {self.microbatch} not divisible by the "
                    f"mesh's {nd} data-parallel shards")
            params = jax.device_put(params, replicated(mesh))
        self.params = params
        self._fns: Dict[int, Any] = {}          # step bucket -> compiled fn
        self.stats: Dict[str, Any] = {
            "compiled_buckets": [], "microbatches": 0, "requests": 0,
            "padded_slots": 0, "wall_s": 0.0,
        }

    @classmethod
    def from_artifact(cls, params, artifact, *, kernel=None,
                      attn_impl: Optional[str] = None, sched=None,
                      mesh: Optional[Mesh] = None, microbatch: int = 8,
                      step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS,
                      clip_x0: Optional[float] = None) -> "ServeEngine":
        """Quantized engine straight from a ``repro.quant.QuantArtifact``
        — the cold-start path: ``QuantArtifact.load(path)`` then this, no
        calibration in the serving process.

        The artifact supplies the model/diffusion configs and the op
        context (``artifact.context(kernel=..., attn_impl=...)``: fused
        int8 kernels when packs exist, fake-quant otherwise;
        ``attn_impl=None`` serves the attention lowering the artifact's
        recipe records — 'flash' single-kernel by default, 'composed'
        for the three-kernel oracle); ``params`` are the fp model
        weights (artifacts carry quantizer state and int8 weight codes,
        never the fp tree). Two identity guards fail fast here rather
        than as garbage samples inside the compiled sampler: a d_model
        mismatch against the artifact's recorded config, and — when the
        artifact records an fp-params content hash — any params tree
        other than the one the calibration ran against
        (``artifact.check_params``).
        """
        artifact.check_params(params)
        dcfg = artifact.model_cfg()
        d_model = params.get("x_proj", {}).get("w", None) if isinstance(
            params, dict) else None
        if d_model is not None and d_model.shape[-1] != dcfg.d_model:
            raise ValueError(
                f"params d_model {d_model.shape[-1]} != artifact's recorded "
                f"DiTCfg.d_model {dcfg.d_model} — wrong checkpoint for this "
                "artifact?")
        return cls(params, dcfg, artifact.dif_cfg(), sched,
                   ctx=artifact.context(kernel=kernel, attn_impl=attn_impl),
                   mesh=mesh, microbatch=microbatch,
                   step_buckets=step_buckets, clip_x0=clip_x0)

    # -- executable construction -------------------------------------------
    def _build(self, steps: int):
        dcfg, dif, sched = self.dcfg, self.dif, self.sched
        ctx, clip = self.ctx, self.clip_x0
        null_label = dcfg.n_classes                # the extra embedding row

        def run(params, labels, seeds, guidance):
            eps = lambda x, t, y, c: dit_apply(params, dcfg, x, t, y, ctx=c)
            shape = (labels.shape[0], dcfg.img_size, dcfg.img_size,
                     dcfg.in_ch)
            return ddpm_sample_paired(eps, dif, sched, shape, labels, seeds,
                                      guidance, null_label=null_label,
                                      steps=steps, ctx=ctx, clip_x0=clip)

        if self.mesh is not None:
            rspec = request_spec(self.mesh)
            run = shard_map(run, mesh=self.mesh,
                            in_specs=(P(), rspec, rspec, rspec),
                            out_specs=batch_spec(self.mesh, 4),
                            check_rep=False)
        return jax.jit(run)

    def _fn(self, steps: int):
        if steps not in self._fns:
            self._fns[steps] = self._build(steps)
            self.stats["compiled_buckets"].append(steps)
        return self._fns[steps]

    # -- execution ----------------------------------------------------------
    def run_microbatch(self, mb: MicroBatch) -> np.ndarray:
        """Run one microbatch; returns (B, H, W, C) samples (incl. padding
        slots — callers drop them via ``mb.valid``)."""
        if mb.batch != self.microbatch:
            raise ValueError(
                f"microbatch has {mb.batch} slots, engine expects "
                f"{self.microbatch}")
        if mb.steps not in self.step_buckets:
            raise ValueError(f"steps {mb.steps} not in configured buckets "
                             f"{self.step_buckets}")
        out = self._fn(mb.steps)(self.params, jnp.asarray(mb.labels),
                                 jnp.asarray(mb.seeds),
                                 jnp.asarray(mb.guidance))
        return np.asarray(jax.block_until_ready(out))

    def run(self, microbatches: Sequence[MicroBatch]
            ) -> Dict[int, GenResult]:
        """Run microbatches in order; returns {request_id: GenResult}."""
        results: Dict[int, GenResult] = {}
        for mb in microbatches:
            t0 = time.perf_counter()
            samples = self.run_microbatch(mb)
            dt = time.perf_counter() - t0
            for slot, rid in enumerate(mb.request_ids):
                results[rid] = GenResult(
                    request_id=rid, sample=samples[slot], steps=mb.steps,
                    microbatch=mb.batch, wall_s=dt)
            self.stats["microbatches"] += 1
            self.stats["requests"] += mb.n_valid
            self.stats["padded_slots"] += mb.n_padded
            self.stats["wall_s"] += dt
        return results

    def serve(self, requests: Sequence[GenRequest]) -> Dict[int, GenResult]:
        """Convenience: coalesce + run a request list in one call."""
        return self.run(coalesce(requests, self.microbatch,
                                 self.step_buckets))
