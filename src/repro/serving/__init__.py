"""Sharded batched DiT serving (the deployment layer above the kernels).

Request lifecycle (docs/serving.md):

  GenRequest --submit--> RequestScheduler --coalesce--> MicroBatch
      --ServeEngine--> shard_map'd ddpm_sample_paired (CFG-paired, TGQ
      threaded, fused int8 kernels when quantized) --> GenResult

``repro.serving.quickcal.range_calibrate`` produces serving-grade W8A8
qparams in seconds for bring-up; the fidelity path stays
``repro.core.ptq.run_ptq``.
"""
from repro.serving.batching import (
    DEFAULT_STEP_BUCKETS, GenRequest, GenResult, MicroBatch, bucket_steps,
    coalesce,
)
from repro.serving.scheduler import RequestScheduler
from repro.serving.engine import ServeEngine
from repro.serving.quickcal import range_calibrate
