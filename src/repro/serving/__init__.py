"""Sharded batched DiT serving (the deployment layer above the kernels).

Request lifecycle (docs/serving.md):

  GenRequest --submit--> RequestScheduler --coalesce--> MicroBatch
      --ServeEngine--> shard_map'd ddpm_sample_paired (CFG-paired, TGQ
      threaded, fused int8 kernels when quantized) --> GenResult

Quantized serving state comes from the unified API
(``repro.quant.quantize`` -> ``QuantArtifact``):
``ServeEngine.from_artifact(params, artifact)`` builds the engine, and
``QuantArtifact.load(path)`` cold-starts a process with no calibration.
The range-only pipeline lives in ``repro.serving.quickcal`` (dispatched
by ``QuantRecipe(method="range")``); the fidelity path is
``repro.core.ptq.run_ptq`` (``method="ho"``).
"""
from repro.serving.batching import (
    DEFAULT_STEP_BUCKETS, GenRequest, GenResult, MicroBatch, bucket_steps,
    coalesce,
)
from repro.serving.scheduler import RequestScheduler, validate_label
from repro.serving.engine import AsyncServeEngine, ServeEngine
from repro.serving.faults import (
    EngineFault, FakeClock, Fault, FaultInjected, FaultInjector,
    degrade_context,
)
from repro.serving.lifecycle import (
    CANCELLED, FAILED, OK, QUEUED, REJECTED, RUNNING, TERMINAL,
    FaultInfo, RequestOutcome, RequestRecord, summarize,
)
from repro.serving.quickcal import range_calibrate as _range_calibrate


def range_calibrate(*args, **kwargs):
    """DEPRECATED shim for out-of-tree callers: use
    ``repro.quant.quantize(params, cfg, dif, QuantRecipe(method="range"))``
    — it runs this calibration, packs the int8 kernels, and returns a
    serializable ``QuantArtifact``. (The implementation is unchanged at
    ``repro.serving.quickcal.range_calibrate`` for internal dispatch.)"""
    import warnings
    warnings.warn(
        "repro.serving.range_calibrate is deprecated: use "
        "repro.quant.quantize(..., QuantRecipe(method='range')) and the "
        "returned QuantArtifact", DeprecationWarning, stacklevel=2)
    return _range_calibrate(*args, **kwargs)
