"""Request-level scheduler: the stateful frontend over the pure batching
functions.

``submit()`` assigns request ids and queues requests; ``flush()`` cuts
the queue into fixed-shape microbatches (bucketing + padding, see
``repro.serving.batching``); ``run()`` drains everything through an
engine and hands back per-request results.

Policy knobs:

- ``max_wait`` requests: ``flush(partial=False)`` only emits FULL
  microbatches and keeps the remainder queued — the steady-state policy
  under load (padding wastes compute). ``run()``/``flush(partial=True)``
  emit the trailing partial batch padded — the drain policy.
- per-request seeds default to a deterministic counter so repeated runs
  of the same submission order reproduce bit-identical samples.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

from repro.serving.batching import (
    DEFAULT_STEP_BUCKETS, GenRequest, GenResult, MicroBatch, bucket_steps,
    coalesce,
)


def validate_label(label: int, n_classes: Optional[int],
                   request_id) -> None:
    """Admission-time label check. An out-of-range label does NOT fail the
    model forward — the class-embedding gather silently reads garbage (or
    the null-label row) and the request gets back a corrupt sample — so
    the only safe place to catch it is BEFORE the request enters a
    microbatch, with an error that names the request."""
    if n_classes is None:
        return
    if not 0 <= int(label) < int(n_classes):
        raise ValueError(
            f"request {request_id}: label {int(label)} out of range "
            f"[0, {int(n_classes)}) — an out-of-range label would gather "
            "garbage from the class-embedding table and return a corrupt "
            "sample instead of failing")


class RequestScheduler:
    """Coalesces an incoming request stream into engine-ready microbatches.

    ``n_classes`` (when given, usually ``dcfg.n_classes``) enables
    admission-time label validation in :meth:`submit`/:meth:`submit_all`.
    """

    def __init__(self, microbatch: int = 8,
                 step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS,
                 n_classes: Optional[int] = None):
        self.microbatch = int(microbatch)
        self.step_buckets = tuple(sorted(int(b) for b in step_buckets))
        self.n_classes = None if n_classes is None else int(n_classes)
        self.pending: List[GenRequest] = []
        self._next_id = 0
        self._warned_roundings: set = set()

    def _warn_rounding(self, requested: int, bucketed: int) -> None:
        """Once per distinct requested step count: the caller asked for a
        step count the deployment doesn't compile and is silently getting
        a different one — worth a warning, not worth per-request spam."""
        if bucketed == requested or requested in self._warned_roundings:
            return
        self._warned_roundings.add(requested)
        warnings.warn(
            f"requested {requested} sampler steps rounded to the "
            f"{'larger' if bucketed > requested else 'SMALLER'} configured "
            f"bucket {bucketed} (step_buckets={self.step_buckets}); "
            "GenResult.requested_steps records the original ask",
            stacklevel=3)

    def submit(self, label: int, steps: int = 50, cfg_scale: float = 1.0,
               seed: Optional[int] = None) -> int:
        """Queue one request; returns its request id. Raises ``ValueError``
        (naming the request id) on an out-of-range label when the
        scheduler knows ``n_classes``."""
        rid = self._next_id
        validate_label(label, self.n_classes, rid)
        bucketed = bucket_steps(steps, self.step_buckets)
        self._warn_rounding(int(steps), bucketed)
        self._next_id += 1
        self.pending.append(GenRequest(
            request_id=rid, label=int(label), steps=bucketed,
            cfg_scale=float(cfg_scale),
            seed=int(seed) if seed is not None else rid,
            requested_steps=int(steps)))
        return rid

    def submit_all(self, requests: Sequence[GenRequest]) -> List[int]:
        """Queue pre-built requests, keeping their ids. Engine results are
        keyed by request id, so duplicates would silently overwrite each
        other: clashing ids are rejected here, and the internal counter
        jumps past the largest external id to keep later ``submit()`` calls
        collision-free."""
        ids = [r.request_id for r in requests]
        taken = {r.request_id for r in self.pending}
        dups = sorted({i for i in ids if ids.count(i) > 1 or i in taken})
        if dups:
            raise ValueError(f"duplicate request ids: {dups}")
        for r in requests:
            validate_label(r.label, self.n_classes, r.request_id)
        self.pending.extend(requests)
        if requests:
            self._next_id = max([self._next_id] + [i + 1 for i in ids])
        return ids

    def flush(self, partial: bool = True) -> List[MicroBatch]:
        """Cut the queue into microbatches. ``partial=False`` keeps any
        incomplete trailing batch (per bucket) queued for later arrivals."""
        batches = coalesce(self.pending, self.microbatch, self.step_buckets)
        if partial:
            self.pending = []
            return batches
        keep: List[GenRequest] = []
        out: List[MicroBatch] = []
        by_id = {r.request_id: r for r in self.pending}
        for mb in batches:
            if mb.n_padded == 0:
                out.append(mb)
            else:
                keep.extend(by_id[rid] for rid in mb.request_ids)
        self.pending = keep
        return out

    def run(self, engine) -> Dict[int, GenResult]:
        """Drain the queue through ``engine`` (padding the tail).

        Scheduler/engine shape compatibility is checked BEFORE the queue
        is flushed — a mismatch must not empty the queue and lose every
        pending request to a mid-run ValueError.
        """
        if engine.microbatch != self.microbatch:
            raise ValueError(
                f"scheduler microbatch {self.microbatch} != engine "
                f"microbatch {engine.microbatch}")
        missing = set(self.step_buckets) - set(engine.step_buckets)
        if missing:
            raise ValueError(f"scheduler step buckets {sorted(missing)} "
                             f"not compiled by the engine "
                             f"{engine.step_buckets}")
        return engine.run(self.flush(partial=True))
