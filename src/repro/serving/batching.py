"""Request coalescing: variable requests -> fixed-shape microbatches.

The compiled sampler executable is shaped by exactly two things: the
microbatch size B and the (static) step count of its ``lax.scan``. To
keep serving on ONE executable per step bucket:

- requests are **bucketed** by step count — a request asking for ``s``
  steps runs at the smallest configured bucket ``>= s`` (a few extra
  denoising steps, never fewer — except above the largest bucket, which
  is the deployment's configured ceiling and clamps; ``GenResult.steps``
  always reports what actually ran),
- each bucket's requests are **packed** into microbatches of exactly B
  slots; a trailing partial batch is **padded** with inert slots
  (``valid=False``) that compute alongside real requests and are dropped
  before results are returned. Padding is harmless by construction: the
  paired sampler draws noise per-slot from per-request keys and the DiT
  forward mixes nothing across the batch dim, so a real request's sample
  is bit-identical whatever rides in the other slots
  (``tests/test_serving.py::test_paired_sampler_batch_invariant``).

Classifier-free guidance does NOT change the microbatch shape: the
engine's sampler runs the conditional/unconditional halves as one 2B
forward internally (see ``repro.diffusion.ddpm_sample_paired``), so a
CFG request costs two model rows but one scheduling slot.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_STEP_BUCKETS: Tuple[int, ...] = (25, 50, 100)


@dataclasses.dataclass(frozen=True)
class GenRequest:
    """One generation request as it arrives at the frontend."""
    request_id: int
    label: int                   # class id (0..n_classes-1)
    steps: int = 50              # requested sampler steps (bucketed up)
    cfg_scale: float = 1.0       # CFG: 1 = conditional, 0 = uncond, >1 guided
    seed: int = 0                # per-request PRNG seed
    requested_steps: Optional[int] = None   # pre-bucketing ask (None: == steps)
    deadline_s: Optional[float] = None      # relative deadline from submit
                                            # (async engine; None = no deadline)


@dataclasses.dataclass(frozen=True)
class GenResult:
    """One finished request."""
    request_id: int
    sample: np.ndarray           # (H, W, C) latent
    steps: int                   # bucketed step count actually run
    microbatch: int              # size of the batch it rode in
    wall_s: float                # wall time of that microbatch
    requested_steps: Optional[int] = None   # what the caller asked for
                                            # before `bucket_steps` rounding


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """A fixed-shape unit of work: exactly ``batch`` slots, one bucket.

    The first ``len(request_ids)`` slots hold real requests (in submission
    order); the rest are padding with ``valid=False``.
    """
    steps: int                   # bucketed step count (compile key)
    labels: np.ndarray           # (B,) int32
    seeds: np.ndarray            # (B,) uint32
    guidance: np.ndarray         # (B,) float32 CFG scales
    valid: np.ndarray            # (B,) bool
    request_ids: Tuple[int, ...]
    requested_steps: Tuple[int, ...] = ()   # pre-bucketing asks, parallel to
                                            # request_ids (() for legacy
                                            # hand-built microbatches)

    @property
    def batch(self) -> int:
        return int(self.labels.shape[0])

    @property
    def n_valid(self) -> int:
        return len(self.request_ids)

    @property
    def n_padded(self) -> int:
        return self.batch - self.n_valid


def bucket_steps(steps: int, buckets: Sequence[int]) -> int:
    """Smallest configured bucket >= steps. Requests above the largest
    bucket CLAMP DOWN to it — the bucket list is the deployment's step
    ceiling, and per-request overshoot is not a supported shape."""
    bs = sorted(int(b) for b in buckets)
    for b in bs:
        if steps <= b:
            return b
    return bs[-1]


def coalesce(requests: Sequence[GenRequest], batch: int,
             step_buckets: Sequence[int] = DEFAULT_STEP_BUCKETS
             ) -> List[MicroBatch]:
    """Pack requests into fixed-shape microbatches.

    Requests are grouped by step bucket (preserving submission order
    within a bucket) and cut into chunks of ``batch``; the final chunk of
    each bucket is padded. Padding slots copy benign values (label 0,
    seed 0, guidance 1) — they are dropped by ``valid`` on the way out.
    """
    if batch <= 0:
        raise ValueError(f"microbatch size must be positive, got {batch}")
    by_bucket: dict = {}
    for r in requests:
        by_bucket.setdefault(bucket_steps(r.steps, step_buckets), []).append(r)

    out: List[MicroBatch] = []
    for steps in sorted(by_bucket):
        rs = by_bucket[steps]
        for s in range(0, len(rs), batch):
            chunk = rs[s:s + batch]
            pad = batch - len(chunk)
            out.append(MicroBatch(
                steps=steps,
                labels=np.asarray([r.label for r in chunk] + [0] * pad,
                                  np.int32),
                seeds=np.asarray([r.seed for r in chunk] + [0] * pad,
                                 np.uint32),
                guidance=np.asarray(
                    [r.cfg_scale for r in chunk] + [1.0] * pad, np.float32),
                valid=np.asarray([True] * len(chunk) + [False] * pad, bool),
                request_ids=tuple(r.request_id for r in chunk),
                requested_steps=tuple(
                    r.requested_steps if r.requested_steps is not None
                    else r.steps for r in chunk),
            ))
    return out
