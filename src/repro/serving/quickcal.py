"""Range-only calibration for serving bring-up and benchmarks.

The paper's full pipeline (Hessian-guided alternating search, Fisher
taps, R rounds — ``repro.core.ptq.run_ptq``) is the fidelity path and
costs minutes. Serving bring-up, smoke tests, and throughput benchmarks
only need *structurally correct* quantizers — per-group TGQ ranges in the
exact stacked ``(G, ...)`` format the fused int8 kernels gather — so this
module calibrates from plain min/max ranges in seconds:

- weights: per-output-channel symmetric ``ChannelQ`` from absmax,
- plain inputs: ``TGQ(UniformQ)`` — per-timestep-group [min, max] ranges,
- post-GELU/SiLU inputs: ``TGQ(MRQSignedQ)`` — per-group negative /
  positive lobe maxima (the two-region step sizes at alpha = 1),
- attention einsums (QK^T / P·V): per-group SYMMETRIC ``TGQ(SymQ)``
  absmax steps for q/k/v, and a per-group ``TGQ(MRQSoftmaxQ)`` region
  split for the post-softmax probs derived from the group's mean
  probability (region 1 sized to cover ~8x the mean — the bulk of the
  concentrated-near-zero mass — with the fine step; the paper's searched
  s1 is the fidelity pipeline's job). These pack via ``pack_int8_qk`` /
  ``pack_int8_pv`` so w8a8 serving runs the int8 attention kernels.

The result feeds ``repro.kernels.ops.convert_for_kernels`` directly; use
``run_ptq`` instead whenever sample quality is being measured.

This module is the 'range' pipeline BEHIND the unified API — call
``repro.quant.quantize(params, cfg, dif, QuantRecipe(method="range"))``
rather than this function directly; the artifact it returns packs,
serializes, and serves in one object.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calib import build_dit_calibration, dit_loss_fn
from repro.core.contexts import CalibrationContext, RecordingContext
from repro.core.quantizers import (
    TGQ, ChannelQ, MRQSignedQ, MRQSoftmaxQ, SymQ, UniformQ,
    channel_scale_from_absmax, sym_scale_from_absmax,
    uniform_params_from_range, weight_absmax,
)
from repro.diffusion import DiffusionCfg, make_schedule
from repro.models import DiTCfg
from repro.quant.groups import resolve_group


def _nearest(groups, g):
    """Nearest calibrated group (shared contract: repro.quant.groups)."""
    return resolve_group(g, calibrated=groups)


def range_calibrate(params, dcfg: DiTCfg, dif: DiffusionCfg, sched=None,
                    key=None, *, wbits: int = 8, abits: int = 8,
                    n_per_group: int = 2, batch: int = 2,
                    max_rows: int = 128
                    ) -> Tuple[Dict[str, dict], Dict[str, np.ndarray]]:
    """Min/max calibration of every DiT linear, time-grouped.

    Runs ``n_per_group`` forward-diffused samples per TGQ group through
    the model eagerly (the standard Phase-1/2 capture machinery), then
    derives quantizer params from ranges alone. Groups with no captured
    rows borrow the nearest calibrated group, so stacked params always
    cover all ``dif.tgq_groups``.

    Returns ``(qparams, weights)`` — exactly the two arguments
    ``convert_for_kernels`` wants.
    """
    sched = sched if sched is not None else make_schedule(dif)
    key = key if key is not None else jax.random.PRNGKey(0)
    loss = dit_loss_fn(params, dcfg)

    x0 = lambda n, k: jax.random.normal(
        k, (n, dcfg.img_size, dcfg.img_size, dcfg.in_ch))
    calib = build_dit_calibration(params, dcfg, dif, sched, x0, key,
                                  n_per_group=n_per_group, batch=batch)

    rec = RecordingContext()
    loss(rec, calib[0][0])
    cal = CalibrationContext(registry=rec.registry,
                             max_rows_per_batch=max_rows)
    for b, tg in calib:
        cal.begin_batch()
        loss(dataclasses.replace(cal, tgroup=tg), b)

    G = dif.tgq_groups
    half = 2 ** (abits - 1)
    qparams: Dict[str, dict] = {}

    # ---- attention einsums: symmetric q/k/v + range-derived probs split --
    for name, info in rec.registry.items():
        if (info.kind != "einsum" or info.b_is_weight
                or name not in cal.store):
            continue
        recs = cal.store[name]
        groups = sorted({r["tg"] for r in recs})

        def stat(f, key):
            vals = {g: max(f(r[key]) for r in recs if r["tg"] == g)
                    for g in groups}
            return jnp.asarray([vals[_nearest(groups, g)] for g in range(G)],
                               jnp.float32)

        absmax = lambda a: max(float(np.max(np.abs(a))), 1e-6)
        if info.a_kind == "post_softmax":
            # region-1 span ~8x the group's mean prob (the concentrated
            # near-zero mass gets the fine step; everything above rides
            # the fixed coarse step s2 = 1/2^{k-1})
            mean_p = stat(lambda a: float(np.mean(a)), "a")
            s1 = jnp.clip(8.0 * mean_p / half,
                          1.0 / (half * half * 8), 1.0 / half)
            xq: Any = TGQ(MRQSoftmaxQ(s1=s1, bits=abits))
        else:
            xq = TGQ(SymQ(scale=sym_scale_from_absmax(stat(absmax, "a"),
                                                      abits), bits=abits))
        qparams[name] = {
            "x": xq,
            "b": TGQ(SymQ(scale=sym_scale_from_absmax(stat(absmax, "b"),
                                                      abits), bits=abits)),
        }

    # ---- linears: per-group ranges --------------------------------------
    for name, info in rec.registry.items():
        if info.kind != "linear" or name not in cal.store:
            continue
        recs = cal.store[name]
        groups = sorted({r["tg"] for r in recs})
        lo_hi = {
            g: (min(float(r["x"].min()) for r in recs if r["tg"] == g),
                max(float(r["x"].max()) for r in recs if r["tg"] == g))
            for g in groups}

        if info.a_kind in ("post_gelu", "post_silu"):
            s_neg, s_pos = [], []
            for g in range(G):
                lo, hi = lo_hi[_nearest(groups, g)]
                s_neg.append(max(-lo, 1e-6) / half)
                s_pos.append(max(hi, 1e-6) / half)
            xq: Any = TGQ(MRQSignedQ(s_neg=jnp.asarray(s_neg, jnp.float32),
                                     s_pos=jnp.asarray(s_pos, jnp.float32),
                                     bits=abits))
        else:
            scales, zeros = [], []
            for g in range(G):
                lo, hi = lo_hi[_nearest(groups, g)]
                s, z = uniform_params_from_range(jnp.float32(lo),
                                                 jnp.float32(hi), abits)
                scales.append(s)
                zeros.append(z)
            xq = TGQ(UniformQ(scale=jnp.stack(scales), zero=jnp.stack(zeros),
                              bits=abits))

        w = cal.weights[name]
        qparams[name] = {
            "x": xq,
            "w": ChannelQ(channel_scale_from_absmax(
                weight_absmax(jnp.asarray(w)), wbits), bits=wbits),
        }
    return qparams, cal.weights
