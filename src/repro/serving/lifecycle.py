"""Request lifecycle: states, structured outcomes, and serving metrics.

Every request admitted to the async engine walks a small state machine:

    QUEUED ──admit──> RUNNING ──finish──────────────> OK
      │                 │  │
      │                 │  └─nan/inf quarantine──> RUNNING (retry, same keys)
      │                 │         └─max_retries──> FAILED
      │                 ├─deadline / cancel──────> CANCELLED
      │                 └─engine fault (ladder exhausted)──> FAILED
      └─reject (queue full / bad label)──────────> REJECTED

Nothing is dropped silently: every submitted request ends in exactly one
terminal state with a :class:`RequestOutcome`, and non-OK outcomes carry a
:class:`FaultInfo` naming the reason. The records double as the metrics
source — :func:`summarize` derives queue-wait, latency percentiles, and
goodput (OK requests per wall-second) from the per-request timestamps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# -- states -----------------------------------------------------------------
QUEUED = "QUEUED"
RUNNING = "RUNNING"
OK = "OK"
FAILED = "FAILED"
REJECTED = "REJECTED"
CANCELLED = "CANCELLED"

TERMINAL = frozenset({OK, FAILED, REJECTED, CANCELLED})

# -- fault codes (FaultInfo.code) -------------------------------------------
NAN_POISONED = "nan_poisoned"      # non-finite latent after a chunk
DEADLINE = "deadline"              # deadline passed at a chunk boundary
QUEUE_FULL = "queue_full"          # bounded-queue backpressure
BAD_LABEL = "bad_label"            # admission-time label validation
ENGINE_FAULT = "engine_fault"      # dispatch failed, ladder exhausted
CANCELLED_BY_USER = "cancelled"    # explicit cancel()
SLOT_ERROR = "slot_error"          # injected/observed per-slot failure


@dataclasses.dataclass(frozen=True)
class FaultInfo:
    """Structured reason attached to every non-OK outcome."""
    code: str                      # one of the module's fault codes
    message: str
    step: Optional[int] = None     # scan position when the fault surfaced
    retries: int = 0               # retries consumed before giving up


@dataclasses.dataclass
class RequestRecord:
    """Mutable per-request bookkeeping while a request is live."""
    request: Any                   # the GenRequest
    status: str = QUEUED
    submit_ts: float = 0.0
    admit_ts: Optional[float] = None
    finish_ts: Optional[float] = None
    deadline_ts: Optional[float] = None   # absolute (engine clock)
    retries: int = 0
    slot: Optional[int] = None
    error: Optional[FaultInfo] = None
    cancel_requested: bool = False
    events: List[Tuple[float, str]] = dataclasses.field(default_factory=list)

    def log(self, ts: float, event: str) -> None:
        self.events.append((float(ts), event))


@dataclasses.dataclass(frozen=True)
class RequestOutcome:
    """One request's terminal result — the async analogue of GenResult,
    extended with the lifecycle fields a service caller needs."""
    request_id: int
    status: str                    # OK | FAILED | REJECTED | CANCELLED
    sample: Optional[np.ndarray]   # (H, W, C); None unless OK
    steps: int                     # bucketed step count (what would/did run)
    requested_steps: Optional[int]
    error: Optional[FaultInfo]
    queue_wait_s: float = 0.0      # submit -> admit (0 if never admitted)
    latency_s: float = 0.0         # submit -> terminal
    retries: int = 0


def outcome_of(rec: RequestRecord, sample: Optional[np.ndarray],
               now: float) -> RequestOutcome:
    """Freeze a record into its terminal outcome (record must be terminal)."""
    if rec.status not in TERMINAL:
        raise ValueError(f"request {rec.request.request_id} not terminal: "
                         f"{rec.status}")
    wait = (rec.admit_ts - rec.submit_ts) if rec.admit_ts is not None else 0.0
    fin = rec.finish_ts if rec.finish_ts is not None else now
    return RequestOutcome(
        request_id=rec.request.request_id, status=rec.status, sample=sample,
        steps=rec.request.steps,
        requested_steps=rec.request.requested_steps, error=rec.error,
        queue_wait_s=float(wait), latency_s=float(fin - rec.submit_ts),
        retries=rec.retries)


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def summarize(outcomes: List[RequestOutcome], wall_s: float
              ) -> Dict[str, Any]:
    """Lifecycle metrics over a set of terminal outcomes.

    goodput counts only OK requests — a retried-to-death or deadline-missed
    request consumed compute but delivered nothing, which is the number a
    capacity planner actually needs (vs. raw throughput).
    """
    by_status: Dict[str, int] = {}
    for o in outcomes:
        by_status[o.status] = by_status.get(o.status, 0) + 1
    ok = [o for o in outcomes if o.status == OK]
    lat = [o.latency_s for o in ok]
    waits = [o.queue_wait_s for o in ok]
    return {
        "requests": len(outcomes),
        "by_status": by_status,
        "ok": len(ok),
        "goodput_rps": (len(ok) / wall_s) if wall_s > 0 else 0.0,
        "queue_wait_p50_s": _pct(waits, 50), "queue_wait_p99_s": _pct(waits, 99),
        "latency_p50_s": _pct(lat, 50), "latency_p99_s": _pct(lat, 99),
        "retries": sum(o.retries for o in outcomes),
        "wall_s": float(wall_s),
    }
