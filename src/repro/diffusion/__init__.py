"""DDPM diffusion process (schedules, loss, respaced ancestral sampling)."""
from repro.diffusion.ddpm import (
    DiffusionCfg, make_schedule, q_sample, ddpm_loss, respaced_timesteps,
    respaced_schedule, tgroup_of, ddpm_sample, ddpm_sample_paired,
    ddpm_sample_python, collect_xt_dataset, request_keys,
)
