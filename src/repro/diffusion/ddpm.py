"""DDPM substrate: noise schedules, forward process, training loss, and the
respaced ancestral sampler used by the paper (T_train=1000 linear schedule;
inference respaced to 100/250 steps as in DiT / TQ-DiT §IV-A).

All samplers thread the TGQ timestep-group index through the model context
(``ctx.with_tgroup(g)``) so time-grouped quantizers select the right
parameter set at each step — the inference-side half of the paper's TGQ.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.ctx import FPContext

_FP = FPContext()


@dataclasses.dataclass(frozen=True)
class DiffusionCfg:
    T: int = 1000                  # training timesteps
    beta_start: float = 1e-4
    beta_end: float = 0.02
    schedule: str = "linear"       # linear | cosine
    tgq_groups: int = 10           # G in the paper (group index fed to ctx)


def make_schedule(cfg: DiffusionCfg):
    """Returns dict of (T,) float32 schedule arrays."""
    if cfg.schedule == "linear":
        betas = np.linspace(cfg.beta_start, cfg.beta_end, cfg.T, dtype=np.float64)
    elif cfg.schedule == "cosine":
        s = 0.008
        ts = np.arange(cfg.T + 1, dtype=np.float64) / cfg.T
        f = np.cos((ts + s) / (1 + s) * np.pi / 2) ** 2
        betas = np.clip(1 - f[1:] / f[:-1], 0, 0.999)
    else:
        raise ValueError(cfg.schedule)
    alphas = 1.0 - betas
    abar = np.cumprod(alphas)
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    post_var = betas * (1.0 - abar_prev) / (1.0 - abar)   # q(x_{t-1}|x_t,x_0)
    j = lambda a: jnp.asarray(a, jnp.float32)
    return {
        "betas": j(betas), "alphas": j(alphas), "abar": j(abar),
        "abar_prev": j(abar_prev),
        "sqrt_abar": j(np.sqrt(abar)),
        "sqrt_1m_abar": j(np.sqrt(1 - abar)),
        "post_var": j(post_var),
        "post_logvar": j(np.log(np.maximum(post_var, 1e-20))),
    }


# ---------------------------------------------------------------------------
# forward process + loss
# ---------------------------------------------------------------------------
def q_sample(sched, x0, t, noise):
    """x_t = sqrt(abar_t) x0 + sqrt(1-abar_t) eps; t: (B,) int32."""
    shape = (-1,) + (1,) * (x0.ndim - 1)
    a = sched["sqrt_abar"][t].reshape(shape)
    b = sched["sqrt_1m_abar"][t].reshape(shape)
    return a * x0 + b * noise


def ddpm_loss(eps_fn: Callable, sched, x0, t, y, key):
    """E ||eps - eps_theta(x_t, t)||^2 (Eq. 11)."""
    noise = jax.random.normal(key, x0.shape, x0.dtype)
    xt = q_sample(sched, x0, t, noise)
    pred = eps_fn(xt, t, y)
    return jnp.mean(jnp.square(pred - noise))


# ---------------------------------------------------------------------------
# respacing (DDPM T=1000 -> 100/250 inference steps)
# ---------------------------------------------------------------------------
def respaced_timesteps(T: int, steps: int) -> np.ndarray:
    """Evenly respaced subset of {0..T-1}, descending (sampling order)."""
    ts = np.linspace(0, T - 1, steps).round().astype(np.int64)
    return np.unique(ts)[::-1].copy()


def respaced_schedule(sched, use_ts: np.ndarray):
    """Rebuild alphas/betas over the respaced chain (Nichol & Dhariwal)."""
    abar = np.asarray(sched["abar"])[use_ts[::-1]]        # ascending
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    alphas = abar / abar_prev
    betas = 1.0 - alphas
    post_var = betas * (1.0 - abar_prev) / (1.0 - abar)
    j = lambda a: jnp.asarray(a, jnp.float32)
    return {
        "betas": j(betas), "alphas": j(alphas), "abar": j(abar),
        "abar_prev": j(abar_prev),
        "sqrt_abar": j(np.sqrt(abar)), "sqrt_1m_abar": j(np.sqrt(1 - abar)),
        "post_var": j(post_var),
        "post_logvar": j(np.log(np.maximum(post_var, 1e-20))),
    }


def tgroup_of(t, T: int, G: int):
    """TGQ group index g(t) = floor(t*G/T) for original-chain timestep t."""
    return jnp.clip((t * G) // T, 0, G - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# ancestral sampler
# ---------------------------------------------------------------------------
def ddpm_sample(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y, key,
                steps: Optional[int] = None, ctx=_FP,
                clip_x0: Optional[float] = None):
    """Ancestral DDPM sampling with respacing.

    eps_fn(x, t, y, ctx) -> predicted noise, where t is the ORIGINAL-chain
    timestep (the model was trained on it). The context receives the TGQ
    group of t at every step.
    Returns x_0 samples of ``shape``.
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)             # descending
    rsched = respaced_schedule(sched, use_ts)
    n = len(use_ts)
    use_ts_j = jnp.asarray(use_ts.copy(), jnp.int32)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)

    def step(carry, i):
        x, key = carry
        key, kn = jax.random.split(key)
        t_orig = use_ts_j[i]                              # original-chain t
        idx = n - 1 - i                                   # respaced index (asc)
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = tgroup_of(t_orig, cfg.T, cfg.tgq_groups)
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]

        # predict x0, clip, then q(x_{t-1} | x_t, x0) mean
        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        noise = jax.random.normal(kn, shape, jnp.float32)
        nonzero = (idx > 0).astype(jnp.float32)
        x = mean + nonzero * jnp.sqrt(rsched["post_var"][idx]) * noise
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), jnp.arange(n))
    return x


def request_keys(seeds) -> jnp.ndarray:
    """(B,) per-request integer seeds -> (B, 2) uint32 PRNG keys.

    Serving draws ALL of a request's noise from its own key (see
    ``ddpm_sample_paired``), so a request's sample depends only on its
    seed — never on which microbatch slot, padding, or device shard it
    happens to land in.
    """
    return jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds, jnp.uint32))


def ddpm_sample_paired(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       seeds, guidance, *, null_label: int,
                       steps: Optional[int] = None, ctx=_FP,
                       clip_x0: Optional[float] = None):
    """Serving-path ancestral sampler: CFG-paired forwards, per-request keys.

    Two differences from :func:`ddpm_sample` (the research sampler):

    - **Per-request noise.** Every request carries its own PRNG seed; all
      noise is drawn per SAMPLE as ``normal(fold_in(PRNGKey(seed), i))``
      (``i`` = scan position, ``i = n`` for the initial latent). A
      request's output is therefore bit-identical no matter how the
      scheduler packs it into microbatches, how much padding rides along,
      or how the batch is sharded across devices — the property the
      sharded-vs-single-device serving tests pin down.
    - **Classifier-free guidance in one batched forward.** Each step runs
      the model ONCE on a 2B batch — the conditional half ``y`` stacked on
      the unconditional half ``null_label`` — and combines
      ``eps = eps_u + s * (eps_c - eps_u)`` with a PER-REQUEST scale
      ``s = guidance[b]`` (s=1: plain conditional, s=0: unconditional).

    The TGQ timestep group is threaded through ``ctx.with_tgroup`` exactly
    as in ``ddpm_sample``, so quantized serving (fused int8 kernels with
    stacked per-group params) compiles once across all groups.

    y: (B,) int labels; seeds: (B,) int per-request seeds;
    guidance: (B,) float CFG scales. Returns x_0 samples of ``shape``.
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)             # descending
    rsched = respaced_schedule(sched, use_ts)
    n = len(use_ts)
    use_ts_j = jnp.asarray(use_ts.copy(), jnp.int32)
    B = shape[0]

    keys = request_keys(seeds)
    sshape = tuple(shape[1:])                             # per-sample shape

    def draw(salt):
        """Per-sample noise: each request's key, folded with the step."""
        return jax.vmap(lambda k: jax.random.normal(
            jax.random.fold_in(k, salt), sshape, jnp.float32))(keys)

    gsc = jnp.asarray(guidance, jnp.float32).reshape(
        (B,) + (1,) * (len(shape) - 1))
    yy = jnp.concatenate([jnp.asarray(y, jnp.int32),
                          jnp.full((B,), null_label, jnp.int32)])

    x = draw(n)                                           # initial latent

    def step(x, i):
        t_orig = use_ts_j[i]                              # original-chain t
        idx = n - 1 - i                                   # respaced index (asc)
        tb = jnp.full((2 * B,), t_orig, jnp.int32)
        g = tgroup_of(t_orig, cfg.T, cfg.tgq_groups)
        eps2 = eps_fn(jnp.concatenate([x, x]), tb, yy, ctx.with_tgroup(g))
        eps_c, eps_u = jnp.split(eps2, 2)
        eps = eps_u + gsc * (eps_c - eps_u)

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]

        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        noise = draw(i)
        nonzero = (idx > 0).astype(jnp.float32)
        x = mean + nonzero * jnp.sqrt(rsched["post_var"][idx]) * noise
        return x, None

    x, _ = jax.lax.scan(step, x, jnp.arange(n))
    return x


# ---------------------------------------------------------------------------
# slot-wise chunked sampler (continuous batching)
# ---------------------------------------------------------------------------
def make_slot_schedule(cfg: DiffusionCfg, sched, step_buckets):
    """Stacked per-bucket respaced schedules for :func:`ddpm_chunk_slots`.

    The async engine's slot pool mixes requests from DIFFERENT step
    buckets (and different positions within them) in one dispatch, so the
    chunk executable gathers its schedule per slot: each configured bucket
    ``b`` contributes one row of ``use_ts`` (descending original-chain
    timesteps) and of every respaced-schedule array (ascending respaced
    index, exactly ``respaced_schedule``'s layout), padded to the longest
    bucket. Padding cells are never gathered — the per-slot respaced index
    is always clamped into ``[0, n_of[bucket])``.
    """
    buckets = tuple(sorted(int(b) for b in step_buckets))
    uts = [respaced_timesteps(cfg.T, b) for b in buckets]
    rss = [respaced_schedule(sched, u) for u in uts]
    n_of = np.asarray([len(u) for u in uts], np.int32)
    n_max = int(n_of.max())
    use_ts = np.zeros((len(buckets), n_max), np.int32)
    fields = ("abar", "abar_prev", "betas", "alphas", "post_var")
    stk = {f: np.full((len(buckets), n_max), 0.5, np.float32)
           for f in fields}
    for k, (u, rs) in enumerate(zip(uts, rss)):
        use_ts[k, :len(u)] = u
        for f in fields:
            stk[f][k, :len(u)] = np.asarray(rs[f])
    out = {"buckets": buckets, "n_of": jnp.asarray(n_of),
           "use_ts": jnp.asarray(use_ts)}
    out.update({f: jnp.asarray(stk[f]) for f in fields})
    return out


def ddpm_init_latent(seed, n, sshape):
    """The initial latent of :func:`ddpm_sample_paired` for one request:
    ``normal(fold_in(PRNGKey(seed), n))`` where ``n`` is the request's
    respaced chain length (``seed``/``n`` may be traced)."""
    return jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(seed), n), tuple(sshape),
        jnp.float32)


def ddpm_chunk_slots(eps_fn: Callable, cfg: DiffusionCfg, slot_sched,
                     x, pos, bk, y, seeds, guidance, *, null_label: int,
                     chunk: int, ctx=_FP, clip_x0: Optional[float] = None):
    """Advance every slot ``chunk`` denoising steps from its OWN position.

    The continuous-batching core: ``x[b]`` is slot ``b``'s latent,
    ``pos[b]`` its scan position in bucket ``bk[b]``'s respaced chain
    (``slot_sched`` from :func:`make_slot_schedule`). A slot with
    ``pos >= n_of[bk]`` is finished/free; its latent and position pass
    through unchanged (``jnp.where`` gating on the batched update).

    Bit-identity contract: a slot's trajectory is bit-identical to
    ``ddpm_sample_paired`` run on its request alone — same
    ``fold_in(PRNGKey(seed), i)`` noise (``i`` = scan position), same
    CFG-paired forward ordering (conditional half stacked on the
    unconditional half), same update arithmetic.

    One-weight-read contract (vector-tgroup batched path): each chunk
    step runs the model ONCE on the 2B CFG-stacked slot batch. Per-slot
    timesteps ride as a vector ``t`` and the per-slot TGQ groups as a
    (2B,) vector through ``ctx.with_tgroup`` — the fused serving kernels
    gather each row's group params in VMEM (``*_vec`` family), so the
    model weights stream ONCE PER DISPATCH regardless of how many slots
    are active or how their timesteps mix. Per-dispatch cost is flat in
    the active-slot count (``benchmarks/serve_throughput.py`` and
    ``benchmarks/kernel_micro.py --vector-tgq`` charge and assert this),
    and the whole chunk loop stays one compiled executable across all
    timestep mixtures.

    Returns ``(x, pos, bad)``; ``bad[b]`` flags any non-finite value in
    slot ``b``'s latent — the post-chunk NaN/Inf quarantine guard, checked
    on device so the host never pulls the pool to look for poison.
    """
    S = slot_sched
    n_of, use_ts = S["n_of"], S["use_ts"]
    B = x.shape[0]
    bshape = (B,) + (1,) * (x.ndim - 1)
    sshape = tuple(x.shape[1:])

    n = n_of[bk]                                      # (B,) chain lengths
    yy = jnp.concatenate([jnp.asarray(y, jnp.int32),
                          jnp.full((B,), null_label, jnp.int32)])
    gsc = jnp.asarray(guidance, jnp.float32).reshape(bshape)

    def draw(i):
        """Per-slot noise at per-slot scan positions ``i`` — the exact
        ``fold_in(PRNGKey(seed), i)`` keys of ``ddpm_sample_paired``."""
        return jax.vmap(lambda sd, ii: jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(sd), ii), sshape,
            jnp.float32))(seeds, i)

    def body(carry, _):
        xc, pc = carry
        run = pc < n
        i = jnp.minimum(pc, n - 1)                    # safe gather when done
        idx = n - 1 - i                               # respaced index (asc)
        t_orig = use_ts[bk, i]                        # (B,) original-chain t
        g = tgroup_of(t_orig, cfg.T, cfg.tgq_groups)  # (B,) per-slot groups
        eps2 = eps_fn(jnp.concatenate([xc, xc]),
                      jnp.concatenate([t_orig, t_orig]), yy,
                      ctx.with_tgroup(jnp.concatenate([g, g])))
        eps_c, eps_u = jnp.split(eps2, 2)
        eps = eps_u + gsc * (eps_c - eps_u)

        abar = S["abar"][bk, idx].reshape(bshape)
        abar_prev = S["abar_prev"][bk, idx].reshape(bshape)
        beta = S["betas"][bk, idx].reshape(bshape)
        alpha = S["alphas"][bk, idx].reshape(bshape)
        x0 = (xc - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * xc)
        noise = draw(i)
        nonzero = (idx > 0).astype(jnp.float32).reshape(bshape)
        xn = mean + nonzero * jnp.sqrt(
            S["post_var"][bk, idx].reshape(bshape)) * noise
        return (jnp.where(run.reshape(bshape), xn, xc),
                jnp.where(run, pc + 1, pc)), None

    (x, pos), _ = jax.lax.scan(body, (x, pos), None, length=chunk)
    bad = ~jnp.all(jnp.isfinite(x.reshape(x.shape[0], -1)), axis=1)
    return x, pos, bad


def ddpm_sample_python(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       key, steps: Optional[int] = None, ctx=_FP,
                       clip_x0: Optional[float] = None):
    """Python-loop sampler (for calibration capture: the PTQ engine's eager
    contexts record per-step activations, which lax.scan would hide)."""
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)
    rsched = respaced_schedule(sched, use_ts)
    rsched = jax.tree.map(np.asarray, rsched)
    n = len(use_ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), cfg.T, cfg.tgq_groups))
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - np.sqrt(1 - abar) * eps) / np.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (np.sqrt(abar_prev) * beta / (1 - abar) * x0
                + np.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        if idx > 0:
            x = mean + np.sqrt(rsched["post_var"][idx]) * jax.random.normal(
                kn, shape, jnp.float32)
        else:
            x = mean
    return x


def collect_xt_dataset(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       key, steps: int, want_ts: np.ndarray, ctx=_FP):
    """Run the sampler and harvest (x_t, t, y) tuples at the requested
    original-chain timesteps — Phase 1 of Algorithm 1 (calibration set
    built from the model's OWN sampling trajectory, matching Q-Diffusion/
    TQ-DiT protocol).
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)
    rsched = jax.tree.map(np.asarray, respaced_schedule(sched, use_ts))
    n = len(use_ts)
    want = set(int(t) for t in want_ts)
    out = []

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i
        if t_orig in want:
            out.append((np.asarray(x), t_orig, np.asarray(y)))
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), cfg.T, cfg.tgq_groups))
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))
        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - np.sqrt(1 - abar) * eps) / np.sqrt(abar)
        mean = (np.sqrt(abar_prev) * beta / (1 - abar) * x0
                + np.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        if idx > 0:
            x = mean + np.sqrt(rsched["post_var"][idx]) * jax.random.normal(
                kn, shape, jnp.float32)
        else:
            x = mean
    return out
