"""DDPM substrate: noise schedules, forward process, training loss, and the
respaced ancestral sampler used by the paper (T_train=1000 linear schedule;
inference respaced to 100/250 steps as in DiT / TQ-DiT §IV-A).

All samplers thread the TGQ timestep-group index through the model context
(``ctx.with_tgroup(g)``) so time-grouped quantizers select the right
parameter set at each step — the inference-side half of the paper's TGQ.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.ctx import FPContext

_FP = FPContext()


@dataclasses.dataclass(frozen=True)
class DiffusionCfg:
    T: int = 1000                  # training timesteps
    beta_start: float = 1e-4
    beta_end: float = 0.02
    schedule: str = "linear"       # linear | cosine
    tgq_groups: int = 10           # G in the paper (group index fed to ctx)


def make_schedule(cfg: DiffusionCfg):
    """Returns dict of (T,) float32 schedule arrays."""
    if cfg.schedule == "linear":
        betas = np.linspace(cfg.beta_start, cfg.beta_end, cfg.T, dtype=np.float64)
    elif cfg.schedule == "cosine":
        s = 0.008
        ts = np.arange(cfg.T + 1, dtype=np.float64) / cfg.T
        f = np.cos((ts + s) / (1 + s) * np.pi / 2) ** 2
        betas = np.clip(1 - f[1:] / f[:-1], 0, 0.999)
    else:
        raise ValueError(cfg.schedule)
    alphas = 1.0 - betas
    abar = np.cumprod(alphas)
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    post_var = betas * (1.0 - abar_prev) / (1.0 - abar)   # q(x_{t-1}|x_t,x_0)
    j = lambda a: jnp.asarray(a, jnp.float32)
    return {
        "betas": j(betas), "alphas": j(alphas), "abar": j(abar),
        "abar_prev": j(abar_prev),
        "sqrt_abar": j(np.sqrt(abar)),
        "sqrt_1m_abar": j(np.sqrt(1 - abar)),
        "post_var": j(post_var),
        "post_logvar": j(np.log(np.maximum(post_var, 1e-20))),
    }


# ---------------------------------------------------------------------------
# forward process + loss
# ---------------------------------------------------------------------------
def q_sample(sched, x0, t, noise):
    """x_t = sqrt(abar_t) x0 + sqrt(1-abar_t) eps; t: (B,) int32."""
    shape = (-1,) + (1,) * (x0.ndim - 1)
    a = sched["sqrt_abar"][t].reshape(shape)
    b = sched["sqrt_1m_abar"][t].reshape(shape)
    return a * x0 + b * noise


def ddpm_loss(eps_fn: Callable, sched, x0, t, y, key):
    """E ||eps - eps_theta(x_t, t)||^2 (Eq. 11)."""
    noise = jax.random.normal(key, x0.shape, x0.dtype)
    xt = q_sample(sched, x0, t, noise)
    pred = eps_fn(xt, t, y)
    return jnp.mean(jnp.square(pred - noise))


# ---------------------------------------------------------------------------
# respacing (DDPM T=1000 -> 100/250 inference steps)
# ---------------------------------------------------------------------------
def respaced_timesteps(T: int, steps: int) -> np.ndarray:
    """Evenly respaced subset of {0..T-1}, descending (sampling order)."""
    ts = np.linspace(0, T - 1, steps).round().astype(np.int64)
    return np.unique(ts)[::-1].copy()


def respaced_schedule(sched, use_ts: np.ndarray):
    """Rebuild alphas/betas over the respaced chain (Nichol & Dhariwal)."""
    abar = np.asarray(sched["abar"])[use_ts[::-1]]        # ascending
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    alphas = abar / abar_prev
    betas = 1.0 - alphas
    post_var = betas * (1.0 - abar_prev) / (1.0 - abar)
    j = lambda a: jnp.asarray(a, jnp.float32)
    return {
        "betas": j(betas), "alphas": j(alphas), "abar": j(abar),
        "abar_prev": j(abar_prev),
        "sqrt_abar": j(np.sqrt(abar)), "sqrt_1m_abar": j(np.sqrt(1 - abar)),
        "post_var": j(post_var),
        "post_logvar": j(np.log(np.maximum(post_var, 1e-20))),
    }


def tgroup_of(t, T: int, G: int):
    """TGQ group index g(t) = floor(t*G/T) for original-chain timestep t."""
    return jnp.clip((t * G) // T, 0, G - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# ancestral sampler
# ---------------------------------------------------------------------------
def ddpm_sample(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y, key,
                steps: Optional[int] = None, ctx=_FP, guidance: float = 0.0,
                clip_x0: Optional[float] = None):
    """Ancestral DDPM sampling with respacing.

    eps_fn(x, t, y, ctx) -> predicted noise, where t is the ORIGINAL-chain
    timestep (the model was trained on it). The context receives the TGQ
    group of t at every step.
    Returns x_0 samples of ``shape``.
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)             # descending
    rsched = respaced_schedule(sched, use_ts)
    n = len(use_ts)
    use_ts_j = jnp.asarray(use_ts.copy(), jnp.int32)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)

    def step(carry, i):
        x, key = carry
        key, kn = jax.random.split(key)
        t_orig = use_ts_j[i]                              # original-chain t
        idx = n - 1 - i                                   # respaced index (asc)
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = tgroup_of(t_orig, cfg.T, cfg.tgq_groups)
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]

        # predict x0, clip, then q(x_{t-1} | x_t, x0) mean
        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        noise = jax.random.normal(kn, shape, jnp.float32)
        nonzero = (idx > 0).astype(jnp.float32)
        x = mean + nonzero * jnp.sqrt(rsched["post_var"][idx]) * noise
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), jnp.arange(n))
    return x


def ddpm_sample_python(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       key, steps: Optional[int] = None, ctx=_FP,
                       clip_x0: Optional[float] = None):
    """Python-loop sampler (for calibration capture: the PTQ engine's eager
    contexts record per-step activations, which lax.scan would hide)."""
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)
    rsched = respaced_schedule(sched, use_ts)
    rsched = jax.tree.map(np.asarray, rsched)
    n = len(use_ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), cfg.T, cfg.tgq_groups))
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - np.sqrt(1 - abar) * eps) / np.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (np.sqrt(abar_prev) * beta / (1 - abar) * x0
                + np.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        if idx > 0:
            x = mean + np.sqrt(rsched["post_var"][idx]) * jax.random.normal(
                kn, shape, jnp.float32)
        else:
            x = mean
    return x


def collect_xt_dataset(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       key, steps: int, want_ts: np.ndarray, ctx=_FP):
    """Run the sampler and harvest (x_t, t, y) tuples at the requested
    original-chain timesteps — Phase 1 of Algorithm 1 (calibration set
    built from the model's OWN sampling trajectory, matching Q-Diffusion/
    TQ-DiT protocol).
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)
    rsched = jax.tree.map(np.asarray, respaced_schedule(sched, use_ts))
    n = len(use_ts)
    want = set(int(t) for t in want_ts)
    out = []

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i
        if t_orig in want:
            out.append((np.asarray(x), t_orig, np.asarray(y)))
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), cfg.T, cfg.tgq_groups))
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))
        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - np.sqrt(1 - abar) * eps) / np.sqrt(abar)
        mean = (np.sqrt(abar_prev) * beta / (1 - abar) * x0
                + np.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        if idx > 0:
            x = mean + np.sqrt(rsched["post_var"][idx]) * jax.random.normal(
                kn, shape, jnp.float32)
        else:
            x = mean
    return out
